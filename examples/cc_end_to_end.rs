//! End-to-end driver (the repository's full-stack validation run):
//! weighted correlation clustering on a sparse signed power-law graph,
//! the §4.2.2 workload, exercising every layer —
//!
//! 1. workload synthesis (Slashdot-like Chung–Lu signed graph with
//!    planted communities),
//! 2. the PROJECT AND FORGET solve (Algorithm 7: collect-mode METRIC
//!    VIOLATIONS oracle + 75 inner project/forget sweeps),
//! 3. the paper's headline metrics: implicit constraint count vs the
//!    active set actually remembered, time, approximation-ratio
//!    certificate, exponential violation decay (Figure 3),
//! 4. pivot rounding and recovery quality against the planted truth,
//! 5. (when artifacts are built) a PJRT cross-check of the oracle's APSP
//!    certificate on a padded subgraph.
//!
//! Scaled by `--nodes` (default 2000; Table 3's 82k/132k shapes are
//! reachable on a big box with `--nodes 82140`).
//!
//! ```bash
//! cargo run --release --example cc_end_to_end -- --nodes 2000
//! ```

use paf::coordinator::{figure2_series, figure3_series, violation_decay_rate};
use paf::graph::generators::{chung_lu_power_law, planted_signed};
use paf::core::problem::SolveOptions;
use paf::problems::correlation::{CcInstance, Correlation};
use paf::util::cli::Args;
use paf::util::table::Table;
use paf::util::timer::{fmt_bytes, peak_rss_bytes};
use paf::util::{Rng, Stopwatch};

fn main() {
    let args = Args::parse_from(std::env::args().skip(1));
    let n = args.get_parsed_or("nodes", 2000usize);
    let seed = args.get_parsed_or("seed", 7u64);
    let clusters = args.get_parsed_or("clusters", 20usize);
    let noise = args.get_parsed_or("noise", 0.05f64);

    // --- 1. Workload: signed sparse graph with planted communities.
    let mut rng = Rng::new(seed);
    let build = Stopwatch::new();
    let g = chung_lu_power_law(n, 11.0, 2.5, &mut rng);
    let (sg, truth) = planted_signed(g, clusters, noise, &mut rng);
    let inst = CcInstance::from_signed(&sg);
    let nn = inst.graph.num_nodes() as f64;
    // The traditional LP would carry O(n³) triangle rows (Table 3 quotes
    // the full cycle-inequality count; we report the n³ triangle count).
    let implicit = nn * (nn - 1.0) * (nn - 2.0) / 2.0;
    println!(
        "workload: n={} m={} planted k={clusters} noise={noise} (built {:.2}s)",
        inst.graph.num_nodes(),
        inst.graph.num_edges(),
        build.elapsed_s()
    );
    println!("implicit triangle-constraint count: {implicit:.3e}");

    // --- 2. Solve (Algorithm 7 config).
    let opts = SolveOptions::new()
        .violation_tol(args.get_parsed_or("tol", 1e-2))
        .max_iters(args.get_parsed_or("max-iters", 120usize));
    let res = Correlation::sparse(&inst).seed(seed).solve(&opts);

    // --- 3. Headline metrics (Table 3's row shape).
    let mut t = Table::new(
        "sparse weighted correlation clustering (Table 3 shape)",
        &["n", "#constraints", "time", "opt ratio", "#active", "iters"],
    );
    t.rowd(&[
        inst.graph.num_nodes().to_string(),
        format!("{implicit:.2e}"),
        format!("{:.1}s", res.result.seconds),
        format!("{:.2}", res.approx_ratio),
        res.result.active_constraints.to_string(),
        res.result.iterations.to_string(),
    ]);
    t.emit("reports", "cc_end_to_end");
    println!("peak RSS: {}", fmt_bytes(peak_rss_bytes()));
    if let Some(rate) = violation_decay_rate(&res.result) {
        println!("violation decay per iteration: {rate:.4} (exponential iff < 1)");
    }
    figure2_series(&res.result, "constraints found vs remembered")
        .emit("reports", "cc_end_to_end_fig2");
    figure3_series(&res.result, "max violation").emit("reports", "cc_end_to_end_fig3");

    // --- 4. Rounding quality vs planted truth (rand index).
    let labels = &res.labels;
    let mut agree = 0usize;
    let mut total = 0usize;
    let mut pair_rng = Rng::new(seed ^ 0xabcd);
    for _ in 0..200_000 {
        let i = pair_rng.below(inst.graph.num_nodes());
        let j = pair_rng.below(inst.graph.num_nodes());
        if i == j {
            continue;
        }
        let same_truth = truth[i] == truth[j];
        let same_ours = labels[i] == labels[j];
        agree += (same_truth == same_ours) as usize;
        total += 1;
    }
    println!(
        "rounded clustering: objective {:.1} (LP cert lower bound {:.1}), rand index vs truth {:.3}",
        res.rounded_objective,
        res.lp_objective / res.approx_ratio,
        agree as f64 / total as f64
    );

    // --- 5. PJRT cross-check (optional, needs `make artifacts`).
    match paf::runtime::Runtime::load(paf::runtime::Runtime::default_dir()) {
        Ok(rt) => {
            let sub = 100.min(inst.graph.num_nodes());
            let p = rt.apsp_size_for(sub).expect("apsp artifact");
            let mut dist = vec![f32::INFINITY; p * p];
            for i in 0..sub {
                dist[i * p + i] = 0.0;
            }
            for (e, &(a, b)) in inst.graph.edges().iter().enumerate() {
                let (a, b) = (a as usize, b as usize);
                if a < sub && b < sub {
                    let w = res.result.x[e].max(0.0) as f32;
                    dist[a * p + b] = w;
                    dist[b * p + a] = w;
                }
            }
            rt.apsp_padded(&mut dist, p).expect("pjrt apsp");
            let mut worst = 0.0f32;
            for (e, &(a, b)) in inst.graph.edges().iter().enumerate() {
                let (a, b) = (a as usize, b as usize);
                if a < sub && b < sub {
                    worst = worst.max(res.result.x[e] as f32 - dist[a * p + b]);
                }
            }
            println!(
                "PJRT cross-check ({}): worst metric violation on {sub}-node subgraph: {worst:.2e}",
                rt.platform
            );
        }
        Err(e) => println!("PJRT cross-check skipped: {e}"),
    }

    assert!(res.result.converged, "end-to-end solve did not converge");
    println!("END-TO-END OK");
}
