//! Metric learning demos: PFITML (Table 4) and the truly stochastic
//! L2-SVM (Table 5) on synthetic datasets.
//!
//! ```bash
//! cargo run --release --example metric_learning
//! ```

use paf::baselines::itml_orig::{solve_itml_orig, ItmlOrigConfig};
use paf::baselines::svm_liblinear::{train_dual_cd, train_primal_newton};
use paf::ml::dataset::{svm_cloud, table4_dataset};
use paf::ml::knn::knn_accuracy;
use paf::ml::mahalanobis::Mat;
use paf::core::problem::SolveOptions;
use paf::problems::itml::{PfItml, PfItmlConfig};
use paf::problems::svm::{train_pf_svm, SvmConfig};
use paf::util::table::Table;
use paf::util::Rng;

fn main() {
    // ---------------- ITML (Table 4 shape, one dataset) ----------------
    let mut rng = Rng::new(3);
    let data = table4_dataset("ionosphere", &mut rng);
    let (mut train, mut test) = data.split(0.8, &mut rng);
    let (mean, std) = train.normalize();
    test.apply_transform(&mean, &std);
    let budget = 50_000;
    let pf = PfItml::new(&train, PfItmlConfig { max_projections: budget, seed: 3, ..Default::default() })
        .solve(&SolveOptions::default());
    let orig = solve_itml_orig(&train, &ItmlOrigConfig { max_projections: budget, seed: 3, ..Default::default() });
    let k = 4;
    let mut t = Table::new("ITML on ionosphere-like data (Table 4 shape)", &["method", "test acc"]);
    t.rowd(&["euclidean".to_string(), format!("{:.5}", knn_accuracy(&Mat::identity(train.d), &train, &test, k))]);
    t.rowd(&["pf-itml (ours)".to_string(), format!("{:.5}", knn_accuracy(&pf.m, &train, &test, k))]);
    t.rowd(&["itml (davis et al.)".to_string(), format!("{:.5}", knn_accuracy(&orig.m, &train, &test, k))]);
    t.emit("reports", "example_itml");
    println!(
        "pf-itml remembered {} active pairs; both methods capped at {budget} projections\n",
        pf.active_pairs
    );

    // ---------------- L2-SVM (Table 5 shape, small n) -------------------
    let mut rng = Rng::new(5);
    let n = 50_000;
    let (all, s) = svm_cloud(2 * n, 100, 10.0, &mut rng);
    let (tr, te) = all.split(0.5, &mut rng);
    println!("svm data: n={n} d=100 label noise s={:.1}%", s * 100.0);
    let ours = train_pf_svm(&tr, &SvmConfig { c: 1e3, epochs: 5, seed: 5 });
    let dual = train_dual_cd(&tr, 1e3, 1e-3, 10, 5);
    let primal = train_primal_newton(&tr, 1e3, 1e-3, 25);
    let mut t = Table::new("L2-SVM (Table 5 shape)", &["solver", "seconds", "test acc"]);
    t.rowd(&["ours (truly stochastic P&F)".to_string(), format!("{:.2}", ours.seconds), format!("{:.1}%", 100.0 * ours.accuracy(&te))]);
    t.rowd(&["liblinear dual".to_string(), format!("{:.2}", dual.seconds), format!("{:.1}%", 100.0 * dual.accuracy(&te))]);
    t.rowd(&["liblinear primal".to_string(), format!("{:.2}", primal.seconds), format!("{:.1}%", 100.0 * primal.accuracy(&te))]);
    t.emit("reports", "example_svm");
    println!("support vectors: {} of {}", ours.num_support(), tr.n);
}
