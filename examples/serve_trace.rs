//! Serving-subsystem demo: replay a mixed job trace through the
//! long-running scheduler — mid-solve admission, priorities, and one
//! (or more) forced checkpoint preemptions at capacity 1.
//!
//! ```bash
//! cargo run --release --example serve_trace
//! ```
//!
//! The trace is generated, serialised to the line-delimited JSON format
//! `paf serve --trace` consumes, and parsed back — so this example is
//! also living documentation of the trace format. With capacity 1 the
//! higher-priority arrivals must preempt the running job: the victim is
//! checkpointed ([`Session::evict`] under the hood), requeued, and
//! later resumed bit-identically to an uninterrupted run (pinned in
//! `rust/tests/determinism.rs`).
//!
//! Two environment variables drive the CI crash-recovery leg:
//!
//! - `PAF_SERVE_STATE_DIR=DIR` — serve with durable checkpoints in
//!   `DIR`, recovering any incomplete jobs found there on startup.
//! - `PAF_SERVE_FAULT=SPEC` — apply a deterministic
//!   [`FaultPlan`](paf::serve::FaultPlan) (e.g. `crash=6`); an injected
//!   crash persists running state and exits with code 42
//!   ([`CRASH_EXIT_CODE`]), so a restart against the same state dir
//!   must recover and finish with every result bit-identical to solo.

use paf::core::problem::SolveOptions;
use paf::serve::{
    demo_trace, emit_serve_json, parse_job_trace, solve_job_solo, FaultPlan, JobBank, Scheduler,
    ServeConfig, ServeEvent, CRASH_EXIT_CODE,
};

fn main() {
    // Generate the mixed nearness + CC demo trace and round-trip it
    // through the on-disk format.
    let trace_text: String = demo_trace(7)
        .iter()
        .map(|j| j.to_json_line() + "\n")
        .collect();
    println!("job trace (line-delimited JSON, `paf serve --trace` format):");
    print!("{trace_text}");
    let jobs = parse_job_trace(&trace_text).expect("generated trace must parse");

    let state_dir = std::env::var_os("PAF_SERVE_STATE_DIR").map(std::path::PathBuf::from);
    let fault_plan = match std::env::var("PAF_SERVE_FAULT") {
        Ok(spec) => FaultPlan::parse(&spec).expect("PAF_SERVE_FAULT must parse"),
        Err(_) => FaultPlan::default(),
    };

    // Materialize the instance arena, then serve with capacity 1: every
    // higher-priority arrival must preempt the running job.
    let bank = JobBank::materialize(&jobs);
    let opts = SolveOptions::new()
        .violation_tol(1e-4)
        .inner_sweeps(2) // mixed-kind traces pin the shared sweep count
        .sharded(0);
    let cfg = ServeConfig {
        capacity: 1,
        opts: opts.clone(),
        state_dir: state_dir.clone(),
        fault_plan,
        ..Default::default()
    };
    let mut scheduler =
        Scheduler::new(jobs.clone(), &bank, cfg).expect("valid serve config");
    scheduler.on_event(|event| match event {
        ServeEvent::Admitted { round, job, resumed } => {
            println!("round {round:>3}: admitted job {job}{}", if *resumed { " (resumed from checkpoint)" } else { "" })
        }
        ServeEvent::Preempted { round, job, rounds_done } => {
            println!("round {round:>3}: PREEMPTED job {job} after {rounds_done} solve rounds")
        }
        ServeEvent::Completed { round, job, converged } => {
            println!("round {round:>3}: job {job} completed (converged={converged})")
        }
        ServeEvent::Expired { round, job, rounds_done } => {
            println!("round {round:>3}: job {job} expired after {rounds_done} rounds")
        }
        ServeEvent::Recovered { round, job, rounds_done } => {
            println!("round {round:>3}: RECOVERED job {job} from durable checkpoint ({rounds_done} rounds done)")
        }
        ServeEvent::Shed { round, job, queue_depth } => {
            println!("round {round:>3}: shed job {job} (overload, {queue_depth} still queued)")
        }
        ServeEvent::Retried { round, job, attempt } => {
            println!("round {round:>3}: retry job {job} (attempt {attempt})")
        }
        ServeEvent::Quarantined { round, job, attempt } => {
            println!("round {round:>3}: quarantined job {job} (attempt {attempt})")
        }
        ServeEvent::Idle { .. } => {}
    });
    let stats = scheduler.run();

    if stats.crashed {
        println!(
            "\nINJECTED CRASH after round {}: running state persisted to {:?}; exiting 42",
            stats.rounds,
            state_dir.as_deref().unwrap_or(std::path::Path::new("<none>"))
        );
        std::process::exit(CRASH_EXIT_CODE);
    }

    println!(
        "\nserved {} jobs in {} scheduler rounds ({} preemptions, {} recovered)",
        stats.jobs.len(),
        stats.rounds,
        stats.preemptions,
        stats.recovered
    );
    for (k, j) in stats.jobs.iter().enumerate() {
        println!(
            "  job {k} ({}, prio {}): arrived r{}, done r{}, {} rounds run, {} projections, \
             preempted {}x, converged={}{}",
            j.name,
            j.priority,
            j.arrival_round,
            j.completed_round.map(|r| r.to_string()).unwrap_or_else(|| "-".to_string()),
            j.rounds_run,
            j.projections,
            j.preemptions,
            j.converged,
            if j.recovered { " (recovered)" } else { "" }
        );
    }
    assert!(stats.all_completed(), "demo trace must complete every job");
    assert!(
        stats.preemptions + stats.recovered >= 1,
        "capacity 1 with a priority spread must force a preemption (or this is a \
         recovery run resuming from checkpoints)"
    );

    // The serve/recovery invariant, checked end to end: every job's
    // result is bit-identical to its uninterrupted solo solve — even
    // when this process recovered the job from another process's
    // durable checkpoint.
    for (k, j) in jobs.iter().enumerate() {
        let solo = solve_job_solo(j, bank.input(j.id), &opts).expect("solo solve");
        let got = stats.jobs[k].result.as_ref().expect("completed job without result");
        assert_eq!(solo.result.x, got.x, "job {k}: served x differs from solo (bitwise)");
        assert_eq!(solo.result.iterations, got.iterations, "job {k}: iterations differ");
        assert_eq!(
            solo.result.total_projections, got.total_projections,
            "job {k}: projections differ"
        );
        assert_eq!(stats.jobs[k].objective, Some(solo.objective), "job {k}: objective differs");
    }
    println!("all jobs bit-identical to their solo solves");
    let _ = emit_serve_json(&stats, "SERVE_demo_trace");
}
