//! Serving-subsystem demo: replay a mixed job trace through the
//! long-running scheduler — mid-solve admission, priorities, and one
//! (or more) forced checkpoint preemptions at capacity 1.
//!
//! ```bash
//! cargo run --release --example serve_trace
//! ```
//!
//! The trace is generated, serialised to the line-delimited JSON format
//! `paf serve --trace` consumes, and parsed back — so this example is
//! also living documentation of the trace format. With capacity 1 the
//! higher-priority arrivals must preempt the running job: the victim is
//! checkpointed ([`Session::evict`] under the hood), requeued, and
//! later resumed bit-identically to an uninterrupted run (pinned in
//! `rust/tests/determinism.rs`).

use paf::core::problem::SolveOptions;
use paf::serve::{
    demo_trace, emit_serve_json, parse_job_trace, JobBank, Scheduler, ServeConfig, ServeEvent,
};

fn main() {
    // Generate the mixed nearness + CC demo trace and round-trip it
    // through the on-disk format.
    let trace_text: String = demo_trace(7)
        .iter()
        .map(|j| j.to_json_line() + "\n")
        .collect();
    println!("job trace (line-delimited JSON, `paf serve --trace` format):");
    print!("{trace_text}");
    let jobs = parse_job_trace(&trace_text).expect("generated trace must parse");

    // Materialize the instance arena, then serve with capacity 1: every
    // higher-priority arrival must preempt the running job.
    let bank = JobBank::materialize(&jobs);
    let opts = SolveOptions::new()
        .violation_tol(1e-4)
        .inner_sweeps(2) // mixed-kind traces pin the shared sweep count
        .sharded(0);
    let cfg = ServeConfig { capacity: 1, opts, ..Default::default() };
    let mut scheduler = Scheduler::new(jobs, &bank, cfg);
    scheduler.on_event(|event| match event {
        ServeEvent::Admitted { round, job, resumed } => {
            println!("round {round:>3}: admitted job {job}{}", if *resumed { " (resumed from checkpoint)" } else { "" })
        }
        ServeEvent::Preempted { round, job, rounds_done } => {
            println!("round {round:>3}: PREEMPTED job {job} after {rounds_done} solve rounds")
        }
        ServeEvent::Completed { round, job, converged } => {
            println!("round {round:>3}: job {job} completed (converged={converged})")
        }
        ServeEvent::Expired { round, job, rounds_done } => {
            println!("round {round:>3}: job {job} expired after {rounds_done} rounds")
        }
        ServeEvent::Idle { .. } => {}
    });
    let stats = scheduler.run();

    println!(
        "\nserved {} jobs in {} scheduler rounds ({} preemptions)",
        stats.jobs.len(),
        stats.rounds,
        stats.preemptions
    );
    for (k, j) in stats.jobs.iter().enumerate() {
        println!(
            "  job {k} ({}, prio {}): arrived r{}, done r{}, {} rounds run, {} projections, \
             preempted {}x, converged={}",
            j.name,
            j.priority,
            j.arrival_round,
            j.completed_round.map(|r| r.to_string()).unwrap_or_else(|| "-".to_string()),
            j.rounds_run,
            j.projections,
            j.preemptions,
            j.converged
        );
    }
    assert!(stats.all_completed(), "demo trace must complete every job");
    assert!(
        stats.preemptions >= 1,
        "capacity 1 with a priority spread must force at least one preemption"
    );
    let _ = emit_serve_json(&stats, "SERVE_demo_trace");
}
