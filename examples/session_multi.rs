//! Multi-instance `Session` demo: a fleet of metric-nearness instances
//! plus an ITML fold, solved together through the unified solve API —
//! with live events, a mid-solve checkpoint, and per-instance results.
//!
//! ```bash
//! cargo run --release --example session_multi
//! ```
//!
//! The three nearness instances are mapped into block-offset regions of
//! ONE variable vector; with the sharded executor the support-disjoint
//! planner packs rows from all of them into the same shards, so a
//! single sharded sweep advances the whole fleet. The ITML fold rides
//! along as a round-driven block. Every per-instance result is
//! bit-identical to solving that instance alone (see
//! `rust/tests/determinism.rs`).

use paf::core::problem::{SolveEvent, SolveOptions};
use paf::core::session::Session;
use paf::graph::generators::type1_complete;
use paf::ml::dataset::gaussian_mixture;
use paf::problems::itml::{PfItml, PfItmlConfig};
use paf::problems::metric_oracle::OracleMode;
use paf::problems::nearness::Nearness;
use paf::util::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let instances: Vec<_> = [40usize, 56, 48].iter().map(|&n| type1_complete(n, &mut rng)).collect();
    let fold = gaussian_mixture(150, 4, 3, 2.5, &mut rng);
    let itml_cfg = PfItmlConfig { max_projections: 8_000, batch: 100, seed: 7, ..Default::default() };

    // One option set for the whole fleet: sharded sweeps, auto threads.
    let opts = SolveOptions::new().violation_tol(1e-4).dual_tol(1e-4).sharded(0);

    let mut session = Session::new(opts);
    let near_handles: Vec<_> = instances
        .iter()
        .map(|inst| session.add(Nearness::new(inst).mode(OracleMode::Collect)))
        .collect();
    let itml_handle = session.add(PfItml::new(&fold, itml_cfg));

    session.on_event(|event| match event {
        SolveEvent::Round(ev) => println!(
            "round {:>3}: {} live blocks, {} found, {} remembered, worst violation {:.2e} \
             (oracle {:.1}ms / sweep {:.1}ms / forget {:.1}ms)",
            ev.round,
            ev.live_blocks,
            ev.found,
            ev.remembered,
            ev.max_violation,
            ev.phases.oracle_s * 1e3,
            ev.phases.sweep_s * 1e3,
            ev.phases.forget_s * 1e3,
        ),
        SolveEvent::BlockDone(done) => println!(
            "  -> block {} ({}) done: converged={} after {} rounds, {} projections",
            done.block, done.name, done.converged, done.iterations, done.projections
        ),
        _ => {}
    });

    // Drive a few rounds stepwise, checkpoint, then run to completion —
    // the checkpoint could equally be restored into a fresh process.
    for _ in 0..2 {
        session.step();
    }
    let ck = session.checkpoint();
    println!(
        "checkpoint at round {}: {} remembered constraints captured",
        ck.round(),
        ck.remembered()
    );
    let summary = session.run();
    println!(
        "fleet finished: {} rounds, all_converged={}, cancelled={}",
        summary.rounds, summary.all_converged, summary.cancelled
    );

    for (k, h) in near_handles.into_iter().enumerate() {
        let res = session.take_unwrap(h);
        assert!(res.result.converged, "nearness block {k} did not converge");
        println!(
            "nearness[{k}]: {} iterations, {} projections, objective {:.4}",
            res.result.iterations, res.result.total_projections, res.objective
        );
    }
    let itml = session.take_unwrap(itml_handle);
    println!(
        "itml fold: {} projections, {} active pairs",
        itml.projections, itml.active_pairs
    );
    assert!(itml.projections >= 8_000);
}
