//! Metric nearness: PROJECT AND FORGET vs triangle fixing (Brickell et
//! al. 2008) on one type-1 instance — a single-row preview of Table 1.
//!
//! ```bash
//! cargo run --release --example nearness_vs_brickell -- --n 150
//! ```

use paf::baselines::brickell::triangle_fixing;
use paf::graph::generators::type1_complete;
use paf::core::problem::SolveOptions;
use paf::problems::nearness::Nearness;
use paf::util::cli::Args;
use paf::util::table::Table;
use paf::util::Rng;

fn main() {
    let args = Args::parse_from(std::env::args().skip(1));
    let n = args.get_parsed_or("n", 150usize);
    let tol = args.get_parsed_or("tol", 1e-2f64);
    let mut rng = Rng::new(args.get_parsed_or("seed", 1u64));
    let inst = type1_complete(n, &mut rng);

    let pf = Nearness::new(&inst).solve(&SolveOptions::new().violation_tol(tol));
    let br = triangle_fixing(n, &inst.weights, tol, 10_000);

    let mut t = Table::new(
        &format!("metric nearness, type-1 K_{n} (Table 1 row)"),
        &["algorithm", "seconds", "converged", "objective ½‖x−d‖²"],
    );
    let obj = |x: &[f64]| -> f64 {
        x.iter().zip(&inst.weights).map(|(a, b)| 0.5 * (a - b) * (a - b)).sum()
    };
    t.rowd(&[
        "project-and-forget".to_string(),
        format!("{:.2}", pf.result.seconds),
        pf.result.converged.to_string(),
        format!("{:.4}", pf.objective),
    ]);
    t.rowd(&[
        "brickell triangle-fixing".to_string(),
        format!("{:.2}", br.seconds),
        br.converged.to_string(),
        format!("{:.4}", obj(&br.x)),
    ]);
    t.emit("reports", "example_nearness_vs_brickell");

    // Both solve the same strictly convex QP: objectives must agree.
    let gap = (obj(&br.x) - pf.objective).abs() / pf.objective.max(1e-9);
    println!("relative objective gap: {gap:.2e}");
    println!(
        "P&F active constraints: {} (vs {} triangle duals Brickell carries)",
        pf.result.active_constraints,
        br.dual_bytes / 8
    );
}
