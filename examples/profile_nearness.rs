//! Profiling driver for the §Perf loop: a fixed metric-nearness solve
//! (type-1, n=260) run three times, suitable for `perf record`:
//!
//! ```bash
//! cargo build --release --example profile_nearness
//! perf record -g ./target/release/examples/profile_nearness
//! perf report --stdio --no-children -g none
//! ```

use paf::graph::generators::type1_complete;
use paf::core::problem::SolveOptions;
use paf::problems::nearness::Nearness;
use paf::util::Rng;

fn main() {
    let mut rng = Rng::new(53);
    let inst = type1_complete(260, &mut rng);
    for _ in 0..3 {
        let res = Nearness::new(&inst).solve(&SolveOptions::new().violation_tol(1e-2));
        assert!(res.result.converged);
        println!(
            "iters {} projections {} seconds {:.3}",
            res.result.iterations, res.result.total_projections, res.result.seconds
        );
    }
}
