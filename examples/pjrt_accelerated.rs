//! The three-layer stack in one view: solve the same dense metric
//! nearness instance with (a) the native Dijkstra oracle and (b) the
//! PJRT-backed oracle whose APSP certificate is the AOT-compiled
//! JAX/Pallas min-plus kernel, then run one batched projection sweep
//! through the `project_*` artifact.
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example pjrt_accelerated
//! ```

use paf::coordinator::batch_project::{batched_sweep, BatchShape};
use paf::coordinator::pjrt_oracle::PjrtMetricOracle;
use paf::core::bregman::DiagonalQuadratic;
use paf::core::solver::{Solver, SolverConfig};
use paf::graph::generators::type1_complete;
use paf::problems::metric_oracle::{max_metric_violation, MetricOracle, OracleMode};
use paf::runtime::Runtime;
use paf::util::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load(Runtime::default_dir())?);
    println!("PJRT platform: {} ({} artifacts)", rt.platform, rt.artifacts.len());

    let mut rng = Rng::new(9);
    let inst = type1_complete(100, &mut rng); // pads into apsp_n128
    let graph = Arc::new(inst.graph.clone());

    let cfg = SolverConfig {
        max_iters: 400,
        inner_sweeps: 4,
        violation_tol: 1e-3,
        dual_tol: f64::INFINITY,
        ..Default::default()
    };

    // (a) native oracle.
    let f = DiagonalQuadratic::unweighted(inst.weights.clone());
    let mut s_native = Solver::new(f, cfg.clone());
    let r_native = s_native.solve(MetricOracle::new(graph.clone(), OracleMode::ProjectOnFind));
    println!(
        "native  : {} iters, {:.2}s, {} active, viol {:.2e}",
        r_native.iterations,
        r_native.seconds,
        r_native.active_constraints,
        max_metric_violation(&inst.graph, &r_native.x)
    );

    // (b) PJRT oracle (AOT min-plus certificate + targeted Dijkstra).
    let f = DiagonalQuadratic::unweighted(inst.weights.clone());
    let mut s_pjrt = Solver::new(f, cfg);
    let r_pjrt = s_pjrt.solve(PjrtMetricOracle::new(graph.clone(), rt.clone())?);
    println!(
        "pjrt    : {} iters, {:.2}s, {} active, viol {:.2e}",
        r_pjrt.iterations,
        r_pjrt.seconds,
        r_pjrt.active_constraints,
        max_metric_violation(&inst.graph, &r_pjrt.x)
    );
    let max_dx = r_native
        .x
        .iter()
        .zip(&r_pjrt.x)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |x_native − x_pjrt| = {max_dx:.2e}");

    // (c) one batched projection sweep through the project artifact on
    // whatever the solver still remembers.
    let mut x = r_pjrt.x.clone();
    let w_inv = vec![1.0; x.len()];
    let stats = batched_sweep(
        &rt,
        BatchShape { b: 256, k: 8 },
        &mut s_pjrt.active,
        &mut x,
        &w_inv,
    )?;
    println!(
        "batched sweep: {} constraints in {} artifact calls ({} skipped as too long), dual movement {:.2e}",
        stats.projected, stats.calls, stats.skipped, stats.dual_movement
    );
    Ok(())
}
