//! Quickstart: repair a noisy dissimilarity matrix into a metric.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use paf::graph::generators::type1_complete;
use paf::problems::metric_oracle::max_metric_violation;
use paf::core::problem::SolveOptions;
use paf::problems::nearness::Nearness;
use paf::util::Rng;

fn main() {
    // 1. A random weighted complete graph on 100 points: |N(0,1)| weights
    //    violate tens of thousands of triangle inequalities.
    let mut rng = Rng::new(42);
    let inst = type1_complete(100, &mut rng);
    println!(
        "input: K_{} with {} edges, initial worst violation {:.3}",
        inst.graph.num_nodes(),
        inst.graph.num_edges(),
        max_metric_violation(&inst.graph, &inst.weights)
    );

    // 2. PROJECT AND FORGET: find the closest metric in L2.
    let res = Nearness::new(&inst).solve(&SolveOptions::new().violation_tol(1e-4));

    // 3. The output is a metric; the active set is tiny relative to the
    //    ~n³/6 triangle constraints the problem formally has.
    println!(
        "solved in {} iterations / {:.2}s: {} projections, {} active constraints",
        res.result.iterations,
        res.result.seconds,
        res.result.total_projections,
        res.result.active_constraints
    );
    println!(
        "objective ½‖x−d‖² = {:.4}, final worst violation {:.2e}",
        res.objective,
        max_metric_violation(&inst.graph, &res.result.x)
    );
    assert!(res.result.converged);
}
