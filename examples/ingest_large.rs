//! Streaming-ingest scale demo: generate a sparse geometric instance on
//! disk (default n ≈ 10⁵; `PAF_INGEST_N` overrides), stream it through
//! the two-pass CSR builder under byte accounting, solve metric nearness
//! on it, and emit the solver JSON with the schema-v5 `ingest` object.
//!
//! Exercises the whole `graph::ingest` path end to end with no network
//! access — the CI ingestion leg runs this at n = 10⁵.
//!
//! ```bash
//! PAF_INGEST_N=100000 cargo run --release --example ingest_large
//! ```

use paf::core::problem::SolveOptions;
use paf::graph::ingest::{ingest_weighted, write_geometric_instance, IngestOptions};
use paf::problems::metric_oracle::OracleMode;
use paf::problems::nearness::Nearness;
use paf::report;
use paf::util::timer::fmt_bytes;
use paf::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::var("PAF_INGEST_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let dir = std::env::temp_dir().join(format!("paf-ingest-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let edges = dir.join("geo.tsv");
    let coords = dir.join("geo.co");

    let clock = Stopwatch::new();
    let info = write_geometric_instance(&edges, Some(&coords), n, 42)?;
    let file_bytes = std::fs::metadata(&edges)?.len();
    println!(
        "generated: {} nodes, {} edge records ({} violated shortcuts), {} on disk, {:.1}s",
        info.nodes,
        info.edges,
        info.violated_shortcuts,
        fmt_bytes(file_bytes),
        clock.elapsed_s()
    );

    let clock = Stopwatch::new();
    let out = ingest_weighted(&edges, IngestOptions::default())?;
    let stats = out.stats;
    println!(
        "ingested: n={} m={} in {:.2}s (parse {:.2}s + build {:.2}s)",
        stats.nodes,
        stats.edges,
        clock.elapsed_s(),
        stats.parse_s,
        stats.build_s
    );
    println!(
        "  working set peak {} / CSR resident {} ({} read, {} dups, {} self-loops)",
        fmt_bytes(stats.peak_bytes),
        fmt_bytes(stats.csr_bytes),
        fmt_bytes(stats.bytes_read),
        stats.duplicates,
        stats.self_loops
    );
    anyhow::ensure!(stats.peak_bytes > 0, "ledger recorded no allocations");
    anyhow::ensure!(stats.nodes == info.nodes, "node count mismatch");

    // Loose tolerance: the point is exercising the streamed instance at
    // scale, not polishing the last digits.
    let opts = SolveOptions { violation_tol: 1e-2, ..SolveOptions::default() };
    let clock = Stopwatch::new();
    let res = Nearness::new(&out.inst).mode(OracleMode::Collect).solve(&opts);
    println!(
        "solved: converged={} in {} rounds / {} projections, {:.1}s",
        res.result.converged,
        res.result.iterations,
        res.result.total_projections,
        clock.elapsed_s()
    );
    anyhow::ensure!(res.result.converged, "nearness solve did not converge");

    let label = format!("SOLVE_nearness_ingest_n{}", stats.nodes);
    let text = report::solver_result_json_with_ingest(&label, &res.result, Some(&stats));
    report::emit_json(&label, &text)?;

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
