//! Fault-tolerance integration tests for the serving subsystem: durable
//! checkpoints, crash recovery, corruption quarantine, and the
//! deterministic fault-injection harness ([`FaultPlan`]).
//!
//! The load-bearing invariant throughout: a job that is preempted,
//! crashed, persisted, and recovered — even across a simulated process
//! boundary (two `Scheduler` instances over one state dir) — produces a
//! `SolverResult` bit-identical to its uninterrupted solo solve.

use paf::core::engine::SweepStrategy;
use paf::core::problem::SolveOptions;
use paf::core::session::Session;
use paf::core::solver::SolverResult;
use paf::graph::generators::{planted_signed, type1_complete};
use paf::graph::Graph;
use paf::problems::correlation::{CcInstance, Correlation};
use paf::problems::itml::{PfItml, PfItmlConfig};
use paf::problems::metric_oracle::OracleMode;
use paf::problems::nearness::Nearness;
use paf::serve::{
    demo_trace, persist, scan_state_dir, solve_job_solo, FaultPlan, Job, JobBank, JobSpec,
    Scheduler, ServeConfig, ServeError, ServeEvent,
};
use paf::util::Rng;
use std::path::PathBuf;

/// A per-test scratch directory (tests run in parallel in one process,
/// so the test name disambiguates; the pid isolates concurrent runs).
fn temp_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("paf-serve-faults-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create temp state dir");
    dir
}

fn assert_bit_identical(reference: &SolverResult, got: &SolverResult, label: &str) {
    assert_eq!(reference.x, got.x, "{label}: x differs (bitwise)");
    assert_eq!(reference.iterations, got.iterations, "{label}: iteration count differs");
    assert_eq!(reference.converged, got.converged, "{label}: convergence differs");
    assert_eq!(
        reference.total_projections, got.total_projections,
        "{label}: projection count differs"
    );
    assert_eq!(
        reference.active_constraints, got.active_constraints,
        "{label}: active-set size differs"
    );
}

fn serve_opts(threads: usize) -> SolveOptions {
    SolveOptions::new()
        .violation_tol(1e-4)
        .inner_sweeps(2)
        .sweep(SweepStrategy::ShardedParallel { threads })
}

/// Crash mid-service, then recover in a fresh scheduler over the same
/// state dir: every job completes and every result is bit-identical to
/// its solo solve — the evict/resume invariant extended across the
/// (simulated) process boundary. Run at two thread counts to pin that
/// persistence is engine-independent.
#[test]
fn crash_recovery_resumes_bit_identically() {
    for threads in [1usize, 4] {
        let dir = temp_dir(&format!("crash-{threads}"));
        let jobs = demo_trace(130);
        let bank = JobBank::materialize(&jobs);
        let opts = serve_opts(threads);
        let solo: Vec<_> = jobs
            .iter()
            .map(|j| solve_job_solo(j, bank.input(j.id), &opts).expect("solo solve"))
            .collect();

        // Process 1: serve with capacity 1 (forces preemptions, which
        // persist checkpoints) and an injected crash after round 6.
        let cfg = ServeConfig {
            capacity: 1,
            opts: opts.clone(),
            state_dir: Some(dir.clone()),
            fault_plan: FaultPlan { crash_after_round: Some(6), ..Default::default() },
            ..Default::default()
        };
        let crashed = Scheduler::new(jobs.clone(), &bank, cfg).expect("valid serve config").run();
        assert!(crashed.crashed, "the fault plan must stop the run");
        assert!(!crashed.all_completed(), "3 mixed jobs cannot finish in 6 rounds at cap 1");
        let files = scan_state_dir(&dir).expect("scan state dir");
        assert!(!files.is_empty(), "the crash must leave durable checkpoints");

        // Process 2: a fresh scheduler over the same state dir.
        let cfg = ServeConfig {
            capacity: 1,
            opts: opts.clone(),
            state_dir: Some(dir.clone()),
            ..Default::default()
        };
        let stats = Scheduler::new(jobs.clone(), &bank, cfg).expect("valid serve config").run();
        assert!(stats.all_completed(), "recovery must complete every job: {stats:?}");
        assert_eq!(stats.recovered, files.len(), "every durable checkpoint must recover");
        assert!(
            stats.events.iter().any(|e| matches!(e.event, ServeEvent::Recovered { .. })),
            "recovery must be in the event stream"
        );
        for (k, (s, want)) in stats.jobs.iter().zip(&solo).enumerate() {
            let got = s.result.as_ref().expect("completed job without result");
            assert_bit_identical(
                &want.result,
                got,
                &format!("threads {threads}, job {k}: recovered vs solo"),
            );
            assert_eq!(s.objective, Some(want.objective), "job {k}: objective differs");
        }
        assert!(
            scan_state_dir(&dir).expect("rescan").is_empty(),
            "completed jobs must drain their state files"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A corrupted checkpoint must fail its checksum on recovery, be moved
/// to `state_dir/corrupt/`, and the job must restart from scratch —
/// still finishing bit-identical to solo, without touching other jobs'
/// recoveries.
#[test]
fn corrupt_checkpoint_is_quarantined_and_job_restarts() {
    let dir = temp_dir("corrupt");
    let jobs = demo_trace(131);
    let bank = JobBank::materialize(&jobs);
    let opts = serve_opts(2);
    let solo: Vec<_> = jobs
        .iter()
        .map(|j| solve_job_solo(j, bank.input(j.id), &opts).expect("solo solve"))
        .collect();

    // Crash after round 6 AND flip a bit in job 0's file on every write.
    let cfg = ServeConfig {
        capacity: 1,
        opts: opts.clone(),
        state_dir: Some(dir.clone()),
        fault_plan: FaultPlan {
            crash_after_round: Some(6),
            corrupt_checkpoint: Some((0, 13)),
            ..Default::default()
        },
        ..Default::default()
    };
    let crashed = Scheduler::new(jobs.clone(), &bank, cfg).expect("valid serve config").run();
    assert!(crashed.crashed);
    let files = scan_state_dir(&dir).expect("scan state dir");
    assert!(
        files.iter().any(|(job, _)| *job == 0),
        "job 0 must have a (corrupted) state file"
    );

    let cfg = ServeConfig {
        capacity: 1,
        opts: opts.clone(),
        state_dir: Some(dir.clone()),
        ..Default::default()
    };
    let stats = Scheduler::new(jobs.clone(), &bank, cfg).expect("valid serve config").run();
    assert!(stats.all_completed(), "quarantine must not block completion: {stats:?}");
    assert_eq!(
        stats.recovered,
        files.len() - 1,
        "all files but the corrupted one must recover"
    );
    assert!(!stats.jobs[0].recovered, "the corrupted job restarts from scratch");
    assert!(stats.jobs[0].error.is_some(), "the corruption is recorded on the job");
    assert!(
        stats
            .events
            .iter()
            .any(|e| matches!(e.event, ServeEvent::Quarantined { round: 0, job: 0, .. })),
        "quarantine must be in the event stream"
    );
    assert!(
        dir.join("corrupt").join("job-0.ckpt").exists(),
        "the corrupt file is preserved for post-mortem, not deleted"
    );
    for (k, (s, want)) in stats.jobs.iter().zip(&solo).enumerate() {
        let got = s.result.as_ref().expect("completed job without result");
        assert_bit_identical(&want.result, got, &format!("job {k}: post-quarantine vs solo"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Property-style roundtrip over a mixed fleet — nearness + CC (vector
/// blocks) and ITML (round block): for every evicted checkpoint the
/// wire encoding re-serializes byte-stably, survives a disk roundtrip,
/// resumes bit-identically, and any single-bit flip is caught by the
/// trailing checksum (never a panic, never a silently wrong resume).
#[test]
fn checkpoint_persist_roundtrip_property() {
    let dir = temp_dir("roundtrip");
    for seed in [1u64, 2, 3] {
        let mut rng = Rng::new(seed);
        let near_inst = type1_complete(14 + 2 * (seed as usize % 3), &mut rng);
        let (sg, _) = planted_signed(Graph::complete(12), 3, 0.1, &mut rng);
        let cc_inst = CcInstance::from_signed(&sg);
        let data = paf::ml::dataset::gaussian_mixture(60, 3, 2, 2.0, &mut rng);
        let icfg =
            PfItmlConfig { max_projections: 1500, batch: 40, seed, ..Default::default() };
        let opts = SolveOptions::new().violation_tol(1e-6).inner_sweeps(2);

        // Uninterrupted references (block trajectories are independent
        // of fleet composition, pinned in tests/determinism.rs).
        let solo_near = Nearness::new(&near_inst).mode(OracleMode::Collect).solve(&opts);
        let solo_cc =
            Correlation::dense(&cc_inst).mode(OracleMode::Collect).seed(seed).solve(&opts);
        let solo_itml = PfItml::new(&data, icfg.clone()).solve(&opts);

        // Interrupt a mixed fleet after 3 rounds and evict every block.
        let mut first = Session::new(opts.clone());
        let hn = first.add(Nearness::new(&near_inst).mode(OracleMode::Collect));
        let hc = first.add(Correlation::dense(&cc_inst).mode(OracleMode::Collect).seed(seed));
        let hi = first.add(PfItml::new(&data, icfg.clone()));
        for _ in 0..3 {
            first.step();
        }
        let ck_itml = first.evict(hi.index());
        let ck_cc = first.evict(hc.index());
        let ck_near = first.evict(hn.index());

        for (label, ck, job) in
            [("near", &ck_near, 0usize), ("cc", &ck_cc, 1), ("itml", &ck_itml, 2)]
        {
            // Byte-stable re-serialization: encode → decode → encode is
            // the identity on bytes.
            let bytes = persist::encode_checkpoint(ck).expect("encode");
            let back = persist::decode_checkpoint(&bytes, std::path::Path::new("mem"))
                .expect("decode own encoding");
            let bytes2 = persist::encode_checkpoint(&back).expect("re-encode");
            assert_eq!(bytes, bytes2, "seed {seed} {label}: re-serialization not byte-stable");

            // Disk roundtrip through the atomic-write path.
            let path = persist::write_checkpoint_atomic(&dir, job, ck).expect("write");
            let loaded = persist::load_checkpoint(&path).expect("load");
            assert_eq!(
                persist::encode_checkpoint(&loaded).expect("encode loaded"),
                bytes,
                "seed {seed} {label}: disk roundtrip changed the checkpoint"
            );

            // Checksum: a single flipped bit anywhere (header, body,
            // digest) is a typed Corrupt error.
            for pos in
                [0usize, 9, bytes.len() / 3, bytes.len() / 2, bytes.len() - 12, bytes.len() - 1]
            {
                let mut bad = bytes.clone();
                bad[pos] ^= 1 << 3;
                let err = persist::decode_checkpoint(&bad, std::path::Path::new("mem"))
                    .expect_err("flipped bit must not decode");
                assert!(
                    matches!(err, ServeError::Corrupt { .. }),
                    "seed {seed} {label} pos {pos}: expected Corrupt, got {err}"
                );
            }
        }

        // Resuming from the *decoded* checkpoints completes each block
        // bit-identically to its uninterrupted solo solve.
        let redecode = |ck: &paf::core::session::BlockCheckpoint| {
            let bytes = persist::encode_checkpoint(ck).expect("encode");
            persist::decode_checkpoint(&bytes, std::path::Path::new("mem")).expect("decode")
        };
        let mut near_s = Session::new(opts.clone());
        let h = near_s
            .admit_resumed(Nearness::new(&near_inst).mode(OracleMode::Collect), &redecode(&ck_near));
        near_s.run();
        let got = near_s.take_unwrap(h);
        assert_bit_identical(&solo_near.result, &got.result, "resumed nearness");
        assert_eq!(solo_near.objective.to_bits(), got.objective.to_bits());

        let mut cc_s = Session::new(opts.clone());
        let h = cc_s.admit_resumed(
            Correlation::dense(&cc_inst).mode(OracleMode::Collect).seed(seed),
            &redecode(&ck_cc),
        );
        cc_s.run();
        let got = cc_s.take_unwrap(h);
        assert_bit_identical(&solo_cc.result, &got.result, "resumed CC");
        assert_eq!(solo_cc.lp_objective.to_bits(), got.lp_objective.to_bits());

        let mut itml_s = Session::new(opts.clone());
        let h = itml_s.admit_resumed(PfItml::new(&data, icfg), &redecode(&ck_itml));
        itml_s.run();
        let got = itml_s.take_unwrap(h);
        assert_eq!(solo_itml.m.a, got.m.a, "resumed ITML matrix diverged");
        assert_eq!(solo_itml.projections, got.projections);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Priority aging flips admission order for a starved low-priority job:
/// with aging on, a job that has waited long enough out-ranks a younger
/// mid-priority job; with aging off, base priority wins.
#[test]
fn priority_aging_prevents_starvation() {
    let mk_jobs = || {
        vec![
            Job {
                id: 0,
                name: "hog".to_string(),
                spec: JobSpec::Nearness { n: 30, graph_type: 1, seed: 40 },
                priority: 99,
                arrival_round: 0,
                max_rounds: None,
                deadline_rounds: None,
                deadline_ms: None,
            },
            Job {
                id: 1,
                name: "starved".to_string(),
                spec: JobSpec::Nearness { n: 10, graph_type: 1, seed: 41 },
                priority: 0,
                arrival_round: 0,
                max_rounds: None,
                deadline_rounds: None,
                deadline_ms: None,
            },
            Job {
                id: 2,
                name: "young-mid".to_string(),
                spec: JobSpec::Nearness { n: 10, graph_type: 1, seed: 42 },
                priority: 4,
                arrival_round: 7,
                max_rounds: None,
                deadline_rounds: None,
                deadline_ms: None,
            },
        ]
    };
    let run = |age_rounds: usize| {
        let jobs = mk_jobs();
        let bank = JobBank::materialize(&jobs);
        let cfg = ServeConfig {
            capacity: 1,
            opts: SolveOptions::new().violation_tol(1e-4),
            age_rounds,
            ..Default::default()
        };
        let stats = Scheduler::new(jobs, &bank, cfg).expect("valid serve config").run();
        assert!(stats.all_completed(), "aging run (age={age_rounds}) must complete");
        (stats.jobs[1].admitted_round.unwrap(), stats.jobs[2].admitted_round.unwrap())
    };
    // Aging off: base priority wins — the younger mid-priority job cuts
    // ahead of the starved one.
    let (starved, young) = run(0);
    assert!(young < starved, "without aging, priority 4 beats priority 0 ({young} vs {starved})");
    // Aging on (1 level per waited round): by the time capacity frees,
    // the starved job has out-aged the 4-level gap (it arrived 7 rounds
    // earlier), so it is admitted first.
    let (starved, young) = run(1);
    assert!(starved < young, "with aging, the starved job goes first ({starved} vs {young})");
}

/// The garble fault + lenient parser end to end: one trace line is
/// deterministically truncated, the lenient parse skips exactly that
/// line with its 1-based number, and the surviving jobs serve normally.
#[test]
fn garbled_trace_line_is_skipped_and_reported() {
    let trace_text: String = demo_trace(132).iter().map(|j| j.to_json_line() + "\n").collect();
    let plan = FaultPlan::parse("garble=2").expect("plan");
    let garbled = plan.apply_to_trace(&trace_text);
    let (jobs, errors) = paf::serve::parse_job_trace_lenient(&garbled);
    assert_eq!(jobs.len(), 2, "two of three lines must survive");
    assert_eq!(errors.len(), 1);
    assert!(
        matches!(&errors[0], ServeError::Trace { line: 2, .. }),
        "the error must carry the 1-based line number: {}",
        errors[0]
    );
    // Ids are re-assigned positionally so the trace still serves.
    let bank = JobBank::materialize(&jobs);
    let cfg = ServeConfig {
        capacity: 2,
        opts: serve_opts(2),
        ..Default::default()
    };
    let stats = Scheduler::new(jobs, &bank, cfg).expect("valid serve config").run();
    assert!(stats.all_completed(), "the surviving jobs must serve normally");
}
