//! Property-based integration tests over the PROJECT AND FORGET engine:
//! randomized instances, invariant assertions. This is the offline
//! stand-in for `proptest` — seeds sweep a family of cases and every
//! failure message carries the seed for reproduction.

#![allow(deprecated)] // exercises the legacy wrappers alongside the raw engine

use paf::core::bregman::{BregmanFunction, DiagonalQuadratic, Entropy};
use paf::core::constraint::Constraint;
use paf::core::oracle::{ListOracle, SampledListOracle};
use paf::core::solver::{Solver, SolverConfig};
use paf::core::stochastic::{solve_stochastic, ConstraintFamily, StochasticConfig};
use paf::graph::generators::{erdos_renyi, type1_complete};
use paf::problems::metric_oracle::max_metric_violation;
use paf::problems::nearness::{solve_nearness, NearnessConfig};
use paf::util::Rng;

/// Random sparse feasible LP-ish instance: constraints are built around a
/// known interior point so the feasible set is provably non-empty.
fn random_feasible_instance(
    seed: u64,
    dim: usize,
    ncons: usize,
) -> (Vec<f64>, Vec<Constraint>) {
    let mut rng = Rng::new(seed);
    let interior: Vec<f64> = (0..dim).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let mut cons = Vec::with_capacity(ncons);
    for _ in 0..ncons {
        let nnz = 1 + rng.below(dim.min(4));
        let idx = rng.sample_indices(dim, nnz);
        let coeffs: Vec<f64> = (0..nnz).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let at_interior: f64 = idx
            .iter()
            .zip(&coeffs)
            .map(|(&i, &a)| a * interior[i])
            .sum();
        // rhs leaves slack so the interior point stays strictly feasible.
        let rhs = at_interior + rng.uniform(0.05, 1.0);
        cons.push(Constraint::new(
            idx.iter().map(|&i| i as u32).collect(),
            coeffs,
            rhs,
        ));
    }
    (interior, cons)
}

#[test]
fn property_solution_feasible_and_kkt_many_seeds() {
    for seed in 0..25u64 {
        let dim = 6 + (seed as usize % 5);
        let (_, cons) = random_feasible_instance(seed, dim, 20);
        let mut rng = Rng::new(seed ^ 0xdead);
        let d: Vec<f64> = (0..dim).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let f = DiagonalQuadratic::unweighted(d.clone());
        let oracle = ListOracle::new(cons.clone());
        let cfg = SolverConfig {
            max_iters: 5000,
            violation_tol: 1e-9,
            dual_tol: 1e-9,
            record_trace: false,
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        let res = solver.solve(oracle);
        assert!(res.converged, "seed {seed}: did not converge");
        // Feasibility.
        for (ci, c) in cons.iter().enumerate() {
            assert!(
                c.violation(&res.x) < 1e-7,
                "seed {seed}: constraint {ci} violated by {}",
                c.violation(&res.x)
            );
        }
        // Dual feasibility.
        for r in 0..solver.active.len() {
            assert!(solver.active.z(r) >= -1e-12, "seed {seed}: negative dual");
        }
        // KKT stationarity: ∇f(x) + Aᵀz = 0 over the remembered set.
        let grad: Vec<f64> = solver.x.iter().zip(&d).map(|(&x, &di)| x - di).collect();
        assert!(
            solver.kkt_residual(&grad) < 1e-7,
            "seed {seed}: KKT residual {}",
            solver.kkt_residual(&grad)
        );
    }
}

#[test]
fn property_forgotten_constraints_are_inactive_at_optimum() {
    // Proposition 2's observable: at convergence, every constraint NOT in
    // the remembered set is strictly satisfied (inactive), and every
    // remembered one is (numerically) active or has positive dual.
    for seed in 0..10u64 {
        let (_, cons) = random_feasible_instance(seed + 100, 8, 30);
        let mut rng = Rng::new(seed);
        let d: Vec<f64> = (0..8).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let f = DiagonalQuadratic::unweighted(d);
        let oracle = ListOracle::new(cons.clone());
        let cfg = SolverConfig {
            max_iters: 5000,
            violation_tol: 1e-10,
            dual_tol: 1e-10,
            record_trace: false,
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        let res = solver.solve(oracle);
        assert!(res.converged);
        for c in &cons {
            if !solver.active.contains(c) {
                // Forgotten -> must be satisfied at the optimum.
                assert!(
                    c.violation(&res.x) < 1e-7,
                    "seed {seed}: forgotten constraint is violated"
                );
            }
        }
    }
}

#[test]
fn property_nearness_idempotent_many_seeds() {
    // Projecting an already-metric input returns it unchanged; projecting
    // twice equals projecting once (projection idempotency).
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed);
        let inst = type1_complete(10, &mut rng);
        let cfg = NearnessConfig { violation_tol: 1e-9, dual_tol: 1e-9, ..Default::default() };
        let first = solve_nearness(&inst, &cfg);
        assert!(first.result.converged);
        let again = solve_nearness(
            &paf::graph::generators::WeightedInstance {
                graph: inst.graph.clone(),
                weights: first.result.x.clone(),
            },
            &cfg,
        );
        let moved: f64 = again
            .result
            .x
            .iter()
            .zip(&first.result.x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(moved < 1e-6, "seed {seed}: re-projection moved by {moved}");
    }
}

#[test]
fn property_nearness_contraction() {
    // Metric projection is 1-Lipschitz in L2: ‖P(a) − P(b)‖ ≤ ‖a − b‖.
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed + 7);
        let inst_a = type1_complete(9, &mut rng);
        let mut wb = inst_a.weights.clone();
        for w in wb.iter_mut() {
            *w += rng.uniform(-0.2, 0.2);
        }
        let inst_b = paf::graph::generators::WeightedInstance {
            graph: inst_a.graph.clone(),
            weights: wb.clone(),
        };
        let cfg = NearnessConfig { violation_tol: 1e-9, dual_tol: 1e-9, ..Default::default() };
        let pa = solve_nearness(&inst_a, &cfg);
        let pb = solve_nearness(&inst_b, &cfg);
        let num: f64 = pa
            .result
            .x
            .iter()
            .zip(&pb.result.x)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let den: f64 = inst_a
            .weights
            .iter()
            .zip(&wb)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(num <= den + 1e-5, "seed {seed}: {num} > {den}");
    }
}

#[test]
fn property_entropy_engine_solves_constrained_problems() {
    // Exercise the non-quadratic Bregman path: min Σ x ln x − x subject
    // to random upper bounds on sub-sums; optimum must satisfy KKT in the
    // entropy geometry (∇f = ln x), x > 0 throughout (zone consistency).
    for seed in 0..5u64 {
        let dim = 5;
        let mut rng = Rng::new(seed + 41);
        let mut cons = Vec::new();
        for _ in 0..6 {
            let nnz = 1 + rng.below(3);
            let idx = rng.sample_indices(dim, nnz);
            // positive rows with rhs < nnz (argmin is all-ones => violated)
            let coeffs = vec![1.0; nnz];
            let rhs = rng.uniform(0.2, nnz as f64 * 0.8);
            cons.push(Constraint::new(idx.iter().map(|&i| i as u32).collect(), coeffs, rhs));
        }
        let f = Entropy::new(dim);
        let oracle = ListOracle::new(cons.clone());
        let cfg = SolverConfig {
            max_iters: 3000,
            violation_tol: 1e-9,
            dual_tol: 1e-9,
            record_trace: false,
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        let res = solver.solve(oracle);
        assert!(res.converged, "seed {seed}");
        assert!(res.x.iter().all(|&v| v > 0.0), "zone violated");
        for c in &cons {
            assert!(c.violation(&res.x) < 1e-6, "seed {seed}: infeasible");
        }
        // Entropy KKT: ln x = −Aᵀz over remembered rows.
        let grad: Vec<f64> = solver.x.iter().map(|&v| v.ln()).collect();
        assert!(solver.kkt_residual(&grad) < 1e-6, "seed {seed}: entropy KKT");
    }
}

#[test]
fn property_random_oracle_matches_deterministic() {
    // Theorem 1 with Property 2: the sampled oracle converges to the same
    // optimum as the full-list oracle.
    for seed in 0..5u64 {
        let (_, cons) = random_feasible_instance(seed + 55, 6, 12);
        let mut rng = Rng::new(seed);
        let d: Vec<f64> = (0..6).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let cfg = SolverConfig {
            max_iters: 20000,
            violation_tol: 1e-10,
            dual_tol: 1e-10,
            record_trace: false,
            ..Default::default()
        };
        let mut det = Solver::new(DiagonalQuadratic::unweighted(d.clone()), cfg.clone());
        let rdet = det.solve(ListOracle::new(cons.clone()));
        assert!(rdet.converged);
        // A Property-2 oracle can sample an all-satisfied batch and trip
        // the stopping test prematurely (convergence holds only with
        // probability 1 over infinite runs) — so run a fixed iteration
        // budget with stopping disabled and compare the iterates.
        let sto_cfg = SolverConfig {
            max_iters: 8000,
            violation_tol: -1.0, // never stop early
            dual_tol: 0.0,
            record_trace: false,
            ..Default::default()
        };
        let mut sto = Solver::new(DiagonalQuadratic::unweighted(d.clone()), sto_cfg);
        let _ = sto.solve(SampledListOracle {
            constraints: cons.clone(),
            batch: 8,
            rng: Rng::new(seed * 31 + 1),
            tol: 0.0,
        });
        for (a, b) in det.x.iter().zip(&sto.x) {
            assert!((a - b).abs() < 1e-4, "seed {seed}: {a} vs {b}");
        }
    }
}

#[test]
fn property_truly_stochastic_halfspace_families() {
    struct RandomRows {
        cons: Vec<Constraint>,
    }
    impl ConstraintFamily for RandomRows {
        fn len(&self) -> usize {
            self.cons.len()
        }
        fn materialize(&self, id: usize, out: &mut Constraint) {
            out.indices.clear();
            out.coeffs.clear();
            out.indices.extend_from_slice(&self.cons[id].indices);
            out.coeffs.extend_from_slice(&self.cons[id].coeffs);
            out.rhs = self.cons[id].rhs;
        }
    }
    for seed in 0..5u64 {
        let (_, cons) = random_feasible_instance(seed + 77, 6, 10);
        let mut rng = Rng::new(seed);
        let d: Vec<f64> = (0..6).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let f = DiagonalQuadratic::unweighted(d);
        let fam = RandomRows { cons: cons.clone() };
        let res = solve_stochastic(
            &f,
            &fam,
            &StochasticConfig { batch: 10, epochs: 4000, seed },
        );
        for (ci, c) in cons.iter().enumerate() {
            assert!(
                c.violation(&res.x) < 1e-5,
                "seed {seed}: constraint {ci} violated by {}",
                c.violation(&res.x)
            );
        }
        assert!(res.z.iter().all(|&z| z >= 0.0));
    }
}

#[test]
fn property_sparse_graph_nearness_many_topologies() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed + 90);
        let g = erdos_renyi(16, 0.25 + 0.1 * (seed as f64 % 3.0), &mut rng);
        if g.num_edges() == 0 {
            continue;
        }
        let weights: Vec<f64> = (0..g.num_edges()).map(|_| rng.normal().abs() + 0.01).collect();
        let inst = paf::graph::generators::WeightedInstance { graph: g, weights };
        let res = solve_nearness(
            &inst,
            &NearnessConfig { violation_tol: 1e-8, dual_tol: 1e-8, ..Default::default() },
        );
        assert!(res.result.converged, "seed {seed}");
        assert!(
            max_metric_violation(&inst.graph, &res.result.x) < 1e-6,
            "seed {seed}: not a metric"
        );
        assert!(res.result.x.iter().all(|&v| v >= -1e-9), "seed {seed}: negative");
    }
}

#[test]
fn property_objective_monotone_in_tolerance() {
    // Tighter tolerance => closer to the true projection => objective of
    // the solution is (weakly) closer to optimal from above... we check
    // the final max violation shrinks with tolerance.
    let mut rng = Rng::new(123);
    let inst = type1_complete(12, &mut rng);
    let mut last_viol = f64::INFINITY;
    for tol in [1e-1, 1e-3, 1e-6] {
        let res = solve_nearness(
            &inst,
            &NearnessConfig { violation_tol: tol, dual_tol: tol, ..Default::default() },
        );
        let v = max_metric_violation(&inst.graph, &res.result.x);
        assert!(v <= last_viol + 1e-12, "violation did not shrink: {v} vs {last_viol}");
        last_viol = v;
    }
    assert!(last_viol < 1e-6);
}

#[test]
fn bregman_projection_minimality_quadratic() {
    // The engine's single projection is the true metric projection: for
    // random hyperplanes, compare against the closed-form formula.
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let dim = 5;
        let d: Vec<f64> = (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let w: Vec<f64> = (0..dim).map(|_| rng.uniform(0.5, 3.0)).collect();
        let f = DiagonalQuadratic::new(d.clone(), w.clone());
        let idx: Vec<u32> = (0..dim as u32).collect();
        let coeffs: Vec<f64> = (0..dim).map(|_| rng.uniform(-1.0, 1.0)).collect();
        // rhs below ⟨a, d⟩ so the constraint is active at the projection.
        let at_d: f64 = coeffs.iter().zip(&d).map(|(&a, &x)| a * x).sum();
        let rhs = at_d - rng.uniform(0.1, 1.0);
        let c = Constraint::new(idx, coeffs.clone(), rhs);
        let oracle = ListOracle::new(vec![c]);
        let cfg = SolverConfig {
            violation_tol: 1e-12,
            dual_tol: 1e-12,
            record_trace: false,
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        let res = solver.solve(oracle);
        assert!(res.converged);
        // Closed form: x = d + θ W⁻¹ a with θ = (rhs − ⟨a,d⟩)/Σ a²/w.
        let denom: f64 = coeffs.iter().zip(&w).map(|(&a, &wi)| a * a / wi).sum();
        let theta = (rhs - at_d) / denom;
        for i in 0..dim {
            let expect = d[i] + theta * coeffs[i] / w[i];
            assert!(
                (res.x[i] - expect).abs() < 1e-9,
                "seed {seed}: coord {i}: {} vs {expect}",
                res.x[i]
            );
        }
    }
}
