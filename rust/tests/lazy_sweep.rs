//! Lazy sweep scheduling properties (PR-6 tentpole).
//!
//! The movement-driven scheduler (`core::engine::lazy`) skips a row only
//! when its projection is *provably* a zero-step no-op, so lazy solves
//! must be **bit-identical** to eager solves — including the cases where
//! nothing is ever skippable — while projecting no more rows, and FORGET
//! must behave exactly as it would eagerly (skipped rows' stored duals
//! ARE their refreshed values). These tests pin those properties on
//! randomized nearness, correlation-clustering (box rows) and ITML
//! workloads through both the raw `Solver` loop and the Problem API.

use paf::core::bregman::DiagonalQuadratic;
use paf::core::engine::SweepStrategy;
use paf::core::problem::SolveOptions;
use paf::core::solver::{Solver, SolverConfig, SolverResult};
use paf::graph::generators::type1_complete;
use paf::graph::Graph;
use paf::problems::correlation::{CcInstance, Correlation};
use paf::problems::itml::{PfItml, PfItmlConfig};
use paf::problems::metric_oracle::{MetricOracle, OracleMode};
use paf::util::Rng;
use std::sync::Arc;

fn assert_bit_identical(reference: &SolverResult, got: &SolverResult, label: &str) {
    assert_eq!(reference.x, got.x, "{label}: x differs (bitwise)");
    assert_eq!(reference.iterations, got.iterations, "{label}: iteration count differs");
    assert_eq!(reference.converged, got.converged, "{label}: convergence differs");
    assert_eq!(
        reference.total_projections, got.total_projections,
        "{label}: projection count differs"
    );
    assert_eq!(
        reference.active_constraints, got.active_constraints,
        "{label}: active-set size differs"
    );
}

fn cc_instance(seed: u64) -> CcInstance {
    let mut rng = Rng::new(seed);
    let g = Graph::complete(12);
    let (sg, _) = paf::graph::generators::planted_signed(g, 3, 0.15, &mut rng);
    CcInstance::from_signed(&sg)
}

/// Raw nearness solve with the trace recorded and the lazy knob exposed.
fn raw_nearness_lazy(
    inst: &paf::graph::generators::WeightedInstance,
    sweep: SweepStrategy,
    inner_sweeps: usize,
    lazy_sweep: bool,
) -> SolverResult {
    let f = DiagonalQuadratic::unweighted(inst.weights.clone());
    let mut oracle = MetricOracle::new(Arc::new(inst.graph.clone()), OracleMode::Collect);
    oracle.report_tol = 1e-9;
    oracle.shard_bucket = matches!(sweep, SweepStrategy::ShardedParallel { .. });
    let cfg = SolverConfig {
        max_iters: 500,
        inner_sweeps,
        violation_tol: 1e-6,
        dual_tol: 1e-6,
        sweep,
        lazy_sweep,
        ..Default::default()
    };
    let mut solver = Solver::new(f, cfg);
    solver.solve(oracle)
}

#[test]
fn lazy_solves_are_bit_identical_on_randomized_nearness() {
    // Property (a): the full SolverResult — iterate, iteration count,
    // projections, active set — is bit-identical with the scheduler on,
    // whether or not any row ever becomes skippable. inner_sweeps = 1
    // covers the nothing-skippable regime (every sweep directly follows
    // oracle movement); inner_sweeps = 3 gives settled rows room to arm
    // and be skipped.
    let mut rng = Rng::new(21);
    for n in [10usize, 13] {
        let inst = type1_complete(n, &mut rng);
        for sweep in
            [SweepStrategy::Sequential, SweepStrategy::ShardedParallel { threads: 3 }]
        {
            for inner in [1usize, 3] {
                let eager = raw_nearness_lazy(&inst, sweep, inner, false);
                let lazy = raw_nearness_lazy(&inst, sweep, inner, true);
                assert!(eager.converged, "eager n={n} {sweep:?} inner={inner}");
                assert_bit_identical(
                    &eager,
                    &lazy,
                    &format!("nearness n={n} {sweep:?} inner={inner}"),
                );
            }
        }
    }
}

#[test]
fn lazy_traces_partition_the_eager_visits() {
    // Property (b), sharpened from "same fixed point within report_tol"
    // to the bit-identity the design actually guarantees — plus the
    // per-round accounting: the lazy rounds' visit/skip counters
    // partition exactly the rows the eager solve projected (the
    // trajectories are identical, so per-sweep active sizes agree).
    let mut rng = Rng::new(22);
    let inst = type1_complete(13, &mut rng);
    for sweep in [SweepStrategy::Sequential, SweepStrategy::ShardedParallel { threads: 2 }]
    {
        let eager = raw_nearness_lazy(&inst, sweep, 3, false);
        let lazy = raw_nearness_lazy(&inst, sweep, 3, true);
        assert_bit_identical(&eager, &lazy, &format!("trace run {sweep:?}"));
        assert_eq!(eager.trace.len(), lazy.trace.len());
        let mut skipped_total = 0usize;
        for (e, l) in eager.trace.iter().zip(&lazy.trace) {
            assert_eq!(e.rows_skipped, 0, "{sweep:?}: eager sweeps never skip");
            assert_eq!(
                l.rows_projected + l.rows_skipped,
                e.rows_projected,
                "{sweep:?} round {}: visit/skip must partition the eager visits",
                e.iteration
            );
            assert_eq!(e.projections, l.projections, "{sweep:?} round {}", e.iteration);
            skipped_total += l.rows_skipped;
        }
        // Not a theorem for arbitrary instances, but pinned for this one:
        // a converging metric solve settles rows, so the scheduler must
        // actually engage (guards against a silently dead skip path).
        assert!(skipped_total > 0, "{sweep:?}: the lazy scheduler never skipped a row");
    }
}

#[test]
fn forget_only_evicts_exact_zero_duals_under_lazy_sweeps() {
    // Property (c): FORGET's zero-dual test reads live duals, and under
    // lazy sweeps a skipped row's stored dual is exactly the value a
    // refresh would compute (zero-step rows change nothing). So FORGET
    // must drop exactly the rows whose dual is (z_tol-)zero and every
    // survivor must keep a nonzero dual — checked against the live
    // active set after every single sweep of a manually driven loop.
    let mut rng = Rng::new(23);
    let inst = type1_complete(12, &mut rng);
    let f = DiagonalQuadratic::unweighted(inst.weights.clone());
    let mut oracle = MetricOracle::new(Arc::new(inst.graph.clone()), OracleMode::Collect);
    oracle.report_tol = 1e-9;
    let cfg = SolverConfig {
        max_iters: 500,
        inner_sweeps: 2,
        violation_tol: 1e-6,
        dual_tol: 1e-6,
        lazy_sweep: true, // explicitly, so the CI eager legs still cover this
        ..Default::default()
    };
    let mut solver = Solver::new(f, cfg);
    let mut forgotten_total = 0usize;
    for _round in 0..40 {
        let outcome = solver.separate_with(&mut oracle);
        for _sweep in 0..2 {
            solver.project_sweep();
            let z_tol = solver.config.z_tol;
            let dead = (0..solver.active.len())
                .filter(|&r| solver.active.z(r).abs() <= z_tol)
                .count();
            let len_before = solver.active.len();
            let dropped = solver.forget();
            assert_eq!(
                dropped, dead,
                "FORGET must drop exactly the zero-dual rows, never a live one"
            );
            assert_eq!(solver.active.len(), len_before - dropped);
            for r in 0..solver.active.len() {
                assert_ne!(
                    solver.active.z(r),
                    0.0,
                    "a surviving row holds a zero dual after FORGET"
                );
            }
            forgotten_total += dropped;
        }
        if outcome.found == 0 && solver.last_dual_movement <= 1e-6 {
            break;
        }
    }
    assert!(forgotten_total > 0, "the run never exercised FORGET");
}

#[test]
fn sequential_and_sharded_stats_agree_on_cc_box_rows() {
    // Satellite regression: `SweepStats::dual_movement` (and the new
    // row counters) cover exactly the executor's sweep — remembered box
    // rows included, sink-side box passes excluded — for BOTH executors.
    // A correlation-clustering instance keeps upper-bound box rows in
    // the remembered list, so any executor disagreement about them shows
    // up as diverging per-round trace counters (or a non-bit-identical
    // iterate, since the dual-movement convergence test would then gate
    // differently).
    let inst = cc_instance(24);
    let base = SolveOptions::new()
        .max_iters(800)
        .violation_tol(1e-4)
        .inner_sweeps(4);
    for lazy in [false, true] {
        let opts = base.clone().lazy_sweep(lazy);
        let seq = Correlation::dense(&inst)
            .mode(OracleMode::Collect)
            .seed(7)
            .solve(&opts.clone().sweep(SweepStrategy::Sequential));
        let par = Correlation::dense(&inst)
            .mode(OracleMode::Collect)
            .seed(7)
            .solve(&opts.clone().sweep(SweepStrategy::ShardedParallel { threads: 2 }));
        assert!(seq.result.converged && par.result.converged, "lazy={lazy}");
        assert_bit_identical(
            &seq.result,
            &par.result,
            &format!("cc seq vs sharded (lazy={lazy})"),
        );
        assert_eq!(seq.labels, par.labels, "lazy={lazy}: rounding differs");
        assert_eq!(seq.result.trace.len(), par.result.trace.len());
        for (s, p) in seq.result.trace.iter().zip(&par.result.trace) {
            assert_eq!(s.projections, p.projections, "round {}", s.iteration);
            assert_eq!(
                s.rows_projected, p.rows_projected,
                "round {}: executors disagree on rows projected (lazy={lazy})",
                s.iteration
            );
            assert_eq!(
                s.rows_skipped, p.rows_skipped,
                "round {}: executors disagree on rows skipped (lazy={lazy})",
                s.iteration
            );
        }
    }
}

#[test]
fn lazy_matches_eager_through_the_problem_api() {
    // The same equivalences through the Session-backed Problem API, for
    // CC (box rows + FORGET churn) and ITML (round-driven block whose
    // sweeps run inside the block driver).
    let inst = cc_instance(25);
    let opts = SolveOptions::new()
        .max_iters(800)
        .violation_tol(1e-4)
        .inner_sweeps(4)
        .sweep(SweepStrategy::ShardedParallel { threads: 2 })
        .lazy_sweep(true);
    let eager = Correlation::dense(&inst)
        .mode(OracleMode::Collect)
        .seed(7)
        .solve(&opts.clone().lazy_sweep(false));
    let lazy = Correlation::dense(&inst).mode(OracleMode::Collect).seed(7).solve(&opts);
    assert!(eager.result.converged);
    assert_bit_identical(&eager.result, &lazy.result, "cc lazy vs eager");
    assert_eq!(eager.labels, lazy.labels);
    assert_eq!(eager.lp_objective, lazy.lp_objective);

    let mut rng = Rng::new(26);
    let data = paf::ml::dataset::gaussian_mixture(80, 4, 2, 2.0, &mut rng);
    let icfg = PfItmlConfig { max_projections: 2000, batch: 50, seed: 3, ..Default::default() };
    let i_eager =
        PfItml::new(&data, icfg.clone()).solve(&SolveOptions::default().lazy_sweep(false));
    let i_lazy = PfItml::new(&data, icfg).solve(&SolveOptions::default().lazy_sweep(true));
    assert_eq!(i_eager.m.a, i_lazy.m.a, "ITML lazy vs eager: matrix differs");
    assert_eq!(i_eager.projections, i_lazy.projections);
    assert_eq!(i_eager.active_pairs, i_lazy.active_pairs);
}
