//! Hostile-input hardening for the `PAFCKPT1` checkpoint wire format:
//! truncation at every byte boundary, absurd length-prefixed counts,
//! and whole-buffer corruption sweeps. The contract under attack bytes
//! is absolute — [`persist::decode_checkpoint`] returns
//! [`ServeError::Corrupt`] (or, for semantically-null damage, a valid
//! checkpoint); it never panics and never allocates anywhere near the
//! claimed element counts.

use paf::core::problem::SolveOptions;
use paf::core::session::Session;
use paf::problems::itml::{PfItml, PfItmlConfig};
use paf::problems::metric_oracle::OracleMode;
use paf::problems::nearness::Nearness;
use paf::serve::{persist, ServeError};
use paf::util::wire::fnv1a64;
use paf::util::Rng;
use std::path::Path;

/// Re-seal a mutated body with a freshly computed trailing digest, so
/// decode gets past the checksum and into the parser under test.
fn reseal(body: &[u8]) -> Vec<u8> {
    let mut out = body.to_vec();
    out.extend_from_slice(&fnv1a64(body).to_le_bytes());
    out
}

fn le_u64(bytes: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap())
}

/// A mid-solve *vector* checkpoint (nearness) — the multi-count wire
/// body: x, rows, per-row indices, trace.
fn vector_checkpoint_bytes() -> Vec<u8> {
    let mut rng = Rng::new(7);
    let inst = paf::graph::generators::type1_complete(16, &mut rng);
    let opts = SolveOptions::new().violation_tol(1e-6).inner_sweeps(2);
    let mut s = Session::new(opts);
    let h = s.add(Nearness::new(&inst).mode(OracleMode::Collect));
    for _ in 0..3 {
        s.step();
    }
    let ck = s.evict(h.index());
    persist::encode_checkpoint(&ck).expect("encode vector checkpoint")
}

/// A mid-solve *round* checkpoint (ITML snapshot codec).
fn round_checkpoint_bytes() -> Vec<u8> {
    let mut rng = Rng::new(7);
    let data = paf::ml::dataset::gaussian_mixture(60, 3, 2, 2.0, &mut rng);
    let icfg = PfItmlConfig { max_projections: 1500, batch: 40, seed: 7, ..Default::default() };
    let opts = SolveOptions::new().violation_tol(1e-6).inner_sweeps(2);
    let mut s = Session::new(opts);
    let h = s.add(PfItml::new(&data, icfg));
    for _ in 0..3 {
        s.step();
    }
    let ck = s.evict(h.index());
    persist::encode_checkpoint(&ck).expect("encode round checkpoint")
}

/// Walk a valid *vector*-kind body and return the byte offset of every
/// length-prefixed count in it (x, rows, each row's indices, trace) —
/// computed from the wire layout itself so the sweep can never drift
/// out of sync with the format.
fn vector_count_offsets(bytes: &[u8]) -> Vec<usize> {
    let mut offs = Vec::new();
    let mut at = 8 + 4 + 4; // magic + version + kind
    at += 8 + 8 + 8; // iterations, projections, last_dual_movement
    offs.push(at); // x count
    let nx = le_u64(bytes, at) as usize;
    at += 8 + 8 * nx;
    offs.push(at); // rows count
    let nrows = le_u64(bytes, at) as usize;
    at += 8;
    for _ in 0..nrows {
        offs.push(at); // row.indices count
        let k = le_u64(bytes, at) as usize;
        at += 8 + 4 * k + 8 * k + 8 + 8; // indices, coeffs, rhs, z
    }
    offs.push(at); // trace count
    let ntrace = le_u64(bytes, at) as usize;
    at += 8 + 12 * 8 * ntrace;
    at += 3 * 8; // phases
    assert_eq!(at, bytes.len() - 8, "walker lost sync with the wire layout");
    offs
}

#[test]
fn zero_length_and_tiny_files_are_corrupt_not_panics() {
    for len in 0..24usize {
        let err = persist::decode_checkpoint(&vec![0u8; len], Path::new("mem"))
            .expect_err("below the minimum frame size nothing can decode");
        assert!(
            matches!(err, ServeError::Corrupt { .. }),
            "len {len}: expected Corrupt, got {err}"
        );
    }
    // The on-disk path agrees: a zero-length file (the classic torn
    // create-then-crash artifact) is Corrupt, not a panic or an Io.
    let dir = std::env::temp_dir()
        .join(format!("paf-persist-hardening-empty-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create temp dir");
    let path = persist::checkpoint_path(&dir, 0);
    std::fs::write(&path, b"").expect("write empty file");
    let err = persist::load_checkpoint(&path).expect_err("empty file must not load");
    assert!(matches!(err, ServeError::Corrupt { .. }), "expected Corrupt, got {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Cut both checkpoint kinds at *every* byte boundary: every prefix —
/// mid-magic, mid-header, mid-count, mid-payload, mid-digest — must
/// decode to `Corrupt`. (A truncated file also loses its trailing
/// digest, so the checksum catches most cuts; the sweep proves no cut
/// point panics or slips through.)
#[test]
fn truncation_at_every_byte_boundary_is_corrupt() {
    for (label, bytes) in
        [("vector", vector_checkpoint_bytes()), ("round", round_checkpoint_bytes())]
    {
        for len in 0..bytes.len() {
            let err = persist::decode_checkpoint(&bytes[..len], Path::new("mem"))
                .expect_err("a strict prefix must never decode");
            assert!(
                matches!(err, ServeError::Corrupt { .. }),
                "{label} cut at {len}: expected Corrupt, got {err}"
            );
        }
    }
}

/// Re-sealed truncation: chop the body *and recompute the digest* so
/// the checksum passes and the parser itself hits the cut. Every cut
/// must still be a typed error — this is the field-boundary sweep the
/// checksum cannot help with.
#[test]
fn resealed_truncation_exercises_every_parser_field() {
    for (label, bytes) in
        [("vector", vector_checkpoint_bytes()), ("round", round_checkpoint_bytes())]
    {
        let body = &bytes[..bytes.len() - 8];
        for len in 0..body.len() {
            match persist::decode_checkpoint(&reseal(&body[..len]), Path::new("mem")) {
                // Cuts below the 24-byte floor are rejected pre-parse;
                // everything else must die inside the parser.
                Err(ServeError::Corrupt { .. }) => {}
                Ok(_) => panic!("{label} resealed cut at {len}: decoded a strict prefix"),
                Err(e) => panic!("{label} resealed cut at {len}: expected Corrupt, got {e}"),
            }
        }
    }
}

/// Absurd length-prefixed counts — `u64::MAX`, `u64::MAX / 8`, and a
/// just-too-big-by-one claim at every count field in the vector body,
/// re-sealed so the checksum passes. Decode must return `Corrupt`
/// without OOM-allocating: the per-element floors in
/// `Reader::get_count` bound every claim by the bytes actually
/// remaining.
#[test]
fn absurd_counts_are_rejected_without_allocation() {
    let bytes = vector_checkpoint_bytes();
    let offsets = vector_count_offsets(&bytes);
    assert!(offsets.len() >= 4, "expected x, rows, row.indices…, trace counts");
    let body_len = bytes.len() - 8;
    for &off in &offsets {
        let honest = le_u64(&bytes, off);
        let remaining = (body_len - off - 8) as u64;
        for claim in [u64::MAX, u64::MAX / 8, 1 << 61, remaining + 1, honest + remaining] {
            let mut body = bytes[..body_len].to_vec();
            body[off..off + 8].copy_from_slice(&claim.to_le_bytes());
            let err = persist::decode_checkpoint(&reseal(&body), Path::new("mem"))
                .expect_err("an impossible count must not decode");
            assert!(
                matches!(err, ServeError::Corrupt { .. }),
                "count at {off} claiming {claim}: expected Corrupt, got {err}"
            );
        }
    }
}

/// Stomp 8 bytes of `0xFF` at every offset of both kinds' bodies,
/// re-sealed: the parser must never panic. (Damage to f64 payloads
/// legitimately decodes — NaNs are representable; anything else must
/// be a typed error.)
#[test]
fn byte_stomp_sweep_never_panics() {
    for (label, bytes) in
        [("vector", vector_checkpoint_bytes()), ("round", round_checkpoint_bytes())]
    {
        let body_len = bytes.len() - 8;
        for at in 0..body_len {
            let mut body = bytes[..body_len].to_vec();
            let end = (at + 8).min(body_len);
            body[at..end].fill(0xFF);
            match persist::decode_checkpoint(&reseal(&body), Path::new("mem")) {
                Ok(_) => {}
                Err(ServeError::Corrupt { .. }) => {}
                Err(e) => panic!("{label} stomp at {at}: unexpected error kind {e}"),
            }
        }
    }
}

/// Trailing garbage after a structurally complete body (with a valid
/// digest over the whole thing) is still `Corrupt`: a checkpoint file
/// is exactly its frame, nothing more.
#[test]
fn trailing_bytes_after_the_body_are_corrupt() {
    let bytes = vector_checkpoint_bytes();
    let mut body = bytes[..bytes.len() - 8].to_vec();
    body.extend_from_slice(&[0u8; 4]);
    let err = persist::decode_checkpoint(&reseal(&body), Path::new("mem"))
        .expect_err("trailing bytes must not decode");
    assert!(matches!(err, ServeError::Corrupt { .. }), "expected Corrupt, got {err}");
}

/// The wrong-kind and wrong-version headers stay typed errors when the
/// digest is honest (regression guard for the explicit header checks).
#[test]
fn bad_headers_with_honest_digests_are_corrupt() {
    let bytes = vector_checkpoint_bytes();
    let body_len = bytes.len() - 8;
    // kind is the u32 after magic (8) + version (4).
    for (off, val, what) in
        [(8usize, 99u32, "version"), (12, 7, "kind"), (12, u32::MAX, "kind")]
    {
        let mut body = bytes[..body_len].to_vec();
        body[off..off + 4].copy_from_slice(&val.to_le_bytes());
        let err = persist::decode_checkpoint(&reseal(&body), Path::new("mem"))
            .expect_err("bad header field must not decode");
        assert!(
            matches!(err, ServeError::Corrupt { .. }),
            "{what}={val}: expected Corrupt, got {err}"
        );
    }
    let mut body = bytes[..body_len].to_vec();
    body[0] ^= 0xFF; // magic
    let err = persist::decode_checkpoint(&reseal(&body), Path::new("mem"))
        .expect_err("bad magic must not decode");
    assert!(matches!(err, ServeError::Corrupt { .. }), "magic: expected Corrupt, got {err}");
}
