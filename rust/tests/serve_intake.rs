//! Live-intake integration tests over real sockets: the wire protocol
//! is exactly the file-trace format, so malformed lines arriving over
//! TCP or a Unix socket must produce the *same* line-numbered
//! lenient-skip reports as [`parse_job_trace_lenient`] on the same
//! text, and a client that vanishes mid-line must not poison the
//! queue for the connections after it.

use paf::serve::{
    parse_job_trace_lenient, run_fleet, spawn_intake, FleetConfig, IntakeItem, IntakeSource,
    ServeConfig, ServeError,
};
use std::io::Write;
use std::path::PathBuf;

fn temp_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("paf-serve-intake-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir
}

/// A trace with two good jobs and two differently-malformed lines,
/// interleaved with comments and blanks so line numbering is earned.
const MIXED_TRACE: &str = "\
# mixed trace: good, torn JSON, good, unknown problem
{\"problem\": \"nearness\", \"n\": 12, \"seed\": 1}

{\"problem\": \"nearness\", \"n\": 13
{\"problem\": \"cc\", \"n\": 10, \"clusters\": 2, \"seed\": 2}
{\"problem\": \"sudoku\", \"n\": 9}
";

/// The same bytes through a TCP socket and through the file parser
/// yield identical jobs and identical skip reports — line numbers,
/// messages, everything.
#[test]
fn tcp_intake_skip_reports_match_the_file_trace_parser() {
    let (file_jobs, file_errors) = parse_job_trace_lenient(MIXED_TRACE);
    assert_eq!(file_jobs.len(), 2);
    assert_eq!(file_errors.len(), 2, "the trace has exactly two bad lines");

    let handle = spawn_intake(IntakeSource::Tcp("127.0.0.1:0".to_string())).expect("bind");
    let addr = handle.addr.expect("bound address");
    {
        let mut conn = std::net::TcpStream::connect(addr).expect("connect");
        conn.write_all(MIXED_TRACE.as_bytes()).expect("send trace");
        conn.write_all(b"drain\n").expect("send drain");
    }
    let items: Vec<IntakeItem> = handle.rx.iter().collect();
    handle.join();

    let mut jobs = Vec::new();
    let mut errors = Vec::new();
    let mut drained = false;
    for item in items {
        match item {
            IntakeItem::Job(j) => jobs.push(j),
            IntakeItem::Skip(e) => errors.push(e),
            IntakeItem::Drain => drained = true,
            IntakeItem::Halt => panic!("nobody sent a halt"),
        }
    }
    assert!(drained, "the drain control line must come through");
    assert_eq!(errors, file_errors, "socket skips must equal file-trace skips");
    assert_eq!(jobs.len(), file_jobs.len());
    for (got, want) in jobs.iter().zip(&file_jobs) {
        assert_eq!(got.id, want.id, "provisional ids count accepted jobs, like file ids");
        assert_eq!(got.name, want.name);
        assert_eq!(got.spec, want.spec);
    }
}

/// A client that disconnects mid-line (no trailing newline on a
/// half-written job) gets its dangling fragment reported as malformed,
/// and the next connection's jobs flow through untouched.
#[test]
fn mid_line_disconnect_does_not_poison_the_queue() {
    let handle = spawn_intake(IntakeSource::Tcp("127.0.0.1:0".to_string())).expect("bind");
    let addr = handle.addr.expect("bound address");
    {
        let mut conn = std::net::TcpStream::connect(addr).expect("connect 1");
        conn.write_all(b"{\"problem\": \"nearness\", \"n\": 12, \"seed\": 1}\n")
            .expect("send whole line");
        conn.write_all(b"{\"problem\": \"nea").expect("send fragment");
        // Drop: the write side closes mid-line.
    }
    {
        let mut conn = std::net::TcpStream::connect(addr).expect("connect 2");
        conn.write_all(b"{\"problem\": \"cc\", \"n\": 10, \"seed\": 2}\ndrain\n")
            .expect("send second connection");
    }
    let items: Vec<IntakeItem> = handle.rx.iter().collect();
    handle.join();

    assert_eq!(items.len(), 4, "job, fragment report, job, drain — got {items:?}");
    assert!(matches!(&items[0], IntakeItem::Job(j) if j.spec.kind() == "nearness"));
    assert!(
        matches!(&items[1], IntakeItem::Skip(ServeError::Trace { line: 2, .. })),
        "the fragment is reported at its connection-relative line: {:?}",
        items[1]
    );
    assert!(
        matches!(&items[2], IntakeItem::Job(j) if j.spec.kind() == "cc" && j.id == 1),
        "the next connection's job survives (ids keep counting): {:?}",
        items[2]
    );
    assert!(matches!(items[3], IntakeItem::Drain));
}

/// End-to-end over a Unix socket: jobs and skips flow through
/// [`run_fleet`], the skip reports land in the fleet stats with
/// file-trace-identical line numbers, and every accepted job completes.
#[test]
fn unix_socket_intake_feeds_a_fleet_end_to_end() {
    let dir = temp_dir("unix-fleet");
    let sock = dir.join("intake.sock");
    let handle = spawn_intake(IntakeSource::Unix(sock.clone())).expect("bind unix socket");
    {
        let mut conn = std::os::unix::net::UnixStream::connect(&sock).expect("connect");
        conn.write_all(MIXED_TRACE.as_bytes()).expect("send trace");
        conn.write_all(b"drain\n").expect("send drain");
    }

    let cfg = FleetConfig {
        shards: 2,
        shard: ServeConfig {
            capacity: 2,
            opts: paf::core::problem::SolveOptions::new()
                .violation_tol(1e-4)
                .inner_sweeps(2)
                .sharded(0),
            ..ServeConfig::default()
        },
        state_dir: Some(dir.clone()),
        ..FleetConfig::default()
    };
    let stats = run_fleet(Vec::new(), Some(handle), cfg, |_| {}).expect("fleet run");

    let (_, file_errors) = parse_job_trace_lenient(MIXED_TRACE);
    assert_eq!(stats.skipped_lines, file_errors.len());
    assert_eq!(stats.skipped, file_errors, "fleet skip reports equal file-trace skips");
    assert_eq!(stats.jobs.len(), 2, "both good jobs registered");
    assert!(stats.all_completed(), "accepted work completes: {stats:?}");
    assert!(stats.drained && !stats.halted);

    // The listener removes its socket file on the way out.
    for _ in 0..200 {
        if !sock.exists() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(!sock.exists(), "the drained listener cleans up its socket file");
    let _ = std::fs::remove_dir_all(&dir);
}
