//! Oracle-level pins for the incremental separation pipeline: the
//! dirty-source Collect scan must deliver the *identical* constraint
//! sequence and certificate as a full rescan — across randomized
//! sweep-like perturbations, through both dirty-set derivations (exact
//! snapshot diff and the engine's movement log) — and the per-round
//! double box pass must count its witnesses exactly once.
//!
//! These tests drive the oracle against recording sinks (no engine in
//! the loop) so the delivered sequence is pinned directly; end-to-end
//! bit-identity of full solves lives in `tests/determinism.rs`.

use paf::core::bregman::DiagonalQuadratic;
use paf::core::constraint::Constraint;
use paf::core::oracle::{Oracle, OracleOutcome, ProjectionSink};
use paf::graph::Graph;
use paf::problems::metric_oracle::{MetricOracle, OracleMode};
use paf::util::Rng;
use std::sync::Arc;

/// Records deliveries without projecting: `x` never moves inside a
/// round, so the second box pass re-sees every violation — which is
/// exactly what exposes double counting.
struct CaptureSink {
    x: Vec<f64>,
    delivered: Vec<Constraint>,
}

impl CaptureSink {
    fn new(x: &[f64]) -> CaptureSink {
        CaptureSink { x: x.to_vec(), delivered: Vec::new() }
    }
}

impl ProjectionSink for CaptureSink {
    fn x(&self) -> &[f64] {
        &self.x
    }

    fn remember(&mut self, c: &Constraint) {
        self.delivered.push(c.clone());
    }

    fn project_and_remember(&mut self, c: &Constraint) {
        self.delivered.push(c.clone());
    }
}

/// CaptureSink plus a hand-maintained movement log, so the oracle's
/// movement-hint fast path (instead of the snapshot diff) is exercised:
/// the test appends every coordinate it perturbs, exactly like the
/// engine marks every coordinate it moves.
struct TrackedCaptureSink {
    inner: CaptureSink,
    log: Vec<u32>,
}

impl ProjectionSink for TrackedCaptureSink {
    fn x(&self) -> &[f64] {
        &self.inner.x
    }

    fn remember(&mut self, c: &Constraint) {
        self.inner.remember(c);
    }

    fn project_and_remember(&mut self, c: &Constraint) {
        self.inner.project_and_remember(c);
    }

    fn movement_cursor(&mut self) -> Option<u64> {
        Some(self.log.len() as u64)
    }

    fn moved_since(&self, cursor: u64, out: &mut Vec<u32>) -> bool {
        if cursor > self.log.len() as u64 {
            return false;
        }
        out.extend(&self.log[cursor as usize..]);
        true
    }
}

fn separate_capture(oracle: &mut MetricOracle, x: &[f64]) -> (OracleOutcome, Vec<Constraint>) {
    let mut sink = CaptureSink::new(x);
    let out = Oracle::<DiagonalQuadratic>::separate(oracle, &mut sink);
    (out, sink.delivered)
}

fn assert_same_round(
    label: &str,
    full: &(OracleOutcome, Vec<Constraint>),
    inc: &(OracleOutcome, Vec<Constraint>),
) {
    assert_eq!(full.0.found, inc.0.found, "{label}: found diverged");
    assert_eq!(
        full.0.max_violation.to_bits(),
        inc.0.max_violation.to_bits(),
        "{label}: certificate diverged"
    );
    assert_eq!(full.1, inc.1, "{label}: delivered sequence diverged");
}

#[test]
fn incremental_equals_full_across_randomized_perturbations() {
    let mut rng = Rng::new(301);
    for (gi, graph) in [
        Graph::complete(14),
        paf::graph::generators::erdos_renyi(24, 0.3, &mut Rng::new(77)),
    ]
    .into_iter()
    .enumerate()
    {
        let g = Arc::new(graph);
        let m = g.num_edges();
        let mut full = MetricOracle::new(g.clone(), OracleMode::Collect);
        full.incremental = false;
        let mut inc = MetricOracle::new(g.clone(), OracleMode::Collect);
        let mut x: Vec<f64> = (0..m).map(|_| rng.uniform(-0.2, 2.0)).collect();
        for round in 0..25 {
            let a = separate_capture(&mut full, &x);
            let b = separate_capture(&mut inc, &x);
            assert_same_round(&format!("graph {gi} round {round} (diff path)"), &a, &b);
            // Sweep-like perturbation: between 0 and ~10% of coordinates.
            let moves = rng.below(1 + m / 10);
            for _ in 0..moves {
                let e = rng.below(m);
                x[e] += rng.uniform(-0.15, 0.15);
            }
        }
    }
}

#[test]
fn movement_hint_path_equals_full_scan() {
    let mut rng = Rng::new(302);
    let g = Arc::new(Graph::complete(16));
    let m = g.num_edges();
    let mut full = MetricOracle::new(g.clone(), OracleMode::Collect);
    full.incremental = false;
    let mut inc = MetricOracle::new(g.clone(), OracleMode::Collect);
    let mut tracked =
        TrackedCaptureSink { inner: CaptureSink::new(&[]), log: Vec::new() };
    let mut x: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 2.0)).collect();
    for round in 0..20 {
        let a = separate_capture(&mut full, &x);
        tracked.inner = CaptureSink::new(&x);
        let out = Oracle::<DiagonalQuadratic>::separate(&mut inc, &mut tracked);
        assert_same_round(
            &format!("round {round} (movement-hint path)"),
            &a,
            &(out, std::mem::take(&mut tracked.inner.delivered)),
        );
        // Perturb AND log — the engine's contract: every moved
        // coordinate is marked (a superset never hurts, a miss would).
        for _ in 0..rng.below(1 + m / 20) {
            let e = rng.below(m);
            x[e] += rng.uniform(-0.1, 0.1);
            tracked.log.push(e as u32);
        }
    }
}

#[test]
fn box_violations_count_once_but_deliver_twice() {
    // K3 with one negative edge: exactly one nonneg violation, no cycle
    // violations under the clamp (the cycle faces of the clamped iterate
    // are metric). The old double-counting bug reported found == 2 here.
    let g = Arc::new(Graph::complete(3));
    let mut oracle = MetricOracle::new(g.clone(), OracleMode::Collect);
    let x = vec![-1.0, 1.0, 1.0];
    let (out, delivered) = separate_capture(&mut oracle, &x);
    assert_eq!(out.found, 1, "box violations must count on the first pass only");
    assert_eq!(out.max_violation, 1.0);
    // Both passes still *deliver* every box row (relaxation projections
    // need them): 3 nonneg rows twice, no cycle rows.
    assert_eq!(delivered.len(), 6, "both box passes must keep delivering");
    assert!(delivered.iter().all(|c| c.indices.len() == 1));
}

#[test]
fn upper_bound_violations_also_count_once() {
    let g = Arc::new(Graph::complete(3));
    let mut oracle = MetricOracle::new(g.clone(), OracleMode::Collect);
    oracle.upper_bound = Some(1.5);
    // Two edges above the bound, none negative, cycle faces metric.
    let x = vec![0.5, 1.9, 1.9];
    let (out, delivered) = separate_capture(&mut oracle, &x);
    assert_eq!(out.found, 2, "upper-bound violations must count once");
    assert!((out.max_violation - 0.4).abs() < 1e-12);
    // 3 nonneg + 3 upper rows per pass, two passes, no cycles.
    assert_eq!(delivered.len(), 12);
}

#[test]
fn overlap_scan_deliver_split_matches_separate() {
    use paf::core::oracle::OverlappableOracle;
    let mut rng = Rng::new(303);
    let g = Arc::new(Graph::complete(12));
    let m = g.num_edges();
    let x: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 2.0)).collect();
    let mut a = MetricOracle::new(g.clone(), OracleMode::Collect);
    let mut b = MetricOracle::new(g.clone(), OracleMode::Collect);
    let full = separate_capture(&mut a, &x);
    let scan = OverlappableOracle::<DiagonalQuadratic>::scan(&b, &x);
    let mut sink = CaptureSink::new(&x);
    let out = OverlappableOracle::<DiagonalQuadratic>::deliver(&mut b, scan, &mut sink);
    assert_same_round("scan+deliver vs separate", &full, &(out, sink.delivered));
}
