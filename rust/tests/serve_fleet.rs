//! Integration tests for the multi-shard fleet supervisor: placement,
//! shard health-checks, checkpoint-based work migration off dead
//! shards, fleet-level overload shedding, and halt/restart through the
//! manifest journal.
//!
//! The load-bearing invariant throughout (inherited from the scheduler
//! and extended across shard death): every job's final `SolverResult`
//! is bit-identical to its uninterrupted solo solve, no matter how
//! many times it was checkpointed, migrated, or carried across a
//! process boundary. That makes every test here timing-robust — the
//! *moment* a fault lands never changes the answer, only the route.

use paf::core::problem::SolveOptions;
use paf::core::solver::SolverResult;
use paf::serve::{
    run_fleet, solve_job_solo, FaultPlan, FleetConfig, FleetEvent, FleetStats, IntakeSource,
    Job, JobBank, JobSpec, ServeConfig,
};
use std::io::Write;
use std::path::PathBuf;

/// A per-test scratch directory (tests run in parallel in one process,
/// so the test name disambiguates; the pid isolates concurrent runs).
fn temp_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("paf-serve-fleet-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create temp state dir");
    dir
}

fn assert_bit_identical(reference: &SolverResult, got: &SolverResult, label: &str) {
    assert_eq!(reference.x, got.x, "{label}: x differs (bitwise)");
    assert_eq!(reference.iterations, got.iterations, "{label}: iteration count differs");
    assert_eq!(reference.converged, got.converged, "{label}: convergence differs");
    assert_eq!(
        reference.total_projections, got.total_projections,
        "{label}: projection count differs"
    );
    assert_eq!(
        reference.active_constraints, got.active_constraints,
        "{label}: active-set size differs"
    );
}

/// Shared solve options. `sharded(0)` defers the thread count to
/// `PAF_THREADS`, so the CI matrix legs exercise both engines without
/// the tests multiplying — the sharded sweep is thread-count invariant,
/// so bit-identity holds on every leg.
fn fleet_opts() -> SolveOptions {
    SolveOptions::new().violation_tol(1e-4).inner_sweeps(2).sharded(0)
}

fn nearness_job(id: usize, n: usize) -> Job {
    Job {
        id,
        name: format!("near-{id}"),
        spec: JobSpec::Nearness { n, graph_type: 1, seed: id as u64 + 1 },
        priority: 0,
        arrival_round: 0,
        max_rounds: None,
        deadline_rounds: None,
        deadline_ms: None,
    }
}

/// Six mixed-size jobs: big enough to outlive the injected fault
/// rounds, small enough to keep the tests quick.
fn six_jobs() -> Vec<Job> {
    (0..6).map(|id| nearness_job(id, 16 + 2 * id)).collect()
}

fn solo_results(jobs: &[Job], opts: &SolveOptions) -> Vec<SolverResult> {
    let bank = JobBank::materialize(jobs);
    jobs.iter()
        .map(|j| solve_job_solo(j, bank.input(j.id), opts).expect("solo solve").result)
        .collect()
}

/// Every job's fleet result must be bitwise the solo result. Jobs with
/// no stats (done in a prior process) are the caller's problem.
fn assert_fleet_matches_solo(stats: &FleetStats, solo: &[SolverResult], label: &str) {
    assert!(stats.all_completed(), "{label}: unfinished jobs: {stats:?}");
    for (g, js) in stats.jobs.iter().enumerate() {
        let s = js.stats.as_ref().unwrap_or_else(|| panic!("{label}: job {g} has no stats"));
        let got = s.result.as_ref().unwrap_or_else(|| panic!("{label}: job {g} has no result"));
        assert_bit_identical(&solo[g], got, &format!("{label}, job {g} ({})", js.name));
    }
}

/// No faults: a three-shard fleet drains a trace with deterministic
/// least-loaded placement, and every result is bit-identical to solo.
#[test]
fn three_shard_fleet_completes_a_trace_bit_identically_to_solo() {
    let dir = temp_dir("three-shard");
    let jobs = six_jobs();
    let opts = fleet_opts();
    let solo = solo_results(&jobs, &opts);

    let cfg = FleetConfig {
        shards: 3,
        shard: ServeConfig {
            capacity: 2,
            opts: opts.clone(),
            checkpoint_every: Some(1),
            ..ServeConfig::default()
        },
        state_dir: Some(dir.clone()),
        ..FleetConfig::default()
    };
    let stats = run_fleet(jobs, None, cfg, |_| {}).expect("valid fleet config");

    assert!(stats.drained, "a trace-only fleet must drain cleanly");
    assert!(!stats.halted);
    assert_eq!(stats.migrations, 0, "no faults, no migrations");
    assert_fleet_matches_solo(&stats, &solo, "three-shard");
    for (k, sh) in stats.shards.iter().enumerate() {
        assert!(!sh.dead, "shard {k} must survive");
        assert_eq!(sh.assigned, 2, "least-loaded placement spreads 6 jobs 2/2/2");
        assert_eq!(sh.completed, 2, "shard {k} finishes what it was assigned");
        assert!(sh.rounds > 0, "shard {k} must have run rounds");
    }
    // Completed jobs drain their durable state; only the manifest stays.
    for k in 0..3 {
        let left = paf::serve::scan_state_dir(&dir.join(format!("shard-{k}")))
            .map(|v| v.len())
            .unwrap_or(0);
        assert_eq!(left, 0, "shard {k} state dir must be empty after a drain");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ISSUE's acceptance test: kill shard 0 at (generation-local)
/// round 2. The supervisor detects the death, reads the dead shard's
/// durable checkpoints, and re-places the orphaned jobs on survivors —
/// and every job, migrated or not, still finishes bit-identical to its
/// uninterrupted solo solve.
#[test]
fn killed_shard_migrates_work_with_bit_identical_continuation() {
    let dir = temp_dir("kill-shard");
    let jobs = six_jobs();
    let opts = fleet_opts();
    let solo = solo_results(&jobs, &opts);

    let cfg = FleetConfig {
        shards: 3,
        shard: ServeConfig {
            capacity: 2,
            opts: opts.clone(),
            checkpoint_every: Some(1),
            ..ServeConfig::default()
        },
        state_dir: Some(dir.clone()),
        fault_plan: FaultPlan { kill_shard: Some((0, 2)), ..Default::default() },
        ..FleetConfig::default()
    };
    let stats = run_fleet(jobs, None, cfg, |_| {}).expect("valid fleet config");

    assert!(stats.shards[0].dead, "the killed shard must be declared dead");
    assert!(stats.shards[0].cause.is_some(), "a dead shard carries its cause");
    assert!(stats.migrations >= 1, "the dead shard's work must migrate: {stats:?}");
    assert!(
        stats.events.iter().any(|e| matches!(
            e.event,
            FleetEvent::ShardDead { shard: 0, .. }
        )),
        "shard death must be in the event stream"
    );
    assert!(
        stats.events.iter().any(|e| matches!(
            e.event,
            FleetEvent::Placed { migrated: true, .. }
        )),
        "migration re-placement must be in the event stream"
    );
    let migrated: Vec<usize> = (0..stats.jobs.len())
        .filter(|&g| stats.jobs[g].migrations > 0)
        .collect();
    assert!(!migrated.is_empty(), "at least one job must have migrated");
    for &g in &migrated {
        assert_ne!(stats.jobs[g].shard, 0, "migrated jobs land on a survivor");
    }
    assert!(stats.drained, "survivors must finish everything");
    assert_fleet_matches_solo(&stats, &solo, "kill-shard");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A stalled shard (heartbeat frozen, thread alive) is detected by the
/// heartbeat timeout, declared dead, and its work migrates the same
/// checkpoint route as a crash.
#[test]
fn stalled_shard_is_detected_by_heartbeat_and_work_migrates() {
    let dir = temp_dir("stall-shard");
    let jobs: Vec<Job> = (0..4).map(|id| nearness_job(id, 16 + 2 * id)).collect();
    let opts = fleet_opts();
    let solo = solo_results(&jobs, &opts);

    let cfg = FleetConfig {
        shards: 2,
        shard: ServeConfig {
            capacity: 2,
            opts: opts.clone(),
            checkpoint_every: Some(1),
            ..ServeConfig::default()
        },
        state_dir: Some(dir.clone()),
        fault_plan: FaultPlan { stall_shard: Some((0, 2)), ..Default::default() },
        stall_timeout_ms: 300,
        ..FleetConfig::default()
    };
    let stats = run_fleet(jobs, None, cfg, |_| {}).expect("valid fleet config");

    assert!(stats.shards[0].dead, "the stalled shard must be declared dead");
    let cause = stats.shards[0].cause.as_deref().unwrap_or("");
    assert!(cause.contains("stalled"), "the cause names the stall, got {cause:?}");
    assert!(stats.migrations >= 1, "the stalled shard's work must migrate");
    assert!(stats.drained, "the survivor must finish everything");
    assert_fleet_matches_solo(&stats, &solo, "stall-shard");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fleet-level overload control: with more arrivals than the high-water
/// mark, the lowest-priority unplaced jobs are shed deterministically
/// before any shard sees them.
#[test]
fn high_water_sheds_the_lowest_priority_arrivals() {
    let dir = temp_dir("high-water");
    let mut jobs = six_jobs();
    for (i, j) in jobs.iter_mut().enumerate() {
        j.priority = 5 - i as i64; // job 5 is the least important
    }
    let opts = fleet_opts();

    let cfg = FleetConfig {
        shards: 2,
        shard: ServeConfig {
            capacity: 2,
            opts: opts.clone(),
            ..ServeConfig::default()
        },
        state_dir: Some(dir.clone()),
        queue_high_water: Some(4),
        ..FleetConfig::default()
    };
    let stats = run_fleet(jobs, None, cfg, |_| {}).expect("valid fleet config");

    assert_eq!(stats.shed, 2, "6 arrivals over a high-water of 4 shed exactly 2");
    let shed: Vec<usize> = stats
        .events
        .iter()
        .filter_map(|e| match e.event {
            FleetEvent::Shed { job } => Some(job),
            _ => None,
        })
        .collect();
    assert_eq!(shed, vec![5, 4], "shedding is lowest-priority-first, deterministic");
    for &g in &[4usize, 5] {
        let s = stats.jobs[g].stats.as_ref().expect("shed jobs get a terminal record");
        assert!(s.shed && s.completed_round.is_none());
    }
    assert!(stats.drained);
    assert!(!stats.all_completed(), "shed jobs never complete");
    for g in 0..4 {
        assert!(stats.jobs[g].completed(), "surviving job {g} completes");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Halt over live TCP intake, then restart over the same state root:
/// the manifest journal re-registers every accepted job (placed or
/// not), the second fleet finishes whatever the first did not, and
/// each job's result — whichever process produced it — is bit-identical
/// to solo.
#[test]
fn halt_persists_and_a_second_fleet_resumes_to_completion() {
    let dir = temp_dir("halt-restart");
    let jobs: Vec<Job> = (0..3).map(|id| nearness_job(id, 18 + 2 * id)).collect();
    let opts = fleet_opts();
    let solo = solo_results(&jobs, &opts);

    let cfg = FleetConfig {
        shards: 2,
        shard: ServeConfig {
            capacity: 2,
            opts: opts.clone(),
            checkpoint_every: Some(1),
            ..ServeConfig::default()
        },
        state_dir: Some(dir.clone()),
        ..FleetConfig::default()
    };

    // Process 1: live intake, three jobs, then a halt order mid-service.
    let intake = paf::serve::spawn_intake(IntakeSource::Tcp("127.0.0.1:0".to_string()))
        .expect("bind tcp intake");
    let addr = intake.addr.expect("tcp intake knows its bound address");
    let cfg1 = cfg.clone();
    let fleet = std::thread::spawn(move || run_fleet(Vec::new(), Some(intake), cfg1, |_| {}));
    {
        let mut conn = std::net::TcpStream::connect(addr).expect("connect intake");
        for j in &jobs {
            writeln!(conn, "{}", j.to_json_line()).expect("send job line");
        }
    }
    // Let the fleet accept (and usually start) the work, then halt. The
    // exact cut point does not matter: determinism makes any interleave
    // of completed / checkpointed / never-placed jobs equivalent.
    std::thread::sleep(std::time::Duration::from_millis(150));
    {
        let mut conn = std::net::TcpStream::connect(addr).expect("connect for halt");
        writeln!(conn, "halt").expect("send halt");
    }
    let first = fleet.join().expect("fleet thread").expect("fleet run 1");
    assert!(first.halted, "the halt order must be honored");
    assert!(first.drained, "a halt is a clean exit — state persisted");
    assert_eq!(first.jobs.len(), 3, "every accepted job is registered");
    assert!(
        first.events.iter().any(|e| matches!(e.event, FleetEvent::HaltStarted)),
        "the halt must be in the event stream"
    );

    // Process 2: same state root, no trace, no intake — the manifest is
    // the workload.
    let second = run_fleet(Vec::new(), None, cfg, |_| {}).expect("fleet run 2");
    assert!(
        second.events.iter().any(|e| matches!(e.event, FleetEvent::Resumed { .. })),
        "run 2 must resume from the manifest"
    );
    assert_eq!(second.jobs.len(), 3, "the manifest re-registers every job");
    assert!(second.all_completed(), "run 2 finishes everything: {second:?}");
    assert!(second.drained && !second.halted);
    for g in 0..3 {
        let done_in_first = first.jobs[g].completed();
        if done_in_first {
            assert!(second.jobs[g].done_prior, "run 2 must know job {g} was done prior");
        }
        // The terminal record lives in whichever process finished the
        // job; compare that one against solo.
        let record = if done_in_first { &first.jobs[g] } else { &second.jobs[g] };
        let s = record.stats.as_ref().unwrap_or_else(|| panic!("job {g} has no stats"));
        let got = s.result.as_ref().unwrap_or_else(|| panic!("job {g} has no result"));
        assert_bit_identical(&solo[g], got, &format!("halt-restart job {g}"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A shed is terminal *durably*: the shed is journaled, so a restart
/// over the same state root reports the job shed again instead of
/// resurrecting and running it.
#[test]
fn shed_jobs_stay_shed_across_a_restart() {
    let dir = temp_dir("shed-restart");
    let mut jobs = six_jobs();
    for (i, j) in jobs.iter_mut().enumerate() {
        j.priority = 5 - i as i64; // job 5 is the least important
    }
    let opts = fleet_opts();

    let cfg = FleetConfig {
        shards: 2,
        shard: ServeConfig {
            capacity: 2,
            opts: opts.clone(),
            ..ServeConfig::default()
        },
        state_dir: Some(dir.clone()),
        queue_high_water: Some(4),
        ..FleetConfig::default()
    };
    let first = run_fleet(jobs, None, cfg.clone(), |_| {}).expect("fleet run 1");
    assert_eq!(first.shed, 2, "6 arrivals over a high-water of 4 shed exactly 2");
    assert!(first.drained);

    // Same state root, no trace: the manifest is the workload, and it
    // must remember both the completions and the sheds.
    let second = run_fleet(Vec::new(), None, cfg, |_| {}).expect("fleet run 2");
    assert_eq!(second.jobs.len(), 6, "the manifest re-registers every job");
    assert_eq!(second.shed, 2, "shed jobs replay as shed, not as runnable");
    assert_eq!(second.completed, 4, "completed jobs replay as done-prior");
    assert!(
        !second.events.iter().any(|e| matches!(e.event, FleetEvent::Placed { .. })),
        "nothing runs on a fully-terminal manifest: {:?}",
        second.events
    );
    for sh in &second.shards {
        assert_eq!(sh.assigned, 0, "no shard may be handed a shed or done job");
    }
    for &g in &[4usize, 5] {
        let s = second.jobs[g].stats.as_ref().expect("shed jobs keep a terminal record");
        assert!(s.shed && s.completed_round.is_none());
    }
    assert!(second.drained);
    assert!(!second.all_completed(), "shed jobs never complete, even across a restart");
    let _ = std::fs::remove_dir_all(&dir);
}

/// An idle shard is not a stalled shard. A worker blocked on an empty
/// queue emits no heartbeats, so after an idle gap longer than the
/// stall timeout the next arrival used to be fatal: the health pass ran
/// in the same supervisor iteration as placement and killed the shard
/// before its worker could wake. The supervisor now stamps the
/// heartbeat on every successful assignment, so staleness only ever
/// measures a shard that *held* work and stopped beating.
#[test]
fn idle_gap_longer_than_stall_timeout_is_not_a_stall() {
    let dir = temp_dir("idle-gap");
    let jobs: Vec<Job> = (0..2).map(|id| nearness_job(id, 14)).collect();
    let opts = fleet_opts();
    let solo = solo_results(&jobs, &opts);

    let cfg = FleetConfig {
        shards: 1,
        shard: ServeConfig {
            capacity: 2,
            opts: opts.clone(),
            ..ServeConfig::default()
        },
        state_dir: Some(dir.clone()),
        stall_timeout_ms: 200,
        ..FleetConfig::default()
    };
    let intake = paf::serve::spawn_intake(IntakeSource::Tcp("127.0.0.1:0".to_string()))
        .expect("bind tcp intake");
    let addr = intake.addr.expect("tcp intake knows its bound address");
    let (ev_tx, ev_rx) = std::sync::mpsc::channel();
    let fleet = std::thread::spawn(move || {
        run_fleet(Vec::new(), Some(intake), cfg, move |e| {
            let _ = ev_tx.send(e.clone());
        })
    });

    {
        let mut conn = std::net::TcpStream::connect(addr).expect("connect intake");
        writeln!(conn, "{}", jobs[0].to_json_line()).expect("send job 0");
    }
    // Wait until job 0 is fully done, then idle well past the stall
    // timeout before the next arrival.
    for ev in ev_rx.iter() {
        if matches!(ev, FleetEvent::JobDone { job: 0, .. }) {
            break;
        }
    }
    std::thread::sleep(std::time::Duration::from_millis(800));
    {
        let mut conn = std::net::TcpStream::connect(addr).expect("connect intake again");
        writeln!(conn, "{}", jobs[1].to_json_line()).expect("send job 1");
        writeln!(conn, "drain").expect("send drain");
    }
    let stats = fleet.join().expect("fleet thread").expect("fleet run");

    assert!(
        !stats.shards[0].dead,
        "an idle gap must not read as a stall: {:?}",
        stats.shards[0].cause
    );
    assert_eq!(stats.migrations, 0, "nothing died, nothing migrates");
    assert!(
        !stats.events.iter().any(|e| matches!(e.event, FleetEvent::ShardDead { .. })),
        "no shard-death may be declared: {:?}",
        stats.events
    );
    assert!(stats.drained, "{stats:?}");
    assert_fleet_matches_solo(&stats, &solo, "idle-gap");
    let _ = std::fs::remove_dir_all(&dir);
}
