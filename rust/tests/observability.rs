//! Observability contracts pinned end to end: the committed example
//! Chrome trace stays loadable, live exports round-trip through the
//! same validator CI re-implements in python3, serve events carry
//! dense sequence numbers, and telemetry frames survive the
//! schema-versioned JSON. (Bit-identity of instrumented vs
//! uninstrumented solves is pinned in `tests/determinism.rs`.)

use paf::obs::{validate_chrome_trace, TelemetryFrame};
use paf::runtime::json::Json;

/// The committed example trace (the shape `paf serve --trace-out`
/// produces: per-worker track rows, nested round/oracle-scan/sweep/
/// forget/checkpoint-persist spans) must load as valid Chrome
/// trace-event JSON — strict B/E pairing, monotone per-thread
/// timestamps.
#[test]
fn committed_example_trace_is_valid_chrome_trace_json() {
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/example_trace.json"
    ))
    .expect("example trace fixture");
    let pairs = validate_chrome_trace(&text).expect("fixture must validate");
    assert_eq!(pairs, 13, "every recorded span closes exactly once");
    // The span taxonomy the README documents is represented.
    for kind in ["round", "oracle-scan", "sweep", "shard", "forget", "checkpoint-persist"] {
        assert!(text.contains(&format!("\"name\": \"{kind}\"")), "missing {kind} span");
    }
    // Pool workers get their own named track rows.
    assert!(text.contains("paf-pool-0") && text.contains("paf-pool-1"));
    // And the document parses with the repo's own JSON reader too.
    let doc = Json::parse(&text).expect("fixture parses");
    let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");
    assert_eq!(events.len(), 30, "13 B/E pairs plus 4 metadata rows");
}

/// A serve run with tracing enabled exports a trace whose serve-side
/// span kinds are present, and the serve JSON carries schema-v6 dense
/// event sequence numbers.
#[test]
fn serve_run_exports_valid_trace_and_sequenced_events() {
    use paf::core::problem::SolveOptions;
    use paf::serve::{serve_stats_json, Job, JobBank, JobSpec, Scheduler, ServeConfig};
    let jobs = vec![Job {
        id: 0,
        name: "solo".to_string(),
        spec: JobSpec::Nearness { n: 12, graph_type: 1, seed: 9 },
        priority: 0,
        arrival_round: 0,
        max_rounds: None,
        deadline_rounds: None,
        deadline_ms: None,
    }];
    let bank = JobBank::materialize(&jobs);
    let cfg = ServeConfig {
        capacity: 1,
        opts: SolveOptions::new().violation_tol(1e-4),
        ..Default::default()
    };
    paf::obs::set_spans_enabled(true);
    let stats = Scheduler::new(jobs, &bank, cfg).expect("valid serve config").run();
    paf::obs::set_spans_enabled(
        std::env::var("PAF_TRACE").map(|v| !v.is_empty() && v != "0").unwrap_or(false),
    );
    assert!(stats.all_completed());
    let trace = paf::obs::chrome_trace_json();
    let pairs = validate_chrome_trace(&trace).expect("live serve trace must validate");
    assert!(pairs > 0, "the serve run must record spans");
    assert!(trace.contains("\"name\": \"round\""), "session rounds are spanned");

    let text = serve_stats_json("obs-test", &stats);
    let doc = Json::parse(&text).expect("serve JSON parses");
    assert!(
        doc.get("schema_version").and_then(|v| v.as_usize())
            >= Some(6),
        "serve JSON must be schema v6+"
    );
    let events = doc.get("events").and_then(|e| e.as_arr()).expect("events");
    assert!(!events.is_empty());
    for (i, e) in events.iter().enumerate() {
        assert_eq!(e.get("seq").and_then(|v| v.as_usize()), Some(i));
    }
}

/// Telemetry frames survive the solver JSON round-trip with their
/// sampled quantities intact (the schema-v6 additive `telemetry`
/// array), and the CSV rendering matches the documented header.
#[test]
fn telemetry_round_trips_through_solver_json_and_csv() {
    use paf::core::problem::SolveOptions;
    use paf::graph::generators::type1_complete;
    use paf::problems::metric_oracle::OracleMode;
    use paf::problems::nearness::Nearness;
    use paf::util::Rng;
    let mut rng = Rng::new(77);
    let inst = type1_complete(12, &mut rng);
    let opts = SolveOptions::new().violation_tol(1e-4).telemetry_every(2);
    let res = Nearness::new(&inst).mode(OracleMode::Collect).solve(&opts).result;
    assert!(res.converged);
    assert!(!res.telemetry.is_empty(), "telemetry_every=2 must sample frames");
    for f in &res.telemetry {
        assert!(f.round % 2 == 0, "frames land on the sampling grid");
        assert!(f.max_violation.is_finite() && f.dual_l1 >= 0.0);
    }

    let text = paf::report::solver_result_json("obs-telemetry", &res);
    let doc = Json::parse(&text).expect("solver JSON parses");
    let tel = doc.get("telemetry").and_then(|t| t.as_arr()).expect("telemetry array");
    assert_eq!(tel.len(), res.telemetry.len());
    let first: &TelemetryFrame = &res.telemetry[0];
    assert_eq!(
        tel[0].get("active_rows").and_then(|v| v.as_usize()),
        Some(first.active_rows)
    );
    assert_eq!(
        tel[0].get("rows_projected").and_then(|v| v.as_usize()),
        Some(first.rows_projected)
    );

    let csv = paf::obs::telemetry_csv(&res.telemetry);
    let mut lines = csv.lines();
    assert_eq!(
        lines.next(),
        Some("round,max_violation,active_rows,dual_l1,moved_fraction,rows_projected,rows_skipped,forget_evictions")
    );
    assert_eq!(lines.count(), res.telemetry.len());
}
