//! Streaming-ingest integration suite (PR-8 tentpole).
//!
//! Pins the subsystem's load-bearing contract: the two-pass streaming
//! CSR builder is **bit-identical** to the legacy in-memory reader on
//! every input both accept — same compacted graph, same weights, and
//! therefore the same `SolverResult` on a nearness solve — while also
//! handling what the legacy reader cannot: DIMACS files, u64 ids above
//! `u32::MAX`, explicit duplicate policies, byte budgets, line-numbered
//! parse errors, and disk-generated instances at n ≥ 10⁵.
//!
//! Runs with cwd = the `rust/` package root, so fixture paths are
//! `tests/fixtures/...`.

use paf::core::problem::SolveOptions;
use paf::graph::generators::WeightedInstance;
use paf::graph::ingest::{
    self, neighborhood_scope, DupPolicy, EdgeScope, IngestFormat, IngestOptions,
};
use paf::graph::io::{read_edge_list, read_edge_list_with};
use paf::problems::metric_oracle::{MetricOracle, OracleMode};
use paf::problems::nearness::Nearness;
use std::path::PathBuf;
use std::sync::Arc;

const SMALL: &str = "tests/fixtures/ingest_small.tsv";
const DUP: &str = "tests/fixtures/ingest_dup.tsv";
const SIGNED: &str = "tests/fixtures/ingest_signed.tsv";
const GRID_GR: &str = "tests/fixtures/grid.gr";
const GRID_CO: &str = "tests/fixtures/grid.co";

fn tmp(name: &str, contents: &str) -> PathBuf {
    let path = std::env::temp_dir().join(format!("paf_ingest_{name}_{}", std::process::id()));
    std::fs::write(&path, contents).unwrap();
    path
}

fn assert_same_instance(a: &WeightedInstance, b: &WeightedInstance, label: &str) {
    assert_eq!(a.graph.num_nodes(), b.graph.num_nodes(), "{label}: node count");
    assert_eq!(a.graph.edges(), b.graph.edges(), "{label}: edge list");
    assert_eq!(a.weights, b.weights, "{label}: weights (bitwise)");
}

#[test]
fn streaming_matches_legacy_reader_bitwise() {
    for path in [SMALL, DUP, SIGNED] {
        let legacy = read_edge_list(path).unwrap();
        let streamed = ingest::ingest_weighted(path, IngestOptions::default()).unwrap();
        assert_same_instance(&legacy, &streamed.inst, path);
        // The id table is the legacy compaction: sorted raw ids.
        let mut sorted = streamed.ids.clone();
        sorted.sort_unstable();
        assert_eq!(streamed.ids, sorted, "{path}: id table not sorted");
    }
}

#[test]
fn streaming_and_legacy_solve_identically() {
    let legacy = read_edge_list(SMALL).unwrap();
    let streamed = ingest::ingest_weighted(SMALL, IngestOptions::default()).unwrap();
    let opts = SolveOptions { violation_tol: 1e-8, dual_tol: 1e-8, ..SolveOptions::default() };
    let a = Nearness::new(&legacy).solve(&opts);
    let b = Nearness::new(&streamed.inst).solve(&opts);
    assert!(a.result.converged && b.result.converged);
    assert_eq!(a.result.x, b.result.x, "solver outputs diverged (bitwise)");
    assert_eq!(a.result.iterations, b.result.iterations);
    assert_eq!(a.result.total_projections, b.result.total_projections);
}

#[test]
fn dup_policies_match_legacy_and_each_other() {
    // KeepFirst is the legacy default: first file-order weight wins.
    let legacy = read_edge_list(DUP).unwrap();
    let first = ingest::ingest_weighted(DUP, IngestOptions::default()).unwrap();
    assert_same_instance(&legacy, &first.inst, "keep-first vs legacy");
    assert_eq!(first.stats.duplicates, 2);

    let last = ingest::ingest_weighted(
        DUP,
        IngestOptions { dup_policy: DupPolicy::KeepLast, ..IngestOptions::default() },
    )
    .unwrap();
    // Same structure, different surviving weights on the dup edges.
    assert_eq!(first.inst.graph.edges(), last.inst.graph.edges());
    assert_ne!(first.inst.weights, last.inst.weights);
    // And KeepLast agrees with the legacy reader under the same policy.
    let legacy_last = read_edge_list_with(DUP, DupPolicy::KeepLast).unwrap();
    assert_same_instance(&legacy_last, &last.inst, "keep-last vs legacy");

    let err = ingest::ingest_weighted(
        DUP,
        IngestOptions { dup_policy: DupPolicy::Error, ..IngestOptions::default() },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("duplicate"), "unhelpful error: {err}");
    assert!(err.contains('1') && err.contains('2'), "should name raw ids: {err}");
}

#[test]
fn crlf_and_whitespace_are_tolerated() {
    // Written via std::fs at test time (committing CRLF fixtures risks
    // git newline normalization).
    let path = tmp("crlf", "# header\r\n1 2 1.5\r\n\r\n  2   3\t2.5  \r\n3 1 2.0\r\n");
    let streamed = ingest::ingest_weighted(&path, IngestOptions::default()).unwrap();
    assert_eq!(streamed.inst.graph.num_nodes(), 3);
    assert_eq!(streamed.inst.graph.num_edges(), 3);
    assert_eq!(streamed.inst.weights, vec![1.5, 2.0, 2.5]);
    // The legacy reader agrees on the same bytes.
    let legacy = read_edge_list(&path).unwrap();
    assert_same_instance(&legacy, &streamed.inst, "crlf");
    let _ = std::fs::remove_file(path);
}

#[test]
fn u64_ids_above_u32_max_are_not_truncated() {
    // 4294967297 = 2^32 + 1 truncates to 1 in u32 — which would turn
    // this edge into a self-loop and silently drop it.
    let path = tmp("bigid", "4294967297 1 2.0\n");
    let streamed = ingest::ingest_weighted(&path, IngestOptions::default()).unwrap();
    assert_eq!(streamed.inst.graph.num_nodes(), 2, "id was truncated");
    assert_eq!(streamed.inst.graph.num_edges(), 1);
    assert_eq!(streamed.ids, vec![1, 4294967297]);
    let _ = std::fs::remove_file(path);
}

#[test]
fn malformed_lines_report_line_numbers() {
    let path = tmp("badline", "1 2 1.0\n2 3 2.0\n3 x 1.0\n");
    let err = ingest::ingest_weighted(&path, IngestOptions::default()).unwrap_err().to_string();
    assert!(err.contains(":3:"), "missing line number: {err}");
    let _ = std::fs::remove_file(path);
}

#[test]
fn empty_and_comment_only_files_ingest_cleanly() {
    for (name, contents) in [("empty", ""), ("comments", "# nothing\n# here\n\n")] {
        let path = tmp(name, contents);
        let streamed = ingest::ingest_weighted(&path, IngestOptions::default()).unwrap();
        assert_eq!(streamed.inst.graph.num_nodes(), 0, "{name}");
        assert_eq!(streamed.inst.graph.num_edges(), 0, "{name}");
        let _ = std::fs::remove_file(path);
    }
}

#[test]
fn dimacs_grid_parses_and_collapses_reverse_arcs() {
    let opts = IngestOptions { format: IngestFormat::Dimacs, ..IngestOptions::default() };
    let out = ingest::ingest_weighted(GRID_GR, opts).unwrap();
    assert_eq!(out.inst.graph.num_nodes(), 9);
    // 13 undirected edges (12 grid + diagonal); each had a reverse arc.
    assert_eq!(out.inst.graph.num_edges(), 13);
    assert_eq!(out.stats.duplicates, 13);
    assert_eq!(out.stats.parsed_edges, 26);
    // Oracle sees exactly one violation: the diagonal (nodes 1, 5 =
    // ranks 0, 4) at 9 vs the unit rim path of length 2.
    let oracle =
        MetricOracle::new(Arc::new(out.inst.graph.clone()), OracleMode::Collect);
    assert_eq!(oracle.scan_cycles(&out.inst.weights).len(), 1);
}

#[test]
fn geo_scope_gates_the_dimacs_violation() {
    let opts = IngestOptions { format: IngestFormat::Dimacs, ..IngestOptions::default() };
    let out = ingest::ingest_weighted(GRID_GR, opts).unwrap();
    let coords = ingest::node_coords(GRID_CO, &out.ids).unwrap();
    let g = Arc::new(out.inst.graph.clone());

    // Radius 1.5 around the origin covers nodes {1, 2, 4, 5} (node 5 at
    // distance √2): the violated diagonal (1, 5) is in scope.
    let wide = neighborhood_scope(&g, &coords, &[(0.0, 0.0)], 1.5);
    let mut oracle = MetricOracle::new(g.clone(), OracleMode::Collect);
    oracle.scope = Some(wide.clone());
    assert_eq!(oracle.scan_cycles(&out.inst.weights).len(), 1, "diagonal should be in scope");

    // Radius 1.2 covers only {1, 2, 4}: the diagonal's far endpoint is
    // outside, so the scoped oracle reports nothing.
    let narrow = neighborhood_scope(&g, &coords, &[(0.0, 0.0)], 1.2);
    assert!(narrow.edges_in_scope() < wide.edges_in_scope());
    let mut oracle = MetricOracle::new(g.clone(), OracleMode::Collect);
    oracle.scope = Some(narrow);
    assert_eq!(oracle.scan_cycles(&out.inst.weights).len(), 0, "diagonal leaked into scope");

    // A scoped nearness solve converges while leaving the out-of-scope
    // diagonal untouched.
    let mask: Vec<bool> = g
        .edges()
        .iter()
        .map(|&(u, v)| u as usize != 0 || v as usize != 4)
        .collect();
    let scope = Arc::new(EdgeScope::from_edge_mask(mask));
    let opts = SolveOptions { violation_tol: 1e-8, dual_tol: 1e-8, ..SolveOptions::default() };
    let res = Nearness::new(&out.inst).scope(Some(scope)).solve(&opts);
    assert!(res.result.converged);
    let diag = g.edge_between(0, 4).unwrap() as usize;
    assert_eq!(res.result.x[diag], out.inst.weights[diag], "out-of-scope edge moved");
}

#[test]
fn byte_budget_is_enforced() {
    let err = ingest::ingest_weighted(
        SMALL,
        IngestOptions { byte_budget: Some(64), ..IngestOptions::default() },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("budget"), "unhelpful error: {err}");
    // A generous budget succeeds and reports a peak within it.
    let out = ingest::ingest_weighted(
        SMALL,
        IngestOptions { byte_budget: Some(1 << 20), ..IngestOptions::default() },
    )
    .unwrap();
    assert!(out.stats.peak_bytes > 0 && out.stats.peak_bytes <= 1 << 20);
}

#[test]
fn generated_instance_at_1e5_streams_under_accounting() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let edges = dir.join(format!("paf_ingest_gen_{pid}.tsv"));
    let coords = dir.join(format!("paf_ingest_gen_{pid}.co"));
    let info = ingest::write_geometric_instance(&edges, Some(&coords), 100_000, 42).unwrap();
    assert!(info.nodes >= 100_000);
    assert!(info.violated_shortcuts > 0);
    let out = ingest::ingest_weighted(&edges, IngestOptions::default()).unwrap();
    assert_eq!(out.inst.graph.num_nodes(), info.nodes);
    assert_eq!(out.inst.graph.num_edges(), info.edges, "generator writes no duplicates");
    assert_eq!(out.stats.duplicates, 0);
    assert!(out.stats.peak_bytes > 0);
    assert!(out.stats.csr_bytes > 0);
    // Coordinates resolve for every node (raw ids are scrambled u64s).
    let c = ingest::node_coords(&coords, &out.ids).unwrap();
    assert_eq!(c.len(), info.nodes);
    let _ = std::fs::remove_file(edges);
    let _ = std::fs::remove_file(coords);
}

#[test]
fn generated_instance_solves_scoped() {
    // Small enough to solve in-test: a 50×50 grid with injected
    // violations, repaired inside a geometric neighborhood.
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let edges = dir.join(format!("paf_ingest_solve_{pid}.tsv"));
    let coords_p = dir.join(format!("paf_ingest_solve_{pid}.co"));
    let info = ingest::write_geometric_instance(&edges, Some(&coords_p), 2_500, 7).unwrap();
    assert!(info.violated_shortcuts > 0);
    let out = ingest::ingest_weighted(&edges, IngestOptions::default()).unwrap();
    let coords = ingest::node_coords(&coords_p, &out.ids).unwrap();
    let g = Arc::new(out.inst.graph.clone());
    // A neighborhood around the grid center.
    let scope = neighborhood_scope(&g, &coords, &[(25.0, 25.0)], 12.0);
    assert!(scope.edges_in_scope() > 0);
    assert!(scope.edges_in_scope() < scope.num_edges());
    let opts = SolveOptions { violation_tol: 1e-6, dual_tol: 1e-6, ..SolveOptions::default() };
    let res = Nearness::new(&out.inst)
        .mode(OracleMode::Collect)
        .scope(Some(scope))
        .solve(&opts);
    assert!(res.result.converged, "scoped solve did not converge");
    let _ = std::fs::remove_file(edges);
    let _ = std::fs::remove_file(coords_p);
}
