//! Thread-count determinism of the parallel runtime (PR-2 tentpole).
//!
//! The sharded executor's scatter-safe parallel apply and the overlapped
//! oracle pipeline are both designed to be *bit-deterministic*: chunk
//! layouts depend only on the configured `threads` value, per-row
//! arithmetic is independent of which worker runs it, and scan results
//! are merged only at the sweep barrier. These tests pin that contract
//! on the two paper workloads: full `SolverResult`s must be bit-identical
//! across thread counts 1, 2 and 8 (the same sweep across `PAF_THREADS`
//! values is covered by the CI matrix, which runs this whole suite under
//! `PAF_THREADS=1` and `PAF_THREADS=4`).

#![allow(deprecated)] // the legacy wrappers are pinned against the Session API here

use paf::core::bregman::DiagonalQuadratic;
use paf::core::engine::SweepStrategy;
use paf::core::problem::{SolveEvent, SolveOptions};
use paf::core::session::Session;
use paf::core::solver::{Solver, SolverConfig, SolverResult};
use paf::graph::generators::type1_complete;
use paf::graph::Graph;
use paf::problems::correlation::{solve_cc, CcConfig, CcInstance, CcResult, Correlation};
use paf::problems::itml::{solve_pf_itml, PfItml, PfItmlConfig};
use paf::problems::metric_oracle::{MetricOracle, OracleMode};
use paf::problems::nearness::{solve_nearness, Nearness, NearnessConfig};
use paf::util::Rng;
use std::sync::Arc;

fn assert_bit_identical(reference: &SolverResult, got: &SolverResult, label: &str) {
    assert_eq!(reference.x, got.x, "{label}: x differs (bitwise)");
    assert_eq!(reference.iterations, got.iterations, "{label}: iteration count differs");
    assert_eq!(reference.converged, got.converged, "{label}: convergence differs");
    assert_eq!(
        reference.total_projections, got.total_projections,
        "{label}: projection count differs"
    );
    assert_eq!(
        reference.active_constraints, got.active_constraints,
        "{label}: active-set size differs"
    );
}

fn nearness_cfg(threads: usize, overlap: bool) -> NearnessConfig {
    NearnessConfig {
        mode: OracleMode::Collect,
        sweep: SweepStrategy::ShardedParallel { threads },
        overlap,
        violation_tol: 1e-6,
        dual_tol: 1e-6,
        ..Default::default()
    }
}

#[test]
fn nearness_sharded_is_thread_count_invariant() {
    let mut rng = Rng::new(41);
    let inst = type1_complete(14, &mut rng);
    let mut reference: Option<SolverResult> = None;
    for threads in [1usize, 2, 8] {
        let res = solve_nearness(&inst, &nearness_cfg(threads, false)).result;
        assert!(res.converged, "nearness (t={threads}) did not converge");
        match &reference {
            None => reference = Some(res),
            Some(r) => assert_bit_identical(r, &res, &format!("nearness t={threads}")),
        }
    }
}

#[test]
fn nearness_sharded_overlap_is_thread_count_invariant() {
    let mut rng = Rng::new(42);
    let inst = type1_complete(14, &mut rng);
    let mut reference: Option<SolverResult> = None;
    for threads in [1usize, 2, 8] {
        let res = solve_nearness(&inst, &nearness_cfg(threads, true)).result;
        assert!(res.converged, "overlapped nearness (t={threads}) did not converge");
        match &reference {
            None => reference = Some(res),
            Some(r) => assert_bit_identical(r, &res, &format!("nearness+overlap t={threads}")),
        }
    }
}

#[test]
fn nearness_overlap_reaches_the_nonoverlapped_optimum() {
    // Overlap changes the trajectory (each scan is one round stale), but
    // the program is strictly convex: same unique optimum.
    let mut rng = Rng::new(43);
    let inst = type1_complete(12, &mut rng);
    let mut tight = nearness_cfg(2, false);
    tight.violation_tol = 1e-8;
    tight.dual_tol = 1e-8;
    let plain = solve_nearness(&inst, &tight);
    tight.overlap = true;
    let overlapped = solve_nearness(&inst, &tight);
    assert!(plain.result.converged && overlapped.result.converged);
    for (a, b) in plain.result.x.iter().zip(&overlapped.result.x) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn instrumentation_is_pure_observation() {
    // Span tracing + telemetry sampling must not perturb one bit of the
    // iterate stream: solve with everything off, then with tracing on
    // and per-round telemetry, and compare bitwise. (Enabling spans
    // process-wide only adds recording to concurrently running tests —
    // observation never feeds back into any solve.)
    let mut rng = Rng::new(44);
    let inst = type1_complete(14, &mut rng);
    let mut opts = SolveOptions::new().violation_tol(1e-6).dual_tol(1e-6);
    opts.sweep = SweepStrategy::ShardedParallel { threads: 2 };
    paf::obs::set_spans_enabled(false);
    let off = Nearness::new(&inst).mode(OracleMode::Collect).solve(&opts).result;
    paf::obs::set_spans_enabled(true);
    let on = Nearness::new(&inst)
        .mode(OracleMode::Collect)
        .solve(&opts.clone().telemetry_every(1))
        .result;
    // Restore the env-driven default (the CI matrix also runs this
    // suite with PAF_TRACE=1).
    paf::obs::set_spans_enabled(
        std::env::var("PAF_TRACE").map(|v| !v.is_empty() && v != "0").unwrap_or(false),
    );
    assert!(off.converged && on.converged);
    assert_bit_identical(&off, &on, "tracing+telemetry on vs off");
    assert!(off.telemetry.is_empty(), "telemetry defaults off");
    assert_eq!(on.telemetry.len(), on.iterations, "telemetry_every=1 samples every round");
    assert!(on.telemetry.iter().any(|f| f.rows_projected > 0));
    let exported = paf::obs::chrome_trace_json();
    paf::obs::validate_chrome_trace(&exported).expect("live trace export must validate");
    assert!(exported.contains("\"name\": \"round\""), "round spans were recorded");
}

fn cc_instance(seed: u64) -> CcInstance {
    let mut rng = Rng::new(seed);
    let g = Graph::complete(12);
    let (sg, _) = paf::graph::generators::planted_signed(g, 3, 0.15, &mut rng);
    CcInstance::from_signed(&sg)
}

fn solve_cc_with(inst: &CcInstance, threads: usize, overlap: bool) -> CcResult {
    let cfg = CcConfig {
        mode: OracleMode::Collect,
        sweep: SweepStrategy::ShardedParallel { threads },
        overlap,
        violation_tol: 1e-4,
        inner_sweeps: 4,
        max_iters: 800,
        ..CcConfig::dense()
    };
    solve_cc(inst, &cfg, 7)
}

#[test]
fn correlation_sharded_overlap_is_thread_count_invariant() {
    let inst = cc_instance(44);
    let mut reference: Option<CcResult> = None;
    for threads in [1usize, 2, 8] {
        let res = solve_cc_with(&inst, threads, true);
        assert!(res.result.converged, "overlapped CC (t={threads}) did not converge");
        match &reference {
            None => reference = Some(res),
            Some(r) => {
                assert_bit_identical(&r.result, &res.result, &format!("cc+overlap t={threads}"));
                // Bit-identical x must round to the identical clustering.
                assert_eq!(r.labels, res.labels, "t={threads}: rounding differs");
                assert_eq!(r.lp_objective, res.lp_objective, "t={threads}: LP objective");
            }
        }
    }
}

#[test]
fn correlation_sharded_parallel_apply_is_thread_count_invariant() {
    let inst = cc_instance(45);
    let mut reference: Option<CcResult> = None;
    for threads in [1usize, 2, 8] {
        let res = solve_cc_with(&inst, threads, false);
        assert!(res.result.converged, "sharded CC (t={threads}) did not converge");
        match &reference {
            None => reference = Some(res),
            Some(r) => {
                assert_bit_identical(&r.result, &res.result, &format!("cc t={threads}"));
                assert_eq!(r.labels, res.labels, "t={threads}: rounding differs");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Session API equivalence (PR-3 tentpole): the stepwise driver, the
// checkpoint/resume path, and K-instance batches must all be
// bit-identical to the historical one-shot `Solver::solve` /
// `solve_overlapped` trajectories.
// ---------------------------------------------------------------------

/// The historical hand-rolled nearness solve (what `solve_nearness` did
/// before the Session refactor): raw oracle + `Solver::solve`.
fn raw_nearness(
    inst: &paf::graph::generators::WeightedInstance,
    sweep: SweepStrategy,
    overlap: bool,
    tol: f64,
) -> SolverResult {
    let f = DiagonalQuadratic::unweighted(inst.weights.clone());
    let mut oracle = MetricOracle::new(Arc::new(inst.graph.clone()), OracleMode::Collect);
    oracle.report_tol = (tol * 1e-3).max(1e-12);
    oracle.shard_bucket = matches!(sweep, SweepStrategy::ShardedParallel { .. });
    let cfg = SolverConfig {
        max_iters: 500,
        inner_sweeps: 1,
        violation_tol: tol,
        dual_tol: tol,
        projection_budget: None,
        record_trace: true,
        z_tol: 0.0,
        sweep,
        parallel_min_rows: None,
        track_movement: true,
        lazy_sweep: true,
    };
    let mut solver = Solver::new(f, cfg);
    if overlap {
        solver.solve_overlapped(oracle)
    } else {
        solver.solve(oracle)
    }
}

fn session_opts(sweep: SweepStrategy, overlap: bool, tol: f64) -> SolveOptions {
    SolveOptions::new()
        .max_iters(500)
        .violation_tol(tol)
        .dual_tol(tol)
        .sweep(sweep)
        .overlap(overlap)
}

#[test]
fn session_single_instance_matches_raw_solver() {
    let mut rng = Rng::new(61);
    let inst = type1_complete(13, &mut rng);
    for (sweep, overlap) in [
        (SweepStrategy::Sequential, false),
        (SweepStrategy::ShardedParallel { threads: 2 }, false),
        (SweepStrategy::ShardedParallel { threads: 2 }, true),
    ] {
        let reference = raw_nearness(&inst, sweep, overlap, 1e-6);
        assert!(reference.converged);
        let got = Nearness::new(&inst)
            .mode(OracleMode::Collect)
            .solve(&session_opts(sweep, overlap, 1e-6));
        assert_bit_identical(
            &reference,
            &got.result,
            &format!("session vs raw ({sweep:?}, overlap={overlap})"),
        );
    }
}

#[test]
fn session_stepwise_matches_one_shot_run() {
    let mut rng = Rng::new(62);
    let inst = type1_complete(12, &mut rng);
    let opts = session_opts(SweepStrategy::ShardedParallel { threads: 2 }, false, 1e-6);
    // One-shot run().
    let mut one_shot = Session::new(opts.clone());
    let h1 = one_shot.add(Nearness::new(&inst).mode(OracleMode::Collect));
    one_shot.run();
    let res_run = one_shot.take_unwrap(h1);
    // Manual step() loop, counting events.
    let mut stepped = Session::new(opts);
    let h2 = stepped.add(Nearness::new(&inst).mode(OracleMode::Collect));
    let mut rounds = 0usize;
    loop {
        match stepped.step() {
            SolveEvent::Finished(summary) => {
                assert!(summary.all_converged);
                break;
            }
            SolveEvent::Round(ev) => {
                assert_eq!(ev.round, rounds, "round events must be consecutive");
                rounds += 1;
            }
            other => panic!("unexpected event: {other:?}"),
        }
    }
    let res_step = stepped.take_unwrap(h2);
    // The final round is reported through the Finished event, so N
    // iterations surface as N−1 Round returns + 1 Finished.
    assert_eq!(rounds + 1, res_step.result.iterations, "one Round event per iteration");
    assert_bit_identical(&res_run.result, &res_step.result, "step loop vs run");
}

#[test]
fn session_checkpoint_resume_is_bit_identical() {
    let mut rng = Rng::new(63);
    let insts: Vec<_> = (0..2).map(|_| type1_complete(11, &mut rng)).collect();
    let opts = session_opts(SweepStrategy::ShardedParallel { threads: 2 }, false, 1e-6);
    // Uninterrupted reference batch.
    let mut full = Session::new(opts.clone());
    let hf: Vec<_> = insts
        .iter()
        .map(|i| full.add(Nearness::new(i).mode(OracleMode::Collect)))
        .collect();
    full.run();
    let reference: Vec<_> = hf.into_iter().map(|h| full.take_unwrap(h)).collect();
    // Interrupted: three rounds, checkpoint, resume in a FRESH session.
    let mut first = Session::new(opts.clone());
    let _h: Vec<_> = insts
        .iter()
        .map(|i| first.add(Nearness::new(i).mode(OracleMode::Collect)))
        .collect();
    for _ in 0..3 {
        first.step();
    }
    let ck = first.checkpoint();
    assert_eq!(ck.round(), 3);
    let mut resumed = Session::new(opts);
    let hr: Vec<_> = insts
        .iter()
        .map(|i| resumed.add(Nearness::new(i).mode(OracleMode::Collect)))
        .collect();
    resumed.restore(&ck);
    resumed.run();
    for (h, want) in hr.into_iter().zip(&reference) {
        let got = resumed.take_unwrap(h);
        assert_bit_identical(&want.result, &got.result, "checkpoint/resume");
        assert_eq!(want.objective, got.objective, "objective differs after resume");
    }
}

#[test]
fn session_checkpoint_resume_overlapped_pipeline() {
    let mut rng = Rng::new(64);
    let inst = type1_complete(12, &mut rng);
    let opts = session_opts(SweepStrategy::ShardedParallel { threads: 2 }, true, 1e-6);
    let mut full = Session::new(opts.clone());
    let h = full.add(Nearness::new(&inst).mode(OracleMode::Collect));
    full.run();
    let reference = full.take_unwrap(h);
    assert!(reference.result.converged);
    let mut first = Session::new(opts.clone());
    let _h = first.add(Nearness::new(&inst).mode(OracleMode::Collect));
    for _ in 0..2 {
        first.step();
    }
    let ck = first.checkpoint();
    let mut resumed = Session::new(opts);
    let hr = resumed.add(Nearness::new(&inst).mode(OracleMode::Collect));
    resumed.restore(&ck);
    resumed.run();
    let got = resumed.take_unwrap(hr);
    assert_bit_identical(&reference.result, &got.result, "overlap checkpoint/resume");
}

#[test]
fn batch_of_k_instances_matches_individual_solves() {
    // The acceptance criterion: K disjoint instances in ONE session,
    // per-instance results bit-identical to K separate solves — for the
    // sequential executor AND the sharded fleet sweep.
    let mut rng = Rng::new(65);
    let insts: Vec<_> =
        [10usize, 13, 11].iter().map(|&n| type1_complete(n, &mut rng)).collect();
    for sweep in [SweepStrategy::Sequential, SweepStrategy::ShardedParallel { threads: 4 }] {
        let opts = session_opts(sweep, false, 1e-6);
        let solo: Vec<_> = insts
            .iter()
            .map(|i| Nearness::new(i).mode(OracleMode::Collect).solve(&opts))
            .collect();
        let mut batch = Session::new(opts);
        let handles: Vec<_> = insts
            .iter()
            .map(|i| batch.add(Nearness::new(i).mode(OracleMode::Collect)))
            .collect();
        let summary = batch.run();
        assert!(summary.all_converged, "{sweep:?}: batch did not converge");
        for (k, (h, want)) in handles.into_iter().zip(&solo).enumerate() {
            let got = batch.take_unwrap(h);
            assert!(want.result.converged, "{sweep:?}: solo {k} did not converge");
            assert_bit_identical(
                &want.result,
                &got.result,
                &format!("batch block {k} ({sweep:?})"),
            );
            assert_eq!(want.objective, got.objective, "block {k}: objective differs");
        }
    }
}

#[test]
fn batch_of_cc_instances_matches_individual_solves() {
    let insts = [cc_instance(66), cc_instance(67)];
    let opts = SolveOptions::new()
        .max_iters(800)
        .violation_tol(1e-4)
        .inner_sweeps(4)
        .sweep(SweepStrategy::ShardedParallel { threads: 2 });
    let solo: Vec<CcResult> = insts
        .iter()
        .map(|i| Correlation::dense(i).mode(OracleMode::Collect).seed(7).solve(&opts))
        .collect();
    let mut batch = Session::new(opts);
    let handles: Vec<_> = insts
        .iter()
        .map(|i| batch.add(Correlation::dense(i).mode(OracleMode::Collect).seed(7)))
        .collect();
    let summary = batch.run();
    assert!(summary.all_converged);
    for (k, (h, want)) in handles.into_iter().zip(&solo).enumerate() {
        let got: CcResult = batch.take_unwrap(h);
        assert_bit_identical(&want.result, &got.result, &format!("cc batch block {k}"));
        assert_eq!(want.labels, got.labels, "block {k}: rounding differs");
        assert_eq!(want.lp_objective, got.lp_objective, "block {k}: LP objective differs");
    }
}

#[test]
fn itml_is_deterministic_and_batches_bit_identically() {
    // The PairList refactor makes PF-ITML runs reproducible (the old
    // HashMap sweep order was per-process random), so the wrapper, a
    // session block, and a 2-fold batch must all agree bitwise.
    let mut rng = Rng::new(68);
    let folds: Vec<_> = (0..2)
        .map(|k| {
            paf::ml::dataset::gaussian_mixture(80, 4, 2, 2.0, &mut rng)
                .split(0.8, &mut Rng::new(100 + k))
                .0
        })
        .collect();
    let cfg = |seed| PfItmlConfig { max_projections: 2000, batch: 50, seed, ..Default::default() };
    let solo: Vec<_> = folds
        .iter()
        .enumerate()
        .map(|(k, f)| solve_pf_itml(f, &cfg(k as u64)))
        .collect();
    // Re-running the wrapper reproduces the matrix exactly.
    let again = solve_pf_itml(&folds[0], &cfg(0));
    assert_eq!(solo[0].m.a, again.m.a, "PF-ITML must be run-to-run deterministic");
    assert_eq!(solo[0].projections, again.projections);
    // A 2-fold batch in one session matches the individual runs.
    let mut batch = Session::new(SolveOptions::default());
    let handles: Vec<_> = folds
        .iter()
        .enumerate()
        .map(|(k, f)| batch.add(PfItml::new(f, cfg(k as u64))))
        .collect();
    batch.run();
    for (k, (h, want)) in handles.into_iter().zip(&solo).enumerate() {
        let got = batch.take_unwrap(h);
        assert_eq!(want.m.a, got.m.a, "fold {k}: matrix differs");
        assert_eq!(want.projections, got.projections, "fold {k}: projections differ");
        assert_eq!(want.active_pairs, got.active_pairs, "fold {k}: active pairs differ");
    }
}

#[test]
fn itml_checkpoint_resume_is_bit_identical() {
    let mut rng = Rng::new(69);
    let data = paf::ml::dataset::gaussian_mixture(80, 4, 2, 2.0, &mut rng);
    let cfg = PfItmlConfig { max_projections: 3000, batch: 60, seed: 9, ..Default::default() };
    let reference = PfItml::new(&data, cfg.clone()).solve(&SolveOptions::default());
    let mut first = Session::new(SolveOptions::default());
    let _h = first.add(PfItml::new(&data, cfg.clone()));
    for _ in 0..2 {
        first.step();
    }
    let ck = first.checkpoint();
    let mut resumed = Session::new(SolveOptions::default());
    let h = resumed.add(PfItml::new(&data, cfg));
    resumed.restore(&ck);
    resumed.run();
    let got = resumed.take_unwrap(h);
    assert_eq!(reference.m.a, got.m.a, "ITML resume diverged");
    assert_eq!(reference.projections, got.projections);
}

#[test]
fn mixed_vector_and_round_blocks_match_individual_solves() {
    // A nearness block and an ITML block share one session; each must
    // match its solo solve exactly.
    let mut rng = Rng::new(70);
    let inst = type1_complete(11, &mut rng);
    let data = paf::ml::dataset::gaussian_mixture(60, 3, 2, 2.0, &mut rng);
    let icfg = PfItmlConfig { max_projections: 1500, batch: 40, seed: 5, ..Default::default() };
    let opts = session_opts(SweepStrategy::Sequential, false, 1e-6);
    let solo_near = Nearness::new(&inst).mode(OracleMode::Collect).solve(&opts);
    let solo_itml = PfItml::new(&data, icfg.clone()).solve(&opts);
    let mut session = Session::new(opts);
    let hn = session.add(Nearness::new(&inst).mode(OracleMode::Collect));
    let hi = session.add(PfItml::new(&data, icfg));
    session.run();
    let got_near = session.take_unwrap(hn);
    let got_itml = session.take_unwrap(hi);
    assert_bit_identical(&solo_near.result, &got_near.result, "mixed session nearness");
    assert_eq!(solo_itml.m.a, got_itml.m.a, "mixed session ITML");
}

#[test]
fn cancellation_stops_at_round_boundary_with_partial_results() {
    let mut rng = Rng::new(71);
    let inst = type1_complete(14, &mut rng);
    // Tight tolerance so the solve would run many rounds uncancelled.
    let opts = session_opts(SweepStrategy::Sequential, false, 1e-10);
    let mut session = Session::new(opts);
    let h = session.add(Nearness::new(&inst).mode(OracleMode::Collect));
    let token = session.cancel_token();
    session.on_event(move |event| {
        if matches!(event, SolveEvent::Round(ev) if ev.round == 1) {
            token.cancel();
        }
    });
    let summary = session.run();
    assert!(summary.cancelled, "cancel token must stop the session");
    assert!(!summary.all_converged);
    assert!(session.is_finished());
    let partial = session.take_unwrap(h);
    assert!(!partial.result.converged);
    assert_eq!(partial.result.iterations, 2, "cancelled after round index 1");
    assert_eq!(partial.result.x.len(), inst.graph.num_edges());
}

// ---------------------------------------------------------------------
// Serving-layer determinism (PR-4 tentpole): dynamic admission into a
// RUNNING fleet, checkpoint-based preemption + resume, and the full
// scheduler replaying a mixed trace — every job bit-identical to its
// solo `Session::solve_one` run, under any PAF_THREADS (the CI matrix
// runs this suite at 1 and 4).
// ---------------------------------------------------------------------

#[test]
fn mid_solve_admission_is_bit_identical_to_solo() {
    // Block A runs 3 rounds alone, then B is admitted into the RUNNING
    // session; both must match their solo solves bit for bit — for the
    // sequential executor and the sharded fleet sweep.
    let mut rng = Rng::new(80);
    let inst_a = type1_complete(13, &mut rng);
    let inst_b = type1_complete(11, &mut rng);
    for sweep in [SweepStrategy::Sequential, SweepStrategy::ShardedParallel { threads: 4 }] {
        let opts = session_opts(sweep, false, 1e-6);
        let solo_a = Nearness::new(&inst_a).mode(OracleMode::Collect).solve(&opts);
        let solo_b = Nearness::new(&inst_b).mode(OracleMode::Collect).solve(&opts);
        let mut session = Session::new(opts);
        let ha = session.add(Nearness::new(&inst_a).mode(OracleMode::Collect));
        for _ in 0..3 {
            session.step();
        }
        let hb = session.admit(Nearness::new(&inst_b).mode(OracleMode::Collect));
        session.run();
        let got_a = session.take_unwrap(ha);
        let got_b = session.take_unwrap(hb);
        assert_bit_identical(
            &solo_a.result,
            &got_a.result,
            &format!("in-flight block perturbed by admission ({sweep:?})"),
        );
        assert_bit_identical(
            &solo_b.result,
            &got_b.result,
            &format!("block admitted at round 3 ({sweep:?})"),
        );
        assert_eq!(solo_b.objective, got_b.objective);
    }
}

#[test]
fn preempt_checkpoint_resume_is_bit_identical_to_uninterrupted() {
    // A and B run together; after 2 rounds B is evicted (checkpoint),
    // A keeps running (and finishes); B is later re-admitted from its
    // checkpoint. Both must equal their solo solves bit for bit, so the
    // eviction's re-offsetting (B's range compacted out while A is
    // in flight, then B re-admitted at a NEW offset) is exact.
    let mut rng = Rng::new(81);
    let inst_a = type1_complete(12, &mut rng);
    let inst_b = type1_complete(14, &mut rng);
    for sweep in [SweepStrategy::Sequential, SweepStrategy::ShardedParallel { threads: 2 }] {
        let opts = session_opts(sweep, false, 1e-6);
        let solo_a = Nearness::new(&inst_a).mode(OracleMode::Collect).solve(&opts);
        let solo_b = Nearness::new(&inst_b).mode(OracleMode::Collect).solve(&opts);
        let mut session = Session::new(opts);
        let ha = session.add(Nearness::new(&inst_a).mode(OracleMode::Collect));
        let hb = session.add(Nearness::new(&inst_b).mode(OracleMode::Collect));
        for _ in 0..2 {
            session.step();
        }
        // Preempt A — the FIRST block, so the surviving in-flight B is
        // re-offset down by A's range while holding live rows and duals.
        let ck = session.evict(ha.index());
        assert_eq!(ck.iterations(), 2);
        assert!(ck.remembered() > 0, "a mid-solve nearness block should hold rows");
        assert!(session.take(ha).is_none(), "evicted block must not have an output");
        // B continues alone for a few rounds (it may even finish).
        for _ in 0..3 {
            session.step();
        }
        // Resume A from the checkpoint (at a NEW offset — B now sits at
        // the front of the concatenated vector); run to completion.
        let ha2 = session.admit_resumed(Nearness::new(&inst_a).mode(OracleMode::Collect), &ck);
        session.run();
        let got_a = session.take_unwrap(ha2);
        let got_b = session.take_unwrap(hb);
        assert_bit_identical(
            &solo_b.result,
            &got_b.result,
            &format!("survivor block perturbed by eviction + re-offset ({sweep:?})"),
        );
        assert_bit_identical(
            &solo_a.result,
            &got_a.result,
            &format!("preempted+resumed block ({sweep:?})"),
        );
    }
}

#[test]
fn resume_into_a_fresh_session_is_bit_identical() {
    // The checkpoint also restores across sessions (serve restarts).
    let mut rng = Rng::new(82);
    let inst = type1_complete(13, &mut rng);
    let opts = session_opts(SweepStrategy::ShardedParallel { threads: 2 }, false, 1e-6);
    let solo = Nearness::new(&inst).mode(OracleMode::Collect).solve(&opts);
    let mut first = Session::new(opts.clone());
    let h = first.add(Nearness::new(&inst).mode(OracleMode::Collect));
    for _ in 0..3 {
        first.step();
    }
    let ck = first.evict(h.index());
    let mut second = Session::new(opts);
    let h2 = second.admit_resumed(Nearness::new(&inst).mode(OracleMode::Collect), &ck);
    second.run();
    let got = second.take_unwrap(h2);
    assert_bit_identical(&solo.result, &got.result, "cross-session resume");
}

#[test]
fn round_block_evict_resume_matches_uninterrupted() {
    // Round-driven blocks (ITML) preempt through their own snapshots.
    let mut rng = Rng::new(84);
    let data = paf::ml::dataset::gaussian_mixture(80, 4, 2, 2.0, &mut rng);
    let cfg = PfItmlConfig { max_projections: 3000, batch: 60, seed: 9, ..Default::default() };
    let reference = PfItml::new(&data, cfg.clone()).solve(&SolveOptions::default());
    let mut session = Session::new(SolveOptions::default());
    let h = session.add(PfItml::new(&data, cfg.clone()));
    for _ in 0..2 {
        session.step();
    }
    let ck = session.evict(h.index());
    assert_eq!(ck.iterations(), 2);
    assert_eq!(ck.remembered(), 0, "round-driven checkpoints carry no vector rows");
    let mut second = Session::new(SolveOptions::default());
    let h2 = second.admit_resumed(PfItml::new(&data, cfg), &ck);
    second.run();
    let got = second.take_unwrap(h2);
    assert_eq!(reference.m.a, got.m.a, "ITML evict/resume diverged");
    assert_eq!(reference.projections, got.projections);
}

#[test]
fn scheduler_replays_a_mixed_trace_with_preemption() {
    use paf::serve::{JobBank, Scheduler, ServeConfig, ServeEvent};
    // 3 jobs, capacity 2: two nearness jobs start, then a strictly
    // higher-priority CC job arrives and must preempt the lower-priority
    // running job. All three complete, every job's SolverResult is
    // bit-identical to its solo solve, and the stats/events record the
    // preemption and the resume.
    let jobs = paf::serve::demo_trace(90);
    assert_eq!(jobs[2].priority, 9, "trace job 2 must be the high-priority arrival");
    let bank = JobBank::materialize(&jobs);
    let opts = SolveOptions::new()
        .violation_tol(1e-5)
        .inner_sweeps(2)
        .sweep(SweepStrategy::ShardedParallel { threads: 2 });
    let solo: Vec<_> = jobs
        .iter()
        .map(|j| paf::serve::solve_job_solo(j, bank.input(j.id), &opts).expect("solo solve"))
        .collect();
    let cfg = ServeConfig { capacity: 2, opts, ..Default::default() };
    let stats = Scheduler::new(jobs.clone(), &bank, cfg).expect("valid serve config").run();
    assert!(stats.all_completed(), "all jobs must complete: {stats:?}");
    assert!(stats.preemptions >= 1, "the high-priority arrival must preempt");
    assert!(
        stats.events.iter().any(|e| matches!(e.event, ServeEvent::Preempted { .. })),
        "preemption must be in the event stream"
    );
    assert!(
        stats
            .events
            .iter()
            .any(|e| matches!(e.event, ServeEvent::Admitted { resumed: true, .. })),
        "the preempted job must resume"
    );
    for (i, e) in stats.events.iter().enumerate() {
        assert_eq!(e.seq, i as u64, "serve events carry dense monotonic sequence numbers");
    }
    for (k, (s, want)) in stats.jobs.iter().zip(&solo).enumerate() {
        assert!(s.converged, "job {k} did not converge under serving");
        let got = s.result.as_ref().expect("completed job without result");
        assert_bit_identical(&want.result, got, &format!("served job {k} vs solo"));
        assert_eq!(s.objective, Some(want.objective), "job {k}: objective differs");
        assert_eq!(s.rounds_run, want.result.iterations, "job {k}: rounds differ");
        assert!(s.phases.total() > 0.0, "job {k}: phase timings missing");
        assert!(s.admitted_round.is_some() && s.completed_round.is_some());
    }
    // The preempted job's stats must show the preemption.
    assert!(
        stats.jobs.iter().any(|s| s.preemptions > 0),
        "some job must record a preemption"
    );
    // The serve JSON for this run parses and carries the per-job stats.
    let text = paf::serve::serve_stats_json("trace", &stats);
    let json = paf::runtime::json::Json::parse(&text).expect("serve JSON invalid");
    assert_eq!(
        json.get("completed").and_then(|v| v.as_usize()),
        Some(3),
        "serve JSON must report 3 completed jobs"
    );
    assert_eq!(
        json.get("jobs").and_then(|j| j.as_arr()).map(|j| j.len()),
        Some(3)
    );
}

#[test]
fn scheduler_is_deterministic_across_thread_counts() {
    use paf::serve::{JobBank, Scheduler, ServeConfig};
    let jobs = paf::serve::demo_trace(91);
    let bank = JobBank::materialize(&jobs);
    let mut reference: Option<Vec<SolverResult>> = None;
    for threads in [1usize, 2, 8] {
        let opts = SolveOptions::new()
            .violation_tol(1e-5)
            .inner_sweeps(2)
            .sweep(SweepStrategy::ShardedParallel { threads });
        let cfg = ServeConfig { capacity: 2, opts, ..Default::default() };
        let stats = Scheduler::new(jobs.clone(), &bank, cfg).expect("valid serve config").run();
        assert!(stats.all_completed());
        let results: Vec<SolverResult> =
            stats.jobs.iter().map(|s| s.result.clone().expect("missing result")).collect();
        match &reference {
            None => reference = Some(results),
            Some(r) => {
                for (k, (want, got)) in r.iter().zip(&results).enumerate() {
                    assert_bit_identical(want, got, &format!("serve job {k} t={threads}"));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Incremental separation (PR-5 tentpole): the dirty-source oracle and
// the engine's movement feedback are pure optimizations — a solve with
// incremental scans (whether the dirty set comes from the movement log
// or from the snapshot diff) must be bit-identical to a full-rescan
// solve, at every thread count, for the plain and overlapped pipelines.
// ---------------------------------------------------------------------

/// `raw_nearness` with the incremental-scan and movement-tracking knobs
/// exposed.
fn raw_nearness_inc(
    inst: &paf::graph::generators::WeightedInstance,
    sweep: SweepStrategy,
    overlap: bool,
    tol: f64,
    incremental: bool,
    track_movement: bool,
) -> SolverResult {
    let f = DiagonalQuadratic::unweighted(inst.weights.clone());
    let mut oracle = MetricOracle::new(Arc::new(inst.graph.clone()), OracleMode::Collect);
    oracle.report_tol = (tol * 1e-3).max(1e-12);
    oracle.shard_bucket = matches!(sweep, SweepStrategy::ShardedParallel { .. });
    oracle.incremental = incremental;
    let cfg = SolverConfig {
        max_iters: 500,
        inner_sweeps: 1,
        violation_tol: tol,
        dual_tol: tol,
        sweep,
        track_movement,
        ..Default::default()
    };
    let mut solver = Solver::new(f, cfg);
    if overlap {
        solver.solve_overlapped(oracle)
    } else {
        solver.solve(oracle)
    }
}

#[test]
fn incremental_oracle_is_bit_identical_to_full_rescan() {
    let mut rng = Rng::new(46);
    let inst = type1_complete(14, &mut rng);
    for overlap in [false, true] {
        let mut reference: Option<SolverResult> = None;
        for threads in [1usize, 2, 8] {
            let sweep = SweepStrategy::ShardedParallel { threads };
            let full = raw_nearness_inc(&inst, sweep, overlap, 1e-6, false, true);
            assert!(full.converged, "full rescan (t={threads}) did not converge");
            // Incremental with the movement-log fast path...
            let inc = raw_nearness_inc(&inst, sweep, overlap, 1e-6, true, true);
            // ...and with tracking off (snapshot-diff dirty sets only).
            let diffed = raw_nearness_inc(&inst, sweep, overlap, 1e-6, true, false);
            assert_bit_identical(
                &full,
                &inc,
                &format!("incremental vs full (t={threads}, overlap={overlap})"),
            );
            assert_bit_identical(
                &full,
                &diffed,
                &format!("diff-only incremental vs full (t={threads}, overlap={overlap})"),
            );
            // And the movement-tracked incremental solve is itself
            // thread-count invariant.
            match &reference {
                None => reference = Some(inc),
                Some(r) => assert_bit_identical(
                    r,
                    &inc,
                    &format!("incremental t={threads}, overlap={overlap}"),
                ),
            }
        }
    }
}

#[test]
fn incremental_cc_with_box_rows_matches_full_rescan() {
    // Correlation clustering exercises the upper-bound box face and the
    // fused box pass; incremental-vs-full must stay bit-identical
    // through the public Problem API too.
    let inst = cc_instance(47);
    let opts = SolveOptions::new()
        .max_iters(800)
        .violation_tol(1e-4)
        .inner_sweeps(4)
        .sweep(SweepStrategy::ShardedParallel { threads: 2 });
    let full = Correlation::dense(&inst)
        .mode(OracleMode::Collect)
        .seed(7)
        .incremental(false)
        .solve(&opts);
    let inc = Correlation::dense(&inst).mode(OracleMode::Collect).seed(7).solve(&opts);
    assert!(full.result.converged && inc.result.converged);
    assert_bit_identical(&full.result, &inc.result, "cc incremental vs full");
    assert_eq!(full.labels, inc.labels, "cc rounding differs");
    // Movement tracking disabled at the options layer: still identical.
    let untracked = Correlation::dense(&inst)
        .mode(OracleMode::Collect)
        .seed(7)
        .solve(&opts.clone().track_movement(false));
    assert_bit_identical(&full.result, &untracked.result, "cc untracked incremental");
}

#[test]
fn serve_preemption_with_incremental_oracles_stays_deterministic() {
    // Eviction re-offsets coordinates mid-flight; the movement log must
    // invalidate (and oracles re-derive dirty sets) rather than carry
    // stale labels. The scheduler suite pins solo-equivalence already;
    // this pins it with the default incremental oracles under both
    // thread extremes again for the mixed preemption trace.
    use paf::serve::{JobBank, Scheduler, ServeConfig};
    let jobs = paf::serve::demo_trace(92);
    let bank = JobBank::materialize(&jobs);
    let mut reference: Option<Vec<SolverResult>> = None;
    for threads in [1usize, 8] {
        let opts = SolveOptions::new()
            .violation_tol(1e-5)
            .inner_sweeps(2)
            .sweep(SweepStrategy::ShardedParallel { threads });
        let solo: Vec<_> = jobs
            .iter()
            .map(|j| paf::serve::solve_job_solo(j, bank.input(j.id), &opts).expect("solo solve"))
            .collect();
        let cfg = ServeConfig { capacity: 2, opts, ..Default::default() };
        let stats = Scheduler::new(jobs.clone(), &bank, cfg).expect("valid serve config").run();
        assert!(stats.all_completed());
        let results: Vec<SolverResult> =
            stats.jobs.iter().map(|s| s.result.clone().expect("missing result")).collect();
        for (k, (got, want)) in results.iter().zip(&solo).enumerate() {
            assert_bit_identical(&want.result, got, &format!("served job {k} t={threads}"));
        }
        match &reference {
            None => reference = Some(results),
            Some(r) => {
                for (k, (want, got)) in r.iter().zip(&results).enumerate() {
                    assert_bit_identical(want, got, &format!("serve inc job {k} t={threads}"));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Lazy sweep scheduling (PR-6 tentpole): skipping provably zero-step
// rows is exact, so lazy solves must be bit-identical to eager solves,
// thread-count invariant, and stable under serve preemption/re-offset.
// ---------------------------------------------------------------------

#[test]
fn lazy_sweep_is_bit_identical_and_thread_count_invariant() {
    let mut rng = Rng::new(48);
    let inst = type1_complete(14, &mut rng);
    for overlap in [false, true] {
        let mut reference: Option<SolverResult> = None;
        for threads in [1usize, 2, 8] {
            let sweep = SweepStrategy::ShardedParallel { threads };
            let opts = session_opts(sweep, overlap, 1e-6);
            let eager = Nearness::new(&inst)
                .mode(OracleMode::Collect)
                .solve(&opts.clone().lazy_sweep(false));
            let lazy = Nearness::new(&inst)
                .mode(OracleMode::Collect)
                .solve(&opts.clone().lazy_sweep(true));
            assert!(eager.result.converged, "eager (t={threads}) did not converge");
            assert_bit_identical(
                &eager.result,
                &lazy.result,
                &format!("lazy vs eager (t={threads}, overlap={overlap})"),
            );
            assert_eq!(eager.objective, lazy.objective);
            match &reference {
                None => reference = Some(lazy.result),
                Some(r) => assert_bit_identical(
                    r,
                    &lazy.result,
                    &format!("lazy t={threads}, overlap={overlap}"),
                ),
            }
        }
    }
}

#[test]
fn lazy_sweep_sequential_matches_eager_on_cc_box_rows() {
    // Correlation clustering carries remembered box rows through the
    // sweeps — the lazy scheduler must treat them like any other row.
    let inst = cc_instance(49);
    let opts = SolveOptions::new()
        .max_iters(800)
        .violation_tol(1e-4)
        .inner_sweeps(4)
        .sweep(SweepStrategy::Sequential);
    let eager = Correlation::dense(&inst)
        .mode(OracleMode::Collect)
        .seed(7)
        .solve(&opts.clone().lazy_sweep(false));
    let lazy = Correlation::dense(&inst)
        .mode(OracleMode::Collect)
        .seed(7)
        .solve(&opts.clone().lazy_sweep(true));
    assert!(eager.result.converged && lazy.result.converged);
    assert_bit_identical(&eager.result, &lazy.result, "cc lazy vs eager (sequential)");
    assert_eq!(eager.labels, lazy.labels, "cc rounding differs under lazy sweeps");
}

#[test]
fn serve_preemption_with_lazy_sweeps_is_bit_identical_to_eager() {
    // Preemption re-offsets the fleet vector mid-flight: the scheduler's
    // incidence index must invalidate (label-keyed) and fall back to a
    // project-all sweep rather than skip against stale labels.
    use paf::serve::{JobBank, Scheduler, ServeConfig};
    let jobs = paf::serve::demo_trace(93);
    let bank = JobBank::materialize(&jobs);
    let mut reference: Option<Vec<SolverResult>> = None;
    for lazy in [false, true] {
        let opts = SolveOptions::new()
            .violation_tol(1e-5)
            .inner_sweeps(2)
            .sweep(SweepStrategy::ShardedParallel { threads: 2 })
            .lazy_sweep(lazy);
        let cfg = ServeConfig { capacity: 2, opts, ..Default::default() };
        let stats = Scheduler::new(jobs.clone(), &bank, cfg).expect("valid serve config").run();
        assert!(stats.all_completed(), "lazy={lazy}: jobs incomplete");
        assert!(
            stats.preemptions >= 1,
            "lazy={lazy}: the demo trace must exercise preemption"
        );
        let results: Vec<SolverResult> =
            stats.jobs.iter().map(|s| s.result.clone().expect("missing result")).collect();
        match &reference {
            None => reference = Some(results),
            Some(r) => {
                for (k, (want, got)) in r.iter().zip(&results).enumerate() {
                    assert_bit_identical(want, got, &format!("serve job {k} lazy vs eager"));
                }
            }
        }
    }
}

#[test]
fn take_is_none_before_done_and_after_double_take() {
    let mut rng = Rng::new(83);
    let inst = type1_complete(10, &mut rng);
    let opts = session_opts(SweepStrategy::Sequential, false, 1e-8);
    let mut session = Session::new(opts);
    let h = session.add(Nearness::new(&inst).mode(OracleMode::Collect));
    assert!(session.take(h).is_none(), "take before any step must be None");
    session.step();
    if !session.block_done(h.index()) {
        assert!(session.take(h).is_none(), "take before the block finished must be None");
    }
    session.run();
    assert!(session.block_done(h.index()));
    assert!(session.take(h).is_some());
    assert!(session.take(h).is_none(), "double take must be None, not a panic");
}

#[test]
fn legacy_wrappers_route_through_session_unchanged() {
    // The deprecated free functions are thin Session wrappers; their
    // outputs must equal the new API's outputs bit for bit.
    let mut rng = Rng::new(72);
    let inst = type1_complete(12, &mut rng);
    let legacy = solve_nearness(
        &inst,
        &NearnessConfig {
            violation_tol: 1e-6,
            dual_tol: 1e-6,
            mode: OracleMode::Collect,
            ..Default::default()
        },
    );
    let modern = Nearness::new(&inst)
        .mode(OracleMode::Collect)
        .solve(&SolveOptions::new().max_iters(500).violation_tol(1e-6).dual_tol(1e-6));
    assert_bit_identical(&legacy.result, &modern.result, "legacy nearness wrapper");
    let cc = cc_instance(73);
    let legacy_cc = solve_cc(
        &cc,
        &CcConfig { violation_tol: 1e-4, mode: OracleMode::Collect, ..CcConfig::dense() },
        5,
    );
    let modern_cc = Correlation::dense(&cc)
        .mode(OracleMode::Collect)
        .seed(5)
        .solve(&SolveOptions::new().max_iters(200).violation_tol(1e-4).inner_sweeps(2));
    assert_bit_identical(&legacy_cc.result, &modern_cc.result, "legacy cc wrapper");
    assert_eq!(legacy_cc.labels, modern_cc.labels);
}
