//! Thread-count determinism of the parallel runtime (PR-2 tentpole).
//!
//! The sharded executor's scatter-safe parallel apply and the overlapped
//! oracle pipeline are both designed to be *bit-deterministic*: chunk
//! layouts depend only on the configured `threads` value, per-row
//! arithmetic is independent of which worker runs it, and scan results
//! are merged only at the sweep barrier. These tests pin that contract
//! on the two paper workloads: full `SolverResult`s must be bit-identical
//! across thread counts 1, 2 and 8 (the same sweep across `PAF_THREADS`
//! values is covered by the CI matrix, which runs this whole suite under
//! `PAF_THREADS=1` and `PAF_THREADS=4`).

use paf::core::engine::SweepStrategy;
use paf::core::solver::SolverResult;
use paf::graph::generators::type1_complete;
use paf::graph::Graph;
use paf::problems::correlation::{solve_cc, CcConfig, CcInstance, CcResult};
use paf::problems::metric_oracle::OracleMode;
use paf::problems::nearness::{solve_nearness, NearnessConfig};
use paf::util::Rng;

fn assert_bit_identical(reference: &SolverResult, got: &SolverResult, label: &str) {
    assert_eq!(reference.x, got.x, "{label}: x differs (bitwise)");
    assert_eq!(reference.iterations, got.iterations, "{label}: iteration count differs");
    assert_eq!(reference.converged, got.converged, "{label}: convergence differs");
    assert_eq!(
        reference.total_projections, got.total_projections,
        "{label}: projection count differs"
    );
    assert_eq!(
        reference.active_constraints, got.active_constraints,
        "{label}: active-set size differs"
    );
}

fn nearness_cfg(threads: usize, overlap: bool) -> NearnessConfig {
    NearnessConfig {
        mode: OracleMode::Collect,
        sweep: SweepStrategy::ShardedParallel { threads },
        overlap,
        violation_tol: 1e-6,
        dual_tol: 1e-6,
        ..Default::default()
    }
}

#[test]
fn nearness_sharded_is_thread_count_invariant() {
    let mut rng = Rng::new(41);
    let inst = type1_complete(14, &mut rng);
    let mut reference: Option<SolverResult> = None;
    for threads in [1usize, 2, 8] {
        let res = solve_nearness(&inst, &nearness_cfg(threads, false)).result;
        assert!(res.converged, "nearness (t={threads}) did not converge");
        match &reference {
            None => reference = Some(res),
            Some(r) => assert_bit_identical(r, &res, &format!("nearness t={threads}")),
        }
    }
}

#[test]
fn nearness_sharded_overlap_is_thread_count_invariant() {
    let mut rng = Rng::new(42);
    let inst = type1_complete(14, &mut rng);
    let mut reference: Option<SolverResult> = None;
    for threads in [1usize, 2, 8] {
        let res = solve_nearness(&inst, &nearness_cfg(threads, true)).result;
        assert!(res.converged, "overlapped nearness (t={threads}) did not converge");
        match &reference {
            None => reference = Some(res),
            Some(r) => assert_bit_identical(r, &res, &format!("nearness+overlap t={threads}")),
        }
    }
}

#[test]
fn nearness_overlap_reaches_the_nonoverlapped_optimum() {
    // Overlap changes the trajectory (each scan is one round stale), but
    // the program is strictly convex: same unique optimum.
    let mut rng = Rng::new(43);
    let inst = type1_complete(12, &mut rng);
    let mut tight = nearness_cfg(2, false);
    tight.violation_tol = 1e-8;
    tight.dual_tol = 1e-8;
    let plain = solve_nearness(&inst, &tight);
    tight.overlap = true;
    let overlapped = solve_nearness(&inst, &tight);
    assert!(plain.result.converged && overlapped.result.converged);
    for (a, b) in plain.result.x.iter().zip(&overlapped.result.x) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

fn cc_instance(seed: u64) -> CcInstance {
    let mut rng = Rng::new(seed);
    let g = Graph::complete(12);
    let (sg, _) = paf::graph::generators::planted_signed(g, 3, 0.15, &mut rng);
    CcInstance::from_signed(&sg)
}

fn solve_cc_with(inst: &CcInstance, threads: usize, overlap: bool) -> CcResult {
    let cfg = CcConfig {
        mode: OracleMode::Collect,
        sweep: SweepStrategy::ShardedParallel { threads },
        overlap,
        violation_tol: 1e-4,
        inner_sweeps: 4,
        max_iters: 800,
        ..CcConfig::dense()
    };
    solve_cc(inst, &cfg, 7)
}

#[test]
fn correlation_sharded_overlap_is_thread_count_invariant() {
    let inst = cc_instance(44);
    let mut reference: Option<CcResult> = None;
    for threads in [1usize, 2, 8] {
        let res = solve_cc_with(&inst, threads, true);
        assert!(res.result.converged, "overlapped CC (t={threads}) did not converge");
        match &reference {
            None => reference = Some(res),
            Some(r) => {
                assert_bit_identical(&r.result, &res.result, &format!("cc+overlap t={threads}"));
                // Bit-identical x must round to the identical clustering.
                assert_eq!(r.labels, res.labels, "t={threads}: rounding differs");
                assert_eq!(r.lp_objective, res.lp_objective, "t={threads}: LP objective");
            }
        }
    }
}

#[test]
fn correlation_sharded_parallel_apply_is_thread_count_invariant() {
    let inst = cc_instance(45);
    let mut reference: Option<CcResult> = None;
    for threads in [1usize, 2, 8] {
        let res = solve_cc_with(&inst, threads, false);
        assert!(res.result.converged, "sharded CC (t={threads}) did not converge");
        match &reference {
            None => reference = Some(res),
            Some(r) => {
                assert_bit_identical(&r.result, &res.result, &format!("cc t={threads}"));
                assert_eq!(r.labels, res.labels, "t={threads}: rounding differs");
            }
        }
    }
}
