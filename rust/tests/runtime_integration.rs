//! Integration tests for the PJRT runtime path: AOT artifacts vs native
//! rust implementations. Requires `make artifacts` (the Makefile's
//! `test` target guarantees the ordering).

use paf::coordinator::batch_project::{batched_sweep, BatchShape};
use paf::coordinator::pjrt_oracle::PjrtMetricOracle;
use paf::core::active_set::ActiveSet;
use paf::core::bregman::DiagonalQuadratic;
use paf::core::constraint::Constraint;
use paf::core::solver::{Solver, SolverConfig};
use paf::graph::apsp::{apsp_dense, DistMatrix};
use paf::graph::generators::type1_complete;
use paf::graph::Graph;
use paf::problems::metric_oracle::{max_metric_violation, MetricOracle, OracleMode};
use paf::runtime::Runtime;
use paf::util::Rng;
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    let dir = Runtime::default_dir();
    match Runtime::load(&dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping runtime tests (no artifacts?): {e}");
            None
        }
    }
}

#[test]
fn manifest_loads_all_variants() {
    let Some(rt) = runtime() else { return };
    assert!(rt.artifacts.len() >= 6, "expected ≥6 artifacts, got {}", rt.artifacts.len());
    for name in [
        "minplus_step_n128",
        "apsp_n128",
        "apsp_n256",
        "project_b256_k8",
        "project_b1024_k16",
    ] {
        assert!(rt.get(name).is_ok(), "missing {name}");
    }
    assert!(!rt.platform.is_empty());
}

#[test]
fn pjrt_apsp_matches_native_floyd_warshall() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(7);
    let n = 100; // padded to 128
    let g = paf::graph::generators::erdos_renyi(n, 0.15, &mut rng);
    let w: Vec<f64> = (0..g.num_edges()).map(|_| rng.uniform(0.1, 4.0)).collect();
    // Native.
    let native = apsp_dense(&g, &w);
    // PJRT on the padded matrix.
    let p = rt.apsp_size_for(n).unwrap();
    assert_eq!(p, 128);
    let mut dist = vec![f32::INFINITY; p * p];
    for i in 0..n {
        dist[i * p + i] = 0.0;
    }
    for (e, &(a, b)) in g.edges().iter().enumerate() {
        let (a, b) = (a as usize, b as usize);
        dist[a * p + b] = w[e] as f32;
        dist[b * p + a] = w[e] as f32;
    }
    rt.apsp_padded(&mut dist, p).unwrap();
    for i in 0..n {
        for j in 0..n {
            let nat = native.get(i, j);
            let pj = dist[i * p + j] as f64;
            if nat.is_finite() {
                assert!(
                    (nat - pj).abs() < 1e-3 * (1.0 + nat),
                    "({i},{j}): native {nat} vs pjrt {pj}"
                );
            } else {
                assert!(pj.is_infinite());
            }
        }
    }
}

#[test]
fn pjrt_minplus_step_matches_native_square() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(8);
    let n = 128;
    let g = paf::graph::generators::erdos_renyi(n, 0.05, &mut rng);
    let w: Vec<f64> = (0..g.num_edges()).map(|_| rng.uniform(0.5, 2.0)).collect();
    let m0 = DistMatrix::from_graph(&g, &w);
    let native = paf::graph::apsp::minplus_square(&m0);
    let art = rt.get("minplus_step_n128").unwrap();
    let dist: Vec<f32> = m0.d.iter().map(|&v| v as f32).collect();
    let out = art.run_f32(&[&dist]).unwrap();
    for (i, (&nat, &pj)) in native.d.iter().zip(&out[0]).enumerate() {
        if nat.is_finite() {
            assert!((nat - pj as f64).abs() < 1e-3 * (1.0 + nat), "idx {i}");
        } else {
            assert!(pj.is_infinite(), "idx {i}");
        }
    }
}

#[test]
fn pjrt_projection_sweep_matches_sequential_on_disjoint_batch() {
    let Some(rt) = runtime() else { return };
    // Build disjoint-support constraints over 4·256 edges.
    let mut rng = Rng::new(9);
    let m = 2048;
    let d: Vec<f64> = (0..m).map(|_| rng.uniform(-1.0, 3.0)).collect();
    let f = DiagonalQuadratic::unweighted(d.clone());
    let mut active = ActiveSet::new();
    for c in 0..256usize {
        let base = (c * 8) as u32;
        let cons = Constraint::cycle(base, &[base + 1, base + 2, base + 3]);
        let slot = active.insert(&cons);
        active.set_z(slot, rng.uniform(0.0, 0.5));
    }
    // Sequential reference via the Solver's project_row.
    let mut solver = Solver::new(f.clone(), SolverConfig::default());
    solver.x = d.clone();
    solver.active = active.clone();
    for r in 0..solver.active.len() {
        solver.project_row(r);
    }
    // Batched PJRT sweep.
    let mut x = d.clone();
    let w_inv = vec![1.0; m];
    let stats = batched_sweep(
        &rt,
        BatchShape { b: 256, k: 8 },
        &mut active,
        &mut x,
        &w_inv,
    )
    .unwrap();
    assert_eq!(stats.projected, 256);
    assert_eq!(stats.calls, 1);
    for (i, (&seq, &bat)) in solver.x.iter().zip(&x).enumerate() {
        assert!((seq - bat).abs() < 1e-4, "x[{i}]: {seq} vs {bat}");
    }
    for r in 0..active.len() {
        assert!((active.z(r) - solver.active.z(r)).abs() < 1e-4, "z[{r}]");
    }
}

#[test]
fn pjrt_batcher_handles_overlaps_by_splitting() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(10);
    let m = 64;
    let d: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 2.0)).collect();
    let mut active = ActiveSet::new();
    // Chain of overlapping constraints: each shares an edge with the next.
    for e in 0..30u32 {
        let slot = active.insert(&Constraint::cycle(e, &[e + 1, e + 2]));
        active.set_z(slot, 0.1);
    }
    let mut x = d.clone();
    let w_inv = vec![1.0; m];
    let stats =
        batched_sweep(&rt, BatchShape { b: 256, k: 8 }, &mut active, &mut x, &w_inv).unwrap();
    // Everything gets projected, across >1 artifact call.
    assert_eq!(stats.projected, 30);
    assert!(stats.calls >= 2, "expected split batches, got {}", stats.calls);
}

#[test]
fn pjrt_oracle_drives_nearness_to_metric() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(11);
    let inst = type1_complete(40, &mut rng); // fits apsp_n128
    let f = DiagonalQuadratic::unweighted(inst.weights.clone());
    let oracle = PjrtMetricOracle::new(Arc::new(inst.graph.clone()), rt.clone()).unwrap();
    // The certificate-based oracle has a slower tail than the on-find
    // scan (it extracts one witness per violated edge per round), so it
    // runs with more inner sweeps.
    let cfg = SolverConfig {
        max_iters: 400,
        inner_sweeps: 4,
        violation_tol: 1e-4,
        dual_tol: f64::INFINITY,
        ..Default::default()
    };
    let mut solver = Solver::new(f, cfg);
    let res = solver.solve(oracle);
    assert!(res.converged, "pjrt-oracle solve did not converge");
    assert!(max_metric_violation(&inst.graph, &res.x) < 1e-3);
}

#[test]
fn pjrt_oracle_agrees_with_native_oracle() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(12);
    let inst = type1_complete(20, &mut rng);
    let cfg = SolverConfig {
        max_iters: 600,
        inner_sweeps: 4,
        violation_tol: 1e-6,
        dual_tol: f64::INFINITY,
        ..Default::default()
    };
    // Native.
    let fa = DiagonalQuadratic::unweighted(inst.weights.clone());
    let mut sa = Solver::new(fa, cfg.clone());
    let ra = sa.solve(MetricOracle::new(Arc::new(inst.graph.clone()), OracleMode::ProjectOnFind));
    // PJRT.
    let fb = DiagonalQuadratic::unweighted(inst.weights.clone());
    let mut sb = Solver::new(fb, cfg);
    let rb = sb.solve(PjrtMetricOracle::new(Arc::new(inst.graph.clone()), rt.clone()).unwrap());
    assert!(ra.converged && rb.converged);
    for (a, b) in ra.x.iter().zip(&rb.x) {
        assert!((a - b).abs() < 1e-2, "{a} vs {b}");
    }
}

#[test]
fn graph_struct_reexports_work() {
    // Guard: the public API surface used by examples stays intact.
    let g = Graph::complete(5);
    assert_eq!(g.num_edges(), 10);
}
