//! Table 2 — dense weighted correlation clustering: P&F (Algorithm 6) vs
//! the Veldt/Ruggles all-triangles Dykstra baseline, on SNAP-like graphs
//! densified via the Wang et al. complete-graph transform.
//!
//! Columns reproduced: time, approximation ratio ((1+γ)/(1+R) cert), and
//! memory (peak RSS for ours; materialised dual bytes for the baseline —
//! the structural quantity behind the paper's "avg memory/iter" column).
//!
//! Paper shape: ours faster with equal-or-better ratio (≈1.33); baseline
//! carries all 3·C(n,3) duals.

use paf::baselines::ruggles::dykstra_cc;
use paf::coordinator::metrics::MemoryProbe;
use paf::graph::generators::snap_like;
use paf::core::problem::SolveOptions;
use paf::problems::correlation::{CcInstance, Correlation};
use paf::util::benchkit::BenchCtx;
use paf::util::table::Table;
use paf::util::timer::fmt_bytes;
use paf::util::Rng;

fn main() {
    let ctx = BenchCtx::from_env();
    // Default scale: ~2% of the paper's graph sizes (K_n instances are
    // O(n²) edges; the full sizes need the paper's 52 GB class machine).
    let scale = std::env::var("PAF_T2_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.02 * ctx.scale);
    let graphs = ["ca-grqc", "power", "ca-hepth", "ca-hepph"];
    let mut table = Table::new(
        "Table 2 — dense CC: ours vs all-triangles Dykstra (Veldt/Ruggles)",
        &[
            "graph", "n", "ours_time", "dykstra_time", "ours_ratio", "dykstra_ratio",
            "ours_peak_mem", "dykstra_dual_mem", "ours_active",
        ],
    );
    for name in graphs {
        let mut rng = Rng::new(2);
        let g = snap_like(name, scale, &mut rng);
        let inst = CcInstance::densify(&g);
        let n = inst.graph.num_nodes();
        println!("-- {name}: densified K_{n} ({} edges)", inst.graph.num_edges());

        let probe = MemoryProbe::start();
        let opts = SolveOptions::new().violation_tol(1e-2).max_iters(200);
        let (ours_t, ours) =
            ctx.bench_once(&format!("ours/{name}"), || Correlation::dense(&inst).seed(3).solve(&opts));
        let mem = probe.finish();
        assert!(ours.result.converged, "{name}: P&F did not converge");

        let (dy_t, dy) = ctx.bench_once(&format!("dykstra/{name}"), || {
            dykstra_cc(&inst, 1.0, 1e-2, 100_000)
        });

        table.rowd(&[
            name.to_string(),
            n.to_string(),
            format!("{ours_t:.2}"),
            format!("{dy_t:.2}"),
            format!("{:.3}", ours.approx_ratio),
            format!("{:.3}", dy.approx_ratio),
            fmt_bytes(mem.peak_rss),
            fmt_bytes(dy.dual_bytes as u64),
            ours.result.active_constraints.to_string(),
        ]);
    }
    table.emit(&ctx.report_dir, "table2_cc_dense");
}
