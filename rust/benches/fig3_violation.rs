//! Figure 3 — maximum metric-constraint violation per iteration on the
//! CA-HepTh-like dense CC instance. Paper shape: exponential decay
//! (Theorem 1's asymptotically linear rate); the bench fits the decay
//! rate and asserts it is geometric (< 1).

use paf::coordinator::{figure3_series, violation_decay_rate};
use paf::graph::generators::snap_like;
use paf::core::problem::SolveOptions;
use paf::problems::correlation::{CcInstance, Correlation};
use paf::util::benchkit::BenchCtx;
use paf::util::Rng;

fn main() {
    let ctx = BenchCtx::from_env();
    let scale = std::env::var("PAF_FIG3_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.015 * ctx.scale);
    let mut rng = Rng::new(5);
    let g = snap_like("ca-hepth", scale, &mut rng);
    let inst = CcInstance::densify(&g);
    let opts = SolveOptions::new().violation_tol(1e-4).max_iters(400);
    let (_, res) = ctx.bench_once("cc/ca-hepth", || Correlation::dense(&inst).seed(7).solve(&opts));
    let series = figure3_series(&res.result, "Figure 3 — max violation per iteration");
    series.emit(&ctx.report_dir, "fig3");
    match violation_decay_rate(&res.result) {
        Some(rate) => {
            println!("fitted asymptotic decay rate: {rate:.4} per iteration");
            assert!(rate < 1.0, "violation decay is not geometric (rate {rate})");
        }
        None => println!("trace too short to fit a rate"),
    }
}
