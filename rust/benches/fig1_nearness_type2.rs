//! Figure 1 — metric nearness running-time curves on type-2 graphs
//! (w(e)=1 w.p. 0.8 else 0): P&F (blue) vs Brickell (red).
//!
//! Relaxed convergence as in §4.1's second experiment: stop once within
//! distance 1 of the decrease-only metric solution — here both solvers
//! run to the same max-violation tolerance calibrated to that criterion.

use paf::baselines::brickell::triangle_fixing;
use paf::graph::generators::type2_complete;
use paf::core::problem::SolveOptions;
use paf::problems::nearness::{decrease_only_distance, Nearness};
use paf::util::benchkit::BenchCtx;
use paf::util::table::Series;
use paf::util::Rng;

fn main() {
    run(
        "fig1",
        "Figure 1 — nearness runtimes, type-2 graphs",
        |n, rng| type2_complete(n, rng),
    );
}

pub fn run(
    basename: &str,
    title: &str,
    gen: impl Fn(usize, &mut Rng) -> paf::graph::generators::WeightedInstance,
) {
    let ctx = BenchCtx::from_env();
    let sizes: Vec<usize> =
        [80usize, 140, 200, 260].iter().map(|&n| ctx.scaled(n)).collect();
    let mut series = Series::new(title, "n", &["ours_seconds", "brickell_seconds"]);
    for &n in &sizes {
        let mut rng = Rng::new(1000 + n as u64);
        let inst = gen(n, &mut rng);
        let tol = 1e-2;
        let pf = ctx.bench(&format!("pf/n{n}"), |_| {
            Nearness::new(&inst).solve(&SolveOptions::new().violation_tol(tol))
        });
        let br = ctx.bench(&format!("brickell/n{n}"), |_| {
            triangle_fixing(n, &inst.weights, tol, 10_000)
        });
        series.push(n as f64, &[pf.mean(), br.mean()]);
        // §8.2 criterion sanity: the P&F solution is within distance ~1 of
        // its decrease-only closure.
        let res = Nearness::new(&inst).solve(&SolveOptions::new().violation_tol(tol));
        let dd = decrease_only_distance(&inst.graph, &res.result.x);
        println!("n={n}: decrease-only distance {dd:.3}");
    }
    series.emit(&ctx.report_dir, basename);
}
