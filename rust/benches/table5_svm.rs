//! Table 5 — L2-SVM at n = 10⁶, d = 100, C = 10³: the truly stochastic
//! P&F trainer vs LIBLINEAR-style dual coordinate descent and primal
//! Newton, across the paper's three noise levels (K = 10, 5, 2 →
//! s ≈ 6.3%, 12.6%, 29.5%).
//!
//! Paper shape: ours fastest by a wide margin over the dual solver with
//! equal-or-better accuracy; the primal solver has the best accuracy.
//! Default runs at n = 10⁶ (scale with PAF_BENCH_SCALE for CI).

use paf::baselines::svm_liblinear::{train_dual_cd, train_primal_newton};
use paf::ml::dataset::svm_cloud;
use paf::problems::svm::{train_pf_svm, SvmConfig};
use paf::util::benchkit::BenchCtx;
use paf::util::table::Table;
use paf::util::Rng;

fn main() {
    let ctx = BenchCtx::from_env();
    let n = std::env::var("PAF_T5_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(ctx.scaled(1_000_000));
    let d = 100;
    let c = 1e3;
    let mut table = Table::new(
        "Table 5 — L2-SVM: time (s) and test accuracy",
        &["n", "d", "s", "ours_t", "dual_t", "primal_t", "ours_acc", "dual_acc", "primal_acc"],
    );
    for k in [10.0, 5.0, 2.0] {
        let mut rng = Rng::new(19);
        let (all, s) = svm_cloud(2 * n, d, k, &mut rng);
        let (train, test) = all.split(0.5, &mut rng);
        println!("-- K={k}: n={n} s={:.1}%", s * 100.0);
        let (ours_t, ours) = ctx.bench_once(&format!("ours/K{k}"), || {
            train_pf_svm(&train, &SvmConfig { c, epochs: 5, seed: 19 })
        });
        // Dual CD at the paper's C=10³ is the slow column; cap epochs so
        // the bench finishes, exactly as LIBLINEAR caps iterations (it
        // reports "reaching maximum iterations" on these runs).
        let (dual_t, dual) = ctx.bench_once(&format!("dual/K{k}"), || {
            train_dual_cd(&train, c, 1e-3, 30, 19)
        });
        let (primal_t, primal) = ctx.bench_once(&format!("primal/K{k}"), || {
            train_primal_newton(&train, c, 1e-3, 25)
        });
        table.rowd(&[
            n.to_string(),
            d.to_string(),
            format!("{:.1}%", s * 100.0),
            format!("{ours_t:.2}"),
            format!("{dual_t:.2}"),
            format!("{primal_t:.2}"),
            format!("{:.1}%", 100.0 * ours.accuracy(&test)),
            format!("{:.1}%", 100.0 * dual.accuracy(&test)),
            format!("{:.1}%", 100.0 * primal.accuracy(&test)),
        ]);
    }
    table.emit(&ctx.report_dir, "table5_svm");
    println!("\npaper shape: ours ≪ dual in time, ≈ dual in accuracy, primal best accuracy.");
}
