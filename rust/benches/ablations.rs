//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. FORGET policy — forget-each-iteration (the paper) vs never-forget
//!    (classic active set) vs forget-all (truly stochastic flavour):
//!    effect on time and remembered-set size.
//! 2. Inner project/forget sweeps — 1 vs 2 vs 8 vs 75 (Algorithms 6 vs 7).
//! 3. Oracle delivery — project-on-find (Algorithm 8) vs collect.
//! 4. Dense APSP backend — native blocked Floyd–Warshall vs the PJRT
//!    min-plus artifact (one oracle round each).
//! 5. Sweep strategy — sequential Gauss–Seidel vs the sharded parallel
//!    executor (1/2/4 threads) on a Collect-mode nearness solve, with
//!    the objective agreement reported alongside the timing.

use paf::core::bregman::DiagonalQuadratic;
use paf::core::engine::SweepStrategy;
use paf::core::solver::{Solver, SolverConfig};
use paf::graph::apsp::apsp_dense;
use paf::graph::generators::{planted_signed, type1_complete};
use paf::core::problem::SolveOptions;
use paf::problems::correlation::{CcInstance, Correlation};
use paf::problems::metric_oracle::{MetricOracle, OracleMode};
use paf::runtime::Runtime;
use paf::util::benchkit::BenchCtx;
use paf::util::table::Table;
use paf::util::Rng;
use std::sync::Arc;

fn main() {
    let ctx = BenchCtx::from_env();
    ablation_forget(&ctx);
    ablation_sweeps(&ctx);
    ablation_oracle_mode(&ctx);
    ablation_apsp_backend(&ctx);
    ablation_sweep_strategy(&ctx);
}

/// 1. Forget policy: we emulate "never forget" by observing the
/// remembered set with forgetting on vs the total *distinct* constraints
/// discovered (what a no-forget active set would carry).
fn ablation_forget(ctx: &BenchCtx) {
    let n = ctx.scaled(120);
    let mut rng = Rng::new(23);
    let inst = type1_complete(n, &mut rng);
    let res = paf::problems::nearness::Nearness::new(&inst)
        .solve(&SolveOptions::new().violation_tol(1e-2));
    let total_found: usize = res.result.trace.iter().map(|t| t.found).sum();
    let peak_merged = res.result.trace.iter().map(|t| t.merged).max().unwrap_or(0);
    let mut t = Table::new(
        "Ablation 1 — FORGET keeps the working set small",
        &["quantity", "count"],
    );
    t.rowd(&["constraints delivered over the run".to_string(), total_found.to_string()]);
    t.rowd(&["peak remembered (with FORGET)".to_string(), peak_merged.to_string()]);
    t.rowd(&["final remembered (≈ active set)".to_string(), res.result.active_constraints.to_string()]);
    t.emit(&ctx.report_dir, "ablation_forget");
}

/// 2. Inner sweep count on a dense CC instance.
fn ablation_sweeps(ctx: &BenchCtx) {
    let n = ctx.scaled(60);
    let mut rng = Rng::new(29);
    let g = paf::graph::Graph::complete(n);
    let (sg, _) = planted_signed(g, 6, 0.15, &mut rng);
    let inst = CcInstance::from_signed(&sg);
    let mut t = Table::new(
        "Ablation 2 — inner project/forget sweeps per iteration",
        &["sweeps", "iterations", "seconds", "projections"],
    );
    for sweeps in [1usize, 2, 8, 75] {
        let opts = SolveOptions::new()
            .inner_sweeps(sweeps)
            .violation_tol(1e-3)
            .max_iters(2000);
        let (secs, res) = ctx.bench_once(&format!("sweeps/{sweeps}"), || {
            Correlation::dense(&inst).seed(1).solve(&opts)
        });
        t.rowd(&[
            sweeps.to_string(),
            res.result.iterations.to_string(),
            format!("{secs:.3}"),
            res.result.total_projections.to_string(),
        ]);
    }
    t.emit(&ctx.report_dir, "ablation_sweeps");
}

/// 3. Project-on-find vs collect vs Property-2 random triangles, on
/// metric nearness. The random oracle cannot self-certify, so it runs a
/// fixed budget and all three report the *residual* metric violation.
fn ablation_oracle_mode(ctx: &BenchCtx) {
    let n = ctx.scaled(140);
    let mut t = Table::new(
        "Ablation 3 — oracle delivery mode",
        &["mode", "iterations", "seconds", "projections", "residual_violation"],
    );
    let mut run = |label: &str, mk: &mut dyn FnMut() -> paf::core::solver::SolverResult| {
        let (secs, res) = ctx.bench_once(&format!("mode/{label}"), mk);
        let mut rng = Rng::new(31);
        let inst = type1_complete(n, &mut rng);
        let viol = paf::problems::metric_oracle::max_metric_violation(&inst.graph, &res.x);
        t.rowd(&[
            label.to_string(),
            res.iterations.to_string(),
            format!("{secs:.3}"),
            res.total_projections.to_string(),
            format!("{viol:.2e}"),
        ]);
    };
    for (label, mode) in [("project-on-find", OracleMode::ProjectOnFind), ("collect", OracleMode::Collect)] {
        run(label, &mut || {
            let mut rng = Rng::new(31);
            let inst = type1_complete(n, &mut rng);
            let f = DiagonalQuadratic::unweighted(inst.weights.clone());
            let oracle = MetricOracle::new(Arc::new(inst.graph.clone()), mode);
            let cfg = SolverConfig {
                max_iters: 500,
                inner_sweeps: 1,
                violation_tol: 1e-2,
                dual_tol: f64::INFINITY,
                ..Default::default()
            };
            let mut solver = Solver::new(f, cfg);
            solver.solve(oracle)
        });
    }
    run("random-triangles", &mut || {
        let mut rng = Rng::new(31);
        let inst = type1_complete(n, &mut rng);
        let f = DiagonalQuadratic::unweighted(inst.weights.clone());
        let oracle = paf::problems::random_oracle::RandomTriangleOracle::new(
            Arc::new(inst.graph.clone()),
            20_000,
            31,
        );
        let cfg = SolverConfig {
            max_iters: 40, // fixed budget: Property 2 cannot certify
            inner_sweeps: 1,
            violation_tol: -1.0,
            dual_tol: 0.0,
            record_trace: false,
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        solver.solve(oracle)
    });
    t.emit(&ctx.report_dir, "ablation_oracle_mode");
}

/// 5. Sweep strategy on a Collect-mode nearness solve (Collect keeps
/// the remembered list large between oracle rounds, which is the regime
/// where sharding the sweep pays).
fn ablation_sweep_strategy(ctx: &BenchCtx) {
    let n = ctx.scaled(150);
    let mut t = Table::new(
        "Ablation 5 — projection sweep strategy",
        &["strategy", "iterations", "seconds", "projections", "objective"],
    );
    let mut objective_seq = None;
    for (label, strategy) in [
        ("sequential", SweepStrategy::Sequential),
        ("sharded-t1", SweepStrategy::ShardedParallel { threads: 1 }),
        ("sharded-t2", SweepStrategy::ShardedParallel { threads: 2 }),
        ("sharded-t4", SweepStrategy::ShardedParallel { threads: 4 }),
    ] {
        let mut rng = Rng::new(41);
        let inst = type1_complete(n, &mut rng);
        let opts = SolveOptions::new().violation_tol(1e-4).sweep(strategy);
        let (secs, res) = ctx.bench_once(&format!("strategy/{label}"), || {
            paf::problems::nearness::Nearness::new(&inst)
                .mode(OracleMode::Collect)
                .solve(&opts)
        });
        // Strategies (and bucketed delivery) take different trajectories
        // to the same optimum; at violation_tol = 1e-4 the objectives
        // agree to the stopping accuracy, not machine precision.
        let reference = *objective_seq.get_or_insert(res.objective);
        assert!(
            (res.objective - reference).abs() <= 1e-3 * (1.0 + reference.abs()),
            "{label}: objective {} drifted from sequential {reference}",
            res.objective
        );
        t.rowd(&[
            label.to_string(),
            res.result.iterations.to_string(),
            format!("{secs:.3}"),
            res.result.total_projections.to_string(),
            format!("{:.6}", res.objective),
        ]);
    }
    t.emit(&ctx.report_dir, "ablation_sweep_strategy");
}

/// 4. APSP backend for one dense oracle certification round.
fn ablation_apsp_backend(ctx: &BenchCtx) {
    let n = 100; // pads into apsp_n128
    let mut rng = Rng::new(37);
    let inst = type1_complete(n, &mut rng);
    let mut t = Table::new(
        "Ablation 4 — dense APSP backend (one oracle round)",
        &["backend", "seconds"],
    );
    let nat = ctx.bench("apsp/native-fw", |_| apsp_dense(&inst.graph, &inst.weights));
    t.rowd(&["native blocked Floyd–Warshall".to_string(), format!("{:.4}", nat.mean())]);
    let dij = ctx.bench("apsp/native-dijkstra", |_| {
        paf::graph::apsp::apsp_dijkstra(&inst.graph, &inst.weights, 1)
    });
    t.rowd(&["native per-source Dijkstra".to_string(), format!("{:.4}", dij.mean())]);
    match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => {
            let p = rt.apsp_size_for(n).unwrap();
            let mut base = vec![f32::INFINITY; p * p];
            for i in 0..n {
                base[i * p + i] = 0.0;
            }
            for (e, &(a, b)) in inst.graph.edges().iter().enumerate() {
                let (a, b) = (a as usize, b as usize);
                base[a * p + b] = inst.weights[e] as f32;
                base[b * p + a] = inst.weights[e] as f32;
            }
            let pj = ctx.bench("apsp/pjrt-minplus", |_| {
                let mut d = base.clone();
                rt.apsp_padded(&mut d, p).unwrap();
                d
            });
            t.rowd(&[
                format!("PJRT min-plus artifact (padded {p})"),
                format!("{:.4}", pj.mean()),
            ]);
        }
        Err(e) => println!("(pjrt backend skipped: {e})"),
    }
    t.emit(&ctx.report_dir, "ablation_apsp_backend");
}
