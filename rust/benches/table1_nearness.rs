//! Table 1 — metric nearness on type-1 (Gaussian) complete graphs:
//! PROJECT AND FORGET vs Brickell triangle fixing vs a materialise-
//! everything "standard solver" (ADMM stand-in for the Mosek/SCS/OSQP
//! columns; see DESIGN.md §substitutions).
//!
//! Paper shape to reproduce: Brickell wins at small n, P&F overtakes as n
//! grows; generic solvers blow up (OOM / timeout) almost immediately.
//!
//! Scale knobs: PAF_BENCH_SCALE (sizes), PAF_T1_SIZES (explicit list).

use paf::baselines::brickell::triangle_fixing;
use paf::baselines::generic_qp::{admm_metric_nearness, QpConfig, QpOutcome};
use paf::graph::generators::type1_complete;
use paf::core::problem::SolveOptions;
use paf::problems::nearness::Nearness;
use paf::util::benchkit::BenchCtx;
use paf::util::table::Table;
use paf::util::Rng;

fn main() {
    let ctx = BenchCtx::from_env();
    let sizes: Vec<usize> = match std::env::var("PAF_T1_SIZES") {
        Ok(s) => s.split(',').filter_map(|v| v.trim().parse().ok()).collect(),
        Err(_) => [100usize, 160, 220, 300]
            .iter()
            .map(|&n| ctx.scaled(n))
            .collect(),
    };
    let tol = 1e-2;
    let mut table = Table::new(
        "Table 1 — metric nearness, type-1 graphs (seconds)",
        &["algorithm", "metric"]
            .iter()
            .cloned()
            .chain(sizes.iter().map(|_| "n"))
            .collect::<Vec<_>>()
            .as_slice(),
    );
    // Header row carrying actual sizes (paper prints sizes as columns).
    {
        let mut row = vec!["(sizes)".to_string(), "n".to_string()];
        row.extend(sizes.iter().map(|n| n.to_string()));
        table.row(&row);
    }

    let mut ours = vec!["ours (P&F)".to_string(), "time".to_string()];
    let mut ours_active = vec!["ours (P&F)".to_string(), "#active".to_string()];
    let mut brick = vec!["brickell triangle-fixing".to_string(), "time".to_string()];
    let mut admm = vec!["generic ADMM (std-solver stand-in)".to_string(), "time".to_string()];
    for &n in &sizes {
        let mut rng = Rng::new(42 + n as u64);
        let inst = type1_complete(n, &mut rng);
        let stats = ctx.bench(&format!("pf/n{n}"), |_| {
            Nearness::new(&inst).solve(&SolveOptions::new().violation_tol(tol))
        });
        // Re-run once to read result fields (benched run discards them).
        let res = Nearness::new(&inst).solve(&SolveOptions::new().violation_tol(tol));
        assert!(res.result.converged, "pf must converge at n={n}");
        ours.push(format!("{:.2}", stats.mean()));
        ours_active.push(res.result.active_constraints.to_string());

        let bstats = ctx.bench(&format!("brickell/n{n}"), |_| {
            triangle_fixing(n, &inst.weights, tol, 10_000)
        });
        brick.push(format!("{:.2}", bstats.mean()));

        // Generic solver: small memory/time budget, as the paper's
        // standard solvers had; report OOM/timeout verbatim.
        let qp_cfg = QpConfig {
            memory_limit: 1 << 28, // 256 MiB "machine"
            time_limit_s: 30.0,
            max_iters: 400,
            tol: tol,
            ..Default::default()
        };
        let (dt, outcome) =
            ctx.bench_once(&format!("admm/n{n}"), || admm_metric_nearness(n, &inst.weights, &qp_cfg));
        admm.push(match outcome {
            QpOutcome::Solved { .. } => format!("{dt:.2}"),
            QpOutcome::OutOfMemory { .. } => "OOM".to_string(),
            QpOutcome::TimedOut { .. } => "timeout".to_string(),
        });
    }
    table.row(&ours);
    table.row(&brick);
    table.row(&admm);
    table.row(&ours_active);
    table.emit(&ctx.report_dir, "table1_nearness");
    println!("\n§4.1 check: P&F active-constraint count should be ≈ n²: see #active row.");
}
