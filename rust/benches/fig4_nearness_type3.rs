//! Figure 4 — metric nearness running-time curves on type-3 graphs
//! (w = ⌈1000·u·v²⌉, u ~ U[0,1], v ~ N(0,1)): P&F vs Brickell.
//!
//! Same harness as Figure 1, different weight distribution (heavy-tailed
//! integer weights make far more triangle inequalities active).

use paf::baselines::brickell::triangle_fixing;
use paf::graph::generators::type3_complete;
use paf::core::problem::SolveOptions;
use paf::problems::nearness::Nearness;
use paf::util::benchkit::BenchCtx;
use paf::util::table::Series;
use paf::util::Rng;

fn main() {
    let ctx = BenchCtx::from_env();
    let sizes: Vec<usize> =
        [80usize, 140, 200, 260].iter().map(|&n| ctx.scaled(n)).collect();
    let mut series = Series::new(
        "Figure 4 — nearness runtimes, type-3 graphs",
        "n",
        &["ours_seconds", "brickell_seconds"],
    );
    for &n in &sizes {
        let mut rng = Rng::new(4000 + n as u64);
        let inst = type3_complete(n, &mut rng);
        // Weights are O(1000); scale the violation tolerance accordingly
        // (the paper relaxes convergence on these instances too).
        let tol = 1.0;
        let pf = ctx.bench(&format!("pf/n{n}"), |_| {
            Nearness::new(&inst).solve(&SolveOptions::new().violation_tol(tol))
        });
        let br = ctx.bench(&format!("brickell/n{n}"), |_| {
            triangle_fixing(n, &inst.weights, tol, 10_000)
        });
        series.push(n as f64, &[pf.mean(), br.mean()]);
    }
    series.emit(&ctx.report_dir, "fig4");
}
