//! Table 3 — sparse weighted correlation clustering at social-network
//! scale (Slashdot / Epinions shapes): the row the paper's headline rests
//! on — trillions of implicit constraints, a few hundred thousand active.
//!
//! Columns: n, implicit #constraints, time, opt ratio, #active, iters.
//! Default scale is 2% of the paper's sizes (full size with
//! PAF_T3_SCALE=1 on a machine with days of budget, matching the paper's
//! 46.7h/121.2h runtimes).

use paf::graph::generators::{sign_edges, snap_like};
use paf::core::problem::SolveOptions;
use paf::problems::correlation::{CcInstance, Correlation};
use paf::util::benchkit::BenchCtx;
use paf::util::table::Table;
use paf::util::Rng;

fn main() {
    let ctx = BenchCtx::from_env();
    let scale = std::env::var("PAF_T3_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.02 * ctx.scale);
    let mut table = Table::new(
        "Table 3 — sparse CC (Slashdot/Epinions shapes)",
        &["graph", "n", "#constraints", "time", "opt_ratio", "#active", "iters"],
    );
    for name in ["slashdot", "epinions"] {
        let mut rng = Rng::new(11);
        let g = snap_like(name, scale, &mut rng);
        let sg = sign_edges(g, 0.77, &mut rng); // ~Slashdot's +/- balance
        let inst = CcInstance::from_signed(&sg);
        let n = inst.graph.num_nodes() as f64;
        let implicit = n * (n - 1.0) * (n - 2.0) / 2.0;
        println!("-- {name}: n={} m={}", inst.graph.num_nodes(), inst.graph.num_edges());
        let opts = SolveOptions::new().max_iters(250);
        let (secs, res) = ctx.bench_once(&format!("sparse-cc/{name}"), || {
            Correlation::sparse(&inst).seed(13).solve(&opts)
        });
        assert!(res.result.converged, "{name} did not converge");
        table.rowd(&[
            name.to_string(),
            (n as usize).to_string(),
            format!("{implicit:.2e}"),
            format!("{secs:.1}"),
            format!("{:.2}", res.approx_ratio),
            res.result.active_constraints.to_string(),
            res.result.iterations.to_string(),
        ]);
    }
    table.emit(&ctx.report_dir, "table3_cc_sparse");
    println!("\npaper shape: #active is a vanishing fraction of #constraints.");
}
