//! Figure 2 — constraints returned by the oracle vs constraints kept
//! after FORGET, per iteration, solving dense CC on the CA-HepTh-like
//! graph. Paper shape: a large initial spike that collapses within ~15
//! iterations as the true active set is identified.

use paf::coordinator::figure2_series;
use paf::graph::generators::snap_like;
use paf::core::problem::SolveOptions;
use paf::problems::correlation::{CcInstance, Correlation};
use paf::util::benchkit::BenchCtx;
use paf::util::Rng;

fn main() {
    let ctx = BenchCtx::from_env();
    let scale = std::env::var("PAF_FIG2_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.015 * ctx.scale);
    let mut rng = Rng::new(5);
    let g = snap_like("ca-hepth", scale, &mut rng);
    let inst = CcInstance::densify(&g);
    println!(
        "ca-hepth-like densified: K_{} ({} edges)",
        inst.graph.num_nodes(),
        inst.graph.num_edges()
    );
    let opts = SolveOptions::new().violation_tol(1e-2).max_iters(200);
    let (_, res) = ctx.bench_once("cc/ca-hepth", || Correlation::dense(&inst).seed(7).solve(&opts));
    assert!(res.result.converged);
    let series = figure2_series(&res.result, "Figure 2 — oracle vs post-forget constraint counts");
    series.emit(&ctx.report_dir, "fig2");
    // Shape assertions: the found-count must collapse from its peak.
    let found: Vec<usize> = res.result.trace.iter().map(|t| t.found).collect();
    let peak = *found.iter().max().unwrap();
    let last = *found.last().unwrap();
    println!("peak found {peak}, final found {last}");
    assert!(last * 2 < peak.max(2), "constraint discovery did not collapse");
}
