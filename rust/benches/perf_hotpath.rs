//! §Perf driver: isolates the solver's hot paths so the optimisation
//! loop (EXPERIMENTS.md §Perf) has stable, comparable numbers.
//!
//! Paths measured:
//!   P1  separation-oracle round (Dijkstra scan + witness extraction),
//!       plus the incremental-separation axes: a cold full scan vs the
//!       dirty-source incremental scan of the same iterate, and a
//!       late-round variant where <5% of the coordinates moved since
//!       the cached scan
//!   P2  projection sweep throughput (projections/second), with a
//!       sweep-strategy axis: sequential Gauss–Seidel vs the sharded
//!       executor (parallel θ+apply on the persistent pool) at 2 and 4
//!       threads
//!   P3  full metric nearness solve (n = 260, type 1)
//!   P4  full dense CC solve (K_120 planted), with the cross-PR
//!       trajectory axis: sequential vs sharded vs sharded+overlap
//!       (oracle scan overlapped with the sweeps), all in Collect mode
//!       so only the runtime changes between variants
//!   P5  active-set merge/forget churn (insert + forget cycles)
//!   P6  native blocked min-plus APSP (the L1 kernel's CPU twin)
//!   P7  multi-instance batching: K nearness instances as a sequential
//!       loop vs one Session fleet sharing a single sharded sweep (the
//!       block-offset multi-instance axis)
//!   P8  serving: the same K jobs as a sequential solo loop vs the
//!       serve scheduler with staggered arrivals — the fleet changes
//!       mid-solve (admissions + compaction), but the sweeps stay
//!       amortised across whatever is running
//!   P9  lazy sweep scheduling on a genuine late solver round: an eager
//!       full sweep vs the movement-driven scheduler (`sweep/lazy`,
//!       which skips armed rows whose support did not move) vs the
//!       settled floor (`sweep/lazy-clean`), all from the same snapshot
//!       — the iterates stay bit-identical, only the visit count drops
//!   P10 serve persistence: the non-destructive mid-solve checkpoint
//!       capture (what `--checkpoint-every` pays per running job), the
//!       wire encoding, the atomic durable write, and recovery
//!       load+decode
//!   P12 observability overhead: the same Collect nearness solve with
//!       instrumentation off, with span tracing on (`obs/spans`), and
//!       with per-round convergence telemetry on (`obs/telemetry`) —
//!       the iterates must stay bit-identical across all three; only
//!       the recording cost may differ
//!   P11 streaming ingestion: a sparse geometric instance written to
//!       disk once, then each ingest stage in isolation — edge-list
//!       parse throughput, the two-pass bounded-memory CSR build (with
//!       the ledger's working-set peak printed alongside), the spatial
//!       neighborhood-scoped oracle scan vs the full scan on the same
//!       iterate, and time-to-first-certificate (cold file → first
//!       completed violation scan)
//!
//! All timings are also written to `reports/BENCH_perf_hotpath.json`
//! (machine-readable; see `BenchCtx::write_json`) so the perf trajectory
//! is tracked across PRs.

use paf::core::bregman::DiagonalQuadratic;
use paf::core::constraint::Constraint;
use paf::core::engine::SweepStrategy;
use paf::core::problem::SolveOptions;
use paf::core::session::Session;
use paf::core::solver::{Solver, SolverConfig};
use paf::graph::apsp::{floyd_warshall_blocked, DistMatrix};
use paf::graph::generators::{planted_signed, type1_complete};
use paf::problems::correlation::{CcInstance, Correlation};
use paf::problems::metric_oracle::{MetricOracle, OracleMode};
use paf::problems::nearness::Nearness;
use paf::util::benchkit::BenchCtx;
use paf::util::Rng;
use std::sync::Arc;

fn main() {
    let ctx = BenchCtx::from_env();
    let mut all = Vec::new();

    // P1: one oracle round on a fresh (violation-rich) instance.
    {
        let mut rng = Rng::new(51);
        let inst = type1_complete(ctx.scaled(300), &mut rng);
        let f = DiagonalQuadratic::unweighted(inst.weights.clone());
        all.push(ctx.bench("P1/oracle-round", |_| {
            let oracle = MetricOracle::new(Arc::new(inst.graph.clone()), OracleMode::ProjectOnFind);
            let cfg = SolverConfig { max_iters: 1, record_trace: false, ..Default::default() };
            let mut s = Solver::new(f.clone(), cfg);
            s.solve(oracle)
        }));

        // Incremental-separation axes on a genuine late-round
        // (low-movement) instance: drive a real Collect solve round by
        // round until one round moves <5% of the coordinates, and
        // measure the oracle's cost for exactly that round's transition
        // — the regime the dirty-source oracle is built for. One sparse
        // axis (balls are local: most sources skip) and one dense
        // honesty axis (balls cover all of V on a complete graph, so
        // only the quantitative reach test and the radius bound save
        // work).
        let mut prng = Rng::new(99);
        let sparse = paf::graph::generators::erdos_renyi(ctx.scaled(600), 0.04, &mut prng);
        let dsp: Vec<f64> =
            (0..sparse.num_edges()).map(|_| prng.uniform(0.2, 2.0)).collect();
        for (label, graph, d) in [
            ("P1/oracle-round", Arc::new(sparse), dsp),
            ("P1/oracle-round-dense", Arc::new(inst.graph.clone()), inst.weights.clone()),
        ] {
            let (x_mid, x_late, moved) = late_round_pair(&graph, d);
            let mut cold = MetricOracle::new(graph.clone(), OracleMode::Collect);
            cold.incremental = false;
            all.push(ctx.bench(&format!("{label}/full"), |_| cold.scan_cycles(&x_late).len()));
            let mut inc = MetricOracle::new(graph.clone(), OracleMode::Collect);
            let mut rescanned = 0;
            all.push(ctx.bench_marked(&format!("{label}/incremental"), |_, region| {
                // Re-warm the cache on the previous round's iterate
                // outside the timed region, so every run measures the
                // same x_mid → x_late transition.
                let base = inc.scan_cycles(&x_mid);
                inc.commit_scan(base);
                region.start();
                let scan = inc.scan_cycles(&x_late);
                let found = scan.len();
                rescanned = scan.rescanned();
                inc.commit_scan(scan);
                found
            }));
            println!(
                "    -> late round moved {moved}/{} coords; incremental rescans \
                 {rescanned}/{} sources",
                graph.num_edges(),
                graph.num_nodes(),
            );
            // A no-movement round: the floor of the incremental scan.
            all.push(ctx.bench_marked(&format!("{label}/incremental-clean"), |_, region| {
                let base = inc.scan_cycles(&x_late);
                inc.commit_scan(base);
                region.start();
                let scan = inc.scan_cycles(&x_late);
                let found = scan.len();
                inc.commit_scan(scan);
                found
            }));
        }
    }

    // P2: sweep throughput over a synthetic active set, across sweep
    // strategies (the sequential-vs-sharded axis; duals are re-seeded
    // per run so every strategy does identical work).
    {
        let mut rng = Rng::new(52);
        let m = 40_000;
        let d: Vec<f64> = (0..m).map(|_| rng.uniform(-1.0, 2.0)).collect();
        let f = DiagonalQuadratic::unweighted(d.clone());
        // Lazy scheduling must stay off here: the per-run reset below
        // re-seeds x and the duals behind the movement tracker's back,
        // which would invalidate the scheduler's zero-step proofs.
        let cfg =
            SolverConfig { record_trace: false, lazy_sweep: false, ..Default::default() };
        let mut s = Solver::new(f, cfg);
        for _ in 0..20_000 {
            let e = rng.below(m) as u32;
            let a = rng.below(m) as u32;
            let b = rng.below(m) as u32;
            if e != a && e != b && a != b {
                let slot = s.active.insert(&Constraint::cycle(e, &[a, b]));
                s.active.set_z(slot, rng.uniform(0.0, 0.3));
            }
        }
        let rows = s.active.len();
        let seed_z: Vec<f64> = (0..rows).map(|r| s.active.z(r)).collect();
        for (label, strategy) in [
            ("sequential", SweepStrategy::Sequential),
            ("sharded-t2", SweepStrategy::ShardedParallel { threads: 2 }),
            ("sharded-t4", SweepStrategy::ShardedParallel { threads: 4 }),
        ] {
            s.set_sweep_strategy(strategy);
            // Reset the iterate and duals before every sweep so each run
            // sweeps the same state (strategies stay comparable), but
            // keep the O(m + rows) reset *outside* the timed region —
            // timing it would compress the very strategy differences the
            // cross-PR JSON tracks.
            let stats = ctx.bench_marked(&format!("P2/sweep-20k-rows/{label}"), |_, region| {
                s.x.copy_from_slice(&d);
                for (r, &z) in seed_z.iter().enumerate() {
                    s.active.set_z(r, z);
                }
                region.start();
                s.project_sweep()
            });
            println!(
                "    -> {:.2} M row-visits/s over {rows} rows ({label})",
                rows as f64 / stats.min() / 1e6
            );
            all.push(stats);
        }
    }

    // P3: full nearness solve.
    {
        let mut rng = Rng::new(53);
        let inst = type1_complete(ctx.scaled(260), &mut rng);
        all.push(ctx.bench("P3/nearness-n260", |_| {
            let res = Nearness::new(&inst).solve(&SolveOptions::new().violation_tol(1e-2));
            assert!(res.result.converged);
            res
        }));
    }

    // P4: dense CC solve. The first case is the historical axis
    // (ProjectOnFind + sequential sweep); the Collect-mode cases isolate
    // the runtime axis — same oracle, same constraints, only the sweep
    // executor and the oracle/sweep overlap change.
    {
        let mut rng = Rng::new(54);
        let g = paf::graph::Graph::complete(ctx.scaled(120));
        let (sg, _) = planted_signed(g, 8, 0.1, &mut rng);
        let inst = CcInstance::from_signed(&sg);
        all.push(ctx.bench("P4/cc-dense-K120", |_| {
            let res = Correlation::dense(&inst).seed(1).solve(&SolveOptions::new().max_iters(200));
            assert!(res.result.converged);
            res
        }));
        for (label, sweep, overlap) in [
            ("collect-seq", SweepStrategy::Sequential, false),
            ("sharded-t4", SweepStrategy::ShardedParallel { threads: 4 }, false),
            ("sharded-t4-overlap", SweepStrategy::ShardedParallel { threads: 4 }, true),
        ] {
            // Collect mode converges in fewer, heavier rounds than
            // ProjectOnFind; give it sweep and iteration headroom so an
            // unconverged run can't silently pollute the cross-PR JSON
            // with an incomparable timing (hence the assert).
            let opts = SolveOptions::new()
                .inner_sweeps(4)
                .max_iters(600)
                .sweep(sweep)
                .overlap(overlap);
            let mut iters = 0;
            all.push(ctx.bench(&format!("P4/cc-dense-K120/{label}"), |_| {
                let res = Correlation::dense(&inst)
                    .mode(OracleMode::Collect)
                    .seed(1)
                    .solve(&opts);
                assert!(res.result.converged, "{label} did not converge");
                iters = res.result.iterations;
                res
            }));
            println!("    -> {iters} iterations ({label})");
        }
    }

    // P7: multi-instance batching (the Session fleet axis). K
    // independent nearness instances: a sequential loop of solo solves
    // vs ONE session whose blocks share a single sharded sweep — the
    // support-disjoint planner packs rows from every instance into the
    // same shards, so the fleet parallelises even when each instance
    // alone is too small to.
    {
        let mut rng = Rng::new(57);
        let k = 4;
        let n = ctx.scaled(100);
        let instances: Vec<_> = (0..k).map(|_| type1_complete(n, &mut rng)).collect();
        let opts_for = |sweep| {
            SolveOptions::new().violation_tol(1e-4).dual_tol(1e-4).record_trace(false).sweep(sweep)
        };
        all.push(ctx.bench(&format!("P7/multi-nearness-k{k}/seq-loop"), |_| {
            let opts = opts_for(SweepStrategy::Sequential);
            let mut objectives = Vec::new();
            for inst in &instances {
                let res = Nearness::new(inst).mode(OracleMode::Collect).solve(&opts);
                assert!(res.result.converged);
                objectives.push(res.objective);
            }
            objectives
        }));
        for (label, sweep) in [
            ("sharded-t4-loop", SweepStrategy::ShardedParallel { threads: 4 }),
            ("session-batch-sharded-t4", SweepStrategy::ShardedParallel { threads: 4 }),
        ] {
            let batched = label.starts_with("session-batch");
            all.push(ctx.bench(&format!("P7/multi-nearness-k{k}/{label}"), |_| {
                let opts = opts_for(sweep);
                let mut objectives = Vec::new();
                if batched {
                    let mut session = Session::new(opts);
                    let handles: Vec<_> = instances
                        .iter()
                        .map(|inst| session.add(Nearness::new(inst).mode(OracleMode::Collect)))
                        .collect();
                    let summary = session.run();
                    assert!(summary.all_converged, "batched fleet did not converge");
                    for h in handles {
                        objectives.push(session.take_unwrap(h).objective);
                    }
                } else {
                    for inst in &instances {
                        let res = Nearness::new(inst).mode(OracleMode::Collect).solve(&opts);
                        assert!(res.result.converged);
                        objectives.push(res.objective);
                    }
                }
                objectives
            }));
        }
    }

    // P8: serving vs sequential jobs. The same 3 nearness jobs either
    // run one after another (solo loop) or flow through the serve
    // scheduler with staggered arrivals — jobs join the RUNNING fleet
    // between rounds, finished blocks compact out, and one sharded
    // sweep serves whoever is resident. Results are bit-identical
    // either way (tests/determinism.rs), so this axis isolates the
    // scheduling overhead + fleet-amortisation trade.
    {
        use paf::serve::{solve_job_solo, Job, JobBank, JobSpec, Scheduler, ServeConfig};
        let n = ctx.scaled(90);
        let jobs: Vec<Job> = (0..3)
            .map(|k| Job {
                id: k,
                name: format!("near-{k}"),
                spec: JobSpec::Nearness { n, graph_type: 1, seed: 60 + k as u64 },
                priority: 0,
                arrival_round: 2 * k, // staggered: the fleet changes mid-solve
                max_rounds: None,
                deadline_rounds: None,
                deadline_ms: None,
            })
            .collect();
        let bank = JobBank::materialize(&jobs);
        let opts =
            SolveOptions::new().violation_tol(1e-4).record_trace(false).sweep(
                SweepStrategy::ShardedParallel { threads: 4 },
            );
        all.push(ctx.bench("P8/serve-3jobs/seq-loop", |_| {
            let mut objectives = Vec::new();
            for job in &jobs {
                let out = solve_job_solo(job, bank.input(job.id), &opts).expect("solo solve");
                assert!(out.result.converged);
                objectives.push(out.objective);
            }
            objectives
        }));
        let mut rounds = 0;
        all.push(ctx.bench("P8/serve-3jobs/scheduler-cap3", |_| {
            let cfg = ServeConfig { capacity: 3, opts: opts.clone(), ..Default::default() };
            let stats =
                Scheduler::new(jobs.clone(), &bank, cfg).expect("valid serve config").run();
            assert!(stats.all_completed(), "serve fleet did not complete");
            rounds = stats.rounds;
            stats.jobs.iter().map(|j| j.objective.unwrap()).collect::<Vec<_>>()
        }));
        println!("    -> {rounds} scheduler rounds (staggered arrivals at 0/2/4)");
    }

    // P9: lazy sweep scheduling on a genuine late solver round. Drive a
    // real Collect nearness solve until one round moves <5% of the
    // coordinates, snapshot the iterate + duals, then measure one sweep
    // from that state under three regimes. Every run restores the
    // snapshot and rebuilds the executor (a fresh scheduler holds no
    // movement cursor, so its first sweep projects everything and
    // re-syncs — restoring x/z behind the tracker's back stays exact),
    // then runs `settle` unmeasured sweeps so the scheduler can arm
    // settled rows before the timed sweep:
    //   sweep/eager       — scheduler off: the timed sweep visits every row
    //   sweep/lazy        — scheduler on, same settle depth: armed rows
    //                       whose support did not move are skipped
    //   sweep/lazy-clean  — deeper settle: the no-new-movement floor,
    //                       the lazy analogue of P1/incremental-clean
    // The eager and lazy end states must stay bit-identical; only the
    // visit count may differ.
    {
        let mut rng = Rng::new(58);
        let inst = type1_complete(ctx.scaled(200), &mut rng);
        let mut s = late_round_solver(&inst);
        let rows = s.active.len();
        assert!(rows > 0, "late round left no remembered rows to sweep");
        let x_snap = s.x.clone();
        let z_snap: Vec<f64> = (0..rows).map(|r| s.active.z(r)).collect();
        let axes = [("eager", false, 2usize), ("lazy", true, 2), ("lazy-clean", true, 6)];
        let mut projected = [0usize; 3];
        let mut skipped = [0usize; 3];
        let mut x_after: Vec<Vec<f64>> = Vec::new();
        for (i, &(label, lazy, settle)) in axes.iter().enumerate() {
            all.push(ctx.bench_marked(&format!("P9/late-sweep/{label}"), |_, region| {
                // Rebuild the executor (fresh, unsynced scheduler) and
                // restore the snapshot, all outside the timed region.
                s.config.lazy_sweep = lazy;
                s.set_sweep_strategy(SweepStrategy::Sequential);
                s.x.copy_from_slice(&x_snap);
                for (r, &z) in z_snap.iter().enumerate() {
                    s.active.set_z(r, z);
                }
                for _ in 0..settle {
                    s.project_sweep();
                }
                let (rp, rs) = (s.sweep_rows_projected, s.sweep_rows_skipped);
                region.start();
                let moved = s.project_sweep();
                projected[i] = s.sweep_rows_projected - rp;
                skipped[i] = s.sweep_rows_skipped - rs;
                moved
            }));
            x_after.push(s.x.clone());
            println!(
                "    -> timed sweep visited {}/{rows} rows, skipped {} ({label})",
                projected[i], skipped[i]
            );
        }
        // The skip rule is exact: same settle depth => bit-identical x.
        assert_eq!(x_after[0], x_after[1], "lazy sweep diverged from eager (bitwise)");
        assert_eq!(projected[0], rows, "an eager sweep visits every remembered row");
        assert_eq!(skipped[0], 0, "eager sweeps never skip");
        assert_eq!(projected[1] + skipped[1], rows, "lazy visit/skip must partition the rows");
        assert!(
            projected[1] < projected[0],
            "the lazy sweep must project strictly fewer rows on a late round \
             ({} vs {})",
            projected[1],
            projected[0],
        );
        assert_eq!(projected[2] + skipped[2], rows, "lazy-clean counters must partition too");
    }

    // P5: active-set churn (insert + forget).
    {
        let mut rng = Rng::new(55);
        all.push(ctx.bench("P5/active-set-churn", |_| {
            let mut set = paf::core::active_set::ActiveSet::new();
            for round in 0..50 {
                for _ in 0..2000 {
                    let e = rng.below(10_000) as u32;
                    let a = rng.below(10_000) as u32;
                    if e != a {
                        let slot = set.insert(&Constraint::cycle(e, &[a, a ^ 1]));
                        set.set_z(slot, if rng.bernoulli(0.5) { 0.0 } else { 1.0 });
                    }
                }
                set.forget_inactive();
                let _ = round;
            }
            set.len()
        }));
    }

    // P6: native blocked min-plus APSP (L1 kernel's CPU twin).
    {
        let mut rng = Rng::new(56);
        let n = 256;
        let g = paf::graph::generators::erdos_renyi(n, 0.08, &mut rng);
        let w: Vec<f64> = (0..g.num_edges()).map(|_| rng.uniform(0.1, 2.0)).collect();
        let base = DistMatrix::from_graph(&g, &w);
        for block in [32usize, 64, 128] {
            all.push(ctx.bench(&format!("P6/fw-blocked-{block}"), |_| {
                let mut m = base.clone();
                floyd_warshall_blocked(&mut m, block);
                m
            }));
        }
    }

    // P10: serve persistence. The durable-checkpoint hot path, axis by
    // axis: capture (non-destructive, the per-job cost of a periodic
    // checkpoint round), encode (wire bytes), write (atomic temp-file +
    // rename), and load+decode (recovery). The roundtrip must stay
    // byte-stable.
    {
        use paf::serve::persist;
        let mut rng = Rng::new(59);
        let inst = type1_complete(ctx.scaled(120), &mut rng);
        let opts = SolveOptions::new().violation_tol(1e-7).record_trace(false);
        let mut session = Session::new(opts);
        let h = session.add(Nearness::new(&inst).mode(OracleMode::Collect));
        for _ in 0..5 {
            session.step();
        }
        let index = h.index();
        all.push(ctx.bench("P10/serve-persist/checkpoint-mem", |_| {
            session.checkpoint_block(index)
        }));
        let ck = session.checkpoint_block(index);
        all.push(ctx.bench("P10/serve-persist/encode", |_| {
            persist::encode_checkpoint(&ck).expect("encode")
        }));
        let bytes = persist::encode_checkpoint(&ck).expect("encode");
        println!(
            "    -> checkpoint wire size: {} bytes ({} remembered rows)",
            bytes.len(),
            ck.remembered()
        );
        let dir = std::env::temp_dir().join(format!("paf-bench-persist-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        all.push(ctx.bench("P10/serve-persist/write-atomic", |_| {
            persist::write_checkpoint_atomic(&dir, 0, &ck).expect("write")
        }));
        let path = persist::checkpoint_path(&dir, 0);
        all.push(ctx.bench("P10/serve-persist/load-decode", |_| {
            persist::load_checkpoint(&path).expect("load")
        }));
        let loaded = persist::load_checkpoint(&path).expect("load");
        assert_eq!(
            persist::encode_checkpoint(&loaded).expect("re-encode"),
            bytes,
            "persist roundtrip must be byte-stable"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    // P11: streaming ingestion. Write one sparse geometric instance to
    // disk, then time each ingest stage separately so a regression in
    // (say) the per-bucket dup resolution doesn't hide inside an
    // end-to-end number. The working-set peak from the byte ledger is
    // printed next to the CSR-build axis — the bounded-memory claim is
    // a number here, not a comment.
    {
        use paf::graph::ingest::{
            ingest_weighted, neighborhood_scope, node_coords, open_source,
            write_geometric_instance, IngestFormat, IngestOptions,
        };
        use paf::util::timer::fmt_bytes;
        let n = ctx.scaled(20_000);
        let dir =
            std::env::temp_dir().join(format!("paf-bench-ingest-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let edges = dir.join("geo.tsv");
        let coords_path = dir.join("geo.co");
        let info =
            write_geometric_instance(&edges, Some(&coords_path), n, 42).expect("generate");
        let file_bytes = std::fs::metadata(&edges).map(|m| m.len()).unwrap_or(0);
        println!(
            "    -> on-disk instance: {} nodes, {} edge records, {}",
            info.nodes,
            info.edges,
            fmt_bytes(file_bytes)
        );

        // Parse throughput alone: stream every record, build nothing.
        all.push(ctx.bench("P11/ingest/parse", |_| {
            let mut src = open_source(&edges, IngestFormat::Snap).expect("open edge list");
            let mut records = 0u64;
            while src.next_edge().expect("parse").is_some() {
                records += 1;
            }
            assert_eq!(records, info.edges as u64);
            records
        }));

        // The two-pass CSR build (parse included: this is the user-facing
        // cost of `--input`), with the ledger peak reported.
        let mut peak = 0u64;
        let mut csr = 0u64;
        all.push(ctx.bench("P11/ingest/csr-build", |_| {
            let out = ingest_weighted(&edges, IngestOptions::default()).expect("ingest");
            peak = out.stats.peak_bytes;
            csr = out.stats.csr_bytes;
            out.stats.edges
        }));
        println!(
            "    -> working-set peak {} for a {} resident CSR",
            fmt_bytes(peak),
            fmt_bytes(csr)
        );

        // Spatial restriction: the scoped oracle scan vs the full scan on
        // the same streamed iterate. The scope is a disc around the grid
        // centre covering ~10% of the area, so the axis measures what
        // geometric locality buys the separation oracle.
        let out = ingest_weighted(&edges, IngestOptions::default()).expect("ingest");
        let xy = node_coords(&coords_path, &out.ids).expect("coords");
        let g = Arc::new(out.inst.graph.clone());
        let x = out.inst.weights.clone();
        let side = (info.nodes as f64).sqrt();
        let scope =
            neighborhood_scope(&g, &xy, &[(side / 2.0, side / 2.0)], side * 0.18);
        println!(
            "    -> scope: {}/{} edges in the neighborhood",
            scope.edges_in_scope(),
            g.num_edges()
        );
        let full = MetricOracle::new(g.clone(), OracleMode::Collect);
        all.push(ctx.bench("P11/ingest/full-oracle", |_| full.scan_cycles(&x).len()));
        let mut scoped = MetricOracle::new(g.clone(), OracleMode::Collect);
        scoped.scope = Some(scope);
        all.push(
            ctx.bench("P11/ingest/neighborhood-oracle", |_| scoped.scan_cycles(&x).len()),
        );

        // Time-to-first-certificate: cold file on disk → the first
        // completed violation scan of the streamed instance. This is the
        // latency a caller pays before the solver can make its first
        // project/forget decision.
        all.push(ctx.bench("P11/ingest/time-to-first-certificate", |_| {
            let out = ingest_weighted(&edges, IngestOptions::default()).expect("ingest");
            let oracle =
                MetricOracle::new(Arc::new(out.inst.graph.clone()), OracleMode::Collect);
            oracle.scan_cycles(&out.inst.weights).len()
        }));

        let _ = std::fs::remove_dir_all(&dir);
    }

    // P12: observability overhead. One Collect nearness solve (a real
    // multi-round trajectory with late low-movement rounds, so the span
    // volume matches production) under three regimes: instrumentation
    // fully off, span tracing on, and per-round telemetry on. Tracing
    // and telemetry are pure observation — the solves must stay
    // bit-identical — so this axis IS the overhead story the README
    // quotes.
    {
        let mut rng = Rng::new(61);
        let inst = type1_complete(ctx.scaled(160), &mut rng);
        let opts = SolveOptions::new().violation_tol(1e-4).record_trace(false);
        let mut x_ref: Option<Vec<f64>> = None;
        for (label, spans, telemetry) in
            [("off", false, 0usize), ("spans", true, 0), ("telemetry", false, 1)]
        {
            paf::obs::set_spans_enabled(spans);
            let mut frames = 0usize;
            all.push(ctx.bench(&format!("P12/obs/{label}"), |_| {
                let res = Nearness::new(&inst)
                    .mode(OracleMode::Collect)
                    .solve(&opts.clone().telemetry_every(telemetry));
                assert!(res.result.converged, "obs/{label} did not converge");
                frames = res.result.telemetry.len();
                match &x_ref {
                    None => x_ref = Some(res.result.x.clone()),
                    Some(want) => assert_eq!(
                        want, &res.result.x,
                        "obs/{label}: instrumentation perturbed the iterates"
                    ),
                }
                res
            }));
            if telemetry > 0 {
                println!("    -> {frames} telemetry frames sampled ({label})");
            }
        }
        let spans: usize =
            paf::obs::snapshot_threads().iter().map(|t| t.spans.len()).sum();
        println!("    -> {spans} spans recorded during the obs/spans runs");
        // Back to the env-driven default for anything after this bench.
        paf::obs::set_spans_enabled(
            std::env::var("PAF_TRACE").map(|v| !v.is_empty() && v != "0").unwrap_or(false),
        );
    }

    // P13: fleet serving. The same 4-job trace through one supervised
    // shard, through three (placement + cross-thread coordination), and
    // through three with shard 0 killed mid-service (checkpoint
    // migration hand-off). Results are bit-identical on every route
    // (tests/serve_fleet.rs), so the axes isolate supervision overhead
    // and the cost of a migration.
    {
        use paf::serve::{run_fleet, FleetConfig, Job, JobSpec, ServeConfig};
        let n = ctx.scaled(70);
        let jobs: Vec<Job> = (0..4)
            .map(|k| Job {
                id: k,
                name: format!("fleet-{k}"),
                spec: JobSpec::Nearness { n, graph_type: 1, seed: 70 + k as u64 },
                priority: 0,
                arrival_round: 0,
                max_rounds: None,
                deadline_rounds: None,
                deadline_ms: None,
            })
            .collect();
        let opts = SolveOptions::new()
            .violation_tol(1e-4)
            .record_trace(false)
            .inner_sweeps(2)
            .sweep(SweepStrategy::ShardedParallel { threads: 4 });
        let shard = ServeConfig {
            capacity: 2,
            opts,
            checkpoint_every: Some(1),
            ..ServeConfig::default()
        };
        let mut migrations = 0usize;
        for (label, shards, kill) in
            [("1shard", 1usize, None), ("3shard", 3, None), ("migration-handoff", 3, Some((0, 2)))]
        {
            let dir = std::env::temp_dir()
                .join(format!("paf-bench-fleet-{}-{label}", std::process::id()));
            all.push(ctx.bench(&format!("P13/serve-fleet/{label}"), |_| {
                let _ = std::fs::remove_dir_all(&dir);
                let cfg = FleetConfig {
                    shards,
                    shard: shard.clone(),
                    state_dir: Some(dir.clone()),
                    fault_plan: paf::serve::FaultPlan { kill_shard: kill, ..Default::default() },
                    ..FleetConfig::default()
                };
                let stats = run_fleet(jobs.clone(), None, cfg, |_| {}).expect("fleet bench run");
                assert!(stats.drained, "fleet/{label} did not drain");
                assert!(stats.all_completed(), "fleet/{label} left jobs unfinished");
                if kill.is_some() {
                    migrations = stats.migrations;
                }
                stats.jobs.iter().map(|j| j.migrations).sum::<usize>()
            }));
            let _ = std::fs::remove_dir_all(&dir);
        }
        println!("    -> {migrations} jobs migrated off the killed shard (migration-handoff)");
    }

    if let Err(e) = ctx.write_json("perf_hotpath", &all) {
        eprintln!("could not write BENCH_perf_hotpath.json: {e}");
    }
    // Refresh the committed trajectory snapshot at the repo root
    // (cargo runs benches with cwd = the package root, so ".." is the
    // workspace root): `PAF_BENCH_COMMIT_ROOT=1 cargo bench --bench
    // perf_hotpath`, then commit the rewritten file.
    if std::env::var("PAF_BENCH_COMMIT_ROOT").ok().as_deref() == Some("1") {
        let mut root = ctx.clone();
        root.report_dir = "..".into();
        if let Err(e) = root.write_json("perf_hotpath", &all) {
            eprintln!("could not write the root BENCH_perf_hotpath.json: {e}");
        }
    }
}

/// Drive a Collect nearness solve round by round until one round moves
/// <5% of the coordinates (or the round budget runs out), returning the
/// iterates before and after that round plus the moved-coordinate count
/// — a *genuine* late-solve oracle transition for the P1 incremental
/// axes, with movement concentrated exactly where real sweeps put it.
fn late_round_pair(
    g: &Arc<paf::graph::Graph>,
    d: Vec<f64>,
) -> (Vec<f64>, Vec<f64>, usize) {
    let m = g.num_edges();
    let cfg = SolverConfig {
        inner_sweeps: 1,
        violation_tol: 1e-7,
        dual_tol: 1e-7,
        record_trace: false,
        ..Default::default()
    };
    let mut s = Solver::new(DiagonalQuadratic::unweighted(d), cfg);
    let mut oracle = MetricOracle::new(g.clone(), OracleMode::Collect);
    let mut prev = s.x.clone();
    for _ in 0..60 {
        let out = s.separate_with(&mut oracle);
        s.sweep_phase();
        let moved = s.x.iter().zip(&prev).filter(|(a, b)| a != b).count();
        if moved > 0 && moved * 20 < m {
            return (prev, s.x.clone(), moved);
        }
        prev.copy_from_slice(&s.x);
        if out.max_violation == 0.0 {
            break;
        }
    }
    // Converged (or budget ran out) without a <5% round: the final
    // repeat-scan is then the cleanest possible "late round".
    let last = s.x.clone();
    let moved = last.iter().zip(&prev).filter(|(a, b)| a != b).count();
    (prev, last, moved)
}

/// Like [`late_round_pair`], but for the P9 sweep axes: drive the solve
/// to the same <5%-movement regime and hand back the live solver — the
/// remembered active set, iterate and duals of a genuine late round.
fn late_round_solver(
    inst: &paf::graph::generators::WeightedInstance,
) -> Solver<DiagonalQuadratic> {
    let m = inst.graph.num_edges();
    let cfg = SolverConfig {
        inner_sweeps: 1,
        violation_tol: 1e-7,
        dual_tol: 1e-7,
        record_trace: false,
        ..Default::default()
    };
    let mut s = Solver::new(DiagonalQuadratic::unweighted(inst.weights.clone()), cfg);
    let mut oracle = MetricOracle::new(Arc::new(inst.graph.clone()), OracleMode::Collect);
    let mut prev = s.x.clone();
    for _ in 0..60 {
        let out = s.separate_with(&mut oracle);
        s.sweep_phase();
        let moved = s.x.iter().zip(&prev).filter(|(a, b)| a != b).count();
        if (moved > 0 && moved * 20 < m) || out.max_violation == 0.0 {
            break;
        }
        prev.copy_from_slice(&s.x);
    }
    s
}
