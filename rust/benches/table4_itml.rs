//! Table 4 — ITML test accuracy: PFITML (full implicit program via the
//! random oracle) vs original ITML (once-sampled 20c² constraints), both
//! capped at the same projection budget, kNN evaluation (§8.3 protocol).
//!
//! Datasets are synthetic stand-ins matched in (n, d, #classes) to the
//! paper's KEEL/UCI suite (offline; see DESIGN.md §substitutions). The
//! shape to reproduce: comparable accuracy overall, ours ahead more often
//! than behind.

use paf::baselines::itml_orig::{solve_itml_orig, ItmlOrigConfig};
use paf::ml::dataset::table4_dataset;
use paf::ml::knn::knn_accuracy;
use paf::ml::mahalanobis::Mat;
use paf::core::problem::SolveOptions;
use paf::problems::itml::{PfItml, PfItmlConfig};
use paf::util::benchkit::BenchCtx;
use paf::util::table::Table;
use paf::util::Rng;

fn main() {
    let ctx = BenchCtx::from_env();
    let budget = std::env::var("PAF_T4_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or((50_000.0 * ctx.scale) as usize)
        .max(1000);
    let datasets =
        ["banana", "ionosphere", "coil2000", "letter", "penbased", "spambase", "texture"];
    let mut table = Table::new(
        "Table 4 — ITML accuracy (ours vs Davis et al.)",
        &["dataset", "ours", "itml", "euclidean", "ours_active_pairs"],
    );
    let mut wins = 0usize;
    let mut ties = 0usize;
    for name in datasets {
        let mut rng = Rng::new(17);
        let data = table4_dataset(name, &mut rng);
        let (mut train, mut test) = data.split(0.8, &mut rng);
        let (mean, std) = train.normalize();
        test.apply_transform(&mean, &std);
        let k = 4;
        let (_, pf) = ctx.bench_once(&format!("pf-itml/{name}"), || {
            PfItml::new(
                &train,
                PfItmlConfig { max_projections: budget, seed: 17, ..Default::default() },
            )
            .solve(&SolveOptions::default())
        });
        let (_, orig) = ctx.bench_once(&format!("itml/{name}"), || {
            solve_itml_orig(
                &train,
                &ItmlOrigConfig { max_projections: budget, seed: 17, ..Default::default() },
            )
        });
        let acc_pf = knn_accuracy(&pf.m, &train, &test, k);
        let acc_orig = knn_accuracy(&orig.m, &train, &test, k);
        let acc_euc = knn_accuracy(&Mat::identity(train.d), &train, &test, k);
        if acc_pf > acc_orig + 1e-9 {
            wins += 1;
        } else if (acc_pf - acc_orig).abs() <= 1e-9 {
            ties += 1;
        }
        table.rowd(&[
            name.to_string(),
            format!("{acc_pf:.5}"),
            format!("{acc_orig:.5}"),
            format!("{acc_euc:.5}"),
            pf.active_pairs.to_string(),
        ]);
    }
    table.emit(&ctx.report_dir, "table4_itml");
    println!("ours better on {wins}/7, tied on {ties}/7 (paper: 4 wins, 1 tie)");
}
