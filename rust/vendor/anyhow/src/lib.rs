//! Offline stand-in for the `anyhow` crate, in the spirit of the main
//! crate's `util` substrate (no network, no proc macros). Implements the
//! subset this workspace uses: [`Error`], [`Result`], [`anyhow!`],
//! [`ensure!`], and `?`-conversion from any `std::error::Error`.
//!
//! The one intentional parallel with the real crate: [`Error`] does NOT
//! implement `std::error::Error` itself, which is what keeps the blanket
//! `From<E: std::error::Error>` impl coherent.

use std::fmt;

/// A boxed dynamic error with a display-oriented `Debug` (so
/// `fn main() -> anyhow::Result<()>` prints the message, not the
/// struct).
pub struct Error {
    inner: Box<dyn std::error::Error + Send + Sync + 'static>,
}

impl Error {
    /// Construct from a plain message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { inner: Box::new(Message(message.to_string())) }
    }

    /// Borrow the underlying error.
    pub fn as_dyn(&self) -> &(dyn std::error::Error + Send + Sync + 'static) {
        &*self.inner
    }
}

struct Message(String);

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Message {}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        while let Some(s) = source {
            write!(f, "\n\nCaused by:\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { inner: Box::new(e) }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] when `$cond` is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Return early with a formatted [`Error`] unconditionally.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?; // From<ParseIntError>
        ensure!(n >= 0, "negative: {n}");
        Ok(n)
    }

    #[test]
    fn conversions_and_macros() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
        let e = parse("-1").unwrap_err();
        assert_eq!(e.to_string(), "negative: -1");
        let io: Error = std::io::Error::other("boom").into();
        assert_eq!(io.to_string(), "boom");
        assert_eq!(format!("{:?}", anyhow!("a {}", 1)), "a 1");
    }
}
