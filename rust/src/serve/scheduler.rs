//! The long-running service loop: one [`Session`] fleet, driven
//! round-by-round while a job queue feeds it.
//!
//! Each scheduler round is: (1) **arrivals** — jobs whose
//! `arrival_round` has come move into the ready queue; (2)
//! **preemption** — while capacity is full and a strictly
//! higher-priority job waits, the lowest-priority running job is
//! checkpointed ([`Session::evict`]) and requeued; (3) **admission** —
//! ready jobs fill free capacity in priority order, fresh jobs through
//! [`Session::admit`], preempted ones through
//! [`Session::admit_resumed`]; (4) one [`Session::step`] advances every
//! running job by one PROJECT AND FORGET round — the fleet shares a
//! single (optionally sharded) sweep, which is the point: sweep
//! throughput is the scarce resource (Ruggles et al., 1901.10084), so
//! the server amortizes one sweep across a *changing* fleet instead of
//! solving jobs one at a time; (5) **completions** — finished blocks
//! are redeemed, their stats recorded, and their coordinate ranges
//! compacted out of the concatenated vector.
//!
//! Every admission, preemption and resumption happens between rounds,
//! where the solve state is a post-FORGET snapshot, so each job's
//! trajectory is bit-identical to its solo `Session::solve_one` run
//! (pinned in `tests/determinism.rs`).

use super::admission::{admit_job, resume_job, take_job, JobBank, JobHandle};
use super::queue::{Job, JobQueue, JobSpec};
use crate::core::problem::SolveOptions;
use crate::core::session::{BlockCheckpoint, Session};
use crate::core::solver::{PhaseTimes, SolverResult};

/// Scheduler knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum concurrently running jobs (fleet size).
    pub capacity: usize,
    /// Shared solve options. Mixed-kind traces must pin
    /// `inner_sweeps` explicitly (all blocks of one session agree on it).
    pub opts: SolveOptions,
    /// Global safety valve on scheduler rounds.
    pub max_service_rounds: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            capacity: 4,
            opts: SolveOptions::new(),
            max_service_rounds: 100_000,
        }
    }
}

/// The scheduler's event stream (also recorded in [`ServeStats`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServeEvent {
    /// A job entered the running fleet (`resumed` = from a preemption
    /// checkpoint).
    Admitted { round: usize, job: usize, resumed: bool },
    /// A running job was checkpointed and requeued to make room for a
    /// higher-priority arrival.
    Preempted { round: usize, job: usize, rounds_done: usize },
    /// A job reached its stop rule; its output is redeemed.
    Completed { round: usize, job: usize, converged: bool },
    /// A job exceeded its own `max_rounds` budget and was dropped.
    Expired { round: usize, job: usize, rounds_done: usize },
    /// No job was runnable this round (waiting on future arrivals).
    Idle { round: usize },
}

/// Per-job service record.
#[derive(Debug, Clone)]
pub struct JobStats {
    pub name: String,
    pub kind: &'static str,
    pub priority: i64,
    pub arrival_round: usize,
    /// First admission round.
    pub admitted_round: Option<usize>,
    pub completed_round: Option<usize>,
    pub preemptions: usize,
    /// Solve rounds actually run (preempted waiting time excluded).
    pub rounds_run: usize,
    pub projections: usize,
    pub converged: bool,
    /// Dropped after exceeding its `max_rounds` budget.
    pub expired: bool,
    /// `completed_round − arrival_round ≤ deadline_rounds`, when a
    /// deadline was set and the job completed.
    pub deadline_met: Option<bool>,
    pub objective: Option<f64>,
    /// Accumulated per-phase timings of the job's own rounds.
    pub phases: PhaseTimes,
    /// The full per-job result (bit-comparable to a solo solve).
    pub result: Option<SolverResult>,
}

/// What a serve run did, per job and overall.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Scheduler rounds driven (includes idle rounds).
    pub rounds: usize,
    pub completed: usize,
    pub preemptions: usize,
    pub expired: usize,
    pub jobs: Vec<JobStats>,
    pub events: Vec<ServeEvent>,
}

impl ServeStats {
    /// Every job completed (none expired or left unfinished).
    pub fn all_completed(&self) -> bool {
        self.completed == self.jobs.len()
    }
}

struct Running {
    job: usize,
    handle: JobHandle,
    /// Scheduler round of this (re-)admission.
    admitted_at: usize,
    /// Solve rounds the job had already run when (re-)admitted.
    base_rounds: usize,
}

/// The long-running scheduler over one [`Session`] fleet.
pub struct Scheduler<'a> {
    cfg: ServeConfig,
    session: Session<'a>,
    bank: &'a JobBank,
    jobs: Vec<Job>,
    /// Job ids sorted by `arrival_round` (stable), consumed in order.
    arrivals: Vec<usize>,
    next_arrival: usize,
    ready: JobQueue,
    running: Vec<Running>,
    checkpoints: Vec<Option<BlockCheckpoint>>,
    stats: ServeStats,
    round: usize,
    observers: Vec<Box<dyn FnMut(&ServeEvent) + 'a>>,
}

impl<'a> Scheduler<'a> {
    /// Build a scheduler over a trace. `bank` must be the materialized
    /// inputs of exactly these jobs ([`JobBank::materialize`]).
    pub fn new(jobs: Vec<Job>, bank: &'a JobBank, cfg: ServeConfig) -> Scheduler<'a> {
        assert!(cfg.capacity >= 1, "serve capacity must be at least 1");
        assert_eq!(jobs.len(), bank.len(), "job trace and bank are misaligned");
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i, "job ids must be positional (job {} has id {})", i, j.id);
        }
        let mixed = jobs
            .windows(2)
            .any(|w| std::mem::discriminant(&w[0].spec) != std::mem::discriminant(&w[1].spec));
        assert!(
            !mixed || cfg.opts.inner_sweeps.is_some(),
            "mixed-kind job traces must pin SolveOptions::inner_sweeps (all blocks of one \
             session agree on it; nearness defaults to 1, dense CC to 2)"
        );
        assert!(
            !cfg.opts.overlap,
            "the serve scheduler requires a non-overlapped session (admission and \
             preemption are multi-block operations)"
        );
        let mut arrivals: Vec<usize> = (0..jobs.len()).collect();
        arrivals.sort_by_key(|&j| jobs[j].arrival_round);
        let stats = ServeStats {
            rounds: 0,
            completed: 0,
            preemptions: 0,
            expired: 0,
            jobs: jobs
                .iter()
                .map(|j| JobStats {
                    name: j.name.clone(),
                    kind: j.spec.kind(),
                    priority: j.priority,
                    arrival_round: j.arrival_round,
                    admitted_round: None,
                    completed_round: None,
                    preemptions: 0,
                    rounds_run: 0,
                    projections: 0,
                    converged: false,
                    expired: false,
                    deadline_met: None,
                    objective: None,
                    phases: PhaseTimes::default(),
                    result: None,
                })
                .collect(),
            events: Vec::new(),
        };
        let checkpoints = (0..jobs.len()).map(|_| None).collect();
        Scheduler {
            session: Session::new(cfg.opts.clone()),
            cfg,
            bank,
            jobs,
            arrivals,
            next_arrival: 0,
            ready: JobQueue::new(),
            running: Vec::new(),
            checkpoints,
            stats,
            round: 0,
            observers: Vec::new(),
        }
    }

    /// Observe scheduler events as they happen.
    pub fn on_event(&mut self, observer: impl FnMut(&ServeEvent) + 'a) {
        self.observers.push(Box::new(observer));
    }

    fn emit(&mut self, event: ServeEvent) {
        for obs in &mut self.observers {
            obs(&event);
        }
        self.stats.events.push(event);
    }

    /// The running job to preempt: lowest priority; ties prefer the most
    /// recently admitted (its warm state is smallest), then the highest
    /// block index — fully deterministic.
    fn pick_victim(&self) -> Option<usize> {
        (0..self.running.len()).min_by_key(|&i| {
            let r = &self.running[i];
            (
                self.jobs[r.job].priority,
                std::cmp::Reverse(r.admitted_at),
                std::cmp::Reverse(r.handle.index()),
            )
        })
    }

    fn preempt(&mut self, vi: usize) {
        let victim = self.running.remove(vi);
        let ck = self.session.evict(victim.handle.index());
        let rounds_done = ck.iterations();
        let job = victim.job;
        self.stats.jobs[job].preemptions += 1;
        self.stats.jobs[job].rounds_run = rounds_done;
        self.stats.jobs[job].projections = ck.projections();
        self.stats.preemptions += 1;
        self.checkpoints[job] = Some(ck);
        self.ready.push(job, self.jobs[job].priority);
        self.emit(ServeEvent::Preempted { round: self.round, job, rounds_done });
    }

    fn admit(&mut self, job: usize) {
        let ck = self.checkpoints[job].take();
        let resumed = ck.is_some();
        let handle = match ck {
            Some(ck) => resume_job(&mut self.session, &self.jobs[job], self.bank.input(job), &ck),
            None => admit_job(&mut self.session, &self.jobs[job], self.bank.input(job)),
        };
        let base_rounds = self.stats.jobs[job].rounds_run;
        if self.stats.jobs[job].admitted_round.is_none() {
            self.stats.jobs[job].admitted_round = Some(self.round);
        }
        self.running.push(Running { job, handle, admitted_at: self.round, base_rounds });
        self.emit(ServeEvent::Admitted { round: self.round, job, resumed });
    }

    /// Drive the trace to completion (all jobs completed or expired, all
    /// arrivals consumed) and return the service record.
    pub fn run(mut self) -> ServeStats {
        loop {
            // 1. Arrivals.
            while self.next_arrival < self.arrivals.len()
                && self.jobs[self.arrivals[self.next_arrival]].arrival_round <= self.round
            {
                let job = self.arrivals[self.next_arrival];
                self.next_arrival += 1;
                self.ready.push(job, self.jobs[job].priority);
            }

            // 2+3. Preemption and admission, interleaved until stable:
            // admit into free capacity; when full, preempt only if the
            // best waiting job has strictly higher priority than the
            // victim. Each preempt+admit pair strictly raises the
            // running fleet's priority multiset, so this terminates.
            loop {
                if self.running.len() < self.cfg.capacity {
                    match self.ready.pop() {
                        Some(job) => {
                            self.admit(job);
                            continue;
                        }
                        None => break,
                    }
                }
                let Some(best) = self.ready.peek_priority() else { break };
                match self.pick_victim() {
                    Some(vi) if best > self.jobs[self.running[vi].job].priority => {
                        self.preempt(vi)
                    }
                    _ => break,
                }
            }

            // 4. One fleet round (or an idle round while waiting).
            if self.running.is_empty() {
                if self.ready.is_empty() && self.next_arrival == self.arrivals.len() {
                    break;
                }
                self.emit(ServeEvent::Idle { round: self.round });
                self.round += 1;
                if self.round >= self.cfg.max_service_rounds {
                    break;
                }
                continue;
            }
            self.session.step();
            self.round += 1;

            // 5. Completions, then per-job round budgets.
            let mut i = 0;
            while i < self.running.len() {
                let (job, handle, base_rounds, admitted_at) = {
                    let r = &self.running[i];
                    (r.job, r.handle, r.base_rounds, r.admitted_at)
                };
                if self.session.block_done(handle.index()) {
                    let outcome = take_job(&mut self.session, handle)
                        .expect("finished block lost its output");
                    let deadline_met = self.jobs[job]
                        .deadline_rounds
                        .map(|d| self.round - self.jobs[job].arrival_round <= d);
                    let converged = outcome.result.converged;
                    let s = &mut self.stats.jobs[job];
                    s.completed_round = Some(self.round);
                    s.rounds_run = outcome.result.iterations;
                    s.projections = outcome.result.total_projections;
                    s.converged = converged;
                    s.objective = Some(outcome.objective);
                    s.phases = outcome.result.phases;
                    s.deadline_met = deadline_met;
                    s.result = Some(outcome.result);
                    self.stats.completed += 1;
                    self.running.remove(i);
                    self.emit(ServeEvent::Completed { round: self.round, job, converged });
                    continue;
                }
                let rounds_done = base_rounds + (self.round - admitted_at);
                if self.jobs[job].max_rounds.is_some_and(|m| rounds_done >= m) {
                    self.running.remove(i);
                    let ck = self.session.evict(handle.index());
                    let s = &mut self.stats.jobs[job];
                    s.rounds_run = ck.iterations();
                    s.projections = ck.projections();
                    s.expired = true;
                    self.stats.expired += 1;
                    self.emit(ServeEvent::Expired {
                        round: self.round,
                        job,
                        rounds_done: ck.iterations(),
                    });
                    continue;
                }
                i += 1;
            }
            // Reclaim finished blocks' coordinate ranges so the
            // concatenated vector stays bounded by the *running* fleet.
            self.session.compact_finished();

            if self.round >= self.cfg.max_service_rounds {
                break;
            }
        }
        self.stats.rounds = self.round;
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::JobBank;

    #[test]
    fn job_round_budget_expires() {
        // An unreachable tolerance with a 3-round budget: the scheduler
        // must evict + expire the job instead of spinning forever.
        let jobs = vec![Job {
            id: 0,
            name: "hopeless".to_string(),
            spec: JobSpec::Nearness { n: 14, graph_type: 1, seed: 5 },
            priority: 0,
            arrival_round: 0,
            max_rounds: Some(3),
            deadline_rounds: Some(1),
        }];
        let bank = JobBank::materialize(&jobs);
        let opts = SolveOptions::new().violation_tol(1e-14).dual_tol(1e-14).max_iters(10_000);
        let cfg = ServeConfig { capacity: 1, opts, ..Default::default() };
        let stats = Scheduler::new(jobs, &bank, cfg).run();
        assert_eq!(stats.expired, 1);
        assert_eq!(stats.completed, 0);
        assert!(!stats.jobs[0].converged);
        assert!(stats.jobs[0].expired);
        assert_eq!(stats.jobs[0].rounds_run, 3);
        assert!(stats.jobs[0].projections > 0, "expiry stats come from the checkpoint");
        assert!(stats.events.iter().any(|e| matches!(e, ServeEvent::Expired { .. })));
    }

    #[test]
    fn idle_rounds_bridge_arrival_gaps() {
        // A single job arriving at round 5: the scheduler idles up to it,
        // then completes it.
        let jobs = vec![Job {
            id: 0,
            name: "late".to_string(),
            spec: JobSpec::Nearness { n: 10, graph_type: 1, seed: 3 },
            priority: 0,
            arrival_round: 5,
            max_rounds: None,
            deadline_rounds: None,
        }];
        let bank = JobBank::materialize(&jobs);
        let cfg = ServeConfig {
            capacity: 2,
            opts: SolveOptions::new().violation_tol(1e-4),
            ..Default::default()
        };
        let stats = Scheduler::new(jobs, &bank, cfg).run();
        assert!(stats.all_completed());
        assert_eq!(
            stats.events.iter().filter(|e| matches!(e, ServeEvent::Idle { .. })).count(),
            5,
            "rounds 0..5 must idle"
        );
        assert_eq!(stats.jobs[0].admitted_round, Some(5));
    }
}

/// Generate the demo/example trace: a mixed nearness + CC workload with
/// staggered arrivals, a priority spread, and one forced preemption (a
/// high-priority CC job arrives while capacity is saturated by
/// low-priority nearness jobs). Deterministic in `seed`.
pub fn demo_trace(seed: u64) -> Vec<Job> {
    vec![
        Job {
            id: 0,
            name: "near-low".to_string(),
            spec: JobSpec::Nearness { n: 26, graph_type: 1, seed },
            priority: 0,
            arrival_round: 0,
            max_rounds: None,
            deadline_rounds: Some(400),
        },
        Job {
            id: 1,
            name: "near-mid".to_string(),
            spec: JobSpec::Nearness { n: 22, graph_type: 2, seed: seed + 1 },
            priority: 1,
            arrival_round: 1,
            max_rounds: None,
            deadline_rounds: None,
        },
        Job {
            id: 2,
            name: "cc-urgent".to_string(),
            spec: JobSpec::Correlation { n: 16, clusters: 3, flip: 0.1, seed: seed + 2 },
            priority: 9,
            arrival_round: 3,
            max_rounds: Some(600),
            deadline_rounds: Some(300),
        },
    ]
}
