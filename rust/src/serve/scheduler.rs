//! The long-running service loop: one [`Session`] fleet, driven
//! round-by-round while a job queue feeds it.
//!
//! Each scheduler round is: (1) **arrivals** — jobs whose
//! `arrival_round` has come move into the ready queue, and parked
//! retries whose backoff elapsed rejoin it; (2) **shedding** — under
//! overload (queue depth over `queue_high_water`) the lowest-priority
//! pending jobs are dropped with an explicit [`ServeEvent::Shed`]
//! rather than degrading everyone; (3) **preemption/admission** — while
//! capacity is full and a strictly higher-*effective*-priority job
//! waits, the lowest-priority running job is checkpointed
//! ([`Session::evict`]) and requeued; ready jobs then fill free
//! capacity in effective-priority order (priority plus aging credit, so
//! no job starves), fresh jobs through [`Session::admit`], preempted or
//! recovered ones through [`Session::admit_resumed`]; (4) one
//! [`Session::step`] advances every running job by one PROJECT AND
//! FORGET round — the fleet shares a single (optionally sharded) sweep,
//! which is the point: sweep throughput is the scarce resource (Ruggles
//! et al., 1901.10084), so the server amortizes one sweep across a
//! *changing* fleet instead of solving jobs one at a time; (5)
//! **completions and deadlines** — finished blocks are redeemed, jobs
//! past their `max_rounds` budget, `deadline_rounds`, or wall-clock
//! `deadline_ms` are evicted and marked `Expired`, and finished
//! coordinate ranges are compacted out of the concatenated vector.
//!
//! Every admission, preemption and resumption happens between rounds,
//! where the solve state is a post-FORGET snapshot, so each job's
//! trajectory is bit-identical to its solo `Session::solve_one` run
//! (pinned in `tests/determinism.rs`).
//!
//! ## Fault tolerance
//!
//! With a `state_dir`, every preemption (and every `checkpoint_every`
//! rounds) also writes the job's [`BlockCheckpoint`] durably
//! ([`super::persist`], atomic temp-file + rename); on startup the
//! scheduler recovers incomplete jobs from the state dir and resumes
//! them bit-identically across the process boundary. Corrupt files are
//! quarantined to `state_dir/corrupt/` and the job restarts from
//! scratch. A job that fails admission (e.g. a poisoned spec) is
//! quarantined and retried with exponential round-backoff up to
//! `retry_limit` while the fleet keeps stepping; the injected-fault
//! seams ([`FaultPlan`]) make every one of these paths deterministic
//! under test.

use super::admission::{admit_job, resume_job, take_job, JobBank, JobHandle};
use super::persist::{self, FaultPlan};
use super::queue::{Job, JobQueue, JobSpec};
use super::ServeError;
use crate::core::problem::SolveOptions;
use crate::core::session::{BlockCheckpoint, Session};
use crate::core::solver::{PhaseTimes, SolverResult};
use std::path::PathBuf;
use std::time::Instant;

/// Scheduler knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum concurrently running jobs (fleet size).
    pub capacity: usize,
    /// Shared solve options. Mixed-kind traces must pin
    /// `inner_sweeps` explicitly (all blocks of one session agree on it).
    pub opts: SolveOptions,
    /// Global safety valve on scheduler rounds.
    pub max_service_rounds: usize,
    /// Durable-checkpoint directory. `None` keeps checkpoints in memory
    /// only (a crash loses all progress).
    pub state_dir: Option<PathBuf>,
    /// Also persist every running job's checkpoint every N rounds (not
    /// just at preemptions), bounding crash-loss to N rounds of work.
    pub checkpoint_every: Option<usize>,
    /// Admission-failure retries before a job is permanently failed.
    pub retry_limit: usize,
    /// Shed the lowest-priority pending jobs while the ready queue is
    /// deeper than this. `None` never sheds.
    pub queue_high_water: Option<usize>,
    /// Priority aging: a waiting job gains one effective priority level
    /// per this many queued rounds (0 disables aging). The admitted job
    /// *keeps* its aged priority (priority inheritance), so it cannot be
    /// preempted right back by the next arrival of its original level.
    pub age_rounds: usize,
    /// Deterministic fault injection (tests and the hidden
    /// `--fault-plan` flag); empty in production.
    pub fault_plan: FaultPlan,
    /// Live metrics: every N scheduler rounds, write one NDJSON
    /// snapshot (queue depth, running/completed/shed/failed/recovered
    /// counters, rounds/sec, per-job progress) to the sink installed
    /// with [`Scheduler::metrics_to`], or stderr by default. 0 = off.
    pub metrics_every: usize,
    /// Cooperative pause: when the flag is set (by another thread, e.g.
    /// a fleet supervisor), the scheduler finishes the current round,
    /// persists every running job's checkpoint (with a `state_dir`),
    /// and returns with [`ServeStats::paused`] set. Unlike a crash the
    /// run is resumable: a new scheduler over the same state dir picks
    /// up bit-identically.
    pub pause: Option<std::sync::Arc<std::sync::atomic::AtomicBool>>,
    /// Liveness heartbeat: when set, the scheduler stamps the obs-clock
    /// microsecond time ([`now_us`](crate::obs::clock::now_us)) into
    /// this atomic at fine granularity — every round boundary, every
    /// admission, every recovered checkpoint, and after every session
    /// step — not just once per round, so a supervisor's staleness
    /// check cannot false-positive on a single long phase. `None` (the
    /// default) in standalone serving.
    pub heartbeat: Option<std::sync::Arc<std::sync::atomic::AtomicU64>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            capacity: 4,
            opts: SolveOptions::new(),
            max_service_rounds: 100_000,
            state_dir: None,
            checkpoint_every: None,
            retry_limit: 2,
            queue_high_water: None,
            age_rounds: 0,
            fault_plan: FaultPlan::default(),
            metrics_every: 0,
            pause: None,
            heartbeat: None,
        }
    }
}

/// The scheduler's event stream (also recorded in [`ServeStats`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServeEvent {
    /// A job entered the running fleet (`resumed` = from a preemption
    /// or recovery checkpoint).
    Admitted { round: usize, job: usize, resumed: bool },
    /// A running job was checkpointed and requeued to make room for a
    /// higher-priority arrival.
    Preempted { round: usize, job: usize, rounds_done: usize },
    /// A job reached its stop rule; its output is redeemed.
    Completed { round: usize, job: usize, converged: bool },
    /// A job exceeded its `max_rounds` budget, `deadline_rounds`, or
    /// wall-clock `deadline_ms` and was dropped.
    Expired { round: usize, job: usize, rounds_done: usize },
    /// No job was runnable this round (waiting on future arrivals).
    Idle { round: usize },
    /// A durable checkpoint from a previous process was loaded at
    /// startup; the job resumes from `rounds_done`.
    Recovered { round: usize, job: usize, rounds_done: usize },
    /// Overload: a pending job was dropped to protect the rest
    /// (`queue_depth` = ready jobs left after the drop).
    Shed { round: usize, job: usize, queue_depth: usize },
    /// A quarantined job's backoff elapsed; attempt `attempt` rejoins
    /// the ready queue.
    Retried { round: usize, job: usize, attempt: usize },
    /// A job failed admission (attempt `attempt`); it is parked with
    /// exponential backoff, or permanently failed past `retry_limit`.
    Quarantined { round: usize, job: usize, attempt: usize },
}

/// A [`ServeEvent`] as recorded in [`ServeStats::events`]: stamped with
/// the scheduler round it was emitted in and a run-wide monotonic
/// sequence number, so filtered or merged event streams can always be
/// restored to exact emission order. Observers still receive the bare
/// [`ServeEvent`] as it happens.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeLogEntry {
    /// Strictly increasing across the whole run, starting at 0.
    pub seq: u64,
    /// Scheduler round at emission (equals the `round` the payload
    /// carries).
    pub round: usize,
    pub event: ServeEvent,
}

/// Per-job service record.
#[derive(Debug, Clone)]
pub struct JobStats {
    pub name: String,
    pub kind: &'static str,
    pub priority: i64,
    pub arrival_round: usize,
    /// First admission round.
    pub admitted_round: Option<usize>,
    pub completed_round: Option<usize>,
    pub preemptions: usize,
    /// Solve rounds actually run (preempted waiting time excluded).
    pub rounds_run: usize,
    pub projections: usize,
    pub converged: bool,
    /// Dropped after exceeding its `max_rounds` budget or a deadline.
    pub expired: bool,
    /// Deadline outcome: `Some(true)` iff the job completed within every
    /// deadline it declared; `Some(false)` for any job that expired, was
    /// shed, or permanently failed (never `null` for those); `None` only
    /// for a job with no deadlines that wasn't dropped.
    pub deadline_met: Option<bool>,
    pub objective: Option<f64>,
    /// Accumulated per-phase timings of the job's own rounds.
    pub phases: PhaseTimes,
    /// The full per-job result (bit-comparable to a solo solve).
    pub result: Option<SolverResult>,
    /// Dropped by overload shedding before ever being admitted.
    pub shed: bool,
    /// Permanently failed admission (`retry_limit` exceeded).
    pub failed: bool,
    /// Times the job rejoined the queue after a quarantine backoff.
    pub retries: usize,
    /// Resumed from a durable checkpoint written by a previous process.
    pub recovered: bool,
    /// Last serve-layer error the job hit (admission failure, corrupt
    /// checkpoint, persist failure), if any.
    pub error: Option<String>,
}

/// What a serve run did, per job and overall.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Scheduler rounds driven (includes idle rounds).
    pub rounds: usize,
    pub completed: usize,
    pub preemptions: usize,
    pub expired: usize,
    /// Jobs resumed from durable checkpoints at startup.
    pub recovered: usize,
    /// Jobs dropped by overload shedding.
    pub shed: usize,
    /// Retry re-admissions after quarantine backoffs.
    pub retried: usize,
    /// Jobs permanently failed (admission errors past `retry_limit`).
    pub failed: usize,
    /// The run stopped on an injected crash after persisting state
    /// (the process should exit with [`persist::CRASH_EXIT_CODE`]).
    pub crashed: bool,
    /// The run stopped on a cooperative [`ServeConfig::pause`] request
    /// after persisting running state; resumable from the state dir.
    pub paused: bool,
    pub jobs: Vec<JobStats>,
    /// The full event stream, each entry stamped with its emission
    /// round and a monotonic sequence number.
    pub events: Vec<ServeLogEntry>,
}

impl ServeStats {
    /// Every job completed (none expired, shed, failed, or unfinished).
    pub fn all_completed(&self) -> bool {
        self.completed == self.jobs.len()
    }
}

struct Running {
    job: usize,
    handle: JobHandle,
    /// Scheduler round of this (re-)admission.
    admitted_at: usize,
    /// Solve rounds the job had already run when (re-)admitted.
    base_rounds: usize,
    /// Effective priority at admission (base + aging credit). Victim
    /// selection compares against this, not the base priority, so an
    /// aged job keeps the level it earned by waiting.
    prio: i64,
}

/// The long-running scheduler over one [`Session`] fleet.
pub struct Scheduler<'a> {
    cfg: ServeConfig,
    session: Session<'a>,
    bank: &'a JobBank,
    jobs: Vec<Job>,
    /// Job ids sorted by `arrival_round` (stable), consumed in order.
    arrivals: Vec<usize>,
    next_arrival: usize,
    /// Jobs already moved past the arrival gate (recovered jobs arrive
    /// early, at round 0, regardless of their trace `arrival_round`).
    arrived: Vec<bool>,
    ready: JobQueue,
    running: Vec<Running>,
    checkpoints: Vec<Option<BlockCheckpoint>>,
    /// Admission failures per job (drives backoff and `retry_limit`).
    attempts: Vec<usize>,
    /// Quarantined jobs waiting out their backoff: `(release_round, job)`.
    parked: Vec<(usize, usize)>,
    /// Wall-clock instant each job first became ready (queueing time
    /// counts against `deadline_ms`).
    ready_at: Vec<Option<Instant>>,
    stats: ServeStats,
    round: usize,
    /// Next event sequence number (stamped in [`Scheduler::emit`]).
    next_seq: u64,
    /// Wall-clock start of [`Scheduler::run`] (drives `rounds_per_sec`
    /// in metrics snapshots).
    started: Instant,
    /// Destination for `metrics_every` NDJSON snapshots; stderr when
    /// unset.
    metrics: Option<Box<dyn std::io::Write + 'a>>,
    observers: Vec<Box<dyn FnMut(&ServeEvent) + 'a>>,
    round_hooks: Vec<Box<dyn FnMut(usize) + 'a>>,
}

impl<'a> Scheduler<'a> {
    /// Build a scheduler over a trace. `bank` must be the materialized
    /// inputs of exactly these jobs ([`JobBank::materialize`]). A bad
    /// configuration is a typed, recoverable [`ServeError::Config`] —
    /// in a fleet it kills one shard admission, not the process.
    pub fn new(
        jobs: Vec<Job>,
        bank: &'a JobBank,
        cfg: ServeConfig,
    ) -> Result<Scheduler<'a>, ServeError> {
        let bad = |msg: String| ServeError::Config { msg };
        if cfg.capacity < 1 {
            return Err(bad("serve capacity must be at least 1".to_string()));
        }
        if jobs.len() != bank.len() {
            return Err(bad(format!(
                "job trace and bank are misaligned ({} jobs, {} bank inputs)",
                jobs.len(),
                bank.len()
            )));
        }
        for (i, j) in jobs.iter().enumerate() {
            if j.id != i {
                return Err(bad(format!(
                    "job ids must be positional (job {} has id {})",
                    i, j.id
                )));
            }
        }
        let mixed = jobs
            .windows(2)
            .any(|w| std::mem::discriminant(&w[0].spec) != std::mem::discriminant(&w[1].spec));
        if mixed && cfg.opts.inner_sweeps.is_none() {
            return Err(bad(
                "mixed-kind job traces must pin SolveOptions::inner_sweeps (all blocks of \
                 one session agree on it; nearness defaults to 1, dense CC to 2)"
                    .to_string(),
            ));
        }
        if cfg.opts.overlap {
            return Err(bad(
                "the serve scheduler requires a non-overlapped session (admission and \
                 preemption are multi-block operations)"
                    .to_string(),
            ));
        }
        let mut arrivals: Vec<usize> = (0..jobs.len()).collect();
        arrivals.sort_by_key(|&j| jobs[j].arrival_round);
        let stats = ServeStats {
            rounds: 0,
            completed: 0,
            preemptions: 0,
            expired: 0,
            recovered: 0,
            shed: 0,
            retried: 0,
            failed: 0,
            crashed: false,
            paused: false,
            jobs: jobs
                .iter()
                .map(|j| JobStats {
                    name: j.name.clone(),
                    kind: j.spec.kind(),
                    priority: j.priority,
                    arrival_round: j.arrival_round,
                    admitted_round: None,
                    completed_round: None,
                    preemptions: 0,
                    rounds_run: 0,
                    projections: 0,
                    converged: false,
                    expired: false,
                    deadline_met: None,
                    objective: None,
                    phases: PhaseTimes::default(),
                    result: None,
                    shed: false,
                    failed: false,
                    retries: 0,
                    recovered: false,
                    error: None,
                })
                .collect(),
            events: Vec::new(),
        };
        let n = jobs.len();
        Ok(Scheduler {
            session: Session::new(cfg.opts.clone()),
            cfg,
            bank,
            jobs,
            arrivals,
            next_arrival: 0,
            arrived: vec![false; n],
            ready: JobQueue::new(),
            running: Vec::new(),
            checkpoints: (0..n).map(|_| None).collect(),
            attempts: vec![0; n],
            parked: Vec::new(),
            ready_at: vec![None; n],
            stats,
            round: 0,
            next_seq: 0,
            started: Instant::now(),
            metrics: None,
            observers: Vec::new(),
            round_hooks: Vec::new(),
        })
    }

    /// Observe scheduler events as they happen.
    pub fn on_event(&mut self, observer: impl FnMut(&ServeEvent) + 'a) {
        self.observers.push(Box::new(observer));
    }

    /// Call `hook(round)` once per scheduler round (idle rounds
    /// included), right after the round is driven. Fleet supervision
    /// piggybacks heartbeats and shard-fault checks on this.
    pub fn on_round(&mut self, hook: impl FnMut(usize) + 'a) {
        self.round_hooks.push(Box::new(hook));
    }

    /// Pre-complete a job slot: the job is treated as already serviced
    /// (its arrival is consumed without ever entering the ready queue).
    /// A fleet shard uses this to rebuild a scheduler over its full
    /// assignment history while re-running only the unfinished jobs,
    /// keeping every job's positional id — and thus its `job-<id>.ckpt`
    /// state file — stable across scheduler generations.
    pub fn exclude(&mut self, job: usize) {
        self.arrived[job] = true;
    }

    /// Redirect `metrics_every` NDJSON snapshots to `sink` (a file, a
    /// `Vec<u8>` in tests, …) instead of stderr.
    pub fn metrics_to(&mut self, sink: impl std::io::Write + 'a) {
        self.metrics = Some(Box::new(sink));
    }

    fn emit(&mut self, event: ServeEvent) {
        for obs in &mut self.observers {
            obs(&event);
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.stats.events.push(ServeLogEntry { seq, round: self.round, event });
    }

    /// One NDJSON live-metrics snapshot (queue/fleet counters plus
    /// per-running-job progress), written every `metrics_every` rounds.
    fn metrics_snapshot(&self) -> String {
        let elapsed = self.started.elapsed().as_secs_f64();
        let rps = if elapsed > 0.0 { self.round as f64 / elapsed } else { 0.0 };
        let jobs: Vec<String> = self
            .running
            .iter()
            .map(|r| {
                format!(
                    "{{\"job\": {}, \"rounds\": {}}}",
                    r.job,
                    r.base_rounds + (self.round - r.admitted_at)
                )
            })
            .collect();
        format!(
            "{{\"round\": {}, \"queue_depth\": {}, \"running\": {}, \"completed\": {}, \
             \"shed\": {}, \"failed\": {}, \"recovered\": {}, \"retried\": {}, \
             \"preemptions\": {}, \"expired\": {}, \"rounds_per_sec\": {:.3}, \"jobs\": [{}]}}\n",
            self.round,
            self.ready.len(),
            self.running.len(),
            self.stats.completed,
            self.stats.shed,
            self.stats.failed,
            self.stats.recovered,
            self.stats.retried,
            self.stats.preemptions,
            self.stats.expired,
            rps,
            jobs.join(", ")
        )
    }

    fn metrics_tick(&mut self) {
        let every = self.cfg.metrics_every;
        if every == 0 || self.round % every != 0 {
            return;
        }
        let line = self.metrics_snapshot();
        match &mut self.metrics {
            Some(w) => {
                let _ = w.write_all(line.as_bytes());
                let _ = w.flush();
            }
            None => eprint!("{line}"),
        }
    }

    /// Milliseconds since the job first became ready (0 if it never has).
    fn elapsed_ms(&self, job: usize) -> u64 {
        self.ready_at[job].map(|t| t.elapsed().as_millis() as u64).unwrap_or(0)
    }

    /// Enter the ready queue; the first time also starts the job's
    /// wall-clock deadline. Requeues (preemption, retry) keep the
    /// original clock — queueing time counts.
    fn mark_ready(&mut self, job: usize) {
        if self.ready_at[job].is_none() {
            self.ready_at[job] = Some(Instant::now());
        }
        self.ready.push_at(job, self.jobs[job].priority, self.round);
    }

    fn remove_state_file(&self, job: usize) {
        if let Some(dir) = &self.cfg.state_dir {
            persist::remove_checkpoint(dir, job);
        }
    }

    /// Persist one job's checkpoint durably (best-effort: a failed
    /// write is recorded on the job and serving continues — the
    /// in-memory state is still intact). Applies the corrupt-byte
    /// fault after the write so tests get deterministic bit rot.
    fn persist_checkpoint(&mut self, job: usize, ck: &BlockCheckpoint) {
        let Some(dir) = self.cfg.state_dir.clone() else { return };
        let fault = self.cfg.fault_plan.clone();
        match persist::write_checkpoint_atomic(&dir, job, ck) {
            Ok(path) => {
                if let Err(e) = fault.corrupt_file(job, &path) {
                    self.stats.jobs[job].error = Some(e.to_string());
                }
            }
            Err(e) => self.stats.jobs[job].error = Some(e.to_string()),
        }
    }

    /// Startup recovery: load every `job-<id>.ckpt` from the state dir.
    /// Valid checkpoints re-enter service immediately (arrival round 0,
    /// resumed bit-identically); corrupt ones are quarantined to
    /// `state_dir/corrupt/` and the job restarts from scratch at its
    /// normal arrival — determinism makes the restart exact, just
    /// without the saved progress.
    fn recover(&mut self) {
        let Some(dir) = self.cfg.state_dir.clone() else { return };
        let found = match persist::scan_state_dir(&dir) {
            Ok(found) => found,
            Err(_) => return, // unreadable dir: serve from scratch
        };
        for (job, path) in found {
            self.beat();
            if job >= self.jobs.len() {
                continue; // a different trace's leftovers; not ours to touch
            }
            if self.arrived[job] {
                continue; // excluded (already-serviced) slot; leave its file alone
            }
            match persist::load_checkpoint(&path) {
                Ok(ck) => {
                    let rounds_done = ck.iterations();
                    let s = &mut self.stats.jobs[job];
                    s.recovered = true;
                    s.rounds_run = rounds_done;
                    s.projections = ck.projections();
                    self.stats.recovered += 1;
                    self.checkpoints[job] = Some(ck);
                    self.arrived[job] = true;
                    self.mark_ready(job);
                    self.emit(ServeEvent::Recovered { round: 0, job, rounds_done });
                }
                Err(e) => {
                    self.stats.jobs[job].error = Some(e.to_string());
                    if let Err(qe) = persist::quarantine(&dir, &path) {
                        self.stats.jobs[job].error = Some(qe.to_string());
                    }
                    let attempt = self.attempts[job];
                    self.emit(ServeEvent::Quarantined { round: 0, job, attempt });
                }
            }
        }
    }

    /// The running job to preempt: lowest *effective* priority (as
    /// admitted); ties prefer the most recently admitted (its warm
    /// state is smallest), then the highest block index — fully
    /// deterministic.
    fn pick_victim(&self) -> Option<usize> {
        (0..self.running.len()).min_by_key(|&i| {
            let r = &self.running[i];
            (r.prio, std::cmp::Reverse(r.admitted_at), std::cmp::Reverse(r.handle.index()))
        })
    }

    fn preempt(&mut self, vi: usize) {
        let victim = self.running.remove(vi);
        let ck = self.session.evict(victim.handle.index());
        let rounds_done = ck.iterations();
        let job = victim.job;
        self.stats.jobs[job].preemptions += 1;
        self.stats.jobs[job].rounds_run = rounds_done;
        self.stats.jobs[job].projections = ck.projections();
        self.stats.preemptions += 1;
        self.persist_checkpoint(job, &ck);
        self.checkpoints[job] = Some(ck);
        self.mark_ready(job);
        self.emit(ServeEvent::Preempted { round: self.round, job, rounds_done });
    }

    /// Admit `job` at effective priority `prio`, or quarantine it on a
    /// typed admission failure. The in-memory checkpoint is only
    /// consumed on success, so a failed resume can retry later.
    fn try_admit(&mut self, job: usize, prio: i64) {
        self.beat();
        let outcome = if self.cfg.fault_plan.poison_spec.contains(&job) {
            Err(ServeError::SpecMismatch {
                job,
                msg: "injected poisoned spec (fault plan)".to_string(),
            })
        } else if let Some(ck) = &self.checkpoints[job] {
            resume_job(&mut self.session, &self.jobs[job], self.bank.input(job), ck)
                .map(|h| (h, true))
        } else {
            admit_job(&mut self.session, &self.jobs[job], self.bank.input(job))
                .map(|h| (h, false))
        };
        match outcome {
            Ok((handle, resumed)) => {
                self.checkpoints[job] = None;
                let base_rounds = self.stats.jobs[job].rounds_run;
                if self.stats.jobs[job].admitted_round.is_none() {
                    self.stats.jobs[job].admitted_round = Some(self.round);
                }
                self.running.push(Running {
                    job,
                    handle,
                    admitted_at: self.round,
                    base_rounds,
                    prio,
                });
                self.emit(ServeEvent::Admitted { round: self.round, job, resumed });
            }
            Err(e) => self.quarantine_failed(job, e),
        }
    }

    /// Record an admission failure: park the job with exponential
    /// round-backoff (2, 4, 8, … rounds), or permanently fail it past
    /// `retry_limit`. The fleet keeps stepping either way.
    fn quarantine_failed(&mut self, job: usize, e: ServeError) {
        self.attempts[job] += 1;
        let attempt = self.attempts[job];
        self.stats.jobs[job].error = Some(e.to_string());
        self.emit(ServeEvent::Quarantined { round: self.round, job, attempt });
        if attempt > self.cfg.retry_limit {
            let s = &mut self.stats.jobs[job];
            s.failed = true;
            s.deadline_met = Some(false);
            self.stats.failed += 1;
            self.checkpoints[job] = None;
            self.remove_state_file(job);
        } else {
            self.parked.push((self.round + (1usize << attempt), job));
        }
    }

    /// Move parked jobs whose backoff elapsed back into the ready
    /// queue, in deterministic (release round, job id) order.
    fn release_parked(&mut self) {
        self.parked.sort_unstable();
        let mut i = 0;
        while i < self.parked.len() {
            if self.parked[i].0 > self.round {
                i += 1;
                continue;
            }
            let (_, job) = self.parked.remove(i);
            self.stats.jobs[job].retries += 1;
            self.stats.retried += 1;
            let attempt = self.attempts[job];
            self.emit(ServeEvent::Retried { round: self.round, job, attempt });
            self.mark_ready(job);
        }
    }

    /// Overload control: drop the lowest-effective-priority pending
    /// jobs while the queue is over the high-water mark.
    fn shed_overflow(&mut self) {
        let Some(hw) = self.cfg.queue_high_water else { return };
        while self.ready.len() > hw {
            let Some(job) = self.ready.shed_lowest(self.round, self.cfg.age_rounds) else {
                break;
            };
            let s = &mut self.stats.jobs[job];
            s.shed = true;
            s.deadline_met = Some(false);
            self.stats.shed += 1;
            self.checkpoints[job] = None;
            self.remove_state_file(job);
            let queue_depth = self.ready.len();
            self.emit(ServeEvent::Shed { round: self.round, job, queue_depth });
        }
    }

    /// True (recording the expiry) if a just-popped queued job already
    /// missed a deadline — dropped without wasting an admission.
    fn expired_in_queue(&mut self, job: usize) -> bool {
        let j = &self.jobs[job];
        let past_rounds =
            j.deadline_rounds.is_some_and(|d| self.round.saturating_sub(j.arrival_round) > d);
        let past_ms = j.deadline_ms.is_some_and(|d| self.elapsed_ms(job) > d);
        if !(past_rounds || past_ms) {
            return false;
        }
        let rounds_done = self.stats.jobs[job].rounds_run;
        let s = &mut self.stats.jobs[job];
        s.expired = true;
        s.deadline_met = Some(false);
        self.stats.expired += 1;
        self.checkpoints[job] = None;
        self.remove_state_file(job);
        self.emit(ServeEvent::Expired { round: self.round, job, rounds_done });
        true
    }

    /// Periodic durability: every `checkpoint_every` rounds, persist
    /// each running job's state non-destructively
    /// ([`Session::checkpoint_block`] — same capture as a preemption,
    /// without perturbing the fleet).
    fn persist_periodic(&mut self) {
        let Some(every) = self.cfg.checkpoint_every else { return };
        if every == 0 || self.round % every != 0 || self.cfg.state_dir.is_none() {
            return;
        }
        let targets: Vec<(usize, usize)> =
            self.running.iter().map(|r| (r.job, r.handle.index())).collect();
        for (job, index) in targets {
            let ck = self.session.checkpoint_block(index);
            self.persist_checkpoint(job, &ck);
        }
    }

    fn crash_due(&self) -> bool {
        self.cfg.fault_plan.crash_after_round.is_some_and(|k| self.round >= k)
    }

    fn pause_requested(&self) -> bool {
        self.cfg
            .pause
            .as_ref()
            .is_some_and(|p| p.load(std::sync::atomic::Ordering::Relaxed))
    }

    /// Stamp the [`ServeConfig::heartbeat`] atomic (no-op without one).
    /// Called at every phase boundary inside a round, so liveness is
    /// visible even when one round outlasts a supervisor's stall
    /// timeout.
    fn beat(&self) {
        if let Some(hb) = &self.cfg.heartbeat {
            hb.store(crate::obs::clock::now_us(), std::sync::atomic::Ordering::Relaxed);
        }
    }

    /// Cooperative pause: persist every running job's checkpoint (same
    /// capture as [`Scheduler::crash_now`] — a between-rounds,
    /// post-FORGET snapshot, so resumption is bit-identical) and flag
    /// the stats `paused`. Preempted jobs were persisted when
    /// preempted; never-admitted jobs have no progress to lose.
    fn pause_now(&mut self) {
        let mut targets: Vec<(usize, usize)> =
            self.running.iter().map(|r| (r.job, r.handle.index())).collect();
        targets.sort_unstable();
        for (job, index) in targets {
            let ck = self.session.checkpoint_block(index);
            self.persist_checkpoint(job, &ck);
        }
        self.stats.paused = true;
    }

    /// Drive the per-round hooks (heartbeats, shard faults).
    fn round_hooks_tick(&mut self) {
        let round = self.round;
        for hook in &mut self.round_hooks {
            hook(round);
        }
    }

    /// Injected crash: persist every running job (preempted jobs were
    /// persisted when preempted), flag the stats, and let `run` return
    /// — the caller exits with [`persist::CRASH_EXIT_CODE`].
    fn crash_now(&mut self) {
        let mut targets: Vec<(usize, usize)> =
            self.running.iter().map(|r| (r.job, r.handle.index())).collect();
        targets.sort_unstable();
        for (job, index) in targets {
            let ck = self.session.checkpoint_block(index);
            self.persist_checkpoint(job, &ck);
        }
        self.stats.crashed = true;
    }

    /// Drive the trace to completion (all jobs completed, expired,
    /// shed, or failed; all arrivals consumed) and return the service
    /// record. With a fault-plan crash, stops early with
    /// `stats.crashed` set after persisting running state; with a
    /// [`ServeConfig::pause`] request, stops early with `stats.paused`
    /// set, also after persisting — resumable, not terminal.
    pub fn run(mut self) -> ServeStats {
        self.started = Instant::now();
        self.recover();
        loop {
            self.beat();
            // 1. Arrivals, then retries whose backoff elapsed.
            while self.next_arrival < self.arrivals.len()
                && self.jobs[self.arrivals[self.next_arrival]].arrival_round <= self.round
            {
                let job = self.arrivals[self.next_arrival];
                self.next_arrival += 1;
                if !self.arrived[job] {
                    self.arrived[job] = true;
                    self.mark_ready(job);
                }
            }
            self.release_parked();

            // 2. Preemption and admission, interleaved until stable:
            // admit into free capacity; when full, preempt only if the
            // best waiting job has strictly higher effective priority
            // than the victim's admitted level. Each preempt+admit pair
            // strictly raises the running fleet's priority multiset
            // (effective priorities are fixed within a round), so this
            // terminates.
            loop {
                if self.running.len() < self.cfg.capacity {
                    match self.ready.pop_aged(self.round, self.cfg.age_rounds) {
                        Some((job, eff)) => {
                            if !self.expired_in_queue(job) {
                                self.try_admit(job, eff);
                            }
                            continue;
                        }
                        None => break,
                    }
                }
                let Some(best) = self.ready.peek_priority_aged(self.round, self.cfg.age_rounds)
                else {
                    break;
                };
                match self.pick_victim() {
                    Some(vi) if best > self.running[vi].prio => self.preempt(vi),
                    _ => break,
                }
            }

            // 3. Overload shedding: with capacity filled, drop the
            // lowest-priority *pending* jobs while the queue is still
            // over the high-water mark.
            self.shed_overflow();

            // 4. One fleet round (or an idle round while waiting).
            if self.running.is_empty() {
                if self.ready.is_empty()
                    && self.parked.is_empty()
                    && self.next_arrival == self.arrivals.len()
                {
                    break;
                }
                self.emit(ServeEvent::Idle { round: self.round });
                self.round += 1;
                self.metrics_tick();
                self.round_hooks_tick();
                if self.crash_due() {
                    self.crash_now();
                    break;
                }
                if self.pause_requested() {
                    self.pause_now();
                    break;
                }
                if self.round >= self.cfg.max_service_rounds {
                    break;
                }
                continue;
            }
            self.session.step();
            self.beat();
            self.round += 1;

            // 5. Completions, then budgets and deadlines.
            let mut i = 0;
            while i < self.running.len() {
                let (job, handle, base_rounds, admitted_at) = {
                    let r = &self.running[i];
                    (r.job, r.handle, r.base_rounds, r.admitted_at)
                };
                if self.session.block_done(handle.index()) {
                    let outcome = take_job(&mut self.session, handle)
                        .expect("finished block lost its output");
                    // saturating: a recovered job re-enters at round 0
                    // and can finish before its trace arrival_round.
                    let rounds_ok = self.jobs[job]
                        .deadline_rounds
                        .map(|d| self.round.saturating_sub(self.jobs[job].arrival_round) <= d);
                    let ms_ok = self.jobs[job].deadline_ms.map(|d| self.elapsed_ms(job) <= d);
                    let deadline_met = match (rounds_ok, ms_ok) {
                        (None, None) => None,
                        (a, b) => Some(a.unwrap_or(true) && b.unwrap_or(true)),
                    };
                    let converged = outcome.result.converged;
                    let s = &mut self.stats.jobs[job];
                    s.completed_round = Some(self.round);
                    s.rounds_run = outcome.result.iterations;
                    s.projections = outcome.result.total_projections;
                    s.converged = converged;
                    s.objective = Some(outcome.objective);
                    s.phases = outcome.result.phases;
                    s.deadline_met = deadline_met;
                    s.result = Some(outcome.result);
                    self.stats.completed += 1;
                    self.running.remove(i);
                    self.remove_state_file(job);
                    self.emit(ServeEvent::Completed { round: self.round, job, converged });
                    continue;
                }
                let rounds_done = base_rounds + (self.round - admitted_at);
                let over_budget = self.jobs[job].max_rounds.is_some_and(|m| rounds_done >= m);
                let past_deadline = self.jobs[job]
                    .deadline_rounds
                    .is_some_and(|d| self.round.saturating_sub(self.jobs[job].arrival_round) > d)
                    || self.jobs[job].deadline_ms.is_some_and(|d| self.elapsed_ms(job) > d);
                if over_budget || past_deadline {
                    self.running.remove(i);
                    let ck = self.session.evict(handle.index());
                    let s = &mut self.stats.jobs[job];
                    s.rounds_run = ck.iterations();
                    s.projections = ck.projections();
                    s.expired = true;
                    s.deadline_met = Some(false);
                    self.stats.expired += 1;
                    self.remove_state_file(job);
                    self.emit(ServeEvent::Expired {
                        round: self.round,
                        job,
                        rounds_done: ck.iterations(),
                    });
                    continue;
                }
                i += 1;
            }
            // Reclaim finished blocks' coordinate ranges so the
            // concatenated vector stays bounded by the *running* fleet.
            self.session.compact_finished();

            // 6. Live metrics, durability, round hooks, and injected
            // crashes / cooperative pauses.
            self.metrics_tick();
            self.persist_periodic();
            self.round_hooks_tick();
            if self.crash_due() {
                self.crash_now();
                break;
            }
            if self.pause_requested() {
                self.pause_now();
                break;
            }

            if self.round >= self.cfg.max_service_rounds {
                break;
            }
        }
        self.stats.rounds = self.round;
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::JobBank;

    fn one_job(spec: JobSpec) -> Vec<Job> {
        vec![Job {
            id: 0,
            name: "solo".to_string(),
            spec,
            priority: 0,
            arrival_round: 0,
            max_rounds: None,
            deadline_rounds: None,
            deadline_ms: None,
        }]
    }

    #[test]
    fn job_round_budget_expires() {
        // An unreachable tolerance with a 3-round budget: the scheduler
        // must evict + expire the job instead of spinning forever.
        let mut jobs = one_job(JobSpec::Nearness { n: 14, graph_type: 1, seed: 5 });
        jobs[0].name = "hopeless".to_string();
        jobs[0].max_rounds = Some(3);
        let bank = JobBank::materialize(&jobs);
        let opts = SolveOptions::new().violation_tol(1e-14).dual_tol(1e-14).max_iters(10_000);
        let cfg = ServeConfig { capacity: 1, opts, ..Default::default() };
        let stats = Scheduler::new(jobs, &bank, cfg).expect("valid serve config").run();
        assert_eq!(stats.expired, 1);
        assert_eq!(stats.completed, 0);
        assert!(!stats.jobs[0].converged);
        assert!(stats.jobs[0].expired);
        assert_eq!(stats.jobs[0].rounds_run, 3);
        assert!(stats.jobs[0].projections > 0, "expiry stats come from the checkpoint");
        assert_eq!(stats.jobs[0].deadline_met, Some(false), "expired is never a null deadline");
        assert!(stats.events.iter().any(|e| matches!(e.event, ServeEvent::Expired { .. })));
    }

    #[test]
    fn round_deadline_is_enforced() {
        // deadline_rounds 2 with an unreachable tolerance: enforcement
        // must evict at round 3 (round − arrival > 2), not run forever.
        let mut jobs = one_job(JobSpec::Nearness { n: 14, graph_type: 1, seed: 5 });
        jobs[0].deadline_rounds = Some(2);
        let bank = JobBank::materialize(&jobs);
        let opts = SolveOptions::new().violation_tol(1e-14).dual_tol(1e-14).max_iters(10_000);
        let cfg = ServeConfig { capacity: 1, opts, ..Default::default() };
        let stats = Scheduler::new(jobs, &bank, cfg).expect("valid serve config").run();
        assert_eq!(stats.expired, 1);
        assert_eq!(stats.completed, 0);
        assert!(stats.jobs[0].expired);
        assert_eq!(stats.jobs[0].rounds_run, 3);
        assert_eq!(stats.jobs[0].deadline_met, Some(false));
    }

    #[test]
    fn wall_clock_deadline_expires_slow_jobs() {
        // A 1 ms deadline plus an observer that sleeps 5 ms on
        // admission: the first post-round deadline check must expire
        // the job, deterministically (the sleep guarantees the clock
        // has advanced past the deadline).
        let mut jobs = one_job(JobSpec::Nearness { n: 14, graph_type: 1, seed: 5 });
        jobs[0].deadline_ms = Some(1);
        let bank = JobBank::materialize(&jobs);
        let opts = SolveOptions::new().violation_tol(1e-14).dual_tol(1e-14).max_iters(10_000);
        let cfg = ServeConfig { capacity: 1, opts, ..Default::default() };
        let mut sched = Scheduler::new(jobs, &bank, cfg).expect("valid serve config");
        sched.on_event(|e| {
            if matches!(e, ServeEvent::Admitted { .. }) {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        });
        let stats = sched.run();
        assert_eq!(stats.expired, 1);
        assert!(stats.jobs[0].expired);
        assert_eq!(stats.jobs[0].deadline_met, Some(false));
    }

    #[test]
    fn generous_deadlines_report_met() {
        let mut jobs = one_job(JobSpec::Nearness { n: 10, graph_type: 1, seed: 3 });
        jobs[0].deadline_rounds = Some(10_000);
        jobs[0].deadline_ms = Some(3_600_000);
        let bank = JobBank::materialize(&jobs);
        let cfg = ServeConfig {
            capacity: 1,
            opts: SolveOptions::new().violation_tol(1e-4),
            ..Default::default()
        };
        let stats = Scheduler::new(jobs, &bank, cfg).expect("valid serve config").run();
        assert!(stats.all_completed());
        assert_eq!(stats.jobs[0].deadline_met, Some(true));
    }

    #[test]
    fn idle_rounds_bridge_arrival_gaps() {
        // A single job arriving at round 5: the scheduler idles up to it,
        // then completes it.
        let mut jobs = one_job(JobSpec::Nearness { n: 10, graph_type: 1, seed: 3 });
        jobs[0].name = "late".to_string();
        jobs[0].arrival_round = 5;
        let bank = JobBank::materialize(&jobs);
        let cfg = ServeConfig {
            capacity: 2,
            opts: SolveOptions::new().violation_tol(1e-4),
            ..Default::default()
        };
        let stats = Scheduler::new(jobs, &bank, cfg).expect("valid serve config").run();
        assert!(stats.all_completed());
        assert_eq!(
            stats.events.iter().filter(|e| matches!(e.event, ServeEvent::Idle { .. })).count(),
            5,
            "rounds 0..5 must idle"
        );
        assert_eq!(stats.jobs[0].admitted_round, Some(5));
    }

    #[test]
    fn poisoned_spec_is_retried_then_permanently_failed() {
        let mut jobs = one_job(JobSpec::Nearness { n: 10, graph_type: 1, seed: 3 });
        jobs.push(Job {
            id: 1,
            name: "healthy".to_string(),
            spec: JobSpec::Nearness { n: 12, graph_type: 1, seed: 4 },
            priority: 0,
            arrival_round: 0,
            max_rounds: None,
            deadline_rounds: None,
            deadline_ms: None,
        });
        let bank = JobBank::materialize(&jobs);
        let cfg = ServeConfig {
            capacity: 2,
            opts: SolveOptions::new().violation_tol(1e-4),
            retry_limit: 2,
            fault_plan: FaultPlan { poison_spec: vec![0], ..Default::default() },
            ..Default::default()
        };
        let stats = Scheduler::new(jobs, &bank, cfg).expect("valid serve config").run();
        // The poisoned job fails, retries twice with backoff, then
        // permanently fails; the healthy job is untouched.
        assert!(stats.jobs[0].failed);
        assert_eq!(stats.jobs[0].retries, 2);
        assert_eq!(stats.jobs[0].deadline_met, Some(false));
        assert!(stats.jobs[0].error.is_some());
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.retried, 2);
        assert_eq!(stats.completed, 1);
        assert!(stats.jobs[1].converged, "the fleet keeps serving around the poisoned job");
        assert_eq!(
            stats.events.iter().filter(|e| matches!(e.event, ServeEvent::Quarantined { .. })).count(),
            3,
            "initial failure plus two retries"
        );
    }

    #[test]
    fn overload_sheds_lowest_priority_pending_jobs() {
        // Capacity 1 and three simultaneous arrivals with a high-water
        // mark of 1: the two lowest-priority pending jobs are shed.
        let jobs: Vec<Job> = (0..4)
            .map(|i| Job {
                id: i,
                name: format!("j{i}"),
                spec: JobSpec::Nearness { n: 10, graph_type: 1, seed: i as u64 },
                priority: i as i64, // job 0 is the lowest priority
                arrival_round: 0,
                max_rounds: None,
                deadline_rounds: None,
                deadline_ms: None,
            })
            .collect();
        let bank = JobBank::materialize(&jobs);
        let cfg = ServeConfig {
            capacity: 1,
            opts: SolveOptions::new().violation_tol(1e-4),
            queue_high_water: Some(1),
            ..Default::default()
        };
        let stats = Scheduler::new(jobs, &bank, cfg).expect("valid serve config").run();
        assert_eq!(stats.shed, 2);
        assert!(stats.jobs[0].shed && stats.jobs[1].shed, "lowest priorities shed first");
        assert_eq!(stats.jobs[0].deadline_met, Some(false));
        assert_eq!(stats.completed, 2);
        assert!(stats.jobs[2].converged && stats.jobs[3].converged);
        assert!(stats.events.iter().any(|e| matches!(e.event, ServeEvent::Shed { .. })));
    }

    /// The event payload's own `round` field, for cross-checking the
    /// log-entry stamp.
    fn payload_round(e: &ServeEvent) -> usize {
        match *e {
            ServeEvent::Admitted { round, .. }
            | ServeEvent::Preempted { round, .. }
            | ServeEvent::Completed { round, .. }
            | ServeEvent::Expired { round, .. }
            | ServeEvent::Idle { round }
            | ServeEvent::Recovered { round, .. }
            | ServeEvent::Shed { round, .. }
            | ServeEvent::Retried { round, .. }
            | ServeEvent::Quarantined { round, .. } => round,
        }
    }

    #[test]
    fn events_carry_monotonic_seq_and_round_stamps() {
        // A workload that exercises many event kinds (idle rounds, a
        // late arrival, completions): every logged entry must carry a
        // dense 0-based sequence number and a round stamp that matches
        // its payload.
        let mut jobs = one_job(JobSpec::Nearness { n: 10, graph_type: 1, seed: 3 });
        jobs[0].arrival_round = 3;
        jobs.push(Job {
            id: 1,
            name: "early".to_string(),
            spec: JobSpec::Nearness { n: 12, graph_type: 1, seed: 4 },
            priority: 0,
            arrival_round: 0,
            max_rounds: None,
            deadline_rounds: None,
            deadline_ms: None,
        });
        let bank = JobBank::materialize(&jobs);
        let cfg = ServeConfig {
            capacity: 1,
            opts: SolveOptions::new().violation_tol(1e-4),
            ..Default::default()
        };
        let stats = Scheduler::new(jobs, &bank, cfg).expect("valid serve config").run();
        assert!(stats.all_completed());
        assert!(stats.events.len() >= 4, "admissions + completions at minimum");
        for (i, e) in stats.events.iter().enumerate() {
            assert_eq!(e.seq, i as u64, "seq numbers are dense and start at 0");
            assert_eq!(e.round, payload_round(&e.event), "stamp matches the payload round");
            assert!(e.round <= stats.rounds);
        }
    }

    #[test]
    fn metrics_snapshots_stream_ndjson() {
        // metrics_every=2 over a real run: the sink receives one JSON
        // object per line, with round stamps on the sampling grid and
        // the final completion count visible in the last snapshot.
        let jobs = one_job(JobSpec::Nearness { n: 12, graph_type: 1, seed: 7 });
        let bank = JobBank::materialize(&jobs);
        let cfg = ServeConfig {
            capacity: 1,
            opts: SolveOptions::new().violation_tol(1e-4),
            metrics_every: 2,
            ..Default::default()
        };
        let sink: std::rc::Rc<std::cell::RefCell<Vec<u8>>> = Default::default();
        let writer = SharedSink(sink.clone());
        let mut sched = Scheduler::new(jobs, &bank, cfg).expect("valid serve config");
        sched.metrics_to(writer);
        let stats = sched.run();
        assert!(stats.all_completed());
        let text = String::from_utf8(sink.borrow().clone()).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        assert!(!lines.is_empty(), "a multi-round solve must produce snapshots");
        for line in &lines {
            let json = crate::runtime::json::Json::parse(line).expect("snapshot parses");
            let round = json.get("round").and_then(|v| v.as_usize()).unwrap();
            assert_eq!(round % 2, 0, "snapshots land on the metrics_every grid");
            assert!(json.get("queue_depth").is_some());
            assert!(json.get("rounds_per_sec").is_some());
            assert!(json.get("jobs").and_then(|v| v.as_arr()).is_some());
        }
    }

    #[test]
    fn bad_configs_are_typed_errors_not_panics() {
        let jobs = one_job(JobSpec::Nearness { n: 8, graph_type: 1, seed: 1 });
        let bank = JobBank::materialize(&jobs);
        let err = |r: Result<Scheduler<'_>, ServeError>| match r {
            Err(ServeError::Config { msg }) => msg,
            Ok(_) => panic!("expected a Config error"),
            Err(other) => panic!("expected Config, got {other:?}"),
        };
        let cfg = ServeConfig { capacity: 0, ..Default::default() };
        assert!(err(Scheduler::new(jobs.clone(), &bank, cfg)).contains("capacity"));
        let mut opts = SolveOptions::new();
        opts.overlap = true;
        let cfg = ServeConfig { capacity: 1, opts, ..Default::default() };
        assert!(err(Scheduler::new(jobs.clone(), &bank, cfg)).contains("non-overlapped"));
        let mut renumbered = jobs.clone();
        renumbered[0].id = 7;
        assert!(err(Scheduler::new(renumbered, &bank, Default::default()))
            .contains("positional"));
        let two = vec![jobs[0].clone(), {
            let mut j = jobs[0].clone();
            j.id = 1;
            j
        }];
        assert!(err(Scheduler::new(two, &bank, Default::default())).contains("misaligned"));
        // Mixed kinds without pinned inner_sweeps.
        let mut mixed = one_job(JobSpec::Nearness { n: 8, graph_type: 1, seed: 1 });
        mixed.push(Job {
            id: 1,
            name: "cc".to_string(),
            spec: JobSpec::Correlation { n: 8, clusters: 2, flip: 0.1, seed: 2 },
            priority: 0,
            arrival_round: 0,
            max_rounds: None,
            deadline_rounds: None,
            deadline_ms: None,
        });
        let mixed_bank = JobBank::materialize(&mixed);
        assert!(err(Scheduler::new(mixed, &mixed_bank, Default::default()))
            .contains("inner_sweeps"));
    }

    #[test]
    fn pause_persists_running_state_and_resumes_bit_identically() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let dir = std::env::temp_dir().join(format!(
            "paf-sched-pause-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let jobs = one_job(JobSpec::Nearness { n: 26, graph_type: 1, seed: 3 });
        let bank = JobBank::materialize(&jobs);
        let opts = SolveOptions::new().violation_tol(1e-4);
        let pause = Arc::new(AtomicBool::new(true)); // pre-set: pause after round 1
        let cfg = ServeConfig {
            capacity: 1,
            opts: opts.clone(),
            state_dir: Some(dir.clone()),
            pause: Some(pause.clone()),
            ..Default::default()
        };
        let paused = Scheduler::new(jobs.clone(), &bank, cfg).expect("valid").run();
        assert!(paused.paused, "the pause flag must stop the run");
        assert!(!paused.crashed, "a pause is not a crash");
        assert_eq!(paused.rounds, 1, "pause lands at the first round boundary");
        assert_eq!(paused.completed, 0);
        assert!(
            persist::checkpoint_path(&dir, 0).exists(),
            "the running job's state must be persisted"
        );
        // Resume against the same state dir: recovery completes the job
        // on the same trajectory as an uninterrupted run.
        pause.store(false, Ordering::Relaxed);
        let cfg = ServeConfig {
            capacity: 1,
            opts: opts.clone(),
            state_dir: Some(dir.clone()),
            ..Default::default()
        };
        let resumed = Scheduler::new(jobs.clone(), &bank, cfg).expect("valid").run();
        assert!(resumed.all_completed());
        assert_eq!(resumed.recovered, 1);
        let solo = super::super::solve_job_solo(&jobs[0], bank.input(0), &opts).expect("solo");
        let got = resumed.jobs[0].result.as_ref().expect("result");
        assert_eq!(got.x, solo.result.x, "paused+resumed x must be bit-identical");
        assert_eq!(got.iterations, solo.result.iterations);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn excluded_jobs_never_run_and_round_hooks_fire_each_round() {
        use std::cell::Cell;
        let mut jobs = one_job(JobSpec::Nearness { n: 12, graph_type: 1, seed: 5 });
        jobs.push(Job {
            id: 1,
            name: "skip-me".to_string(),
            spec: JobSpec::Nearness { n: 12, graph_type: 1, seed: 6 },
            priority: 0,
            arrival_round: 0,
            max_rounds: None,
            deadline_rounds: None,
            deadline_ms: None,
        });
        let bank = JobBank::materialize(&jobs);
        let cfg = ServeConfig {
            capacity: 2,
            opts: SolveOptions::new().violation_tol(1e-4),
            ..Default::default()
        };
        let hooks = Cell::new(0usize);
        let last_round = Cell::new(0usize);
        let mut sched = Scheduler::new(jobs, &bank, cfg).expect("valid serve config");
        sched.exclude(1);
        sched.on_round(|r| {
            hooks.set(hooks.get() + 1);
            last_round.set(r);
        });
        let stats = sched.run();
        assert_eq!(stats.completed, 1, "only the non-excluded job runs");
        assert!(stats.jobs[0].converged);
        assert!(stats.jobs[1].completed_round.is_none());
        assert!(!stats.jobs[1].shed && !stats.jobs[1].failed && !stats.jobs[1].expired);
        assert_eq!(hooks.get(), stats.rounds, "one hook call per round");
        assert_eq!(last_round.get(), stats.rounds);
    }

    /// Test-only shared byte sink (the scheduler owns the writer, the
    /// test keeps a handle to the bytes).
    struct SharedSink(std::rc::Rc<std::cell::RefCell<Vec<u8>>>);
    impl std::io::Write for SharedSink {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.borrow_mut().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }
}

/// Generate the demo/example trace: a mixed nearness + CC workload with
/// staggered arrivals, a priority spread, and one forced preemption (a
/// high-priority CC job arrives while capacity is saturated by
/// low-priority nearness jobs). Deterministic in `seed`. Deadlines are
/// generous — they are *enforced* now, and the demo jobs are meant to
/// complete with `deadline_met: true`.
pub fn demo_trace(seed: u64) -> Vec<Job> {
    vec![
        Job {
            id: 0,
            name: "near-low".to_string(),
            spec: JobSpec::Nearness { n: 26, graph_type: 1, seed },
            priority: 0,
            arrival_round: 0,
            max_rounds: None,
            deadline_rounds: Some(4000),
            deadline_ms: None,
        },
        Job {
            id: 1,
            name: "near-mid".to_string(),
            spec: JobSpec::Nearness { n: 22, graph_type: 2, seed: seed + 1 },
            priority: 1,
            arrival_round: 1,
            max_rounds: None,
            deadline_rounds: None,
            deadline_ms: None,
        },
        Job {
            id: 2,
            name: "cc-urgent".to_string(),
            spec: JobSpec::Correlation { n: 16, clusters: 3, flip: 0.1, seed: seed + 2 },
            priority: 9,
            arrival_round: 3,
            max_rounds: Some(600),
            deadline_rounds: Some(3000),
            deadline_ms: None,
        },
    ]
}
