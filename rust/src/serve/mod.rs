//! The serving subsystem: a long-running scheduler over
//! [`Session`](crate::core::session::Session) with mid-solve admission
//! and checkpoint-based preemption.
//!
//! The batch CLI solves jobs one at a time; this layer turns the same
//! engine into a *service*. A [`Scheduler`] owns one session fleet and
//! drives it round-by-round while a [`JobQueue`] feeds it: new jobs are
//! admitted into the **running** fleet between rounds (the concatenated
//! variable vector re-offsets dynamically), higher-priority arrivals
//! preempt lower-priority running jobs by checkpointing and requeueing
//! them, and every job's trajectory stays bit-identical to a solo solve.
//! Per-job stats and the event stream are exported through the
//! schema-versioned solver JSON
//! ([`serve_stats_json`], schema v[`crate::report::SOLVER_JSON_SCHEMA_VERSION`]).
//!
//! Quick tour: [`queue`] — job specs, trace parsing, the priority
//! queue; [`admission`] — the owned instance arena ([`JobBank`]) and
//! typed-handle adapters; [`scheduler`] — the service loop; [`intake`]
//! — live job arrival over a socket or stdin; [`fleet`] — the
//! multi-shard supervisor (health checks, checkpoint migration,
//! manifest-journaled restart).

pub mod admission;
pub mod fleet;
pub mod intake;
pub mod persist;
pub mod queue;
pub mod scheduler;

pub use admission::{admit_job, resume_job, solve_job_solo, take_job, JobBank, JobHandle, JobInput, JobOutcome};
pub use fleet::{
    run_fleet, FleetConfig, FleetEvent, FleetJobStats, FleetLogEntry, FleetStats, ShardStats,
};
pub use intake::{spawn_intake, IntakeHandle, IntakeItem, IntakeSource};
pub use persist::{
    load_checkpoint, remove_checkpoint, scan_state_dir, write_checkpoint_atomic, FaultPlan,
    CRASH_EXIT_CODE,
};
pub use queue::{parse_intake_line, parse_job_trace, parse_job_trace_lenient, Job, JobQueue, JobSpec};
pub use scheduler::{
    demo_trace, JobStats, Scheduler, ServeConfig, ServeEvent, ServeLogEntry, ServeStats,
};

use crate::report;

/// Typed serve-layer failure. The scheduler never panics on bad input:
/// a malformed trace line is skipped-and-reported, a job whose spec or
/// checkpoint is unusable is quarantined and retried, and the rest of
/// the fleet keeps stepping.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// A malformed job-trace line (1-based line number; 0 for
    /// whole-trace problems such as an empty trace).
    Trace { line: usize, msg: String },
    /// A job whose spec does not match its bank input or cannot be
    /// admitted.
    SpecMismatch { job: usize, msg: String },
    /// Filesystem failure in the durable-checkpoint path.
    Io { path: String, msg: String },
    /// A checkpoint file that failed checksum or decode validation.
    Corrupt { path: String, msg: String },
    /// A checkpoint kind this build cannot serialize.
    Unsupported { msg: String },
    /// A malformed `--fault-plan` spec.
    FaultPlan { msg: String },
    /// An invalid scheduler/fleet configuration. Recoverable: in a
    /// fleet this kills one shard admission, not the process.
    Config { msg: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Trace { line, msg } if *line == 0 => write!(f, "trace: {msg}"),
            ServeError::Trace { line, msg } => write!(f, "trace line {line}: {msg}"),
            ServeError::SpecMismatch { job, msg } => write!(f, "job {job}: {msg}"),
            ServeError::Io { path, msg } => write!(f, "{path}: {msg}"),
            ServeError::Corrupt { path, msg } => write!(f, "corrupt checkpoint {path}: {msg}"),
            ServeError::Unsupported { msg } => write!(f, "unsupported: {msg}"),
            ServeError::FaultPlan { msg } => write!(f, "fault plan: {msg}"),
            ServeError::Config { msg } => write!(f, "config: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Serialise a [`ServeStats`] as the schema-versioned serve JSON
/// (`"kind": "serve"`; schema version shared with the solver-result
/// JSON). `label` must not contain `"` or `\` (labels are
/// code-controlled, as in [`report::solver_result_json`]).
pub fn serve_stats_json(label: &str, stats: &ServeStats) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!(
        "  \"schema_version\": {},\n",
        report::SOLVER_JSON_SCHEMA_VERSION
    ));
    out.push_str("  \"kind\": \"serve\",\n");
    out.push_str(&format!("  \"label\": \"{label}\",\n"));
    out.push_str(&format!("  \"rounds\": {},\n", stats.rounds));
    out.push_str(&format!("  \"completed\": {},\n", stats.completed));
    out.push_str(&format!("  \"preemptions\": {},\n", stats.preemptions));
    out.push_str(&format!("  \"expired\": {},\n", stats.expired));
    out.push_str(&format!("  \"recovered\": {},\n", stats.recovered));
    out.push_str(&format!("  \"shed\": {},\n", stats.shed));
    out.push_str(&format!("  \"retried\": {},\n", stats.retried));
    out.push_str(&format!("  \"failed\": {},\n", stats.failed));
    out.push_str(&format!("  \"crashed\": {},\n", stats.crashed));
    out.push_str(&format!("  \"paused\": {},\n", stats.paused));
    out.push_str("  \"jobs\": [\n");
    for (k, j) in stats.jobs.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"id\": {k}, \"name\": \"{}\", \"kind\": \"{}\", \"priority\": {}, \
             \"arrival_round\": {}, ",
            queue::json_escape(&j.name),
            j.kind,
            j.priority,
            j.arrival_round
        ));
        out.push_str(&format!(
            "\"admitted_round\": {}, \"completed_round\": {}, ",
            opt_num(j.admitted_round),
            opt_num(j.completed_round)
        ));
        out.push_str(&format!(
            "\"preemptions\": {}, \"rounds_run\": {}, \"projections\": {}, \
             \"converged\": {}, \"expired\": {}, ",
            j.preemptions, j.rounds_run, j.projections, j.converged, j.expired
        ));
        out.push_str(&format!(
            "\"shed\": {}, \"failed\": {}, \"retries\": {}, \"recovered\": {}, \"error\": {}, ",
            j.shed,
            j.failed,
            j.retries,
            j.recovered,
            match &j.error {
                Some(e) => format!("\"{}\"", queue::json_escape(e)),
                None => "null".to_string(),
            }
        ));
        // Sweep-scheduling counters, summed over the job's recorded
        // trace (0 for jobs that never produced a result).
        let (rows_projected, rows_skipped) = j
            .result
            .as_ref()
            .map(|r| {
                r.trace.iter().fold((0usize, 0usize), |(p, s), it| {
                    (p + it.rows_projected, s + it.rows_skipped)
                })
            })
            .unwrap_or((0, 0));
        out.push_str(&format!(
            "\"rows_projected\": {rows_projected}, \"rows_skipped\": {rows_skipped}, "
        ));
        out.push_str(&format!(
            "\"deadline_met\": {}, \"objective\": {}, ",
            match j.deadline_met {
                Some(b) => b.to_string(),
                None => "null".to_string(),
            },
            match j.objective {
                Some(v) => format!("{v:.9}"),
                None => "null".to_string(),
            }
        ));
        out.push_str(&format!(
            "\"phases\": {{\"oracle_s\": {:.9}, \"sweep_s\": {:.9}, \"forget_s\": {:.9}}}}}{}\n",
            j.phases.oracle_s,
            j.phases.sweep_s,
            j.phases.forget_s,
            if k + 1 == stats.jobs.len() { "" } else { "," }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"events\": [\n");
    for (k, e) in stats.events.iter().enumerate() {
        let body = serve_event_body(&e.event);
        out.push_str(&format!(
            "    {{\"seq\": {}, {body}}}{}\n",
            e.seq,
            if k + 1 == stats.events.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn opt_num(v: Option<usize>) -> String {
    match v {
        Some(v) => v.to_string(),
        None => "null".to_string(),
    }
}

/// The `"event": ...` JSON body of one serve event (no braces) —
/// shared between the single-scheduler serve JSON and the fleet JSON's
/// shard-event entries.
fn serve_event_body(event: &ServeEvent) -> String {
    match event {
        ServeEvent::Admitted { round, job, resumed } => format!(
            "\"event\": \"admitted\", \"round\": {round}, \"job\": {job}, \
             \"resumed\": {resumed}"
        ),
        ServeEvent::Preempted { round, job, rounds_done } => format!(
            "\"event\": \"preempted\", \"round\": {round}, \"job\": {job}, \
             \"rounds_done\": {rounds_done}"
        ),
        ServeEvent::Completed { round, job, converged } => format!(
            "\"event\": \"completed\", \"round\": {round}, \"job\": {job}, \
             \"converged\": {converged}"
        ),
        ServeEvent::Expired { round, job, rounds_done } => format!(
            "\"event\": \"expired\", \"round\": {round}, \"job\": {job}, \
             \"rounds_done\": {rounds_done}"
        ),
        ServeEvent::Idle { round } => format!("\"event\": \"idle\", \"round\": {round}"),
        ServeEvent::Recovered { round, job, rounds_done } => format!(
            "\"event\": \"recovered\", \"round\": {round}, \"job\": {job}, \
             \"rounds_done\": {rounds_done}"
        ),
        ServeEvent::Shed { round, job, queue_depth } => format!(
            "\"event\": \"shed\", \"round\": {round}, \"job\": {job}, \
             \"queue_depth\": {queue_depth}"
        ),
        ServeEvent::Retried { round, job, attempt } => format!(
            "\"event\": \"retried\", \"round\": {round}, \"job\": {job}, \
             \"attempt\": {attempt}"
        ),
        ServeEvent::Quarantined { round, job, attempt } => format!(
            "\"event\": \"quarantined\", \"round\": {round}, \"job\": {job}, \
             \"attempt\": {attempt}"
        ),
    }
}

/// Serialise a [`FleetStats`] as the schema-versioned fleet JSON
/// (`"kind": "serve-fleet"`, schema v7): per-shard service records,
/// per-job fleet records with an `x_fnv1a` digest of the final
/// solution vector (FNV-1a 64 over the little-endian `f64` bytes, as a
/// hex string — bit-identity across runs is `==` on these), and the
/// fleet event stream (placements, migrations, shard deaths, and every
/// shard's serve events with fleet-global job ids).
pub fn fleet_stats_json(label: &str, stats: &FleetStats) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!(
        "  \"schema_version\": {},\n",
        report::SOLVER_JSON_SCHEMA_VERSION
    ));
    out.push_str("  \"kind\": \"serve-fleet\",\n");
    out.push_str(&format!("  \"label\": \"{label}\",\n"));
    out.push_str(&format!("  \"migrations\": {},\n", stats.migrations));
    out.push_str(&format!("  \"completed\": {},\n", stats.completed));
    out.push_str(&format!("  \"shed\": {},\n", stats.shed));
    out.push_str(&format!("  \"skipped_lines\": {},\n", stats.skipped_lines));
    out.push_str(&format!("  \"drained\": {},\n", stats.drained));
    out.push_str(&format!("  \"halted\": {},\n", stats.halted));
    out.push_str("  \"shards\": [\n");
    for (k, s) in stats.shards.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"id\": {k}, \"assigned\": {}, \"completed\": {}, \"rounds\": {}, \
             \"dead\": {}, \"cause\": {}}}{}\n",
            s.assigned,
            s.completed,
            s.rounds,
            s.dead,
            match &s.cause {
                Some(c) => format!("\"{}\"", queue::json_escape(c)),
                None => "null".to_string(),
            },
            if k + 1 == stats.shards.len() { "" } else { "," }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"jobs\": [\n");
    for (k, j) in stats.jobs.iter().enumerate() {
        let (completed_round, rounds_run, converged, objective) = match &j.stats {
            Some(s) => (
                opt_num(s.completed_round),
                s.rounds_run.to_string(),
                s.converged.to_string(),
                match s.objective {
                    Some(v) => format!("{v:.9}"),
                    None => "null".to_string(),
                },
            ),
            None => (
                "null".to_string(),
                "0".to_string(),
                "false".to_string(),
                "null".to_string(),
            ),
        };
        // The determinism fingerprint: migrated jobs must match their
        // uninterrupted solo solve bit for bit.
        let x_fnv1a = j
            .stats
            .as_ref()
            .and_then(|s| s.result.as_ref())
            .map(|r| {
                let mut bytes = Vec::with_capacity(r.x.len() * 8);
                for v in &r.x {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                format!("\"{:016x}\"", crate::util::wire::fnv1a64(&bytes))
            })
            .unwrap_or_else(|| "null".to_string());
        out.push_str(&format!(
            "    {{\"id\": {k}, \"name\": \"{}\", \"kind\": \"{}\", \"priority\": {}, \
             \"shard\": {}, \"migrations\": {}, \"done_prior\": {}, \"completed\": {}, \
             \"completed_round\": {completed_round}, \"rounds_run\": {rounds_run}, \
             \"converged\": {converged}, \"objective\": {objective}, \
             \"x_fnv1a\": {x_fnv1a}}}{}\n",
            queue::json_escape(&j.name),
            j.kind,
            j.priority,
            j.shard,
            j.migrations,
            j.done_prior,
            j.completed(),
            if k + 1 == stats.jobs.len() { "" } else { "," }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"events\": [\n");
    for (k, e) in stats.events.iter().enumerate() {
        let body = match &e.event {
            FleetEvent::Placed { job, shard, migrated, with_checkpoint } => format!(
                "\"event\": \"placed\", \"job\": {job}, \"shard\": {shard}, \
                 \"migrated\": {migrated}, \"with_checkpoint\": {with_checkpoint}"
            ),
            FleetEvent::SkippedLine { line, msg } => format!(
                "\"event\": \"skipped-line\", \"line\": {line}, \"msg\": \"{}\"",
                queue::json_escape(msg)
            ),
            FleetEvent::Shed { job } => format!("\"event\": \"shed\", \"job\": {job}"),
            FleetEvent::ShardDead { shard, cause } => format!(
                "\"event\": \"shard-dead\", \"shard\": {shard}, \"cause\": \"{}\"",
                queue::json_escape(cause)
            ),
            FleetEvent::JobDone { job, shard, completed } => format!(
                "\"event\": \"job-done\", \"job\": {job}, \"shard\": {shard}, \
                 \"completed\": {completed}"
            ),
            FleetEvent::DrainStarted => "\"event\": \"drain-started\"".to_string(),
            FleetEvent::HaltStarted => "\"event\": \"halt-started\"".to_string(),
            FleetEvent::Resumed { jobs, done_prior } => format!(
                "\"event\": \"resumed\", \"jobs\": {jobs}, \"done_prior\": {done_prior}"
            ),
            FleetEvent::Shard { shard, event } => {
                format!("\"shard\": {shard}, {}", serve_event_body(event))
            }
        };
        out.push_str(&format!(
            "    {{\"seq\": {}, \"at_us\": {}, {body}}}{}\n",
            e.seq,
            e.at_us,
            if k + 1 == stats.events.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Persist fleet stats as `<basename>.json` under the report directory.
pub fn emit_fleet_json(
    stats: &FleetStats,
    basename: &str,
) -> std::io::Result<std::path::PathBuf> {
    report::emit_json(basename, &fleet_stats_json(basename, stats))
}

/// Persist serve stats as `<basename>.json` under the report directory.
pub fn emit_serve_json(
    stats: &ServeStats,
    basename: &str,
) -> std::io::Result<std::path::PathBuf> {
    report::emit_json(basename, &serve_stats_json(basename, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::solver::PhaseTimes;
    use crate::runtime::json::Json;

    #[test]
    fn serve_json_is_parseable_and_versioned() {
        let stats = ServeStats {
            rounds: 7,
            completed: 1,
            preemptions: 1,
            expired: 0,
            recovered: 1,
            shed: 0,
            retried: 1,
            failed: 0,
            crashed: false,
            paused: false,
            jobs: vec![JobStats {
                name: "near-a".to_string(),
                kind: "nearness",
                priority: 2,
                arrival_round: 0,
                admitted_round: Some(0),
                completed_round: Some(7),
                preemptions: 1,
                rounds_run: 5,
                projections: 123,
                converged: true,
                expired: false,
                deadline_met: Some(true),
                objective: Some(1.5),
                phases: PhaseTimes { oracle_s: 0.1, sweep_s: 0.2, forget_s: 0.01 },
                result: None,
                shed: false,
                failed: false,
                retries: 1,
                recovered: true,
                error: Some("corrupt checkpoint \"x\"".to_string()),
            }],
            events: [
                ServeEvent::Recovered { round: 0, job: 0, rounds_done: 3 },
                ServeEvent::Admitted { round: 0, job: 0, resumed: true },
                ServeEvent::Preempted { round: 2, job: 0, rounds_done: 2 },
                ServeEvent::Quarantined { round: 3, job: 0, attempt: 1 },
                ServeEvent::Retried { round: 5, job: 0, attempt: 1 },
                ServeEvent::Admitted { round: 5, job: 0, resumed: true },
                ServeEvent::Completed { round: 7, job: 0, converged: true },
            ]
            .into_iter()
            .enumerate()
            .map(|(i, event)| ServeLogEntry {
                seq: i as u64,
                round: match event {
                    ServeEvent::Recovered { round, .. }
                    | ServeEvent::Admitted { round, .. }
                    | ServeEvent::Preempted { round, .. }
                    | ServeEvent::Quarantined { round, .. }
                    | ServeEvent::Retried { round, .. }
                    | ServeEvent::Completed { round, .. } => round,
                    _ => 0,
                },
                event,
            })
            .collect(),
        };
        let text = serve_stats_json("unit", &stats);
        let json = Json::parse(&text).expect("invalid serve JSON");
        assert_eq!(
            json.get("schema_version").and_then(|v| v.as_usize()),
            Some(report::SOLVER_JSON_SCHEMA_VERSION as usize)
        );
        assert_eq!(json.get("kind").and_then(|v| v.as_str()), Some("serve"));
        let jobs = json.get("jobs").and_then(|j| j.as_arr()).expect("jobs array");
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].get("preemptions").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(jobs[0].get("deadline_met"), Some(&Json::Bool(true)));
        assert_eq!(jobs[0].get("rows_projected").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(jobs[0].get("rows_skipped").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(jobs[0].get("shed"), Some(&Json::Bool(false)));
        assert_eq!(jobs[0].get("failed"), Some(&Json::Bool(false)));
        assert_eq!(jobs[0].get("recovered"), Some(&Json::Bool(true)));
        assert_eq!(jobs[0].get("retries").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(
            jobs[0].get("error").and_then(|v| v.as_str()),
            Some("corrupt checkpoint \"x\""),
            "error strings are JSON-escaped"
        );
        assert_eq!(json.get("recovered").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(json.get("retried").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(json.get("crashed"), Some(&Json::Bool(false)));
        let events = json.get("events").and_then(|e| e.as_arr()).expect("events array");
        assert_eq!(events.len(), 7);
        assert_eq!(events[0].get("event").and_then(|v| v.as_str()), Some("recovered"));
        assert_eq!(events[2].get("event").and_then(|v| v.as_str()), Some("preempted"));
        assert_eq!(events[3].get("event").and_then(|v| v.as_str()), Some("quarantined"));
        assert_eq!(events[4].get("event").and_then(|v| v.as_str()), Some("retried"));
        for (i, e) in events.iter().enumerate() {
            assert_eq!(
                e.get("seq").and_then(|v| v.as_usize()),
                Some(i),
                "v6 serve events carry dense sequence numbers"
            );
        }
    }

    #[test]
    fn fleet_json_is_parseable_and_carries_digests() {
        let stats = FleetStats {
            shards: vec![
                ShardStats { assigned: 2, completed: 1, rounds: 9, dead: false, cause: None },
                ShardStats {
                    assigned: 1,
                    completed: 0,
                    rounds: 4,
                    dead: true,
                    cause: Some("worker panicked".to_string()),
                },
            ],
            jobs: vec![
                FleetJobStats {
                    name: "near-a".to_string(),
                    kind: "nearness",
                    priority: 0,
                    shard: 0,
                    migrations: 1,
                    done_prior: false,
                    stats: Some(JobStats {
                        name: "near-a".to_string(),
                        kind: "nearness",
                        priority: 0,
                        arrival_round: 0,
                        admitted_round: Some(0),
                        completed_round: Some(5),
                        preemptions: 0,
                        rounds_run: 5,
                        projections: 42,
                        converged: true,
                        expired: false,
                        deadline_met: None,
                        objective: Some(0.5),
                        phases: PhaseTimes::default(),
                        result: Some(crate::core::solver::SolverResult {
                            x: vec![1.0, 2.5],
                            iterations: 5,
                            converged: true,
                            total_projections: 42,
                            active_constraints: 3,
                            trace: Vec::new(),
                            seconds: 0.1,
                            phases: PhaseTimes::default(),
                            telemetry: Vec::new(),
                        }),
                        shed: false,
                        failed: false,
                        retries: 0,
                        recovered: true,
                        error: None,
                    }),
                },
                FleetJobStats {
                    name: "prior".to_string(),
                    kind: "cc",
                    priority: 1,
                    shard: 1,
                    migrations: 0,
                    done_prior: true,
                    stats: None,
                },
            ],
            migrations: 1,
            skipped_lines: 1,
            skipped: vec![ServeError::Trace { line: 3, msg: "bad".to_string() }],
            completed: 2,
            shed: 0,
            drained: true,
            halted: false,
            events: vec![
                FleetLogEntry {
                    seq: 0,
                    at_us: 10,
                    event: FleetEvent::Placed {
                        job: 0,
                        shard: 0,
                        migrated: false,
                        with_checkpoint: false,
                    },
                },
                FleetLogEntry {
                    seq: 1,
                    at_us: 20,
                    event: FleetEvent::ShardDead {
                        shard: 1,
                        cause: "worker panicked".to_string(),
                    },
                },
                FleetLogEntry {
                    seq: 2,
                    at_us: 30,
                    event: FleetEvent::Shard {
                        shard: 0,
                        event: ServeEvent::Completed { round: 5, job: 0, converged: true },
                    },
                },
            ],
        };
        let text = fleet_stats_json("unit", &stats);
        let json = Json::parse(&text).expect("invalid fleet JSON");
        assert_eq!(
            json.get("schema_version").and_then(|v| v.as_usize()),
            Some(report::SOLVER_JSON_SCHEMA_VERSION as usize)
        );
        assert_eq!(json.get("kind").and_then(|v| v.as_str()), Some("serve-fleet"));
        assert_eq!(json.get("migrations").and_then(|v| v.as_usize()), Some(1));
        let shards = json.get("shards").and_then(|s| s.as_arr()).expect("shards array");
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[1].get("dead"), Some(&Json::Bool(true)));
        assert_eq!(
            shards[1].get("cause").and_then(|v| v.as_str()),
            Some("worker panicked")
        );
        let jobs = json.get("jobs").and_then(|j| j.as_arr()).expect("jobs array");
        assert_eq!(jobs.len(), 2);
        // The digest is FNV-1a 64 over the final x's little-endian f64
        // bytes, as a fixed-width hex string.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&1.0f64.to_le_bytes());
        bytes.extend_from_slice(&2.5f64.to_le_bytes());
        let expect = format!("{:016x}", crate::util::wire::fnv1a64(&bytes));
        assert_eq!(jobs[0].get("x_fnv1a").and_then(|v| v.as_str()), Some(expect.as_str()));
        assert_eq!(jobs[0].get("migrations").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(jobs[1].get("x_fnv1a"), Some(&Json::Null));
        assert_eq!(jobs[1].get("done_prior"), Some(&Json::Bool(true)));
        assert_eq!(jobs[1].get("completed"), Some(&Json::Bool(true)));
        let events = json.get("events").and_then(|e| e.as_arr()).expect("events array");
        assert_eq!(events[1].get("event").and_then(|v| v.as_str()), Some("shard-dead"));
        assert_eq!(
            events[2].get("event").and_then(|v| v.as_str()),
            Some("completed"),
            "shard serve events embed with their shard id"
        );
        assert_eq!(events[2].get("shard").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(events[2].get("at_us").and_then(|v| v.as_usize()), Some(30));
    }
}
