//! Scale-out serving: a supervisor over N scheduler shards with live
//! intake, heartbeat health-checks, and checkpoint-based work
//! migration.
//!
//! ## Architecture
//!
//! ```text
//!                    intake thread (unix / tcp / stdin)
//!                           │ bounded channel (backpressure)
//!                           ▼
//!  ┌─────────────────── supervisor ────────────────────┐
//!  │ global job registry · least-loaded placement ·    │
//!  │ high-water shedding · heartbeat health checks ·   │
//!  │ checkpoint migration · fleet-manifest journal     │
//!  └───┬───────────────────┬───────────────────┬───────┘
//!      │ assign channel    │                   │   heartbeats (atomics)
//!      ▼                   ▼                   ▼   + report channel
//!  shard 0 thread      shard 1 thread      shard 2 thread
//!  Scheduler over      Scheduler over      Scheduler over
//!  its own Session     its own Session     its own Session
//!  state: shard-0/     state: shard-1/     state: shard-2/
//! ```
//!
//! Each **shard** is a worker thread running a sequence of
//! [`Scheduler`] *generations*: whenever new work is assigned, the
//! supervisor raises the shard's cooperative pause flag; the running
//! generation finishes its round, persists every running job durably
//! ([`ServeStats::paused`](super::ServeStats)), and the worker rebuilds
//! a scheduler over its *full* assignment history — completed slots
//! [`exclude`](Scheduler::exclude)d, unfinished slots recovered from
//! their own `job-<local>.ckpt` files — so every job's positional local
//! id (and thus its state file) is stable for the shard's whole life,
//! and every pause/resume continues bit-identically (the PR 7
//! invariant).
//!
//! **Health**: shards heartbeat through a shared atomic
//! ([`now_us`](crate::obs::clock::now_us)) at fine granularity — every
//! scheduler round *and* every in-round phase (admissions, recovered
//! checkpoints, session steps, per materialized job), plus a stamp from
//! the supervisor itself on every assignment, so neither an idle gap in
//! `rx.recv()` nor one long phase reads as a stall. The supervisor
//! declares a shard dead when its worker thread exits unexpectedly
//! (panic), when it reports a fault, or when its heartbeat goes stale
//! past `stall_timeout_ms` while it holds work. [`FaultPlan`]'s
//! `kill-shard=K@R` / `stall-shard=K@R` make both paths deterministic
//! under test.
//!
//! **Migration**: a dead shard's outstanding jobs are re-placed on the
//! least-loaded survivors, each carrying the raw bytes of its durable
//! checkpoint from the dead shard's state dir (when one exists). The
//! survivor drops the bytes into its own dir under the job's new local
//! id and resumes through the normal recovery path — validation,
//! quarantine, and bit-identical continuation all come for free. A job
//! that was never checkpointed restarts from scratch, which the
//! determinism invariant makes exact, just without the saved progress.
//!
//! **Durability**: every placement is journaled to
//! `state_dir/fleet-manifest.jsonl` (the job's own trace line embedded,
//! so live-intake jobs survive too) and every terminal job is marked
//! done (or shed). A restarted fleet replays the manifest: done and
//! shed jobs are not re-run, unfinished jobs re-enter placement with
//! the freshest readable checkpoint from any shard dir they ever lived
//! in — at-least-once semantics across process boundaries. Recovery is
//! itself crash-safe: the pulled checkpoint bytes are re-persisted
//! under `state_dir/recovered/` before the shard dirs are cleared, and
//! the rebuilt manifest replaces the old journal by an atomic
//! temp-file rename, never an in-place truncate.
//!
//! **Shutdown**: a `drain` control line stops intake and lets every
//! shard finish (exit 0, state dirs empty); `halt` stops now — every
//! shard pauses, persists, and exits, leaving the manifest and
//! checkpoints for the next process.
//!
//! Generation rebuilds reset shard-local clocks: wall-clock
//! `deadline_ms` and quarantine-retry backoffs restart with each
//! generation (round budgets — `max_rounds` — stay cumulative, carried
//! by checkpoint iteration counts). Fault rounds in `kill-shard=K@R` /
//! `stall-shard=K@R` are generation-local rounds.

use super::admission::JobBank;
use super::intake::{IntakeHandle, IntakeItem};
use super::persist::{self, FaultPlan};
use super::queue::{self, Job};
use super::scheduler::{JobStats, Scheduler, ServeConfig, ServeEvent};
use super::ServeError;
use crate::obs::clock::now_us;
use crate::runtime::json::Json;
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Fleet knobs. `shard` is the per-shard scheduler template; its
/// `state_dir`, `pause`, and `fault_plan` fields are overridden per
/// shard by the supervisor.
#[derive(Clone)]
pub struct FleetConfig {
    /// Scheduler shards (worker threads), each over its own `Session`.
    pub shards: usize,
    /// Per-shard scheduler template. `opts.inner_sweeps` must be
    /// pinned: live intake can mix job kinds at any time.
    pub shard: ServeConfig,
    /// Fleet state root (`shard-<K>/` per shard + the manifest).
    /// `None` uses a fresh per-process temp dir — migration and halt
    /// still work, cross-process restart won't survive a temp cleaner.
    pub state_dir: Option<PathBuf>,
    /// Fleet-level faults: `kill-shard`/`stall-shard`/`poison` (the
    /// single-scheduler directives are rejected here).
    pub fault_plan: FaultPlan,
    /// Shed the lowest-priority *unplaced* arrivals while more than
    /// this many jobs are in flight fleet-wide. `None` never sheds.
    pub queue_high_water: Option<usize>,
    /// Declare a shard dead when it holds work but has not heartbeat
    /// for this long.
    pub stall_timeout_ms: u64,
    /// Per-shard metrics NDJSON: shard K appends to
    /// `<metrics_out>.shard<K>` (requires `shard.metrics_every > 0`).
    pub metrics_out: Option<PathBuf>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards: 1,
            shard: ServeConfig {
                opts: crate::core::problem::SolveOptions::new().inner_sweeps(2),
                ..ServeConfig::default()
            },
            state_dir: None,
            fault_plan: FaultPlan::default(),
            queue_high_water: None,
            stall_timeout_ms: 2_000,
            metrics_out: None,
        }
    }
}

/// Fleet-level events (shard serve events ride along in
/// [`FleetEvent::Shard`], their job ids translated to fleet-global
/// ids).
#[derive(Debug, Clone, PartialEq)]
pub enum FleetEvent {
    /// A job was placed on (or migrated to) a shard.
    Placed { job: usize, shard: usize, migrated: bool, with_checkpoint: bool },
    /// A malformed intake line was skipped (connection-relative
    /// 1-based line number, same reporting as a file trace).
    SkippedLine { line: usize, msg: String },
    /// An unplaced arrival was dropped under overload.
    Shed { job: usize },
    /// A shard was declared dead; its work migrates.
    ShardDead { shard: usize, cause: String },
    /// A job reached a terminal state on its shard.
    JobDone { job: usize, shard: usize, completed: bool },
    /// Intake closed (drain control line, stdin EOF, or trace-only
    /// fleet out of arrivals); the fleet finishes and exits.
    DrainStarted,
    /// A halt was ordered: shards pause, persist, and exit.
    HaltStarted,
    /// A prior process's manifest was replayed (`jobs` non-done jobs
    /// re-entered placement; the trace argument was ignored).
    Resumed { jobs: usize, done_prior: usize },
    /// A serve event from a live shard, job ids fleet-global.
    Shard { shard: usize, event: ServeEvent },
}

/// A [`FleetEvent`] stamped with a fleet-wide sequence number and the
/// obs-clock microsecond timestamp of emission.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetLogEntry {
    pub seq: u64,
    pub at_us: u64,
    pub event: FleetEvent,
}

/// Per-shard service record.
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Jobs ever assigned (including migrated-in).
    pub assigned: usize,
    /// Jobs that completed here.
    pub completed: usize,
    /// Cumulative scheduler rounds across all generations.
    pub rounds: usize,
    pub dead: bool,
    pub cause: Option<String>,
}

/// Per-job fleet record.
#[derive(Debug, Clone)]
pub struct FleetJobStats {
    pub name: String,
    pub kind: &'static str,
    pub priority: i64,
    /// The shard the job last lived on.
    pub shard: usize,
    /// Times the job was migrated off a dead shard.
    pub migrations: usize,
    /// Completed by a *previous* process (manifest replay); the result
    /// itself lived and died with that process.
    pub done_prior: bool,
    /// Terminal shard-level record (None while in flight, or for
    /// `done_prior` jobs).
    pub stats: Option<JobStats>,
}

impl FleetJobStats {
    /// The job finished successfully (here or in a prior process).
    pub fn completed(&self) -> bool {
        self.done_prior || self.stats.as_ref().is_some_and(|s| s.completed_round.is_some())
    }
}

/// What a fleet run did.
#[derive(Debug, Clone)]
pub struct FleetStats {
    pub shards: Vec<ShardStats>,
    pub jobs: Vec<FleetJobStats>,
    /// Jobs re-placed off dead shards.
    pub migrations: usize,
    /// Malformed intake lines skipped (details in `skipped`).
    pub skipped_lines: usize,
    /// The skip reports themselves, line-numbered exactly like
    /// [`parse_job_trace_lenient`](super::parse_job_trace_lenient)'s.
    pub skipped: Vec<ServeError>,
    pub completed: usize,
    /// Arrivals dropped by fleet-level high-water shedding.
    pub shed: usize,
    /// The run ended cleanly: graceful drain (everything finished) or
    /// an ordered halt (everything persisted). `false` means work was
    /// stranded with no live shard to run it.
    pub drained: bool,
    /// The run ended on a `halt` order (state persisted for restart).
    pub halted: bool,
    pub events: Vec<FleetLogEntry>,
}

impl FleetStats {
    /// Every registered job completed (in this process or a prior one).
    pub fn all_completed(&self) -> bool {
        self.jobs.iter().all(FleetJobStats::completed)
    }
}

/// Supervisor → shard: one work assignment. `ckpt` carries the raw
/// durable-checkpoint bytes a migrated job resumes from (validated by
/// the receiving scheduler's normal recovery path).
enum ShardMsg {
    Assign { job: Job, global: usize, ckpt: Option<Vec<u8>>, poisoned: bool },
}

/// Shard → supervisor reports.
enum ShardReport {
    /// A serve event, job ids already fleet-global.
    Event { shard: usize, event: ServeEvent },
    /// A job reached a terminal state (or ran out of round budget).
    JobDone { shard: usize, global: usize, stats: Box<JobStats> },
    /// The worker is dying (panic or unrecoverable config error).
    Dead { shard: usize, cause: String },
    /// The worker exited its loop (drain or halt).
    Drained { shard: usize },
}

/// Heartbeat / control block shared between one shard and the
/// supervisor.
struct ShardShared {
    /// Cumulative scheduler rounds (updated between generations).
    rounds: AtomicUsize,
    /// [`now_us`] at the last heartbeat. Stamped from three sides so
    /// staleness means a wedged worker, not an idle or busy one: the
    /// worker (generation edges, per materialized job, and the
    /// scheduler's in-round [`ServeConfig::heartbeat`] beats), the
    /// scheduler's per-round hook, and the *supervisor* on every
    /// assignment — a shard that idled in `rx.recv()` for longer than
    /// the stall timeout must not look dead the instant work arrives.
    beat_us: Arc<AtomicU64>,
    /// Set by the supervisor when the shard is declared dead; a
    /// stalled worker wakes on it and unwinds.
    dead: AtomicBool,
    /// Set by the supervisor to stop the worker at the next generation
    /// boundary (state stays persisted).
    halt: AtomicBool,
    /// The cooperative pause flag installed into each generation's
    /// [`ServeConfig::pause`].
    pause: Arc<AtomicBool>,
}

/// Payload for injected shard faults (panics carry no message; the
/// supervisor's cause string names the fault).
struct InjectedShardFault;

fn translate(e: &ServeEvent, globals: &[usize]) -> ServeEvent {
    let g = |j: usize| globals.get(j).copied().unwrap_or(j);
    match *e {
        ServeEvent::Admitted { round, job, resumed } => {
            ServeEvent::Admitted { round, job: g(job), resumed }
        }
        ServeEvent::Preempted { round, job, rounds_done } => {
            ServeEvent::Preempted { round, job: g(job), rounds_done }
        }
        ServeEvent::Completed { round, job, converged } => {
            ServeEvent::Completed { round, job: g(job), converged }
        }
        ServeEvent::Expired { round, job, rounds_done } => {
            ServeEvent::Expired { round, job: g(job), rounds_done }
        }
        ServeEvent::Idle { round } => ServeEvent::Idle { round },
        ServeEvent::Recovered { round, job, rounds_done } => {
            ServeEvent::Recovered { round, job: g(job), rounds_done }
        }
        ServeEvent::Shed { round, job, queue_depth } => {
            ServeEvent::Shed { round, job: g(job), queue_depth }
        }
        ServeEvent::Retried { round, job, attempt } => {
            ServeEvent::Retried { round, job: g(job), attempt }
        }
        ServeEvent::Quarantined { round, job, attempt } => {
            ServeEvent::Quarantined { round, job: g(job), attempt }
        }
    }
}

/// The slots of one shard worker: its full assignment history, local
/// ids positional.
#[derive(Default)]
struct ShardSlots {
    jobs: Vec<Job>,
    globals: Vec<usize>,
    poisoned: Vec<usize>,
    done: Vec<bool>,
}

impl ShardSlots {
    fn accept(&mut self, msg: ShardMsg, state_dir: &Path) {
        let ShardMsg::Assign { mut job, global, ckpt, poisoned } = msg;
        let local = self.jobs.len();
        job.id = local;
        job.arrival_round = 0;
        if poisoned {
            self.poisoned.push(local);
        }
        if let Some(bytes) = ckpt {
            // Drop the migrated checkpoint into our own state dir under
            // the new local id; the next generation's recovery scan
            // validates it (and quarantines it if the dead shard left
            // it corrupt — the job then restarts from scratch).
            let _ = std::fs::create_dir_all(state_dir);
            let path = persist::checkpoint_path(state_dir, local);
            let tmp = state_dir.join(format!("job-{local}.ckpt.tmp"));
            let _ = std::fs::write(&tmp, &bytes).and_then(|_| std::fs::rename(&tmp, &path));
        }
        self.jobs.push(job);
        self.globals.push(global);
        self.done.push(false);
    }

    fn has_work(&self) -> bool {
        self.done.iter().any(|d| !d)
    }
}

/// One shard worker: a loop of scheduler generations over the shard's
/// full assignment history (stable positional local ids).
#[allow(clippy::too_many_arguments)]
fn shard_worker(
    shard: usize,
    template: ServeConfig,
    state_dir: PathBuf,
    metrics_path: Option<PathBuf>,
    kill_round: Option<usize>,
    stall_round: Option<usize>,
    rx: Receiver<ShardMsg>,
    report: Sender<ShardReport>,
    shared: Arc<ShardShared>,
) {
    let mut slots = ShardSlots::default();
    let mut rounds_total = 0usize;
    loop {
        if shared.halt.load(Relaxed) {
            break;
        }
        if !slots.has_work() {
            // Idle: block for work. A closed channel is the drain order.
            match rx.recv() {
                Ok(msg) => slots.accept(msg, &state_dir),
                Err(_) => break,
            }
        }
        // Clear the nudge *before* draining the backlog: a nudge
        // arriving after this point pauses the next generation, which
        // then picks its assignment up here.
        shared.pause.store(false, Relaxed);
        while let Ok(msg) = rx.try_recv() {
            slots.accept(msg, &state_dir);
        }
        if shared.halt.load(Relaxed) {
            break;
        }
        shared.beat_us.store(now_us(), Relaxed);

        // One generation: a scheduler over the full assignment
        // history, finished slots excluded, unfinished ones recovered
        // from this shard's own state dir.
        let gen_jobs = slots.jobs.clone();
        let bank =
            JobBank::materialize_with(&gen_jobs, || shared.beat_us.store(now_us(), Relaxed));
        let cfg = ServeConfig {
            state_dir: Some(state_dir.clone()),
            pause: Some(Arc::clone(&shared.pause)),
            heartbeat: Some(Arc::clone(&shared.beat_us)),
            max_service_rounds: template.max_service_rounds.saturating_sub(rounds_total).max(1),
            fault_plan: FaultPlan {
                poison_spec: slots.poisoned.clone(),
                ..FaultPlan::default()
            },
            ..template.clone()
        };
        let mut sched = match Scheduler::new(gen_jobs, &bank, cfg) {
            Ok(s) => s,
            Err(e) => {
                shared.dead.store(true, Relaxed);
                let _ = report.send(ShardReport::Dead { shard, cause: e.to_string() });
                return;
            }
        };
        for (local, d) in slots.done.iter().enumerate() {
            if *d {
                sched.exclude(local);
            }
        }
        if let Some(path) = &metrics_path {
            if let Ok(f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
                sched.metrics_to(f);
            }
        }
        let globals = slots.globals.clone();
        let rep = report.clone();
        sched.on_event(move |e| {
            let _ = rep.send(ShardReport::Event { shard, event: translate(e, &globals) });
        });
        let beat = Arc::clone(&shared);
        sched.on_round(move |round| {
            if stall_round.is_some_and(|r| round >= r) {
                // Injected stall: freeze with the heartbeat stopped;
                // wake only when the supervisor declares us dead.
                while !beat.dead.load(Relaxed) {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                std::panic::panic_any(InjectedShardFault);
            }
            beat.beat_us.store(now_us(), Relaxed);
            if kill_round.is_some_and(|r| round >= r) {
                std::panic::panic_any(InjectedShardFault);
            }
        });
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || sched.run())) {
            Ok(stats) => {
                rounds_total += stats.rounds;
                shared.rounds.store(rounds_total, Relaxed);
                shared.beat_us.store(now_us(), Relaxed);
                for (local, js) in stats.jobs.iter().enumerate() {
                    if slots.done[local] {
                        continue;
                    }
                    let terminal = js.completed_round.is_some()
                        || js.expired
                        || js.shed
                        || js.failed;
                    // Non-terminal slots after a *pause* resume next
                    // generation; after an exhausted round budget they
                    // are surrendered as-is (no spinning).
                    if terminal || !stats.paused {
                        slots.done[local] = true;
                        let _ = report.send(ShardReport::JobDone {
                            shard,
                            global: slots.globals[local],
                            stats: Box::new(js.clone()),
                        });
                    }
                }
            }
            Err(_) => {
                // A panicked generation (injected fault or real bug):
                // whatever checkpoints were last persisted are the
                // migration medium. Report and die.
                shared.dead.store(true, Relaxed);
                let _ =
                    report.send(ShardReport::Dead { shard, cause: "worker panicked".to_string() });
                return;
            }
        }
    }
    let _ = report.send(ShardReport::Drained { shard });
}

/// How a seed job enters the registry at startup.
#[derive(Clone, Copy, PartialEq)]
enum SeedFate {
    /// Re-enters placement (possibly with recovered checkpoint bytes).
    Live,
    /// Completed by a prior process; registered, never re-run.
    DonePrior,
    /// Shed by a prior process; registered with its terminal shed
    /// record, never re-run.
    ShedPrior,
}

/// A job recovered from a prior process's manifest.
struct RecoveredJob {
    job: Job,
    done: bool,
    /// Terminal by fleet-level shedding (never completed) — replayed so
    /// a shed job does not resurrect after a restart.
    shed: bool,
    /// Every `(shard, local)` the job was ever assigned, oldest first.
    assigns: Vec<(usize, usize)>,
}

/// Replay a fleet manifest (NDJSON). Unparseable lines are skipped —
/// a torn final append must not block recovery of everything before
/// it.
fn replay_manifest(text: &str) -> Vec<RecoveredJob> {
    let mut slots: Vec<Option<RecoveredJob>> = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(obj) = Json::parse(line) else { continue };
        let op = obj.get("op").and_then(Json::as_str).unwrap_or("");
        let Some(global) = obj.get("global").and_then(Json::as_usize) else { continue };
        match op {
            "accept" | "assign" | "done-prior" => {
                let Some(jline) = obj.get("line").and_then(Json::as_str) else { continue };
                let Ok(mut job) = queue::parse_intake_line(jline, 0, global) else { continue };
                job.id = global;
                if slots.len() <= global {
                    slots.resize_with(global + 1, || None);
                }
                let slot = slots[global].get_or_insert_with(|| RecoveredJob {
                    job: job.clone(),
                    done: false,
                    shed: false,
                    assigns: Vec::new(),
                });
                slot.job = job;
                if op == "done-prior" {
                    slot.done = true;
                } else if let (Some(shard), Some(local)) = (
                    obj.get("shard").and_then(Json::as_usize),
                    obj.get("local").and_then(Json::as_usize),
                ) {
                    slot.assigns.push((shard, local));
                }
            }
            "done" => {
                if let Some(Some(slot)) = slots.get_mut(global) {
                    slot.done = true;
                }
            }
            "shed" => {
                if let Some(Some(slot)) = slots.get_mut(global) {
                    slot.done = true;
                    slot.shed = true;
                }
            }
            _ => {}
        }
    }
    slots.into_iter().flatten().collect()
}

fn manifest_path(root: &Path) -> PathBuf {
    root.join("fleet-manifest.jsonl")
}

/// Where a restarting fleet re-persists the checkpoint bytes it pulled
/// out of the (about-to-be-cleared) shard dirs, so a crash *during*
/// recovery still leaves every migrated checkpoint on disk. Files here
/// are the lookup of last resort — a newer checkpoint under a shard
/// dir (journaled `assign`) always wins — and are removed when their
/// job reaches a terminal state.
fn recovered_ckpt_path(root: &Path, global: usize) -> PathBuf {
    root.join("recovered").join(format!("job-{global}.ckpt"))
}

fn journal(file: &mut Option<std::fs::File>, line: String) {
    if let Some(f) = file {
        use std::io::Write;
        let _ = writeln!(f, "{line}");
    }
}

fn manifest_job_line(job: &Job) -> String {
    queue::json_escape(&job.to_json_line())
}

/// Declare a shard dead and queue its outstanding work for migration:
/// read each job's durable checkpoint bytes from the dead shard's
/// state dir (the files are atomically renamed into place, and nothing
/// writes them once `dead` is raised), then requeue the jobs in global
/// order. Returns the events to emit.
fn declare_dead(
    shard: usize,
    cause: String,
    root: &Path,
    stats: &mut FleetStats,
    shared: &[Arc<ShardShared>],
    txs: &mut [Option<Sender<ShardMsg>>],
    assigned_seq: &[Vec<usize>],
    outstanding: &mut [Vec<usize>],
    pending: &mut VecDeque<(usize, Option<Vec<u8>>, bool)>,
) -> Vec<FleetEvent> {
    if stats.shards[shard].dead {
        return Vec::new();
    }
    stats.shards[shard].dead = true;
    stats.shards[shard].cause = Some(cause.clone());
    // Order matters: mark dead (wakes a stalled worker into its
    // unwind), read the checkpoint bytes while nothing can be writing
    // them, *then* ask any false-positive zombie to pause-and-exit.
    shared[shard].dead.store(true, Relaxed);
    let events = vec![FleetEvent::ShardDead { shard, cause }];
    let dir = root.join(format!("shard-{shard}"));
    let mut work: Vec<usize> = std::mem::take(&mut outstanding[shard]);
    work.sort_unstable();
    for global in work {
        let local = assigned_seq[shard].iter().position(|&g| g == global);
        // The dead shard's own file is the freshest; a shard that died
        // before ever accepting a replayed job falls back to the copy
        // recovery persisted under `recovered/`.
        let bytes = local
            .and_then(|l| std::fs::read(persist::checkpoint_path(&dir, l)).ok())
            .or_else(|| std::fs::read(recovered_ckpt_path(root, global)).ok());
        stats.jobs[global].migrations += 1;
        stats.migrations += 1;
        pending.push_back((global, bytes, true));
    }
    shared[shard].halt.store(true, Relaxed);
    shared[shard].pause.store(true, Relaxed);
    txs[shard] = None;
    events
}

impl JobStats {
    /// A terminal record for a job the *supervisor* dropped before any
    /// scheduler ever saw it (fleet-level shedding).
    fn shed_placeholder(job: &Job) -> JobStats {
        JobStats {
            name: job.name.clone(),
            kind: job.spec.kind(),
            priority: job.priority,
            arrival_round: 0,
            admitted_round: None,
            completed_round: None,
            preemptions: 0,
            rounds_run: 0,
            projections: 0,
            converged: false,
            expired: false,
            deadline_met: Some(false),
            objective: None,
            phases: Default::default(),
            result: None,
            shed: true,
            failed: false,
            retries: 0,
            recovered: false,
            error: None,
        }
    }
}

/// Fallback state roots for fleets without an explicit `state_dir`
/// (distinct per call so parallel tests never collide).
static TEMP_ROOT_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Run a supervised fleet to completion. `initial_jobs` seed the
/// global registry (ignored when a prior process's manifest is
/// replayed — the manifest is canonical); `intake` optionally feeds
/// live arrivals until a drain/halt. Returns when every job reached a
/// terminal state (graceful drain), on an ordered halt (state
/// persisted), or when work is stranded with no live shard
/// (`drained = false`; the CLI exits nonzero).
pub fn run_fleet(
    initial_jobs: Vec<Job>,
    intake: Option<IntakeHandle>,
    cfg: FleetConfig,
    mut on_event: impl FnMut(&FleetEvent),
) -> Result<FleetStats, ServeError> {
    let bad = |msg: String| ServeError::Config { msg };
    if cfg.shards < 1 {
        return Err(bad("fleet needs at least one shard".to_string()));
    }
    if cfg.shard.opts.inner_sweeps.is_none() {
        return Err(bad(
            "fleet serving must pin SolveOptions::inner_sweeps (live intake can mix job \
             kinds at any time)"
                .to_string(),
        ));
    }
    let plan = cfg.fault_plan.clone();
    if plan.crash_after_round.is_some()
        || plan.corrupt_checkpoint.is_some()
        || plan.garble_trace_line.is_some()
    {
        return Err(bad(
            "crash=/corrupt=/garble= are single-scheduler faults; the fleet supervisor \
             supports kill-shard=, stall-shard=, and poison="
                .to_string(),
        ));
    }
    for (what, f) in [("kill-shard", plan.kill_shard), ("stall-shard", plan.stall_shard)] {
        if let Some((shard, _)) = f {
            if shard >= cfg.shards {
                return Err(bad(format!(
                    "{what} names shard {shard}, but the fleet has {} shards",
                    cfg.shards
                )));
            }
        }
    }

    let root = cfg.state_dir.clone().unwrap_or_else(|| {
        std::env::temp_dir().join(format!(
            "paf-fleet-{}-{}",
            std::process::id(),
            TEMP_ROOT_SEQ.fetch_add(1, Relaxed)
        ))
    });
    std::fs::create_dir_all(&root)
        .map_err(|e| ServeError::Io { path: root.display().to_string(), msg: e.to_string() })?;

    // Replay a prior process's manifest (if any), pulling each
    // unfinished job's freshest readable checkpoint bytes into memory:
    // newest journaled `assign` first across the shard dirs, falling
    // back to the `recovered/` copy a prior *recovery* persisted.
    let mpath = manifest_path(&root);
    let recovered = match std::fs::read_to_string(&mpath) {
        Ok(text) => replay_manifest(&text),
        Err(_) => Vec::new(),
    };
    let replayed = !recovered.is_empty();
    let mut seeds: Vec<(Job, Option<Vec<u8>>, SeedFate)> = Vec::new();
    let mut resumed_event = None;
    if !replayed {
        for (i, mut job) in initial_jobs.into_iter().enumerate() {
            job.id = i;
            seeds.push((job, None, SeedFate::Live));
        }
    } else {
        let mut live = 0usize;
        let mut prior = 0usize;
        for r in recovered {
            let global = seeds.len();
            let fate = if r.shed {
                SeedFate::ShedPrior
            } else if r.done {
                SeedFate::DonePrior
            } else {
                SeedFate::Live
            };
            let bytes = if fate == SeedFate::Live {
                r.assigns
                    .iter()
                    .rev()
                    .find_map(|&(shard, local)| {
                        std::fs::read(persist::checkpoint_path(
                            &root.join(format!("shard-{shard}")),
                            local,
                        ))
                        .ok()
                    })
                    .or_else(|| std::fs::read(recovered_ckpt_path(&root, global)).ok())
            } else {
                None
            };
            if fate == SeedFate::Live {
                live += 1;
            } else {
                prior += 1;
            }
            seeds.push((r.job, bytes, fate));
        }
        resumed_event = Some(FleetEvent::Resumed { jobs: live, done_prior: prior });
    }
    // Crash-safe recovery order — a crash at any point below must leave
    // a state the *next* restart fully recovers from:
    //   1. re-persist every live job's freshest checkpoint bytes under
    //      `recovered/` (the shard dirs are about to be cleared and
    //      local ids restart from zero, so those copies become
    //      unreachable);
    //   2. atomically swap in the rebuilt manifest (temp file + rename),
    //      so the journal is always either the complete old registry or
    //      the complete new one, never a truncated half;
    //   3. only then clear the shard dirs (stale local ids must not
    //      leak into a new shard's recovery scan).
    if replayed {
        let rdir = root.join("recovered");
        std::fs::create_dir_all(&rdir).map_err(|e| ServeError::Io {
            path: rdir.display().to_string(),
            msg: e.to_string(),
        })?;
        for (global, (_, bytes, fate)) in seeds.iter().enumerate() {
            let path = recovered_ckpt_path(&root, global);
            match bytes {
                Some(b) if *fate == SeedFate::Live => {
                    let tmp = rdir.join(format!("job-{global}.ckpt.tmp"));
                    let _ = std::fs::write(&tmp, b).and_then(|_| std::fs::rename(&tmp, &path));
                }
                _ => {
                    let _ = std::fs::remove_file(&path);
                }
            }
        }
        use std::fmt::Write as _;
        let mut rebuilt = String::new();
        for (global, (job, _, fate)) in seeds.iter().enumerate() {
            let line = manifest_job_line(job);
            let _ = writeln!(
                rebuilt,
                "{{\"op\": \"accept\", \"global\": {global}, \"line\": \"{line}\"}}"
            );
            match fate {
                SeedFate::DonePrior => {
                    let _ = writeln!(
                        rebuilt,
                        "{{\"op\": \"done-prior\", \"global\": {global}, \"line\": \"{line}\"}}"
                    );
                }
                SeedFate::ShedPrior => {
                    let _ = writeln!(rebuilt, "{{\"op\": \"shed\", \"global\": {global}}}");
                }
                SeedFate::Live => {}
            }
        }
        let tmp = root.join("fleet-manifest.jsonl.tmp");
        std::fs::write(&tmp, rebuilt)
            .and_then(|_| std::fs::rename(&tmp, &mpath))
            .map_err(|e| ServeError::Io {
                path: mpath.display().to_string(),
                msg: e.to_string(),
            })?;
    }
    for shard in 0..cfg.shards {
        let dir = root.join(format!("shard-{shard}"));
        if let Ok(found) = persist::scan_state_dir(&dir) {
            for (_, path) in found {
                let _ = std::fs::remove_file(path);
            }
        }
    }
    // The rebuilt manifest already journals the recovered registry
    // (accept + done-prior/shed records), so a replayed run appends to
    // it; a fresh run starts its journal from scratch.
    let mut manifest = if replayed {
        std::fs::OpenOptions::new().append(true).open(&mpath).ok()
    } else {
        std::fs::OpenOptions::new().create(true).write(true).truncate(true).open(&mpath).ok()
    };
    // Seed registration must not re-journal what the rebuilt manifest
    // already holds.
    let mut journal_accepts = !replayed;

    // Spawn the shards.
    let (report_tx, report_rx) = std::sync::mpsc::channel::<ShardReport>();
    let mut txs: Vec<Option<Sender<ShardMsg>>> = Vec::new();
    let mut handles: Vec<Option<std::thread::JoinHandle<()>>> = Vec::new();
    let mut shared: Vec<Arc<ShardShared>> = Vec::new();
    for shard in 0..cfg.shards {
        let (tx, rx) = std::sync::mpsc::channel::<ShardMsg>();
        let sh = Arc::new(ShardShared {
            rounds: AtomicUsize::new(0),
            beat_us: Arc::new(AtomicU64::new(now_us())),
            dead: AtomicBool::new(false),
            halt: AtomicBool::new(false),
            pause: Arc::new(AtomicBool::new(false)),
        });
        let kill = plan.kill_shard.and_then(|(s, r)| (s == shard).then_some(r));
        let stall = plan.stall_shard.and_then(|(s, r)| (s == shard).then_some(r));
        let metrics_path = cfg
            .metrics_out
            .as_ref()
            .map(|p| PathBuf::from(format!("{}.shard{shard}", p.display())));
        let template = cfg.shard.clone();
        let state_dir = root.join(format!("shard-{shard}"));
        let rep = report_tx.clone();
        let sh2 = Arc::clone(&sh);
        let handle = std::thread::Builder::new()
            .name(format!("paf-shard-{shard}"))
            .spawn(move || {
                shard_worker(shard, template, state_dir, metrics_path, kill, stall, rx, rep, sh2)
            })
            .map_err(|e| ServeError::Io {
                path: format!("<shard {shard} thread>"),
                msg: e.to_string(),
            })?;
        txs.push(Some(tx));
        handles.push(Some(handle));
        shared.push(sh);
    }
    drop(report_tx);

    // Supervisor state.
    let mut stats = FleetStats {
        shards: vec![ShardStats::default(); cfg.shards],
        jobs: Vec::new(),
        migrations: 0,
        skipped_lines: 0,
        skipped: Vec::new(),
        completed: 0,
        shed: 0,
        drained: false,
        halted: false,
        events: Vec::new(),
    };
    let mut jobs: Vec<Job> = Vec::new();
    let mut assigned_seq: Vec<Vec<usize>> = vec![Vec::new(); cfg.shards];
    let mut outstanding: Vec<Vec<usize>> = vec![Vec::new(); cfg.shards];
    // (global, checkpoint bytes, migrated?) awaiting placement.
    let mut pending: VecDeque<(usize, Option<Vec<u8>>, bool)> = VecDeque::new();
    let mut next_seq = 0u64;
    let mut intake_open = intake.is_some();
    let mut halting = false;
    let mut stranded = false;
    let mut drain_announced = false;

    macro_rules! emit {
        ($ev:expr) => {{
            let event = $ev;
            on_event(&event);
            stats.events.push(FleetLogEntry { seq: next_seq, at_us: now_us(), event });
            next_seq += 1;
        }};
    }
    macro_rules! register {
        ($job:expr) => {{
            let mut job: Job = $job;
            let global = jobs.len();
            job.id = global;
            stats.jobs.push(FleetJobStats {
                name: job.name.clone(),
                kind: job.spec.kind(),
                priority: job.priority,
                shard: 0,
                migrations: 0,
                done_prior: false,
                stats: None,
            });
            // Journal acceptance immediately: a job the fleet has
            // taken must survive a restart even if a halt lands
            // before it is ever placed on a shard. (Replayed seeds are
            // already in the rebuilt manifest — not re-journaled.)
            if journal_accepts {
                journal(
                    &mut manifest,
                    format!(
                        "{{\"op\": \"accept\", \"global\": {global}, \"line\": \"{}\"}}",
                        manifest_job_line(&job)
                    ),
                );
            }
            jobs.push(job);
            global
        }};
    }
    macro_rules! job_done {
        ($shard:expr, $global:expr, $js:expr) => {{
            let (shard, global, js): (usize, usize, Box<JobStats>) = ($shard, $global, $js);
            if !stats.shards[shard].dead {
                outstanding[shard].retain(|&g| g != global);
                let completed = js.completed_round.is_some();
                if completed {
                    stats.completed += 1;
                    stats.shards[shard].completed += 1;
                }
                stats.jobs[global].stats = Some(*js);
                journal(&mut manifest, format!("{{\"op\": \"done\", \"global\": {global}}}"));
                let _ = std::fs::remove_file(recovered_ckpt_path(&root, global));
                emit!(FleetEvent::JobDone { job: global, shard, completed });
            }
        }};
    }

    if let Some(ev) = resumed_event {
        emit!(ev);
    }
    for (job, bytes, fate) in seeds {
        let global = register!(job);
        match fate {
            SeedFate::DonePrior => {
                stats.jobs[global].done_prior = true;
                stats.completed += 1;
            }
            SeedFate::ShedPrior => {
                // Terminal in a prior process: keep its shed record so
                // it is reported consistently, but never re-run it.
                stats.jobs[global].stats = Some(JobStats::shed_placeholder(&jobs[global]));
                stats.shed += 1;
            }
            SeedFate::Live => pending.push_back((global, bytes, false)),
        }
    }
    journal_accepts = true;

    loop {
        // 1. Live intake (non-blocking): register arrivals, record
        // skips, honor drain/halt orders.
        if intake_open {
            let rx = &intake.as_ref().expect("intake_open implies a handle").rx;
            loop {
                match rx.try_recv() {
                    Ok(IntakeItem::Job(job)) => {
                        let global = register!(job);
                        pending.push_back((global, None, false));
                    }
                    Ok(IntakeItem::Skip(e)) => {
                        stats.skipped_lines += 1;
                        let (line, msg) = match &e {
                            ServeError::Trace { line, msg } => (*line, msg.clone()),
                            other => (0, other.to_string()),
                        };
                        stats.skipped.push(e);
                        emit!(FleetEvent::SkippedLine { line, msg });
                    }
                    Ok(IntakeItem::Drain) => {
                        intake_open = false;
                        drain_announced = true;
                        emit!(FleetEvent::DrainStarted);
                        break;
                    }
                    Ok(IntakeItem::Halt) => {
                        intake_open = false;
                        halting = true;
                        emit!(FleetEvent::HaltStarted);
                        break;
                    }
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        intake_open = false;
                        drain_announced = true;
                        emit!(FleetEvent::DrainStarted);
                        break;
                    }
                }
            }
        }

        if halting {
            for sh in &shared {
                sh.halt.store(true, Relaxed);
                sh.pause.store(true, Relaxed);
            }
            for tx in &mut txs {
                *tx = None;
            }
        }

        // 2. Overload control: shed the lowest-priority unplaced
        // arrivals while the fleet holds more than high-water jobs.
        if let Some(hw) = cfg.queue_high_water {
            let in_flight: usize = outstanding.iter().map(Vec::len).sum();
            while !pending.is_empty() && in_flight + pending.len() > hw {
                let worst = pending
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, (g, _, _))| (jobs[*g].priority, std::cmp::Reverse(*g)))
                    .map(|(i, _)| i)
                    .expect("non-empty pending");
                let Some((global, _, _)) = pending.remove(worst) else { break };
                stats.jobs[global].stats = Some(JobStats::shed_placeholder(&jobs[global]));
                stats.shed += 1;
                // Sheds are terminal: journal them so a manifest replay
                // does not resurrect and run a job already reported
                // dropped.
                journal(&mut manifest, format!("{{\"op\": \"shed\", \"global\": {global}}}"));
                let _ = std::fs::remove_file(recovered_ckpt_path(&root, global));
                emit!(FleetEvent::Shed { job: global });
            }
        }

        // 3. Placement: least-loaded live shard, jobs in arrival
        // order; each shard that got work is nudged once at the end
        // (its running generation pauses and picks the work up).
        if !halting {
            let mut nudged: Vec<usize> = Vec::new();
            while let Some((global, ckpt, migrated)) = pending.pop_front() {
                let target = (0..cfg.shards)
                    .filter(|&s| !stats.shards[s].dead && txs[s].is_some())
                    .min_by_key(|&s| (outstanding[s].len(), s));
                let Some(to) = target else {
                    pending.push_front((global, ckpt, migrated));
                    stranded = true;
                    break;
                };
                let local = assigned_seq[to].len();
                assigned_seq[to].push(global);
                outstanding[to].push(global);
                stats.shards[to].assigned += 1;
                stats.jobs[global].shard = to;
                let with_checkpoint = ckpt.is_some();
                let poisoned = plan.poison_spec.contains(&global);
                journal(
                    &mut manifest,
                    format!(
                        "{{\"op\": \"assign\", \"global\": {global}, \"shard\": {to}, \
                         \"local\": {local}, \"line\": \"{}\"}}",
                        manifest_job_line(&jobs[global])
                    ),
                );
                let sent = txs[to].as_ref().expect("placement only targets live senders").send(
                    ShardMsg::Assign { job: jobs[global].clone(), global, ckpt, poisoned },
                );
                if let Err(std::sync::mpsc::SendError(ShardMsg::Assign { ckpt, .. })) = sent {
                    // The shard died between the liveness check and the
                    // send; undo — keeping the checkpoint bytes the
                    // failed message still carries — and let the health
                    // pass migrate it.
                    assigned_seq[to].pop();
                    outstanding[to].retain(|&g| g != global);
                    stats.shards[to].assigned -= 1;
                    pending.push_front((global, ckpt, migrated));
                    break;
                }
                // Count the hand-off itself as a heartbeat: the worker
                // last beat at its previous generation's end, and an
                // idle gap longer than the stall timeout must not read
                // as a stall the moment the shard holds work again.
                shared[to].beat_us.store(now_us(), Relaxed);
                emit!(FleetEvent::Placed { job: global, shard: to, migrated, with_checkpoint });
                if !nudged.contains(&to) {
                    nudged.push(to);
                }
            }
            for s in nudged {
                shared[s].pause.store(true, Relaxed);
            }
        }

        // 4. Shard reports.
        loop {
            match report_rx.try_recv() {
                Ok(ShardReport::Event { shard, event }) => {
                    if !stats.shards[shard].dead {
                        emit!(FleetEvent::Shard { shard, event });
                    }
                }
                Ok(ShardReport::JobDone { shard, global, stats: js }) => {
                    job_done!(shard, global, js);
                }
                Ok(ShardReport::Dead { shard, cause }) => {
                    let evs = declare_dead(
                        shard,
                        cause,
                        &root,
                        &mut stats,
                        &shared,
                        &mut txs,
                        &assigned_seq,
                        &mut outstanding,
                        &mut pending,
                    );
                    for ev in evs {
                        emit!(ev);
                    }
                }
                Ok(ShardReport::Drained { .. }) => {}
                Err(_) => break,
            }
        }

        // 5. Health: a shard holding work is dead when its thread
        // exited or its heartbeat went stale.
        for shard in 0..cfg.shards {
            if stats.shards[shard].dead || outstanding[shard].is_empty() {
                continue;
            }
            let exited = handles[shard].as_ref().is_some_and(|h| h.is_finished());
            let stale = now_us().saturating_sub(shared[shard].beat_us.load(Relaxed))
                > cfg.stall_timeout_ms.saturating_mul(1_000);
            if exited || stale {
                let cause = if exited {
                    "worker thread exited with work outstanding".to_string()
                } else {
                    format!("heartbeat stalled past {} ms", cfg.stall_timeout_ms)
                };
                let evs = declare_dead(
                    shard,
                    cause,
                    &root,
                    &mut stats,
                    &shared,
                    &mut txs,
                    &assigned_seq,
                    &mut outstanding,
                    &mut pending,
                );
                for ev in evs {
                    emit!(ev);
                }
            }
        }

        // 6. Termination.
        let in_flight: usize = outstanding.iter().map(Vec::len).sum();
        if halting {
            if handles.iter().flatten().all(|h| h.is_finished()) {
                for h in handles.iter_mut().filter_map(Option::take) {
                    let _ = h.join();
                }
                while let Ok(report) = report_rx.try_recv() {
                    if let ShardReport::JobDone { shard, global, stats: js } = report {
                        job_done!(shard, global, js);
                    }
                }
                stats.drained = true;
                stats.halted = true;
                break;
            }
        } else if stranded && (in_flight > 0 || !pending.is_empty()) {
            // Work left, nobody alive to run it.
            for tx in &mut txs {
                *tx = None;
            }
            for h in handles.iter_mut().filter_map(Option::take) {
                let _ = h.join();
            }
            stats.drained = false;
            break;
        } else if !intake_open && pending.is_empty() && in_flight == 0 {
            // Graceful drain: close the assign channels; idle workers
            // wake on the disconnect and exit.
            if !drain_announced {
                drain_announced = true;
                emit!(FleetEvent::DrainStarted);
            }
            for tx in &mut txs {
                *tx = None;
            }
            for h in handles.iter_mut().filter_map(Option::take) {
                let _ = h.join();
            }
            while let Ok(report) = report_rx.try_recv() {
                match report {
                    ShardReport::JobDone { shard, global, stats: js } => {
                        job_done!(shard, global, js);
                    }
                    ShardReport::Event { shard, event } => {
                        if !stats.shards[shard].dead {
                            emit!(FleetEvent::Shard { shard, event });
                        }
                    }
                    _ => {}
                }
            }
            stats.drained = true;
            break;
        }

        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    for shard in 0..cfg.shards {
        stats.shards[shard].rounds = shared[shard].rounds.load(Relaxed);
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::problem::SolveOptions;
    use crate::serve::JobSpec;

    fn job(id: usize, n: usize) -> Job {
        Job {
            id,
            name: format!("j{id}"),
            spec: JobSpec::Nearness { n, graph_type: 1, seed: id as u64 + 1 },
            priority: 0,
            arrival_round: 0,
            max_rounds: None,
            deadline_rounds: None,
            deadline_ms: None,
        }
    }

    #[test]
    fn fleet_config_validation_is_typed() {
        let err = |cfg: FleetConfig| match run_fleet(vec![job(0, 8)], None, cfg, |_| {}) {
            Err(ServeError::Config { msg }) => msg,
            other => panic!("expected Config error, got {other:?}"),
        };
        assert!(err(FleetConfig { shards: 0, ..Default::default() }).contains("shard"));
        let unpinned = FleetConfig {
            shard: ServeConfig { opts: SolveOptions::new(), ..ServeConfig::default() },
            ..Default::default()
        };
        assert!(err(unpinned).contains("inner_sweeps"));
        let crashy = FleetConfig {
            fault_plan: FaultPlan { crash_after_round: Some(3), ..Default::default() },
            ..Default::default()
        };
        assert!(err(crashy).contains("single-scheduler"));
        let out_of_range = FleetConfig {
            shards: 2,
            fault_plan: FaultPlan { kill_shard: Some((5, 1)), ..Default::default() },
            ..Default::default()
        };
        assert!(err(out_of_range).contains("shard 5"));
    }

    #[test]
    fn manifest_replay_reconstructs_jobs_assignments_and_doneness() {
        let j0 = job(0, 8).to_json_line();
        let j1 = job(1, 9).to_json_line();
        let j2 = job(2, 10).to_json_line();
        let text = format!(
            "{{\"op\": \"assign\", \"global\": 0, \"shard\": 0, \"local\": 0, \"line\": \"{}\"}}\n\
             {{\"op\": \"assign\", \"global\": 1, \"shard\": 1, \"local\": 0, \"line\": \"{}\"}}\n\
             {{\"op\": \"assign\", \"global\": 1, \"shard\": 0, \"local\": 1, \"line\": \"{}\"}}\n\
             {{\"op\": \"done\", \"global\": 0}}\n\
             {{\"op\": \"accept\", \"global\": 2, \"line\": \"{}\"}}\n\
             this line is torn garbage\n",
            queue::json_escape(&j0),
            queue::json_escape(&j1),
            queue::json_escape(&j1),
            queue::json_escape(&j2),
        );
        let recovered = replay_manifest(&text);
        assert_eq!(recovered.len(), 3);
        assert!(
            !recovered[2].done && recovered[2].assigns.is_empty(),
            "an accepted-but-never-placed job survives with no assignments"
        );
        assert!(recovered[0].done);
        assert_eq!(recovered[0].assigns, vec![(0, 0)]);
        assert!(!recovered[1].done, "job 1 is still in flight");
        assert_eq!(
            recovered[1].assigns,
            vec![(1, 0), (0, 1)],
            "both assignments survive, oldest first (newest wins the checkpoint lookup)"
        );
        assert_eq!(recovered[1].job.spec, job(1, 9).spec);
        assert_eq!(recovered[1].job.id, 1, "globals are re-pinned on replay");
    }

    #[test]
    fn done_prior_jobs_survive_a_second_replay() {
        let line = queue::json_escape(&job(0, 8).to_json_line());
        let text = format!("{{\"op\": \"done-prior\", \"global\": 0, \"line\": \"{line}\"}}\n");
        let recovered = replay_manifest(&text);
        assert_eq!(recovered.len(), 1);
        assert!(recovered[0].done);
        assert!(recovered[0].assigns.is_empty());
    }

    #[test]
    fn replay_marks_shed_jobs_terminal() {
        let line = queue::json_escape(&job(0, 8).to_json_line());
        let text = format!(
            "{{\"op\": \"accept\", \"global\": 0, \"line\": \"{line}\"}}\n\
             {{\"op\": \"shed\", \"global\": 0}}\n"
        );
        let recovered = replay_manifest(&text);
        assert_eq!(recovered.len(), 1);
        assert!(
            recovered[0].done && recovered[0].shed,
            "a journaled shed is terminal — the job must not resurrect on replay"
        );
    }

    /// The crash-window invariant of recovery itself: after the rebuilt
    /// manifest has been swapped in (accept records only — the old
    /// `assign` records are gone) and the shard dirs cleared, the
    /// `recovered/` copy of each live job's checkpoint must be enough
    /// to resume it bit-identically. This simulates a process dying at
    /// exactly that point and restarting.
    #[test]
    fn recovery_resumes_from_the_recovered_dir_when_shard_dirs_are_gone() {
        let dir = std::env::temp_dir().join(format!(
            "paf-fleet-recdir-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let opts = SolveOptions::new().violation_tol(1e-4).inner_sweeps(2).sharded(0);
        let cfg = FleetConfig {
            shards: 1,
            state_dir: Some(dir.clone()),
            shard: ServeConfig {
                capacity: 2,
                checkpoint_every: Some(1),
                opts: opts.clone(),
                ..ServeConfig::default()
            },
            fault_plan: FaultPlan { kill_shard: Some((0, 2)), ..Default::default() },
            ..Default::default()
        };
        // Run 1: the only shard dies at round 2 — work strands, but the
        // durable checkpoint and the manifest survive.
        let first = run_fleet(vec![job(0, 24)], None, cfg.clone(), |_| {}).expect("valid");
        assert!(!first.drained, "one shard + kill-shard strands the work");
        let shard_ckpt = persist::checkpoint_path(&dir.join("shard-0"), 0);
        assert!(shard_ckpt.exists(), "the killed shard left a durable checkpoint");

        // Reproduce the mid-recovery crash state by hand.
        let bytes = std::fs::read(&shard_ckpt).expect("read checkpoint");
        std::fs::create_dir_all(dir.join("recovered")).expect("mk recovered");
        std::fs::write(recovered_ckpt_path(&dir, 0), &bytes).expect("persist recovered copy");
        std::fs::write(
            manifest_path(&dir),
            format!(
                "{{\"op\": \"accept\", \"global\": 0, \"line\": \"{}\"}}\n",
                manifest_job_line(&job(0, 24))
            ),
        )
        .expect("rewrite manifest as rebuilt (no assigns)");
        std::fs::remove_dir_all(dir.join("shard-0")).expect("drop shard dir");

        // Run 2: must find the recovered/ copy, resume (not restart),
        // and finish bit-identical to solo.
        let cfg2 = FleetConfig { fault_plan: FaultPlan::default(), ..cfg };
        let second = run_fleet(Vec::new(), None, cfg2, |_| {}).expect("valid");
        assert!(second.drained && second.all_completed(), "{second:?}");
        let s = second.jobs[0].stats.as_ref().expect("terminal record");
        assert!(s.recovered, "the job must resume from recovered/, not restart from scratch");
        assert!(
            !recovered_ckpt_path(&dir, 0).exists(),
            "a terminal job cleans up its recovered/ copy"
        );
        let jobs = vec![job(0, 24)];
        let bank = JobBank::materialize(&jobs);
        let solo = crate::serve::solve_job_solo(&jobs[0], bank.input(0), &opts).expect("solo");
        let got = s.result.as_ref().expect("completed job has a result");
        assert_eq!(solo.result.x, got.x, "recovered continuation must be bit-identical");
        assert_eq!(solo.result.iterations, got.iterations);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn single_shard_fleet_drains_a_small_trace() {
        let dir = std::env::temp_dir().join(format!(
            "paf-fleet-unit-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = FleetConfig {
            shards: 1,
            state_dir: Some(dir.clone()),
            shard: ServeConfig {
                capacity: 2,
                opts: SolveOptions::new().violation_tol(1e-4).inner_sweeps(2).sharded(0),
                ..ServeConfig::default()
            },
            ..Default::default()
        };
        let jobs = vec![job(0, 12), job(1, 14)];
        let stats = run_fleet(jobs, None, cfg, |_| {}).expect("valid fleet config");
        assert!(stats.drained, "a trace-only fleet must drain cleanly");
        assert!(!stats.halted);
        assert!(stats.all_completed(), "{stats:?}");
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.shards[0].assigned, 2);
        assert_eq!(stats.shards[0].completed, 2);
        assert!(!stats.shards[0].dead);
        assert_eq!(stats.migrations, 0);
        assert!(
            stats.events.iter().any(|e| matches!(e.event, FleetEvent::Placed { .. })),
            "placement events recorded"
        );
        let mut last = 0u64;
        for e in &stats.events {
            assert!(e.at_us >= last, "fleet event timestamps are monotone");
            last = e.at_us;
        }
        assert!(
            persist::scan_state_dir(&dir.join("shard-0")).expect("scan").is_empty(),
            "a drained shard leaves no state files"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
