//! Durable checkpoints: the on-disk [`BlockCheckpoint`] wire format,
//! atomic writes, quarantine, state-dir recovery scans, and the
//! deterministic [`FaultPlan`] seam the fault tests drive.
//!
//! ## File format (version 1)
//!
//! Everything is little-endian; `f64` travels as its IEEE bit pattern,
//! so a decoded checkpoint re-encodes to the identical bytes and a
//! recovered job's trajectory is bit-identical to the uninterrupted
//! solve.
//!
//! ```text
//! magic   u64   "PAFCKPT1"
//! version u32   1
//! kind    u32   1 = vector block, 2 = round-driven block
//! body    kind-specific sections, each length-prefixed
//! digest  u64   FNV-1a 64 over every preceding byte
//! ```
//!
//! The digest is verified over the whole file *before* any section is
//! parsed, so a bit-flipped length field is caught by the checksum and
//! can never drive a bogus allocation; section parsing is additionally
//! bounds-checked (`wire::Reader`) as defense in depth. Writes go to a
//! `*.tmp` sibling and `rename` into place, so a crash mid-write leaves
//! either the old checkpoint or a temp file the recovery scan ignores —
//! never a torn `*.ckpt`. Files that fail validation are moved to
//! `DIR/corrupt/` and the job restarts from scratch.

use super::ServeError;
use crate::core::constraint::Constraint;
use crate::core::session::BlockCheckpoint;
use crate::core::solver::{IterStats, PhaseTimes};
use crate::problems::itml;
use crate::util::wire::{fnv1a64, Reader, WireError, Writer};
use std::path::{Path, PathBuf};

const MAGIC: u64 = u64::from_le_bytes(*b"PAFCKPT1");
const VERSION: u32 = 1;
const KIND_VECTOR: u32 = 1;
const KIND_ROUND: u32 = 2;
/// Round-snapshot codec tags (which problem serialized the snapshot).
const SNAP_ITML: u32 = 1;

/// Exit code a `serve` process uses for an injected crash
/// ([`FaultPlan::crash_after_round`]), so the CI harness can tell a
/// planned crash from a real failure.
pub const CRASH_EXIT_CODE: i32 = 42;

fn corrupt(path: &Path, msg: impl Into<String>) -> ServeError {
    ServeError::Corrupt { path: path.display().to_string(), msg: msg.into() }
}

fn io_err(path: &Path, e: &std::io::Error) -> ServeError {
    ServeError::Io { path: path.display().to_string(), msg: e.to_string() }
}

fn wire_err(path: &Path, e: WireError) -> ServeError {
    corrupt(path, e.to_string())
}

/// Serialize a [`BlockCheckpoint`] to its on-disk bytes (header + body
/// + trailing digest). Fails only for a round-driven checkpoint whose
/// problem has no snapshot codec.
pub fn encode_checkpoint(ck: &BlockCheckpoint) -> Result<Vec<u8>, ServeError> {
    let mut w = Writer::new();
    w.put_u64(MAGIC);
    w.put_u32(VERSION);
    if let Some(v) = ck.vector_view() {
        w.put_u32(KIND_VECTOR);
        w.put_u64(v.iterations as u64);
        w.put_u64(v.projections as u64);
        w.put_f64(v.last_dual_movement);
        w.put_u64(v.x.len() as u64);
        for &xi in v.x {
            w.put_f64(xi);
        }
        w.put_u64(v.rows.len() as u64);
        for (c, z) in v.rows {
            w.put_u64(c.indices.len() as u64);
            for &i in &c.indices {
                w.put_u32(i);
            }
            for &a in &c.coeffs {
                w.put_f64(a);
            }
            w.put_f64(c.rhs);
            w.put_f64(*z);
        }
        w.put_u64(v.trace.len() as u64);
        for it in v.trace {
            put_iter_stats(&mut w, it);
        }
        w.put_f64(v.phases.oracle_s);
        w.put_f64(v.phases.sweep_s);
        w.put_f64(v.phases.forget_s);
    } else {
        let (state, iterations, projections) =
            ck.round_view().expect("checkpoint is neither vector nor round");
        w.put_u32(KIND_ROUND);
        w.put_u64(iterations as u64);
        w.put_u64(projections as u64);
        w.put_u32(SNAP_ITML);
        if !itml::encode_round_snapshot(state, &mut w) {
            return Err(ServeError::Unsupported {
                msg: "this round-driven problem has no snapshot codec".to_string(),
            });
        }
    }
    let digest = fnv1a64(w.as_slice());
    w.put_u64(digest);
    Ok(w.into_bytes())
}

/// Decode checkpoint bytes, verifying the trailing digest over the
/// whole buffer before parsing anything. `path` labels errors only.
pub fn decode_checkpoint(bytes: &[u8], path: &Path) -> Result<BlockCheckpoint, ServeError> {
    if bytes.len() < 8 + 4 + 4 + 8 {
        return Err(corrupt(path, format!("truncated: {} bytes", bytes.len())));
    }
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let digest = u64::from_le_bytes(tail.try_into().unwrap());
    let want = fnv1a64(body);
    if digest != want {
        return Err(corrupt(
            path,
            format!("checksum mismatch: file {digest:#018x}, computed {want:#018x}"),
        ));
    }
    let mut r = Reader::new(body);
    let we = |e: WireError| wire_err(path, e);
    if r.get_u64("magic").map_err(we)? != MAGIC {
        return Err(corrupt(path, "bad magic (not a checkpoint file)"));
    }
    let version = r.get_u32("version").map_err(we)?;
    if version != VERSION {
        return Err(corrupt(path, format!("unsupported version {version}")));
    }
    let kind = r.get_u32("kind").map_err(we)?;
    let ck = match kind {
        KIND_VECTOR => {
            let iterations = r.get_u64("iterations").map_err(we)? as usize;
            let projections = r.get_u64("projections").map_err(we)? as usize;
            let last_dual_movement = r.get_f64("last_dual_movement").map_err(we)?;
            let nx = r.get_count(8, "x").map_err(we)?;
            let mut x = Vec::with_capacity(nx);
            for _ in 0..nx {
                x.push(r.get_f64("x").map_err(we)?);
            }
            // A row is at least k(u32+f64) + rhs + z; 12 bytes/index is
            // the per-element floor the count check can rely on.
            let nrows = r.get_count(8 + 8 + 8, "rows").map_err(we)?;
            let mut rows = Vec::with_capacity(nrows);
            for _ in 0..nrows {
                let k = r.get_count(4 + 8, "row.indices").map_err(we)?;
                let mut indices = Vec::with_capacity(k);
                for _ in 0..k {
                    indices.push(r.get_u32("row.index").map_err(we)?);
                }
                let mut coeffs = Vec::with_capacity(k);
                for _ in 0..k {
                    coeffs.push(r.get_f64("row.coeff").map_err(we)?);
                }
                let rhs = r.get_f64("row.rhs").map_err(we)?;
                let z = r.get_f64("row.z").map_err(we)?;
                rows.push((Constraint::new(indices, coeffs, rhs), z));
            }
            let ntrace = r.get_count(12 * 8, "trace").map_err(we)?;
            let mut trace = Vec::with_capacity(ntrace);
            for _ in 0..ntrace {
                trace.push(get_iter_stats(&mut r).map_err(we)?);
            }
            let phases = PhaseTimes {
                oracle_s: r.get_f64("phases.oracle_s").map_err(we)?,
                sweep_s: r.get_f64("phases.sweep_s").map_err(we)?,
                forget_s: r.get_f64("phases.forget_s").map_err(we)?,
            };
            BlockCheckpoint::from_vector_parts(
                x,
                rows,
                iterations,
                projections,
                last_dual_movement,
                trace,
                phases,
            )
        }
        KIND_ROUND => {
            let iterations = r.get_u64("iterations").map_err(we)? as usize;
            let projections = r.get_u64("projections").map_err(we)? as usize;
            let codec = r.get_u32("snapshot.codec").map_err(we)?;
            if codec != SNAP_ITML {
                return Err(corrupt(path, format!("unknown snapshot codec {codec}")));
            }
            let state = itml::decode_round_snapshot(&mut r).map_err(we)?;
            BlockCheckpoint::from_round_parts(state, iterations, projections)
        }
        other => return Err(corrupt(path, format!("unknown checkpoint kind {other}"))),
    };
    if r.remaining() != 0 {
        return Err(corrupt(path, format!("{} trailing bytes after body", r.remaining())));
    }
    Ok(ck)
}

fn put_iter_stats(w: &mut Writer, it: &IterStats) {
    w.put_u64(it.iteration as u64);
    w.put_u64(it.found as u64);
    w.put_u64(it.merged as u64);
    w.put_u64(it.remembered as u64);
    w.put_f64(it.max_violation);
    w.put_u64(it.projections as u64);
    w.put_f64(it.seconds);
    w.put_f64(it.oracle_s);
    w.put_f64(it.sweep_s);
    w.put_f64(it.forget_s);
    w.put_u64(it.rows_projected as u64);
    w.put_u64(it.rows_skipped as u64);
}

fn get_iter_stats(r: &mut Reader<'_>) -> Result<IterStats, WireError> {
    Ok(IterStats {
        iteration: r.get_u64("trace.iteration")? as usize,
        found: r.get_u64("trace.found")? as usize,
        merged: r.get_u64("trace.merged")? as usize,
        remembered: r.get_u64("trace.remembered")? as usize,
        max_violation: r.get_f64("trace.max_violation")?,
        projections: r.get_u64("trace.projections")? as usize,
        seconds: r.get_f64("trace.seconds")?,
        oracle_s: r.get_f64("trace.oracle_s")?,
        sweep_s: r.get_f64("trace.sweep_s")?,
        forget_s: r.get_f64("trace.forget_s")?,
        rows_projected: r.get_u64("trace.rows_projected")? as usize,
        rows_skipped: r.get_u64("trace.rows_skipped")? as usize,
    })
}

/// `DIR/job-<id>.ckpt` — one durable checkpoint per incomplete job.
pub fn checkpoint_path(dir: &Path, job: usize) -> PathBuf {
    dir.join(format!("job-{job}.ckpt"))
}

/// Write a checkpoint atomically: encode, write to `*.tmp`, fsync-free
/// `rename` into place (rename is atomic on POSIX within a directory).
/// Returns the final path.
pub fn write_checkpoint_atomic(
    dir: &Path,
    job: usize,
    ck: &BlockCheckpoint,
) -> Result<PathBuf, ServeError> {
    let mut span = crate::obs::span(crate::obs::SpanKind::CheckpointPersist);
    std::fs::create_dir_all(dir).map_err(|e| io_err(dir, &e))?;
    let bytes = encode_checkpoint(ck)?;
    let path = checkpoint_path(dir, job);
    let tmp = dir.join(format!("job-{job}.ckpt.tmp"));
    std::fs::write(&tmp, &bytes).map_err(|e| io_err(&tmp, &e))?;
    std::fs::rename(&tmp, &path).map_err(|e| io_err(&path, &e))?;
    if let Some(sp) = span.as_mut() {
        sp.counts(job as u64, bytes.len() as u64);
    }
    Ok(path)
}

/// Read and validate a checkpoint file.
pub fn load_checkpoint(path: &Path) -> Result<BlockCheckpoint, ServeError> {
    let mut span = crate::obs::span(crate::obs::SpanKind::CheckpointPersist);
    let bytes = std::fs::read(path).map_err(|e| io_err(path, &e))?;
    if let Some(sp) = span.as_mut() {
        sp.counts(0, bytes.len() as u64);
    }
    decode_checkpoint(&bytes, path)
}

/// Drop a job's checkpoint once the job completes (or is shed, expires
/// without a retry, or permanently fails). Best-effort: a missing file
/// is fine.
pub fn remove_checkpoint(dir: &Path, job: usize) {
    let _ = std::fs::remove_file(checkpoint_path(dir, job));
}

/// Move a failed-validation checkpoint to `DIR/corrupt/` so it never
/// poisons another recovery scan but stays available for post-mortems.
/// Returns the quarantine path.
pub fn quarantine(dir: &Path, path: &Path) -> Result<PathBuf, ServeError> {
    let qdir = dir.join("corrupt");
    std::fs::create_dir_all(&qdir).map_err(|e| io_err(&qdir, &e))?;
    let name = path.file_name().unwrap_or_else(|| std::ffi::OsStr::new("unnamed.ckpt"));
    let dest = qdir.join(name);
    std::fs::rename(path, &dest).map_err(|e| io_err(path, &e))?;
    Ok(dest)
}

/// Recovery scan: every `job-<id>.ckpt` in the state dir, sorted by job
/// id so recovery order is deterministic. Temp files, the `corrupt/`
/// subdir, and unrelated names are ignored. A missing dir is an empty
/// scan (first run against a fresh `--state-dir`).
pub fn scan_state_dir(dir: &Path) -> Result<Vec<(usize, PathBuf)>, ServeError> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(io_err(dir, &e)),
    };
    let mut found = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| io_err(dir, &e))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(id) = name
            .strip_prefix("job-")
            .and_then(|rest| rest.strip_suffix(".ckpt"))
            .and_then(|id| id.parse::<usize>().ok())
        else {
            continue;
        };
        found.push((id, entry.path()));
    }
    found.sort_by_key(|&(id, _)| id);
    Ok(found)
}

/// A deterministic fault-injection plan, compiled into the scheduler's
/// seams so every recovery invariant is testable without real crashes
/// or real bit rot. Parsed from the hidden `--fault-plan` CLI flag:
///
/// ```text
/// crash=K          persist all running jobs and exit after round K
/// corrupt=JOB:BYTE XOR one bit of byte (BYTE mod len) after writing
///                  JOB's checkpoint
/// poison=ID        mismatch job ID's spec against its bank input
/// garble=LINE      truncate trace line LINE (1-based) before parsing
/// kill-shard=K@R   fleet only: panic shard K after its round R (the
///                  supervisor must detect the death and migrate)
/// stall-shard=K@R  fleet only: freeze shard K's worker thread at its
///                  round R (the supervisor must detect the missing
///                  heartbeat and migrate)
/// ```
///
/// Directives combine comma-separated, e.g. `crash=12,corrupt=1:40`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// After this scheduler round completes, persist every running
    /// job's checkpoint and stop with `ServeStats::crashed` set; the
    /// process then exits with [`CRASH_EXIT_CODE`].
    pub crash_after_round: Option<usize>,
    /// `(job, byte)`: after writing this job's checkpoint, XOR bit 0 of
    /// `byte % file_len` in place — deterministic bit rot.
    pub corrupt_checkpoint: Option<(usize, usize)>,
    /// Jobs whose spec is deliberately mismatched against the bank
    /// (exercises the quarantine-and-retry path).
    pub poison_spec: Vec<usize>,
    /// 1-based trace line to garble before parsing (exercises the
    /// skip-and-report path).
    pub garble_trace_line: Option<usize>,
    /// `(shard, round)`: panic shard `shard`'s worker thread once its
    /// scheduler reaches round `round` (fleet only — single-scheduler
    /// serve rejects it).
    pub kill_shard: Option<(usize, usize)>,
    /// `(shard, round)`: freeze shard `shard`'s worker thread at round
    /// `round` without persisting anything further (fleet only). The
    /// supervisor's heartbeat staleness check must catch it.
    pub stall_shard: Option<(usize, usize)>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        *self == FaultPlan::default()
    }

    /// Parse the `--fault-plan` directive string.
    pub fn parse(s: &str) -> Result<FaultPlan, ServeError> {
        let bad = |msg: String| ServeError::FaultPlan { msg };
        let mut plan = FaultPlan::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| bad(format!("directive {part:?} is not key=value")))?;
            let parse_usize = |v: &str, what: &str| {
                v.parse::<usize>().map_err(|_| bad(format!("{what} {v:?} is not a number")))
            };
            match key {
                "crash" => plan.crash_after_round = Some(parse_usize(val, "crash round")?),
                "corrupt" => {
                    let (job, byte) = val
                        .split_once(':')
                        .ok_or_else(|| bad(format!("corrupt value {val:?} is not JOB:BYTE")))?;
                    plan.corrupt_checkpoint =
                        Some((parse_usize(job, "corrupt job")?, parse_usize(byte, "corrupt byte")?));
                }
                "poison" => plan.poison_spec.push(parse_usize(val, "poison job")?),
                "garble" => plan.garble_trace_line = Some(parse_usize(val, "garble line")?),
                "kill-shard" | "stall-shard" => {
                    let (shard, round) = val
                        .split_once('@')
                        .ok_or_else(|| bad(format!("{key} value {val:?} is not SHARD@ROUND")))?;
                    let pair = (
                        parse_usize(shard, "shard index")?,
                        parse_usize(round, "shard round")?,
                    );
                    if key == "kill-shard" {
                        plan.kill_shard = Some(pair);
                    } else {
                        plan.stall_shard = Some(pair);
                    }
                }
                other => return Err(bad(format!("unknown directive {other:?}"))),
            }
        }
        Ok(plan)
    }

    /// Apply [`FaultPlan::garble_trace_line`] to a trace's text:
    /// truncate the named line mid-token so it no longer parses.
    pub fn apply_to_trace(&self, text: &str) -> String {
        let Some(target) = self.garble_trace_line else {
            return text.to_string();
        };
        let mut out = String::with_capacity(text.len());
        for (lineno, line) in text.lines().enumerate() {
            if lineno + 1 == target {
                out.push_str(&line[..line.len().min(7)]);
            } else {
                out.push_str(line);
            }
            out.push('\n');
        }
        out
    }

    /// Apply [`FaultPlan::corrupt_checkpoint`] to a just-written file:
    /// flip one bit of the configured byte. No-op for other jobs.
    pub fn corrupt_file(&self, job: usize, path: &Path) -> Result<(), ServeError> {
        let Some((target, byte)) = self.corrupt_checkpoint else { return Ok(()) };
        if target != job {
            return Ok(());
        }
        let mut bytes = std::fs::read(path).map_err(|e| io_err(path, &e))?;
        if bytes.is_empty() {
            return Ok(());
        }
        let at = byte % bytes.len();
        bytes[at] ^= 1;
        std::fs::write(path, &bytes).map_err(|e| io_err(path, &e))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_parses_and_roundtrips_semantics() {
        let plan = FaultPlan::parse(
            "crash=12, corrupt=1:40, poison=2, poison=0, garble=3, kill-shard=1@5, stall-shard=2@9",
        )
        .expect("valid plan");
        assert_eq!(plan.crash_after_round, Some(12));
        assert_eq!(plan.corrupt_checkpoint, Some((1, 40)));
        assert_eq!(plan.poison_spec, vec![2, 0]);
        assert_eq!(plan.garble_trace_line, Some(3));
        assert_eq!(plan.kill_shard, Some((1, 5)));
        assert_eq!(plan.stall_shard, Some((2, 9)));
        assert!(!plan.is_empty());
        assert!(FaultPlan::parse("").expect("empty plan").is_empty());
        assert!(FaultPlan::parse("crash").is_err(), "missing value");
        assert!(FaultPlan::parse("corrupt=5").is_err(), "missing byte");
        assert!(FaultPlan::parse("kill-shard=1").is_err(), "missing round");
        assert!(FaultPlan::parse("stall-shard=a@2").is_err(), "bad shard index");
        assert!(FaultPlan::parse("explode=1").is_err(), "unknown key");
    }

    #[test]
    fn garbled_trace_line_no_longer_parses_but_others_do() {
        let text = "{\"problem\": \"nearness\", \"n\": 8}\n{\"problem\": \"cc\", \"n\": 9}\n";
        let plan = FaultPlan { garble_trace_line: Some(2), ..Default::default() };
        let garbled = plan.apply_to_trace(text);
        let (jobs, errors) = crate::serve::parse_job_trace_lenient(&garbled);
        assert_eq!(jobs.len(), 1);
        assert_eq!(errors.len(), 1);
    }

    #[test]
    fn state_dir_scan_orders_by_job_id_and_ignores_noise() {
        let dir = std::env::temp_dir().join(format!(
            "paf-persist-scan-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(dir.join("corrupt")).unwrap();
        for name in ["job-10.ckpt", "job-2.ckpt", "job-3.ckpt.tmp", "notes.txt"] {
            std::fs::write(dir.join(name), b"x").unwrap();
        }
        let found = scan_state_dir(&dir).expect("scan");
        let ids: Vec<usize> = found.iter().map(|&(id, _)| id).collect();
        assert_eq!(ids, vec![2, 10]);
        assert!(scan_state_dir(&dir.join("missing")).expect("fresh dir").is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_header_is_corrupt_not_panic() {
        let p = Path::new("unit.ckpt");
        assert!(matches!(decode_checkpoint(b"PAFCK", p), Err(ServeError::Corrupt { .. })));
        // Valid length, garbage digest.
        let mut bytes = vec![0u8; 64];
        bytes[63] = 0xff;
        assert!(matches!(decode_checkpoint(&bytes, p), Err(ServeError::Corrupt { .. })));
    }
}
