//! Lowering jobs into a live [`Session`]: the owned instance arena and
//! the typed-handle adapters the scheduler drives.
//!
//! `Session<'a>` borrows problem inputs for `'a`, so a long-running
//! scheduler needs every job's instance to outlive the session. The
//! [`JobBank`] materializes all instances of a trace up front (they are
//! generated from the job specs, so this is cheap and deterministic);
//! the scheduler then borrows the bank for the session's lifetime.
//!
//! Admission itself is [`Session::admit`] — mid-solve, between rounds —
//! and resumption is [`Session::admit_resumed`] from the
//! [`BlockCheckpoint`] captured at preemption. Both paths are
//! bit-identical to an uninterrupted solo solve (see
//! `tests/determinism.rs`).

use super::queue::{Job, JobSpec};
use super::ServeError;
use crate::core::problem::{Handle, SolveOptions};
use crate::core::session::{BlockCheckpoint, Session};
use crate::core::solver::SolverResult;
use crate::graph::generators::{
    planted_signed, type1_complete, type2_complete, type3_complete, WeightedInstance,
};
use crate::graph::Graph;
use crate::problems::correlation::{CcInstance, CcResult, Correlation};
use crate::problems::metric_oracle::OracleMode;
use crate::problems::nearness::{Nearness, NearnessResult};
use crate::util::Rng;

/// A materialized problem input.
pub enum JobInput {
    Nearness(WeightedInstance),
    Cc(CcInstance),
}

impl JobSpec {
    /// Generate this spec's problem instance (deterministic in the
    /// spec: same spec, same instance, bit for bit).
    pub fn materialize(&self) -> JobInput {
        match self {
            JobSpec::Nearness { n, graph_type, seed } => {
                let mut rng = Rng::new(*seed);
                let inst = match graph_type {
                    2 => type2_complete(*n, &mut rng),
                    3 => type3_complete(*n, &mut rng),
                    _ => type1_complete(*n, &mut rng),
                };
                JobInput::Nearness(inst)
            }
            JobSpec::Correlation { n, clusters, flip, seed } => {
                let mut rng = Rng::new(*seed);
                let (sg, _) = planted_signed(Graph::complete(*n), *clusters, *flip, &mut rng);
                JobInput::Cc(CcInstance::from_signed(&sg))
            }
        }
    }
}

/// The owned arena of job inputs, index-aligned with the trace's jobs.
pub struct JobBank {
    inputs: Vec<JobInput>,
}

impl JobBank {
    /// Materialize every job's instance.
    pub fn materialize(jobs: &[Job]) -> JobBank {
        JobBank::materialize_with(jobs, || {})
    }

    /// Materialize every job's instance, calling `tick` after each one.
    /// Fleet shards stamp their liveness heartbeat here so a large
    /// trace's instance build never looks like a stall to the
    /// supervisor.
    pub fn materialize_with(jobs: &[Job], mut tick: impl FnMut()) -> JobBank {
        JobBank {
            inputs: jobs
                .iter()
                .map(|j| {
                    let input = j.spec.materialize();
                    tick();
                    input
                })
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    pub fn input(&self, job: usize) -> &JobInput {
        &self.inputs[job]
    }
}

/// A typed session handle for either job kind.
#[derive(Debug, Clone, Copy)]
pub enum JobHandle {
    Nearness(Handle<NearnessResult>),
    Cc(Handle<CcResult>),
}

impl JobHandle {
    /// The underlying block index ([`Handle::index`]).
    pub fn index(&self) -> usize {
        match self {
            JobHandle::Nearness(h) => h.index(),
            JobHandle::Cc(h) => h.index(),
        }
    }
}

/// What a completed job hands back to the scheduler: the full
/// [`SolverResult`] (bit-comparable against a solo solve) plus the
/// problem-level objective (nearness: ½‖x−d‖²_W; CC: the LP objective).
pub struct JobOutcome {
    pub result: SolverResult,
    pub objective: f64,
}

/// The typed error every admission path returns on a job whose spec and
/// bank input disagree — isolation, not a panic: the scheduler
/// quarantines the one bad job and the rest of the fleet keeps going.
fn spec_mismatch(job: &Job) -> ServeError {
    ServeError::SpecMismatch {
        job: job.id,
        msg: format!("spec kind {:?} does not match its bank input", job.spec.kind()),
    }
}

/// Build the job's problem and admit it into the running session (the
/// oracle runs in Collect mode: deterministic delivery, overlappable,
/// shard-bucketed exactly when the sharded engine is selected).
pub fn admit_job<'a>(
    session: &mut Session<'a>,
    job: &Job,
    input: &'a JobInput,
) -> Result<JobHandle, ServeError> {
    match (&job.spec, input) {
        (JobSpec::Nearness { .. }, JobInput::Nearness(inst)) => Ok(JobHandle::Nearness(
            session.admit(Nearness::new(inst).mode(OracleMode::Collect)),
        )),
        (JobSpec::Correlation { seed, .. }, JobInput::Cc(inst)) => Ok(JobHandle::Cc(
            session.admit(Correlation::dense(inst).mode(OracleMode::Collect).seed(*seed)),
        )),
        _ => Err(spec_mismatch(job)),
    }
}

/// Re-admit a preempted job from its checkpoint (same problem, same
/// options as the original admission).
pub fn resume_job<'a>(
    session: &mut Session<'a>,
    job: &Job,
    input: &'a JobInput,
    ck: &BlockCheckpoint,
) -> Result<JobHandle, ServeError> {
    match (&job.spec, input) {
        (JobSpec::Nearness { .. }, JobInput::Nearness(inst)) => Ok(JobHandle::Nearness(
            session.admit_resumed(Nearness::new(inst).mode(OracleMode::Collect), ck),
        )),
        (JobSpec::Correlation { seed, .. }, JobInput::Cc(inst)) => {
            Ok(JobHandle::Cc(session.admit_resumed(
                Correlation::dense(inst).mode(OracleMode::Collect).seed(*seed),
                ck,
            )))
        }
        _ => Err(spec_mismatch(job)),
    }
}

/// Redeem a finished job's typed output (None while it still runs).
pub fn take_job(session: &mut Session<'_>, handle: JobHandle) -> Option<JobOutcome> {
    match handle {
        JobHandle::Nearness(h) => session
            .take(h)
            .map(|r| JobOutcome { objective: r.objective, result: r.result }),
        JobHandle::Cc(h) => session
            .take(h)
            .map(|r| JobOutcome { objective: r.lp_objective, result: r.result }),
    }
}

/// Solve one job alone — the reference trajectory the serve paths are
/// pinned against, and the sequential baseline in `perf_hotpath` P8.
pub fn solve_job_solo(
    job: &Job,
    input: &JobInput,
    opts: &SolveOptions,
) -> Result<JobOutcome, ServeError> {
    match (&job.spec, input) {
        (JobSpec::Nearness { .. }, JobInput::Nearness(inst)) => {
            let r = Session::solve_one(opts.clone(), Nearness::new(inst).mode(OracleMode::Collect));
            Ok(JobOutcome { objective: r.objective, result: r.result })
        }
        (JobSpec::Correlation { seed, .. }, JobInput::Cc(inst)) => {
            let r = Session::solve_one(
                opts.clone(),
                Correlation::dense(inst).mode(OracleMode::Collect).seed(*seed),
            );
            Ok(JobOutcome { objective: r.lp_objective, result: r.result })
        }
        _ => Err(spec_mismatch(job)),
    }
}
