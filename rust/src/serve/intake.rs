//! Live job intake: a listener thread that feeds the fleet supervisor
//! line-delimited JSON jobs from a Unix socket, a TCP socket, or stdin.
//!
//! The wire format is exactly the `paf serve --trace` file format, one
//! job per line (parsed via [`queue::parse_intake_line`], the same
//! code path as file traces), plus two control lines:
//!
//! ```text
//! drain            stop accepting work; finish everything, exit 0
//! halt             stop now; persist running state, exit 0
//! ```
//!
//! (also accepted as JSON: `{"op": "drain"}` / `{"op": "halt"}`).
//!
//! Robustness contract, pinned by `tests/serve_intake.rs`:
//!
//! - A malformed line is skipped and reported with its 1-based line
//!   number *within that connection* — identical semantics to
//!   [`parse_job_trace_lenient`](super::parse_job_trace_lenient)'s
//!   per-file reports. The connection (and the queue) live on.
//! - A client that disconnects mid-line cannot poison the queue: the
//!   dangling partial line is parsed if complete-enough or reported as
//!   malformed, and the listener simply moves to the next connection.
//! - Backpressure is real: items flow through a bounded
//!   [`sync_channel`](std::sync::mpsc::sync_channel), so a flood of
//!   arrivals blocks the socket reader rather than ballooning memory
//!   (the supervisor's high-water shedding governs the queue proper).

use super::queue::{self, Job};
use super::ServeError;
use std::io::BufRead;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, SyncSender};

/// Where the intake listener accepts jobs from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IntakeSource {
    /// Read the process's stdin to EOF, then drain.
    Stdin,
    /// Bind a TCP listener (`HOST:PORT`; port 0 picks a free port).
    Tcp(String),
    /// Bind a Unix-domain socket at this path (a stale socket file from
    /// a previous run is removed first).
    Unix(PathBuf),
}

impl IntakeSource {
    /// Parse a `--listen` flag value: `stdin` (or `-`), `unix:PATH`,
    /// `tcp:HOST:PORT`, or a bare `HOST:PORT`.
    pub fn parse(s: &str) -> Result<IntakeSource, ServeError> {
        let s = s.trim();
        match s {
            "stdin" | "-" => Ok(IntakeSource::Stdin),
            _ if s.is_empty() => Err(ServeError::Config {
                msg: "--listen needs stdin, unix:PATH, or HOST:PORT".to_string(),
            }),
            _ => {
                if let Some(path) = s.strip_prefix("unix:") {
                    return Ok(IntakeSource::Unix(PathBuf::from(path)));
                }
                let addr = s.strip_prefix("tcp:").unwrap_or(s);
                if addr.rsplit_once(':').is_none() {
                    return Err(ServeError::Config {
                        msg: format!("--listen {s:?} is not stdin, unix:PATH, or HOST:PORT"),
                    });
                }
                Ok(IntakeSource::Tcp(addr.to_string()))
            }
        }
    }
}

/// One message from the intake thread to the supervisor.
#[derive(Debug)]
pub enum IntakeItem {
    /// A parsed job (its `id` is provisional; the supervisor assigns
    /// the fleet-global id on receipt).
    Job(Job),
    /// A malformed line, reported with its connection-relative line
    /// number — the supervisor records it and keeps serving.
    Skip(ServeError),
    /// `drain` control line (or stdin EOF): stop intake, finish all
    /// accepted work, exit cleanly.
    Drain,
    /// `halt` control line: stop intake *and* ask every shard to pause
    /// and persist; the supervisor exits once state is durable.
    Halt,
}

/// A running intake listener.
pub struct IntakeHandle {
    /// Bounded item stream (the supervisor's end).
    pub rx: Receiver<IntakeItem>,
    /// The actual bound TCP address, when the source was TCP — lets
    /// tests bind port 0 and then connect.
    pub addr: Option<std::net::SocketAddr>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl IntakeHandle {
    /// Wait for the listener thread to finish (it exits after a drain
    /// or halt control line, stdin EOF, or when the supervisor drops
    /// the receiver).
    pub fn join(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for IntakeHandle {
    fn drop(&mut self) {
        // Best-effort: the thread exits on its own once its sends fail
        // (receiver dropped) or its source closes; never block drop.
        let _ = self.join.take();
    }
}

/// Channel bound: a flood of arrivals blocks the socket reader once
/// this many items are in flight, instead of growing without bound.
const INTAKE_CHANNEL_BOUND: usize = 64;

/// Spawn the intake listener for `source`. Binding happens in the
/// calling thread so errors surface synchronously (and the bound TCP
/// address is known before any client connects).
pub fn spawn_intake(source: IntakeSource) -> Result<IntakeHandle, ServeError> {
    let (tx, rx) = std::sync::mpsc::sync_channel(INTAKE_CHANNEL_BOUND);
    match source {
        IntakeSource::Stdin => {
            let join = std::thread::Builder::new()
                .name("paf-intake".to_string())
                .spawn(move || {
                    let stdin = std::io::stdin();
                    pump_stream(stdin.lock(), &tx);
                    let _ = tx.send(IntakeItem::Drain);
                })
                .map_err(|e| spawn_err(&e))?;
            Ok(IntakeHandle { rx, addr: None, join: Some(join) })
        }
        IntakeSource::Tcp(addr) => {
            let listener = std::net::TcpListener::bind(&addr)
                .map_err(|e| ServeError::Io { path: addr.clone(), msg: e.to_string() })?;
            let bound = listener.local_addr().ok();
            let join = std::thread::Builder::new()
                .name("paf-intake".to_string())
                .spawn(move || {
                    for conn in listener.incoming() {
                        let Ok(conn) = conn else { continue };
                        if !pump_stream(std::io::BufReader::new(conn), &tx) {
                            break;
                        }
                    }
                })
                .map_err(|e| spawn_err(&e))?;
            Ok(IntakeHandle { rx, addr: bound, join: Some(join) })
        }
        IntakeSource::Unix(path) => {
            // A stale socket file from a crashed run would fail the
            // bind; remove it first (a live listener would have it
            // open, but two fleets on one path is operator error).
            let _ = std::fs::remove_file(&path);
            let listener = std::os::unix::net::UnixListener::bind(&path).map_err(|e| {
                ServeError::Io { path: path.display().to_string(), msg: e.to_string() }
            })?;
            let cleanup = path.clone();
            let join = std::thread::Builder::new()
                .name("paf-intake".to_string())
                .spawn(move || {
                    for conn in listener.incoming() {
                        let Ok(conn) = conn else { continue };
                        if !pump_stream(std::io::BufReader::new(conn), &tx) {
                            break;
                        }
                    }
                    let _ = std::fs::remove_file(&cleanup);
                })
                .map_err(|e| spawn_err(&e))?;
            Ok(IntakeHandle { rx, addr: None, join: Some(join) })
        }
    }
}

fn spawn_err(e: &std::io::Error) -> ServeError {
    ServeError::Io { path: "<intake thread>".to_string(), msg: e.to_string() }
}

/// Pump one connection's lines into the channel. Returns `false` when
/// the listener should stop accepting (drain/halt seen, or the
/// supervisor dropped its receiver); `true` to accept the next
/// connection. An I/O error mid-read is a dropped client, not a fleet
/// problem: whatever complete lines arrived are already queued, and
/// the final partial line (no trailing newline) is handled like any
/// other line — parsed or reported, never silently kept.
fn pump_stream<R: BufRead>(mut reader: R, tx: &SyncSender<IntakeItem>) -> bool {
    let mut lineno = 0usize;
    let mut accepted = 0usize;
    let mut buf = String::new();
    loop {
        buf.clear();
        let complete = match reader.read_line(&mut buf) {
            Ok(0) => return true, // clean EOF: next connection
            Ok(_) => buf.ends_with('\n'),
            Err(_) => return true, // dropped client: queue is unaffected
        };
        lineno += 1;
        let line = buf.trim();
        if !line.is_empty() && !line.starts_with('#') {
            match classify(line) {
                Control::Drain => {
                    let _ = tx.send(IntakeItem::Drain);
                    return false;
                }
                Control::Halt => {
                    let _ = tx.send(IntakeItem::Halt);
                    return false;
                }
                Control::None => {
                    // The provisional id doubles as the dedup seed
                    // default; the supervisor re-ids on arrival.
                    let item = match queue::parse_intake_line(line, lineno, accepted) {
                        Ok(job) => {
                            accepted += 1;
                            IntakeItem::Job(job)
                        }
                        Err(e) => IntakeItem::Skip(e),
                    };
                    if tx.send(item).is_err() {
                        return false; // supervisor gone
                    }
                }
            }
        }
        if !complete {
            // A partial final line means the client vanished mid-write;
            // treat it as EOF for this connection.
            return true;
        }
    }
}

enum Control {
    Drain,
    Halt,
    None,
}

/// Recognize control lines before attempting a job parse, so `drain`
/// is an order, not a malformed job.
fn classify(line: &str) -> Control {
    match line {
        "drain" => return Control::Drain,
        "halt" => return Control::Halt,
        _ => {}
    }
    if line.starts_with('{') {
        if let Ok(obj) = crate::runtime::json::Json::parse(line) {
            if let Some(op) = obj.get("op").and_then(crate::runtime::json::Json::as_str) {
                match op {
                    "drain" => return Control::Drain,
                    "halt" => return Control::Halt,
                    _ => {}
                }
            }
        }
    }
    Control::None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_flag_parses_every_source_shape() {
        assert_eq!(IntakeSource::parse("stdin").unwrap(), IntakeSource::Stdin);
        assert_eq!(IntakeSource::parse("-").unwrap(), IntakeSource::Stdin);
        assert_eq!(
            IntakeSource::parse("unix:/tmp/paf.sock").unwrap(),
            IntakeSource::Unix(PathBuf::from("/tmp/paf.sock"))
        );
        assert_eq!(
            IntakeSource::parse("tcp:127.0.0.1:7000").unwrap(),
            IntakeSource::Tcp("127.0.0.1:7000".to_string())
        );
        assert_eq!(
            IntakeSource::parse("127.0.0.1:0").unwrap(),
            IntakeSource::Tcp("127.0.0.1:0".to_string())
        );
        assert!(matches!(IntakeSource::parse(""), Err(ServeError::Config { .. })));
        assert!(matches!(IntakeSource::parse("florp"), Err(ServeError::Config { .. })));
    }

    #[test]
    fn pump_reports_malformed_lines_with_connection_line_numbers() {
        let text = "# comment\n\
                    {\"problem\": \"nearness\", \"n\": 8}\n\
                    {\"problem\": \"nearness\"\n\
                    {\"problem\": \"cc\", \"n\": 9}\n";
        let (tx, rx) = std::sync::mpsc::sync_channel(16);
        assert!(pump_stream(std::io::Cursor::new(text), &tx));
        drop(tx);
        let items: Vec<IntakeItem> = rx.iter().collect();
        assert_eq!(items.len(), 3);
        let IntakeItem::Job(a) = &items[0] else { panic!("want job, got {:?}", items[0]) };
        assert_eq!((a.id, a.name.as_str()), (0, "nearness-0"));
        let IntakeItem::Skip(ServeError::Trace { line, .. }) = &items[1] else {
            panic!("want skip, got {:?}", items[1]);
        };
        assert_eq!(*line, 3, "line numbers are 1-based and count blank/comment lines");
        let IntakeItem::Job(b) = &items[2] else { panic!("want job, got {:?}", items[2]) };
        assert_eq!(b.id, 1, "provisional ids count only accepted jobs");
    }

    #[test]
    fn partial_final_line_ends_the_connection_without_poisoning() {
        // Mid-line disconnect: no trailing newline on a half-written
        // job. The partial line is reported malformed, the pump asks
        // for the next connection, nothing hangs.
        let text = "{\"problem\": \"nearness\", \"n\": 8}\n{\"problem\": \"nea";
        let (tx, rx) = std::sync::mpsc::sync_channel(16);
        assert!(pump_stream(std::io::Cursor::new(text), &tx), "pump must move on");
        drop(tx);
        let items: Vec<IntakeItem> = rx.iter().collect();
        assert_eq!(items.len(), 2);
        assert!(matches!(items[0], IntakeItem::Job(_)));
        assert!(matches!(items[1], IntakeItem::Skip(ServeError::Trace { line: 2, .. })));
    }

    #[test]
    fn control_lines_win_over_job_parsing() {
        let text = "{\"problem\": \"nearness\", \"n\": 8}\ndrain\n{\"problem\": \"cc\", \"n\": 9}\n";
        let (tx, rx) = std::sync::mpsc::sync_channel(16);
        assert!(!pump_stream(std::io::Cursor::new(text), &tx), "drain stops the listener");
        drop(tx);
        let items: Vec<IntakeItem> = rx.iter().collect();
        assert_eq!(items.len(), 2, "nothing after the drain line is read");
        assert!(matches!(items[0], IntakeItem::Job(_)));
        assert!(matches!(items[1], IntakeItem::Drain));

        let (tx, rx) = std::sync::mpsc::sync_channel(16);
        assert!(!pump_stream(std::io::Cursor::new("{\"op\": \"halt\"}\n"), &tx));
        drop(tx);
        assert!(matches!(rx.iter().next(), Some(IntakeItem::Halt)));
    }
}
