//! Job specifications and the priority queue feeding the scheduler.
//!
//! A [`Job`] names a problem instance (self-contained: synthetic
//! generator + seed, so a trace file fully determines the workload), a
//! priority, an arrival round, and optional per-job budgets. Traces are
//! line-delimited JSON — one job object per line, `#` comments and
//! blank lines ignored — parsed with the crate's offline JSON reader:
//!
//! ```text
//! # mixed nearness + correlation-clustering trace
//! {"problem": "nearness", "name": "near-a", "n": 40, "graph_type": 1,
//!  "seed": 1, "priority": 0, "arrival_round": 0}
//! {"problem": "cc", "name": "cc-b", "n": 24, "clusters": 3, "flip": 0.1,
//!  "seed": 2, "priority": 5, "arrival_round": 3, "max_rounds": 400,
//!  "deadline_rounds": 200}
//! ```
//!
//! The [`JobQueue`] orders ready jobs by priority (higher first) with
//! FIFO tie-breaking on enqueue order — fully deterministic, so a serve
//! run is reproducible from its trace.

use super::ServeError;
use crate::runtime::json::Json;

/// What problem a job solves. Instances are generated, not stored, so
/// job traces stay tiny and self-describing.
#[derive(Debug, Clone, PartialEq)]
pub enum JobSpec {
    /// Metric nearness on a complete weighted graph (`graph_type` 1–3,
    /// the paper's instance families).
    Nearness { n: usize, graph_type: u8, seed: u64 },
    /// Dense correlation clustering on a planted `K_n` with `clusters`
    /// groups and sign-flip noise `flip`.
    Correlation { n: usize, clusters: usize, flip: f64, seed: u64 },
}

impl JobSpec {
    /// Short kind tag (`"nearness"` / `"cc"`, the trace vocabulary).
    pub fn kind(&self) -> &'static str {
        match self {
            JobSpec::Nearness { .. } => "nearness",
            JobSpec::Correlation { .. } => "cc",
        }
    }
}

/// One unit of work for the scheduler.
#[derive(Debug, Clone)]
pub struct Job {
    /// Position in the trace (and in the [`super::JobBank`]).
    pub id: usize,
    pub name: String,
    pub spec: JobSpec,
    /// Higher runs first; a strictly higher-priority arrival may preempt
    /// a running lower-priority job when capacity is full.
    pub priority: i64,
    /// Scheduler round at which the job becomes available.
    pub arrival_round: usize,
    /// Per-job cap on solve rounds actually run (preemption time does
    /// not count); the scheduler expires the job when exceeded.
    pub max_rounds: Option<usize>,
    /// Completion deadline, in scheduler rounds after arrival —
    /// **enforced**: a job still unfinished past it is evicted and
    /// marked `Expired` (`deadline_met: false` in the stats).
    pub deadline_rounds: Option<usize>,
    /// Wall-clock completion deadline in milliseconds, measured from the
    /// moment the job becomes ready (queueing time counts). Enforced the
    /// same way as [`Job::deadline_rounds`].
    pub deadline_ms: Option<u64>,
}

/// Escape a string for embedding in a JSON string literal (quotes,
/// backslashes, control characters) — job names are user-controlled.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl Job {
    /// The job as one trace line (the inverse of [`parse_job_trace`]).
    pub fn to_json_line(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "{{\"problem\": \"{}\", \"name\": \"{}\"",
            self.spec.kind(),
            json_escape(&self.name)
        ));
        match &self.spec {
            JobSpec::Nearness { n, graph_type, seed } => {
                s.push_str(&format!(
                    ", \"n\": {n}, \"graph_type\": {graph_type}, \"seed\": {seed}"
                ));
            }
            JobSpec::Correlation { n, clusters, flip, seed } => {
                s.push_str(&format!(
                    ", \"n\": {n}, \"clusters\": {clusters}, \"flip\": {flip}, \"seed\": {seed}"
                ));
            }
        }
        s.push_str(&format!(
            ", \"priority\": {}, \"arrival_round\": {}",
            self.priority, self.arrival_round
        ));
        if let Some(m) = self.max_rounds {
            s.push_str(&format!(", \"max_rounds\": {m}"));
        }
        if let Some(d) = self.deadline_rounds {
            s.push_str(&format!(", \"deadline_rounds\": {d}"));
        }
        if let Some(d) = self.deadline_ms {
            s.push_str(&format!(", \"deadline_ms\": {d}"));
        }
        s.push('}');
        s
    }
}

fn get_usize(obj: &Json, key: &str) -> Option<usize> {
    obj.get(key).and_then(Json::as_usize)
}

fn get_f64(obj: &Json, key: &str) -> Option<f64> {
    match obj.get(key) {
        Some(Json::Num(v)) => Some(*v),
        _ => None,
    }
}

fn get_i64(obj: &Json, key: &str) -> Option<i64> {
    match obj.get(key) {
        Some(Json::Num(v)) if v.fract() == 0.0 => Some(*v as i64),
        _ => None,
    }
}

/// Parse one trace line (already trimmed, known non-comment) into the
/// job with positional id `id`. `lineno` is 1-based, for error reports.
fn parse_job_line(line: &str, lineno: usize, id: usize) -> Result<Job, ServeError> {
    let err = |msg: String| ServeError::Trace { line: lineno, msg };
    let obj = Json::parse(line).map_err(|e| err(e.to_string()))?;
    let kind = obj
        .get("problem")
        .and_then(Json::as_str)
        .ok_or_else(|| err("missing \"problem\"".to_string()))?;
    let n = get_usize(&obj, "n").ok_or_else(|| err("missing \"n\"".to_string()))?;
    // JSON numbers travel as f64: integers at or above 2^53 are not
    // exactly representable, so a mangled seed would silently break
    // the trace-determines-workload guarantee. Reject them.
    let seed = match get_usize(&obj, "seed") {
        Some(s) if s >= (1usize << 53) => {
            return Err(err(format!(
                "\"seed\" {s} is not exactly representable as a JSON number \
                 (seeds must be below 2^53)"
            )))
        }
        Some(s) => s as u64,
        None => id as u64,
    };
    let spec = match kind {
        "nearness" => JobSpec::Nearness {
            n,
            graph_type: get_usize(&obj, "graph_type").unwrap_or(1) as u8,
            seed,
        },
        "cc" => JobSpec::Correlation {
            n,
            clusters: get_usize(&obj, "clusters").unwrap_or(2),
            flip: get_f64(&obj, "flip").unwrap_or(0.1),
            seed,
        },
        other => {
            return Err(err(format!(
                "unknown problem {other:?} (expected \"nearness\" or \"cc\")"
            )))
        }
    };
    let name = obj
        .get("name")
        .and_then(Json::as_str)
        .map(str::to_string)
        .unwrap_or_else(|| format!("{kind}-{id}"));
    Ok(Job {
        id,
        name,
        spec,
        priority: get_i64(&obj, "priority").unwrap_or(0),
        arrival_round: get_usize(&obj, "arrival_round").unwrap_or(0),
        max_rounds: get_usize(&obj, "max_rounds"),
        deadline_rounds: get_usize(&obj, "deadline_rounds"),
        deadline_ms: get_usize(&obj, "deadline_ms").map(|v| v as u64),
    })
}

/// Parse a line-delimited JSON job trace (see the module docs for the
/// format). Job ids are assigned by position. Strict: the first
/// malformed line aborts the parse with its line number.
pub fn parse_job_trace(text: &str) -> Result<Vec<Job>, ServeError> {
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        jobs.push(parse_job_line(line, lineno + 1, jobs.len())?);
    }
    if jobs.is_empty() {
        return Err(ServeError::Trace { line: 0, msg: "trace contains no jobs".to_string() });
    }
    Ok(jobs)
}

/// Lenient trace parse: malformed lines are skipped and reported (with
/// their 1-based line numbers) instead of aborting the run; ids are
/// assigned by position among the lines that *did* parse, so the
/// surviving jobs load into a [`super::JobBank`] unchanged. An empty
/// result with no errors means the trace had no job lines at all.
pub fn parse_job_trace_lenient(text: &str) -> (Vec<Job>, Vec<ServeError>) {
    let mut jobs = Vec::new();
    let mut errors = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match parse_job_line(line, lineno + 1, jobs.len()) {
            Ok(job) => jobs.push(job),
            Err(e) => errors.push(e),
        }
    }
    (jobs, errors)
}

/// Parse a single already-trimmed job line with an explicit positional
/// id — the live-intake entry point ([`super::intake`]), where lines
/// arrive one connection at a time rather than as a whole file. Errors
/// carry `lineno` (1-based within the connection) exactly as the
/// file-trace parsers report them.
pub fn parse_intake_line(line: &str, lineno: usize, id: usize) -> Result<Job, ServeError> {
    parse_job_line(line, lineno, id)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Entry {
    priority: i64,
    /// Enqueue sequence number; earlier wins on equal priority.
    seq: u64,
    job: usize,
    /// Scheduler round at which the job entered the queue (aging base).
    enqueued: usize,
}

impl Entry {
    /// Effective priority after aging: the base priority plus one level
    /// per `age_rounds` rounds spent waiting (0 disables aging). This is
    /// what guarantees a low-priority job cannot starve forever under a
    /// stream of high-priority arrivals.
    fn effective(&self, now: usize, age_rounds: usize) -> i64 {
        if age_rounds == 0 {
            self.priority
        } else {
            self.priority + (now.saturating_sub(self.enqueued) / age_rounds) as i64
        }
    }
}

/// The ready queue: jobs that have arrived (or were preempted) and wait
/// for capacity. Deterministic priority order with FIFO tie-breaking,
/// plus optional priority aging and overload shedding. Backed by a
/// plain vector — queues are small and effective priorities drift with
/// `now`, so a heap's cached order would go stale anyway.
#[derive(Debug, Default)]
pub struct JobQueue {
    entries: Vec<Entry>,
    seq: u64,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    pub fn push(&mut self, job: usize, priority: i64) {
        self.push_at(job, priority, 0);
    }

    /// Enqueue recording the current round, so aging can credit the wait.
    pub fn push_at(&mut self, job: usize, priority: i64, now: usize) {
        let seq = self.seq;
        self.seq += 1;
        self.entries.push(Entry { priority, seq, job, enqueued: now });
    }

    /// Index of the entry [`JobQueue::pop_aged`] would take: highest
    /// effective priority, FIFO (lowest seq) within a level.
    fn best(&self, now: usize, age_rounds: usize) -> Option<usize> {
        self.entries
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| {
                a.effective(now, age_rounds)
                    .cmp(&b.effective(now, age_rounds))
                    .then_with(|| b.seq.cmp(&a.seq))
            })
            .map(|(i, _)| i)
    }

    /// Highest-priority ready job, if any (no aging).
    pub fn pop(&mut self) -> Option<usize> {
        self.pop_aged(0, 0).map(|(job, _)| job)
    }

    /// Highest *effective*-priority ready job and that effective
    /// priority. The caller records the effective priority as the
    /// admitted job's runtime priority (priority inheritance), so an
    /// aged job cannot be preempted right back by the next arrival of
    /// its original level.
    pub fn pop_aged(&mut self, now: usize, age_rounds: usize) -> Option<(usize, i64)> {
        let i = self.best(now, age_rounds)?;
        let e = self.entries.remove(i);
        Some((e.job, e.effective(now, age_rounds)))
    }

    /// Priority of the job [`JobQueue::pop`] would return.
    pub fn peek_priority(&self) -> Option<i64> {
        self.peek_priority_aged(0, 0)
    }

    /// Effective priority of the job [`JobQueue::pop_aged`] would return.
    pub fn peek_priority_aged(&self, now: usize, age_rounds: usize) -> Option<i64> {
        self.best(now, age_rounds).map(|i| self.entries[i].effective(now, age_rounds))
    }

    /// Overload shedding: remove and return the job with the *lowest*
    /// effective priority, latest-enqueued first within a level (the
    /// jobs that have waited least lose first).
    pub fn shed_lowest(&mut self, now: usize, age_rounds: usize) -> Option<usize> {
        let i = self
            .entries
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.effective(now, age_rounds)
                    .cmp(&b.effective(now, age_rounds))
                    .then_with(|| b.seq.cmp(&a.seq))
            })
            .map(|(i, _)| i)?;
        Some(self.entries.remove(i).job)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_orders_by_priority_then_fifo() {
        let mut q = JobQueue::new();
        q.push(0, 1);
        q.push(1, 5);
        q.push(2, 1);
        q.push(3, 5);
        assert_eq!(q.peek_priority(), Some(5));
        assert_eq!(q.pop(), Some(1), "higher priority first");
        assert_eq!(q.pop(), Some(3), "FIFO within a priority");
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn trace_roundtrip() {
        let jobs = vec![
            Job {
                id: 0,
                name: "near-a".to_string(),
                spec: JobSpec::Nearness { n: 40, graph_type: 1, seed: 1 },
                priority: 0,
                arrival_round: 0,
                max_rounds: None,
                deadline_rounds: Some(200),
                deadline_ms: None,
            },
            Job {
                id: 1,
                name: "cc-b".to_string(),
                spec: JobSpec::Correlation { n: 24, clusters: 3, flip: 0.1, seed: 2 },
                priority: 5,
                arrival_round: 3,
                max_rounds: Some(400),
                deadline_rounds: None,
                deadline_ms: Some(2500),
            },
        ];
        let text: String = format!(
            "# comment line\n\n{}\n{}\n",
            jobs[0].to_json_line(),
            jobs[1].to_json_line()
        );
        let parsed = parse_job_trace(&text).expect("roundtrip parse");
        assert_eq!(parsed.len(), 2);
        for (a, b) in jobs.iter().zip(&parsed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.name, b.name);
            assert_eq!(a.spec, b.spec);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.arrival_round, b.arrival_round);
            assert_eq!(a.max_rounds, b.max_rounds);
            assert_eq!(a.deadline_rounds, b.deadline_rounds);
            assert_eq!(a.deadline_ms, b.deadline_ms);
        }
    }

    #[test]
    fn hostile_job_names_roundtrip_escaped() {
        let job = Job {
            id: 0,
            name: "we\"ird\\name\twith\ncontrol".to_string(),
            spec: JobSpec::Nearness { n: 5, graph_type: 1, seed: 0 },
            priority: 0,
            arrival_round: 0,
            max_rounds: None,
            deadline_rounds: None,
            deadline_ms: None,
        };
        let line = job.to_json_line();
        crate::runtime::json::Json::parse(&line).expect("escaped line must be valid JSON");
        let parsed = parse_job_trace(&(line + "\n")).expect("escaped trace must parse");
        assert_eq!(parsed[0].name, job.name);
    }

    #[test]
    fn seeds_at_or_above_2_pow_53_are_rejected() {
        let line = "{\"problem\": \"nearness\", \"n\": 4, \"seed\": 9007199254740992}";
        assert!(parse_job_trace(line).is_err(), "inexactly-representable seed must error");
        let ok = parse_job_trace("{\"problem\": \"nearness\", \"n\": 4, \"seed\": 4503599627370496}")
            .expect("2^52 is exact");
        assert_eq!(ok[0].spec, JobSpec::Nearness { n: 4, graph_type: 1, seed: 1 << 52 });
    }

    #[test]
    fn trace_defaults_and_errors() {
        let jobs =
            parse_job_trace("{\"problem\": \"nearness\", \"n\": 12}\n").expect("minimal job");
        assert_eq!(jobs[0].name, "nearness-0");
        assert_eq!(jobs[0].priority, 0);
        assert_eq!(jobs[0].spec, JobSpec::Nearness { n: 12, graph_type: 1, seed: 0 });
        assert!(parse_job_trace("").is_err(), "empty trace");
        assert!(parse_job_trace("{\"problem\": \"qp\", \"n\": 3}").is_err(), "unknown kind");
        assert!(parse_job_trace("{\"problem\": \"cc\"}").is_err(), "missing n");
    }

    #[test]
    fn strict_parse_reports_the_offending_line_number() {
        let text = "# header\n{\"problem\": \"nearness\", \"n\": 8}\n{garbage\n";
        match parse_job_trace(text) {
            Err(ServeError::Trace { line, .. }) => assert_eq!(line, 3),
            other => panic!("expected a Trace error, got {other:?}"),
        }
    }

    #[test]
    fn lenient_parse_skips_bad_lines_with_line_numbers() {
        let text = "{\"problem\": \"nearness\", \"n\": 8}\n\
                    {garbage\n\
                    {\"problem\": \"qp\", \"n\": 3}\n\
                    {\"problem\": \"cc\", \"n\": 9}\n";
        let (jobs, errors) = parse_job_trace_lenient(text);
        assert_eq!(jobs.len(), 2);
        // Ids stay positional among the jobs that parsed, so the bank
        // loads them unchanged.
        assert_eq!(jobs[0].id, 0);
        assert_eq!(jobs[1].id, 1);
        assert_eq!(jobs[1].spec, JobSpec::Correlation { n: 9, clusters: 2, flip: 0.1, seed: 1 });
        let lines: Vec<usize> = errors
            .iter()
            .map(|e| match e {
                ServeError::Trace { line, .. } => *line,
                other => panic!("unexpected error kind {other:?}"),
            })
            .collect();
        assert_eq!(lines, vec![2, 3]);
    }

    #[test]
    fn aging_promotes_starved_jobs_and_reports_effective_priority() {
        let mut q = JobQueue::new();
        q.push_at(0, 0, 0); // low priority, waiting since round 0
        q.push_at(1, 5, 100); // high priority, just arrived
        // Without aging the high-priority job wins.
        assert_eq!(q.peek_priority_aged(100, 0), Some(5));
        // With one level per 10 waited rounds, the starved job has aged
        // to effective priority 10 and jumps the queue.
        assert_eq!(q.peek_priority_aged(100, 10), Some(10));
        assert_eq!(q.pop_aged(100, 10), Some((0, 10)));
        assert_eq!(q.pop_aged(100, 10), Some((1, 5)));
        assert_eq!(q.pop_aged(100, 10), None);
    }

    #[test]
    fn shed_drops_lowest_priority_latest_enqueued_first() {
        let mut q = JobQueue::new();
        q.push_at(0, 1, 0);
        q.push_at(1, 0, 0);
        q.push_at(2, 0, 0);
        assert_eq!(q.shed_lowest(0, 0), Some(2), "latest of the lowest level sheds first");
        assert_eq!(q.shed_lowest(0, 0), Some(1));
        assert_eq!(q.shed_lowest(0, 0), Some(0));
        assert_eq!(q.shed_lowest(0, 0), None);
    }
}
