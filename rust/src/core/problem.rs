//! The unified problem layer: one [`SolveOptions`] vocabulary for every
//! workload, and the [`Problem`] trait that lowers a typed problem
//! (metric nearness, correlation clustering, ITML, …) into something the
//! [`Session`](crate::core::session::Session) driver can execute.
//!
//! Two execution families exist:
//!
//! - **Vector problems** ([`Lowered::Vector`]) build a
//!   [`DiagonalQuadratic`] Bregman block plus a separation oracle and are
//!   executed by the shared PROJECT AND FORGET engine. Many independent
//!   vector problems batch into *one* solver: each block occupies a
//!   block-offset region of a single concatenated variable vector, and
//!   because blocks never share coordinates the support-disjoint shard
//!   planner parallelises across the whole fleet in one sharded sweep
//!   (the Ruggles et al. observation that disjoint constraint blocks
//!   parallelise trivially).
//! - **Round-driven problems** ([`Lowered::Rounds`]) own their iterate
//!   (e.g. ITML's Mahalanobis matrix, which lives in a LogDet geometry
//!   the vector engine does not cover) and expose one
//!   oracle/sweep/forget round at a time via [`RoundProblem`]; the
//!   session steps them in lockstep with the vector fleet.
//!
//! The legacy free functions (`solve_nearness`, `solve_cc`,
//! `solve_pf_itml`) and their per-problem config structs are thin
//! deprecated wrappers over this layer.

use super::bregman::DiagonalQuadratic;
use super::engine::SweepStrategy;
use super::oracle::{Oracle, OracleOutcome, OverlappableOracle, ProjectionSink};
use super::solver::{PhaseTimes, SolverConfig, SolverResult};
use std::any::Any;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// The consolidated solve-knob vocabulary, defined once for every
/// workload (previously re-declared per problem config). Engine knobs
/// (`sweep`, `overlap`, `parallel_min_rows`) and stop knobs
/// (`violation_tol`, `dual_tol`, `max_iters`, `projection_budget`) live
/// here; problem-structural knobs (oracle mode, γ, inner sweeps) live on
/// the individual [`Problem`] builders.
///
/// Environment overrides are preserved: `PAF_THREADS` sizes the worker
/// pool, `PAF_PARALLEL_MIN_ROWS` tunes the sharded executor's
/// serial/parallel threshold, and [`SolveOptions::from_env`] additionally
/// honours `PAF_SWEEP` / `PAF_OVERLAP` / `PAF_LAZY_SWEEP` for engine
/// selection.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    /// Hard iteration cap per block.
    pub max_iters: usize,
    /// Convergence: the oracle's max violation must fall below this.
    pub violation_tol: f64,
    /// Convergence also requires the last sweep's dual movement below
    /// this; the default `INFINITY` reproduces the paper's large-scale
    /// violation-only stopping.
    pub dual_tol: f64,
    /// Projection sweeps per round; `None` = the problem's own default
    /// (1 for nearness per Algorithm 8, 2/75 for dense/sparse CC).
    pub inner_sweeps: Option<usize>,
    /// Optional cap on total projections per block.
    pub projection_budget: Option<usize>,
    /// Record per-iteration statistics.
    pub record_trace: bool,
    /// FORGET treats duals with `|z|` below this as zero.
    pub z_tol: f64,
    /// Projection-sweep executor (sequential vs support-disjoint sharded
    /// parallel).
    pub sweep: SweepStrategy,
    /// Sharded executor's serial/parallel shard-size threshold
    /// (`None` = `PAF_PARALLEL_MIN_ROWS` or the tuned default).
    pub parallel_min_rows: Option<usize>,
    /// Overlap the oracle scan with the projection sweeps
    /// (single-block sessions with an overlap-capable oracle only; the
    /// certificate is then one round stale, so convergence detection is
    /// one round more conservative).
    pub overlap: bool,
    /// Feed per-round coordinate movement back to incremental oracles
    /// (the engine's movement log). Observation only — results are
    /// bit-identical either way; `false` forces incremental oracles
    /// onto their snapshot-diff fallback.
    pub track_movement: bool,
    /// Movement-driven lazy sweep scheduling: skip active rows whose
    /// support did not move since their last (zero-step) projection and
    /// visit the rest violated-first. Exact — results are bit-identical
    /// to the eager sweep either way. Requires `track_movement`; the
    /// engine auto-falls back to eager sweeps when movement tracking is
    /// unavailable (e.g. the PJRT batch executor).
    pub lazy_sweep: bool,
    /// Sample a convergence-telemetry frame every N rounds (0 = off).
    /// Observation only — frames are computed from state the round
    /// already produced, so results are bit-identical either way.
    pub telemetry_every: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            max_iters: 500,
            violation_tol: 1e-2,
            dual_tol: f64::INFINITY,
            inner_sweeps: None,
            projection_budget: None,
            record_trace: true,
            z_tol: 0.0,
            sweep: SweepStrategy::Sequential,
            parallel_min_rows: None,
            overlap: false,
            track_movement: true,
            lazy_sweep: default_lazy_sweep(),
            telemetry_every: 0,
        }
    }
}

impl SolveOptions {
    pub fn new() -> SolveOptions {
        SolveOptions::default()
    }

    /// Defaults plus the `PAF_SWEEP` (`sequential`, `sharded`,
    /// `sharded:<threads>`), `PAF_OVERLAP` (`1`/`true`) and
    /// `PAF_LAZY_SWEEP` (`0`/`false` disables) env overrides.
    pub fn from_env() -> SolveOptions {
        let mut opts = SolveOptions::default();
        if let Ok(v) = std::env::var("PAF_SWEEP") {
            opts.sweep = parse_sweep(&v).unwrap_or(opts.sweep);
        }
        if let Ok(v) = std::env::var("PAF_OVERLAP") {
            opts.overlap = v == "1" || v.eq_ignore_ascii_case("true");
        }
        if let Ok(v) = std::env::var("PAF_LAZY_SWEEP") {
            opts.lazy_sweep = parse_lazy_sweep(&v);
        }
        opts
    }

    pub fn max_iters(mut self, n: usize) -> Self {
        self.max_iters = n;
        self
    }

    pub fn violation_tol(mut self, tol: f64) -> Self {
        self.violation_tol = tol;
        self
    }

    pub fn dual_tol(mut self, tol: f64) -> Self {
        self.dual_tol = tol;
        self
    }

    pub fn inner_sweeps(mut self, n: usize) -> Self {
        self.inner_sweeps = Some(n);
        self
    }

    pub fn projection_budget(mut self, budget: usize) -> Self {
        self.projection_budget = Some(budget);
        self
    }

    pub fn record_trace(mut self, on: bool) -> Self {
        self.record_trace = on;
        self
    }

    pub fn z_tol(mut self, tol: f64) -> Self {
        self.z_tol = tol;
        self
    }

    pub fn sweep(mut self, sweep: SweepStrategy) -> Self {
        self.sweep = sweep;
        self
    }

    /// Shorthand for the sharded executor (`threads == 0` = auto).
    pub fn sharded(mut self, threads: usize) -> Self {
        self.sweep = SweepStrategy::ShardedParallel { threads };
        self
    }

    pub fn parallel_min_rows(mut self, rows: usize) -> Self {
        self.parallel_min_rows = Some(rows);
        self
    }

    pub fn overlap(mut self, on: bool) -> Self {
        self.overlap = on;
        self
    }

    pub fn track_movement(mut self, on: bool) -> Self {
        self.track_movement = on;
        self
    }

    pub fn lazy_sweep(mut self, on: bool) -> Self {
        self.lazy_sweep = on;
        self
    }

    /// Sample convergence telemetry every `n` rounds (0 disables).
    pub fn telemetry_every(mut self, n: usize) -> Self {
        self.telemetry_every = n;
        self
    }

    /// The per-block [`SolverConfig`] these options induce;
    /// `inner_sweeps_default` is the problem's structural default, used
    /// when the options leave `inner_sweeps` unset.
    pub fn solver_config(&self, inner_sweeps_default: usize) -> SolverConfig {
        SolverConfig {
            max_iters: self.max_iters,
            inner_sweeps: self.inner_sweeps.unwrap_or(inner_sweeps_default),
            violation_tol: self.violation_tol,
            dual_tol: self.dual_tol,
            projection_budget: self.projection_budget,
            record_trace: self.record_trace,
            z_tol: self.z_tol,
            sweep: self.sweep,
            parallel_min_rows: self.parallel_min_rows,
            track_movement: self.track_movement,
            lazy_sweep: self.lazy_sweep,
            telemetry_every: self.telemetry_every,
        }
    }
}

/// Parse a `PAF_LAZY_SWEEP`-style toggle: `0`/`false` disables the lazy
/// sweep scheduler, everything else keeps it on (the default).
pub fn parse_lazy_sweep(s: &str) -> bool {
    let s = s.trim();
    !(s == "0" || s.eq_ignore_ascii_case("false"))
}

/// Process-wide default for the lazy sweep scheduler: on, unless
/// `PAF_LAZY_SWEEP=0` is set (the CI eager legs run the whole suite
/// this way). Explicit `SolverConfig::lazy_sweep` /
/// [`SolveOptions::lazy_sweep`] settings always win over the env.
pub fn default_lazy_sweep() -> bool {
    std::env::var("PAF_LAZY_SWEEP").map(|v| parse_lazy_sweep(&v)).unwrap_or(true)
}

/// Parse a `PAF_SWEEP`-style strategy string.
pub fn parse_sweep(s: &str) -> Option<SweepStrategy> {
    let s = s.trim();
    if s.eq_ignore_ascii_case("sequential") {
        return Some(SweepStrategy::Sequential);
    }
    if s.eq_ignore_ascii_case("sharded") {
        return Some(SweepStrategy::ShardedParallel { threads: 0 });
    }
    if let Some(t) = s.strip_prefix("sharded:") {
        return t.parse::<usize>().ok().map(|threads| SweepStrategy::ShardedParallel { threads });
    }
    None
}

/// A typed problem instance that a [`Session`](crate::core::session::Session)
/// can solve: it builds the Bregman geometry, the separation oracle and
/// (implicitly, via the geometry's `argmin`) the initial iterate, and it
/// interprets the final iterate into a typed result.
///
/// The lifetime `'a` bounds borrows the problem carries into the session
/// (instances typically borrow their input data).
pub trait Problem<'a> {
    /// Typed interpretation of the solved block.
    type Output: 'static;

    /// Lower this instance into session-executable form. `opts` is the
    /// session's option set — oracles may depend on it (e.g. the metric
    /// oracle pre-buckets delivery by disjoint shard exactly when the
    /// sharded engine is selected).
    fn lower(self, opts: &SolveOptions) -> Lowered<'a, Self::Output>;
}

/// What a [`Problem`] lowers to.
pub enum Lowered<'a, T> {
    /// A diagonal-quadratic vector block solved by the shared engine
    /// (batchable with other vector blocks into one sharded sweep).
    Vector(VectorPart<'a, T>),
    /// A self-driving round-based problem (e.g. ITML's matrix iterate).
    Rounds(Box<dyn RoundProblem<Output = T> + 'a>),
}

/// The vector-block lowering: geometry + oracle + per-block solver
/// config + result interpretation.
pub struct VectorPart<'a, T> {
    /// Display name (traces and events).
    pub name: &'static str,
    /// The block's Bregman geometry; its `argmin` is the initial
    /// iterate, and it is handed back to `interpret` for objective
    /// evaluation.
    pub f: DiagonalQuadratic,
    /// The block's separation oracle, in block-local coordinates
    /// (`0..f.dim()`); the session offsets deliveries when batching.
    pub oracle: VectorOracle<'a>,
    /// Per-block solver knobs (stop rules may differ per block; the
    /// structural knobs `inner_sweeps`/`z_tol`/`sweep` must agree across
    /// the blocks of one session).
    pub config: SolverConfig,
    /// Interpret the block's final iterate + statistics.
    pub interpret: Box<dyn FnOnce(&DiagonalQuadratic, SolverResult) -> T + 'a>,
}

/// An erased vector-block oracle. `Overlappable` additionally supports
/// the scan/deliver split required by the overlapped pipeline.
pub enum VectorOracle<'a> {
    Plain(Box<dyn Oracle<DiagonalQuadratic> + 'a>),
    Overlappable(ErasedOverlappable<'a>),
}

impl VectorOracle<'_> {
    /// Human-readable oracle name.
    pub fn name(&self) -> &str {
        match self {
            VectorOracle::Plain(o) => o.name(),
            VectorOracle::Overlappable(o) => Oracle::<DiagonalQuadratic>::name(o),
        }
    }
}

/// Object-safe mirror of [`OverlappableOracle`] with the scan payload
/// boxed as `Any`. Implemented blanket-wise for every overlappable
/// oracle whose scan type is `'static`.
pub trait DynOverlappable: Send + Sync {
    fn dyn_separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome;
    fn dyn_scan(&self, x: &[f64]) -> Box<dyn Any + Send>;
    fn dyn_deliver(
        &mut self,
        scan: Box<dyn Any + Send>,
        sink: &mut dyn ProjectionSink,
    ) -> OracleOutcome;
    fn dyn_name(&self) -> &str;
}

impl<O> DynOverlappable for O
where
    O: OverlappableOracle<DiagonalQuadratic> + Send + Sync,
    O::Scan: 'static,
{
    fn dyn_separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        Oracle::<DiagonalQuadratic>::separate(self, sink)
    }

    fn dyn_scan(&self, x: &[f64]) -> Box<dyn Any + Send> {
        Box::new(OverlappableOracle::<DiagonalQuadratic>::scan(self, x))
    }

    fn dyn_deliver(
        &mut self,
        scan: Box<dyn Any + Send>,
        sink: &mut dyn ProjectionSink,
    ) -> OracleOutcome {
        let scan = scan
            .downcast::<O::Scan>()
            .expect("overlap pipeline delivered a foreign scan payload");
        OverlappableOracle::<DiagonalQuadratic>::deliver(self, *scan, sink)
    }

    fn dyn_name(&self) -> &str {
        Oracle::<DiagonalQuadratic>::name(self)
    }
}

/// A boxed [`DynOverlappable`] presented back as a concrete
/// [`OverlappableOracle`], so the erased oracle can flow through the
/// exact same `solve_overlapped` machinery as a typed one (same calls,
/// same arithmetic — erasure never changes results).
pub struct ErasedOverlappable<'a>(Box<dyn DynOverlappable + 'a>);

impl<'a> ErasedOverlappable<'a> {
    pub fn new<O>(oracle: O) -> ErasedOverlappable<'a>
    where
        O: OverlappableOracle<DiagonalQuadratic> + Send + Sync + 'a,
        O::Scan: 'static,
    {
        ErasedOverlappable(Box::new(oracle))
    }
}

impl Oracle<DiagonalQuadratic> for ErasedOverlappable<'_> {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        self.0.dyn_separate(sink)
    }

    fn name(&self) -> &str {
        self.0.dyn_name()
    }
}

impl OverlappableOracle<DiagonalQuadratic> for ErasedOverlappable<'_> {
    type Scan = Box<dyn Any + Send>;

    fn scan(&self, x: &[f64]) -> Self::Scan {
        self.0.dyn_scan(x)
    }

    fn deliver(&mut self, scan: Self::Scan, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        self.0.dyn_deliver(scan, sink)
    }
}

/// Opaque state snapshot of a round-driven problem (for
/// checkpoint/resume). `Arc`ed so checkpoints stay cheaply clonable.
pub type RoundSnapshot = Arc<dyn Any + Send + Sync>;

/// What one round of a round-driven problem did (event reporting).
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundReport {
    /// Constraints the round's oracle batch delivered.
    pub found: usize,
    /// Projections performed this round.
    pub projections: usize,
    /// Remembered (active) constraints after the round's FORGET.
    pub active: usize,
}

/// A problem that drives its own iterate but exposes the PROJECT AND
/// FORGET loop at round granularity, so the session can step it in
/// lockstep with the vector fleet (observers, cancellation and
/// checkpointing all compose).
pub trait RoundProblem {
    type Output: 'static;

    fn name(&self) -> &'static str {
        "round-problem"
    }

    /// Execute one oracle/sweep/forget round.
    fn round(&mut self) -> RoundReport;

    /// Has the problem reached its stop rule?
    fn done(&self) -> bool;

    /// Interpret the final state into the typed result.
    fn finish(self: Box<Self>) -> Self::Output;

    /// Snapshot the full solve state, if the problem supports
    /// checkpointing (`None` otherwise).
    fn snapshot(&self) -> Option<RoundSnapshot> {
        None
    }

    /// Restore a snapshot produced by [`RoundProblem::snapshot`].
    fn restore(&mut self, snapshot: &RoundSnapshot) {
        let _ = snapshot;
        panic!("this round-driven problem does not support checkpoint/restore");
    }
}

/// Cooperative cancellation for a running session: clone the token,
/// call [`CancelToken::cancel`] from anywhere (another thread, a signal
/// handler, an observer), and the session stops at the next round
/// boundary with a [`SolveEvent::Cancelled`].
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Typed handle to one problem added to a session; redeem with
/// [`Session::take`](crate::core::session::Session::take) once the
/// session finished.
pub struct Handle<T> {
    pub(crate) idx: usize,
    pub(crate) _marker: PhantomData<fn() -> T>,
}

impl<T> Handle<T> {
    pub(crate) fn new(idx: usize) -> Handle<T> {
        Handle { idx, _marker: PhantomData }
    }

    /// The block index inside the session (event correlation).
    pub fn index(&self) -> usize {
        self.idx
    }
}

impl<T> Clone for Handle<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for Handle<T> {}

impl<T> std::fmt::Debug for Handle<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Handle({})", self.idx)
    }
}

/// One completed session round, aggregated over the live blocks.
#[derive(Debug, Clone)]
pub struct RoundEvent {
    /// 0-based session round.
    pub round: usize,
    /// Blocks still being driven this round.
    pub live_blocks: usize,
    /// Constraints delivered across live vector blocks.
    pub found: usize,
    /// Remembered rows after the merge (all vector blocks).
    pub merged: usize,
    /// Remembered rows after the sweeps' FORGETs.
    pub remembered: usize,
    /// Worst oracle-certificate violation over the live vector blocks.
    pub max_violation: f64,
    /// Projections performed this round (vector fleet + round-driven).
    pub projections: usize,
    /// Per-phase timing breakdown of the round.
    pub phases: PhaseTimes,
    /// Wall-clock seconds for the round.
    pub seconds: f64,
}

/// A block reached its stop rule.
#[derive(Debug, Clone)]
pub struct BlockDone {
    pub block: usize,
    pub name: &'static str,
    /// For vector blocks: the convergence certificate held (false on a
    /// session-imposed iteration/projection cap). For round-driven
    /// blocks: the problem's *own* stop rule completed — e.g. PF-ITML's
    /// equalised projection budget counts as converged, matching the
    /// paper's protocol. Always false when finalized by cancellation.
    pub converged: bool,
    pub iterations: usize,
    pub projections: usize,
}

/// Per-block summary in the final certificate.
#[derive(Debug, Clone)]
pub struct BlockSummary {
    pub name: &'static str,
    pub converged: bool,
    pub iterations: usize,
    pub projections: usize,
}

/// The session's final certificate: what happened, per block.
#[derive(Debug, Clone)]
pub struct SessionSummary {
    /// Session rounds driven.
    pub rounds: usize,
    /// Every block converged.
    pub all_converged: bool,
    /// The cancel token fired before completion.
    pub cancelled: bool,
    pub blocks: Vec<BlockSummary>,
}

/// Typed events yielded by [`Session::step`](crate::core::session::Session::step)
/// and delivered to observers.
#[derive(Debug, Clone)]
pub enum SolveEvent {
    /// One session round completed.
    Round(RoundEvent),
    /// A block reached its stop rule (emitted before the enclosing
    /// round/finished event).
    BlockDone(BlockDone),
    /// The cancel token fired; the session stopped early.
    Cancelled { round: usize },
    /// All blocks are done (also returned by further `step` calls).
    Finished(SessionSummary),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_strings_parse() {
        assert_eq!(parse_sweep("sequential"), Some(SweepStrategy::Sequential));
        assert_eq!(parse_sweep("Sharded"), Some(SweepStrategy::ShardedParallel { threads: 0 }));
        assert_eq!(
            parse_sweep("sharded:4"),
            Some(SweepStrategy::ShardedParallel { threads: 4 })
        );
        assert_eq!(parse_sweep("sharded:x"), None);
        assert_eq!(parse_sweep("mystery"), None);
    }

    #[test]
    fn lazy_sweep_strings_parse() {
        assert!(!parse_lazy_sweep("0"));
        assert!(!parse_lazy_sweep("false"));
        assert!(!parse_lazy_sweep(" FALSE "));
        assert!(parse_lazy_sweep("1"));
        assert!(parse_lazy_sweep("true"));
        assert!(parse_lazy_sweep("anything-else"));
    }

    #[test]
    fn options_induce_solver_config() {
        let opts = SolveOptions::new()
            .max_iters(7)
            .violation_tol(1e-5)
            .dual_tol(1e-6)
            .z_tol(1e-14)
            .sharded(3)
            .record_trace(false);
        let cfg = opts.solver_config(2);
        assert_eq!(cfg.max_iters, 7);
        assert_eq!(cfg.inner_sweeps, 2, "problem default wins when unset");
        assert_eq!(opts.clone().inner_sweeps(5).solver_config(2).inner_sweeps, 5);
        assert_eq!(cfg.violation_tol, 1e-5);
        assert_eq!(cfg.dual_tol, 1e-6);
        assert_eq!(cfg.z_tol, 1e-14);
        assert!(!cfg.record_trace);
        assert_eq!(cfg.sweep, SweepStrategy::ShardedParallel { threads: 3 });
        assert!(cfg.lazy_sweep, "lazy sweeps default on");
        assert!(!opts.clone().lazy_sweep(false).solver_config(2).lazy_sweep);
        assert_eq!(cfg.telemetry_every, 0, "telemetry defaults off");
        assert_eq!(opts.clone().telemetry_every(3).solver_config(2).telemetry_every, 3);
    }

    #[test]
    fn cancel_token_is_shared() {
        let a = CancelToken::new();
        let b = a.clone();
        assert!(!a.is_cancelled());
        b.cancel();
        assert!(a.is_cancelled());
    }
}
