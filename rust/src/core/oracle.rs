//! Separation-oracle interfaces (Properties 1 and 2 of the paper).
//!
//! The engine hands the oracle a [`ProjectionSink`]: the oracle can either
//! `remember` a violated constraint (plain Algorithm 1) or
//! `project_and_remember` it immediately (the Algorithm 8 implementation
//! detail: "it is much more efficient in practice to do the project and
//! forget steps for a single constraint as we find it" — the constraint is
//! then kept only if its dual is nonzero after the projection).

use super::bregman::BregmanFunction;
use super::constraint::Constraint;
use super::solver::Solver;

/// What an oracle reports back to the engine after one separation round.
#[derive(Debug, Clone, Copy, Default)]
pub struct OracleOutcome {
    /// Constraints delivered to the sink this round.
    pub found: usize,
    /// Maximum violation witnessed, i.e. `max_C dist`-style certificate.
    /// 0 means the oracle certifies (approximate) feasibility.
    pub max_violation: f64,
}

/// The engine-side interface the oracle drives.
pub trait ProjectionSink {
    /// Current iterate (read-only).
    fn x(&self) -> &[f64];

    /// Remember a constraint for the upcoming projection sweep.
    fn remember(&mut self, c: &Constraint);

    /// Project onto the constraint immediately and remember it iff its
    /// dual is nonzero afterwards (Algorithm 8, lines 9–12).
    fn project_and_remember(&mut self, c: &Constraint);
}

/// A deterministic separation oracle (Property 1): on input `x` it either
/// certifies feasibility (returns `max_violation == 0`) or delivers a list
/// of violated constraints whose worst violation is within a fixed
/// function φ of the distance to the feasible set.
pub trait Oracle<F: BregmanFunction> {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome;

    /// Human-readable name for traces.
    fn name(&self) -> &str {
        "oracle"
    }
}

/// A random separation oracle (Property 2): every constraint has sampling
/// probability ≥ τ > 0. Implemented as a plain [`Oracle`] whose `separate`
/// samples; the marker trait documents which guarantee an implementation
/// provides (used by tests to pick the right convergence assertions).
pub trait RandomOracle<F: BregmanFunction>: Oracle<F> {}

/// An oracle over an explicit, finite constraint list — the textbook
/// (cyclic Bregman) setting. Deterministic Property-1 oracle: it returns
/// every currently-violated constraint. Mostly used by tests and the SVM
/// baseline; real metric problems use the graph oracles in `problems::`.
pub struct ListOracle {
    pub constraints: Vec<Constraint>,
    /// Violation tolerance below which a constraint is not reported.
    pub tol: f64,
}

impl ListOracle {
    pub fn new(constraints: Vec<Constraint>) -> ListOracle {
        ListOracle { constraints, tol: 0.0 }
    }
}

impl<F: BregmanFunction> Oracle<F> for ListOracle {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        for c in &self.constraints {
            let v = c.violation(sink.x());
            if v > self.tol {
                sink.remember(c);
                out.found += 1;
                out.max_violation = out.max_violation.max(v);
            }
        }
        out
    }

    fn name(&self) -> &str {
        "list"
    }
}

/// Uniform random sampling over an explicit list (Property 2 with
/// τ = batch/len): the stochastic baseline of §3.1.3.
pub struct SampledListOracle {
    pub constraints: Vec<Constraint>,
    pub batch: usize,
    pub rng: crate::util::Rng,
}

impl<F: BregmanFunction> Oracle<F> for SampledListOracle {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        let n = self.constraints.len();
        for _ in 0..self.batch.min(n) {
            let c = &self.constraints[self.rng.below(n)];
            let v = c.violation(sink.x());
            out.max_violation = out.max_violation.max(v);
            sink.project_and_remember(c);
            out.found += 1;
        }
        out
    }

    fn name(&self) -> &str {
        "sampled-list"
    }
}

impl<F: BregmanFunction> RandomOracle<F> for SampledListOracle {}

/// Run a closure as an oracle (for ad-hoc problem drivers).
pub struct FnOracle<G>(pub G, pub &'static str);

impl<F, G> Oracle<F> for FnOracle<G>
where
    F: BregmanFunction,
    G: FnMut(&mut dyn ProjectionSink) -> OracleOutcome,
{
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        (self.0)(sink)
    }

    fn name(&self) -> &str {
        self.1
    }
}

/// Convenience used by problem drivers: solve with an oracle built from a
/// closure. Re-exported through [`Solver::solve_with`].
pub fn oracle_from_fn<F, G>(g: G, name: &'static str) -> FnOracle<G>
where
    F: BregmanFunction,
    G: FnMut(&mut dyn ProjectionSink) -> OracleOutcome,
{
    let _ = std::marker::PhantomData::<F>;
    FnOracle(g, name)
}

/// Blanket helper so `&mut O` is itself an oracle (lets drivers reuse one).
impl<F: BregmanFunction, O: Oracle<F>> Oracle<F> for &mut O {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        (**self).separate(sink)
    }

    fn name(&self) -> &str {
        (**self).name()
    }
}

#[allow(unused)]
fn _assert_object_safe(_: &dyn ProjectionSink) {}

#[allow(unused)]
fn _solver_is_referenced(_: &Solver<super::bregman::DiagonalQuadratic>) {}
