//! Separation-oracle interfaces (Properties 1 and 2 of the paper).
//!
//! The engine hands the oracle a [`ProjectionSink`]: the oracle can either
//! `remember` a violated constraint (plain Algorithm 1) or
//! `project_and_remember` it immediately (the Algorithm 8 implementation
//! detail: "it is much more efficient in practice to do the project and
//! forget steps for a single constraint as we find it" — the constraint is
//! then kept only if its dual is nonzero after the projection).

use super::bregman::BregmanFunction;
use super::constraint::Constraint;
use super::solver::Solver;

/// What an oracle reports back to the engine after one separation round.
///
/// # The `max_violation == 0` feasibility-certificate convention
///
/// `max_violation` is the oracle's convergence certificate, and the
/// convention is load-bearing: the solver stops (together with the dual
/// test) exactly when `max_violation <= violation_tol`. An oracle that
/// witnessed **no** violation above its reporting tolerance must leave
/// `max_violation` at `0.0` — that is the certificate "the iterate is
/// feasible up to my tolerance". Conversely, violations at or below the
/// oracle's reporting tolerance must not leak into `max_violation`:
/// every implementation here applies one tolerance symmetrically to
/// *reporting a constraint* and to *witnessing its violation*, so the
/// certificate and the delivered list always agree. Property-2 (random)
/// oracles can sample an all-satisfied batch and emit a spurious
/// certificate; their solves disable violation-based stopping instead
/// (see `SampledListOracle`).
#[derive(Debug, Clone, Copy, Default)]
pub struct OracleOutcome {
    /// Constraints delivered to the sink this round.
    pub found: usize,
    /// Maximum violation witnessed above the oracle's reporting
    /// tolerance; `0.0` certifies (approximate) feasibility.
    pub max_violation: f64,
}

/// Which box face of the feasible set a bulk
/// [`ProjectionSink::project_box`] pass delivers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoxKind {
    /// Non-negativity rows `−x_e ≤ 0` (always part of MET(G)).
    NonNeg,
    /// Upper-bound rows `x_e ≤ bound` (correlation clustering's box).
    Upper,
}

/// What one bulk box pass witnessed: rows violated by more than the
/// pass's tolerance and their worst violation, both measured against the
/// iterate each row saw *before* its own projection — exactly what the
/// per-row delivery loop historically reported.
#[derive(Debug, Clone, Copy, Default)]
pub struct BoxOutcome {
    pub found: usize,
    pub max_violation: f64,
}

/// The engine-side interface the oracle drives.
pub trait ProjectionSink {
    /// Current iterate (read-only).
    fn x(&self) -> &[f64];

    /// Remember a constraint for the upcoming projection sweep.
    fn remember(&mut self, c: &Constraint);

    /// Project onto the constraint immediately and remember it iff its
    /// dual is nonzero afterwards (Algorithm 8, lines 9–12).
    fn project_and_remember(&mut self, c: &Constraint);

    /// Bulk-deliver one box face per coordinate `start..start + len` of
    /// this sink's iterate: `−x_e ≤ 0` ([`BoxKind::NonNeg`]; `bound` is
    /// ignored) or `x_e ≤ bound` ([`BoxKind::Upper`]). Semantically
    /// identical to calling [`ProjectionSink::project_and_remember`]
    /// with the corresponding single-index row for each coordinate in
    /// ascending order — which is exactly what this default does. The
    /// engine sink overrides it with a fused pass that resolves the
    /// per-row duals through a flat slot mirror instead of per-row
    /// content hashing, and materializes a row only when it must enter
    /// the store (see `Solver`'s sink). Violations at or below `tol`
    /// are not counted (the oracle's reporting-tolerance convention).
    fn project_box(
        &mut self,
        kind: BoxKind,
        start: u32,
        len: usize,
        bound: f64,
        tol: f64,
    ) -> BoxOutcome {
        let mut out = BoxOutcome::default();
        let mut c = match kind {
            BoxKind::NonNeg => Constraint::nonneg(0),
            BoxKind::Upper => Constraint::upper(0, bound),
        };
        for k in 0..len {
            let e = start as usize + k;
            let v = match kind {
                BoxKind::NonNeg => -self.x()[e],
                BoxKind::Upper => self.x()[e] - bound,
            };
            if v > tol {
                out.found += 1;
                out.max_violation = out.max_violation.max(v);
            }
            // Delivered regardless of violation: satisfied rows with
            // z > 0 still need relaxation projections.
            c.indices[0] = e as u32;
            self.project_and_remember(&c);
        }
        out
    }

    /// Movement-feedback seam for incremental oracles: a cursor into the
    /// engine's coordinate-movement log, to be taken at the moment the
    /// oracle snapshots the iterate. Taking a cursor also starts a new
    /// mark-dedup epoch on tracking sinks (so a coordinate moved both
    /// before and after the cursor is re-logged after it — the window
    /// must stay a superset of the movement since the snapshot), which
    /// is why this takes `&mut self`. `None` when the sink has no
    /// tracking (non-engine sinks, tracking disabled) — the oracle then
    /// falls back to diffing its own snapshot.
    fn movement_cursor(&mut self) -> Option<u64> {
        None
    }

    /// Append the coordinates (in *this sink's* coordinate space)
    /// touched by projections since `cursor` to `out`; the list is a
    /// superset of the coordinates whose value changed, possibly with
    /// duplicates. Returns `false` — appending nothing — when the log
    /// no longer covers the window; callers must then diff instead.
    fn moved_since(&self, cursor: u64, out: &mut Vec<u32>) -> bool {
        let _ = (cursor, out);
        false
    }
}

/// A deterministic separation oracle (Property 1): on input `x` it either
/// certifies feasibility (returns `max_violation == 0`) or delivers a list
/// of violated constraints whose worst violation is within a fixed
/// function φ of the distance to the feasible set.
pub trait Oracle<F: BregmanFunction> {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome;

    /// Human-readable name for traces.
    fn name(&self) -> &str {
        "oracle"
    }
}

/// A random separation oracle (Property 2): every constraint has sampling
/// probability ≥ τ > 0. Implemented as a plain [`Oracle`] whose `separate`
/// samples; the marker trait documents which guarantee an implementation
/// provides (used by tests to pick the right convergence assertions).
pub trait RandomOracle<F: BregmanFunction>: Oracle<F> {}

/// An oracle whose separation *scan* is a pure, read-only function of a
/// snapshot of the iterate, with constraint delivery deferred to a
/// second step.
///
/// This is the seam for oracle/sweep overlap
/// (`Solver::solve_overlapped`): `scan` runs on the worker pool against
/// the back buffer of a double-buffered `x` while the engine drains the
/// current round's projection sweeps on the front buffer; `deliver`
/// merges the findings at the sweep barrier. Implementations must keep
/// `scan` free of observable mutation and deterministic in `x` — both
/// are what makes the overlapped solve bit-reproducible at every thread
/// count. `separate` should be equivalent to `scan` + `deliver` run
/// back-to-back, so the overlapped pipeline differs from the plain one
/// only in *which* snapshot each scan sees (one round staler), never in
/// what a scan of a given snapshot produces.
pub trait OverlappableOracle<F: BregmanFunction>: Oracle<F> {
    /// Findings of one scan (crosses the sweep barrier, hence `Send`).
    type Scan: Send;

    /// Read-only separation scan of `x`.
    fn scan(&self, x: &[f64]) -> Self::Scan;

    /// Merge a scan's findings into the sink. The returned certificate's
    /// `max_violation` refers to the scanned snapshot — in the
    /// overlapped pipeline that snapshot is one round stale, so the
    /// solver's convergence test is correspondingly conservative.
    fn deliver(&mut self, scan: Self::Scan, sink: &mut dyn ProjectionSink) -> OracleOutcome;
}

/// An oracle over an explicit, finite constraint list — the textbook
/// (cyclic Bregman) setting. Deterministic Property-1 oracle: it returns
/// every currently-violated constraint. Mostly used by tests and the SVM
/// baseline; real metric problems use the graph oracles in `problems::`.
pub struct ListOracle {
    pub constraints: Vec<Constraint>,
    /// Reporting tolerance, with the same semantics as
    /// `MetricOracle::report_tol`: violations at or below `tol` are
    /// neither delivered nor counted into `max_violation`, so when every
    /// violation is within `tol` the outcome is the
    /// `max_violation == 0` feasibility certificate. Keep `tol` below
    /// the solver's `violation_tol`, or the oracle certifies earlier
    /// than the solver intends.
    pub tol: f64,
}

impl ListOracle {
    pub fn new(constraints: Vec<Constraint>) -> ListOracle {
        ListOracle { constraints, tol: 0.0 }
    }

    /// Like [`ListOracle::new`] with an explicit reporting tolerance.
    pub fn with_tol(constraints: Vec<Constraint>, tol: f64) -> ListOracle {
        ListOracle { constraints, tol }
    }
}

impl<F: BregmanFunction> Oracle<F> for ListOracle {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        for c in &self.constraints {
            let v = c.violation(sink.x());
            if v > self.tol {
                sink.remember(c);
                out.found += 1;
                out.max_violation = out.max_violation.max(v);
            }
        }
        out
    }

    fn name(&self) -> &str {
        "list"
    }
}

/// Uniform random sampling over an explicit list (Property 2 with
/// τ = batch/len): the stochastic baseline of §3.1.3. Its
/// `max_violation` is only the max over the *sampled* batch — a
/// `0.0` outcome is NOT a feasibility certificate (see
/// [`OracleOutcome`]); solves using it disable violation stopping.
pub struct SampledListOracle {
    pub constraints: Vec<Constraint>,
    pub batch: usize,
    pub rng: crate::util::Rng,
    /// Reporting tolerance, symmetric with [`ListOracle::tol`].
    pub tol: f64,
}

impl<F: BregmanFunction> Oracle<F> for SampledListOracle {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        let n = self.constraints.len();
        for _ in 0..self.batch.min(n) {
            let c = &self.constraints[self.rng.below(n)];
            let v = c.violation(sink.x());
            if v > self.tol {
                out.max_violation = out.max_violation.max(v);
            }
            // Delivered regardless: satisfied rows with dual history
            // still need their relaxation projection.
            sink.project_and_remember(c);
            out.found += 1;
        }
        out
    }

    fn name(&self) -> &str {
        "sampled-list"
    }
}

impl<F: BregmanFunction> RandomOracle<F> for SampledListOracle {}

/// Run a closure as an oracle (for ad-hoc problem drivers).
pub struct FnOracle<G>(pub G, pub &'static str);

impl<F, G> Oracle<F> for FnOracle<G>
where
    F: BregmanFunction,
    G: FnMut(&mut dyn ProjectionSink) -> OracleOutcome,
{
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        (self.0)(sink)
    }

    fn name(&self) -> &str {
        self.1
    }
}

/// Convenience used by problem drivers: solve with an oracle built from a
/// closure. Re-exported through [`Solver::solve_with`].
pub fn oracle_from_fn<F, G>(g: G, name: &'static str) -> FnOracle<G>
where
    F: BregmanFunction,
    G: FnMut(&mut dyn ProjectionSink) -> OracleOutcome,
{
    let _ = std::marker::PhantomData::<F>;
    FnOracle(g, name)
}

/// Blanket helper so `&mut O` is itself an oracle (lets drivers reuse one).
impl<F: BregmanFunction, O: Oracle<F>> Oracle<F> for &mut O {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        (**self).separate(sink)
    }

    fn name(&self) -> &str {
        (**self).name()
    }
}

#[allow(unused)]
fn _assert_object_safe(_: &dyn ProjectionSink) {}

#[allow(unused)]
fn _solver_is_referenced(_: &Solver<super::bregman::DiagonalQuadratic>) {}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal sink that records deliveries without projecting.
    struct RecordingSink {
        x: Vec<f64>,
        remembered: usize,
    }

    impl ProjectionSink for RecordingSink {
        fn x(&self) -> &[f64] {
            &self.x
        }
        fn remember(&mut self, _c: &Constraint) {
            self.remembered += 1;
        }
        fn project_and_remember(&mut self, _c: &Constraint) {
            self.remembered += 1;
        }
    }

    #[test]
    fn list_oracle_tol_is_symmetric_between_reporting_and_certificate() {
        // Two constraints violated by 0.05 and 0.20 at x.
        let cons = vec![
            Constraint::new(vec![0], vec![1.0], 1.0),
            Constraint::new(vec![1], vec![1.0], 1.0),
        ];
        let mut sink = RecordingSink { x: vec![1.05, 1.20], remembered: 0 };
        // tol below both: both delivered, certificate reports the worst.
        let mut oracle = ListOracle::with_tol(cons.clone(), 1e-3);
        let out = Oracle::<crate::core::bregman::DiagonalQuadratic>::separate(
            &mut oracle,
            &mut sink,
        );
        assert_eq!(out.found, 2);
        assert!((out.max_violation - 0.20).abs() < 1e-12);
        // tol between the two violations: the sub-tol row is neither
        // delivered nor counted into the certificate.
        let mut sink = RecordingSink { x: vec![1.05, 1.20], remembered: 0 };
        let mut oracle = ListOracle::with_tol(cons.clone(), 0.1);
        let out = Oracle::<crate::core::bregman::DiagonalQuadratic>::separate(
            &mut oracle,
            &mut sink,
        );
        assert_eq!(out.found, 1);
        assert_eq!(sink.remembered, 1);
        assert!((out.max_violation - 0.20).abs() < 1e-12);
        // tol above both: max_violation == 0 is the feasibility
        // certificate, and — symmetrically — nothing is delivered.
        let mut sink = RecordingSink { x: vec![1.05, 1.20], remembered: 0 };
        let mut oracle = ListOracle::with_tol(cons, 0.5);
        let out = Oracle::<crate::core::bregman::DiagonalQuadratic>::separate(
            &mut oracle,
            &mut sink,
        );
        assert_eq!(out.found, 0);
        assert_eq!(sink.remembered, 0);
        assert_eq!(out.max_violation, 0.0);
    }

    #[test]
    fn sampled_oracle_respects_tol_in_certificate() {
        let cons = vec![Constraint::new(vec![0], vec![1.0], 1.0)];
        let mut sink = RecordingSink { x: vec![1.05], remembered: 0 };
        let mut oracle = SampledListOracle {
            constraints: cons,
            batch: 4,
            rng: crate::util::Rng::new(3),
            tol: 0.1,
        };
        let out = Oracle::<crate::core::bregman::DiagonalQuadratic>::separate(
            &mut oracle,
            &mut sink,
        );
        // Sub-tol violations are still delivered (relaxation needs them)
        // but never leak into the certificate.
        assert!(out.found > 0);
        assert!(sink.remembered > 0);
        assert_eq!(out.max_violation, 0.0);
    }
}
