//! The PROJECT AND FORGET outer loop (Algorithm 1).
//!
//! Per iteration: query the separation oracle, merge its findings into the
//! remembered list `L^(ν)`, run `inner_sweeps` rounds of Bregman
//! projections with dual corrections over the merged list (Algorithm 3),
//! forget every constraint whose dual returned to zero, and test
//! convergence. The engine maintains the KKT identity
//! `∇f(x) = −Aᵀz` (Step 1 of the convergence proof) at all times, which
//! tests verify directly.

use super::active_set::ActiveSet;
use super::bregman::{BregmanFunction, DiagonalQuadratic};
use super::constraint::{Constraint, ConstraintView};
use super::engine::{self, MovementTracker, SweepExecutor, SweepStrategy};
use super::oracle::{BoxKind, BoxOutcome, Oracle, OracleOutcome, OverlappableOracle, ProjectionSink};
use crate::obs;
use crate::obs::TelemetryFrame;
use crate::util::pool;
use crate::util::Stopwatch;

/// Tuning knobs for the solve loop.
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Hard iteration cap.
    pub max_iters: usize,
    /// Projection sweeps over the merged list per iteration (the paper
    /// uses 2 for metric nearness / dense CC and 75 for sparse CC).
    pub inner_sweeps: usize,
    /// Convergence: stop when the oracle's max violation falls below this.
    pub violation_tol: f64,
    /// Convergence also requires the total dual movement `Σ|c|` of the
    /// last iteration's sweeps to fall below this (the oracle certifying
    /// feasibility is necessary but not sufficient: remembered constraints
    /// may still be relaxing over-corrections). Set to `f64::INFINITY` to
    /// stop on violations alone, as the paper's large-scale runs do.
    pub dual_tol: f64,
    /// Optional cap on total individual projections (ITML comparisons).
    pub projection_budget: Option<usize>,
    /// Record per-iteration statistics (Figures 2 and 3).
    pub record_trace: bool,
    /// Dual values with |z| below this are treated as zero by FORGET
    /// (guards against floating-point dust keeping dead constraints).
    pub z_tol: f64,
    /// Which sweep executor runs the projection sweeps (see
    /// [`SweepStrategy`]). `Sequential` reproduces the historical solver
    /// bit for bit; `ShardedParallel` runs support-disjoint rows
    /// concurrently with deterministic results.
    pub sweep: SweepStrategy,
    /// Minimum shard size for the sharded executor's parallel θ+apply
    /// path; `None` = auto (`PAF_PARALLEL_MIN_ROWS` env override or the
    /// tuned default). Purely a scheduling threshold — serial and
    /// parallel in-shard paths are arithmetic-identical, so this never
    /// changes results.
    pub parallel_min_rows: Option<usize>,
    /// Feed per-round coordinate movement back to incremental oracles
    /// (the [`MovementTracker`] dirty log, drained through the sink's
    /// movement seam). Pure observation — results are bit-identical
    /// either way; `false` only forces incremental oracles onto their
    /// snapshot-diff fallback. Auto-disabled when the configured
    /// executor has no tracked sweep path (the PJRT batch adapter).
    pub track_movement: bool,
    /// Movement-driven lazy sweep scheduling (see `engine::lazy`): skip
    /// rows that are provably zero-step no-ops (support unmoved since
    /// the row's last projection *and* last dual step zero) and visit
    /// the rest of each support-disjoint shard in greedy Gauss–Southwell
    /// order. The skip rule is exact, so results — `x`, every dual, the
    /// projection counts, the recording channel — are bit-identical to
    /// eager sweeps; only `IterStats::rows_projected` shrinks. Engages
    /// only on movement-tracked sweeps, so it auto-disables alongside
    /// `track_movement` (and for executors without a tracked path, e.g.
    /// PJRT). External surgery on `x` or the duals outside the engine's
    /// own paths requires `Solver::invalidate_movement` first (the
    /// checkpoint-restore path already does this) — the next sweep then
    /// projects everything once and re-arms from fresh state.
    pub lazy_sweep: bool,
    /// Sample a convergence-telemetry frame every N rounds (0 = off).
    /// Frames land in [`SolverResult::telemetry`]; sampling is pure
    /// observation and never changes the trajectory.
    pub telemetry_every: usize,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            max_iters: 1000,
            inner_sweeps: 2,
            violation_tol: 1e-2,
            dual_tol: 1e-9,
            projection_budget: None,
            record_trace: true,
            z_tol: 0.0,
            sweep: SweepStrategy::Sequential,
            parallel_min_rows: None,
            track_movement: true,
            lazy_sweep: crate::core::problem::default_lazy_sweep(),
            telemetry_every: 0,
        }
    }
}

/// Wall-clock seconds spent in each phase of the PROJECT AND FORGET
/// round: the separation oracle (scan + delivery), the projection
/// sweeps, and the FORGET compactions. Attached to both [`IterStats`]
/// (per round) and [`SolverResult`] (accumulated over the solve).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimes {
    pub oracle_s: f64,
    pub sweep_s: f64,
    pub forget_s: f64,
}

impl PhaseTimes {
    /// Sum of all phase times.
    pub fn total(&self) -> f64 {
        self.oracle_s + self.sweep_s + self.forget_s
    }

    /// Accumulate another breakdown into this one.
    pub fn accumulate(&mut self, other: &PhaseTimes) {
        self.oracle_s += other.oracle_s;
        self.sweep_s += other.sweep_s;
        self.forget_s += other.forget_s;
    }
}

/// Per-iteration statistics (drives Figures 2 and 3).
#[derive(Debug, Clone, Copy, Default)]
pub struct IterStats {
    pub iteration: usize,
    /// Constraints delivered by the oracle this round.
    pub found: usize,
    /// Remembered list size after the merge, before FORGET.
    pub merged: usize,
    /// Remembered list size after FORGET.
    pub remembered: usize,
    /// Max violation the oracle witnessed at the start of the round.
    pub max_violation: f64,
    /// Individual projections performed this round.
    pub projections: usize,
    /// Wall-clock seconds for the round.
    pub seconds: f64,
    /// Oracle time this round (scan + delivery; for the overlapped
    /// pipeline only the non-overlapped delivery part).
    pub oracle_s: f64,
    /// Projection-sweep time this round.
    pub sweep_s: f64,
    /// FORGET time this round.
    pub forget_s: f64,
    /// Rows whose projection kernel ran across this round's sweeps
    /// (including zero-step visits). With eager sweeps this is
    /// `inner_sweeps × |active set|`; lazy sweeps visit fewer.
    pub rows_projected: usize,
    /// Rows the lazy scheduler elided this round as provably zero-step
    /// (`rows_projected + rows_skipped` = rows an eager round would
    /// have visited). Always 0 in eager mode.
    pub rows_skipped: usize,
}

impl IterStats {
    /// The round's per-phase breakdown as a [`PhaseTimes`].
    pub fn phases(&self) -> PhaseTimes {
        PhaseTimes { oracle_s: self.oracle_s, sweep_s: self.sweep_s, forget_s: self.forget_s }
    }
}

/// Outcome of a solve.
#[derive(Debug, Clone)]
pub struct SolverResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    pub total_projections: usize,
    /// Final number of remembered (≈ active) constraints.
    pub active_constraints: usize,
    pub trace: Vec<IterStats>,
    pub seconds: f64,
    /// Accumulated per-phase timing breakdown (recorded even when
    /// `record_trace` is off).
    pub phases: PhaseTimes,
    /// Sampled convergence-telemetry frames (empty unless
    /// [`SolverConfig::telemetry_every`] > 0).
    pub telemetry: Vec<TelemetryFrame>,
}

/// The stop decision taken at the end of every round. One shared rule
/// for `solve`, `solve_overlapped` and the `Session` drivers — the
/// two-quiet-rounds variant is selected by passing the previous round's
/// dual movement (see [`round_verdict`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundVerdict {
    /// Keep iterating.
    Continue,
    /// Oracle certificate + dual test passed: converged.
    Converged,
    /// The projection budget is exhausted (not converged).
    BudgetExhausted,
}

/// THE stop rule (previously copy-drifted between `solve` and
/// `solve_overlapped`): converged when the oracle's certificate is
/// within `violation_tol` AND the last sweep's dual movement is within
/// `dual_tol` — and, if `prev_dual_movement` is supplied (the overlapped
/// pipeline, whose certificate is one round stale), the *previous*
/// round's dual movement as well, so a stale "feasible" certificate is
/// never declared on an iterate the scan never saw. A non-converged
/// round then stops iff the projection budget is spent.
pub fn round_verdict(
    config: &SolverConfig,
    outcome: &OracleOutcome,
    last_dual_movement: f64,
    prev_dual_movement: Option<f64>,
    total_projections: usize,
) -> RoundVerdict {
    let prev_quiet = match prev_dual_movement {
        Some(prev) => prev <= config.dual_tol,
        None => true,
    };
    let quiet = last_dual_movement <= config.dual_tol && prev_quiet;
    if outcome.max_violation <= config.violation_tol && quiet {
        return RoundVerdict::Converged;
    }
    if let Some(budget) = config.projection_budget {
        if total_projections >= budget {
            return RoundVerdict::BudgetExhausted;
        }
    }
    RoundVerdict::Continue
}

/// What one round of the overlapped pipeline produced (the shared shape
/// between `solve_overlapped` and the stepwise `Session` driver).
#[derive(Debug, Clone, Copy)]
pub(crate) struct OverlappedRound {
    pub outcome: OracleOutcome,
    /// Remembered list size after the merge, before the sweeps.
    pub merged: usize,
    /// Remembered list size after the sweeps' FORGETs.
    pub remembered: usize,
    pub phases: PhaseTimes,
}

/// The PROJECT AND FORGET solver over a Bregman function `F`.
pub struct Solver<F: BregmanFunction> {
    pub f: F,
    pub x: Vec<f64>,
    pub active: ActiveSet,
    pub config: SolverConfig,
    /// Total projections performed (across the lifetime of the solver).
    pub projections: usize,
    /// Total dual movement `Σ|c|` of the most recent sweep.
    pub last_dual_movement: f64,
    /// Rows visited by executor sweeps across the solver's lifetime
    /// (kernel executed, including zero-step visits; the sink's on-find
    /// and box-pass projections are not rows-visited counts and are
    /// excluded). Round deltas feed `IterStats::rows_projected`.
    pub sweep_rows_projected: usize,
    /// Rows elided by the lazy scheduler across the solver's lifetime
    /// (see `SweepStats::rows_skipped`).
    pub sweep_rows_skipped: usize,
    /// Rows dropped by FORGET across the solver's lifetime. Round
    /// deltas feed [`TelemetryFrame::forget_evictions`].
    pub forget_evictions: u64,
    /// The projection engine executing sweeps (chosen by `config.sweep`).
    executor: Box<dyn SweepExecutor<F>>,
    /// Reused FORGET compaction-map buffer.
    slot_map: Vec<u32>,
    /// Per-round coordinate movement (the sweep→oracle feedback log;
    /// see [`MovementTracker`]). Filled by every sweep path and by the
    /// engine sink's on-find/box projections.
    movement: MovementTracker,
    /// Flat coordinate→slot mirror for the fused box pass (rebuilt per
    /// membership generation; see [`BoxSlotCache`]).
    box_cache: BoxSlotCache,
}

/// Flat coordinate→slot mirror of the box rows in the active set, so
/// the per-round box pass resolves duals without per-row content
/// hashing. Keyed to the set's `(instance_id, generation)`: any
/// membership change (merge, FORGET, relabeling, restore into a fresh
/// set) invalidates it, and a rebuild is one linear scan over the rows.
#[derive(Debug, Default)]
struct BoxSlotCache {
    /// `nonneg[e]` / `upper[e]` = slot of the `−x_e ≤ 0` / `x_e ≤ b`
    /// row, or `u32::MAX`.
    nonneg: Vec<u32>,
    upper: Vec<u32>,
    instance: u64,
    generation: u64,
}

impl BoxSlotCache {
    /// Make the mirror current for `active` over `dim` coordinates.
    fn ensure(&mut self, active: &ActiveSet, dim: usize) {
        if self.instance == active.instance_id()
            && self.generation == active.generation()
            && self.nonneg.len() == dim
        {
            return;
        }
        self.nonneg.clear();
        self.nonneg.resize(dim, u32::MAX);
        self.upper.clear();
        self.upper.resize(dim, u32::MAX);
        for r in 0..active.len() {
            let v = active.view(r);
            if v.indices.len() != 1 {
                continue;
            }
            let e = v.indices[0] as usize;
            if e >= dim {
                continue;
            }
            if v.coeffs[0] == -1.0 && v.rhs == 0.0 {
                self.nonneg[e] = r as u32;
            } else if v.coeffs[0] == 1.0 {
                self.upper[e] = r as u32;
            }
        }
        self.instance = active.instance_id();
        self.generation = active.generation();
    }

    /// Adopt the set's current generation after in-pass inserts kept
    /// the mirror up to date incrementally.
    fn sync(&mut self, active: &ActiveSet) {
        self.instance = active.instance_id();
        self.generation = active.generation();
    }
}

/// The sink implementation the solver exposes to oracles.
struct EngineSink<'a, F: BregmanFunction> {
    f: &'a F,
    x: &'a mut Vec<f64>,
    active: &'a mut ActiveSet,
    projections: &'a mut usize,
    z_tol: f64,
    movement: &'a mut MovementTracker,
    box_cache: &'a mut BoxSlotCache,
}

impl<F: BregmanFunction> ProjectionSink for EngineSink<'_, F> {
    fn x(&self) -> &[f64] {
        self.x
    }

    fn remember(&mut self, c: &Constraint) {
        self.active.insert(c);
    }

    fn project_and_remember(&mut self, c: &Constraint) {
        // Fast no-op path: a *satisfied* constraint with no dual history
        // needs neither a projection nor a slot — computing θ first saves
        // the insert/hash/forget churn for the (vast majority of)
        // satisfied rows the oracle re-delivers each round.
        let view = ConstraintView { indices: &c.indices, coeffs: &c.coeffs, rhs: c.rhs };
        let theta = self.f.theta(self.x, view);
        let key = c.key();
        let slot = match self.active.slot_of_key(key) {
            Some(slot) => slot,
            None => {
                if theta >= 0.0 {
                    return; // satisfied, no history: projection is a no-op
                }
                self.active.insert_with_key(c, key)
            }
        };
        let z = self.active.z(slot);
        let step = z.min(theta);
        if step != 0.0 {
            self.f.apply(self.x, self.active.view(slot), step);
            *self.projections += 1;
            self.movement.mark_slice(&c.indices);
        }
        let nz = z - step;
        self.active.set_z(slot, nz);
        // Forget-on-find: if the dual is (numerically) zero the constraint
        // was satisfied and needed no net correction — FORGET will drop it
        // (Algorithm 8, lines 9–12).
        if nz.abs() <= self.z_tol {
            self.active.set_z(slot, 0.0);
        }
    }

    /// The fused box pass: one linear sweep over the coordinate range,
    /// per-row arithmetic identical (same operations, same order) to
    /// `project_and_remember` on the corresponding single-index row —
    /// but duals resolve through the flat [`BoxSlotCache`] mirror
    /// instead of an FNV key + hash probe per row, and a `Constraint`
    /// is materialized only on the rare violated-without-history path
    /// that must insert into the store.
    fn project_box(
        &mut self,
        kind: BoxKind,
        start: u32,
        len: usize,
        bound: f64,
        tol: f64,
    ) -> BoxOutcome {
        self.box_cache.ensure(self.active, self.x.len());
        let mut out = BoxOutcome::default();
        let (coeff, rhs) = match kind {
            BoxKind::NonNeg => (-1.0f64, 0.0),
            BoxKind::Upper => (1.0f64, bound),
        };
        for k in 0..len {
            let e = start as usize + k;
            let xe = self.x[e];
            let v = match kind {
                BoxKind::NonNeg => -xe,
                BoxKind::Upper => xe - bound,
            };
            if v > tol {
                out.found += 1;
                out.max_violation = out.max_violation.max(v);
            }
            let idx = [e as u32];
            let co = [coeff];
            let view = ConstraintView { indices: &idx, coeffs: &co, rhs };
            let theta = self.f.theta(self.x, view);
            let slots = match kind {
                BoxKind::NonNeg => &mut self.box_cache.nonneg,
                BoxKind::Upper => &mut self.box_cache.upper,
            };
            let mut slot = slots[e];
            // A mirrored single-index +1 row with a foreign rhs is some
            // other constraint, not this box face: take the keyed path.
            if slot != u32::MAX && self.active.view(slot as usize).rhs != rhs {
                slot = u32::MAX;
            }
            let slot = if slot != u32::MAX {
                slot as usize
            } else {
                let c = match kind {
                    BoxKind::NonNeg => Constraint::nonneg(e as u32),
                    BoxKind::Upper => Constraint::upper(e as u32, bound),
                };
                let key = c.key();
                match self.active.slot_of_key(key) {
                    Some(s) => s,
                    None => {
                        if theta >= 0.0 {
                            continue; // satisfied, no history: no-op
                        }
                        let s = self.active.insert_with_key(&c, key);
                        slots[e] = s as u32;
                        s
                    }
                }
            };
            let z = self.active.z(slot);
            let step = z.min(theta);
            if step != 0.0 {
                self.f.apply(self.x, self.active.view(slot), step);
                *self.projections += 1;
                self.movement.mark(e as u32);
            }
            let nz = z - step;
            self.active.set_z(slot, nz);
            if nz.abs() <= self.z_tol {
                self.active.set_z(slot, 0.0);
            }
        }
        // In-pass inserts kept the mirror coherent; adopt the new key.
        self.box_cache.sync(self.active);
        out
    }

    fn movement_cursor(&mut self) -> Option<u64> {
        self.movement.take_cursor()
    }

    fn moved_since(&self, cursor: u64, out: &mut Vec<u32>) -> bool {
        self.movement.moved_since(cursor, out)
    }
}

impl<F: BregmanFunction> Solver<F> {
    /// Start at the unconstrained minimiser (`∇f(x⁰) = 0`, line 1).
    pub fn new(f: F, config: SolverConfig) -> Solver<F> {
        let x = f.argmin();
        let executor =
            engine::executor_with::<F>(config.sweep, config.parallel_min_rows, config.lazy_sweep);
        let movement = MovementTracker::new(x.len(), config.track_movement);
        Solver {
            f,
            x,
            active: ActiveSet::new(),
            config,
            projections: 0,
            last_dual_movement: 0.0,
            sweep_rows_projected: 0,
            sweep_rows_skipped: 0,
            forget_evictions: 0,
            executor,
            slot_map: Vec::new(),
            movement,
            box_cache: BoxSlotCache::default(),
        }
    }

    /// The per-round coordinate-movement state (the sweep→oracle
    /// feedback channel; incremental oracles read it through the sink's
    /// movement seam).
    pub fn movement(&self) -> &MovementTracker {
        &self.movement
    }

    /// Drop every outstanding movement window so incremental consumers
    /// fall back to their exact snapshot diff. Called whenever the
    /// iterate is rewritten outside the tracked paths (checkpoint
    /// restore); also the right hammer after any external surgery on
    /// `x` that the engine did not see.
    pub fn invalidate_movement(&mut self) {
        self.movement.invalidate();
    }

    /// Swap the sweep executor (e.g. to compare strategies on one
    /// solver). Also updates `config.sweep` to match.
    pub fn set_sweep_strategy(&mut self, strategy: SweepStrategy) {
        self.config.sweep = strategy;
        self.executor = engine::executor_with::<F>(
            strategy,
            self.config.parallel_min_rows,
            self.config.lazy_sweep,
        );
    }

    /// Name of the active sweep executor (traces/benches).
    pub fn sweep_executor_name(&self) -> &'static str {
        self.executor.name()
    }

    /// One Bregman projection with dual correction onto remembered row `r`
    /// (Algorithm 3, lines 2–6). Returns true if `x` moved.
    #[inline]
    pub fn project_row(&mut self, r: usize) -> bool {
        let moved = engine::project_row_in_place(&self.f, &mut self.x, &mut self.active, r);
        if moved == 0.0 {
            return false;
        }
        self.movement.mark_slice(self.active.view(r).indices);
        self.projections += 1;
        self.last_dual_movement += moved;
        true
    }

    /// The one dispatch point for executor sweeps: movement-tracked when
    /// the tracker is live, with permanent disable (and a correct plain
    /// fallback) for executors without a tracked path.
    fn run_sweep(&mut self, mut record: Option<&mut dyn FnMut(u32, f64)>) -> engine::SweepStats {
        if self.movement.is_enabled() {
            self.movement.advance_epoch();
            let reborrow = match record {
                Some(ref mut r) => Some(&mut **r),
                None => None,
            };
            if let Some(stats) = self.executor.sweep_tracked(
                &self.f,
                &mut self.x,
                &mut self.active,
                &mut self.movement,
                reborrow,
            ) {
                return stats;
            }
            // No tracked path (PJRT adapter): a silently untracked sweep
            // would under-report movement, so stop tracking for good.
            self.movement.disable();
        }
        match record {
            Some(r) => self
                .executor
                .sweep_recorded(&self.f, &mut self.x, &mut self.active, r)
                .expect("the configured sweep executor does not support recorded sweeps"),
            None => self.executor.sweep(&self.f, &mut self.x, &mut self.active),
        }
    }

    /// One full sweep over the remembered list, delegated to the
    /// configured [`SweepExecutor`]. Returns projections done.
    pub fn project_sweep(&mut self) -> usize {
        let stats = self.run_sweep(None);
        self.projections += stats.projections;
        self.last_dual_movement = stats.dual_movement;
        self.sweep_rows_projected += stats.rows_projected;
        self.sweep_rows_skipped += stats.rows_skipped;
        stats.projections
    }

    /// [`Solver::project_sweep`] with exact per-row movement recording
    /// (`record(slot, |step|)` for every row that moved, in the
    /// executor's deterministic bookkeeping order) — the `Session`
    /// batch driver's per-block accounting channel. Panics for
    /// executors without recording support; both built-in strategies
    /// support it.
    pub fn project_sweep_recorded(&mut self, record: &mut dyn FnMut(u32, f64)) -> usize {
        let stats = self.run_sweep(Some(record));
        self.projections += stats.projections;
        self.last_dual_movement = stats.dual_movement;
        self.sweep_rows_projected += stats.rows_projected;
        self.sweep_rows_skipped += stats.rows_skipped;
        stats.projections
    }

    /// FORGET step: drop rows with zero dual. Returns how many. The
    /// stable-slot compaction map is forwarded to the sweep executor so
    /// a cached shard plan survives the compaction without replanning.
    pub fn forget(&mut self) -> usize {
        let z_tol = self.config.z_tol;
        if z_tol > 0.0 {
            for r in 0..self.active.len() {
                if self.active.z(r).abs() <= z_tol {
                    self.active.set_z(r, 0.0);
                }
            }
        }
        let generation_before = self.active.generation();
        let dropped = self.active.forget_inactive_with_map(&mut self.slot_map);
        self.forget_evictions += dropped as u64;
        if dropped > 0 {
            self.executor.after_forget(
                &self.slot_map,
                self.active.instance_id(),
                generation_before,
                self.active.generation(),
            );
        }
        dropped
    }

    /// Run `body` against the engine-side [`ProjectionSink`] (the same
    /// sink `solve` hands to its oracle). This is the seam the `Session`
    /// layer uses to drive oracles itself — e.g. wrapped in a
    /// block-offset adapter for multi-instance solves.
    pub fn with_sink<R>(&mut self, body: impl FnOnce(&mut dyn ProjectionSink) -> R) -> R {
        let mut sink = EngineSink {
            f: &self.f,
            x: &mut self.x,
            active: &mut self.active,
            projections: &mut self.projections,
            z_tol: self.config.z_tol,
            movement: &mut self.movement,
            box_cache: &mut self.box_cache,
        };
        body(&mut sink)
    }

    /// Phase 1 + merge: run one separation round of `oracle` against the
    /// engine sink.
    pub fn separate_with<O: Oracle<F> + ?Sized>(&mut self, oracle: &mut O) -> OracleOutcome {
        self.with_sink(|sink| oracle.separate(sink))
    }

    /// Phases 2+3: `inner_sweeps` × (projection sweep + FORGET) —
    /// Algorithms 6–8 interleave them exactly like this. Returns the
    /// measured sweep/forget times (oracle_s stays zero).
    pub fn sweep_phase(&mut self) -> PhaseTimes {
        let mut t = PhaseTimes::default();
        let mut lap = Stopwatch::new();
        for _ in 0..self.config.inner_sweeps {
            let rows_before = (self.sweep_rows_projected, self.sweep_rows_skipped);
            let mut sweep_span = obs::span(obs::SpanKind::Sweep);
            self.project_sweep();
            if let Some(g) = sweep_span.as_mut() {
                g.counts(
                    (self.sweep_rows_projected - rows_before.0) as u64,
                    (self.sweep_rows_skipped - rows_before.1) as u64,
                );
            }
            drop(sweep_span);
            t.sweep_s += lap.lap_s();
            let mut forget_span = obs::span(obs::SpanKind::Forget);
            let dropped = self.forget();
            if let Some(g) = forget_span.as_mut() {
                g.counts(dropped as u64, 0);
            }
            drop(forget_span);
            t.forget_s += lap.lap_s();
        }
        t
    }

    /// Shared per-round trace entry (stats bookkeeping for every driver).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn round_stats(
        &self,
        iteration: usize,
        outcome: &OracleOutcome,
        merged: usize,
        remembered: usize,
        proj_before: usize,
        rows_before: (usize, usize),
        seconds: f64,
        phases: &PhaseTimes,
    ) -> IterStats {
        IterStats {
            iteration,
            found: outcome.found,
            merged,
            remembered,
            max_violation: outcome.max_violation,
            projections: self.projections - proj_before,
            seconds,
            oracle_s: phases.oracle_s,
            sweep_s: phases.sweep_s,
            forget_s: phases.forget_s,
            rows_projected: self.sweep_rows_projected - rows_before.0,
            rows_skipped: self.sweep_rows_skipped - rows_before.1,
        }
    }

    /// Whether the convergence-telemetry stream samples round `nu`.
    #[inline]
    pub(crate) fn telemetry_due(&self, nu: usize) -> bool {
        let every = self.config.telemetry_every;
        every > 0 && nu % every == 0
    }

    /// Assemble one convergence-telemetry frame from the round's deltas.
    /// `dual_l1` sums |z| over the active set *after* the round's
    /// FORGETs; `moved_fraction` is the round's movement-log marks over
    /// the coordinate count, clamped to 1 (marks dedup per epoch, so a
    /// coordinate can be counted once per sweep). For multi-block
    /// sessions the set-wide quantities are fleet-wide.
    pub(crate) fn telemetry_frame(
        &self,
        round: usize,
        outcome: &OracleOutcome,
        rows_before: (usize, usize),
        marks_before: u64,
        evictions_before: u64,
    ) -> TelemetryFrame {
        let mut dual_l1 = 0.0;
        for r in 0..self.active.len() {
            dual_l1 += self.active.z(r).abs();
        }
        let dim = self.x.len().max(1) as f64;
        let moved = (self.movement.marks().saturating_sub(marks_before)) as f64 / dim;
        TelemetryFrame {
            round,
            max_violation: outcome.max_violation,
            active_rows: self.active.len(),
            dual_l1,
            moved_fraction: moved.min(1.0),
            rows_projected: self.sweep_rows_projected - rows_before.0,
            rows_skipped: self.sweep_rows_skipped - rows_before.1,
            forget_evictions: (self.forget_evictions - evictions_before) as usize,
        }
    }

    /// Shared result assembly.
    pub(crate) fn finish_result(
        &self,
        iterations: usize,
        converged: bool,
        trace: Vec<IterStats>,
        phases: PhaseTimes,
        seconds: f64,
        telemetry: Vec<TelemetryFrame>,
    ) -> SolverResult {
        SolverResult {
            x: self.x.clone(),
            iterations,
            converged,
            total_projections: self.projections,
            active_constraints: self.active.len(),
            trace,
            seconds,
            phases,
            telemetry,
        }
    }

    /// Run the full PROJECT AND FORGET loop against `oracle`.
    pub fn solve<O: Oracle<F>>(&mut self, mut oracle: O) -> SolverResult {
        let clock = Stopwatch::new();
        let mut trace = Vec::new();
        let mut telemetry = Vec::new();
        let mut phases = PhaseTimes::default();
        let mut converged = false;
        let mut iterations = 0;
        for nu in 0..self.config.max_iters {
            iterations = nu + 1;
            let mut round = Stopwatch::new();
            let proj_before = self.projections;
            let rows_before = (self.sweep_rows_projected, self.sweep_rows_skipped);
            let marks_before = self.movement.marks();
            let evictions_before = self.forget_evictions;
            let mut round_span = obs::span(obs::SpanKind::Round);

            // Phase 1+merge: oracle delivers violated constraints (and may
            // project-on-find).
            let mut lap = Stopwatch::new();
            let outcome = self.separate_with(&mut oracle);
            let oracle_s = lap.lap_s();
            let merged = self.active.len();

            // Phases 2+3: projection sweeps, each followed by FORGET.
            let round_phases = PhaseTimes { oracle_s, ..self.sweep_phase() };
            let remembered = self.active.len();
            phases.accumulate(&round_phases);
            if let Some(g) = round_span.as_mut() {
                g.counts(outcome.found as u64, remembered as u64);
            }
            drop(round_span);

            if self.config.record_trace {
                trace.push(self.round_stats(
                    nu,
                    &outcome,
                    merged,
                    remembered,
                    proj_before,
                    rows_before,
                    round.lap_s(),
                    &round_phases,
                ));
            }
            if self.telemetry_due(nu) {
                telemetry.push(self.telemetry_frame(
                    nu,
                    &outcome,
                    rows_before,
                    marks_before,
                    evictions_before,
                ));
            }

            match round_verdict(
                &self.config,
                &outcome,
                self.last_dual_movement,
                None,
                self.projections,
            ) {
                RoundVerdict::Converged => {
                    converged = true;
                    break;
                }
                RoundVerdict::BudgetExhausted => break,
                RoundVerdict::Continue => {}
            }
        }
        self.finish_result(iterations, converged, trace, phases, clock.elapsed_s(), telemetry)
    }

    /// Run PROJECT AND FORGET with the oracle's scan phase overlapped
    /// with the projection sweeps (the async pipeline from the ROADMAP).
    ///
    /// Buffer ownership and the barrier:
    /// - the solver owns and mutates `self.x` (the front buffer);
    /// - `shadow` (the back buffer, owned by this loop) is a snapshot of
    ///   `x` taken right after the merge, before the round's sweeps;
    /// - the oracle's [`OverlappableOracle::scan`] runs on the worker
    ///   pool against `shadow` while this thread drains the sweeps on
    ///   `x`; the end of the pool scope is the **sweep barrier**, where
    ///   the scan's findings are handed back and merged at the top of
    ///   the next round.
    ///
    /// Consequently round ν delivers constraints scanned against round
    /// ν−1's post-merge, pre-sweep iterate: the certificate is one round
    /// staler than in [`Solver::solve`] (which already certifies the
    /// pre-sweep iterate of the same round). To keep the certificate
    /// meaningful despite that extra round of drift, convergence
    /// requires the dual-movement test to hold in **two consecutive
    /// rounds** — the round that produced the certified snapshot and
    /// the round that checks it — which bounds `‖x_final − x_certified‖`
    /// by the same `dual_tol`-scale quantity as the plain loop (and
    /// degenerates to the plain violation-only rule when
    /// `dual_tol = ∞`, as in the paper's large-scale runs). The
    /// pipeline structure is fixed — scan results depend only on the
    /// snapshot, merges happen only at the barrier — so the solve is
    /// bit-deterministic and independent of the thread count.
    pub fn solve_overlapped<O>(&mut self, mut oracle: O) -> SolverResult
    where
        O: OverlappableOracle<F> + Sync,
    {
        let clock = Stopwatch::new();
        let mut trace = Vec::new();
        let mut telemetry = Vec::new();
        let mut phases = PhaseTimes::default();
        let mut converged = false;
        let mut iterations = 0;
        // The oracle-side back buffer of the double-buffered iterate.
        let mut shadow = self.x.clone();
        // Dual movement of the *previous* round's last sweep — the round
        // whose pre-sweep iterate the current certificate refers to.
        let mut prev_dual_movement = f64::INFINITY;
        // Round 0 has nothing to overlap with: scan synchronously.
        let mut pending = Some(oracle.scan(&self.x));
        for nu in 0..self.config.max_iters {
            iterations = nu + 1;
            let mut round_clock = Stopwatch::new();
            let proj_before = self.projections;
            let rows_before = (self.sweep_rows_projected, self.sweep_rows_skipped);
            let marks_before = self.movement.marks();
            let evictions_before = self.forget_evictions;
            let mut round_span = obs::span(obs::SpanKind::Round);

            let scan = pending.take().expect("overlap pipeline lost a scan");
            let (round, next_scan) =
                self.overlapped_round(&mut oracle, scan, &mut shadow, prev_dual_movement);
            phases.accumulate(&round.phases);
            if let Some(g) = round_span.as_mut() {
                g.counts(round.outcome.found as u64, round.remembered as u64);
            }
            drop(round_span);

            if self.config.record_trace {
                trace.push(self.round_stats(
                    nu,
                    &round.outcome,
                    round.merged,
                    round.remembered,
                    proj_before,
                    rows_before,
                    round_clock.lap_s(),
                    &round.phases,
                ));
            }
            if self.telemetry_due(nu) {
                telemetry.push(self.telemetry_frame(
                    nu,
                    &round.outcome,
                    rows_before,
                    marks_before,
                    evictions_before,
                ));
            }

            // Two consecutive quiet rounds: `prev_dual_movement` bounds
            // the drift between the certified snapshot and this round's
            // start, `last_dual_movement` bounds this round's sweeps —
            // without the former, a stale "feasible" certificate could
            // be declared on an iterate the scan never saw.
            match round_verdict(
                &self.config,
                &round.outcome,
                self.last_dual_movement,
                Some(prev_dual_movement),
                self.projections,
            ) {
                RoundVerdict::Converged => {
                    converged = true;
                    break;
                }
                RoundVerdict::BudgetExhausted => break,
                RoundVerdict::Continue => {}
            }
            prev_dual_movement = self.last_dual_movement;
            // Refill the pipeline; the synchronous fallback only fires
            // when the speculative scan was skipped but the round turned
            // out not to be final.
            pending = Some(match next_scan {
                Some(scan) => scan,
                None => {
                    let mut lap = Stopwatch::new();
                    let scan = oracle.scan(&shadow);
                    phases.oracle_s += lap.lap_s();
                    scan
                }
            });
        }
        self.finish_result(iterations, converged, trace, phases, clock.elapsed_s(), telemetry)
    }

    /// One round of the overlapped pipeline, shared verbatim by
    /// [`Solver::solve_overlapped`] and the stepwise `Session` driver:
    /// deliver the pending scan, snapshot `x` into `shadow`, then run the
    /// sweeps while the next scan runs on the pool (unless this round is
    /// likely final — see the comment inside). Returns the round's
    /// numbers plus the speculative next scan, if one was taken.
    pub(crate) fn overlapped_round<O>(
        &mut self,
        oracle: &mut O,
        scan: O::Scan,
        shadow: &mut [f64],
        prev_dual_movement: f64,
    ) -> (OverlappedRound, Option<O::Scan>)
    where
        O: OverlappableOracle<F> + Sync,
    {
        // Merge the findings scanned during the previous round's sweeps
        // (or synchronously, for round 0).
        let mut lap = Stopwatch::new();
        let outcome = self.with_sink(|sink| oracle.deliver(scan, sink));
        let oracle_s = lap.lap_s();
        let merged = self.active.len();

        // Snapshot for the oracle, then overlap: the next round's scan
        // runs on the pool while this thread drains the sweeps.
        // Exception: two of the three stop-rule inputs (the stale
        // certificate and the previous round's dual movement) are
        // already known here — when both pass, this round is very likely
        // final, so skip the speculative scan instead of paying a full
        // discarded Dijkstra pass. If the post-sweep dual test then
        // fails after all, the pipeline is refilled by the caller with a
        // synchronous scan of the *same* snapshot — identical input,
        // identical findings, so the trajectory (and bit-determinism) is
        // unchanged either way.
        shadow.copy_from_slice(&self.x);
        let likely_final = outcome.max_violation <= self.config.violation_tol
            && prev_dual_movement <= self.config.dual_tol;
        let mut next_scan: Option<O::Scan> = None;
        let mut phases = if likely_final {
            self.sweep_phase()
        } else {
            let oracle_ref = &*oracle;
            let shadow_ref: &[f64] = shadow;
            let slot = &mut next_scan;
            let mut sweep_times = PhaseTimes::default();
            pool::global().scope(|s| {
                s.spawn(move || {
                    *slot = Some(oracle_ref.scan(shadow_ref));
                });
                sweep_times = self.sweep_phase();
            });
            sweep_times
        };
        phases.oracle_s = oracle_s;
        let remembered = self.active.len();
        (OverlappedRound { outcome, merged, remembered, phases }, next_scan)
    }

    /// KKT residual `‖∇f(x) + Aᵀz‖_∞` over the remembered set — exactly
    /// zero in exact arithmetic for the quadratic (Step 1 of the proof);
    /// exposed for tests and debugging. Only valid while no constraint
    /// with nonzero dual has been forgotten, and for `DiagonalQuadratic`-
    /// style functions where ∇f is cheap — hence the explicit gradient
    /// argument.
    pub fn kkt_residual(&self, grad: &[f64]) -> f64 {
        let mut atz = vec![0.0; self.x.len()];
        for r in 0..self.active.len() {
            let v = self.active.view(r);
            let z = self.active.z(r);
            for (&i, &a) in v.indices.iter().zip(v.coeffs) {
                atz[i as usize] += a * z;
            }
        }
        grad.iter()
            .zip(&atz)
            .map(|(&g, &az)| (g + az).abs())
            .fold(0.0, f64::max)
    }
}

/// Dynamic-fleet surgery on the concatenated diagonal-quadratic vector
/// (the `Session` admission/eviction paths). These are deliberately
/// specialised to [`DiagonalQuadratic`]: block concatenation is only
/// defined for the diagonal geometry, where appending/removing a
/// coordinate range leaves every other coordinate's arithmetic
/// untouched bit for bit.
impl Solver<DiagonalQuadratic> {
    /// Append a new variable block (anchors `d`, weights `w`) at the end
    /// of the concatenated vector. The new coordinates start at the
    /// block's unconstrained minimiser (`∇f = 0` there, exactly as a
    /// fresh solo solve would), existing coordinates, duals and the
    /// remembered rows are untouched — and since the active set's
    /// membership did not change, a cached shard plan stays warm.
    /// Returns the appended coordinate range.
    pub fn append_variables(&mut self, d: &[f64], w: &[f64]) -> std::ops::Range<usize> {
        let start = self.x.len();
        let mut nd = std::mem::take(&mut self.f.d);
        let mut nw = std::mem::take(&mut self.f.w);
        nd.extend_from_slice(d);
        nw.extend_from_slice(w);
        self.f = DiagonalQuadratic::new(nd, nw);
        self.x.extend_from_slice(d); // block-local argmin
        // Growth keeps existing coordinate labels, so outstanding
        // movement windows stay valid; the dirty set just widens.
        self.movement.resize(self.x.len());
        start..self.x.len()
    }

    /// Remove a variable coordinate range from the concatenated vector
    /// (a block was evicted or compacted away): the iterate and geometry
    /// drop the range, and every remembered row's indices `>= range.end`
    /// slide down by `range.len()`. The caller must already have removed
    /// every row supported inside `range` (debug-asserted downstream).
    /// The executor is notified through
    /// [`SweepExecutor::after_reoffset`], so a current shard plan adopts
    /// the relabeling instead of replanning.
    pub fn remove_variable_range(&mut self, range: std::ops::Range<usize>) {
        if range.is_empty() {
            return;
        }
        let mut nd = std::mem::take(&mut self.f.d);
        let mut nw = std::mem::take(&mut self.f.w);
        nd.drain(range.clone());
        nw.drain(range.clone());
        self.f = DiagonalQuadratic::new(nd, nw);
        self.x.drain(range.clone());
        // The uniform relabeling orphans every logged coordinate: shrink
        // the dirty set and invalidate outstanding movement windows
        // (consumers fall back to their exact snapshot diff once).
        self.movement.remove_range(range.clone());
        let (before, after) =
            self.active.shift_indices_from(range.end as u32, range.len() as u32);
        if before != after {
            self.executor.after_reoffset(self.active.instance_id(), before, after);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bregman::DiagonalQuadratic;
    use crate::core::oracle::ListOracle;

    /// Tiny QP: min ½‖x − d‖² s.t. a few half-spaces; compare against the
    /// known analytic projection.
    #[test]
    fn projects_onto_single_halfspace() {
        let f = DiagonalQuadratic::unweighted(vec![2.0, 2.0]);
        let oracle = ListOracle::new(vec![Constraint::new(vec![0, 1], vec![1.0, 1.0], 2.0)]);
        let mut s = Solver::new(f, SolverConfig { violation_tol: 1e-10, ..Default::default() });
        let res = s.solve(oracle);
        assert!(res.converged);
        // Projection of (2,2) onto x+y<=2 is (1,1).
        assert!((res.x[0] - 1.0).abs() < 1e-8, "{:?}", res.x);
        assert!((res.x[1] - 1.0).abs() < 1e-8);
        assert_eq!(res.active_constraints, 1);
    }

    #[test]
    fn inactive_constraints_are_forgotten() {
        let f = DiagonalQuadratic::unweighted(vec![0.0, 0.0]);
        // Both constraints satisfied at the optimum x = d = 0; the second
        // is violated at no point along the trajectory.
        let oracle = ListOracle::new(vec![
            Constraint::new(vec![0], vec![1.0], 5.0),
            Constraint::new(vec![1], vec![1.0], 5.0),
        ]);
        let mut s = Solver::new(f, SolverConfig::default());
        let res = s.solve(oracle);
        assert!(res.converged);
        assert_eq!(res.iterations, 1);
        assert_eq!(res.active_constraints, 0, "no active constraints at optimum");
    }

    #[test]
    fn intersection_of_two_halfspaces() {
        // min ½‖x−(3,0)‖² s.t. x0<=1, x0−x1<=0  -> optimum (1,1)? Check:
        // optimum is argmin over the polytope; (1,1): distance² = 4+1=5.
        // Alternative (1,0) violates x0-x1<=0? 1-0=1>0 violated. So the
        // active set is both constraints; solution on their intersection
        // x0=1, x1=1? Gradient (x−d) must be -A^T z with z>=0:
        // x=(1,1): grad=(-2,1); a1=(1,0), a2=(1,-1); -z1*a1 - z2*a2 =
        // (-z1-z2, z2) = (-2, 1) -> z2=1, z1=1 >= 0. Optimal.
        let f = DiagonalQuadratic::unweighted(vec![3.0, 0.0]);
        let oracle = ListOracle::new(vec![
            Constraint::new(vec![0], vec![1.0], 1.0),
            Constraint::new(vec![0, 1], vec![1.0, -1.0], 0.0),
        ]);
        let mut s = Solver::new(
            f,
            SolverConfig { violation_tol: 1e-12, max_iters: 5000, ..Default::default() },
        );
        let res = s.solve(oracle);
        assert!(res.converged);
        assert!((res.x[0] - 1.0).abs() < 1e-6, "{:?}", res.x);
        assert!((res.x[1] - 1.0).abs() < 1e-6);
        assert_eq!(res.active_constraints, 2);
    }

    #[test]
    fn kkt_identity_maintained() {
        let d = vec![3.0, 0.0, -1.0];
        let f = DiagonalQuadratic::unweighted(d.clone());
        let oracle = ListOracle::new(vec![
            Constraint::new(vec![0], vec![1.0], 1.0),
            Constraint::new(vec![0, 1], vec![1.0, -1.0], 0.0),
            Constraint::new(vec![2], vec![-1.0], 0.0),
        ]);
        let mut s = Solver::new(f, SolverConfig { max_iters: 50, ..Default::default() });
        let res = s.solve(oracle);
        // ∇f(x) = x − d for the unweighted quadratic.
        let grad: Vec<f64> = s.x.iter().zip(&d).map(|(&x, &di)| x - di).collect();
        assert!(s.kkt_residual(&grad) < 1e-9, "KKT violated: {}", s.kkt_residual(&grad));
        assert!(res.total_projections > 0);
    }

    #[test]
    fn duals_stay_nonnegative() {
        let f = DiagonalQuadratic::unweighted(vec![5.0, -5.0, 2.0, 0.0]);
        let oracle = ListOracle::new(vec![
            Constraint::new(vec![0, 1], vec![1.0, 1.0], 0.5),
            Constraint::new(vec![1, 2], vec![-1.0, 1.0], 0.25),
            Constraint::new(vec![0, 3], vec![1.0, -2.0], 1.0),
        ]);
        let mut s = Solver::new(f, SolverConfig { max_iters: 200, ..Default::default() });
        let _ = s.solve(oracle);
        for r in 0..s.active.len() {
            assert!(s.active.z(r) >= -1e-12, "negative dual at {r}");
        }
    }

    #[test]
    fn kkt_identity_maintained_sharded() {
        let d = vec![3.0, 0.0, -1.0];
        let f = DiagonalQuadratic::unweighted(d.clone());
        let oracle = ListOracle::new(vec![
            Constraint::new(vec![0], vec![1.0], 1.0),
            Constraint::new(vec![0, 1], vec![1.0, -1.0], 0.0),
            Constraint::new(vec![2], vec![-1.0], 0.0),
        ]);
        let cfg = SolverConfig {
            max_iters: 50,
            sweep: SweepStrategy::ShardedParallel { threads: 4 },
            ..Default::default()
        };
        let mut s = Solver::new(f, cfg);
        let res = s.solve(oracle);
        let grad: Vec<f64> = s.x.iter().zip(&d).map(|(&x, &di)| x - di).collect();
        assert!(s.kkt_residual(&grad) < 1e-9, "KKT violated: {}", s.kkt_residual(&grad));
        assert!(res.total_projections > 0);
        assert_eq!(s.sweep_executor_name(), "sharded-parallel");
    }

    #[test]
    fn duals_stay_nonnegative_sharded() {
        let f = DiagonalQuadratic::unweighted(vec![5.0, -5.0, 2.0, 0.0]);
        let oracle = ListOracle::new(vec![
            Constraint::new(vec![0, 1], vec![1.0, 1.0], 0.5),
            Constraint::new(vec![1, 2], vec![-1.0, 1.0], 0.25),
            Constraint::new(vec![0, 3], vec![1.0, -2.0], 1.0),
        ]);
        let cfg = SolverConfig {
            max_iters: 200,
            sweep: SweepStrategy::ShardedParallel { threads: 3 },
            ..Default::default()
        };
        let mut s = Solver::new(f, cfg);
        let _ = s.solve(oracle);
        for r in 0..s.active.len() {
            assert!(s.active.z(r) >= -1e-12, "negative dual at {r}");
        }
    }

    #[test]
    fn sharded_matches_sequential_objective() {
        // Overlapping constraint soup around a known interior point, so
        // both strategies must converge to the same (unique) projection.
        use crate::util::Rng;
        let mut rng = Rng::new(77);
        let dim = 12;
        let interior: Vec<f64> = (0..dim).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut cons = Vec::new();
        for _ in 0..40 {
            let nnz = 1 + rng.below(4);
            let idx: Vec<u32> =
                rng.sample_indices(dim, nnz).into_iter().map(|i| i as u32).collect();
            let coeffs: Vec<f64> = (0..nnz).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let at: f64 =
                idx.iter().zip(&coeffs).map(|(&i, &a)| a * interior[i as usize]).sum();
            cons.push(Constraint::new(idx, coeffs, at + rng.uniform(0.05, 0.6)));
        }
        let d: Vec<f64> = (0..dim).map(|_| rng.uniform(-4.0, 4.0)).collect();
        let mut solve = |sweep: SweepStrategy| {
            let cfg = SolverConfig {
                max_iters: 20000,
                violation_tol: 1e-10,
                dual_tol: 1e-10,
                record_trace: false,
                sweep,
                ..Default::default()
            };
            let mut s = Solver::new(DiagonalQuadratic::unweighted(d.clone()), cfg);
            let res = s.solve(ListOracle::new(cons.clone()));
            assert!(res.converged, "{:?} did not converge", sweep);
            (s.f.value(&res.x), res.x)
        };
        let (obj_seq, x_seq) = solve(SweepStrategy::Sequential);
        let (obj_par, x_par) = solve(SweepStrategy::ShardedParallel { threads: 4 });
        assert!(
            (obj_seq - obj_par).abs() <= 1e-6 * (1.0 + obj_seq.abs()),
            "objectives diverge: {obj_seq} vs {obj_par}"
        );
        for (a, b) in x_seq.iter().zip(&x_par) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    /// Minimal [`OverlappableOracle`]: scan records violated list rows,
    /// deliver remembers them (the ListOracle semantics, split in two).
    struct OverlapHalfspaces {
        constraints: Vec<Constraint>,
    }

    impl Oracle<DiagonalQuadratic> for OverlapHalfspaces {
        fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
            let scan = OverlappableOracle::<DiagonalQuadratic>::scan(self, sink.x());
            OverlappableOracle::<DiagonalQuadratic>::deliver(self, scan, sink)
        }
    }

    impl OverlappableOracle<DiagonalQuadratic> for OverlapHalfspaces {
        type Scan = Vec<(f64, usize)>;

        fn scan(&self, x: &[f64]) -> Self::Scan {
            self.constraints
                .iter()
                .enumerate()
                .filter_map(|(i, c)| {
                    let v = c.violation(x);
                    if v > 0.0 {
                        Some((v, i))
                    } else {
                        None
                    }
                })
                .collect()
        }

        fn deliver(
            &mut self,
            scan: Self::Scan,
            sink: &mut dyn ProjectionSink,
        ) -> OracleOutcome {
            let mut out = OracleOutcome::default();
            for (v, i) in scan {
                out.found += 1;
                out.max_violation = out.max_violation.max(v);
                sink.remember(&self.constraints[i]);
            }
            out
        }
    }

    #[test]
    fn overlapped_solve_matches_plain_solve() {
        // The overlapped pipeline scans a one-round-stale snapshot, so
        // the trajectory differs — but the program is strictly convex, so
        // both must land on the unique projection.
        let cons = vec![
            Constraint::new(vec![0, 1], vec![1.0, 1.0], 2.0),
            Constraint::new(vec![0], vec![1.0], 1.5),
        ];
        let cfg = SolverConfig {
            violation_tol: 1e-10,
            dual_tol: 1e-10,
            max_iters: 5000,
            ..Default::default()
        };
        let mut plain = Solver::new(DiagonalQuadratic::unweighted(vec![2.0, 2.0]), cfg.clone());
        let rp = plain.solve(ListOracle::new(cons.clone()));
        let mut over = Solver::new(DiagonalQuadratic::unweighted(vec![2.0, 2.0]), cfg);
        let ro = over.solve_overlapped(OverlapHalfspaces { constraints: cons });
        assert!(rp.converged, "plain solve diverged");
        assert!(ro.converged, "overlapped solve diverged");
        for (a, b) in rp.x.iter().zip(&ro.x) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
        // Projection of (2,2) onto {x+y<=2, x0<=1.5} is (1,1).
        assert!((ro.x[0] - 1.0).abs() < 1e-8 && (ro.x[1] - 1.0).abs() < 1e-8);
    }

    #[test]
    fn overlapped_solve_with_sharded_sweep_keeps_kkt() {
        let d = vec![3.0, 0.0, -1.0, 2.0];
        let cons = vec![
            Constraint::new(vec![0], vec![1.0], 1.0),
            Constraint::new(vec![1, 2], vec![1.0, -1.0], 0.0),
            Constraint::new(vec![3], vec![-1.0], 0.0),
        ];
        let cfg = SolverConfig {
            max_iters: 200,
            sweep: SweepStrategy::ShardedParallel { threads: 4 },
            parallel_min_rows: Some(2),
            ..Default::default()
        };
        let mut s = Solver::new(DiagonalQuadratic::unweighted(d.clone()), cfg);
        let res = s.solve_overlapped(OverlapHalfspaces { constraints: cons });
        assert!(res.total_projections > 0);
        let grad: Vec<f64> = s.x.iter().zip(&d).map(|(&x, &di)| x - di).collect();
        assert!(s.kkt_residual(&grad) < 1e-9, "KKT violated: {}", s.kkt_residual(&grad));
    }

    #[test]
    fn projection_budget_respected() {
        let f = DiagonalQuadratic::unweighted(vec![10.0; 4]);
        let oracle = ListOracle::new(vec![
            Constraint::new(vec![0, 1, 2, 3], vec![1.0; 4], 1.0),
            Constraint::new(vec![0], vec![1.0], 0.1),
        ]);
        let cfg = SolverConfig {
            projection_budget: Some(3),
            violation_tol: 0.0,
            max_iters: 1000,
            ..Default::default()
        };
        let mut s = Solver::new(f, cfg);
        let res = s.solve(oracle);
        assert!(!res.converged);
        assert!(res.total_projections >= 3 && res.total_projections <= 12);
    }

    #[test]
    fn trace_records_forget_dynamics() {
        let f = DiagonalQuadratic::unweighted(vec![4.0, 4.0, 4.0]);
        let oracle = ListOracle::new(vec![
            Constraint::new(vec![0], vec![1.0], 1.0),
            Constraint::new(vec![1], vec![1.0], 1.0),
            Constraint::new(vec![2], vec![1.0], 100.0), // never active
        ]);
        let mut s = Solver::new(f, SolverConfig::default());
        let res = s.solve(oracle);
        assert!(!res.trace.is_empty());
        let last = res.trace.last().unwrap();
        assert!(last.remembered <= last.merged);
        // The never-violated constraint must not be remembered.
        assert!(res.active_constraints <= 2);
    }
}
