//! Sparse half-space constraints `⟨a, x⟩ ≤ b` and a flat, cache-friendly
//! constraint store.
//!
//! Metric constrained problems generate millions of transient cycle
//! constraints, so the store keeps all rows in three flat arrays
//! (`indices` / `coeffs` / per-row offsets) rather than a `Vec<Vec<…>>`.
//! The FORGET step is a *batch* removal (drop every row whose dual is
//! zero), implemented as a single linear `retain` compaction pass.
//! Content-hash identity lets the merge `L^(ν) ∪ L` deduplicate.

/// An owned sparse constraint row: `Σ coeffs[k]·x[indices[k]] ≤ rhs`.
#[derive(Debug, Clone, PartialEq)]
pub struct Constraint {
    pub indices: Vec<u32>,
    pub coeffs: Vec<f64>,
    pub rhs: f64,
}

/// A borrowed view into a stored row (what the Bregman projections see).
#[derive(Debug, Clone, Copy)]
pub struct ConstraintView<'a> {
    pub indices: &'a [u32],
    pub coeffs: &'a [f64],
    pub rhs: f64,
}

/// Content-derived identity of a constraint (FNV-1a over the canonical
/// sorted row). Used to deduplicate the active-set merge.
pub type ConstraintKey = u64;

/// Sentinel in a [`ConstraintStore::retain_with_map`] slot map marking a
/// row that was dropped by the compaction.
pub const SLOT_DROPPED: u32 = u32::MAX;

impl Constraint {
    pub fn new(indices: Vec<u32>, coeffs: Vec<f64>, rhs: f64) -> Constraint {
        assert_eq!(indices.len(), coeffs.len());
        Constraint { indices, coeffs, rhs }
    }

    /// The metric cycle constraint `x_e − Σ_{ẽ∈path} x_ẽ ≤ 0`.
    pub fn cycle(edge: u32, path: &[u32]) -> Constraint {
        let mut indices = Vec::with_capacity(path.len() + 1);
        let mut coeffs = Vec::with_capacity(path.len() + 1);
        indices.push(edge);
        coeffs.push(1.0);
        for &p in path {
            indices.push(p);
            coeffs.push(-1.0);
        }
        Constraint { indices, coeffs, rhs: 0.0 }
    }

    /// Non-negativity `−x_e ≤ 0`.
    pub fn nonneg(edge: u32) -> Constraint {
        Constraint { indices: vec![edge], coeffs: vec![-1.0], rhs: 0.0 }
    }

    /// Upper bound `x_e ≤ ub` (the `[0,1]` box of correlation clustering).
    pub fn upper(edge: u32, ub: f64) -> Constraint {
        Constraint { indices: vec![edge], coeffs: vec![1.0], rhs: ub }
    }

    /// Violation amount `max(0, ⟨a,x⟩ − b)` at `x`.
    pub fn violation(&self, x: &[f64]) -> f64 {
        let dot: f64 = self
            .indices
            .iter()
            .zip(&self.coeffs)
            .map(|(&i, &a)| a * x[i as usize])
            .sum();
        (dot - self.rhs).max(0.0)
    }

    /// Content hash over the canonically sorted row (see
    /// [`ConstraintView::key`]).
    pub fn key(&self) -> ConstraintKey {
        ConstraintView { indices: &self.indices, coeffs: &self.coeffs, rhs: self.rhs }.key()
    }
}

impl ConstraintView<'_> {
    /// Content hash over the canonically sorted row. Rows up to 64
    /// nonzeros sort in a stack buffer (the hot path: cycle constraints);
    /// longer rows fall back to a heap allocation.
    pub fn key(&self) -> ConstraintKey {
        let n = self.indices.len();
        let mut stack = [(0u32, 0.0f64); 64];
        let mut heap: Vec<(u32, f64)>;
        let pairs: &mut [(u32, f64)] = if n <= 64 {
            for (k, (&i, &a)) in self.indices.iter().zip(self.coeffs).enumerate() {
                stack[k] = (i, a);
            }
            &mut stack[..n]
        } else {
            heap = self.indices.iter().cloned().zip(self.coeffs.iter().cloned()).collect();
            &mut heap
        };
        pairs.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        let mut h: u64 = 0xcbf29ce484222325;
        let mut feed = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        for (i, a) in pairs.iter() {
            feed(&i.to_le_bytes());
            feed(&a.to_bits().to_le_bytes());
        }
        feed(&self.rhs.to_bits().to_le_bytes());
        h
    }
}

/// Flat storage for a set of constraints with parallel dual variables.
///
/// Rows are addressed by dense slot ids `0..len`. Removal happens only
/// through [`ConstraintStore::retain`], which compacts the pools in one
/// linear pass; slot ids are NOT stable across `retain` — stable identity
/// is the content key.
#[derive(Debug, Default, Clone)]
pub struct ConstraintStore {
    indices: Vec<u32>,
    coeffs: Vec<f64>,
    /// Row r occupies indices[offsets[r]..offsets[r+1]].
    offsets: Vec<u32>,
    rhs: Vec<f64>,
    /// Dual variable z_r ≥ 0 per row.
    pub z: Vec<f64>,
    keys: Vec<ConstraintKey>,
}

impl ConstraintStore {
    pub fn new() -> Self {
        ConstraintStore { offsets: vec![0], ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.rhs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rhs.is_empty()
    }

    /// Total stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Append a row with initial dual `z`; returns its current slot.
    pub fn push(&mut self, c: &Constraint, z: f64) -> usize {
        self.push_with_key(c, z, c.key())
    }

    /// Append when the key is already computed (avoids re-hashing).
    pub fn push_with_key(&mut self, c: &Constraint, z: f64, key: ConstraintKey) -> usize {
        self.indices.extend_from_slice(&c.indices);
        self.coeffs.extend_from_slice(&c.coeffs);
        self.offsets.push(self.indices.len() as u32);
        self.rhs.push(c.rhs);
        self.z.push(z);
        self.keys.push(key);
        self.rhs.len() - 1
    }

    /// Borrow row `r`.
    #[inline]
    pub fn view(&self, r: usize) -> ConstraintView<'_> {
        let (s, e) = (self.offsets[r] as usize, self.offsets[r + 1] as usize);
        ConstraintView { indices: &self.indices[s..e], coeffs: &self.coeffs[s..e], rhs: self.rhs[r] }
    }

    pub fn key_of(&self, r: usize) -> ConstraintKey {
        self.keys[r]
    }

    /// Keep only rows where `keep(slot, z)` is true, compacting all pools
    /// in one linear pass. Returns the number of rows dropped.
    pub fn retain<F: FnMut(usize, f64) -> bool>(&mut self, keep: F) -> usize {
        self.retain_impl(keep, None)
    }

    /// [`ConstraintStore::retain`] that additionally records the
    /// stable-slot compaction map: after the call, `map[old_slot]` holds
    /// the row's new slot, or [`SLOT_DROPPED`] if it was removed. Lets
    /// callers holding slot references (shard plans, external dual
    /// mirrors) survive a FORGET in O(rows) instead of re-resolving
    /// through content keys.
    pub fn retain_with_map<F: FnMut(usize, f64) -> bool>(
        &mut self,
        keep: F,
        map: &mut Vec<u32>,
    ) -> usize {
        map.clear();
        map.reserve(self.len());
        self.retain_impl(keep, Some(map))
    }

    fn retain_impl<F: FnMut(usize, f64) -> bool>(
        &mut self,
        mut keep: F,
        mut map: Option<&mut Vec<u32>>,
    ) -> usize {
        let n = self.len();
        let mut write_row = 0usize;
        let mut write_nz = 0usize;
        let mut dropped = 0usize;
        for r in 0..n {
            let (s, e) = (self.offsets[r] as usize, self.offsets[r + 1] as usize);
            if keep(r, self.z[r]) {
                if write_row != r {
                    self.indices.copy_within(s..e, write_nz);
                    self.coeffs.copy_within(s..e, write_nz);
                    self.rhs[write_row] = self.rhs[r];
                    self.z[write_row] = self.z[r];
                    self.keys[write_row] = self.keys[r];
                }
                if let Some(m) = map.as_deref_mut() {
                    m.push(write_row as u32);
                }
                write_nz += e - s;
                write_row += 1;
                self.offsets[write_row] = write_nz as u32;
            } else {
                dropped += 1;
                if let Some(m) = map.as_deref_mut() {
                    m.push(SLOT_DROPPED);
                }
            }
        }
        self.indices.truncate(write_nz);
        self.coeffs.truncate(write_nz);
        self.offsets.truncate(write_row + 1);
        self.rhs.truncate(write_row);
        self.z.truncate(write_row);
        self.keys.truncate(write_row);
        dropped
    }

    /// Re-offset all stored variable indices: every index `>= start` is
    /// decreased by `delta` (the block-removal compaction of the
    /// `Session` fleet — a variable range `[start − delta, start)` was
    /// dropped, so the tail of the coordinate space slides down).
    /// Content keys are recomputed for every row whose indices moved.
    /// Returns true if any index changed.
    ///
    /// The caller must guarantee that no stored index lies inside
    /// `[start − delta, start)` (debug-asserted) — the map must stay
    /// injective or content identity (and the disjointness invariants
    /// downstream shard plans rely on) would silently break.
    pub fn shift_indices_from(&mut self, start: u32, delta: u32) -> bool {
        if delta == 0 {
            return false;
        }
        let mut changed = false;
        for r in 0..self.len() {
            let (s, e) = (self.offsets[r] as usize, self.offsets[r + 1] as usize);
            let mut moved = false;
            for i in &mut self.indices[s..e] {
                if *i >= start {
                    *i -= delta;
                    moved = true;
                } else {
                    debug_assert!(
                        *i < start - delta,
                        "shift_indices_from: index {} inside the removed range [{}, {})",
                        *i,
                        start - delta,
                        start
                    );
                }
            }
            if moved {
                // Only rows whose indices actually moved change content;
                // everything below the cut keeps its key untouched.
                let key = self.view(r).key();
                self.keys[r] = key;
                changed = true;
            }
        }
        changed
    }

    /// Clear all rows (the truly-stochastic FORGET).
    pub fn clear(&mut self) {
        self.indices.clear();
        self.coeffs.clear();
        self.offsets.truncate(1);
        self.rhs.clear();
        self.z.clear();
        self.keys.clear();
    }

    /// Reconstruct an owned `Constraint` (tests / diagnostics).
    pub fn to_constraint(&self, r: usize) -> Constraint {
        let v = self.view(r);
        Constraint::new(v.indices.to_vec(), v.coeffs.to_vec(), v.rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_constraint_shape() {
        let c = Constraint::cycle(7, &[1, 2, 3]);
        assert_eq!(c.indices, vec![7, 1, 2, 3]);
        assert_eq!(c.coeffs, vec![1.0, -1.0, -1.0, -1.0]);
        assert_eq!(c.rhs, 0.0);
    }

    #[test]
    fn violation_measure() {
        let c = Constraint::cycle(0, &[1, 2]);
        // x_0 = 5, path sums to 3 -> violation 2.
        assert_eq!(c.violation(&[5.0, 1.0, 2.0]), 2.0);
        assert_eq!(c.violation(&[2.0, 1.0, 2.0]), 0.0);
    }

    #[test]
    fn key_is_order_invariant_and_content_sensitive() {
        let a = Constraint::new(vec![1, 5, 9], vec![1.0, -1.0, -1.0], 0.0);
        let b = Constraint::new(vec![9, 1, 5], vec![-1.0, 1.0, -1.0], 0.0);
        assert_eq!(a.key(), b.key());
        let c = Constraint::new(vec![1, 5, 9], vec![1.0, -1.0, 1.0], 0.0);
        assert_ne!(a.key(), c.key());
        let d = Constraint::new(vec![1, 5, 9], vec![1.0, -1.0, -1.0], 1.0);
        assert_ne!(a.key(), d.key());
    }

    #[test]
    fn store_push_view_roundtrip() {
        let mut s = ConstraintStore::new();
        let c1 = Constraint::cycle(0, &[1, 2]);
        let c2 = Constraint::nonneg(5);
        s.push(&c1, 0.0);
        s.push(&c2, 1.5);
        assert_eq!(s.len(), 2);
        assert_eq!(s.to_constraint(0), c1);
        assert_eq!(s.to_constraint(1), c2);
        assert_eq!(s.z[1], 1.5);
        assert_eq!(s.nnz(), 4);
    }

    #[test]
    fn retain_compacts_correctly() {
        let mut s = ConstraintStore::new();
        let cs: Vec<Constraint> = (0..6u32)
            .map(|i| Constraint::cycle(i, &(0..=i).map(|j| 10 + j).collect::<Vec<_>>()))
            .collect();
        for (i, c) in cs.iter().enumerate() {
            s.push(c, if i % 2 == 0 { 0.0 } else { 1.0 });
        }
        // Forget even slots (z == 0).
        let dropped = s.retain(|_, z| z != 0.0);
        assert_eq!(dropped, 3);
        assert_eq!(s.len(), 3);
        assert_eq!(s.to_constraint(0), cs[1]);
        assert_eq!(s.to_constraint(1), cs[3]);
        assert_eq!(s.to_constraint(2), cs[5]);
        assert_eq!(s.z, vec![1.0, 1.0, 1.0]);
        assert_eq!(s.nnz(), cs[1].indices.len() + cs[3].indices.len() + cs[5].indices.len());
    }

    #[test]
    fn retain_with_map_reports_slot_moves() {
        let mut s = ConstraintStore::new();
        for i in 0..6u32 {
            s.push(&Constraint::nonneg(i), if i % 2 == 0 { 0.0 } else { 1.0 });
        }
        let mut map = Vec::new();
        let dropped = s.retain_with_map(|_, z| z != 0.0, &mut map);
        assert_eq!(dropped, 3);
        assert_eq!(map, vec![SLOT_DROPPED, 0, SLOT_DROPPED, 1, SLOT_DROPPED, 2]);
        // The surviving rows really live at the mapped slots.
        for (old, &new) in map.iter().enumerate() {
            if new != SLOT_DROPPED {
                assert_eq!(s.to_constraint(new as usize), Constraint::nonneg(old as u32));
            }
        }
        // A map-less retain over the same store still works.
        assert_eq!(s.retain(|_, _| true), 0);
    }

    #[test]
    fn retain_all_and_none() {
        let mut s = ConstraintStore::new();
        for i in 0..4u32 {
            s.push(&Constraint::nonneg(i), i as f64);
        }
        assert_eq!(s.retain(|_, _| true), 0);
        assert_eq!(s.len(), 4);
        assert_eq!(s.retain(|_, _| false), 4);
        assert!(s.is_empty());
        assert_eq!(s.nnz(), 0);
        // Store remains usable after emptying.
        s.push(&Constraint::nonneg(9), 2.0);
        assert_eq!(s.to_constraint(0), Constraint::nonneg(9));
    }

    #[test]
    fn shift_indices_reoffsets_and_rekeys() {
        let mut s = ConstraintStore::new();
        s.push(&Constraint::cycle(2, &[3, 4]), 1.0); // entirely below the cut
        s.push(&Constraint::cycle(10, &[11]), 2.0); // entirely above it
        assert!(!s.shift_indices_from(8, 0), "delta 0 is a no-op");
        // A variable range [5, 8) was removed: indices >= 8 slide by 3.
        assert!(s.shift_indices_from(8, 3));
        assert_eq!(s.to_constraint(0), Constraint::cycle(2, &[3, 4]));
        assert_eq!(s.to_constraint(1), Constraint::cycle(7, &[8]));
        assert_eq!(s.key_of(1), Constraint::cycle(7, &[8]).key(), "keys must follow content");
        assert_eq!(s.z, vec![1.0, 2.0], "duals untouched by the relabeling");
    }

    #[test]
    fn heavy_churn_stays_consistent() {
        use crate::util::Rng;
        let mut rng = Rng::new(8);
        let mut s = ConstraintStore::new();
        let mut mirror: Vec<(Constraint, f64)> = Vec::new();
        for step in 0..500 {
            if mirror.is_empty() || rng.bernoulli(0.7) {
                let len = 1 + rng.below(6);
                let idx: Vec<u32> = (0..len).map(|_| rng.below(100) as u32).collect();
                let coef: Vec<f64> = (0..len).map(|_| rng.uniform(-2.0, 2.0)).collect();
                let c = Constraint::new(idx, coef, rng.uniform(-1.0, 1.0));
                s.push(&c, step as f64);
                mirror.push((c, step as f64));
            } else {
                // Random subset removal via retain.
                let seed = rng.next_u64();
                let mut keep_rng = Rng::new(seed);
                let keeps: Vec<bool> = (0..mirror.len()).map(|_| keep_rng.bernoulli(0.5)).collect();
                s.retain(|r, _| keeps[r]);
                let mut it = keeps.iter();
                mirror.retain(|_| *it.next().unwrap());
            }
            assert_eq!(s.len(), mirror.len());
        }
        for (r, (c, z)) in mirror.iter().enumerate() {
            assert_eq!(&s.to_constraint(r), c);
            assert_eq!(s.z[r], *z);
            assert_eq!(s.key_of(r), c.key());
        }
    }
}
