//! The truly stochastic variant (§3.2.1 / Theorem 2).
//!
//! At each step a random batch of constraints is sampled and projected
//! onto, *independently of previous iterations*: the constraint list is
//! forgotten wholesale, but the dual variables must persist — here they
//! are indexed by a dense constraint id supplied by a
//! [`ConstraintFamily`], the natural shape for problems like the L2-SVM
//! where there is one margin constraint per data point (Algorithm 10).

use super::bregman::BregmanFunction;
use super::constraint::Constraint;
use crate::util::Rng;

/// An indexed family of constraints `0..len` that can be materialised on
/// demand (they are never all stored).
pub trait ConstraintFamily: Send + Sync {
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialise constraint `id` into `out` (reused across calls).
    fn materialize(&self, id: usize, out: &mut Constraint);
}

/// Configuration for the truly stochastic loop.
#[derive(Debug, Clone)]
pub struct StochasticConfig {
    /// Projections per epoch (one epoch samples this many constraints).
    pub batch: usize,
    /// Number of epochs.
    pub epochs: usize,
    /// Random seed.
    pub seed: u64,
}

/// Result of a stochastic solve.
#[derive(Debug, Clone)]
pub struct StochasticResult {
    pub x: Vec<f64>,
    /// Persistent duals, one per constraint id.
    pub z: Vec<f64>,
    pub total_projections: usize,
    /// Number of ids with nonzero dual at the end (≈ support size).
    pub support: usize,
    pub seconds: f64,
}

/// Run the truly stochastic PROJECT AND FORGET: sample ids uniformly
/// (Property 2 with τ = batch/len per epoch), project with persistent
/// duals, keep no constraint list.
pub fn solve_stochastic<F, Fam>(
    f: &F,
    family: &Fam,
    cfg: &StochasticConfig,
) -> StochasticResult
where
    F: BregmanFunction,
    Fam: ConstraintFamily,
{
    let clock = crate::util::Stopwatch::new();
    let mut x = f.argmin();
    let mut z = vec![0.0f64; family.len()];
    let mut rng = Rng::new(cfg.seed);
    let mut scratch = Constraint::new(vec![], vec![], 0.0);
    let mut total = 0usize;
    let n = family.len();
    for _ in 0..cfg.epochs {
        for _ in 0..cfg.batch {
            let id = rng.below(n);
            family.materialize(id, &mut scratch);
            let view = super::constraint::ConstraintView {
                indices: &scratch.indices,
                coeffs: &scratch.coeffs,
                rhs: scratch.rhs,
            };
            let theta = f.theta(&x, view);
            let step = z[id].min(theta);
            if step != 0.0 {
                f.apply(&mut x, view, step);
                z[id] -= step;
                total += 1;
            }
        }
    }
    let support = z.iter().filter(|&&v| v != 0.0).count();
    StochasticResult { x, z, total_projections: total, support, seconds: clock.elapsed_s() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bregman::DiagonalQuadratic;

    /// A family of box constraints x_i <= 1 over each coordinate.
    struct Box1 {
        dim: usize,
    }

    impl ConstraintFamily for Box1 {
        fn len(&self) -> usize {
            self.dim
        }

        fn materialize(&self, id: usize, out: &mut Constraint) {
            out.indices.clear();
            out.coeffs.clear();
            out.indices.push(id as u32);
            out.coeffs.push(1.0);
            out.rhs = 1.0;
        }
    }

    #[test]
    fn converges_to_box_projection() {
        // min ½‖x − 3·1‖² s.t. x_i <= 1 -> x = 1.
        let f = DiagonalQuadratic::unweighted(vec![3.0; 8]);
        let cfg = StochasticConfig { batch: 8, epochs: 50, seed: 1 };
        let res = solve_stochastic(&f, &Box1 { dim: 8 }, &cfg);
        for (i, &xi) in res.x.iter().enumerate() {
            assert!((xi - 1.0).abs() < 1e-9, "x[{i}] = {xi}");
        }
        // Every constraint is active -> full support, duals = 2.
        assert_eq!(res.support, 8);
        for &zi in &res.z {
            assert!((zi - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn kkt_holds_for_stochastic_duals() {
        let d = vec![2.0, -1.0, 0.5, 4.0];
        let f = DiagonalQuadratic::unweighted(d.clone());
        let cfg = StochasticConfig { batch: 16, epochs: 40, seed: 3 };
        let res = solve_stochastic(&f, &Box1 { dim: 4 }, &cfg);
        // ∇f(x) = x − d must equal −A^T z = −z (A = I here).
        for i in 0..4 {
            let grad = res.x[i] - d[i];
            assert!((grad + res.z[i]).abs() < 1e-9, "kkt at {i}");
        }
        // Inactive coordinates (d < 1) keep zero duals.
        assert_eq!(res.z[1], 0.0);
        assert_eq!(res.z[2], 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let f = DiagonalQuadratic::unweighted(vec![2.0; 5]);
        let cfg = StochasticConfig { batch: 5, epochs: 10, seed: 42 };
        let a = solve_stochastic(&f, &Box1 { dim: 5 }, &cfg);
        let b = solve_stochastic(&f, &Box1 { dim: 5 }, &cfg);
        assert_eq!(a.x, b.x);
        assert_eq!(a.total_projections, b.total_projections);
    }
}
