//! The remembered constraint list `L^(ν)` with dual variables and FORGET.
//!
//! `ActiveSet` wraps the flat [`ConstraintStore`] with a content-key index
//! so that the merge `L̃^(ν+1) = L^(ν) ∪ L` (Algorithm 1, line 4) is a true
//! set union: a constraint rediscovered by the oracle while still
//! remembered is not duplicated (its dual history is preserved).

use super::constraint::{Constraint, ConstraintKey, ConstraintStore, ConstraintView};
use std::collections::HashMap;

/// The active-set sketch: constraints believed active, with duals.
#[derive(Debug, Default, Clone)]
pub struct ActiveSet {
    store: ConstraintStore,
    index: HashMap<ConstraintKey, u32>,
}

impl ActiveSet {
    pub fn new() -> ActiveSet {
        ActiveSet { store: ConstraintStore::new(), index: HashMap::new() }
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Total nonzeros across remembered rows (memory diagnostics).
    pub fn nnz(&self) -> usize {
        self.store.nnz()
    }

    /// Merge one constraint into the set. Returns its slot; if it was
    /// already remembered, the existing slot (and dual) is reused.
    pub fn insert(&mut self, c: &Constraint) -> usize {
        let key = c.key();
        if let Some(&slot) = self.index.get(&key) {
            return slot as usize;
        }
        let slot = self.store.push_with_key(c, 0.0, key);
        self.index.insert(key, slot as u32);
        slot
    }

    /// Is this constraint currently remembered?
    pub fn contains(&self, c: &Constraint) -> bool {
        self.index.contains_key(&c.key())
    }

    /// Slot of a remembered constraint by precomputed key, if any.
    #[inline]
    pub fn slot_of_key(&self, key: ConstraintKey) -> Option<usize> {
        self.index.get(&key).map(|&s| s as usize)
    }

    /// Merge with a precomputed key (avoids re-hashing on hot paths).
    pub fn insert_with_key(&mut self, c: &Constraint, key: ConstraintKey) -> usize {
        if let Some(&slot) = self.index.get(&key) {
            return slot as usize;
        }
        let slot = self.store.push_with_key(c, 0.0, key);
        self.index.insert(key, slot as u32);
        slot
    }

    /// Borrow row `r` and its dual.
    #[inline]
    pub fn view(&self, r: usize) -> ConstraintView<'_> {
        self.store.view(r)
    }

    #[inline]
    pub fn z(&self, r: usize) -> f64 {
        self.store.z[r]
    }

    #[inline]
    pub fn set_z(&mut self, r: usize, z: f64) {
        self.store.z[r] = z;
    }

    /// FORGET (Algorithm 3, lines 9–15): drop every row with `z == 0`.
    /// Returns the number of forgotten constraints.
    pub fn forget_inactive(&mut self) -> usize {
        let dropped = self.store.retain(|_, z| z != 0.0);
        if dropped > 0 {
            self.rebuild_index();
        }
        dropped
    }

    /// Truly-stochastic FORGET (§3.2.1): forget *all* constraints. The
    /// caller is responsible for keeping dual values externally.
    pub fn forget_all(&mut self) {
        self.store.clear();
        self.index.clear();
    }

    fn rebuild_index(&mut self) {
        self.index.clear();
        for r in 0..self.store.len() {
            self.index.insert(self.store.key_of(r), r as u32);
        }
    }

    /// Owned copy of row `r` (diagnostics).
    pub fn to_constraint(&self, r: usize) -> Constraint {
        self.store.to_constraint(r)
    }

    /// Maximum violation among remembered constraints at `x`.
    pub fn max_violation(&self, x: &[f64]) -> f64 {
        (0..self.len())
            .map(|r| {
                let v = self.view(r);
                let dot: f64 =
                    v.indices.iter().zip(v.coeffs).map(|(&i, &a)| a * x[i as usize]).sum();
                (dot - v.rhs).max(0.0)
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_semantics_no_duplicates() {
        let mut s = ActiveSet::new();
        let c = Constraint::cycle(0, &[1, 2]);
        let slot1 = s.insert(&c);
        s.set_z(slot1, 2.5);
        let slot2 = s.insert(&Constraint::cycle(0, &[1, 2]));
        assert_eq!(slot1, slot2);
        assert_eq!(s.len(), 1);
        assert_eq!(s.z(slot2), 2.5, "dual history preserved across re-insert");
    }

    #[test]
    fn forget_drops_only_zero_duals() {
        let mut s = ActiveSet::new();
        let a = Constraint::cycle(0, &[1]);
        let b = Constraint::cycle(2, &[3]);
        let c = Constraint::cycle(4, &[5]);
        let sa = s.insert(&a);
        let sb = s.insert(&b);
        let sc = s.insert(&c);
        s.set_z(sa, 0.0);
        s.set_z(sb, 1.0);
        s.set_z(sc, 0.0);
        assert_eq!(s.forget_inactive(), 2);
        assert_eq!(s.len(), 1);
        assert!(s.contains(&b));
        assert!(!s.contains(&a));
        // Index stays consistent: re-inserting a forgotten constraint
        // creates a fresh slot with zero dual.
        let slot = s.insert(&a);
        assert_eq!(s.z(slot), 0.0);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn forget_all_clears() {
        let mut s = ActiveSet::new();
        for i in 0..10u32 {
            let slot = s.insert(&Constraint::nonneg(i));
            s.set_z(slot, 1.0);
        }
        s.forget_all();
        assert!(s.is_empty());
        assert!(!s.contains(&Constraint::nonneg(0)));
    }

    #[test]
    fn max_violation_over_set() {
        let mut s = ActiveSet::new();
        s.insert(&Constraint::cycle(0, &[1])); // x0 - x1 <= 0
        s.insert(&Constraint::upper(1, 1.0)); // x1 <= 1
        let x = vec![3.0, 1.5];
        // First: 3 - 1.5 = 1.5 violation; second: 0.5 violation.
        assert!((s.max_violation(&x) - 1.5).abs() < 1e-12);
        assert_eq!(s.max_violation(&[0.0, 0.5]), 0.0);
    }

    #[test]
    fn index_survives_repeated_forget_cycles() {
        use crate::util::Rng;
        let mut rng = Rng::new(21);
        let mut s = ActiveSet::new();
        for round in 0..50 {
            for _ in 0..20 {
                let e = rng.below(30) as u32;
                let p = rng.below(30) as u32;
                if e != p {
                    let slot = s.insert(&Constraint::cycle(e, &[p]));
                    s.set_z(slot, if rng.bernoulli(0.5) { 0.0 } else { 1.0 });
                }
            }
            s.forget_inactive();
            // All remembered rows must be findable through the index.
            for r in 0..s.len() {
                let c = s.to_constraint(r);
                assert!(s.contains(&c), "round {round}: lost row {r}");
                assert_ne!(s.z(r), 0.0);
            }
        }
    }
}
