//! The remembered constraint list `L^(ν)` with dual variables and FORGET.
//!
//! `ActiveSet` wraps the flat [`ConstraintStore`] with a content-key index
//! so that the merge `L̃^(ν+1) = L^(ν) ∪ L` (Algorithm 1, line 4) is a true
//! set union: a constraint rediscovered by the oracle while still
//! remembered is not duplicated (its dual history is preserved).

use super::constraint::{Constraint, ConstraintKey, ConstraintStore, ConstraintView};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-unique [`ActiveSet::instance_id`] source (0 is never issued,
/// so a default [`crate::core::engine::ShardPlan`] matches no set).
static NEXT_INSTANCE_ID: AtomicU64 = AtomicU64::new(1);

fn next_instance_id() -> u64 {
    NEXT_INSTANCE_ID.fetch_add(1, Ordering::Relaxed)
}

/// The active-set sketch: constraints believed active, with duals.
#[derive(Debug)]
pub struct ActiveSet {
    store: ConstraintStore,
    index: HashMap<ConstraintKey, u32>,
    /// Bumped on every membership change (new slot, forget, clear) —
    /// NOT on dual updates. Shard plans and other slot-keyed caches use
    /// it (together with [`ActiveSet::instance_id`]) to detect staleness
    /// without diffing the set.
    generation: u64,
    /// Process-unique identity of this set. Generations are per-instance
    /// counters, so a cache keyed on the generation alone could be
    /// aliased by a *different* set that happens to share the count —
    /// with the sharded executor's scatter-safe parallel apply that
    /// aliasing would be a data race, not just wrong numbers. Clones get
    /// a fresh id: they start identical but diverge independently.
    instance_id: u64,
    /// Monotonic count of *new-slot* insertions (never decremented by
    /// FORGET). Together with the generation and length deltas this lets
    /// slot-keyed caches recognize a pure oracle append — `Δgeneration
    /// == Δinserts == Δlen` — without diffing membership (the lazy sweep
    /// scheduler's fast path).
    inserts: u64,
}

impl Default for ActiveSet {
    fn default() -> Self {
        ActiveSet::new()
    }
}

impl Clone for ActiveSet {
    fn clone(&self) -> Self {
        ActiveSet {
            store: self.store.clone(),
            index: self.index.clone(),
            generation: self.generation,
            instance_id: next_instance_id(),
            inserts: self.inserts,
        }
    }
}

impl ActiveSet {
    pub fn new() -> ActiveSet {
        ActiveSet {
            store: ConstraintStore::new(),
            index: HashMap::new(),
            generation: 0,
            instance_id: next_instance_id(),
            inserts: 0,
        }
    }

    /// Process-unique identity of this instance (see the field docs).
    #[inline]
    pub fn instance_id(&self) -> u64 {
        self.instance_id
    }

    /// Membership generation: two observations with equal generation saw
    /// identical slot→constraint assignments (duals may differ).
    #[inline]
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Monotonic new-slot insertion count (see the field docs).
    #[inline]
    pub fn inserts(&self) -> u64 {
        self.inserts
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Total nonzeros across remembered rows (memory diagnostics).
    pub fn nnz(&self) -> usize {
        self.store.nnz()
    }

    /// Merge one constraint into the set. Returns its slot; if it was
    /// already remembered, the existing slot (and dual) is reused.
    pub fn insert(&mut self, c: &Constraint) -> usize {
        let key = c.key();
        self.insert_with_key(c, key)
    }

    /// Is this constraint currently remembered?
    pub fn contains(&self, c: &Constraint) -> bool {
        self.index.contains_key(&c.key())
    }

    /// Slot of a remembered constraint by precomputed key, if any.
    #[inline]
    pub fn slot_of_key(&self, key: ConstraintKey) -> Option<usize> {
        self.index.get(&key).map(|&s| s as usize)
    }

    /// Merge with a precomputed key (avoids re-hashing on hot paths).
    pub fn insert_with_key(&mut self, c: &Constraint, key: ConstraintKey) -> usize {
        if let Some(&slot) = self.index.get(&key) {
            return slot as usize;
        }
        let slot = self.store.push_with_key(c, 0.0, key);
        self.index.insert(key, slot as u32);
        self.generation += 1;
        self.inserts += 1;
        slot
    }

    /// Borrow row `r` and its dual.
    #[inline]
    pub fn view(&self, r: usize) -> ConstraintView<'_> {
        self.store.view(r)
    }

    #[inline]
    pub fn z(&self, r: usize) -> f64 {
        self.store.z[r]
    }

    #[inline]
    pub fn set_z(&mut self, r: usize, z: f64) {
        self.store.z[r] = z;
    }

    /// FORGET (Algorithm 3, lines 9–15): drop every row with `z == 0`.
    /// Returns the number of forgotten constraints.
    pub fn forget_inactive(&mut self) -> usize {
        let dropped = self.store.retain(|_, z| z != 0.0);
        if dropped > 0 {
            self.generation += 1;
            self.rebuild_index();
        }
        dropped
    }

    /// FORGET that also records the stable-slot compaction map (see
    /// [`ConstraintStore::retain_with_map`]): `map[old_slot]` is the new
    /// slot or `SLOT_DROPPED`. The map is always filled, even when
    /// nothing was dropped (then it is the identity).
    pub fn forget_inactive_with_map(&mut self, map: &mut Vec<u32>) -> usize {
        let dropped = self.store.retain_with_map(|_, z| z != 0.0, map);
        if dropped > 0 {
            self.generation += 1;
            self.rebuild_index();
        }
        dropped
    }

    /// Re-offset the remembered rows after a variable range was removed
    /// from the concatenated fleet vector: every stored index `>= start`
    /// slides down by `delta` (see
    /// [`ConstraintStore::shift_indices_from`]). Slots, duals and the
    /// rows' relative order are untouched — only the coordinate labels
    /// (and therefore the content keys) change — so this counts as a
    /// membership-generation bump, and the key index is rebuilt.
    /// Returns `(generation_before, generation_after)` so slot-keyed
    /// caches (shard plans) can *adopt* the new generation instead of
    /// replanning: an injective index relabeling preserves
    /// support-disjointness.
    pub fn shift_indices_from(&mut self, start: u32, delta: u32) -> (u64, u64) {
        let before = self.generation;
        if self.store.shift_indices_from(start, delta) {
            self.generation += 1;
            self.rebuild_index();
        }
        (before, self.generation)
    }

    /// Truly-stochastic FORGET (§3.2.1): forget *all* constraints. The
    /// caller is responsible for keeping dual values externally.
    pub fn forget_all(&mut self) {
        if !self.store.is_empty() {
            self.generation += 1;
        }
        self.store.clear();
        self.index.clear();
    }

    fn rebuild_index(&mut self) {
        self.index.clear();
        for r in 0..self.store.len() {
            self.index.insert(self.store.key_of(r), r as u32);
        }
    }

    /// Owned copy of row `r` (diagnostics).
    pub fn to_constraint(&self, r: usize) -> Constraint {
        self.store.to_constraint(r)
    }

    /// Maximum violation among remembered constraints at `x`.
    pub fn max_violation(&self, x: &[f64]) -> f64 {
        (0..self.len())
            .map(|r| {
                let v = self.view(r);
                let dot: f64 =
                    v.indices.iter().zip(v.coeffs).map(|(&i, &a)| a * x[i as usize]).sum();
                (dot - v.rhs).max(0.0)
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_semantics_no_duplicates() {
        let mut s = ActiveSet::new();
        let c = Constraint::cycle(0, &[1, 2]);
        let slot1 = s.insert(&c);
        s.set_z(slot1, 2.5);
        let slot2 = s.insert(&Constraint::cycle(0, &[1, 2]));
        assert_eq!(slot1, slot2);
        assert_eq!(s.len(), 1);
        assert_eq!(s.z(slot2), 2.5, "dual history preserved across re-insert");
    }

    #[test]
    fn forget_drops_only_zero_duals() {
        let mut s = ActiveSet::new();
        let a = Constraint::cycle(0, &[1]);
        let b = Constraint::cycle(2, &[3]);
        let c = Constraint::cycle(4, &[5]);
        let sa = s.insert(&a);
        let sb = s.insert(&b);
        let sc = s.insert(&c);
        s.set_z(sa, 0.0);
        s.set_z(sb, 1.0);
        s.set_z(sc, 0.0);
        assert_eq!(s.forget_inactive(), 2);
        assert_eq!(s.len(), 1);
        assert!(s.contains(&b));
        assert!(!s.contains(&a));
        // Index stays consistent: re-inserting a forgotten constraint
        // creates a fresh slot with zero dual.
        let slot = s.insert(&a);
        assert_eq!(s.z(slot), 0.0);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn forget_all_clears() {
        let mut s = ActiveSet::new();
        for i in 0..10u32 {
            let slot = s.insert(&Constraint::nonneg(i));
            s.set_z(slot, 1.0);
        }
        s.forget_all();
        assert!(s.is_empty());
        assert!(!s.contains(&Constraint::nonneg(0)));
    }

    #[test]
    fn max_violation_over_set() {
        let mut s = ActiveSet::new();
        s.insert(&Constraint::cycle(0, &[1])); // x0 - x1 <= 0
        s.insert(&Constraint::upper(1, 1.0)); // x1 <= 1
        let x = vec![3.0, 1.5];
        // First: 3 - 1.5 = 1.5 violation; second: 0.5 violation.
        assert!((s.max_violation(&x) - 1.5).abs() < 1e-12);
        assert_eq!(s.max_violation(&[0.0, 0.5]), 0.0);
    }

    #[test]
    fn interleaved_insert_forget_reinsert_keeps_slots_and_index_consistent() {
        // The hot-path sequence the engine refactor leans on:
        // insert_with_key → forget_inactive (compaction) → re-insert.
        let mut s = ActiveSet::new();
        let cons: Vec<Constraint> = (0..8u32).map(|i| Constraint::cycle(i, &[i + 8])).collect();
        let keys: Vec<_> = cons.iter().map(|c| c.key()).collect();
        for (c, &k) in cons.iter().zip(&keys) {
            let slot = s.insert_with_key(c, k);
            s.set_z(slot, if slot % 2 == 0 { 0.0 } else { (slot + 1) as f64 });
        }
        // insert_with_key on a remembered key returns the existing slot.
        assert_eq!(s.insert_with_key(&cons[3], keys[3]), 3);
        assert_eq!(s.forget_inactive(), 4);
        // Survivors (old odd slots) compacted to 0..4 with duals intact,
        // and the key index follows the compaction.
        assert_eq!(s.len(), 4);
        for r in 0..s.len() {
            let c = s.to_constraint(r);
            let slot = s.slot_of_key(c.key()).expect("index lost a surviving row");
            assert_eq!(slot, r);
            assert_eq!(s.z(r), (2 * r + 2) as f64);
        }
        // Re-inserting a forgotten constraint allocates a fresh tail slot.
        let slot = s.insert_with_key(&cons[0], keys[0]);
        assert_eq!(slot, 4);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn forgotten_then_rediscovered_restarts_with_zero_dual() {
        let mut s = ActiveSet::new();
        let c = Constraint::cycle(1, &[2, 3]);
        let slot = s.insert(&c);
        s.set_z(slot, 7.5);
        s.set_z(slot, 0.0); // projection relaxed the dual back to zero
        assert_eq!(s.forget_inactive(), 1);
        assert!(!s.contains(&c));
        let slot = s.insert(&c);
        assert_eq!(s.z(slot), 0.0, "rediscovered constraint must restart at z = 0");
    }

    #[test]
    fn generation_tracks_membership_not_duals() {
        let mut s = ActiveSet::new();
        let g0 = s.generation();
        let slot = s.insert(&Constraint::nonneg(0));
        let g1 = s.generation();
        assert_ne!(g0, g1, "insert must bump the generation");
        // Dual updates and duplicate merges leave membership unchanged.
        s.set_z(slot, 3.0);
        s.insert(&Constraint::nonneg(0));
        assert_eq!(s.generation(), g1);
        // A forget that drops nothing is also not a membership change.
        assert_eq!(s.forget_inactive(), 0);
        assert_eq!(s.generation(), g1);
        s.set_z(slot, 0.0);
        assert_eq!(s.forget_inactive(), 1);
        assert_ne!(s.generation(), g1);
        let g2 = s.generation();
        s.forget_all(); // already empty: no membership change
        assert_eq!(s.generation(), g2);
    }

    #[test]
    fn inserts_counter_is_monotonic_and_counts_new_slots_only() {
        let mut s = ActiveSet::new();
        assert_eq!(s.inserts(), 0);
        let slot = s.insert(&Constraint::nonneg(0));
        s.insert(&Constraint::nonneg(1));
        assert_eq!(s.inserts(), 2);
        // Duplicate merges and dual updates are not insertions.
        s.insert(&Constraint::nonneg(0));
        s.set_z(slot, 1.0);
        assert_eq!(s.inserts(), 2);
        // FORGET never rewinds the counter (it is the append-detection
        // half of the lazy scheduler's structural key).
        assert_eq!(s.forget_inactive(), 1);
        assert_eq!(s.inserts(), 2);
        s.forget_all();
        assert_eq!(s.inserts(), 2);
        s.insert(&Constraint::nonneg(2));
        assert_eq!(s.inserts(), 3);
        assert_eq!(s.clone().inserts(), 3, "clones keep the count");
    }

    #[test]
    fn forget_with_map_matches_compaction() {
        let mut s = ActiveSet::new();
        for i in 0..10u32 {
            let slot = s.insert(&Constraint::nonneg(i));
            s.set_z(slot, if i % 3 == 0 { 0.0 } else { 1.0 });
        }
        let snapshot: Vec<Constraint> = (0..s.len()).map(|r| s.to_constraint(r)).collect();
        let mut map = Vec::new();
        let dropped = s.forget_inactive_with_map(&mut map);
        assert_eq!(dropped, 4);
        assert_eq!(map.len(), snapshot.len());
        for (old, &new) in map.iter().enumerate() {
            if new == crate::core::constraint::SLOT_DROPPED {
                assert!(!s.contains(&snapshot[old]));
            } else {
                assert_eq!(s.to_constraint(new as usize), snapshot[old]);
                assert_eq!(s.slot_of_key(snapshot[old].key()), Some(new as usize));
            }
        }
    }

    #[test]
    fn shift_indices_bumps_generation_and_rebuilds_index() {
        let mut s = ActiveSet::new();
        let a = Constraint::cycle(1, &[2]);
        let b = Constraint::cycle(9, &[10, 11]);
        let sa = s.insert(&a);
        s.set_z(sa, 1.0);
        let sb = s.insert(&b);
        s.set_z(sb, 2.0);
        let g = s.generation();
        // A variable range [3, 6) was removed: indices >= 6 slide by 3.
        let (before, after) = s.shift_indices_from(6, 3);
        assert_eq!(before, g);
        assert!(after > before, "a content relabeling is a membership-generation bump");
        // Slots, order and duals unchanged; only the labels moved.
        assert_eq!(s.to_constraint(0), a);
        let b_shifted = Constraint::cycle(6, &[7, 8]);
        assert_eq!(s.to_constraint(1), b_shifted);
        assert_eq!(s.z(1), 2.0);
        assert!(s.contains(&b_shifted), "index must resolve the new content key");
        assert!(!s.contains(&b), "the old key must be gone");
        // A shift that touches nothing leaves the generation alone.
        let (b2, a2) = s.shift_indices_from(100, 5);
        assert_eq!(b2, a2);
    }

    #[test]
    fn index_survives_repeated_forget_cycles() {
        use crate::util::Rng;
        let mut rng = Rng::new(21);
        let mut s = ActiveSet::new();
        for round in 0..50 {
            for _ in 0..20 {
                let e = rng.below(30) as u32;
                let p = rng.below(30) as u32;
                if e != p {
                    let slot = s.insert(&Constraint::cycle(e, &[p]));
                    s.set_z(slot, if rng.bernoulli(0.5) { 0.0 } else { 1.0 });
                }
            }
            s.forget_inactive();
            // All remembered rows must be findable through the index.
            for r in 0..s.len() {
                let c = s.to_constraint(r);
                assert!(s.contains(&c), "round {round}: lost row {r}");
                assert_ne!(s.z(r), 0.0);
            }
        }
    }
}
