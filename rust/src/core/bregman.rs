//! Bregman functions and their exact hyperplane projections.
//!
//! A Bregman function `f` (Definition 5 of the paper) induces the
//! generalized distance `D_f(x, y) = f(x) − f(y) − ⟨∇f(y), x − y⟩`. The
//! PROJECT step (Algorithm 3) needs, for a hyperplane `H = {⟨a, x⟩ = b}`:
//!
//!   1. `θ` solving `∇f(x*) − ∇f(x) = θ·a`, `⟨a, x*⟩ = b` — the dual step
//!      size of the Bregman projection of `x` onto `H` (θ < 0 iff the
//!      half-space `⟨a, x⟩ ≤ b` is violated, by convexity);
//!   2. the *primal move* `x ← x'` with `∇f(x') − ∇f(x) = c·a`, where the
//!      engine clamps `c = min(z, θ)` to maintain dual feasibility.
//!
//! For the diagonal quadratic `f(x) = ½(x−d)ᵀW(x−d)` (metric nearness,
//! correlation clustering, SVM) both are closed-form (eq. 3.2). For the
//! negative entropy both reduce to a scalar Newton solve (Dhillon & Tropp
//! 2007); entropy is included to exercise the engine's generality.

use super::constraint::ConstraintView;
use crate::util::pool::DisjointCell;

/// A Bregman function over `R^m` supporting sparse hyperplane projections.
pub trait BregmanFunction: Send + Sync {
    /// Dimension of the variable vector.
    fn dim(&self) -> usize;

    /// The minimiser of `f` (the algorithm's start point: `∇f(x⁰) = 0`).
    fn argmin(&self) -> Vec<f64>;

    /// `f(x)` (used by tests and diagnostics).
    fn value(&self, x: &[f64]) -> f64;

    /// `D_f(x, y)` generalized Bregman distance.
    fn divergence(&self, x: &[f64], y: &[f64]) -> f64;

    /// The dual step `θ` for projecting `x` onto the boundary of `c`.
    fn theta(&self, x: &[f64], c: ConstraintView<'_>) -> f64;

    /// Apply the primal move `∇f(x') − ∇f(x) = step·a` in place.
    fn apply(&self, x: &mut [f64], c: ConstraintView<'_>, step: f64);

    /// Fused θ + clamped apply for one row, reading and writing the
    /// iterate through a shared [`DisjointCell`] so that support-disjoint
    /// rows can be projected *and applied* concurrently (the sharded
    /// executor's scatter-safe parallel apply). Computes `θ`, clamps
    /// `step = min(z, θ)`, applies the primal move, and returns the step
    /// (`0.0` for a no-op). Implementations must be arithmetic-identical
    /// to `theta` followed by `apply` on exclusively-owned data — that
    /// identity is what keeps the sharded sweep bit-deterministic across
    /// thread counts (and equal to its serial in-shard path).
    ///
    /// # Safety
    /// No other thread may read or write any index in `c.indices` for
    /// the duration of the call. The sharded executor guarantees this via
    /// the support-disjointness invariant of `ShardPlan`.
    unsafe fn project_disjoint(&self, x: &DisjointCell<'_>, c: ConstraintView<'_>, z: f64)
        -> f64;
}

/// `f(x) = ½ (x − d)ᵀ W (x − d)` with diagonal positive `W`.
///
/// `∇f(x) = W(x−d)`, so the primal move is `x_e += step·a_e / W_e` and
/// `θ = (b − ⟨a, x⟩) / Σ_e a_e²/W_e` (eq. 3.2 with Q = W).
#[derive(Debug, Clone)]
pub struct DiagonalQuadratic {
    /// Anchor point `d` (the input dissimilarities).
    pub d: Vec<f64>,
    /// Diagonal weights (all > 0).
    pub w: Vec<f64>,
    /// Precomputed 1/W for the hot path.
    w_inv: Vec<f64>,
}

impl DiagonalQuadratic {
    pub fn new(d: Vec<f64>, w: Vec<f64>) -> Self {
        assert_eq!(d.len(), w.len());
        assert!(w.iter().all(|&wi| wi > 0.0), "weights must be positive");
        let w_inv = w.iter().map(|&wi| 1.0 / wi).collect();
        DiagonalQuadratic { d, w, w_inv }
    }

    /// Unweighted variant `½‖x − d‖²`.
    pub fn unweighted(d: Vec<f64>) -> Self {
        let m = d.len();
        DiagonalQuadratic::new(d, vec![1.0; m])
    }

    /// Build from precomputed inverse weights `1/W` (exact, no double
    /// reciprocal): the geometry callers that already hold `w_inv`
    /// (e.g. the PJRT batch adapter) reproduce it bit for bit.
    pub fn from_inverse_weights(d: Vec<f64>, w_inv: Vec<f64>) -> Self {
        assert_eq!(d.len(), w_inv.len());
        assert!(w_inv.iter().all(|&wi| wi > 0.0), "inverse weights must be positive");
        let w = w_inv.iter().map(|&wi| 1.0 / wi).collect();
        DiagonalQuadratic { d, w, w_inv }
    }

    /// Precomputed `1/W` (the hot-path view batched executors gather).
    #[inline]
    pub fn inv_weights(&self) -> &[f64] {
        &self.w_inv
    }
}

impl BregmanFunction for DiagonalQuadratic {
    fn dim(&self) -> usize {
        self.d.len()
    }

    fn argmin(&self) -> Vec<f64> {
        self.d.clone()
    }

    fn value(&self, x: &[f64]) -> f64 {
        x.iter()
            .zip(&self.d)
            .zip(&self.w)
            .map(|((&xi, &di), &wi)| 0.5 * wi * (xi - di) * (xi - di))
            .sum()
    }

    fn divergence(&self, x: &[f64], y: &[f64]) -> f64 {
        // For a quadratic, D_f(x,y) = ½(x−y)ᵀW(x−y).
        x.iter()
            .zip(y)
            .zip(&self.w)
            .map(|((&xi, &yi), &wi)| 0.5 * wi * (xi - yi) * (xi - yi))
            .sum()
    }

    #[inline]
    fn theta(&self, x: &[f64], c: ConstraintView<'_>) -> f64 {
        let mut dot = 0.0;
        let mut denom = 0.0;
        for (&i, &a) in c.indices.iter().zip(c.coeffs) {
            let i = i as usize;
            dot += a * x[i];
            denom += a * a * self.w_inv[i];
        }
        (c.rhs - dot) / denom
    }

    #[inline]
    fn apply(&self, x: &mut [f64], c: ConstraintView<'_>, step: f64) {
        for (&i, &a) in c.indices.iter().zip(c.coeffs) {
            let i = i as usize;
            x[i] += step * a * self.w_inv[i];
        }
    }

    #[inline]
    unsafe fn project_disjoint(
        &self,
        x: &DisjointCell<'_>,
        c: ConstraintView<'_>,
        z: f64,
    ) -> f64 {
        // Same operations in the same order as `theta` + `apply`, so the
        // result is bit-identical to the exclusive-access path.
        let mut dot = 0.0;
        let mut denom = 0.0;
        for (&i, &a) in c.indices.iter().zip(c.coeffs) {
            let i = i as usize;
            dot += a * x.get(i);
            denom += a * a * self.w_inv[i];
        }
        let theta = (c.rhs - dot) / denom;
        let step = z.min(theta);
        if step == 0.0 {
            return 0.0;
        }
        for (&i, &a) in c.indices.iter().zip(c.coeffs) {
            let i = i as usize;
            x.add(i, step * a * self.w_inv[i]);
        }
        step
    }
}

/// Negative entropy `f(x) = Σ x_i ln x_i − x_i` with zone `x > 0`.
///
/// `∇f(x) = ln x`, so the primal move is multiplicative:
/// `x'_e = x_e · exp(step · a_e)`, and `θ` solves
/// `Σ_e a_e · x_e · exp(θ a_e) = b` — strictly monotone in θ, solved by
/// safeguarded Newton.
#[derive(Debug, Clone)]
pub struct Entropy {
    /// Anchor (the algorithm's x⁰ has ∇f = 0, i.e. all-ones).
    pub dim: usize,
}

impl Entropy {
    pub fn new(dim: usize) -> Self {
        Entropy { dim }
    }

    /// Solve `g(θ) = Σ a_e x_e exp(θ a_e) − b = 0` by Newton + bisection.
    fn solve_theta(x: &[f64], c: ConstraintView<'_>, tol: f64) -> f64 {
        Entropy::solve_theta_with(
            |t| {
                let mut v = 0.0;
                let mut dv = 0.0;
                for (&i, &a) in c.indices.iter().zip(c.coeffs) {
                    let e = x[i as usize] * (t * a).exp();
                    v += a * e;
                    dv += a * a * e;
                }
                (v - c.rhs, dv)
            },
            tol,
        )
    }

    /// Safeguarded Newton + bisection on the strictly increasing `g`
    /// given as `eval(θ) -> (g(θ), g'(θ))` — shared by the full-vector
    /// and gathered-support paths so their arithmetic cannot drift.
    fn solve_theta_with(eval: impl Fn(f64) -> (f64, f64), tol: f64) -> f64 {
        // Bracket the root: g is strictly increasing (dv > 0).
        let (mut lo, mut hi) = (-1.0f64, 1.0f64);
        while eval(lo).0 > 0.0 {
            lo *= 2.0;
            if lo < -1e6 {
                break;
            }
        }
        while eval(hi).0 < 0.0 {
            hi *= 2.0;
            if hi > 1e6 {
                break;
            }
        }
        let mut t = 0.0;
        for _ in 0..100 {
            let (v, dv) = eval(t);
            if v.abs() < tol {
                return t;
            }
            if v > 0.0 {
                hi = t;
            } else {
                lo = t;
            }
            let newton = t - v / dv;
            t = if newton > lo && newton < hi { newton } else { 0.5 * (lo + hi) };
        }
        t
    }
}

impl BregmanFunction for Entropy {
    fn dim(&self) -> usize {
        self.dim
    }

    fn argmin(&self) -> Vec<f64> {
        vec![1.0; self.dim] // ∇f(1) = ln 1 = 0
    }

    fn value(&self, x: &[f64]) -> f64 {
        x.iter().map(|&xi| xi * xi.ln() - xi).sum()
    }

    fn divergence(&self, x: &[f64], y: &[f64]) -> f64 {
        x.iter()
            .zip(y)
            .map(|(&xi, &yi)| xi * (xi / yi).ln() - xi + yi)
            .sum()
    }

    fn theta(&self, x: &[f64], c: ConstraintView<'_>) -> f64 {
        Entropy::solve_theta(x, c, 1e-12)
    }

    fn apply(&self, x: &mut [f64], c: ConstraintView<'_>, step: f64) {
        for (&i, &a) in c.indices.iter().zip(c.coeffs) {
            let i = i as usize;
            x[i] *= (step * a).exp();
        }
    }

    unsafe fn project_disjoint(
        &self,
        x: &DisjointCell<'_>,
        c: ConstraintView<'_>,
        z: f64,
    ) -> f64 {
        // Run the shared Newton solve reading the support through the
        // cell each evaluation — the row's indices are exclusively owned
        // for the whole call, so the values (and therefore the
        // arithmetic, op for op) are identical to `theta`'s, with no
        // per-row gather allocation in the parallel hot loop.
        let theta = Entropy::solve_theta_with(
            |t| {
                let mut v = 0.0;
                let mut dv = 0.0;
                for (&i, &a) in c.indices.iter().zip(c.coeffs) {
                    let e = x.get(i as usize) * (t * a).exp();
                    v += a * e;
                    dv += a * a * e;
                }
                (v - c.rhs, dv)
            },
            1e-12,
        );
        let step = z.min(theta);
        if step == 0.0 {
            return 0.0;
        }
        for (&i, &a) in c.indices.iter().zip(c.coeffs) {
            x.scale(i as usize, (step * a).exp());
        }
        step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::constraint::Constraint;

    fn view(c: &Constraint) -> ConstraintView<'_> {
        ConstraintView { indices: &c.indices, coeffs: &c.coeffs, rhs: c.rhs }
    }

    #[test]
    fn quadratic_theta_sign_convention() {
        // f = ½‖x‖², constraint x_0 ≤ 1.
        let f = DiagonalQuadratic::unweighted(vec![0.0, 0.0]);
        let c = Constraint::new(vec![0], vec![1.0], 1.0);
        // Violated point: x0 = 3 > 1 -> θ < 0.
        assert!(f.theta(&[3.0, 0.0], view(&c)) < 0.0);
        // Satisfied point: θ > 0.
        assert!(f.theta(&[0.0, 0.0], view(&c)) > 0.0);
        // On the boundary: θ = 0.
        assert_eq!(f.theta(&[1.0, 0.0], view(&c)), 0.0);
    }

    #[test]
    fn quadratic_projection_lands_on_hyperplane() {
        let f = DiagonalQuadratic::unweighted(vec![0.0; 3]);
        let c = Constraint::new(vec![0, 1, 2], vec![1.0, -2.0, 0.5], 4.0);
        let mut x = vec![5.0, 1.0, -2.0];
        let theta = f.theta(&x, view(&c));
        f.apply(&mut x, view(&c), theta);
        let dot: f64 = [1.0, -2.0, 0.5].iter().zip(&x).map(|(a, b)| a * b).sum();
        assert!((dot - 4.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_projection_is_weighted_least_norm() {
        // Projection onto ⟨a,x⟩=b under W minimises ½(x'−x)ᵀW(x'−x):
        // x' = x + W⁻¹a·θ. Verify against the explicit formula for a 2-d case.
        let f = DiagonalQuadratic::new(vec![0.0, 0.0], vec![4.0, 1.0]);
        let c = Constraint::new(vec![0, 1], vec![1.0, 1.0], 1.0);
        let mut x = vec![0.0, 0.0];
        let theta = f.theta(&x, view(&c));
        f.apply(&mut x, view(&c), theta);
        // θ = (1-0)/(1/4 + 1) = 0.8; x = (0.2, 0.8).
        assert!((x[0] - 0.2).abs() < 1e-12);
        assert!((x[1] - 0.8).abs() < 1e-12);
    }

    #[test]
    fn quadratic_divergence_matches_definition() {
        let f = DiagonalQuadratic::new(vec![1.0, 2.0], vec![2.0, 3.0]);
        let x = vec![2.0, 0.0];
        let y = vec![0.5, 1.5];
        let by_def = f.value(&x)
            - f.value(&y)
            - (0..2)
                .map(|i| f.w[i] * (y[i] - f.d[i]) * (x[i] - y[i]))
                .sum::<f64>();
        assert!((f.divergence(&x, &y) - by_def).abs() < 1e-12);
    }

    #[test]
    fn entropy_projection_lands_on_hyperplane() {
        let f = Entropy::new(3);
        let c = Constraint::new(vec![0, 1, 2], vec![1.0, 1.0, 1.0], 1.0);
        let mut x = vec![1.0, 1.0, 1.0];
        let theta = f.theta(&x, view(&c));
        f.apply(&mut x, view(&c), theta);
        let sum: f64 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum {sum}");
        // Multiplicative update keeps positivity (zone consistency).
        assert!(x.iter().all(|&v| v > 0.0));
        // Uniform start -> uniform projection.
        assert!((x[0] - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn entropy_theta_sign_convention() {
        let f = Entropy::new(2);
        let c = Constraint::new(vec![0, 1], vec![1.0, 1.0], 1.0);
        // Violated (sum = 2 > 1) -> θ < 0; satisfied (sum = 0.5) -> θ > 0.
        assert!(f.theta(&[1.0, 1.0], view(&c)) < 0.0);
        assert!(f.theta(&[0.25, 0.25], view(&c)) > 0.0);
    }

    #[test]
    fn entropy_divergence_is_kl() {
        let f = Entropy::new(2);
        let x = [0.3f64, 0.7];
        let y = [0.5f64, 0.5];
        let kl: f64 = x
            .iter()
            .zip(&y)
            .map(|(&a, &b)| a * (a / b).ln() - a + b)
            .sum();
        assert!((f.divergence(&x, &y) - kl).abs() < 1e-12);
        assert!(f.divergence(&x, &y) > 0.0);
        assert!(f.divergence(&x, &x).abs() < 1e-15);
    }

    #[test]
    fn quadratic_project_disjoint_matches_theta_apply() {
        let f = DiagonalQuadratic::new(vec![0.5, -1.0, 2.0], vec![1.0, 2.0, 4.0]);
        let c = Constraint::new(vec![0, 2], vec![1.0, -0.5], 0.25);
        for z in [0.0, 0.1, 5.0] {
            let mut xa = vec![1.0, 2.0, -0.5];
            let theta = f.theta(&xa, view(&c));
            let step = z.min(theta);
            if step != 0.0 {
                f.apply(&mut xa, view(&c), step);
            }
            let mut xb = vec![1.0, 2.0, -0.5];
            let got = {
                let cell = crate::util::pool::DisjointCell::new(&mut xb);
                // SAFETY: exclusive access, no concurrency in this test.
                unsafe { f.project_disjoint(&cell, view(&c), z) }
            };
            // Bitwise: the fused kernel must reproduce the two-step path.
            assert_eq!(got, if step == 0.0 { 0.0 } else { step }, "z = {z}");
            assert_eq!(xa, xb, "z = {z}");
        }
    }

    #[test]
    fn entropy_project_disjoint_matches_theta_apply() {
        let f = Entropy::new(3);
        let c = Constraint::new(vec![0, 1, 2], vec![1.0, 1.0, 1.0], 1.0);
        for z in [0.0, 0.2, 10.0] {
            let mut xa = vec![1.0, 0.5, 0.25];
            let theta = f.theta(&xa, view(&c));
            let step = z.min(theta);
            if step != 0.0 {
                f.apply(&mut xa, view(&c), step);
            }
            let mut xb = vec![1.0, 0.5, 0.25];
            let got = {
                let cell = crate::util::pool::DisjointCell::new(&mut xb);
                // SAFETY: exclusive access, no concurrency in this test.
                unsafe { f.project_disjoint(&cell, view(&c), z) }
            };
            assert_eq!(got, if step == 0.0 { 0.0 } else { step }, "z = {z}");
            assert_eq!(xa, xb, "z = {z}");
        }
    }

    #[test]
    fn argmin_has_zero_gradient() {
        let f = DiagonalQuadratic::new(vec![1.0, -2.0], vec![2.0, 5.0]);
        assert_eq!(f.argmin(), vec![1.0, -2.0]);
        let e = Entropy::new(4);
        assert_eq!(e.argmin(), vec![1.0; 4]);
    }
}
