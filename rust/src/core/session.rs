//! The [`Session`] solve driver: one entry point for every workload,
//! stepwise execution with typed events, cooperative cancellation,
//! checkpoint/resume, and **multi-instance block solving**.
//!
//! A session holds any number of lowered [`Problem`]s. Vector blocks are
//! concatenated into one variable vector (block `k` occupies
//! `offsets[k]..offsets[k+1]`), share one [`Solver`] and one
//! [`ActiveSet`], and are driven with *per-block* convergence
//! accounting. Because blocks never share coordinates, every constraint
//! of block A is support-disjoint from every constraint of block B, so
//! the sharded executor's first-fit planner packs rows from the whole
//! fleet into the same shards — one sharded sweep advances every
//! instance at once (the ROADMAP multi-instance item; cf. Ruggles et
//! al., 1901.10084).
//!
//! # Per-block bit-identity
//!
//! A batch solve is bit-identical, per block, to solving each instance
//! alone with the same options (pinned in `tests/determinism.rs`):
//!
//! - per-block oracles see exactly their slice of `x` (via
//!   [`OffsetSink`]) and deliver in the same order as a solo solve;
//! - both executors visit rows in slot order (the sharded planner's
//!   first-fit passes restrict to a block exactly as they would run on
//!   that block alone, since foreign blocks touch disjoint coordinates);
//! - projections of foreign rows never read or write this block's
//!   coordinates (diagonal geometry, disjoint supports);
//! - a block that reaches its stop rule is **frozen**: its rows are
//!   dropped from the shared set, so later rounds leave it untouched —
//!   exactly where the solo solve stopped.
//!
//! Per-block dual movement and projection counts come from the
//! executors' exact per-row recording channel
//! ([`Solver::project_sweep_recorded`]) — observation only, the sweep's
//! arithmetic is untouched, and restricting the recorded movements to
//! one block reproduces that block's solo sums bit for bit.
//!
//! # Dynamic fleets (the serving layer)
//!
//! The fleet is not fixed at build time: [`Session::admit`] joins a new
//! block to a *running* session between rounds (the concatenated vector
//! grows; nothing else moves), [`Session::evict`] checkpoints and
//! detaches a live block into a [`BlockCheckpoint`] (its coordinate
//! range is compacted out and everything above it re-offsets uniformly,
//! with the shard plan surviving through the stable-slot FORGET map and
//! the executor's `after_reoffset` adoption), and
//! [`Session::admit_resumed`] continues an evicted block bit-identically
//! to never having been interrupted. `serve::Scheduler` drives these
//! from a job queue with priorities and checkpoint-based preemption.

use super::active_set::ActiveSet;
use super::bregman::DiagonalQuadratic;
use super::constraint::Constraint;
use super::oracle::{BoxKind, BoxOutcome, Oracle, OracleOutcome, OverlappableOracle, ProjectionSink};
use super::problem::{
    BlockDone, BlockSummary, CancelToken, Handle, Lowered, Problem, RoundEvent, RoundProblem,
    RoundReport, RoundSnapshot, SessionSummary, SolveEvent, SolveOptions, VectorOracle,
};
use super::solver::{
    round_verdict, IterStats, PhaseTimes, RoundVerdict, Solver, SolverConfig, SolverResult,
};
use crate::obs::{self, TelemetryFrame};
use crate::util::Stopwatch;
use std::any::Any;
use std::ops::Range;

/// The unified solve entry point. See the module docs.
///
/// Lifecycle: [`Session::add`] problems, then either [`Session::run`]
/// to completion or drive [`Session::step`] round by round; redeem
/// typed results with [`Session::take`].
pub struct Session<'a> {
    opts: SolveOptions,
    blocks: Vec<VectorBlock<'a>>,
    rounds: Vec<RoundBlock<'a>>,
    solver: Option<Solver<DiagonalQuadratic>>,
    /// Block start offsets into the concatenated vector
    /// (`len == blocks.len() + 1` once built).
    offsets: Vec<usize>,
    built: bool,
    round: usize,
    finished: bool,
    cancelled: bool,
    cancel: CancelToken,
    observers: Vec<Box<dyn FnMut(&SolveEvent) + 'a>>,
    outputs: Vec<Option<Box<dyn Any>>>,
    /// Overlapped pipeline state (single-vector-block sessions): the
    /// oracle-side back buffer and the scan taken from it.
    shadow: Option<Vec<f64>>,
    pending: Option<Box<dyn Any + Send>>,
    prev_dual_movement: f64,
    clock: Option<Stopwatch>,
    /// Reused slot→block classification (multi-block accounting).
    rowblock: Vec<u32>,
}

struct VectorBlock<'a> {
    name: &'static str,
    /// Block-local geometry (kept for `interpret`; the solver runs the
    /// concatenation).
    f: DiagonalQuadratic,
    oracle: VectorOracle<'a>,
    config: SolverConfig,
    interpret: Option<BoxedInterpret<'a>>,
    handle: usize,
    range: Range<usize>,
    iterations: usize,
    converged: bool,
    done: bool,
    projections: usize,
    last_dual_movement: f64,
    trace: Vec<IterStats>,
    /// Sampled convergence frames (fleet-wide quantities in multi-block
    /// sessions — see [`Solver::telemetry_frame`]).
    telemetry: Vec<TelemetryFrame>,
    phases: PhaseTimes,
    /// Captured at finalize (checkpoint/resume re-interprets from it).
    result: Option<SolverResult>,
}

type BoxedInterpret<'a> =
    Box<dyn FnOnce(&DiagonalQuadratic, SolverResult) -> Box<dyn Any> + 'a>;

struct RoundBlock<'a> {
    name: &'static str,
    prob: Option<Box<dyn ErasedRoundProblem + 'a>>,
    handle: usize,
    iterations: usize,
    projections: usize,
    done: bool,
    /// Reached its own stop rule (false when cancel-finalized).
    converged: bool,
    /// State snapshot taken just before `finish` (checkpoint support).
    final_state: Option<RoundSnapshot>,
}

/// Object-level mirror of [`RoundProblem`] with the output boxed.
trait ErasedRoundProblem {
    fn round_erased(&mut self) -> RoundReport;
    fn done_erased(&self) -> bool;
    fn finish_erased(self: Box<Self>) -> Box<dyn Any>;
    fn snapshot_erased(&self) -> Option<RoundSnapshot>;
    fn restore_erased(&mut self, snapshot: &RoundSnapshot);
}

struct RoundShim<'a, T: 'static>(Box<dyn RoundProblem<Output = T> + 'a>);

impl<T: 'static> ErasedRoundProblem for RoundShim<'_, T> {
    fn round_erased(&mut self) -> RoundReport {
        self.0.round()
    }

    fn done_erased(&self) -> bool {
        self.0.done()
    }

    fn finish_erased(self: Box<Self>) -> Box<dyn Any> {
        Box::new(self.0.finish())
    }

    fn snapshot_erased(&self) -> Option<RoundSnapshot> {
        self.0.snapshot()
    }

    fn restore_erased(&mut self, snapshot: &RoundSnapshot) {
        self.0.restore(snapshot)
    }
}

/// Sink adapter mapping a block-local oracle onto the shared vector:
/// `x()` exposes the block's slice, deliveries are index-shifted by the
/// block offset. Values and keys are otherwise untouched, so a block's
/// trajectory matches its solo solve bit for bit.
struct OffsetSink<'s> {
    inner: &'s mut dyn ProjectionSink,
    range: Range<usize>,
    scratch: Constraint,
}

impl<'s> OffsetSink<'s> {
    fn new(inner: &'s mut dyn ProjectionSink, range: Range<usize>) -> OffsetSink<'s> {
        OffsetSink { inner, range, scratch: Constraint::new(Vec::new(), Vec::new(), 0.0) }
    }

    fn shift(&mut self, c: &Constraint) {
        let off = self.range.start as u32;
        self.scratch.indices.clear();
        self.scratch.indices.extend(c.indices.iter().map(|&i| i + off));
        self.scratch.coeffs.clear();
        self.scratch.coeffs.extend_from_slice(&c.coeffs);
        self.scratch.rhs = c.rhs;
    }
}

impl ProjectionSink for OffsetSink<'_> {
    fn x(&self) -> &[f64] {
        &self.inner.x()[self.range.clone()]
    }

    fn remember(&mut self, c: &Constraint) {
        self.shift(c);
        self.inner.remember(&self.scratch);
    }

    fn project_and_remember(&mut self, c: &Constraint) {
        self.shift(c);
        self.inner.project_and_remember(&self.scratch);
    }

    fn project_box(
        &mut self,
        kind: BoxKind,
        start: u32,
        len: usize,
        bound: f64,
        tol: f64,
    ) -> BoxOutcome {
        // Same index shift as `shift`, but in bulk: the block's fused
        // box pass runs directly on the engine sink's coordinate range.
        self.inner.project_box(kind, start + self.range.start as u32, len, bound, tol)
    }

    fn movement_cursor(&mut self) -> Option<u64> {
        self.inner.movement_cursor()
    }

    fn moved_since(&self, cursor: u64, out: &mut Vec<u32>) -> bool {
        // Translate engine (fleet) coordinates into this block's local
        // space; foreign blocks' movement is filtered out — their
        // coordinates can never appear in this block's rows.
        let mut fleet = Vec::new();
        if !self.inner.moved_since(cursor, &mut fleet) {
            return false;
        }
        let (s, e) = (self.range.start as u32, self.range.end as u32);
        out.extend(fleet.into_iter().filter(|&c| c >= s && c < e).map(|c| c - s));
        true
    }
}

/// Block index owning variable `idx` (`offsets` is sorted, starts at 0).
fn block_of(offsets: &[usize], idx: u32) -> usize {
    offsets.partition_point(|&o| o <= idx as usize) - 1
}

/// Remembered-row count per block (slot classification by first index —
/// supports never cross block boundaries).
fn rows_per_block(solver: &Solver<DiagonalQuadratic>, offsets: &[usize]) -> Vec<usize> {
    let mut counts = vec![0usize; offsets.len().saturating_sub(1)];
    for r in 0..solver.active.len() {
        counts[block_of(offsets, solver.active.view(r).indices[0])] += 1;
    }
    counts
}

/// Round-level aggregates for the event stream.
#[derive(Default)]
struct RoundAgg {
    found: usize,
    merged: usize,
    remembered: usize,
    max_violation: f64,
    projections: usize,
    phases: PhaseTimes,
}

/// A resumable snapshot of a session's solve state: the iterate, the
/// remembered constraints with their duals, per-block accounting, and
/// (for the overlapped pipeline) the oracle-side back buffer. Restore it
/// into a fresh session holding the *same problems in the same order*;
/// the continuation is bit-identical to never having stopped.
#[derive(Clone)]
pub struct Checkpoint {
    round: usize,
    finished: bool,
    cancelled: bool,
    x: Vec<f64>,
    rows: Vec<(Constraint, f64)>,
    projections: usize,
    last_dual_movement: f64,
    prev_dual_movement: f64,
    shadow: Option<Vec<f64>>,
    blocks: Vec<BlockCkpt>,
    rounds: Vec<RoundCkpt>,
}

#[derive(Clone)]
struct BlockCkpt {
    iterations: usize,
    done: bool,
    converged: bool,
    projections: usize,
    last_dual_movement: f64,
    trace: Vec<IterStats>,
    phases: PhaseTimes,
    result: Option<SolverResult>,
}

#[derive(Clone)]
struct RoundCkpt {
    iterations: usize,
    projections: usize,
    done: bool,
    converged: bool,
    state: Option<RoundSnapshot>,
}

impl Checkpoint {
    /// Session rounds completed when the checkpoint was taken.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Remembered constraints captured (all vector blocks).
    pub fn remembered(&self) -> usize {
        self.rows.len()
    }
}

/// The resumable state of ONE block detached from a live session by
/// [`Session::evict`] — the serving layer's preemption token. For a
/// vector block it carries the block's slice of the iterate, its
/// remembered rows re-based to block-local indices (with duals), and
/// the per-block accounting; for a round-driven block, the problem's
/// own snapshot. Feed it back through [`Session::admit_resumed`] (same
/// problem, same options — in the same session or a different one) and
/// the block continues bit-identically to never having been preempted.
#[derive(Clone)]
pub struct BlockCheckpoint {
    inner: BlockCkptInner,
}

#[derive(Clone)]
enum BlockCkptInner {
    Vector {
        x: Vec<f64>,
        rows: Vec<(Constraint, f64)>,
        iterations: usize,
        projections: usize,
        last_dual_movement: f64,
        trace: Vec<IterStats>,
        phases: PhaseTimes,
    },
    Round {
        state: RoundSnapshot,
        iterations: usize,
        projections: usize,
    },
}

impl BlockCheckpoint {
    /// Rounds the block had run when it was evicted.
    pub fn iterations(&self) -> usize {
        match &self.inner {
            BlockCkptInner::Vector { iterations, .. } => *iterations,
            BlockCkptInner::Round { iterations, .. } => *iterations,
        }
    }

    /// Projections the block had performed when it was evicted.
    pub fn projections(&self) -> usize {
        match &self.inner {
            BlockCkptInner::Vector { projections, .. } => *projections,
            BlockCkptInner::Round { projections, .. } => *projections,
        }
    }

    /// Remembered constraints captured (vector blocks; 0 otherwise).
    pub fn remembered(&self) -> usize {
        match &self.inner {
            BlockCkptInner::Vector { rows, .. } => rows.len(),
            BlockCkptInner::Round { .. } => 0,
        }
    }

    /// Borrowed view of a vector-block checkpoint's contents, or `None`
    /// for a round-driven checkpoint. The durable-persistence layer
    /// (`serve::persist`) serializes through this without cloning.
    pub(crate) fn vector_view(&self) -> Option<VectorCkptView<'_>> {
        match &self.inner {
            BlockCkptInner::Vector {
                x,
                rows,
                iterations,
                projections,
                last_dual_movement,
                trace,
                phases,
            } => Some(VectorCkptView {
                x,
                rows,
                iterations: *iterations,
                projections: *projections,
                last_dual_movement: *last_dual_movement,
                trace,
                phases: *phases,
            }),
            BlockCkptInner::Round { .. } => None,
        }
    }

    /// The opaque problem snapshot plus `(iterations, projections)` of a
    /// round-driven checkpoint, or `None` for a vector checkpoint.
    pub(crate) fn round_view(&self) -> Option<(&RoundSnapshot, usize, usize)> {
        match &self.inner {
            BlockCkptInner::Vector { .. } => None,
            BlockCkptInner::Round { state, iterations, projections } => {
                Some((state, *iterations, *projections))
            }
        }
    }

    /// Rebuild a vector-block checkpoint from deserialized parts — the
    /// inverse of [`BlockCheckpoint::vector_view`].
    pub(crate) fn from_vector_parts(
        x: Vec<f64>,
        rows: Vec<(Constraint, f64)>,
        iterations: usize,
        projections: usize,
        last_dual_movement: f64,
        trace: Vec<IterStats>,
        phases: PhaseTimes,
    ) -> BlockCheckpoint {
        BlockCheckpoint {
            inner: BlockCkptInner::Vector {
                x,
                rows,
                iterations,
                projections,
                last_dual_movement,
                trace,
                phases,
            },
        }
    }

    /// Rebuild a round-driven checkpoint from deserialized parts — the
    /// inverse of [`BlockCheckpoint::round_view`].
    pub(crate) fn from_round_parts(
        state: RoundSnapshot,
        iterations: usize,
        projections: usize,
    ) -> BlockCheckpoint {
        BlockCheckpoint { inner: BlockCkptInner::Round { state, iterations, projections } }
    }
}

/// Borrowed contents of a vector-block [`BlockCheckpoint`]; the field
/// order mirrors the durable wire layout in `serve::persist`.
pub(crate) struct VectorCkptView<'a> {
    pub x: &'a [f64],
    pub rows: &'a [(Constraint, f64)],
    pub iterations: usize,
    pub projections: usize,
    pub last_dual_movement: f64,
    pub trace: &'a [IterStats],
    pub phases: PhaseTimes,
}

impl<'a> Session<'a> {
    pub fn new(opts: SolveOptions) -> Session<'a> {
        Session {
            opts,
            blocks: Vec::new(),
            rounds: Vec::new(),
            solver: None,
            offsets: Vec::new(),
            built: false,
            round: 0,
            finished: false,
            cancelled: false,
            cancel: CancelToken::new(),
            observers: Vec::new(),
            outputs: Vec::new(),
            shadow: None,
            pending: None,
            prev_dual_movement: f64::INFINITY,
            clock: None,
            rowblock: Vec::new(),
        }
    }

    /// The session's option set.
    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    /// Add one problem instance. Returns a typed handle to redeem with
    /// [`Session::take`] once the session finished. Panics if called
    /// after stepping started.
    pub fn add<P: Problem<'a>>(&mut self, problem: P) -> Handle<P::Output> {
        assert!(!self.built, "Session::add after stepping started");
        let handle = self.outputs.len();
        self.outputs.push(None);
        match problem.lower(&self.opts) {
            Lowered::Vector(part) => {
                let interpret = part.interpret;
                let erased: BoxedInterpret<'a> =
                    Box::new(move |f, r| Box::new(interpret(f, r)) as Box<dyn Any>);
                self.blocks.push(VectorBlock {
                    name: part.name,
                    f: part.f,
                    oracle: part.oracle,
                    config: part.config,
                    interpret: Some(erased),
                    handle,
                    range: 0..0,
                    iterations: 0,
                    converged: false,
                    done: false,
                    projections: 0,
                    last_dual_movement: f64::INFINITY,
                    trace: Vec::new(),
                    telemetry: Vec::new(),
                    phases: PhaseTimes::default(),
                    result: None,
                });
            }
            Lowered::Rounds(rp) => {
                let name = rp.name();
                self.rounds.push(RoundBlock {
                    name,
                    prob: Some(Box::new(RoundShim(rp))),
                    handle,
                    iterations: 0,
                    projections: 0,
                    done: false,
                    converged: false,
                    final_state: None,
                });
            }
        }
        Handle::new(handle)
    }

    /// Register an observer invoked on every [`SolveEvent`].
    pub fn on_event(&mut self, observer: impl FnMut(&SolveEvent) + 'a) {
        self.observers.push(Box::new(observer));
    }

    /// A cooperative cancellation token for this session.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Number of problems added.
    pub fn len(&self) -> usize {
        self.outputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.outputs.is_empty()
    }

    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// One-problem convenience: add, run to completion, take.
    pub fn solve_one<P: Problem<'a>>(opts: SolveOptions, problem: P) -> P::Output {
        let mut session = Session::new(opts);
        let handle = session.add(problem);
        session.run();
        session.take_unwrap(handle)
    }

    /// Redeem a handle's typed output. Returns `None` while the block
    /// has not finished yet (or after the output was already taken) —
    /// the serving paths poll this on live sessions, where a preempted
    /// or still-running job must not panic the scheduler. A finished
    /// block's output is available as soon as its [`SolveEvent::BlockDone`]
    /// fired, even while other blocks keep running. Still panics on a
    /// handle whose type does not match (a programming error, not a
    /// runtime state).
    pub fn take<T: 'static>(&mut self, handle: Handle<T>) -> Option<T> {
        let boxed = self.outputs.get_mut(handle.idx)?.take()?;
        Some(*boxed.downcast::<T>().expect("Session::take: handle type mismatch"))
    }

    /// [`Session::take`] for callers that know the block finished:
    /// panics on an unfinished (or already-taken) handle.
    pub fn take_unwrap<T: 'static>(&mut self, handle: Handle<T>) -> T {
        self.take(handle)
            .expect("Session::take_unwrap: block not finished yet (or output already taken)")
    }

    /// Has this handle's block reached its stop rule? (Also true once
    /// the output was taken; false for a block evicted from the
    /// session.)
    pub fn block_done(&self, index: usize) -> bool {
        if self.outputs.get(index).is_some_and(|o| o.is_some()) {
            return true;
        }
        self.blocks.iter().any(|b| b.handle == index && b.done)
            || self.rounds.iter().any(|r| r.handle == index && r.done)
    }

    fn notify(&mut self, event: &SolveEvent) {
        for obs in &mut self.observers {
            obs(event);
        }
    }

    fn session_seconds(&self) -> f64 {
        self.clock.as_ref().map(Stopwatch::elapsed_s).unwrap_or(0.0)
    }

    /// Lay out the concatenated vector fleet. Called lazily by the first
    /// `step`/`run`/`restore`.
    fn build(&mut self) {
        if self.built {
            return;
        }
        self.built = true;
        self.clock = Some(Stopwatch::new());
        self.offsets.clear();
        self.offsets.push(0);
        if self.blocks.is_empty() {
            return;
        }
        // Structural knobs are shared by the one solver driving the
        // fleet; per-block *stop* knobs may differ freely.
        let sweeps0 = self.blocks[0].config.inner_sweeps;
        let z0 = self.blocks[0].config.z_tol;
        let mut d = Vec::new();
        let mut w = Vec::new();
        for b in &mut self.blocks {
            assert_eq!(
                b.config.inner_sweeps, sweeps0,
                "all vector blocks in one session must agree on inner_sweeps \
                 (block {:?} wants {}, session runs {})",
                b.name, b.config.inner_sweeps, sweeps0
            );
            assert!(
                b.config.z_tol == z0,
                "all vector blocks in one session must agree on z_tol \
                 (block {:?} wants {}, session runs {})",
                b.name, b.config.z_tol, z0
            );
            let start = d.len();
            d.extend_from_slice(&b.f.d);
            w.extend_from_slice(&b.f.w);
            b.range = start..d.len();
            self.offsets.push(d.len());
        }
        let mut cfg = self.blocks[0].config.clone();
        cfg.max_iters = self.blocks.iter().map(|b| b.config.max_iters).max().unwrap_or(1);
        // The session does its own per-block trace/budget accounting.
        cfg.record_trace = false;
        cfg.projection_budget = None;
        self.solver = Some(Solver::new(DiagonalQuadratic::new(d, w), cfg));
    }

    fn overlap_active(&self) -> bool {
        self.opts.overlap
            && self.blocks.len() == 1
            && matches!(self.blocks[0].oracle, VectorOracle::Overlappable(_))
    }

    /// Drive one session round across all live blocks. Returns the
    /// round's event ([`SolveEvent::Finished`] when this round completed
    /// the solve, or on every call thereafter).
    pub fn step(&mut self) -> SolveEvent {
        self.build();
        if self.finished {
            return SolveEvent::Finished(self.summary());
        }
        if self.cancel.is_cancelled() {
            self.finish_cancelled();
            let event = SolveEvent::Cancelled { round: self.round };
            self.notify(&event);
            return event;
        }
        let live = self.blocks.iter().filter(|b| !b.done).count()
            + self.rounds.iter().filter(|r| !r.done).count();
        let round_clock = Stopwatch::new();
        let mut agg = RoundAgg::default();
        let mut done_events: Vec<BlockDone> = Vec::new();

        if self.blocks.iter().any(|b| !b.done) {
            if self.overlap_active() {
                self.overlapped_vector_round(&mut agg, &mut done_events);
            } else {
                self.plain_vector_round(&mut agg, &mut done_events);
            }
        }

        for rb in &mut self.rounds {
            if rb.done {
                continue;
            }
            let prob = rb.prob.as_mut().expect("live round block lost its problem");
            let report = prob.round_erased();
            rb.iterations += 1;
            rb.projections += report.projections;
            agg.found += report.found;
            agg.projections += report.projections;
            if prob.done_erased() {
                rb.done = true;
                rb.converged = true;
                rb.final_state = prob.snapshot_erased();
                let prob = rb.prob.take().expect("round block finished twice");
                self.outputs[rb.handle] = Some(prob.finish_erased());
                done_events.push(BlockDone {
                    block: rb.handle,
                    name: rb.name,
                    converged: true,
                    iterations: rb.iterations,
                    projections: rb.projections,
                });
            }
        }

        let seconds = round_clock.elapsed_s();
        let round_event = SolveEvent::Round(RoundEvent {
            round: self.round,
            live_blocks: live,
            found: agg.found,
            merged: agg.merged,
            remembered: agg.remembered,
            max_violation: agg.max_violation,
            projections: agg.projections,
            phases: agg.phases,
            seconds,
        });
        self.round += 1;
        for done in done_events {
            self.notify(&SolveEvent::BlockDone(done));
        }
        self.notify(&round_event);
        if self.blocks.iter().all(|b| b.done) && self.rounds.iter().all(|r| r.done) {
            self.finished = true;
            let finished = SolveEvent::Finished(self.summary());
            self.notify(&finished);
            return finished;
        }
        round_event
    }

    /// Run to completion (or cancellation) and return the certificate.
    pub fn run(&mut self) -> SessionSummary {
        loop {
            match self.step() {
                SolveEvent::Finished(summary) => return summary,
                SolveEvent::Cancelled { .. } => return self.summary(),
                _ => {}
            }
        }
    }

    /// The current per-block certificate.
    pub fn summary(&self) -> SessionSummary {
        let mut blocks: Vec<Option<BlockSummary>> =
            (0..self.outputs.len()).map(|_| None).collect();
        for b in &self.blocks {
            blocks[b.handle] = Some(BlockSummary {
                name: b.name,
                converged: b.converged,
                iterations: b.iterations,
                projections: b.projections,
            });
        }
        for r in &self.rounds {
            blocks[r.handle] = Some(BlockSummary {
                name: r.name,
                converged: r.converged,
                iterations: r.iterations,
                projections: r.projections,
            });
        }
        let blocks: Vec<BlockSummary> = blocks.into_iter().flatten().collect();
        SessionSummary {
            rounds: self.round,
            all_converged: !self.cancelled && blocks.iter().all(|b| b.converged),
            cancelled: self.cancelled,
            blocks,
        }
    }

    /// One plain (non-overlapped) round of the vector fleet: every live
    /// block's oracle in block order, then the shared sweeps with
    /// per-block accounting, then per-block stop decisions.
    fn plain_vector_round(&mut self, agg: &mut RoundAgg, done: &mut Vec<BlockDone>) {
        let nb = self.blocks.len();
        let multi = nb > 1;
        let solver = self.solver.as_mut().expect("vector fleet not built");
        let record_trace = self.opts.record_trace;
        let round_clock = Stopwatch::new();
        let marks_before = solver.movement().marks();
        let evictions_before = solver.forget_evictions;
        let mut round_span = obs::span(obs::SpanKind::Round);

        // Phase 1: separation oracles, block by block. Each block's
        // deliveries touch only its own coordinates, so block order is
        // immaterial to any block's trajectory.
        let mut outcomes: Vec<Option<OracleOutcome>> = vec![None; nb];
        let mut oracle_proj = vec![0usize; nb];
        let mut oracle_s = vec![0.0f64; nb];
        for (bi, b) in self.blocks.iter_mut().enumerate() {
            if b.done {
                continue;
            }
            let before = solver.projections;
            let mut lap = Stopwatch::new();
            let range = b.range.clone();
            let outcome = match &mut b.oracle {
                VectorOracle::Plain(o) => {
                    if multi {
                        solver.with_sink(|sink| {
                            let mut off = OffsetSink::new(sink, range);
                            o.separate(&mut off)
                        })
                    } else {
                        solver.separate_with(&mut **o)
                    }
                }
                VectorOracle::Overlappable(o) => {
                    if multi {
                        solver.with_sink(|sink| {
                            let mut off = OffsetSink::new(sink, range);
                            o.separate(&mut off)
                        })
                    } else {
                        solver.separate_with(o)
                    }
                }
            };
            oracle_s[bi] = lap.lap_s();
            oracle_proj[bi] = solver.projections - before;
            outcomes[bi] = Some(outcome);
        }
        let merged_per = rows_per_block(solver, &self.offsets);
        let proj_after_oracle = solver.projections;
        let rows_before = (solver.sweep_rows_projected, solver.sweep_rows_skipped);

        // Phases 2+3: shared sweeps over the union. For batches, the
        // executor's recording channel reports every row's exact
        // movement in bookkeeping order; classified by block, that
        // reproduces each block's solo projection count and (for the
        // last sweep — the stop rule's input) its solo dual-movement
        // sum bit for bit.
        let inner_sweeps = solver.config.inner_sweeps;
        let mut sweep_proj = vec![0usize; nb];
        let mut last_move = vec![0.0f64; nb];
        let mut sweep_s = 0.0;
        let mut forget_s = 0.0;
        for sweep in 0..inner_sweeps {
            let mut lap = Stopwatch::new();
            if multi {
                // Slot→block map for this sweep (membership is stable
                // within a sweep; FORGET below invalidates it).
                self.rowblock.clear();
                for r in 0..solver.active.len() {
                    self.rowblock
                        .push(block_of(&self.offsets, solver.active.view(r).indices[0]) as u32);
                }
                let last = sweep + 1 == inner_sweeps;
                let rowblock = &self.rowblock;
                let sweep_proj = &mut sweep_proj;
                let last_move = &mut last_move;
                lap.lap_s();
                solver.project_sweep_recorded(&mut |slot, movement| {
                    let bi = rowblock[slot as usize] as usize;
                    sweep_proj[bi] += 1;
                    if last {
                        last_move[bi] += movement;
                    }
                });
            } else {
                solver.project_sweep();
            }
            sweep_s += lap.lap_s();
            solver.forget();
            forget_s += lap.lap_s();
        }
        if !multi {
            sweep_proj[0] = solver.projections - proj_after_oracle;
            last_move[0] = solver.last_dual_movement;
        }
        // Sweep scheduling counters: the sweeps run over the block
        // union, so in multi-block sessions each block's trace reports
        // the fleet-wide visit/skip totals for the round.
        let rows_projected = solver.sweep_rows_projected - rows_before.0;
        let rows_skipped = solver.sweep_rows_skipped - rows_before.1;
        let remembered_per = rows_per_block(solver, &self.offsets);
        if let Some(g) = round_span.as_mut() {
            g.counts(
                outcomes.iter().flatten().map(|o| o.found as u64).sum::<u64>(),
                remembered_per.iter().sum::<usize>() as u64,
            );
        }
        drop(round_span);

        // Per-block bookkeeping + the shared stop rule.
        let seconds = round_clock.elapsed_s();
        agg.merged += merged_per.iter().sum::<usize>();
        agg.remembered += remembered_per.iter().sum::<usize>();
        agg.phases.sweep_s += sweep_s;
        agg.phases.forget_s += forget_s;
        for bi in 0..nb {
            let Some(outcome) = outcomes[bi] else { continue };
            let b = &mut self.blocks[bi];
            let proj_round = oracle_proj[bi] + sweep_proj[bi];
            b.projections += proj_round;
            b.last_dual_movement = last_move[bi];
            let phases =
                PhaseTimes { oracle_s: oracle_s[bi], sweep_s, forget_s };
            b.phases.accumulate(&phases);
            if record_trace {
                b.trace.push(IterStats {
                    iteration: b.iterations,
                    found: outcome.found,
                    merged: merged_per[bi],
                    remembered: remembered_per[bi],
                    max_violation: outcome.max_violation,
                    projections: proj_round,
                    seconds,
                    oracle_s: phases.oracle_s,
                    sweep_s,
                    forget_s,
                    rows_projected,
                    rows_skipped,
                });
            }
            if solver.telemetry_due(b.iterations) {
                b.telemetry.push(solver.telemetry_frame(
                    b.iterations,
                    &outcome,
                    rows_before,
                    marks_before,
                    evictions_before,
                ));
            }
            b.iterations += 1;
            agg.found += outcome.found;
            agg.max_violation = agg.max_violation.max(outcome.max_violation);
            agg.projections += proj_round;
            agg.phases.oracle_s += oracle_s[bi];
            let verdict = round_verdict(
                &b.config,
                &outcome,
                b.last_dual_movement,
                None,
                b.projections,
            );
            let stop = match verdict {
                RoundVerdict::Converged => Some(true),
                RoundVerdict::BudgetExhausted => Some(false),
                RoundVerdict::Continue => (b.iterations >= b.config.max_iters).then_some(false),
            };
            if let Some(converged) = stop {
                let seconds = self.clock.as_ref().map(Stopwatch::elapsed_s).unwrap_or(0.0);
                finalize_block(
                    b,
                    &mut self.outputs,
                    &solver.x,
                    remembered_per[bi],
                    converged,
                    seconds,
                    done,
                );
                if multi {
                    // Freeze: drop the finished block's rows so later
                    // rounds leave it exactly where its solo solve
                    // stopped. (After the sweeps' FORGETs no other row
                    // has a zero dual, so only this block is dropped.)
                    for r in 0..solver.active.len() {
                        if block_of(&self.offsets, solver.active.view(r).indices[0]) == bi {
                            solver.active.set_z(r, 0.0);
                        }
                    }
                    solver.forget();
                }
            }
        }
    }

    /// One overlapped round (single vector block): the exact
    /// `Solver::solve_overlapped` pipeline, driven stepwise through the
    /// shared `overlapped_round` helper.
    fn overlapped_vector_round(&mut self, agg: &mut RoundAgg, done: &mut Vec<BlockDone>) {
        let solver = self.solver.as_mut().expect("vector fleet not built");
        let record_trace = self.opts.record_trace;
        let round_clock = Stopwatch::new();
        let b = &mut self.blocks[0];
        let VectorOracle::Overlappable(oracle) = &mut b.oracle else {
            unreachable!("overlap_active guarantees an overlappable oracle");
        };
        // Prime (fresh start) or re-prime (post-restore) the pipeline:
        // the pending scan is always the scan of `shadow`, so resuming
        // from a checkpointed shadow reproduces it exactly.
        if self.pending.is_none() {
            if self.shadow.is_none() {
                self.shadow = Some(solver.x.clone());
            }
            let mut lap = Stopwatch::new();
            let scan = OverlappableOracle::<DiagonalQuadratic>::scan(
                oracle,
                self.shadow.as_ref().unwrap(),
            );
            b.phases.oracle_s += lap.lap_s();
            self.pending = Some(scan);
        }
        let scan = self.pending.take().unwrap();
        let proj_before = solver.projections;
        let rows_before = (solver.sweep_rows_projected, solver.sweep_rows_skipped);
        let marks_before = solver.movement().marks();
        let evictions_before = solver.forget_evictions;
        let mut round_span = obs::span(obs::SpanKind::Round);
        let prev = self.prev_dual_movement;
        let (round, next_scan) =
            solver.overlapped_round(oracle, scan, self.shadow.as_mut().unwrap(), prev);
        let proj_round = solver.projections - proj_before;
        b.projections += proj_round;
        b.last_dual_movement = solver.last_dual_movement;
        b.phases.accumulate(&round.phases);
        if let Some(g) = round_span.as_mut() {
            g.counts(round.outcome.found as u64, round.remembered as u64);
        }
        drop(round_span);
        let seconds = round_clock.elapsed_s();
        if record_trace {
            b.trace.push(IterStats {
                iteration: b.iterations,
                found: round.outcome.found,
                merged: round.merged,
                remembered: round.remembered,
                max_violation: round.outcome.max_violation,
                projections: proj_round,
                seconds,
                oracle_s: round.phases.oracle_s,
                sweep_s: round.phases.sweep_s,
                forget_s: round.phases.forget_s,
                rows_projected: solver.sweep_rows_projected - rows_before.0,
                rows_skipped: solver.sweep_rows_skipped - rows_before.1,
            });
        }
        if solver.telemetry_due(b.iterations) {
            b.telemetry.push(solver.telemetry_frame(
                b.iterations,
                &round.outcome,
                rows_before,
                marks_before,
                evictions_before,
            ));
        }
        b.iterations += 1;
        agg.found += round.outcome.found;
        agg.merged += round.merged;
        agg.remembered += round.remembered;
        agg.max_violation = agg.max_violation.max(round.outcome.max_violation);
        agg.projections += proj_round;
        agg.phases.accumulate(&round.phases);
        let verdict = round_verdict(
            &b.config,
            &round.outcome,
            b.last_dual_movement,
            Some(prev),
            b.projections,
        );
        match verdict {
            RoundVerdict::Continue if b.iterations < b.config.max_iters => {
                self.prev_dual_movement = b.last_dual_movement;
                self.pending = Some(match next_scan {
                    Some(scan) => scan,
                    None => {
                        let mut lap = Stopwatch::new();
                        let scan = OverlappableOracle::<DiagonalQuadratic>::scan(
                            oracle,
                            self.shadow.as_ref().unwrap(),
                        );
                        b.phases.oracle_s += lap.lap_s();
                        scan
                    }
                });
            }
            verdict => {
                let converged = verdict == RoundVerdict::Converged;
                let seconds = self.clock.as_ref().map(Stopwatch::elapsed_s).unwrap_or(0.0);
                finalize_block(
                    b,
                    &mut self.outputs,
                    &solver.x,
                    round.remembered,
                    converged,
                    seconds,
                    done,
                );
            }
        }
    }

    /// Cancellation: finalize every live block in its current state
    /// (`converged == false`) so outputs stay redeemable, emit the
    /// corresponding [`SolveEvent::BlockDone`]s, and mark the session
    /// finished.
    fn finish_cancelled(&mut self) {
        self.cancelled = true;
        self.finished = true;
        let seconds = self.session_seconds();
        let mut done_events: Vec<BlockDone> = Vec::new();
        if let Some(solver) = self.solver.as_mut() {
            let per_block = rows_per_block(solver, &self.offsets);
            for (bi, b) in self.blocks.iter_mut().enumerate() {
                if b.done {
                    continue;
                }
                finalize_block(
                    b,
                    &mut self.outputs,
                    &solver.x,
                    per_block[bi],
                    false,
                    seconds,
                    &mut done_events,
                );
            }
        }
        for rb in &mut self.rounds {
            if rb.done {
                continue;
            }
            rb.done = true;
            let prob = rb.prob.take().expect("live round block lost its problem");
            rb.final_state = prob.snapshot_erased();
            self.outputs[rb.handle] = Some(prob.finish_erased());
            done_events.push(BlockDone {
                block: rb.handle,
                name: rb.name,
                converged: false,
                iterations: rb.iterations,
                projections: rb.projections,
            });
        }
        for done in done_events {
            self.notify(&SolveEvent::BlockDone(done));
        }
    }

    /// Snapshot the full solve state for later [`Session::restore`].
    /// Valid after at least one `step`; cheap to clone.
    pub fn checkpoint(&self) -> Checkpoint {
        assert!(self.built, "Session::checkpoint before the first step()");
        let (x, rows, projections, last_dual_movement) = match &self.solver {
            Some(s) => (
                s.x.clone(),
                (0..s.active.len())
                    .map(|r| (s.active.to_constraint(r), s.active.z(r)))
                    .collect(),
                s.projections,
                s.last_dual_movement,
            ),
            None => (Vec::new(), Vec::new(), 0, 0.0),
        };
        Checkpoint {
            round: self.round,
            finished: self.finished,
            cancelled: self.cancelled,
            x,
            rows,
            projections,
            last_dual_movement,
            prev_dual_movement: self.prev_dual_movement,
            shadow: self.shadow.clone(),
            blocks: self
                .blocks
                .iter()
                .map(|b| BlockCkpt {
                    iterations: b.iterations,
                    done: b.done,
                    converged: b.converged,
                    projections: b.projections,
                    last_dual_movement: b.last_dual_movement,
                    trace: b.trace.clone(),
                    phases: b.phases,
                    result: b.result.clone(),
                })
                .collect(),
            rounds: self
                .rounds
                .iter()
                .map(|r| RoundCkpt {
                    iterations: r.iterations,
                    projections: r.projections,
                    done: r.done,
                    converged: r.converged,
                    state: if r.done {
                        Some(r.final_state.clone().expect(
                            "this round-driven problem does not support checkpointing",
                        ))
                    } else {
                        Some(
                            r.prob
                                .as_ref()
                                .expect("live round block lost its problem")
                                .snapshot_erased()
                                .expect(
                                    "this round-driven problem does not support checkpointing",
                                ),
                        )
                    },
                })
                .collect(),
        }
    }

    /// Restore a [`Checkpoint`] taken from a session holding the same
    /// problems in the same order. Continuing with `step`/`run` is then
    /// bit-identical to the uninterrupted solve (oracles are rebuilt
    /// from the problems; all solve state — iterate, duals, per-block
    /// accounting, the overlap back buffer — comes from the checkpoint).
    pub fn restore(&mut self, ck: &Checkpoint) {
        self.build();
        assert_eq!(
            self.blocks.len(),
            ck.blocks.len(),
            "checkpoint/session mismatch: vector block count"
        );
        assert_eq!(
            self.rounds.len(),
            ck.rounds.len(),
            "checkpoint/session mismatch: round-driven block count"
        );
        if let Some(solver) = self.solver.as_mut() {
            assert_eq!(
                solver.x.len(),
                ck.x.len(),
                "checkpoint/session mismatch: variable dimensions"
            );
            solver.x.copy_from_slice(&ck.x);
            solver.active = ActiveSet::new();
            for (c, z) in &ck.rows {
                let slot = solver.active.insert(c);
                solver.active.set_z(slot, *z);
            }
            solver.projections = ck.projections;
            solver.last_dual_movement = ck.last_dual_movement;
            // The iterate was rewritten outside the tracked paths: any
            // outstanding movement window under-reports, so incremental
            // oracles must re-derive their dirty sets from snapshots.
            solver.invalidate_movement();
        }
        for (b, bc) in self.blocks.iter_mut().zip(&ck.blocks) {
            b.iterations = bc.iterations;
            b.done = bc.done;
            b.converged = bc.converged;
            b.projections = bc.projections;
            b.last_dual_movement = bc.last_dual_movement;
            b.trace = bc.trace.clone();
            b.phases = bc.phases;
            b.result = bc.result.clone();
            if bc.done {
                let result =
                    bc.result.clone().expect("checkpointed finished block without result");
                let interpret = b.interpret.take().expect("block finalized twice");
                self.outputs[b.handle] = Some(interpret(&b.f, result));
            }
        }
        for (rb, rc) in self.rounds.iter_mut().zip(&ck.rounds) {
            rb.iterations = rc.iterations;
            rb.projections = rc.projections;
            rb.done = rc.done;
            rb.converged = rc.converged;
            if let Some(state) = &rc.state {
                let prob = rb.prob.as_mut().expect("round block restored twice");
                prob.restore_erased(state);
                if rc.done {
                    rb.final_state = Some(state.clone());
                    let prob = rb.prob.take().unwrap();
                    self.outputs[rb.handle] = Some(prob.finish_erased());
                }
            }
        }
        self.round = ck.round;
        self.finished = ck.finished;
        self.cancelled = ck.cancelled;
        self.prev_dual_movement = ck.prev_dual_movement;
        self.shadow = ck.shadow.clone();
        // The pending scan is not serialised: it is always the scan of
        // `shadow`, and scans are pure functions of their snapshot, so
        // the next step re-derives it bit-identically.
        self.pending = None;
        self.clock = Some(Stopwatch::new());
    }

    // -----------------------------------------------------------------
    // Dynamic fleet surgery (the serving layer's admission, preemption
    // and compaction paths). All three operations happen only *between*
    // rounds, where the solve state is exactly a post-FORGET snapshot.
    // -----------------------------------------------------------------

    /// Admit one problem into the session — before OR after stepping
    /// started. Before the first `step`/`run` this is [`Session::add`];
    /// afterwards the block joins the *running* fleet dynamically: the
    /// concatenated variable vector grows by the block's coordinates
    /// (started at the block's own unconstrained minimiser, exactly as a
    /// fresh solo solve), existing blocks' offsets, rows and duals are
    /// untouched, and a cached shard plan stays warm (membership did not
    /// change — the new block's rows only arrive with its first oracle
    /// round). The admitted block's trajectory is bit-identical to its
    /// solo solve (pinned in `tests/determinism.rs`).
    ///
    /// Panics when admitting a vector block mid-solve into an overlapped
    /// session (the overlap pipeline is single-block), or when the new
    /// block's structural knobs (`inner_sweeps`, `z_tol`) disagree with
    /// the running fleet's.
    pub fn admit<P: Problem<'a>>(&mut self, problem: P) -> Handle<P::Output> {
        if !self.built {
            return self.add(problem);
        }
        assert!(!self.cancelled, "Session::admit into a cancelled session");
        let handle = self.outputs.len();
        self.outputs.push(None);
        match problem.lower(&self.opts) {
            Lowered::Vector(part) => {
                assert!(
                    !self.opts.overlap,
                    "mid-solve admission of vector blocks requires a non-overlapped \
                     session (the overlap pipeline is single-block)"
                );
                if let Some(solver) = self.solver.as_ref() {
                    assert_eq!(
                        part.config.inner_sweeps, solver.config.inner_sweeps,
                        "admitted block {:?} disagrees with the running fleet on inner_sweeps",
                        part.name
                    );
                    assert!(
                        part.config.z_tol == solver.config.z_tol,
                        "admitted block {:?} disagrees with the running fleet on z_tol",
                        part.name
                    );
                }
                if self.solver.is_none() {
                    // First vector block of a (previously round-only or
                    // empty) built session: create the shared solver. As
                    // in `build`, the session does its own per-block
                    // trace/budget accounting.
                    let mut cfg = part.config.clone();
                    cfg.record_trace = false;
                    cfg.projection_budget = None;
                    self.solver =
                        Some(Solver::new(DiagonalQuadratic::new(Vec::new(), Vec::new()), cfg));
                }
                let solver = self.solver.as_mut().expect("solver just ensured above");
                let range = solver.append_variables(&part.f.d, &part.f.w);
                self.offsets.push(range.end);
                let interpret = part.interpret;
                let erased: BoxedInterpret<'a> =
                    Box::new(move |f, r| Box::new(interpret(f, r)) as Box<dyn Any>);
                self.blocks.push(VectorBlock {
                    name: part.name,
                    f: part.f,
                    oracle: part.oracle,
                    config: part.config,
                    interpret: Some(erased),
                    handle,
                    range,
                    iterations: 0,
                    converged: false,
                    done: false,
                    projections: 0,
                    last_dual_movement: f64::INFINITY,
                    trace: Vec::new(),
                    telemetry: Vec::new(),
                    phases: PhaseTimes::default(),
                    result: None,
                });
            }
            Lowered::Rounds(rp) => {
                let name = rp.name();
                self.rounds.push(RoundBlock {
                    name,
                    prob: Some(Box::new(RoundShim(rp))),
                    handle,
                    iterations: 0,
                    projections: 0,
                    done: false,
                    converged: false,
                    final_state: None,
                });
            }
        }
        self.finished = false;
        Handle::new(handle)
    }

    /// Checkpoint-and-detach a *live* block (the serving layer's
    /// preemption): its resumable state is captured into a
    /// [`BlockCheckpoint`], its rows are dropped from the shared set,
    /// and (for vector blocks) its coordinate range is compacted out of
    /// the concatenated vector — every later block's offsets, and all
    /// remembered indices above the range, slide down uniformly. The
    /// relabeling is injective, so support-disjointness is preserved and
    /// the shard plan survives through the stable-slot FORGET map plus
    /// the [`SweepExecutor::after_reoffset`](crate::core::engine::SweepExecutor::after_reoffset)
    /// adoption — no replan, and no block's own trajectory is perturbed.
    ///
    /// Capture a live block's resumable state WITHOUT detaching it —
    /// the durable-checkpoint path (`paf serve --state-dir`). Call at a
    /// round boundary (the same post-FORGET state [`Session::evict`]
    /// assumes) and the capture is exactly what `evict` would produce,
    /// so feeding it through [`Session::admit_resumed`] in a fresh
    /// process continues the block bit-identically to never having been
    /// interrupted. Unlike `evict`, the session is untouched and the
    /// block keeps stepping.
    ///
    /// `index` is [`Handle::index`]. Panics under the same conditions
    /// as [`Session::evict`].
    pub fn checkpoint_block(&self, index: usize) -> BlockCheckpoint {
        assert!(self.built, "Session::checkpoint_block before the first step()");
        if let Some(b) = self.blocks.iter().find(|b| b.handle == index) {
            assert!(
                !b.done,
                "Session::checkpoint_block: block {index} already finished — take() its output instead"
            );
            assert!(
                !self.opts.overlap,
                "checkpointing vector blocks from an overlapped session is not supported"
            );
            let range = b.range.clone();
            let solver = self.solver.as_ref().expect("vector fleet not built");
            let mut rows = Vec::new();
            for r in 0..solver.active.len() {
                let first = solver.active.view(r).indices[0] as usize;
                if range.contains(&first) {
                    let mut c = solver.active.to_constraint(r);
                    for i in &mut c.indices {
                        *i -= range.start as u32;
                    }
                    rows.push((c, solver.active.z(r)));
                }
            }
            return BlockCheckpoint {
                inner: BlockCkptInner::Vector {
                    x: solver.x[range].to_vec(),
                    rows,
                    iterations: b.iterations,
                    projections: b.projections,
                    last_dual_movement: b.last_dual_movement,
                    trace: b.trace.clone(),
                    phases: b.phases,
                },
            };
        }
        if let Some(rb) = self.rounds.iter().find(|r| r.handle == index) {
            assert!(
                !rb.done,
                "Session::checkpoint_block: block {index} already finished — take() its output instead"
            );
            let state = rb
                .prob
                .as_ref()
                .expect("live round block lost its problem")
                .snapshot_erased()
                .expect("this round-driven problem does not support checkpointing");
            return BlockCheckpoint {
                inner: BlockCkptInner::Round {
                    state,
                    iterations: rb.iterations,
                    projections: rb.projections,
                },
            };
        }
        panic!("Session::checkpoint_block: no live block with handle index {index}");
    }

    /// `index` is [`Handle::index`]. Panics if no live (not-done) block
    /// has that handle, if the session is overlapped, or (round-driven
    /// blocks) if the problem does not support checkpointing.
    pub fn evict(&mut self, index: usize) -> BlockCheckpoint {
        assert!(self.built, "Session::evict before the first step()");
        if let Some(bi) = self.blocks.iter().position(|b| b.handle == index) {
            assert!(
                !self.blocks[bi].done,
                "Session::evict: block {index} already finished — take() its output instead"
            );
            assert!(
                !self.opts.overlap,
                "evicting vector blocks from an overlapped session is not supported"
            );
            let (mut block, x, rows) = self.remove_vector_block(bi);
            return BlockCheckpoint {
                inner: BlockCkptInner::Vector {
                    x,
                    rows,
                    iterations: block.iterations,
                    projections: block.projections,
                    last_dual_movement: block.last_dual_movement,
                    trace: std::mem::take(&mut block.trace),
                    phases: block.phases,
                },
            };
        }
        if let Some(ri) = self.rounds.iter().position(|r| r.handle == index) {
            assert!(
                !self.rounds[ri].done,
                "Session::evict: block {index} already finished — take() its output instead"
            );
            let rb = self.rounds.remove(ri);
            let prob = rb.prob.expect("live round block lost its problem");
            let state = prob
                .snapshot_erased()
                .expect("this round-driven problem does not support checkpointing");
            return BlockCheckpoint {
                inner: BlockCkptInner::Round {
                    state,
                    iterations: rb.iterations,
                    projections: rb.projections,
                },
            };
        }
        panic!("Session::evict: no live block with handle index {index}");
    }

    /// Re-admit a previously evicted block and restore its state: the
    /// problem is lowered afresh (same problem, same options as the
    /// original admission), its new coordinate range takes the
    /// checkpointed iterate slice, and its remembered rows re-enter the
    /// shared set — in their original relative order, re-based to the
    /// new offset. Stepping on is bit-identical to the uninterrupted
    /// solve (pinned in `tests/determinism.rs`).
    pub fn admit_resumed<P: Problem<'a>>(
        &mut self,
        problem: P,
        ck: &BlockCheckpoint,
    ) -> Handle<P::Output> {
        self.build();
        let handle = self.admit(problem);
        match &ck.inner {
            BlockCkptInner::Vector {
                x,
                rows,
                iterations,
                projections,
                last_dual_movement,
                trace,
                phases,
            } => {
                let b = self
                    .blocks
                    .last_mut()
                    .expect("admit_resumed: vector checkpoint for a non-vector problem");
                assert_eq!(
                    b.handle, handle.idx,
                    "admit_resumed: vector checkpoint for a non-vector problem"
                );
                assert_eq!(
                    b.range.len(),
                    x.len(),
                    "admit_resumed: checkpoint dimension mismatch for block {:?}",
                    b.name
                );
                b.iterations = *iterations;
                b.projections = *projections;
                b.last_dual_movement = *last_dual_movement;
                b.trace = trace.clone();
                b.phases = *phases;
                let off = b.range.start as u32;
                let range = b.range.clone();
                let solver = self.solver.as_mut().expect("vector fleet not built");
                solver.x[range].copy_from_slice(x);
                let mut shifted = Constraint::new(Vec::new(), Vec::new(), 0.0);
                for (c, z) in rows {
                    shifted.indices.clear();
                    shifted.indices.extend(c.indices.iter().map(|&i| i + off));
                    shifted.coeffs.clear();
                    shifted.coeffs.extend_from_slice(&c.coeffs);
                    shifted.rhs = c.rhs;
                    let slot = solver.active.insert(&shifted);
                    solver.active.set_z(slot, *z);
                }
            }
            BlockCkptInner::Round { state, iterations, projections } => {
                let rb = self
                    .rounds
                    .last_mut()
                    .expect("admit_resumed: round checkpoint for a non-round problem");
                assert_eq!(
                    rb.handle, handle.idx,
                    "admit_resumed: round checkpoint for a non-round problem"
                );
                rb.iterations = *iterations;
                rb.projections = *projections;
                rb.prob
                    .as_mut()
                    .expect("live round block lost its problem")
                    .restore_erased(state);
            }
        }
        handle
    }

    /// Reclaim the coordinate ranges (and any leftover rows) of finished
    /// vector blocks, and drop finished round-driven blocks. Long-running
    /// serving calls this after completions so the concatenated vector
    /// does not grow without bound; outputs stay redeemable through
    /// [`Session::take`]. Returns the number of variables reclaimed.
    pub fn compact_finished(&mut self) -> usize {
        if !self.built {
            return 0;
        }
        let mut reclaimed = 0;
        while let Some(bi) = self.blocks.iter().position(|b| b.done) {
            let (_block, x, _rows) = self.remove_vector_block(bi);
            reclaimed += x.len();
        }
        self.rounds.retain(|r| !r.done);
        reclaimed
    }

    /// Detach vector block `bi` from the fleet: capture its slice of the
    /// iterate and its remembered rows (re-based to block-local indices,
    /// in slot order), drop those rows through the stable-slot FORGET
    /// path, then compact the block's coordinate range out of the
    /// concatenated vector and re-offset every later block.
    fn remove_vector_block(
        &mut self,
        bi: usize,
    ) -> (VectorBlock<'a>, Vec<f64>, Vec<(Constraint, f64)>) {
        let range = self.blocks[bi].range.clone();
        let len = range.len();
        let solver = self.solver.as_mut().expect("vector fleet not built");
        let mut rows = Vec::new();
        for r in 0..solver.active.len() {
            let first = solver.active.view(r).indices[0] as usize;
            if range.contains(&first) {
                let mut c = solver.active.to_constraint(r);
                for i in &mut c.indices {
                    *i -= range.start as u32;
                }
                rows.push((c, solver.active.z(r)));
                solver.active.set_z(r, 0.0);
            }
        }
        if !rows.is_empty() {
            // Post-round state is post-FORGET, so every *other* row has a
            // nonzero (and > z_tol) dual: only this block's rows drop,
            // and the shard plan follows through the stable-slot map.
            solver.forget();
        }
        let x = solver.x[range.clone()].to_vec();
        solver.remove_variable_range(range);
        let block = self.blocks.remove(bi);
        for b in &mut self.blocks[bi..] {
            b.range = b.range.start - len..b.range.end - len;
        }
        self.offsets.remove(bi + 1);
        for o in &mut self.offsets[bi + 1..] {
            *o -= len;
        }
        (block, x, rows)
    }
}

/// Capture a finished block's [`SolverResult`], interpret it into the
/// typed output, and emit its [`BlockDone`].
fn finalize_block(
    b: &mut VectorBlock<'_>,
    outputs: &mut [Option<Box<dyn Any>>],
    x: &[f64],
    active_constraints: usize,
    converged: bool,
    seconds: f64,
    done: &mut Vec<BlockDone>,
) {
    b.done = true;
    b.converged = converged;
    let result = SolverResult {
        x: x[b.range.clone()].to_vec(),
        iterations: b.iterations,
        converged,
        total_projections: b.projections,
        active_constraints,
        trace: std::mem::take(&mut b.trace),
        seconds,
        phases: b.phases,
        telemetry: std::mem::take(&mut b.telemetry),
    };
    b.result = Some(result.clone());
    let interpret = b.interpret.take().expect("block finalized twice");
    outputs[b.handle] = Some(interpret(&b.f, result));
    done.push(BlockDone {
        block: b.handle,
        name: b.name,
        converged,
        iterations: b.iterations,
        projections: b.projections,
    });
}

