//! Per-round coordinate movement tracking — the sweep→oracle feedback
//! channel of the incremental separation pipeline.
//!
//! Every projection that moves the iterate touches only its row's
//! support, and the engine already knows exactly which rows moved (the
//! serial dual bookkeeping of both executors). The [`MovementTracker`]
//! turns that knowledge into a *coordinate dirty log*: an epoch-stamped
//! bitmap (dedup within a sweep) feeding an append-only log of touched
//! coordinates. Incremental oracles take a **cursor** into the log when
//! they snapshot the iterate and later ask for every coordinate touched
//! since — a superset of the coordinates whose value actually changed,
//! which is the safe direction for cache invalidation.
//!
//! Correctness never *depends* on this tracker: consumers must hold a
//! snapshot of the iterate they cached against and fall back to an exact
//! element-wise diff whenever [`MovementTracker::moved_since`] declines
//! (log window evicted, tracking disabled, coordinates relabeled). The
//! tracker is the fast path that makes the common late-solve round — a
//! handful of moved coordinates — O(moved) instead of O(m).
//!
//! Lifecycle hooks keep the log honest across the engine's structural
//! operations: FORGET compaction renames *slots*, not coordinates, so it
//! needs no hook; fleet growth ([`MovementTracker::resize`]) keeps old
//! coordinates stable; an eviction's uniform relabeling
//! ([`MovementTracker::remove_range`]) invalidates every outstanding
//! cursor, because logged coordinates refer to the old labels.

use std::collections::VecDeque;
use std::ops::Range;

/// Default bound on logged coordinates (u32 each). When a round moves
/// more than this, the oldest window is evicted and consumers with
/// cursors before it fall back to their snapshot diff — which is the
/// right trade: a round that moved millions of coordinates is a round
/// where the incremental scan rescans nearly everything anyway.
pub const DEFAULT_MOVEMENT_LOG_CAPACITY: usize = 1 << 20;

/// Epoch-stamped coordinate dirty set with an append-only cursor log.
/// Owned by the `Solver`, filled by all sweep paths (sequential, the
/// sharded executor's serial bookkeeping barrier, and the engine sink's
/// on-find / box projections), drained by incremental oracles through
/// the `ProjectionSink` movement seam.
#[derive(Debug)]
pub struct MovementTracker {
    enabled: bool,
    /// `stamp[coord]` = epoch of the last mark (dedup within an epoch).
    stamp: Vec<u64>,
    epoch: u64,
    /// Touched coordinates, oldest first; `log[0]` is absolute index
    /// `log_start` in cursor space.
    log: VecDeque<u32>,
    log_start: u64,
    /// Total marks ever appended — the cursor space.
    appended: u64,
    capacity: usize,
}

impl MovementTracker {
    pub fn new(dim: usize, enabled: bool) -> MovementTracker {
        MovementTracker {
            enabled,
            stamp: vec![0; dim],
            epoch: 1,
            log: VecDeque::new(),
            log_start: 0,
            appended: 0,
            capacity: DEFAULT_MOVEMENT_LOG_CAPACITY,
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Permanently stop tracking (e.g. the configured sweep executor has
    /// no tracked path, so the log would silently under-report).
    /// Outstanding and future cursors all resolve to "not covered".
    pub fn disable(&mut self) {
        self.enabled = false;
        self.log.clear();
    }

    /// Record that `coord`'s value may have changed. O(1); deduplicated
    /// per epoch.
    #[inline]
    pub fn mark(&mut self, coord: u32) {
        if !self.enabled {
            return;
        }
        let c = coord as usize;
        if c >= self.stamp.len() || self.stamp[c] == self.epoch {
            return;
        }
        self.stamp[c] = self.epoch;
        self.log.push_back(coord);
        self.appended += 1;
        if self.log.len() > self.capacity {
            let drop = self.log.len() - self.capacity;
            self.log.drain(..drop);
            self.log_start += drop as u64;
        }
    }

    /// Mark a whole support (the moved row's indices).
    #[inline]
    pub fn mark_slice(&mut self, coords: &[u32]) {
        if !self.enabled {
            return;
        }
        for &c in coords {
            self.mark(c);
        }
    }

    /// Start a new dedup epoch (the solver calls this once per sweep —
    /// granularity only affects log size, never correctness: a
    /// coordinate marked in two epochs appears twice, and consumers
    /// treat the drained list as a set).
    pub fn advance_epoch(&mut self) {
        self.epoch += 1;
    }

    /// Cursor for "everything from now on" (`None` when disabled).
    /// Take it at the moment the iterate is snapshotted.
    pub fn cursor(&self) -> Option<u64> {
        self.enabled.then_some(self.appended)
    }

    /// Take a cursor AND start a new dedup epoch. This is the form
    /// consumers must use: epochs then never span a cursor, so a mark
    /// after the cursor can only be suppressed by an earlier mark of
    /// the same epoch — which is itself after the cursor — and the
    /// drained window stays a true superset of the coordinates moved
    /// since. (A plain [`MovementTracker::cursor`] taken mid-epoch
    /// could silently lose a post-cursor re-movement of a coordinate
    /// already stamped before it.)
    pub fn take_cursor(&mut self) -> Option<u64> {
        self.advance_epoch();
        self.cursor()
    }

    /// Append every coordinate marked since `cursor` to `out` (possibly
    /// with duplicates across epochs). Returns `false` — and appends
    /// nothing — when the window is not covered: tracking disabled, the
    /// log evicted past the cursor, or the cursor invalidated by a
    /// relabeling. Callers must then fall back to an exact diff.
    pub fn moved_since(&self, cursor: u64, out: &mut Vec<u32>) -> bool {
        if !self.enabled || cursor < self.log_start || cursor > self.appended {
            return false;
        }
        out.extend(self.log.iter().skip((cursor - self.log_start) as usize));
        true
    }

    /// Coordinates marked in the current log window (diagnostics).
    pub fn log_len(&self) -> usize {
        self.log.len()
    }

    /// Total marks ever appended (the cursor space). Telemetry diffs
    /// this across a round for the moved-coordinate fraction; dedup is
    /// per epoch, so it slightly over-counts across epochs.
    pub fn marks(&self) -> u64 {
        self.appended
    }

    /// Override the log budget (tests; the default is
    /// [`DEFAULT_MOVEMENT_LOG_CAPACITY`]).
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity.max(1);
    }

    /// Fleet growth: new coordinates were appended to the variable
    /// vector. Existing labels are untouched, so outstanding cursors
    /// stay valid.
    pub fn resize(&mut self, dim: usize) {
        self.stamp.resize(dim, 0);
    }

    /// Fleet eviction: `range` was removed and every higher coordinate
    /// slid down. Logged entries refer to the *old* labels, so every
    /// outstanding cursor is invalidated (consumers diff instead).
    pub fn remove_range(&mut self, range: Range<usize>) {
        let range = range.start.min(self.stamp.len())..range.end.min(self.stamp.len());
        self.stamp.drain(range);
        self.epoch += 1;
        self.invalidate();
    }

    /// Drop the log window so every *outstanding* cursor resolves to
    /// "not covered" (restore/relabeling paths); cursors taken after
    /// this call work normally.
    pub fn invalidate(&mut self) {
        self.log.clear();
        self.appended += 1;
        self.log_start = self.appended;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marks_flow_to_cursor_windows() {
        let mut t = MovementTracker::new(10, true);
        let c0 = t.cursor().unwrap();
        t.mark(3);
        t.mark(7);
        t.mark(3); // same epoch: deduped
        let mut out = Vec::new();
        assert!(t.moved_since(c0, &mut out));
        assert_eq!(out, vec![3, 7]);
        // A later cursor sees only later marks.
        let c1 = t.cursor().unwrap();
        t.advance_epoch();
        t.mark(3); // new epoch: logged again
        out.clear();
        assert!(t.moved_since(c1, &mut out));
        assert_eq!(out, vec![3]);
    }

    #[test]
    fn disabled_tracker_declines() {
        let mut t = MovementTracker::new(4, false);
        assert!(t.cursor().is_none());
        t.mark(1);
        let mut out = Vec::new();
        assert!(!t.moved_since(0, &mut out));
        // disable() mid-flight kills outstanding cursors too.
        let mut t = MovementTracker::new(4, true);
        let c = t.cursor().unwrap();
        t.mark(1);
        t.disable();
        assert!(!t.moved_since(c, &mut out));
        assert!(t.cursor().is_none());
    }

    #[test]
    fn capacity_eviction_invalidates_old_cursors_only() {
        let mut t = MovementTracker::new(100, true);
        t.set_capacity(4);
        let old = t.cursor().unwrap();
        for i in 0..3 {
            t.mark(i);
        }
        let recent = t.cursor().unwrap();
        for i in 3..8 {
            t.mark(i); // overflows the window; the oldest entries evict
        }
        let mut out = Vec::new();
        assert!(!t.moved_since(old, &mut out), "evicted window must decline");
        out.clear();
        assert!(t.moved_since(recent, &mut out), "recent window still covered");
        assert_eq!(out, vec![4, 5, 6, 7]);
    }

    #[test]
    fn invalidate_and_remove_range_kill_outstanding_cursors() {
        let mut t = MovementTracker::new(10, true);
        let c = t.cursor().unwrap();
        t.mark(2);
        t.invalidate();
        let mut out = Vec::new();
        assert!(!t.moved_since(c, &mut out), "invalidated window must decline");
        let c2 = t.cursor().unwrap();
        t.advance_epoch();
        t.mark(5);
        assert!(t.moved_since(c2, &mut out), "fresh cursors work after invalidate");
        assert_eq!(out, vec![5]);
        // remove_range: labels changed, so even fresh-looking windows die.
        let c3 = t.cursor().unwrap();
        t.remove_range(0..4);
        out.clear();
        assert!(!t.moved_since(c3, &mut out));
        // The stamp vector shrank with the coordinate space.
        t.advance_epoch();
        t.mark(9); // now out of range (dim is 6): ignored, no panic
        assert_eq!(t.log_len(), 0);
        t.mark(5);
        assert_eq!(t.log_len(), 1);
    }

    #[test]
    fn take_cursor_starts_a_fresh_epoch() {
        // Regression: a coordinate marked before the cursor and moved
        // AGAIN after it must appear in the window. A plain cursor taken
        // mid-epoch would let the dedup stamp suppress the re-mark.
        let mut t = MovementTracker::new(8, true);
        t.mark(3); // e.g. the round's first box pass
        let c = t.take_cursor().unwrap();
        t.mark(3); // the second box pass's rounding residue
        let mut out = Vec::new();
        assert!(t.moved_since(c, &mut out));
        assert_eq!(out, vec![3], "post-cursor re-movement must be logged");
    }

    #[test]
    fn resize_preserves_outstanding_cursors() {
        let mut t = MovementTracker::new(4, true);
        let c = t.cursor().unwrap();
        t.mark(1);
        t.resize(8);
        t.advance_epoch();
        t.mark(6);
        let mut out = Vec::new();
        assert!(t.moved_since(c, &mut out), "growth keeps old labels valid");
        assert_eq!(out, vec![1, 6]);
    }
}
