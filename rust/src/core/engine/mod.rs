//! The projection engine: pluggable executors for the Bregman projection
//! sweep over the remembered list `L^(ν)` (Algorithm 3, lines 2–6).
//!
//! The solver's hot loop is the sweep; this subsystem factors it behind
//! the [`SweepExecutor`] trait so the same outer loop can run
//!
//! - [`sequential::SequentialSweep`] — the exact Gauss–Seidel sweep in
//!   slot order, arithmetic-identical to the historical in-solver loop
//!   (and therefore bit-identical in its results);
//! - [`sharded::ShardedSweep`] — the Ruggles/Veldt/Gleich parallel
//!   scheme: rows are partitioned into support-disjoint shards by
//!   [`shards::ShardPlan`], shards execute one after another, and the
//!   rows *within* a shard are both projected **and applied**
//!   concurrently on the persistent worker pool (their projections
//!   commute and their writes are race-free because they touch disjoint
//!   coordinates of `x` — the scatter-safe
//!   `BregmanFunction::project_disjoint` path);
//! - the PJRT-batched executor in `coordinator::batch_project`, which
//!   gathers each shard into the padded `[B, K]` artifact layout instead
//!   of running native arithmetic.
//!
//! The shard plan is recomputed lazily: [`crate::core::ActiveSet`] bumps
//! a generation counter whenever membership changes, and FORGET hands the
//! executor a stable-slot compaction map so a pure forget remaps the
//! existing plan in O(rows) instead of replanning from scratch.
//!
//! The engine also feeds the separation oracle back: every sweep path
//! can mark the coordinates it moved into a [`MovementTracker`]
//! ([`SweepExecutor::sweep_tracked`]), which incremental oracles drain
//! through the `ProjectionSink` movement seam to skip sources whose
//! dependency ball saw no movement (see `problems::metric_oracle`).

pub mod lazy;
pub mod movement;
pub mod sequential;
pub mod sharded;
pub mod shards;

pub use lazy::{LazyScheduler, RowIndex};
pub use movement::{MovementTracker, DEFAULT_MOVEMENT_LOG_CAPACITY};
pub use sequential::SequentialSweep;
pub use sharded::{parallel_min_rows_default, ShardedSweep, PARALLEL_MIN_ROWS};
pub use shards::{ShardLimits, ShardPlan};

use super::active_set::ActiveSet;
use super::bregman::BregmanFunction;

/// Which sweep executor the solver runs (the `SolverConfig::sweep` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SweepStrategy {
    /// Exact sequential Gauss–Seidel in slot order — the default, and
    /// bit-identical to the historical solver loop.
    #[default]
    Sequential,
    /// Support-disjoint sharded parallel sweep. `threads == 0` means
    /// "auto" (`PAF_THREADS` or the machine's available parallelism).
    /// Results are deterministic: independent of the thread count.
    ShardedParallel {
        threads: usize,
    },
}

/// What one sweep did (the executor-side view of `IterStats`).
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepStats {
    /// Individual projections that moved `x` (sequential/sharded), or
    /// rows handed to the batched artifact (PJRT adapter).
    pub projections: usize,
    /// Total dual movement `Σ|c|` over the rows **this sweep** projected
    /// — reduced deterministically in slot order within each shard,
    /// shard by shard, so the sequential and sharded executors agree bit
    /// for bit. Covers exactly the executor's sweep, including any
    /// remembered box rows it visits; projections the engine sink
    /// performs *outside* the sweep (the on-find projection and the
    /// fused box pass during separation) are **not** included here —
    /// they count into `Solver::projections` and the movement tracker
    /// only.
    pub dual_movement: f64,
    /// Shards executed (1 for the sequential executor).
    pub shards: usize,
    /// Rows whose projection kernel actually ran this sweep (including
    /// zero-step visits). An eager sweep visits everything, so this
    /// equals `active.len()`; a lazy sweep visits fewer.
    pub rows_projected: usize,
    /// Rows the lazy scheduler skipped as provably zero-step (support
    /// unmoved since the row's last visit *and* last dual step zero).
    /// `rows_projected + rows_skipped == active.len()` for the native
    /// executors; always 0 in eager mode.
    pub rows_skipped: usize,
}

/// A projection-sweep executor over the remembered list.
///
/// One `sweep` call performs one full pass over rows `0..active.len()`:
/// for each row, the Bregman projection with dual correction
/// `c = min(z, θ)`, `x ← x'` with `∇f(x') − ∇f(x) = c·a`, `z ← z − c`.
/// Implementations may reorder rows (and run support-disjoint rows
/// concurrently) but must visit every row exactly once per sweep.
pub trait SweepExecutor<F: BregmanFunction> {
    /// Run one full sweep, updating `x` and the duals in place.
    fn sweep(&mut self, f: &F, x: &mut [f64], active: &mut ActiveSet) -> SweepStats;

    /// Like [`SweepExecutor::sweep`], additionally invoking
    /// `record(slot, movement)` for every row whose projection moved,
    /// with `movement = |c|` — the *exact* clamped dual step the engine
    /// applied — in the executor's deterministic serial bookkeeping
    /// order. This is the `Session` batch driver's per-block accounting
    /// channel: restricting the calls to one block's rows reproduces
    /// that block's solo projection count and dual-movement sum bit for
    /// bit (recomputing the movement from dual snapshots would not —
    /// `z − (z − c)` need not round back to `c`). Executors without
    /// recording support return `None` (the PJRT batch adapter).
    fn sweep_recorded(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        record: &mut dyn FnMut(u32, f64),
    ) -> Option<SweepStats> {
        let _ = (f, x, active, record);
        None
    }

    /// Movement-tracked sweep: like [`SweepExecutor::sweep`] (or, with
    /// `record`, [`SweepExecutor::sweep_recorded`]), additionally
    /// marking into `tracker` the support of every row whose projection
    /// moved — at the executor's serial bookkeeping point, so the mark
    /// order is the deterministic slot order and per-worker movement is
    /// effectively merged at the shard barrier. Marks are a superset of
    /// the coordinates whose value changed bit-wise (a nonzero dual step
    /// may still round to a no-op write), which is the safe direction
    /// for the incremental oracle's cache invalidation. Tracking is pure
    /// observation: the sweep arithmetic is untouched.
    ///
    /// Returns `None` when the executor has no tracked path (the PJRT
    /// batch adapter); the solver then permanently disables the tracker
    /// so stale movement windows can never under-report.
    fn sweep_tracked(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        tracker: &mut MovementTracker,
        record: Option<&mut dyn FnMut(u32, f64)>,
    ) -> Option<SweepStats> {
        let _ = (f, x, active, tracker, record);
        None
    }

    /// FORGET notification: `map[old_slot]` is the row's new slot, or
    /// [`crate::core::constraint::SLOT_DROPPED`] if it was forgotten;
    /// `instance` is the compacted set's `ActiveSet::instance_id` and the
    /// generations bracket the compaction (the set's value just before
    /// and just after it). Executors with cached plans keyed to
    /// (`instance`, `generation_before`) remap instead of replanning;
    /// both halves of the key matter — generations are per-instance
    /// counters, so a map from a *different* set could otherwise be
    /// applied to (or panic on) a foreign plan.
    fn after_forget(
        &mut self,
        map: &[u32],
        instance: u64,
        generation_before: u64,
        generation_after: u64,
    ) {
        let _ = (map, instance, generation_before, generation_after);
    }

    /// Fleet re-offset notification: the active set's variable indices
    /// were uniformly relabeled (a block's coordinate range was removed
    /// from the concatenated vector and the tail slid down — the
    /// `Session` eviction/compaction path). Slot ids, row order and
    /// support-disjointness are all preserved by the injective
    /// relabeling, so an executor holding a plan keyed to (`instance`,
    /// `generation_before`) may simply adopt `generation_after` instead
    /// of replanning. The default does nothing — a stale plan is then
    /// rebuilt lazily at the next sweep, which is always correct.
    fn after_reoffset(&mut self, instance: u64, generation_before: u64, generation_after: u64) {
        let _ = (instance, generation_before, generation_after);
    }

    /// Human-readable name for traces and benches.
    fn name(&self) -> &'static str;
}

/// Build the executor for a strategy with the default parallel-apply
/// threshold (`PAF_PARALLEL_MIN_ROWS` or the tuned constant) and lazy
/// sweep scheduling on.
pub fn executor_for<F: BregmanFunction>(strategy: SweepStrategy) -> Box<dyn SweepExecutor<F>> {
    executor_with::<F>(strategy, None, true)
}

/// Build the executor for a strategy; `parallel_min_rows` overrides the
/// sharded executor's serial/parallel threshold (`None` = env override or
/// [`PARALLEL_MIN_ROWS`]), and `lazy_sweep` toggles the movement-driven
/// scheduler on the tracked path (see [`lazy`]). Used by `Solver::new`
/// to thread the `SolverConfig` knobs through. Both are purely
/// scheduling choices — they never change results.
pub fn executor_with<F: BregmanFunction>(
    strategy: SweepStrategy,
    parallel_min_rows: Option<usize>,
    lazy_sweep: bool,
) -> Box<dyn SweepExecutor<F>> {
    match strategy {
        SweepStrategy::Sequential => Box::new(SequentialSweep::with_lazy(lazy_sweep)),
        SweepStrategy::ShardedParallel { threads } => {
            let mut exec = ShardedSweep::new(threads);
            if let Some(rows) = parallel_min_rows {
                exec.parallel_min_rows = rows.max(2);
            }
            exec.set_lazy(lazy_sweep);
            Box::new(exec)
        }
    }
}

/// The single-row projection kernel (Algorithm 3, lines 2–6): `θ`, the
/// dual clamp `c = min(z, θ)`, the primal move and the dual update, in
/// place. Returns `|c|`, or `0.0` when the projection was a no-op.
///
/// This is THE projection arithmetic — every native execution path
/// (sequential executor, sharded serial path, the PJRT adapter's tail,
/// `Solver::project_row`) calls this one function so the clamp rule and
/// accounting can never drift between them.
pub fn project_row_in_place<F: BregmanFunction>(
    f: &F,
    x: &mut [f64],
    active: &mut ActiveSet,
    r: usize,
) -> f64 {
    let view = active.view(r);
    let theta = f.theta(x, view);
    let z = active.z(r);
    let step = z.min(theta);
    if step == 0.0 {
        return 0.0;
    }
    f.apply(x, view, step);
    active.set_z(r, z - step);
    step.abs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bregman::DiagonalQuadratic;
    use crate::core::constraint::Constraint;
    use crate::util::Rng;

    /// Random overlapping constraint soup shared by the executor tests.
    fn random_active_set(seed: u64, dim: usize, rows: usize) -> ActiveSet {
        let mut rng = Rng::new(seed);
        let mut active = ActiveSet::new();
        while active.len() < rows {
            let nnz = 1 + rng.below(4);
            let idx: Vec<u32> =
                rng.sample_indices(dim, nnz).into_iter().map(|i| i as u32).collect();
            let coeffs: Vec<f64> = (0..nnz).map(|_| rng.uniform(-1.5, 1.5)).collect();
            let slot = active.insert(&Constraint::new(idx, coeffs, rng.uniform(-0.5, 0.5)));
            active.set_z(slot, rng.uniform(0.0, 0.4));
        }
        active
    }

    #[test]
    fn sharded_sweep_is_thread_count_invariant() {
        let dim = 40;
        let mut rng = Rng::new(5);
        let d: Vec<f64> = (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let f = DiagonalQuadratic::unweighted(d.clone());
        let base = random_active_set(6, dim, 60);
        let mut reference: Option<(Vec<f64>, Vec<f64>)> = None;
        for threads in [1usize, 2, 4, 7] {
            let mut active = base.clone();
            let mut x = d.clone();
            let mut exec = ShardedSweep::new(threads);
            exec.parallel_min_rows = 2; // force the parallel path
            let stats =
                SweepExecutor::<DiagonalQuadratic>::sweep(&mut exec, &f, &mut x, &mut active);
            assert!(stats.projections > 0);
            let zs: Vec<f64> = (0..active.len()).map(|r| active.z(r)).collect();
            match &reference {
                None => reference = Some((x, zs)),
                Some((rx, rz)) => {
                    // Bitwise: the schedule is deterministic by design.
                    assert_eq!(rx, &x, "x differs at {threads} threads");
                    assert_eq!(rz, &zs, "z differs at {threads} threads");
                }
            }
        }
    }

    #[test]
    fn sharded_matches_sequential_on_disjoint_rows() {
        // With mutually disjoint supports the Gauss–Seidel order is
        // irrelevant, so sequential and sharded must agree bitwise.
        let dim = 64;
        let mut rng = Rng::new(11);
        let d: Vec<f64> = (0..dim).map(|_| rng.uniform(-1.0, 3.0)).collect();
        let f = DiagonalQuadratic::unweighted(d.clone());
        let mut active = ActiveSet::new();
        for c in 0..16u32 {
            let base = c * 4;
            let slot = active.insert(&Constraint::cycle(base, &[base + 1, base + 2, base + 3]));
            active.set_z(slot, rng.uniform(0.0, 0.5));
        }
        let mut seq_active = active.clone();
        let mut seq_x = d.clone();
        let mut seq = SequentialSweep::new();
        let s1 =
            SweepExecutor::<DiagonalQuadratic>::sweep(&mut seq, &f, &mut seq_x, &mut seq_active);
        let mut par_active = active.clone();
        let mut par_x = d.clone();
        let mut par = ShardedSweep::new(4);
        par.parallel_min_rows = 2; // force the parallel path
        let s2 =
            SweepExecutor::<DiagonalQuadratic>::sweep(&mut par, &f, &mut par_x, &mut par_active);
        assert_eq!(seq_x, par_x);
        for r in 0..seq_active.len() {
            assert_eq!(seq_active.z(r), par_active.z(r), "z[{r}]");
        }
        assert_eq!(s1.projections, s2.projections);
        assert!((s1.dual_movement - s2.dual_movement).abs() < 1e-15);
    }

    #[test]
    fn reoffset_adoption_keeps_plan_current() {
        // Rows living above a coordinate range that gets removed: after
        // the uniform index shift the plan's shards (slot ids) are
        // structurally unchanged, so after_reoffset must adopt the new
        // generation instead of forcing a replan.
        let mut active = ActiveSet::new();
        for c in 0..6u32 {
            let base = 8 + c * 3;
            let slot = active.insert(&Constraint::cycle(base, &[base + 1, base + 2]));
            active.set_z(slot, 1.0);
        }
        let f = DiagonalQuadratic::unweighted(vec![0.5; 30]);
        let mut x = vec![0.5; 30];
        let mut exec = ShardedSweep::new(2);
        SweepExecutor::<DiagonalQuadratic>::sweep(&mut exec, &f, &mut x, &mut active);
        assert!(exec.plan().is_current(&active), "sweep must leave a current plan");
        // Variable range [0, 8) removed from the fleet vector.
        let (before, after) = active.shift_indices_from(8, 8);
        assert_ne!(before, after);
        assert!(!exec.plan().is_current(&active), "the shift staled the plan's key");
        SweepExecutor::<DiagonalQuadratic>::after_reoffset(
            &mut exec,
            active.instance_id(),
            before,
            after,
        );
        assert!(exec.plan().is_current(&active), "adoption must revalidate the plan");
        // A foreign instance must NOT be adopted: a fake further bump
        // under a wrong id would re-key the plan off the real set.
        SweepExecutor::<DiagonalQuadratic>::after_reoffset(&mut exec, 0xdead, after, after + 1);
        assert!(exec.plan().is_current(&active), "foreign adoption must be ignored");
    }

    #[test]
    fn tracked_sweep_marks_exactly_the_moved_supports() {
        let dim = 64;
        let mut rng = Rng::new(12);
        let d: Vec<f64> = (0..dim).map(|_| rng.uniform(-1.0, 3.0)).collect();
        let f = DiagonalQuadratic::unweighted(d.clone());
        let mut active = ActiveSet::new();
        for c in 0..16u32 {
            let base = c * 4;
            let slot =
                active.insert(&Constraint::cycle(base, &[base + 1, base + 2, base + 3]));
            active.set_z(slot, rng.uniform(0.0, 0.5));
        }
        for strategy in
            [SweepStrategy::Sequential, SweepStrategy::ShardedParallel { threads: 3 }]
        {
            let mut exec = executor_for::<DiagonalQuadratic>(strategy);
            let mut x = d.clone();
            let mut set = active.clone();
            let mut tracker = MovementTracker::new(dim, true);
            let cursor = tracker.cursor().unwrap();
            let mut moved_rows: Vec<u32> = Vec::new();
            let stats = exec
                .sweep_tracked(
                    &f,
                    &mut x,
                    &mut set,
                    &mut tracker,
                    Some(&mut |slot, _| moved_rows.push(slot)),
                )
                .expect("built-in executors must support tracked sweeps");
            assert_eq!(stats.projections, moved_rows.len(), "{strategy:?}");
            assert!(stats.projections > 0, "{strategy:?}: nothing moved");
            // The tracker must hold exactly the union of the moved rows'
            // supports — no more (untouched coords) and no less (every
            // moved coordinate is in some moved row's support).
            let mut expected: Vec<u32> = moved_rows
                .iter()
                .flat_map(|&r| set.view(r as usize).indices.to_vec())
                .collect();
            expected.sort_unstable();
            expected.dedup();
            let mut got = Vec::new();
            assert!(tracker.moved_since(cursor, &mut got), "window must be covered");
            got.sort_unstable();
            got.dedup();
            assert_eq!(expected, got, "{strategy:?}: marked set diverges");
        }
    }

    #[test]
    fn lazy_sweeps_match_eager_and_skip_settled_rows() {
        // Disjoint clamped rows settle in two sweeps: sweep 0 spends the
        // whole dual (z < θ), sweep 1 re-visits them (their own support
        // moved) and arms on the exact zero step, and from sweep 2 on
        // the lazy scheduler skips every row while the eager executor
        // keeps visiting all of them.
        let dim = 16usize;
        let f = DiagonalQuadratic::unweighted(vec![0.0; dim]);
        let mut base = ActiveSet::new();
        for i in 0..(dim as u32) / 2 {
            let slot =
                base.insert(&Constraint::new(vec![2 * i, 2 * i + 1], vec![1.0, 1.0], 1.0));
            base.set_z(slot, 0.1);
        }
        let n = base.len();
        for strategy in
            [SweepStrategy::Sequential, SweepStrategy::ShardedParallel { threads: 3 }]
        {
            let mut eager = executor_with::<DiagonalQuadratic>(strategy, Some(2), false);
            let mut lazy = executor_with::<DiagonalQuadratic>(strategy, Some(2), true);
            let (mut ex, mut lx) = (vec![0.0; dim], vec![0.0; dim]);
            let (mut eset, mut lset) = (base.clone(), base.clone());
            let mut et = MovementTracker::new(dim, true);
            let mut lt = MovementTracker::new(dim, true);
            for (sweep, &skips) in [0usize, 0, n, n].iter().enumerate() {
                let es = eager.sweep_tracked(&f, &mut ex, &mut eset, &mut et, None).unwrap();
                let ls = lazy.sweep_tracked(&f, &mut lx, &mut lset, &mut lt, None).unwrap();
                assert_eq!(ex, lx, "{strategy:?} sweep {sweep}: x diverged");
                for r in 0..n {
                    assert_eq!(eset.z(r), lset.z(r), "{strategy:?} sweep {sweep}: z[{r}]");
                }
                assert_eq!(es.projections, ls.projections, "{strategy:?} sweep {sweep}");
                assert_eq!(es.dual_movement, ls.dual_movement, "{strategy:?} sweep {sweep}");
                assert_eq!(es.rows_projected, n, "{strategy:?}: eager visits everything");
                assert_eq!(es.rows_skipped, 0, "{strategy:?}: eager never skips");
                assert_eq!(ls.rows_skipped, skips, "{strategy:?} sweep {sweep}: skips");
                assert_eq!(
                    ls.rows_projected + ls.rows_skipped,
                    n,
                    "{strategy:?} sweep {sweep}: visit/skip partition"
                );
            }
        }
    }

    #[test]
    fn lazy_sweeps_are_bit_identical_on_overlapping_soup() {
        // Overlapping supports exercise the intra-sweep dirty channel
        // (an earlier row's move must unskip later rows sharing support).
        // Lazy and eager must agree bitwise in x, every dual, the stats
        // and the recording channel, sweep after sweep.
        let dim = 40;
        let mut rng = Rng::new(7);
        let d: Vec<f64> = (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let f = DiagonalQuadratic::unweighted(d.clone());
        // Random soup on coords 0..36 plus two isolated clamped rows on
        // 36..40 that provably settle (z < θ) — so at least those two
        // must be skipped in the later sweeps.
        let mut base = random_active_set(8, dim - 4, 60);
        for lo in [36u32, 38] {
            let slot = base.insert(&Constraint::new(vec![lo, lo + 1], vec![1.0, 1.0], 10.0));
            base.set_z(slot, 0.05);
        }
        for strategy in
            [SweepStrategy::Sequential, SweepStrategy::ShardedParallel { threads: 4 }]
        {
            let mut eager = executor_with::<DiagonalQuadratic>(strategy, Some(2), false);
            let mut lazy = executor_with::<DiagonalQuadratic>(strategy, Some(2), true);
            let (mut ex, mut lx) = (d.clone(), d.clone());
            let (mut eset, mut lset) = (base.clone(), base.clone());
            let mut et = MovementTracker::new(dim, true);
            let mut lt = MovementTracker::new(dim, true);
            let mut skipped_total = 0usize;
            for sweep in 0..8 {
                let mut erec: Vec<(u32, f64)> = Vec::new();
                let mut lrec: Vec<(u32, f64)> = Vec::new();
                let es = eager
                    .sweep_tracked(
                        &f,
                        &mut ex,
                        &mut eset,
                        &mut et,
                        Some(&mut |slot, m| erec.push((slot, m))),
                    )
                    .unwrap();
                let ls = lazy
                    .sweep_tracked(
                        &f,
                        &mut lx,
                        &mut lset,
                        &mut lt,
                        Some(&mut |slot, m| lrec.push((slot, m))),
                    )
                    .unwrap();
                assert_eq!(ex, lx, "{strategy:?} sweep {sweep}: x diverged");
                for r in 0..eset.len() {
                    assert_eq!(eset.z(r), lset.z(r), "{strategy:?} sweep {sweep}: z[{r}]");
                }
                assert_eq!(erec, lrec, "{strategy:?} sweep {sweep}: recording channel");
                assert_eq!(es.projections, ls.projections, "{strategy:?} sweep {sweep}");
                assert_eq!(es.dual_movement, ls.dual_movement, "{strategy:?} sweep {sweep}");
                assert_eq!(
                    ls.rows_projected + ls.rows_skipped,
                    eset.len(),
                    "{strategy:?} sweep {sweep}: visit/skip partition"
                );
                skipped_total += ls.rows_skipped;
            }
            assert!(
                skipped_total > 0,
                "{strategy:?}: eight sweeps settled no row — the lazy path never engaged"
            );
        }
    }

    #[test]
    fn executor_factory_names() {
        let seq = executor_for::<DiagonalQuadratic>(SweepStrategy::Sequential);
        assert_eq!(seq.name(), "sequential");
        let par = executor_for::<DiagonalQuadratic>(SweepStrategy::ShardedParallel { threads: 2 });
        assert_eq!(par.name(), "sharded-parallel");
    }
}
