//! Greedy support-disjoint shard planning over the remembered list.
//!
//! Two rows *conflict* when their index sets intersect; projections onto
//! non-conflicting rows commute (they read and write disjoint coordinates
//! of `x`), so any independent set of the conflict graph can be projected
//! concurrently with a result identical to processing it sequentially in
//! any order. The planner greedily colors rows in slot order with the
//! epoch-marker trick (one `u32` per variable, no clearing between
//! shards): repeated first-fit passes, each pass claiming the rows whose
//! support is still free this epoch. Each pass places at least one row,
//! so planning terminates; rows still unplaced after `max_shards` passes
//! land in a sequential `tail` (adversarial conflict chains degrade to
//! Gauss–Seidel instead of exploding the shard count).
//!
//! The plan is keyed to [`ActiveSet::generation`]: membership changes
//! invalidate it, but a FORGET compaction only *removes* rows, so
//! [`ShardPlan::remap_after_forget`] rewrites slot ids through the
//! stable-slot compaction map in O(rows) — disjointness is preserved
//! under taking subsets.

use crate::core::active_set::ActiveSet;
use crate::core::constraint::SLOT_DROPPED;

/// Planner limits; the native sharded executor uses [`ShardLimits::none`],
/// the PJRT batch adapter caps shards at the artifact's `[B, K]` shape.
#[derive(Debug, Clone, Copy)]
pub struct ShardLimits {
    /// Disjoint passes before the remainder is dumped into the tail.
    pub max_shards: usize,
    /// Rows per shard (the artifact batch dimension `B`).
    pub max_shard_rows: usize,
    /// Rows with more nonzeros than this are excluded from shards
    /// entirely (the artifact support dimension `K`) and reported in
    /// [`ShardPlan::oversized`].
    pub max_row_nnz: usize,
}

impl ShardLimits {
    /// No artifact-shape limits; shard-count cap keeps planning linear.
    pub fn none() -> ShardLimits {
        ShardLimits { max_shards: 64, max_shard_rows: usize::MAX, max_row_nnz: usize::MAX }
    }

    /// Limits for a padded `[b, k]` projection artifact.
    pub fn batched(b: usize, k: usize) -> ShardLimits {
        ShardLimits { max_shards: 4096, max_shard_rows: b, max_row_nnz: k }
    }
}

/// A partition of the remembered rows into support-disjoint shards, plus
/// a sequential tail and the rows excluded as oversized.
#[derive(Debug, Clone, Default)]
pub struct ShardPlan {
    /// Support-disjoint row groups, each safe to project concurrently.
    pub shards: Vec<Vec<u32>>,
    /// Rows unplaced after `max_shards` passes — must run sequentially.
    pub tail: Vec<u32>,
    /// Rows whose support exceeds `max_row_nnz` (PJRT adapter only; the
    /// caller is responsible for covering them natively).
    pub oversized: Vec<u32>,
    /// `ActiveSet::generation` this plan was built against.
    generation: u64,
    /// `ActiveSet::instance_id` this plan was built against (0 = none).
    instance: u64,
    /// Reused epoch-marker buffer (one entry per variable index).
    owner: Vec<u32>,
    epoch: u32,
}

impl ShardPlan {
    pub fn new() -> ShardPlan {
        ShardPlan::default()
    }

    /// Is this plan current for `active`? The key is the pair
    /// (`instance_id`, `generation`): generations are per-instance
    /// counters, so without the process-unique instance id a caller
    /// swapping in a *different* `ActiveSet` (the solver's `active`
    /// field is public) could alias a stale plan whose shards are not
    /// support-disjoint for the new set — under the parallel apply that
    /// would be a data race, not just wrong numbers. The row-count check
    /// stays as a cheap sanity belt.
    pub fn is_current(&self, active: &ActiveSet) -> bool {
        self.instance == active.instance_id()
            && self.generation == active.generation()
            && self.planned_rows() + self.oversized.len() == active.len()
    }

    /// The generation this plan was built against.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The `ActiveSet::instance_id` this plan was built against
    /// (0 = no set yet).
    pub fn instance(&self) -> u64 {
        self.instance
    }

    /// Rows covered by the plan (shards + tail; excludes oversized).
    pub fn planned_rows(&self) -> usize {
        self.shards.iter().map(Vec::len).sum::<usize>() + self.tail.len()
    }

    /// Rebuild from scratch for the current contents of `active`.
    /// `dim` is the length of `x` (an upper bound on variable indices).
    pub fn rebuild(&mut self, active: &ActiveSet, dim: usize, limits: &ShardLimits) {
        self.shards.clear();
        self.tail.clear();
        self.oversized.clear();
        if self.owner.len() < dim {
            self.owner.resize(dim, self.epoch);
        }
        let n = active.len();
        let mut queue: Vec<u32> = Vec::with_capacity(n);
        for r in 0..n {
            if active.view(r).indices.len() > limits.max_row_nnz {
                self.oversized.push(r as u32);
            } else {
                queue.push(r as u32);
            }
        }
        let mut leftover: Vec<u32> = Vec::new();
        while !queue.is_empty() {
            if self.shards.len() == limits.max_shards {
                self.tail.append(&mut queue);
                break;
            }
            // Epoch wrap: reset markers once per ~4G passes.
            if self.epoch == u32::MAX {
                self.owner.iter_mut().for_each(|o| *o = 0);
                self.epoch = 0;
            }
            self.epoch += 1;
            let epoch = self.epoch;
            let mut shard: Vec<u32> = Vec::new();
            for &r in &queue {
                if shard.len() == limits.max_shard_rows {
                    leftover.push(r);
                    continue;
                }
                let v = active.view(r as usize);
                if v.indices.iter().any(|&i| self.owner[i as usize] == epoch) {
                    leftover.push(r);
                } else {
                    for &i in v.indices {
                        self.owner[i as usize] = epoch;
                    }
                    shard.push(r);
                }
            }
            debug_assert!(!shard.is_empty(), "a planning pass must place >= 1 row");
            self.shards.push(shard);
            std::mem::swap(&mut queue, &mut leftover);
            leftover.clear();
        }
        self.generation = active.generation();
        self.instance = active.instance_id();
    }

    /// Adopt a new generation without replanning. Valid ONLY when the
    /// membership change behind the bump kept every slot id, the row
    /// order, and pairwise support-disjointness intact — i.e. a uniform
    /// injective relabeling of the variable indices (the `Session`
    /// fleet's block-removal re-offset). Shards store slot ids, not
    /// indices, so the plan's structure is untouched by such a change.
    pub fn adopt_generation(&mut self, generation: u64) {
        self.generation = generation;
    }

    /// Cheap update after FORGET: rewrite every row id through the
    /// stable-slot compaction `map` (`SLOT_DROPPED` = forgotten), drop
    /// emptied shards, and adopt the post-compaction `generation`.
    /// Subsets of disjoint shards stay disjoint, and since FORGET only
    /// removes rows the remapped plan still covers every surviving slot.
    pub fn remap_after_forget(&mut self, map: &[u32], generation: u64) {
        let remap = |rows: &mut Vec<u32>| {
            rows.retain_mut(|r| {
                let new = map[*r as usize];
                *r = new;
                new != SLOT_DROPPED
            });
        };
        for shard in &mut self.shards {
            remap(shard);
        }
        self.shards.retain(|s| !s.is_empty());
        remap(&mut self.tail);
        remap(&mut self.oversized);
        self.generation = generation;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::constraint::Constraint;
    use crate::util::Rng;

    fn assert_disjoint_and_covering(plan: &ShardPlan, active: &ActiveSet) {
        let mut seen = vec![false; active.len()];
        for shard in &plan.shards {
            let mut used: std::collections::HashSet<u32> = std::collections::HashSet::new();
            for &r in shard {
                assert!(!seen[r as usize], "row {r} planned twice");
                seen[r as usize] = true;
                for &i in active.view(r as usize).indices {
                    assert!(used.insert(i), "index {i} reused inside a shard");
                }
            }
        }
        for &r in plan.tail.iter().chain(&plan.oversized) {
            assert!(!seen[r as usize], "row {r} planned twice");
            seen[r as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "some row left unplanned");
    }

    fn soup(seed: u64, dim: usize, rows: usize) -> ActiveSet {
        let mut rng = Rng::new(seed);
        let mut active = ActiveSet::new();
        while active.len() < rows {
            let nnz = 1 + rng.below(5);
            let idx: Vec<u32> =
                rng.sample_indices(dim, nnz).into_iter().map(|i| i as u32).collect();
            let slot =
                active.insert(&Constraint::new(idx, vec![1.0; nnz], rng.uniform(-1.0, 1.0)));
            active.set_z(slot, 1.0);
        }
        active
    }

    #[test]
    fn plan_is_disjoint_and_covers_all_rows() {
        for seed in 0..8u64 {
            let active = soup(seed, 30, 40);
            let mut plan = ShardPlan::new();
            plan.rebuild(&active, 30, &ShardLimits::none());
            assert_disjoint_and_covering(&plan, &active);
            assert!(plan.is_current(&active));
        }
    }

    #[test]
    fn fully_disjoint_rows_form_one_shard() {
        let mut active = ActiveSet::new();
        for c in 0..10u32 {
            let base = c * 3;
            let slot = active.insert(&Constraint::cycle(base, &[base + 1, base + 2]));
            active.set_z(slot, 1.0);
        }
        let mut plan = ShardPlan::new();
        plan.rebuild(&active, 30, &ShardLimits::none());
        assert_eq!(plan.shards.len(), 1);
        assert_eq!(plan.shards[0].len(), 10);
        assert!(plan.tail.is_empty());
    }

    #[test]
    fn max_shards_cap_spills_to_tail() {
        // A clique on one shared index: every row conflicts with every
        // other, so each pass places exactly one row.
        let mut active = ActiveSet::new();
        for c in 0..10u32 {
            let slot = active.insert(&Constraint::new(vec![0, c + 1], vec![1.0, -1.0], 0.0));
            active.set_z(slot, 1.0);
        }
        let limits = ShardLimits { max_shards: 3, ..ShardLimits::none() };
        let mut plan = ShardPlan::new();
        plan.rebuild(&active, 16, &limits);
        assert_eq!(plan.shards.len(), 3);
        assert_eq!(plan.tail.len(), 7);
        assert_disjoint_and_covering(&plan, &active);
    }

    #[test]
    fn batched_limits_respected() {
        let active = soup(3, 50, 60);
        let mut plan = ShardPlan::new();
        plan.rebuild(&active, 50, &ShardLimits::batched(4, 3));
        for shard in &plan.shards {
            assert!(shard.len() <= 4);
            for &r in shard {
                assert!(active.view(r as usize).indices.len() <= 3);
            }
        }
        for &r in &plan.oversized {
            assert!(active.view(r as usize).indices.len() > 3);
        }
        assert_disjoint_and_covering(&plan, &active);
    }

    #[test]
    fn plan_is_not_aliased_by_a_different_set_with_equal_generation() {
        // Two independently built sets with identical generation and row
        // count: only the process-unique instance id tells them apart,
        // and reusing a plan across them would hand non-disjoint rows to
        // the parallel apply.
        let a = soup(1, 30, 20);
        let b = soup(2, 30, 20);
        assert_eq!(a.generation(), b.generation());
        assert_eq!(a.len(), b.len());
        let mut plan = ShardPlan::new();
        plan.rebuild(&a, 30, &ShardLimits::none());
        assert!(plan.is_current(&a));
        assert!(!plan.is_current(&b), "different instance must invalidate the plan");
        // Clones diverge independently, so they get a fresh id too.
        let c = a.clone();
        assert!(!plan.is_current(&c), "a clone must not alias its source's plan");
    }

    #[test]
    fn remap_after_forget_tracks_compaction() {
        let mut active = soup(9, 25, 30);
        let mut plan = ShardPlan::new();
        plan.rebuild(&active, 25, &ShardLimits::none());
        // Zero out every third dual and forget.
        for r in 0..active.len() {
            if r % 3 == 0 {
                active.set_z(r, 0.0);
            }
        }
        let mut map = Vec::new();
        let dropped = active.forget_inactive_with_map(&mut map);
        assert!(dropped > 0);
        plan.remap_after_forget(&map, active.generation());
        assert!(plan.is_current(&active));
        assert_eq!(plan.planned_rows() + plan.oversized.len(), active.len());
        assert_disjoint_and_covering(&plan, &active);
    }
}
