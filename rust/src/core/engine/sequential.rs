//! The exact sequential Gauss–Seidel sweep (the historical solver loop).

use super::lazy::LazyScheduler;
use super::movement::MovementTracker;
use super::{project_row_in_place, SweepExecutor, SweepStats};
use crate::core::active_set::ActiveSet;
use crate::core::bregman::BregmanFunction;

/// Projects rows `0..len` in slot order, each against the `x` already
/// updated by its predecessors. Arithmetic-identical to the pre-engine
/// `Solver::project_sweep`, so `SweepStrategy::Sequential` reproduces the
/// historical results bit for bit.
///
/// On the tracked path the embedded [`LazyScheduler`] may elide rows
/// that are provably zero-step no-ops (see [`super::lazy`]); elision is
/// exact, so the lazy sequential sweep is still bit-identical to the
/// eager one. Skipping never reorders: a Gauss–Seidel chain's rows do
/// not commute, so the visited rows keep strict slot order.
#[derive(Debug, Clone)]
pub struct SequentialSweep {
    lazy: LazyScheduler,
}

impl Default for SequentialSweep {
    fn default() -> Self {
        SequentialSweep::new()
    }
}

impl SequentialSweep {
    /// Lazy scheduling on (exact, so on is the safe default).
    pub fn new() -> SequentialSweep {
        SequentialSweep::with_lazy(true)
    }

    pub fn with_lazy(lazy: bool) -> SequentialSweep {
        SequentialSweep { lazy: LazyScheduler::new(lazy) }
    }

    /// Toggle the lazy scheduler (the `SolverConfig::lazy_sweep` knob).
    pub fn set_lazy(&mut self, on: bool) {
        self.lazy.set_enabled(on);
    }
}

impl SequentialSweep {
    /// The one sweep loop, monomorphized over the recorder so the plain
    /// path keeps its exact historical shape (the no-op recorder
    /// compiles away). Movement marks happen right where the row's dual
    /// bookkeeping does — tracking observes, never reorders.
    fn sweep_impl<F: BregmanFunction>(
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        mut tracker: Option<&mut MovementTracker>,
        mut record: impl FnMut(u32, f64),
    ) -> SweepStats {
        let mut shard_span = crate::obs::span(crate::obs::SpanKind::Shard);
        let mut stats = SweepStats { shards: 1, ..SweepStats::default() };
        stats.rows_projected = active.len();
        for r in 0..active.len() {
            let moved = project_row_in_place(f, x, active, r);
            if moved != 0.0 {
                stats.projections += 1;
                stats.dual_movement += moved;
                record(r as u32, moved);
                if let Some(t) = tracker.as_deref_mut() {
                    t.mark_slice(active.view(r).indices);
                }
            }
        }
        if let Some(g) = shard_span.as_mut() {
            g.counts(stats.rows_projected as u64, stats.projections as u64);
        }
        stats
    }

    /// The lazy tracked sweep: same slot order, but rows the scheduler
    /// proves zero-step are elided. Identical `x`/duals/stats to
    /// [`SequentialSweep::sweep_impl`] by the skip-rule exactness — a
    /// skipped row would have contributed nothing to any of them.
    fn lazy_sweep_impl<F: BregmanFunction>(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        tracker: &mut MovementTracker,
        mut record: impl FnMut(u32, f64),
    ) -> SweepStats {
        let mut shard_span = crate::obs::span(crate::obs::SpanKind::Shard);
        let lazy = &mut self.lazy;
        let allow_skip = lazy.begin_sweep(active, x.len(), tracker);
        let mut stats = SweepStats { shards: 1, ..SweepStats::default() };
        for r in 0..active.len() {
            if allow_skip && lazy.can_skip(r) {
                stats.rows_skipped += 1;
                continue;
            }
            stats.rows_projected += 1;
            let moved = project_row_in_place(f, x, active, r);
            lazy.visited(r, moved);
            if moved != 0.0 {
                stats.projections += 1;
                stats.dual_movement += moved;
                record(r as u32, moved);
                tracker.mark_slice(active.view(r).indices);
                // Intra-sweep channel: later rows sharing support must
                // not be skipped against this row's pre-move state.
                lazy.note_moved(active.view(r).indices);
            }
        }
        lazy.end_sweep(tracker);
        if let Some(g) = shard_span.as_mut() {
            g.counts(stats.rows_projected as u64, stats.projections as u64);
        }
        stats
    }
}

impl<F: BregmanFunction> SweepExecutor<F> for SequentialSweep {
    fn sweep(&mut self, f: &F, x: &mut [f64], active: &mut ActiveSet) -> SweepStats {
        // Untracked sweeps mutate state the scheduler cannot see.
        self.lazy.poison();
        SequentialSweep::sweep_impl(f, x, active, None, |_, _| {})
    }

    fn sweep_recorded(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        record: &mut dyn FnMut(u32, f64),
    ) -> Option<SweepStats> {
        self.lazy.poison();
        Some(SequentialSweep::sweep_impl(f, x, active, None, record))
    }

    fn sweep_tracked(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        tracker: &mut MovementTracker,
        mut record: Option<&mut dyn FnMut(u32, f64)>,
    ) -> Option<SweepStats> {
        Some(if self.lazy.is_on() {
            self.lazy_sweep_impl(f, x, active, tracker, |slot, moved| {
                if let Some(r) = record.as_mut() {
                    r(slot, moved);
                }
            })
        } else {
            SequentialSweep::sweep_impl(f, x, active, Some(tracker), |slot, moved| {
                if let Some(r) = record.as_mut() {
                    r(slot, moved);
                }
            })
        })
    }

    fn after_forget(
        &mut self,
        map: &[u32],
        instance: u64,
        generation_before: u64,
        generation_after: u64,
    ) {
        self.lazy.after_forget(map, instance, generation_before, generation_after);
    }

    fn after_reoffset(&mut self, instance: u64, generation_before: u64, generation_after: u64) {
        self.lazy.after_reoffset(instance, generation_before, generation_after);
    }

    fn name(&self) -> &'static str {
        "sequential"
    }
}
