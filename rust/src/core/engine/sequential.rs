//! The exact sequential Gauss–Seidel sweep (the historical solver loop).

use super::{project_row_in_place, SweepExecutor, SweepStats};
use crate::core::active_set::ActiveSet;
use crate::core::bregman::BregmanFunction;

/// Projects rows `0..len` in slot order, each against the `x` already
/// updated by its predecessors. Arithmetic-identical to the pre-engine
/// `Solver::project_sweep`, so `SweepStrategy::Sequential` reproduces the
/// historical results bit for bit.
#[derive(Debug, Default, Clone)]
pub struct SequentialSweep;

impl SequentialSweep {
    pub fn new() -> SequentialSweep {
        SequentialSweep
    }
}

impl<F: BregmanFunction> SweepExecutor<F> for SequentialSweep {
    fn sweep(&mut self, f: &F, x: &mut [f64], active: &mut ActiveSet) -> SweepStats {
        let mut stats = SweepStats { shards: 1, ..SweepStats::default() };
        for r in 0..active.len() {
            let moved = project_row_in_place(f, x, active, r);
            if moved != 0.0 {
                stats.projections += 1;
                stats.dual_movement += moved;
            }
        }
        stats
    }

    fn name(&self) -> &'static str {
        "sequential"
    }
}
