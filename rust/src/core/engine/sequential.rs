//! The exact sequential Gauss–Seidel sweep (the historical solver loop).

use super::movement::MovementTracker;
use super::{project_row_in_place, SweepExecutor, SweepStats};
use crate::core::active_set::ActiveSet;
use crate::core::bregman::BregmanFunction;

/// Projects rows `0..len` in slot order, each against the `x` already
/// updated by its predecessors. Arithmetic-identical to the pre-engine
/// `Solver::project_sweep`, so `SweepStrategy::Sequential` reproduces the
/// historical results bit for bit.
#[derive(Debug, Default, Clone)]
pub struct SequentialSweep;

impl SequentialSweep {
    pub fn new() -> SequentialSweep {
        SequentialSweep
    }
}

impl SequentialSweep {
    /// The one sweep loop, monomorphized over the recorder so the plain
    /// path keeps its exact historical shape (the no-op recorder
    /// compiles away). Movement marks happen right where the row's dual
    /// bookkeeping does — tracking observes, never reorders.
    fn sweep_impl<F: BregmanFunction>(
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        mut tracker: Option<&mut MovementTracker>,
        mut record: impl FnMut(u32, f64),
    ) -> SweepStats {
        let mut stats = SweepStats { shards: 1, ..SweepStats::default() };
        for r in 0..active.len() {
            let moved = project_row_in_place(f, x, active, r);
            if moved != 0.0 {
                stats.projections += 1;
                stats.dual_movement += moved;
                record(r as u32, moved);
                if let Some(t) = tracker.as_deref_mut() {
                    t.mark_slice(active.view(r).indices);
                }
            }
        }
        stats
    }
}

impl<F: BregmanFunction> SweepExecutor<F> for SequentialSweep {
    fn sweep(&mut self, f: &F, x: &mut [f64], active: &mut ActiveSet) -> SweepStats {
        SequentialSweep::sweep_impl(f, x, active, None, |_, _| {})
    }

    fn sweep_recorded(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        record: &mut dyn FnMut(u32, f64),
    ) -> Option<SweepStats> {
        Some(SequentialSweep::sweep_impl(f, x, active, None, record))
    }

    fn sweep_tracked(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        tracker: &mut MovementTracker,
        mut record: Option<&mut dyn FnMut(u32, f64)>,
    ) -> Option<SweepStats> {
        Some(SequentialSweep::sweep_impl(f, x, active, Some(tracker), |slot, moved| {
            if let Some(r) = record.as_mut() {
                r(slot, moved);
            }
        }))
    }

    fn name(&self) -> &'static str {
        "sequential"
    }
}
