//! The exact sequential Gauss–Seidel sweep (the historical solver loop).

use super::{project_row_in_place, SweepExecutor, SweepStats};
use crate::core::active_set::ActiveSet;
use crate::core::bregman::BregmanFunction;

/// Projects rows `0..len` in slot order, each against the `x` already
/// updated by its predecessors. Arithmetic-identical to the pre-engine
/// `Solver::project_sweep`, so `SweepStrategy::Sequential` reproduces the
/// historical results bit for bit.
#[derive(Debug, Default, Clone)]
pub struct SequentialSweep;

impl SequentialSweep {
    pub fn new() -> SequentialSweep {
        SequentialSweep
    }
}

impl SequentialSweep {
    /// The one sweep loop, monomorphized over the recorder so the plain
    /// path keeps its exact historical shape (the no-op recorder
    /// compiles away).
    fn sweep_impl<F: BregmanFunction>(
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        mut record: impl FnMut(u32, f64),
    ) -> SweepStats {
        let mut stats = SweepStats { shards: 1, ..SweepStats::default() };
        for r in 0..active.len() {
            let moved = project_row_in_place(f, x, active, r);
            if moved != 0.0 {
                stats.projections += 1;
                stats.dual_movement += moved;
                record(r as u32, moved);
            }
        }
        stats
    }
}

impl<F: BregmanFunction> SweepExecutor<F> for SequentialSweep {
    fn sweep(&mut self, f: &F, x: &mut [f64], active: &mut ActiveSet) -> SweepStats {
        SequentialSweep::sweep_impl(f, x, active, |_, _| {})
    }

    fn sweep_recorded(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        record: &mut dyn FnMut(u32, f64),
    ) -> Option<SweepStats> {
        Some(SequentialSweep::sweep_impl(f, x, active, record))
    }

    fn name(&self) -> &'static str {
        "sequential"
    }
}
