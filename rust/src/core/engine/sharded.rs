//! Support-disjoint sharded parallel sweep (Ruggles, Veldt & Gleich).
//!
//! Shards run one after another; the rows inside a shard have pairwise
//! disjoint supports, so their projections commute: each row reads and
//! writes only its own coordinates of `x`, which makes any within-shard
//! order — including a fully concurrent one — *exactly* the sequential
//! result. Both phases of a shard fan out over the persistent pool
//! (`util::pool`): workers run the fused θ+apply kernel
//! [`BregmanFunction::project_disjoint`] through a [`DisjointCell`]
//! (scatter-safe: disjointness makes the per-index writes race-free),
//! and only the O(1)-per-row dual bookkeeping plus the `dual_movement`
//! reduction stay serial, in slot order — which keeps the whole sweep
//! deterministic and independent of the thread count.

use super::lazy::LazyScheduler;
use super::movement::MovementTracker;
use super::shards::{ShardLimits, ShardPlan};
use super::{project_row_in_place, SweepExecutor, SweepStats};
use crate::core::active_set::ActiveSet;
use crate::core::bregman::BregmanFunction;
use crate::util::pool::{default_threads, parallel_map, DisjointCell};

/// Baseline for [`ShardedSweep::parallel_min_rows`]: below this many rows
/// a shard is projected serially. With the persistent worker pool there
/// is no per-sweep thread spawn to amortise any more, so the threshold
/// sits far below the scoped-thread era's 64. (Serial and parallel paths
/// are arithmetic-identical on a disjoint shard, so this is purely a
/// scheduling choice and never changes results.)
pub const PARALLEL_MIN_ROWS: usize = 8;

/// The effective default threshold: the `PAF_PARALLEL_MIN_ROWS` env
/// override if set (clamped to ≥ 2), else [`PARALLEL_MIN_ROWS`]. A
/// per-solve override lives on `SolverConfig::parallel_min_rows`.
pub fn parallel_min_rows_default() -> usize {
    min_rows_from(std::env::var("PAF_PARALLEL_MIN_ROWS").ok().as_deref())
}

/// Pure core of [`parallel_min_rows_default`], split out so tests cover
/// the parse/clamp rules without mutating process-global env state
/// (concurrent `setenv`/`getenv` in one test binary is libc UB).
fn min_rows_from(raw: Option<&str>) -> usize {
    match raw.and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => n.max(2),
        None => PARALLEL_MIN_ROWS,
    }
}

/// The sharded executor with its lazily maintained plan.
#[derive(Debug)]
pub struct ShardedSweep {
    /// Worker threads; 0 = auto (`PAF_THREADS` / available cores).
    pub threads: usize,
    /// Shards smaller than this run serially (see
    /// [`parallel_min_rows_default`]).
    pub parallel_min_rows: usize,
    plan: ShardPlan,
    lazy: LazyScheduler,
}

impl Default for ShardedSweep {
    fn default() -> Self {
        ShardedSweep::new(0)
    }
}

impl ShardedSweep {
    pub fn new(threads: usize) -> ShardedSweep {
        ShardedSweep {
            threads,
            parallel_min_rows: parallel_min_rows_default(),
            plan: ShardPlan::new(),
            lazy: LazyScheduler::new(true),
        }
    }

    /// The current plan (benches/tests observability).
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Toggle the lazy scheduler (the `SolverConfig::lazy_sweep` knob).
    pub fn set_lazy(&mut self, on: bool) {
        self.lazy.set_enabled(on);
    }
}

impl ShardedSweep {
    /// The one sweep loop, monomorphized over the recorder so the plain
    /// path keeps its exact historical shape (the no-op recorder
    /// compiles away). `record(slot, |step|)` runs inside the serial
    /// bookkeeping, in the same deterministic slot order as the
    /// `dual_movement` reduction — and so do the movement marks, which
    /// is what "merge per-worker dirty sets at the barrier" means here:
    /// workers compute the parallel θ+apply steps, the barrier's serial
    /// loop folds each moved row's support into the tracker.
    fn sweep_impl<F: BregmanFunction>(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        mut tracker: Option<&mut MovementTracker>,
        mut record: impl FnMut(u32, f64),
    ) -> SweepStats {
        if !self.plan.is_current(active) {
            self.plan.rebuild(active, x.len(), &ShardLimits::none());
        }
        let threads = if self.threads == 0 { default_threads() } else { self.threads };
        let parallel_min = self.parallel_min_rows.max(2);
        let mut stats = SweepStats::default();
        let plan = &self.plan;
        for shard in &plan.shards {
            let mut shard_span = crate::obs::span(crate::obs::SpanKind::Shard);
            let proj_before = stats.projections;
            stats.shards += 1;
            stats.rows_projected += shard.len();
            if threads > 1 && shard.len() >= parallel_min {
                // Parallel θ+apply: every row reads and writes only its
                // own support (the ShardPlan invariant), so the fused
                // kernel is race-free and each step equals the serial one
                // bit for bit, for any chunking.
                let cell = DisjointCell::new(&mut *x);
                let act: &ActiveSet = active;
                let steps: Vec<f64> = parallel_map(shard.len(), threads, |k| {
                    let r = shard[k] as usize;
                    // SAFETY: supports within a shard are pairwise
                    // disjoint, so no index of row `r` is touched by any
                    // other worker during the map.
                    unsafe { f.project_disjoint(&cell, act.view(r), act.z(r)) }
                });
                // Serial dual bookkeeping + deterministic reduction in
                // slot order (the barrier merge for movement marks too).
                for (k, &step) in steps.iter().enumerate() {
                    if step == 0.0 {
                        continue;
                    }
                    let r = shard[k] as usize;
                    let z = active.z(r);
                    active.set_z(r, z - step);
                    stats.projections += 1;
                    stats.dual_movement += step.abs();
                    record(r as u32, step.abs());
                    if let Some(t) = tracker.as_deref_mut() {
                        t.mark_slice(active.view(r).indices);
                    }
                }
            } else {
                for &r in shard {
                    let moved = project_row_in_place(f, x, active, r as usize);
                    if moved != 0.0 {
                        stats.projections += 1;
                        stats.dual_movement += moved;
                        record(r, moved);
                        if let Some(t) = tracker.as_deref_mut() {
                            t.mark_slice(active.view(r as usize).indices);
                        }
                    }
                }
            }
            if let Some(g) = shard_span.as_mut() {
                g.counts(shard.len() as u64, (stats.projections - proj_before) as u64);
            }
        }
        // Tail rows (conflict chains past the shard cap): plain
        // Gauss–Seidel, exact by construction.
        if !plan.tail.is_empty() {
            let mut shard_span = crate::obs::span(crate::obs::SpanKind::Shard);
            let proj_before = stats.projections;
            stats.shards += 1;
            stats.rows_projected += plan.tail.len();
            for &r in &plan.tail {
                let moved = project_row_in_place(f, x, active, r as usize);
                if moved != 0.0 {
                    stats.projections += 1;
                    stats.dual_movement += moved;
                    record(r, moved);
                    if let Some(t) = tracker.as_deref_mut() {
                        t.mark_slice(active.view(r as usize).indices);
                    }
                }
            }
            if let Some(g) = shard_span.as_mut() {
                g.counts(plan.tail.len() as u64, (stats.projections - proj_before) as u64);
            }
        }
        stats
    }

    /// The lazy, priority-ordered tracked sweep. Per shard: drop the
    /// rows the scheduler proves zero-step, visit the remainder in
    /// greedy Gauss–Southwell order (largest last |dual step| first) —
    /// reordering is free of arithmetic consequences *only* because a
    /// shard's rows have pairwise disjoint supports, so their
    /// projections commute — then run the dual bookkeeping, stats
    /// reduction, movement marks and recorder strictly in **slot**
    /// order, exactly like the eager sweep. Since skipped rows would
    /// have contributed nothing to any of those channels (zero step),
    /// the lazy sweep is bit-identical to the eager one in `x`, every
    /// dual, `projections`, `dual_movement` and the recording order.
    /// The tail is a Gauss–Seidel chain (rows conflict): it skips but
    /// never reorders.
    fn lazy_sweep_impl<F: BregmanFunction>(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        tracker: &mut MovementTracker,
        mut record: impl FnMut(u32, f64),
    ) -> SweepStats {
        if !self.plan.is_current(active) {
            self.plan.rebuild(active, x.len(), &ShardLimits::none());
        }
        let threads = if self.threads == 0 { default_threads() } else { self.threads };
        let parallel_min = self.parallel_min_rows.max(2);
        let ShardedSweep { plan, lazy, .. } = self;
        let allow_skip = lazy.begin_sweep(active, x.len(), tracker);
        let mut stats = SweepStats::default();
        let mut visit: Vec<u32> = Vec::new();
        let mut pairs: Vec<(u32, f64)> = Vec::new();
        for shard in &plan.shards {
            let mut shard_span = crate::obs::span(crate::obs::SpanKind::Shard);
            let proj_before = stats.projections;
            stats.shards += 1;
            visit.clear();
            if allow_skip {
                visit.extend(shard.iter().copied().filter(|&r| !lazy.can_skip(r as usize)));
                stats.rows_skipped += shard.len() - visit.len();
            } else {
                visit.extend_from_slice(shard);
            }
            stats.rows_projected += visit.len();
            lazy.order_by_priority(&mut visit);
            pairs.clear();
            if threads > 1 && visit.len() >= parallel_min {
                // Parallel θ+apply over the visit list (same safety
                // argument as the eager path: disjoint supports).
                let cell = DisjointCell::new(&mut *x);
                let act: &ActiveSet = active;
                let vis: &[u32] = &visit;
                let steps: Vec<f64> = parallel_map(vis.len(), threads, |k| {
                    let r = vis[k] as usize;
                    // SAFETY: supports within a shard are pairwise
                    // disjoint, so no index of row `r` is touched by any
                    // other worker during the map.
                    unsafe { f.project_disjoint(&cell, act.view(r), act.z(r)) }
                });
                pairs.extend(visit.iter().copied().zip(steps));
                pairs.sort_unstable_by_key(|&(r, _)| r);
                for &(r32, step) in &pairs {
                    let r = r32 as usize;
                    lazy.visited(r, step.abs());
                    if step == 0.0 {
                        continue;
                    }
                    let z = active.z(r);
                    active.set_z(r, z - step);
                    stats.projections += 1;
                    stats.dual_movement += step.abs();
                    record(r32, step.abs());
                    tracker.mark_slice(active.view(r).indices);
                    lazy.note_moved(active.view(r).indices);
                }
            } else {
                // Serial compute in priority order (commutes), then the
                // same slot-order bookkeeping as above.
                for &r in &visit {
                    let moved = project_row_in_place(f, x, active, r as usize);
                    pairs.push((r, moved));
                }
                pairs.sort_unstable_by_key(|&(r, _)| r);
                for &(r32, moved) in &pairs {
                    let r = r32 as usize;
                    lazy.visited(r, moved);
                    if moved == 0.0 {
                        continue;
                    }
                    stats.projections += 1;
                    stats.dual_movement += moved;
                    record(r32, moved);
                    tracker.mark_slice(active.view(r).indices);
                    lazy.note_moved(active.view(r).indices);
                }
            }
            if let Some(g) = shard_span.as_mut() {
                g.counts(visit.len() as u64, (stats.projections - proj_before) as u64);
            }
        }
        if !plan.tail.is_empty() {
            let mut shard_span = crate::obs::span(crate::obs::SpanKind::Shard);
            let proj_before = stats.projections;
            stats.shards += 1;
            for &r32 in &plan.tail {
                let r = r32 as usize;
                if allow_skip && lazy.can_skip(r) {
                    stats.rows_skipped += 1;
                    continue;
                }
                stats.rows_projected += 1;
                let moved = project_row_in_place(f, x, active, r);
                lazy.visited(r, moved);
                if moved != 0.0 {
                    stats.projections += 1;
                    stats.dual_movement += moved;
                    record(r32, moved);
                    tracker.mark_slice(active.view(r).indices);
                    lazy.note_moved(active.view(r).indices);
                }
            }
            if let Some(g) = shard_span.as_mut() {
                g.counts(plan.tail.len() as u64, (stats.projections - proj_before) as u64);
            }
        }
        lazy.end_sweep(tracker);
        stats
    }
}

impl<F: BregmanFunction> SweepExecutor<F> for ShardedSweep {
    fn sweep(&mut self, f: &F, x: &mut [f64], active: &mut ActiveSet) -> SweepStats {
        // Untracked sweeps mutate state the scheduler cannot see.
        self.lazy.poison();
        self.sweep_impl(f, x, active, None, |_, _| {})
    }

    fn sweep_recorded(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        record: &mut dyn FnMut(u32, f64),
    ) -> Option<SweepStats> {
        self.lazy.poison();
        Some(self.sweep_impl(f, x, active, None, record))
    }

    fn sweep_tracked(
        &mut self,
        f: &F,
        x: &mut [f64],
        active: &mut ActiveSet,
        tracker: &mut MovementTracker,
        mut record: Option<&mut dyn FnMut(u32, f64)>,
    ) -> Option<SweepStats> {
        Some(if self.lazy.is_on() {
            self.lazy_sweep_impl(f, x, active, tracker, |slot, moved| {
                if let Some(r) = record.as_mut() {
                    r(slot, moved);
                }
            })
        } else {
            self.sweep_impl(f, x, active, Some(tracker), |slot, moved| {
                if let Some(r) = record.as_mut() {
                    r(slot, moved);
                }
            })
        })
    }

    fn after_forget(
        &mut self,
        map: &[u32],
        instance: u64,
        generation_before: u64,
        generation_after: u64,
    ) {
        // Only a plan built against the pre-forget state of this exact
        // set instance can be remapped; anything staler (or any foreign
        // set's map) is rebuilt lazily at the next sweep.
        if self.plan.instance() == instance && self.plan.generation() == generation_before {
            self.plan.remap_after_forget(map, generation_after);
        }
        self.lazy.after_forget(map, instance, generation_before, generation_after);
    }

    fn after_reoffset(&mut self, instance: u64, generation_before: u64, generation_after: u64) {
        // An injective index relabeling keeps slot ids and disjointness;
        // a plan built against the pre-reoffset generation of this exact
        // set stays structurally valid and just adopts the new key.
        if self.plan.instance() == instance && self.plan.generation() == generation_before {
            self.plan.adopt_generation(generation_after);
        }
        self.lazy.after_reoffset(instance, generation_before, generation_after);
    }

    fn name(&self) -> &'static str {
        "sharded-parallel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_override_parse_and_clamp_rules() {
        // Tested through the pure core — mutating the process env from a
        // multithreaded test binary races libc's getenv/setenv.
        assert_eq!(min_rows_from(Some("17")), 17);
        assert_eq!(min_rows_from(Some("0")), 2, "clamped to >= 2");
        assert_eq!(min_rows_from(Some("1")), 2, "clamped to >= 2");
        assert_eq!(min_rows_from(Some("not a number")), PARALLEL_MIN_ROWS);
        assert_eq!(min_rows_from(None), PARALLEL_MIN_ROWS);
    }
}
