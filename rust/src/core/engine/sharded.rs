//! Support-disjoint sharded parallel sweep (Ruggles, Veldt & Gleich).
//!
//! Shards run one after another; the rows inside a shard have pairwise
//! disjoint supports, so their projections commute: computing every `θ`
//! against the shard-entry snapshot of `x` and then applying the moves is
//! *exactly* the sequential result for any within-shard order. The `θ`
//! phase (the dot products — the dominant cost) fans out over
//! `util::pool`; the apply phase and the `last_dual_movement` reduction
//! run serially in slot order, which makes the whole sweep deterministic
//! and independent of the thread count.

use super::shards::{ShardLimits, ShardPlan};
use super::{project_row_in_place, SweepExecutor, SweepStats};
use crate::core::active_set::ActiveSet;
use crate::core::bregman::BregmanFunction;
use crate::util::pool::{default_threads, parallel_map};

/// Default for [`ShardedSweep::parallel_min_rows`]: below this many rows
/// a shard is projected serially — scoped-thread spawn overhead would
/// eat the win on tiny shards. (Serial and parallel paths are
/// arithmetic-identical on a disjoint shard, so this is purely a
/// scheduling choice and never changes results.)
pub const PARALLEL_MIN_ROWS: usize = 64;

/// The sharded executor with its lazily maintained plan.
#[derive(Debug)]
pub struct ShardedSweep {
    /// Worker threads; 0 = auto (`PAF_THREADS` / available cores).
    pub threads: usize,
    /// Shards smaller than this run serially (see [`PARALLEL_MIN_ROWS`]).
    pub parallel_min_rows: usize,
    plan: ShardPlan,
}

impl Default for ShardedSweep {
    fn default() -> Self {
        ShardedSweep::new(0)
    }
}

impl ShardedSweep {
    pub fn new(threads: usize) -> ShardedSweep {
        ShardedSweep { threads, parallel_min_rows: PARALLEL_MIN_ROWS, plan: ShardPlan::new() }
    }

    /// The current plan (benches/tests observability).
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }
}

impl<F: BregmanFunction> SweepExecutor<F> for ShardedSweep {
    fn sweep(&mut self, f: &F, x: &mut [f64], active: &mut ActiveSet) -> SweepStats {
        if !self.plan.is_current(active) {
            self.plan.rebuild(active, x.len(), &ShardLimits::none());
        }
        let threads = if self.threads == 0 { default_threads() } else { self.threads };
        let parallel_min = self.parallel_min_rows.max(2);
        let mut stats = SweepStats::default();
        let plan = &self.plan;
        for shard in &plan.shards {
            stats.shards += 1;
            if threads > 1 && shard.len() >= parallel_min {
                // Parallel θ against the shard-entry snapshot (reads only;
                // disjoint supports make this equal to in-place order).
                let xr: &[f64] = x;
                let act: &ActiveSet = active;
                let steps: Vec<f64> = parallel_map(shard.len(), threads, |k| {
                    let r = shard[k] as usize;
                    let theta = f.theta(xr, act.view(r));
                    act.z(r).min(theta)
                });
                // Serial apply + deterministic reduction in slot order.
                for (k, &step) in steps.iter().enumerate() {
                    if step == 0.0 {
                        continue;
                    }
                    let r = shard[k] as usize;
                    let view = active.view(r);
                    f.apply(x, view, step);
                    let z = active.z(r);
                    active.set_z(r, z - step);
                    stats.projections += 1;
                    stats.dual_movement += step.abs();
                }
            } else {
                for &r in shard {
                    let moved = project_row_in_place(f, x, active, r as usize);
                    if moved != 0.0 {
                        stats.projections += 1;
                        stats.dual_movement += moved;
                    }
                }
            }
        }
        // Tail rows (conflict chains past the shard cap): plain
        // Gauss–Seidel, exact by construction.
        if !plan.tail.is_empty() {
            stats.shards += 1;
            for &r in &plan.tail {
                let moved = project_row_in_place(f, x, active, r as usize);
                if moved != 0.0 {
                    stats.projections += 1;
                    stats.dual_movement += moved;
                }
            }
        }
        stats
    }

    fn after_forget(&mut self, map: &[u32], generation_before: u64, generation_after: u64) {
        // Only a plan built against the pre-forget set can be remapped;
        // anything staler is rebuilt lazily at the next sweep.
        if self.plan.generation() == generation_before {
            self.plan.remap_after_forget(map, generation_after);
        }
    }

    fn name(&self) -> &'static str {
        "sharded-parallel"
    }
}
