//! Movement-driven lazy sweep scheduling (greedy Gauss–Southwell) — the
//! symmetric twin of the incremental separation oracle: PR 5 made the
//! *oracle* cost scale with iterate movement, this module does the same
//! for the *sweep*.
//!
//! # The skip rule (exact, not heuristic)
//!
//! The projection kernel's step for row `r` is a pure function of the
//! iterate restricted to `r`'s support and of `r`'s dual:
//! `c = min(z_r, θ_r(x|support))`. Therefore, if
//!
//! 1. `r`'s last projection had a **zero step** (so it changed neither
//!    `x` nor `z_r`), and
//! 2. no coordinate in `r`'s support moved since that visit, and
//! 3. `z_r` was not raised in between (no engine path ever raises a
//!    dual: sweeps and the sink only run `z ← z − c` with `c = min(z, θ)`,
//!    and FORGET/`z_tol` only lower duals toward zero — which keeps a
//!    zero-step row's step at zero, since step 0 implies `θ ≥ 0`),
//!
//! then re-running the kernel would compute bit-identical inputs and
//! return a zero step again. Skipping the row is a *no-op elision*, so a
//! lazy sweep is **bit-identical** to the eager sweep in `x`, every
//! dual, `SweepStats::projections`/`dual_movement`, and the per-row
//! recording channel — only [`SweepStats::rows_projected`] shrinks.
//!
//! # How movement reaches the scheduler
//!
//! Two channels, both conservative supersets of real movement:
//!
//! - **Within a sweep**, the executor calls
//!   [`LazyScheduler::note_moved`] at its serial bookkeeping point for
//!   every moved row; the [`RowIndex`] (coordinate → incident rows)
//!   fans the moved support out to dirty flags, so a later row in the
//!   same Gauss–Seidel pass is never skipped against a stale predicate.
//! - **Between sweeps**, the solver's [`MovementTracker`] log covers
//!   every other mutation path (the engine sink's on-find projections
//!   and fused box pass). [`LazyScheduler::begin_sweep`] drains the log
//!   window since the previous sweep; if the window is not covered
//!   (log evicted, tracker invalidated by a checkpoint restore or a
//!   coordinate relabeling), the whole sweep falls back to project-all
//!   — the fallback is the eager sweep, so correctness never depends
//!   on the log.
//!
//! # FORGET staleness rule
//!
//! The scheduler caches *scheduling* metadata only (dirty/armed flags
//! and priorities) — never dual values. Duals live solely in the
//! [`ActiveSet`], so the FORGET zero-dual test always reads live state:
//! a skippable row's dual is, by the skip rule, exactly the value its
//! last projection left (and the last refresh saw), which is precisely
//! what an eager sweep would have handed FORGET. Skipped rows therefore
//! participate in dual relaxation and FORGET *unchanged*; no refresh
//! pass is needed before eviction.
//!
//! # Priority order
//!
//! Within each support-disjoint shard the remaining (non-skipped) rows
//! are visited in descending order of their last |dual step| (fresh
//! rows first) — greedy Gauss–Southwell. Projections inside a shard
//! commute (disjoint supports), so the ordering is free of arithmetic
//! consequences; the stats/bookkeeping reduction stays in slot order,
//! which keeps lazy ≡ eager bitwise. The sequential executor and the
//! sharded tail are Gauss–Seidel chains whose rows do *not* commute, so
//! they skip but never reorder.

use super::movement::MovementTracker;
use crate::core::active_set::ActiveSet;
use crate::core::constraint::SLOT_DROPPED;

/// Coordinate → incident remembered rows, keyed to the active set's
/// `(instance_id, generation)`. Kept current across oracle admission
/// (append), FORGET compaction (stable-slot remap) and serve-time
/// re-offsetting (invalidate + lazy rebuild: the labels changed).
#[derive(Debug, Clone, Default)]
pub struct RowIndex {
    /// `rows_of[coord]` = slots of the rows whose support contains it.
    rows_of: Vec<Vec<u32>>,
    instance: u64,
    generation: u64,
}

impl RowIndex {
    pub fn new() -> RowIndex {
        RowIndex::default()
    }

    /// Does the index describe `active`'s current membership?
    pub fn is_current(&self, active: &ActiveSet) -> bool {
        self.instance == active.instance_id() && self.generation == active.generation()
    }

    /// Make the index current: full rebuild on a key mismatch, plain
    /// resize when only the coordinate space changed (fleet growth adds
    /// coordinates no remembered row touches yet; a tail-range removal
    /// leaves the dropped entries empty).
    pub fn ensure(&mut self, active: &ActiveSet, dim: usize) {
        if !self.is_current(active) {
            self.rebuild(active, dim);
            return;
        }
        if self.rows_of.len() != dim {
            self.rows_of.resize_with(dim, Vec::new);
        }
    }

    /// Rebuild from scratch: one linear scan, O(nnz + dim).
    pub fn rebuild(&mut self, active: &ActiveSet, dim: usize) {
        for v in &mut self.rows_of {
            v.clear();
        }
        self.rows_of.resize_with(dim, Vec::new);
        for r in 0..active.len() {
            for &c in active.view(r).indices {
                if let Some(v) = self.rows_of.get_mut(c as usize) {
                    v.push(r as u32);
                }
            }
        }
        self.instance = active.instance_id();
        self.generation = active.generation();
    }

    /// Append-only growth: rows `from..active.len()` are new (the
    /// oracle's merge); existing slots and labels are untouched.
    pub fn append_rows(&mut self, active: &ActiveSet, from: usize, dim: usize) {
        if self.rows_of.len() < dim {
            self.rows_of.resize_with(dim, Vec::new);
        }
        for r in from..active.len() {
            for &c in active.view(r).indices {
                if let Some(v) = self.rows_of.get_mut(c as usize) {
                    v.push(r as u32);
                }
            }
        }
        self.instance = active.instance_id();
        self.generation = active.generation();
    }

    /// FORGET: apply the stable-slot compaction map in place, O(nnz).
    pub fn remap_after_forget(&mut self, map: &[u32], generation_after: u64) {
        for v in &mut self.rows_of {
            v.retain_mut(|r| {
                let nr = map.get(*r as usize).copied().unwrap_or(SLOT_DROPPED);
                if nr == SLOT_DROPPED {
                    false
                } else {
                    *r = nr;
                    true
                }
            });
        }
        self.generation = generation_after;
    }

    /// Force the next [`RowIndex::ensure`] to rebuild (coordinate
    /// labels changed: the stored incidences are orphaned).
    pub fn invalidate(&mut self) {
        // Instance ids start at 1, so 0 never matches a real set.
        self.instance = 0;
    }

    /// Rows whose support contains `coord` (empty for out-of-range).
    #[inline]
    pub fn rows_of(&self, coord: u32) -> &[u32] {
        self.rows_of.get(coord as usize).map(Vec::as_slice).unwrap_or(&[])
    }
}

/// Per-executor lazy sweep state: one dirty/armed flag pair and a
/// Gauss–Southwell priority per slot, plus the [`RowIndex`] and the
/// movement-log cursor that keep them exact. See the module docs for
/// the skip rule and its proof obligations.
#[derive(Debug, Clone)]
pub struct LazyScheduler {
    enabled: bool,
    /// `armed[r]`: `r`'s last projection had a zero step — skippable
    /// unless its support moved since.
    armed: Vec<bool>,
    /// `dirty[r]`: some support coordinate of `r` moved since `r`'s
    /// last visit (conservative superset).
    dirty: Vec<bool>,
    /// Last |dual step| per slot (`∞` for never-visited rows) — the
    /// greedy priority.
    last_step: Vec<f64>,
    index: RowIndex,
    /// Per-coordinate dedup stamp so one coordinate's incidence list is
    /// walked at most once per sweep.
    coord_epoch: Vec<u64>,
    epoch: u64,
    /// Movement-log cursor of the last completed tracked sweep (`None`
    /// = no covered window: the next sweep projects everything).
    synced_to: Option<u64>,
    /// Structural key mirroring the active set (with the monotonic
    /// insert counter, so pure oracle appends are recognized without
    /// diffing membership).
    instance: u64,
    generation: u64,
    inserts: u64,
    /// Reused drain buffer for `moved_since`.
    drain: Vec<u32>,
}

impl LazyScheduler {
    pub fn new(enabled: bool) -> LazyScheduler {
        LazyScheduler {
            enabled,
            armed: Vec::new(),
            dirty: Vec::new(),
            last_step: Vec::new(),
            index: RowIndex::new(),
            coord_epoch: Vec::new(),
            epoch: 0,
            synced_to: None,
            instance: 0,
            generation: 0,
            inserts: 0,
            drain: Vec::new(),
        }
    }

    /// Is lazy scheduling on for this executor?
    #[inline]
    pub fn is_on(&self) -> bool {
        self.enabled
    }

    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
        if !on {
            self.synced_to = None;
        }
    }

    /// Full reset: nothing armed, everything dirty, priorities fresh.
    fn reset(&mut self, active: &ActiveSet, dim: usize) {
        let n = active.len();
        self.armed.clear();
        self.armed.resize(n, false);
        self.dirty.clear();
        self.dirty.resize(n, true);
        self.last_step.clear();
        self.last_step.resize(n, f64::INFINITY);
        self.instance = active.instance_id();
        self.generation = active.generation();
        self.inserts = active.inserts();
        self.synced_to = None;
        self.index.rebuild(active, dim);
    }

    /// Start one tracked sweep: sync structure (membership growth /
    /// identity changes), then drain the movement-log window since the
    /// last sweep into dirty flags. Returns `true` when skipping is
    /// allowed this sweep; `false` means project-all (the state still
    /// warms: every visit arms or re-dirties rows for the next sweep).
    pub fn begin_sweep(
        &mut self,
        active: &ActiveSet,
        dim: usize,
        tracker: &MovementTracker,
    ) -> bool {
        // Structural sync. A pure oracle append is recognized by the
        // generation/insert/len deltas agreeing; anything else (foreign
        // instance, compaction we were not told about, forget_all,
        // restore) resets — which is always correct, just not lazy.
        if active.instance_id() != self.instance {
            self.reset(active, dim);
        } else if active.generation() != self.generation {
            let dg = active.generation().wrapping_sub(self.generation);
            let di = active.inserts().wrapping_sub(self.inserts);
            let old_len = self.armed.len();
            let grown = active.len().saturating_sub(old_len) as u64;
            if old_len <= active.len() && dg == di && di == grown {
                self.armed.resize(active.len(), false);
                self.dirty.resize(active.len(), true);
                self.last_step.resize(active.len(), f64::INFINITY);
                self.index.append_rows(active, old_len, dim);
                self.generation = active.generation();
                self.inserts = active.inserts();
            } else {
                self.reset(active, dim);
            }
        } else if self.armed.len() != active.len() {
            // Equal generations imply equal membership; defensive.
            self.reset(active, dim);
        }
        self.index.ensure(active, dim);
        if self.coord_epoch.len() != dim {
            self.coord_epoch.clear();
            self.coord_epoch.resize(dim, 0);
            self.epoch = 0;
        }
        self.epoch += 1;

        // Movement sync: dirty every row whose support was touched
        // since the last completed sweep (sink on-find projections, the
        // fused box pass). An uncovered window means unknown movement:
        // fall back to project-all for this sweep.
        let mut covered = false;
        if let Some(prev) = self.synced_to {
            let mut buf = std::mem::take(&mut self.drain);
            buf.clear();
            if tracker.moved_since(prev, &mut buf) {
                covered = true;
                for i in 0..buf.len() {
                    self.touch_coord(buf[i]);
                }
            }
            self.drain = buf;
        }
        if !covered {
            self.synced_to = None;
        }
        covered
    }

    /// End the tracked sweep: the next window starts *after* this
    /// sweep's own marks (they were already folded into dirty flags by
    /// [`LazyScheduler::note_moved`] at the bookkeeping point). Takes
    /// the cursor with [`MovementTracker::take_cursor`] so the dedup
    /// epoch rolls over: a coordinate stamped during this sweep that
    /// moves *again* afterwards (a sink on-find projection or box pass
    /// before the next sweep) is re-logged after the cursor instead of
    /// being suppressed by its intra-sweep stamp.
    pub fn end_sweep(&mut self, tracker: &mut MovementTracker) {
        self.synced_to = tracker.take_cursor();
    }

    /// Discard the movement window (an untracked sweep or external
    /// surgery mutated state behind the scheduler's back): the next
    /// tracked sweep projects everything.
    pub fn poison(&mut self) {
        self.synced_to = None;
    }

    /// Is row `r` provably a zero-step no-op this sweep?
    #[inline]
    pub fn can_skip(&self, r: usize) -> bool {
        self.armed[r] && !self.dirty[r]
    }

    /// Record a visit's outcome (`moved` = |dual step|, 0.0 for a
    /// no-op). Zero-step rows arm; moved rows stay hot and their new
    /// |step| becomes the next sweep's priority.
    #[inline]
    pub fn visited(&mut self, r: usize, moved: f64) {
        self.armed[r] = moved == 0.0;
        self.dirty[r] = false;
        self.last_step[r] = moved;
    }

    /// Fan a moved row's support out to the incident rows' dirty flags
    /// (the intra-sweep channel). Never deduped: a coordinate may move
    /// *again* after an incident row was already visited this sweep, and
    /// that row must be re-dirtied or its next-sweep skip would be
    /// tested against a stale predicate. (The begin-of-sweep drain *is*
    /// deduped — see [`LazyScheduler::touch_coord`] — because no row has
    /// been visited yet when it runs, so dirtying there is idempotent.)
    pub fn note_moved(&mut self, support: &[u32]) {
        for &c in support {
            self.dirty_rows_of(c);
        }
    }

    /// Drain-phase touch: dirty `c`'s incident rows at most once per
    /// sweep. Only sound before any row of the sweep has been visited.
    fn touch_coord(&mut self, c: u32) {
        let ci = c as usize;
        if ci >= self.coord_epoch.len() || self.coord_epoch[ci] == self.epoch {
            return;
        }
        self.coord_epoch[ci] = self.epoch;
        self.dirty_rows_of(c);
    }

    fn dirty_rows_of(&mut self, c: u32) {
        for &r in self.index.rows_of(c) {
            if let Some(d) = self.dirty.get_mut(r as usize) {
                *d = true;
            }
        }
    }

    /// Sort `visit` (slots of one support-disjoint shard) into greedy
    /// Gauss–Southwell order: largest last |dual step| first, fresh
    /// (never-visited, `∞`) rows before everything, slot ascending as
    /// the deterministic tie-break.
    pub fn order_by_priority(&self, visit: &mut [u32]) {
        visit.sort_by(|&a, &b| {
            let (pa, pb) = (self.last_step[a as usize], self.last_step[b as usize]);
            pb.partial_cmp(&pa).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
    }

    /// FORGET notification (same contract as
    /// [`super::SweepExecutor::after_forget`]): remap the per-slot state
    /// through the stable-slot compaction map.
    pub fn after_forget(
        &mut self,
        map: &[u32],
        instance: u64,
        generation_before: u64,
        generation_after: u64,
    ) {
        if instance != self.instance || generation_before != self.generation {
            return;
        }
        debug_assert_eq!(map.len(), self.armed.len());
        let mut new_len = 0usize;
        for (old, &new) in map.iter().enumerate() {
            if new == SLOT_DROPPED {
                continue;
            }
            let n = new as usize;
            // Compaction preserves order (new <= old), so the forward
            // in-place copy never clobbers unread entries.
            self.armed[n] = self.armed[old];
            self.dirty[n] = self.dirty[old];
            self.last_step[n] = self.last_step[old];
            new_len = n + 1;
        }
        self.armed.truncate(new_len);
        self.dirty.truncate(new_len);
        self.last_step.truncate(new_len);
        self.generation = generation_after;
        self.index.remap_after_forget(map, generation_after);
    }

    /// Re-offset notification: slots and flags survive (an injective
    /// coordinate relabeling changes neither any row's dual nor the
    /// values at its support), but the incidence index is label-keyed
    /// and must rebuild, and the movement log was invalidated — the
    /// next sweep projects everything once.
    pub fn after_reoffset(&mut self, instance: u64, generation_before: u64, generation_after: u64) {
        if instance != self.instance || generation_before != self.generation {
            return;
        }
        self.generation = generation_after;
        self.index.invalidate();
        self.synced_to = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::constraint::Constraint;

    fn set_of(rows: &[(&[u32], f64)]) -> ActiveSet {
        let mut s = ActiveSet::new();
        for (idx, z) in rows {
            let coeffs = vec![1.0; idx.len()];
            let slot = s.insert(&Constraint::new(idx.to_vec(), coeffs, 0.0));
            s.set_z(slot, *z);
        }
        s
    }

    #[test]
    fn row_index_tracks_incidence_through_forget_and_append() {
        let mut s = set_of(&[(&[0, 1], 1.0), (&[1, 2], 0.0), (&[3], 2.0)]);
        let mut idx = RowIndex::new();
        idx.ensure(&s, 5);
        assert_eq!(idx.rows_of(1), &[0, 1]);
        assert_eq!(idx.rows_of(3), &[2]);
        assert_eq!(idx.rows_of(4), &[] as &[u32]);
        assert!(idx.is_current(&s));
        // FORGET drops row 1 (z == 0); the remap keeps the index exact.
        let mut map = Vec::new();
        let g_after = {
            s.forget_inactive_with_map(&mut map);
            s.generation()
        };
        idx.remap_after_forget(&map, g_after);
        assert!(idx.is_current(&s));
        assert_eq!(idx.rows_of(1), &[0]);
        assert_eq!(idx.rows_of(2), &[] as &[u32]);
        assert_eq!(idx.rows_of(3), &[1], "row 2 compacted to slot 1");
        // Append-only growth: a new row lands without a full rebuild.
        let slot = s.insert(&Constraint::new(vec![2, 4], vec![1.0, 1.0], 0.0));
        idx.append_rows(&s, slot, 5);
        assert!(idx.is_current(&s));
        assert_eq!(idx.rows_of(4), &[slot as u32]);
        // Invalidation forces the next ensure to rebuild.
        idx.invalidate();
        assert!(!idx.is_current(&s));
        idx.ensure(&s, 5);
        assert!(idx.is_current(&s));
        assert_eq!(idx.rows_of(2), &[slot as u32]);
    }

    #[test]
    fn scheduler_recognizes_pure_appends_and_resets_otherwise() {
        let mut s = set_of(&[(&[0], 1.0), (&[1], 1.0)]);
        let mut tracker = MovementTracker::new(4, true);
        let mut sched = LazyScheduler::new(true);
        // First sweep: nothing synced yet, so no skipping.
        assert!(!sched.begin_sweep(&s, 4, &tracker));
        sched.visited(0, 0.0);
        sched.visited(1, 0.5);
        sched.end_sweep(&mut tracker);
        // Second sweep with no movement: row 0 armed+clean, row 1 hot.
        assert!(sched.begin_sweep(&s, 4, &tracker));
        assert!(sched.can_skip(0));
        assert!(!sched.can_skip(1));
        sched.visited(1, 0.0);
        sched.end_sweep(&mut tracker);
        // A pure oracle append keeps the armed state of old rows.
        s.insert(&Constraint::new(vec![2], vec![1.0], 0.0));
        assert!(sched.begin_sweep(&s, 4, &tracker));
        assert!(sched.can_skip(0), "append must not disturb armed rows");
        assert!(!sched.can_skip(2), "fresh rows are dirty");
        sched.visited(2, 0.0);
        sched.end_sweep(&mut tracker);
        // forget_all is NOT an append: full reset, nothing skippable.
        s.forget_all();
        s.insert(&Constraint::new(vec![0], vec![1.0], 0.0));
        assert!(!sched.begin_sweep(&s, 4, &tracker), "reset voids the window");
        assert!(!sched.can_skip(0));
    }

    #[test]
    fn movement_window_gaps_force_project_all() {
        let s = set_of(&[(&[0, 1], 1.0)]);
        let mut tracker = MovementTracker::new(4, true);
        let mut sched = LazyScheduler::new(true);
        sched.begin_sweep(&s, 4, &tracker);
        sched.visited(0, 0.0);
        sched.end_sweep(&mut tracker);
        assert!(sched.begin_sweep(&s, 4, &tracker));
        assert!(sched.can_skip(0));
        sched.visited(0, 0.0);
        sched.end_sweep(&mut tracker);
        // A restore-style invalidation orphans the cursor: next sweep
        // must project everything, then recover its window.
        tracker.invalidate();
        assert!(!sched.begin_sweep(&s, 4, &tracker));
        sched.visited(0, 0.0);
        sched.end_sweep(&mut tracker);
        assert!(sched.begin_sweep(&s, 4, &tracker), "window re-established");
        assert!(sched.can_skip(0));
    }

    #[test]
    fn sink_movement_between_sweeps_undirties_armed_rows() {
        let s = set_of(&[(&[0, 1], 1.0), (&[2, 3], 1.0)]);
        let mut tracker = MovementTracker::new(4, true);
        let mut sched = LazyScheduler::new(true);
        sched.begin_sweep(&s, 4, &tracker);
        sched.visited(0, 0.0);
        sched.visited(1, 0.0);
        sched.end_sweep(&mut tracker);
        // The engine sink moves coordinate 2 between sweeps (an on-find
        // projection): only the incident row may lose its skip.
        tracker.mark(2);
        assert!(sched.begin_sweep(&s, 4, &tracker));
        assert!(sched.can_skip(0), "row over {{0,1}} is untouched");
        assert!(!sched.can_skip(1), "row over {{2,3}} saw movement");
    }

    #[test]
    fn priority_order_is_biggest_step_first_with_slot_tiebreak() {
        let s = set_of(&[(&[0], 1.0), (&[1], 1.0), (&[2], 1.0), (&[3], 1.0)]);
        let tracker = MovementTracker::new(4, true);
        let mut sched = LazyScheduler::new(true);
        sched.begin_sweep(&s, 4, &tracker);
        sched.visited(0, 0.25);
        sched.visited(1, 0.75);
        sched.visited(2, 0.25);
        // Row 3 never visited: ∞ priority, goes first.
        let mut visit = vec![0u32, 1, 2, 3];
        sched.order_by_priority(&mut visit);
        assert_eq!(visit, vec![3, 1, 0, 2]);
    }

    #[test]
    fn remove_after_a_visit_redirties_despite_drain_dedup() {
        // Rows A = {0,1}, B = {1,2} share coordinate 1.
        let s = set_of(&[(&[0, 1], 1.0), (&[1, 2], 1.0)]);
        let mut tracker = MovementTracker::new(4, true);
        let mut sched = LazyScheduler::new(true);
        sched.begin_sweep(&s, 4, &tracker);
        sched.visited(0, 0.0);
        sched.visited(1, 0.0);
        sched.end_sweep(&mut tracker);
        // The sink moves coordinate 1 between sweeps; the next sweep's
        // drain walks it (and stamps its per-sweep dedup epoch).
        tracker.mark(1);
        assert!(sched.begin_sweep(&s, 4, &tracker));
        assert!(!sched.can_skip(0));
        assert!(!sched.can_skip(1));
        // Row A settles first, then row B moves coordinate 1 AGAIN in
        // the same sweep: the intra-sweep walk must not be suppressed
        // by the drain's stamp, or A would be skipped next sweep
        // against a stale θ.
        sched.visited(0, 0.0);
        sched.visited(1, 0.25);
        tracker.mark_slice(&[1, 2]);
        sched.note_moved(&[1, 2]);
        sched.end_sweep(&mut tracker);
        assert!(sched.begin_sweep(&s, 4, &tracker));
        assert!(!sched.can_skip(0), "coordinate 1 moved after row A's visit");
        assert!(!sched.can_skip(1), "row B itself moved");
    }

    #[test]
    fn sink_remove_of_a_swept_coord_reaches_the_next_drain() {
        let s = set_of(&[(&[0, 1], 1.0), (&[1, 2], 1.0)]);
        let mut tracker = MovementTracker::new(4, true);
        let mut sched = LazyScheduler::new(true);
        // Mimic the solver: one dedup epoch per sweep.
        tracker.advance_epoch();
        sched.begin_sweep(&s, 4, &tracker);
        sched.visited(1, 0.5); // row B moves first...
        tracker.mark_slice(&[1, 2]);
        sched.note_moved(&[1, 2]);
        sched.visited(0, 0.0); // ...then row A settles (dirty cleared)
        sched.end_sweep(&mut tracker);
        // The sink re-moves coordinate 1 after the sweep. Had end_sweep
        // not rolled the dedup epoch, this mark would be suppressed by
        // the sweep's own stamp and never reach the drain window.
        tracker.mark(1);
        assert!(sched.begin_sweep(&s, 4, &tracker));
        assert!(!sched.can_skip(0), "post-sweep sink movement must re-dirty row A");
    }
}
