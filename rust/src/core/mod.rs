//! The PROJECT AND FORGET engine (Algorithms 1 and 3 of the paper).
//!
//! - [`bregman`] — Bregman functions and their hyperplane projections.
//! - [`constraint`] — sparse half-space constraints and the flat store.
//! - [`active_set`] — the remembered list `L^(ν)` with duals `z` and the
//!   FORGET step.
//! - [`oracle`] — separation-oracle traits (Property 1 / Property 2).
//! - [`engine`] — pluggable projection-sweep executors (sequential
//!   Gauss–Seidel and the support-disjoint sharded parallel sweep).
//! - [`solver`] — the outer loop: oracle → merge → project sweep → forget.
//! - [`problem`] — the unified problem layer: [`SolveOptions`] and the
//!   [`Problem`] trait every workload lowers through.
//! - [`session`] — the [`Session`] driver: stepwise solves with typed
//!   events, cancellation, checkpoint/resume, and multi-instance block
//!   batching over the shard planner.
//! - [`stochastic`] — the truly stochastic variant (§3.2.1).

pub mod active_set;
pub mod bregman;
pub mod constraint;
pub mod engine;
pub mod oracle;
pub mod problem;
pub mod session;
pub mod solver;
pub mod stochastic;

pub use active_set::ActiveSet;
pub use bregman::{BregmanFunction, DiagonalQuadratic, Entropy};
pub use constraint::{Constraint, ConstraintKey};
pub use engine::{SweepExecutor, SweepStats, SweepStrategy};
pub use oracle::{Oracle, OracleOutcome, OverlappableOracle, RandomOracle};
pub use problem::{
    CancelToken, Handle, Lowered, Problem, RoundProblem, SessionSummary, SolveEvent,
    SolveOptions, VectorPart,
};
pub use session::{BlockCheckpoint, Checkpoint, Session};
pub use solver::{IterStats, PhaseTimes, Solver, SolverConfig, SolverResult};
