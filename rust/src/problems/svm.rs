//! L2-SVM training with the truly stochastic PROJECT AND FORGET
//! (§4.4 / Algorithm 10, Table 5).
//!
//! `min ½‖w‖² + (C/2)Σξ_i²  s.t.  y_i⟨w, x_i⟩ ≥ 1 − ξ_i`
//!
//! The combined variable is `v = (w, ξ)` with diagonal quadratic
//! `f(v) = ½‖w‖² + (C/2)‖ξ‖²`; the margin constraint of sample `i` is the
//! sparse row `−y_i x_i·w − ξ_i ≤ −1`, whose Bregman projection is
//! closed-form:
//!
//! `θ_i = (y_i⟨w, x_i⟩ + ξ_i − 1) / (‖x_i‖² + 1/C)`
//!
//! with primal move `w ← w + c·y_i·x_i`, `ξ_i ← ξ_i + c/C` for
//! `c = min(z_i, θ_i)` (θ < 0 iff the margin is violated). The ξ ≥ 0 rows
//! are redundant for the L2 penalty and omitted, exactly as Algorithm 10
//! does. Per iteration the constraint list is forgotten wholesale; only
//! the duals `z` persist (Theorem 2's setting).

use crate::ml::dataset::Dataset;
use crate::util::{Rng, Stopwatch};

/// Training configuration.
#[derive(Debug, Clone)]
pub struct SvmConfig {
    /// Slack penalty C.
    pub c: f64,
    /// Passes over n random samples (Algorithm 10's MaxIters).
    pub epochs: usize,
    pub seed: u64,
}

impl Default for SvmConfig {
    fn default() -> Self {
        SvmConfig { c: 1e3, epochs: 5, seed: 0 }
    }
}

/// Trained model + accounting.
#[derive(Debug, Clone)]
pub struct SvmModel {
    pub w: Vec<f64>,
    /// Slack variables (one per training sample).
    pub xi: Vec<f64>,
    /// Persistent duals (support vectors have z > 0).
    pub z: Vec<f64>,
    pub projections: usize,
    pub seconds: f64,
}

impl SvmModel {
    /// Decision value ⟨w, x⟩.
    pub fn decision(&self, x: &[f64]) -> f64 {
        self.w.iter().zip(x).map(|(&w, &v)| w * v).sum()
    }

    /// Accuracy on a labelled dataset (labels 0/1).
    pub fn accuracy(&self, data: &Dataset) -> f64 {
        let mut correct = 0usize;
        for i in 0..data.n {
            let pred = self.decision(data.row(i)) >= 0.0;
            if pred == (data.y[i] == 1) {
                correct += 1;
            }
        }
        correct as f64 / data.n.max(1) as f64
    }

    /// Support-vector count (nonzero duals).
    pub fn num_support(&self) -> usize {
        self.z.iter().filter(|&&z| z > 0.0).count()
    }
}

/// Train with the truly stochastic variant (Algorithm 10): each epoch
/// samples `n` random data points and projects `v = (w, ξ)` onto their
/// margin constraints with persistent dual corrections.
pub fn train_pf_svm(data: &Dataset, cfg: &SvmConfig) -> SvmModel {
    let clock = Stopwatch::new();
    let (n, d) = (data.n, data.d);
    let mut w = vec![0.0f64; d];
    let mut xi = vec![0.0f64; n];
    let mut z = vec![0.0f64; n];
    // Precompute ‖x_i‖² once (the denominators).
    let norms: Vec<f64> = (0..n)
        .map(|i| data.row(i).iter().map(|&v| v * v).sum::<f64>())
        .collect();
    let inv_c = 1.0 / cfg.c;
    let mut rng = Rng::new(cfg.seed);
    let mut projections = 0usize;
    for _ in 0..cfg.epochs {
        for _ in 0..n {
            let i = rng.below(n);
            let row = data.row(i);
            let yi = if data.y[i] == 1 { 1.0 } else { -1.0 };
            let margin: f64 = {
                let dot: f64 = w.iter().zip(row).map(|(&wv, &xv)| wv * xv).sum();
                yi * dot + xi[i]
            };
            let theta = (margin - 1.0) / (norms[i] + inv_c);
            let c = z[i].min(theta);
            if c == 0.0 {
                continue;
            }
            // v ← v + c·W⁻¹·a with a = −(y_i x_i, e_i):
            // w ← w − c·y_i·x_i, ξ_i ← ξ_i − c/C; dual z_i ← z_i − (−c)… the
            // sign convention folds to the usual Dykstra update below.
            for (wv, &xv) in w.iter_mut().zip(row) {
                *wv -= c * yi * xv;
            }
            xi[i] -= c * inv_c;
            z[i] -= c;
            projections += 1;
        }
    }
    SvmModel { w, xi, z, projections, seconds: clock.elapsed_s() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::dataset::svm_cloud;

    #[test]
    fn separable_data_perfectly_classified() {
        let mut rng = Rng::new(1);
        // Clean margins: huge K -> negligible label noise.
        let (train, s) = svm_cloud(2000, 10, 50.0, &mut rng);
        assert!(s < 0.02);
        let model = train_pf_svm(&train, &SvmConfig { epochs: 10, ..Default::default() });
        let acc = model.accuracy(&train);
        // Train accuracy is capped by the label-noise rate s itself.
        assert!(acc > 0.96 - s, "train accuracy {acc} (noise {s})");
    }

    #[test]
    fn generalizes_to_test_set() {
        let mut rng = Rng::new(2);
        let (all, _) = svm_cloud(6000, 20, 10.0, &mut rng);
        let (train, test) = all.split(0.5, &mut rng);
        let model = train_pf_svm(&train, &SvmConfig { epochs: 8, seed: 2, ..Default::default() });
        let acc = model.accuracy(&test);
        assert!(acc > 0.88, "test accuracy {acc}");
    }

    #[test]
    fn duals_nonnegative_and_kkt() {
        let mut rng = Rng::new(3);
        let (train, _) = svm_cloud(500, 5, 5.0, &mut rng);
        let model = train_pf_svm(&train, &SvmConfig { epochs: 20, seed: 3, ..Default::default() });
        for &zi in &model.z {
            assert!(zi >= 0.0);
        }
        // KKT: w = Σ_i z_i y_i x_i (gradient identity maintained by the
        // dual corrections); ξ_i = z_i / C.
        let d = train.d;
        let mut w_ref = vec![0.0; d];
        for i in 0..train.n {
            let yi = if train.y[i] == 1 { 1.0 } else { -1.0 };
            for (j, &xv) in train.row(i).iter().enumerate() {
                w_ref[j] += model.z[i] * yi * xv;
            }
        }
        for (a, b) in model.w.iter().zip(&w_ref) {
            assert!((a - b).abs() < 1e-8, "kkt: {a} vs {b}");
        }
        for i in 0..train.n {
            assert!((model.xi[i] - model.z[i] / 1e3).abs() < 1e-10);
        }
    }

    #[test]
    fn support_is_sparse_on_separable_data() {
        let mut rng = Rng::new(4);
        let (train, _) = svm_cloud(2000, 10, 50.0, &mut rng);
        let model = train_pf_svm(&train, &SvmConfig { epochs: 10, seed: 4, ..Default::default() });
        // Far-from-margin points never get projected onto.
        assert!(
            model.num_support() < train.n / 2,
            "support {} of {}",
            model.num_support(),
            train.n
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(5);
        let (train, _) = svm_cloud(300, 4, 5.0, &mut rng);
        let cfg = SvmConfig { epochs: 3, seed: 9, ..Default::default() };
        let a = train_pf_svm(&train, &cfg);
        let b = train_pf_svm(&train, &cfg);
        assert_eq!(a.w, b.w);
        assert_eq!(a.projections, b.projections);
    }

    #[test]
    fn noisier_data_lower_accuracy() {
        // Table 5's qualitative shape: accuracy degrades with s.
        let mut rng = Rng::new(6);
        let (clean, s1) = svm_cloud(4000, 20, 10.0, &mut rng);
        let (noisy, s2) = svm_cloud(4000, 20, 1.3, &mut rng);
        assert!(s1 < s2);
        let cfg = SvmConfig { epochs: 6, seed: 6, ..Default::default() };
        let (ctr, cte) = clean.split(0.5, &mut rng);
        let (ntr, nte) = noisy.split(0.5, &mut rng);
        let acc_clean = train_pf_svm(&ctr, &cfg).accuracy(&cte);
        let acc_noisy = train_pf_svm(&ntr, &cfg).accuracy(&nte);
        assert!(acc_clean > acc_noisy, "{acc_clean} !> {acc_noisy}");
    }
}
