//! Metric nearness (§4.1): given dissimilarities `d` on the edges of `G`,
//! find the closest point of MET(G) in the (weighted) L2 norm.
//!
//! `minimize ½ Σ_e w_e (x_e − d_e)²  s.t.  x ∈ MET(G)`
//!
//! Solved with PROJECT AND FORGET using the METRIC VIOLATIONS oracle in
//! project-on-find mode; per Algorithm 8 each discovered constraint is
//! projected onto once on discovery and once in the following sweep.

use super::metric_oracle::{MetricOracle, OracleMode};
use crate::core::bregman::{BregmanFunction, DiagonalQuadratic};
use crate::core::engine::SweepStrategy;
use crate::core::problem::{
    ErasedOverlappable, Lowered, Problem, SolveOptions, VectorOracle, VectorPart,
};
use crate::core::session::Session;
use crate::core::solver::SolverResult;
use crate::graph::generators::WeightedInstance;
use crate::graph::ingest::EdgeScope;
use crate::graph::Graph;
use std::sync::Arc;

/// Metric nearness as a [`Problem`]: find the closest point of MET(G)
/// to the instance's dissimilarities in the (weighted) L2 norm.
///
/// ```ignore
/// let res: NearnessResult = Nearness::new(&inst).solve(&SolveOptions::new());
/// // or batched with other instances:
/// let mut session = Session::new(SolveOptions::new().sharded(0));
/// let handles: Vec<_> = insts.iter().map(|i| session.add(Nearness::new(i))).collect();
/// session.run();
/// ```
pub struct Nearness<'a> {
    inst: &'a WeightedInstance,
    /// Per-edge norm weights (`None` = unweighted).
    norm_weights: Option<Vec<f64>>,
    /// Constraint delivery mode (the paper uses project-on-find).
    mode: OracleMode,
    /// Dirty-source incremental separation (Collect mode; identical
    /// findings, rescans only moved sources).
    incremental: bool,
    /// Optional geometric edge scope for the oracle (local metric
    /// repair; see [`MetricOracle::scope`]).
    scope: Option<Arc<EdgeScope>>,
}

impl<'a> Nearness<'a> {
    pub fn new(inst: &'a WeightedInstance) -> Nearness<'a> {
        Nearness {
            inst,
            norm_weights: None,
            mode: OracleMode::ProjectOnFind,
            incremental: true,
            scope: None,
        }
    }

    /// Constraint delivery mode; [`OracleMode::Collect`] additionally
    /// unlocks the oracle/sweep overlap (`SolveOptions::overlap`).
    pub fn mode(mut self, mode: OracleMode) -> Self {
        self.mode = mode;
        self
    }

    /// Toggle the oracle's dirty-source incremental scan (default on;
    /// `false` forces a full rescan every round — the ablation axis).
    pub fn incremental(mut self, on: bool) -> Self {
        self.incremental = on;
        self
    }

    /// Weighted norm `½ Σ_e w_e (x_e − d_e)²`.
    pub fn norm_weights(mut self, w: Option<Vec<f64>>) -> Self {
        self.norm_weights = w;
        self
    }

    /// Restrict the oracle's separation to an edge scope (geometric
    /// neighborhood repair; built by
    /// [`crate::graph::ingest::neighborhood_scope`]). Out-of-scope edges
    /// keep their input values apart from the `x ≥ 0` box.
    pub fn scope(mut self, scope: Option<Arc<EdgeScope>>) -> Self {
        self.scope = scope;
        self
    }

    /// One-shot convenience: solve this instance alone.
    pub fn solve(self, opts: &SolveOptions) -> NearnessResult {
        Session::solve_one(opts.clone(), self)
    }
}

impl<'a> Problem<'a> for Nearness<'a> {
    type Output = NearnessResult;

    fn lower(self, opts: &SolveOptions) -> Lowered<'a, NearnessResult> {
        let m = self.inst.graph.num_edges();
        let w = self.norm_weights.unwrap_or_else(|| vec![1.0; m]);
        let f = DiagonalQuadratic::new(self.inst.weights.clone(), w);
        let mut oracle = MetricOracle::new(Arc::new(self.inst.graph.clone()), self.mode);
        oracle.report_tol = (opts.violation_tol * 1e-3).max(1e-12);
        oracle.incremental = self.incremental;
        oracle.scope = self.scope.clone();
        // Shard-bucketed delivery helps exactly when the sharded engine
        // consumes it; sequential solves keep the historical slot order.
        oracle.shard_bucket = matches!(opts.sweep, SweepStrategy::ShardedParallel { .. });
        let oracle = if self.mode == OracleMode::Collect {
            // Collect scans are pure in the snapshot: overlappable.
            VectorOracle::Overlappable(ErasedOverlappable::new(oracle))
        } else {
            // ProjectOnFind mutates x while scanning: plain only.
            VectorOracle::Plain(Box::new(oracle))
        };
        // Algorithm 8: one extra sweep after the on-find projections.
        let config = opts.solver_config(1);
        Lowered::Vector(VectorPart {
            name: "nearness",
            f,
            oracle,
            config,
            interpret: Box::new(|f: &DiagonalQuadratic, result: SolverResult| {
                let objective = f.value(&result.x);
                NearnessResult { result, objective }
            }),
        })
    }
}

/// Options for a metric nearness solve.
#[deprecated(note = "use `Nearness` with `core::problem::SolveOptions` / `core::Session`")]
#[derive(Debug, Clone)]
pub struct NearnessConfig {
    /// Per-edge weights for the norm (None = unweighted).
    pub weights: Option<Vec<f64>>,
    /// Stop when the worst metric violation is below this.
    pub violation_tol: f64,
    /// Stop only when dual movement also falls below this
    /// (`INFINITY` reproduces the paper's violation-only stopping).
    pub dual_tol: f64,
    pub max_iters: usize,
    /// Constraint delivery mode (paper uses project-on-find).
    pub mode: OracleMode,
    pub record_trace: bool,
    /// Projection-sweep executor (sequential vs sharded parallel).
    pub sweep: SweepStrategy,
    /// Overlap the oracle's Dijkstra scan with the projection sweeps
    /// (`Solver::solve_overlapped`; Collect mode only — ignored for
    /// ProjectOnFind, whose scan mutates `x` as it goes). The scan then
    /// certifies the previous round's iterate, so convergence detection
    /// is one round more conservative.
    pub overlap: bool,
}

#[allow(deprecated)]
impl Default for NearnessConfig {
    fn default() -> Self {
        NearnessConfig {
            weights: None,
            violation_tol: 1e-2,
            dual_tol: f64::INFINITY,
            max_iters: 500,
            mode: OracleMode::ProjectOnFind,
            record_trace: true,
            sweep: SweepStrategy::Sequential,
            overlap: false,
        }
    }
}

#[allow(deprecated)]
impl NearnessConfig {
    /// The [`SolveOptions`] this legacy config maps onto.
    pub fn to_options(&self) -> SolveOptions {
        SolveOptions {
            max_iters: self.max_iters,
            violation_tol: self.violation_tol,
            dual_tol: self.dual_tol,
            record_trace: self.record_trace,
            sweep: self.sweep,
            overlap: self.overlap,
            ..SolveOptions::default()
        }
    }
}

/// Result: the nearest metric plus solve statistics.
#[derive(Debug, Clone)]
pub struct NearnessResult {
    pub result: SolverResult,
    /// ½‖x − d‖²_W at the solution.
    pub objective: f64,
}

/// Solve metric nearness on the instance's graph.
///
/// Thin wrapper over the [`Session`] API (bit-identical to it; pinned
/// in `tests/determinism.rs`).
#[deprecated(note = "use `Nearness::new(inst).solve(&opts)` or `core::Session`")]
#[allow(deprecated)]
pub fn solve_nearness(inst: &WeightedInstance, cfg: &NearnessConfig) -> NearnessResult {
    Nearness::new(inst)
        .mode(cfg.mode)
        .norm_weights(cfg.weights.clone())
        .solve(&cfg.to_options())
}

/// The *decrease-only* metric solution for the current iterate: the
/// all-pairs shortest-path closure of `x` restricted to the edges of `G`
/// (Gilbert & Jain 2017). Used by the paper's §8.2 convergence criterion
/// `‖x̂ − x‖₂ ≤ 1`.
pub fn decrease_only_metric(g: &Graph, x: &[f64]) -> Vec<f64> {
    let apsp = crate::graph::apsp::apsp_dijkstra(g, x, crate::util::pool::default_threads());
    g.edges()
        .iter()
        .map(|&(a, b)| apsp.get(a as usize, b as usize))
        .collect()
}

/// `‖decrease_only(x) − x‖₂` — the §8.2 convergence measure.
pub fn decrease_only_distance(g: &Graph, x: &[f64]) -> f64 {
    decrease_only_metric(g, x)
        .iter()
        .zip(x)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::core::solver::{Solver, SolverConfig};
    use crate::graph::generators::{type1_complete, type2_complete, type3_complete};
    use crate::problems::metric_oracle::max_metric_violation;
    use crate::util::Rng;

    fn tight() -> NearnessConfig {
        NearnessConfig { violation_tol: 1e-8, dual_tol: 1e-8, ..Default::default() }
    }

    #[test]
    fn type1_instance_solves_to_metric() {
        let mut rng = Rng::new(7);
        let inst = type1_complete(15, &mut rng);
        let res = solve_nearness(&inst, &tight());
        assert!(res.result.converged);
        assert!(max_metric_violation(&inst.graph, &res.result.x) < 1e-6);
        assert!(res.objective >= 0.0);
    }

    #[test]
    fn type2_and_type3_solve() {
        let mut rng = Rng::new(8);
        for inst in [type2_complete(12, &mut rng), type3_complete(12, &mut rng)] {
            let res = solve_nearness(&inst, &tight());
            assert!(res.result.converged);
            assert!(max_metric_violation(&inst.graph, &res.result.x) < 1e-6);
        }
    }

    #[test]
    fn optimality_vs_brute_force_qp() {
        // 4 nodes / 6 edges: check against a slow projected-cyclic
        // reference (exhaustive triangle constraints, many sweeps).
        let mut rng = Rng::new(9);
        let inst = type1_complete(4, &mut rng);
        let res = solve_nearness(&inst, &tight());
        // Reference: Dykstra over ALL triangle constraints of K_4.
        let g = &inst.graph;
        let mut cons = Vec::new();
        for i in 0..4u32 {
            for j in (i + 1)..4 {
                for k in 0..4u32 {
                    if k == i || k == j {
                        continue;
                    }
                    let e = g.edge_between(i as usize, j as usize).unwrap();
                    let p1 = g.edge_between(i as usize, k as usize).unwrap();
                    let p2 = g.edge_between(k as usize, j as usize).unwrap();
                    cons.push(crate::core::constraint::Constraint::cycle(e, &[p1, p2]));
                }
            }
        }
        for e in 0..6u32 {
            cons.push(crate::core::constraint::Constraint::nonneg(e));
        }
        let f = DiagonalQuadratic::unweighted(inst.weights.clone());
        let oracle = crate::core::oracle::ListOracle::new(cons);
        let mut sref = Solver::new(
            f,
            SolverConfig {
                max_iters: 20000,
                violation_tol: 1e-12,
                dual_tol: 1e-12,
                record_trace: false,
                ..Default::default()
            },
        );
        let rref = sref.solve(oracle);
        assert!(rref.converged);
        for (a, b) in res.result.x.iter().zip(&rref.x) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn decrease_only_distance_zero_for_metric() {
        let mut rng = Rng::new(10);
        let inst = type1_complete(10, &mut rng);
        let res = solve_nearness(&inst, &tight());
        let dd = decrease_only_distance(&inst.graph, &res.result.x);
        assert!(dd < 1e-6, "decrease-only distance {dd}");
    }

    #[test]
    fn weighted_nearness_respects_weights() {
        // A heavily weighted edge should move less.
        let mut rng = Rng::new(11);
        let inst = type1_complete(8, &mut rng);
        let uw = solve_nearness(&inst, &tight());
        let mut cfg = tight();
        let mut w = vec![1.0; inst.graph.num_edges()];
        w[0] = 1000.0;
        cfg.weights = Some(w);
        let hw = solve_nearness(&inst, &cfg);
        let move_uw = (uw.result.x[0] - inst.weights[0]).abs();
        let move_hw = (hw.result.x[0] - inst.weights[0]).abs();
        assert!(move_hw <= move_uw + 1e-9, "{move_hw} > {move_uw}");
    }

    #[test]
    fn works_on_non_complete_graphs() {
        // The paper notes P&F extends metric nearness to incomplete
        // graphs; build a sparse instance and check feasibility.
        let mut rng = Rng::new(12);
        let g = crate::graph::generators::erdos_renyi(20, 0.3, &mut rng);
        let weights: Vec<f64> = (0..g.num_edges()).map(|_| rng.normal().abs()).collect();
        let inst = WeightedInstance { graph: g, weights };
        let res = solve_nearness(&inst, &tight());
        assert!(res.result.converged);
        assert!(max_metric_violation(&inst.graph, &res.result.x) < 1e-6);
    }

    #[test]
    fn active_constraint_count_scales_like_n_squared() {
        // §4.1: "our algorithm consistently returns ~n² constraints".
        // At small n we just sanity-check the order of magnitude.
        let mut rng = Rng::new(13);
        let inst = type1_complete(16, &mut rng);
        let res = solve_nearness(&inst, &tight());
        let n = 16.0f64;
        let active = res.result.active_constraints as f64;
        assert!(active > n, "suspiciously few active constraints: {active}");
        assert!(active < n * n * 4.0, "suspiciously many: {active}");
    }
}
