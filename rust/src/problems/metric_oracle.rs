//! METRIC VIOLATIONS — the separation oracle for MET(G) (Algorithm 2).
//!
//! Given the iterate `x` as edge weights, run Dijkstra from every node; an
//! edge `(i, j)` with `x(i,j) > d(i,j)` witnesses a violated cycle
//! inequality `x(e) ≤ Σ_{ẽ ∈ P} x(ẽ)` where `P` is the shortest path.
//! This oracle satisfies Property 1 with `φ(t) = t/n` (Proposition 1) and
//! runs in `Θ(n² log n + n·|E|)`.
//!
//! Two delivery modes, matching the paper's implementations (§8):
//! - [`OracleMode::ProjectOnFind`] — project onto each violated cycle the
//!   moment it is found and remember it only if its dual stays nonzero
//!   (Algorithm 8; "much more efficient in practice ... also helps cut
//!   down on memory usage").
//! - [`OracleMode::Collect`] — deliver the whole list and let the solver
//!   sweep (Algorithm 7); Dijkstra runs are sharded across threads since
//!   nothing mutates `x` during the scan. The scan phase is also exposed
//!   on its own ([`MetricOracle::scan_cycles`] behind
//!   [`OverlappableOracle`]) so `Solver::solve_overlapped` can run it on
//!   the worker pool against a snapshot of `x` while the engine drains
//!   the current round's projection sweeps.
//!
//! The oracle also polices the non-metric faces of MET(G): `x ≥ 0` always,
//! plus optional `x ≤ ub` box rows (correlation clustering's `Ax ≤ b`);
//! these are the paper's never-forgotten "additional constraints" `L_a`,
//! re-delivered every round.

use crate::core::bregman::BregmanFunction;
use crate::core::constraint::Constraint;
use crate::core::oracle::{Oracle, OracleOutcome, OverlappableOracle, ProjectionSink};
use crate::graph::dijkstra::{dijkstra, DijkstraScratch};
use crate::graph::Graph;
use crate::util::pool::parallel_map_chunks;
use std::sync::Arc;

/// Constraint-delivery strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OracleMode {
    /// Algorithm 8: sequential scan, projecting as constraints are found.
    ProjectOnFind,
    /// Algorithms 6/7: collect the full violation list (threaded), then
    /// let the engine's sweeps handle projection.
    Collect,
}

/// The METRIC VIOLATIONS oracle over a fixed graph.
pub struct MetricOracle {
    pub graph: Arc<Graph>,
    pub mode: OracleMode,
    /// Worker threads for the Collect mode's Dijkstra shard.
    pub threads: usize,
    /// Violations below this are not reported (floating-point guard).
    pub report_tol: f64,
    /// Enforce `x ≥ 0` (always part of MET(G)).
    pub nonneg: bool,
    /// Optional upper bound per edge (correlation clustering's x ≤ 1).
    pub upper_bound: Option<f64>,
    /// Collect mode only: deliver the found constraints pre-bucketed by
    /// support-disjoint shard, so the engine's first-fit planner
    /// reconstructs the buckets as large shards. Off by default because
    /// it reorders delivery (and therefore slot order): the problem
    /// drivers enable it exactly when `SweepStrategy::ShardedParallel`
    /// is selected, keeping sequential solves bit-identical to the
    /// historical delivery order.
    pub shard_bucket: bool,
    scratch: DijkstraScratch,
}

impl MetricOracle {
    pub fn new(graph: Arc<Graph>, mode: OracleMode) -> MetricOracle {
        let n = graph.num_nodes();
        MetricOracle {
            graph,
            mode,
            threads: crate::util::pool::default_threads(),
            report_tol: 1e-12,
            nonneg: true,
            upper_bound: None,
            shard_bucket: false,
            scratch: DijkstraScratch::new(n),
        }
    }

    /// Deliver the box rows (`L_a`): projected every round, so their duals
    /// persist while needed and the rows are re-added if forgotten.
    fn deliver_box(&self, sink: &mut dyn ProjectionSink, out: &mut OracleOutcome) {
        let m = self.graph.num_edges();
        // One reused row mutated per edge (2m fresh Vecs per round is
        // measurable at CC scale — §Perf).
        if self.nonneg {
            let mut c = Constraint::nonneg(0);
            for e in 0..m {
                let v = -sink.x()[e];
                if v > self.report_tol {
                    out.max_violation = out.max_violation.max(v);
                    out.found += 1; // `found` counts violated rows only
                }
                // Delivered regardless of violation: satisfied rows with
                // z > 0 still need relaxation projections.
                c.indices[0] = e as u32;
                sink.project_and_remember(&c);
            }
        }
        if let Some(ub) = self.upper_bound {
            let mut c = Constraint::upper(0, ub);
            for e in 0..m {
                let v = sink.x()[e] - ub;
                if v > self.report_tol {
                    out.max_violation = out.max_violation.max(v);
                    out.found += 1;
                }
                c.indices[0] = e as u32;
                sink.project_and_remember(&c);
            }
        }
    }

    fn separate_on_find(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        // Box rows first: Dijkstra needs non-negative weights, so pull the
        // iterate inside MET(G)'s box faces before the cycle scan.
        self.deliver_box(sink, &mut out);
        let g = self.graph.clone();
        let n = g.num_nodes();
        // Clamped weight mirror of x, maintained *incrementally*: a
        // projection only touches its constraint's support, so refreshing
        // those entries is O(|support|) instead of O(m) per source.
        // (Transient negative entries mid-round would break Dijkstra, and
        // any cycle violated under the clamp is violated under x.)
        let mut w: Vec<f64> = sink.x().iter().map(|&v| v.max(0.0)).collect();
        // Reused buffers: the shortest path and the constraint row.
        let mut path: Vec<u32> = Vec::new();
        let mut cons = Constraint::new(vec![], vec![], 0.0);
        for src in 0..n {
            // Shortest paths under the *current* x (which earlier
            // projections this round may already have improved).
            dijkstra(&g, &w, src, &mut self.scratch);
            for &(nb, eid) in g.neighbors(src) {
                // Each undirected edge is scanned from its smaller endpoint.
                if (nb as usize) < src {
                    continue;
                }
                let viol = sink.x()[eid as usize] - self.scratch.dist[nb as usize];
                if viol > self.report_tol {
                    self.scratch.path_edges_into(nb as usize, &mut path);
                    // Degenerate case: the "path" is the edge itself.
                    if path.len() == 1 && path[0] == eid {
                        continue;
                    }
                    out.max_violation = out.max_violation.max(viol);
                    out.found += 1;
                    // Build the cycle row into the reused buffer.
                    cons.indices.clear();
                    cons.coeffs.clear();
                    cons.indices.push(eid);
                    cons.coeffs.push(1.0);
                    for &p in &path {
                        cons.indices.push(p);
                        cons.coeffs.push(-1.0);
                    }
                    cons.rhs = 0.0;
                    sink.project_and_remember(&cons);
                    // Refresh the clamped mirror on the touched support.
                    for &i in &cons.indices {
                        w[i as usize] = sink.x()[i as usize].max(0.0);
                    }
                }
            }
        }
        out
    }

    /// Read-only Collect scan: Dijkstra from every source against a
    /// clamped snapshot of `x`, returning the violated cycle rows in
    /// deterministic source order (per-source lists concatenated in
    /// source order — independent of chunking and of the pool's worker
    /// count). Safe to run concurrently with projection sweeps mutating
    /// a *different* buffer of the iterate; that is exactly what
    /// `Solver::solve_overlapped` does with it.
    pub fn scan_cycles(&self, x: &[f64]) -> MetricScan {
        let g = self.graph.clone();
        let n = g.num_nodes();
        // Clamp for Dijkstra; any cycle violated under the clamp is
        // violated under x itself.
        let w: Vec<f64> = x.iter().map(|&v| v.max(0.0)).collect();
        let tol = self.report_tol;
        let found = parallel_map_chunks(n, self.threads, |range| {
            let mut scratch = DijkstraScratch::new(n);
            let mut list: Vec<(f64, Constraint)> = Vec::new();
            for src in range {
                dijkstra(&g, &w, src, &mut scratch);
                for &(nb, eid) in g.neighbors(src) {
                    if (nb as usize) < src {
                        continue;
                    }
                    let viol = w[eid as usize] - scratch.dist[nb as usize];
                    if viol > tol {
                        let path = scratch.path_edges(nb as usize);
                        if path.len() == 1 && path[0] == eid {
                            continue;
                        }
                        list.push((viol, Constraint::cycle(eid, &path)));
                    }
                }
            }
            list
        });
        MetricScan { found: found.into_iter().flatten().collect() }
    }

    /// Count a scan into the certificate and hand its rows to the sink —
    /// in historical source order, or pre-bucketed by support-disjoint
    /// shard when `shard_bucket` is set.
    fn deliver_found(
        &self,
        mut all: Vec<(f64, Constraint)>,
        sink: &mut dyn ProjectionSink,
        out: &mut OracleOutcome,
    ) {
        for &(viol, _) in &all {
            out.max_violation = out.max_violation.max(viol);
            out.found += 1;
        }
        if !self.shard_bucket {
            // Historical delivery order (deterministic: per-source lists
            // concatenated in source order).
            for (_, c) in &all {
                sink.remember(c);
            }
        } else {
            // Deliver pre-bucketed by support-disjoint shard: consecutive
            // slots then form long disjoint runs, so the engine's
            // first-fit planner (which scans in slot order) reconstructs
            // these exact buckets as shards — bigger shards, cheaper
            // planning. The bucketing is the same epoch trick as the
            // planner; delivery order within a bucket follows discovery
            // order, so the set of delivered constraints is unchanged.
            let mut owner = vec![0u32; self.graph.num_edges()];
            let mut epoch = 0u32;
            let mut leftover: Vec<(f64, Constraint)> = Vec::new();
            const MAX_BUCKET_PASSES: u32 = 32;
            while !all.is_empty() {
                epoch += 1;
                if epoch > MAX_BUCKET_PASSES {
                    // Adversarial conflict chains: deliver the rest as-is.
                    for (_, c) in &all {
                        sink.remember(c);
                    }
                    break;
                }
                for (viol, c) in all.drain(..) {
                    if c.indices.iter().any(|&i| owner[i as usize] == epoch) {
                        leftover.push((viol, c));
                    } else {
                        for &i in &c.indices {
                            owner[i as usize] = epoch;
                        }
                        sink.remember(&c);
                    }
                }
                std::mem::swap(&mut all, &mut leftover);
            }
        }
    }

    fn separate_collect(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        // Box rows first: Dijkstra needs the iterate inside the box
        // faces before the cycle scan.
        self.deliver_box(sink, &mut out);
        let scan = self.scan_cycles(sink.x());
        self.deliver_found(scan.found, sink, &mut out);
        self.deliver_box(sink, &mut out);
        out
    }
}

/// Findings of one Collect-mode separation scan: the violated cycle rows
/// with their violations, in deterministic source order. Produced by
/// [`MetricOracle::scan_cycles`] — possibly on the worker pool, against
/// the back buffer of an overlapped solve — and consumed at the sweep
/// barrier by [`OverlappableOracle::deliver`].
pub struct MetricScan {
    found: Vec<(f64, Constraint)>,
}

impl MetricScan {
    /// Number of violated cycle rows found.
    pub fn len(&self) -> usize {
        self.found.len()
    }

    pub fn is_empty(&self) -> bool {
        self.found.is_empty()
    }
}

impl<F: BregmanFunction> OverlappableOracle<F> for MetricOracle {
    type Scan = MetricScan;

    fn scan(&self, x: &[f64]) -> MetricScan {
        self.scan_cycles(x)
    }

    /// Same shape as `separate_collect` with the scan factored out: box
    /// rows (measured against the *current* iterate), the scanned cycle
    /// rows (violations refer to the scanned snapshot), box rows again.
    fn deliver(&mut self, scan: MetricScan, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        self.deliver_box(sink, &mut out);
        self.deliver_found(scan.found, sink, &mut out);
        self.deliver_box(sink, &mut out);
        out
    }
}

impl<F: BregmanFunction> Oracle<F> for MetricOracle {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        match self.mode {
            OracleMode::ProjectOnFind => self.separate_on_find(sink),
            OracleMode::Collect => self.separate_collect(sink),
        }
    }

    fn name(&self) -> &str {
        "metric-violations"
    }
}

/// Check full metric feasibility of `x` on `G` up to `tol`: every edge
/// weight within `tol` of being ≤ its shortest-path distance, and
/// `x ≥ −tol`. (Test/diagnostic helper — runs a full APSP.)
pub fn max_metric_violation(g: &Graph, x: &[f64]) -> f64 {
    let mut worst = x.iter().cloned().fold(0.0f64, |acc, xi| acc.max(-xi));
    let mut scratch = DijkstraScratch::new(g.num_nodes());
    for src in 0..g.num_nodes() {
        dijkstra(g, x, src, &mut scratch);
        for &(nb, eid) in g.neighbors(src) {
            if (nb as usize) < src {
                continue;
            }
            worst = worst.max(x[eid as usize] - scratch.dist[nb as usize]);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bregman::DiagonalQuadratic;
    use crate::core::solver::{Solver, SolverConfig};
    use crate::util::Rng;

    fn solve_nearness_with(mode: OracleMode, n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let inst = crate::graph::generators::type1_complete(n, &mut rng);
        let g = Arc::new(inst.graph.clone());
        let f = DiagonalQuadratic::unweighted(inst.weights.clone());
        let oracle = MetricOracle::new(g, mode);
        let cfg = SolverConfig {
            max_iters: 300,
            inner_sweeps: 1,
            violation_tol: 1e-8,
            dual_tol: 1e-8,
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        let res = solver.solve(oracle);
        assert!(res.converged, "did not converge");
        (inst.weights, res.x)
    }

    #[test]
    fn output_is_metric_project_on_find() {
        let (_, x) = solve_nearness_with(OracleMode::ProjectOnFind, 12, 1);
        let g = Graph::complete(12);
        assert!(max_metric_violation(&g, &x) < 1e-6);
    }

    #[test]
    fn output_is_metric_collect() {
        let (_, x) = solve_nearness_with(OracleMode::Collect, 12, 2);
        let g = Graph::complete(12);
        assert!(max_metric_violation(&g, &x) < 1e-6);
    }

    #[test]
    fn modes_agree_on_optimum() {
        // Both modes solve the same strictly convex program, so the
        // optimal x must match regardless of constraint discovery order.
        let (_, xa) = solve_nearness_with(OracleMode::ProjectOnFind, 10, 3);
        let (_, xb) = solve_nearness_with(OracleMode::Collect, 10, 3);
        for (a, b) in xa.iter().zip(&xb) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn shard_bucketed_collect_reaches_same_optimum() {
        // Bucketing only permutes delivery order; the strictly convex
        // program still has one optimum, and the sharded engine must
        // agree with the plain sequential Collect solve.
        let mut rng = Rng::new(3);
        let inst = crate::graph::generators::type1_complete(10, &mut rng);
        let g = Arc::new(inst.graph.clone());
        let f = DiagonalQuadratic::unweighted(inst.weights.clone());
        let mut oracle = MetricOracle::new(g, OracleMode::Collect);
        oracle.shard_bucket = true;
        let cfg = SolverConfig {
            max_iters: 300,
            inner_sweeps: 1,
            violation_tol: 1e-8,
            dual_tol: 1e-8,
            sweep: crate::core::engine::SweepStrategy::ShardedParallel { threads: 2 },
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        let res = solver.solve(oracle);
        assert!(res.converged, "bucketed collect did not converge");
        let (_, xb) = solve_nearness_with(OracleMode::Collect, 10, 3);
        for (a, b) in res.x.iter().zip(&xb) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn already_metric_input_is_fixed_point() {
        // Build a metric input (shortest-path closure of a random graph)
        // and verify the solver returns it unchanged in one iteration.
        let mut rng = Rng::new(4);
        let inst = crate::graph::generators::type1_complete(9, &mut rng);
        let g = Arc::new(inst.graph.clone());
        let apsp = crate::graph::apsp::apsp_dense(&inst.graph, &inst.weights);
        let mut metric = inst.weights.clone();
        for (e, &(a, b)) in inst.graph.edges().iter().enumerate() {
            metric[e] = apsp.get(a as usize, b as usize);
        }
        let f = DiagonalQuadratic::unweighted(metric.clone());
        let oracle = MetricOracle::new(g, OracleMode::ProjectOnFind);
        let mut solver = Solver::new(
            f,
            SolverConfig { violation_tol: 1e-9, dual_tol: 1e-9, ..Default::default() },
        );
        let res = solver.solve(oracle);
        assert!(res.converged);
        assert_eq!(res.iterations, 1);
        for (a, b) in res.x.iter().zip(&metric) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn nonneg_enforced() {
        // Negative input weights must be lifted to ≥ 0.
        let g = Arc::new(Graph::complete(4));
        let d = vec![-1.0, 0.5, 0.5, 0.5, 0.5, 0.5];
        let f = DiagonalQuadratic::unweighted(d);
        let oracle = MetricOracle::new(g.clone(), OracleMode::ProjectOnFind);
        let mut solver = Solver::new(
            f,
            SolverConfig { violation_tol: 1e-9, dual_tol: 1e-9, ..Default::default() },
        );
        let res = solver.solve(oracle);
        assert!(res.converged);
        assert!(res.x.iter().all(|&v| v >= -1e-9), "{:?}", res.x);
    }

    #[test]
    fn upper_bound_box_respected() {
        let g = Arc::new(Graph::complete(4));
        let d = vec![2.0; 6];
        let f = DiagonalQuadratic::unweighted(d);
        let mut oracle = MetricOracle::new(g.clone(), OracleMode::ProjectOnFind);
        oracle.upper_bound = Some(1.0);
        let mut solver = Solver::new(
            f,
            SolverConfig { violation_tol: 1e-9, dual_tol: 1e-9, ..Default::default() },
        );
        let res = solver.solve(oracle);
        assert!(res.converged);
        assert!(res.x.iter().all(|&v| v <= 1.0 + 1e-9), "{:?}", res.x);
    }

    #[test]
    fn oracle_certifies_feasible_point() {
        let g = Arc::new(Graph::complete(5));
        // All-ones is a metric on K_5.
        let f = DiagonalQuadratic::unweighted(vec![1.0; 10]);
        let oracle = MetricOracle::new(g, OracleMode::Collect);
        let mut solver = Solver::new(f, SolverConfig::default());
        let res = solver.solve(oracle);
        assert!(res.converged);
        assert_eq!(res.iterations, 1);
    }
}
