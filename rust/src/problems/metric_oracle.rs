//! METRIC VIOLATIONS — the separation oracle for MET(G) (Algorithm 2).
//!
//! Given the iterate `x` as edge weights, run Dijkstra from every node; an
//! edge `(i, j)` with `x(i,j) > d(i,j)` witnesses a violated cycle
//! inequality `x(e) ≤ Σ_{ẽ ∈ P} x(ẽ)` where `P` is the shortest path.
//! This oracle satisfies Property 1 with `φ(t) = t/n` (Proposition 1) and
//! runs in `Θ(n² log n + n·|E|)` — *per full scan*. Two mechanisms make
//! the amortized cost scale with how much the iterate moved instead:
//!
//! - **Radius-bounded Dijkstra** (stateless, always on): a violation at
//!   `(src, nb)` needs `d(src, nb) < x_e`, and `x_e` is at most the
//!   maximum clamped weight incident to `src` — so every per-source run
//!   stops once the popped distance exceeds that radius
//!   ([`crate::graph::dijkstra::dijkstra_bounded`]), and a source whose
//!   radius is below the reporting tolerance is skipped outright.
//! - **Dirty-source incremental rescans** (Collect mode,
//!   [`MetricOracle::incremental`]): each scanned source persists its
//!   violated rows plus a *radius certificate* — the nodes settled
//!   within its radius, with their exact distances. A source is
//!   rescanned only when (a) one of its incident edges changed (they
//!   set both its radius and the compared weights), or (b) some changed
//!   edge could lie on a path entering its radius:
//!   `dist(src, endpoint) + min(w_old, w_new) ≤ radius` for an endpoint
//!   of the changed edge. The test is sound even for many simultaneous
//!   changes: on any new path of length ≤ radius, the *first* changed
//!   edge along it has a change-free prefix, so that prefix's length is
//!   the stored (old, settled, exact) distance of its endpoint — which
//!   is precisely what (b) bounds. Endpoints beyond the radius have old
//!   distance > radius, so treating them as ∞ is also sound. A clean
//!   source therefore sees unchanged distances, violation values and
//!   witness paths, and re-delivers its cached rows — identical to what
//!   a rescan would produce. (Degenerate caveat: if two distinct paths
//!   have *exactly* equal f64 length, the reported witness path — never
//!   the violated set or the certificate values — can depend on heap
//!   tie-breaking; exact collisions of distinct float path sums do not
//!   arise in the randomized pins and would only swap equivalent
//!   witnesses.) Changed coordinates come from the engine's movement
//!   log (the `ProjectionSink` movement seam) when it covers the
//!   window, else from an exact element-wise diff against the cached
//!   snapshot; the hint is intersected with the exact comparison, so
//!   both paths make identical rescan decisions. Certificates live
//!   under a memory budget ([`MetricOracle::incremental_budget_nodes`],
//!   counting stored `(node, dist)` entries); a source whose ball
//!   exceeds its share simply rescans every round.
//!
//! Two delivery modes, matching the paper's implementations (§8):
//! - [`OracleMode::ProjectOnFind`] — project onto each violated cycle the
//!   moment it is found and remember it only if its dual stays nonzero
//!   (Algorithm 8; "much more efficient in practice ... also helps cut
//!   down on memory usage").
//! - [`OracleMode::Collect`] — deliver the whole list and let the solver
//!   sweep (Algorithm 7); Dijkstra runs are sharded across threads since
//!   nothing mutates `x` during the scan. The scan phase is also exposed
//!   on its own ([`MetricOracle::scan_cycles`] behind
//!   [`OverlappableOracle`]) so `Solver::solve_overlapped` can run it on
//!   the worker pool against a snapshot of `x` while the engine drains
//!   the current round's projection sweeps.
//!
//! An optional **edge scope** ([`MetricOracle::scope`], built by
//! [`crate::graph::ingest::neighborhood_scope`] from a spatial index over
//! node coordinates) restricts which edges may be *reported* as violated:
//! out-of-scope edges are skipped in the radius computation and the
//! violation check, so the separation frontier narrows to a geometric
//! neighborhood. Shortest-path witnesses still run over the whole graph,
//! so every emitted row remains a genuine MET(G) inequality — the scope
//! never weakens a constraint, it only leaves out-of-scope violations
//! unrepaired (by design: the solve is a *local* metric repair). The
//! scope is fixed at construction, so the incremental cache stays sound:
//! a rescan of a clean source reproduces its cached (scoped) rows
//! exactly.
//!
//! The oracle also polices the non-metric faces of MET(G): `x ≥ 0` always,
//! plus optional `x ≤ ub` box rows (correlation clustering's `Ax ≤ b`);
//! these are the paper's never-forgotten "additional constraints" `L_a`,
//! re-delivered every round through the sink's fused
//! [`ProjectionSink::project_box`] pass (flat dual lookup, no per-row
//! content hashing). The box faces are delivered twice per Collect round
//! — before the cycle scan (Dijkstra needs the iterate inside the box)
//! and after it (so remembered box duals relax every round) — but only
//! the **first** pass counts into the round's certificate: the second
//! pass re-measures rows the first one already projected, and counting
//! them again double-reported `found` and could leak post-projection
//! residue into `max_violation`.

use crate::core::bregman::BregmanFunction;
use crate::core::constraint::Constraint;
use crate::core::oracle::{
    BoxKind, Oracle, OracleOutcome, OverlappableOracle, ProjectionSink,
};
use crate::graph::dijkstra::{dijkstra, dijkstra_auto, DijkstraScratch};
use crate::graph::ingest::EdgeScope;
use crate::graph::Graph;
use crate::util::pool::parallel_map_chunks;
use std::sync::Arc;

/// Constraint-delivery strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OracleMode {
    /// Algorithm 8: sequential scan, projecting as constraints are found.
    ProjectOnFind,
    /// Algorithms 6/7: collect the full violation list (threaded), then
    /// let the engine's sweeps handle projection.
    Collect,
}

/// Default memory budget for the incremental scan's radius
/// certificates, in stored `(node, dist)` entries across all sources
/// (16 bytes each after alignment; 16 Mi ≈ 256 MB worst case, far less
/// in practice — balls only reach the cap on huge dense instances, and
/// shrink as the iterate approaches the metric cone). Each source gets
/// an equal share `budget / n`; a ball larger than its share is simply
/// not certified and that source rescans every round — graceful
/// degradation, never wrong answers.
pub const DEFAULT_INCREMENTAL_BUDGET_NODES: usize = 16 << 20;

/// The METRIC VIOLATIONS oracle over a fixed graph.
pub struct MetricOracle {
    pub graph: Arc<Graph>,
    pub mode: OracleMode,
    /// Worker threads for the Collect mode's Dijkstra shard.
    pub threads: usize,
    /// Violations below this are not reported (floating-point guard).
    pub report_tol: f64,
    /// Enforce `x ≥ 0` (always part of MET(G)).
    pub nonneg: bool,
    /// Optional upper bound per edge (correlation clustering's x ≤ 1).
    pub upper_bound: Option<f64>,
    /// Collect mode only: deliver the found constraints pre-bucketed by
    /// support-disjoint shard, so the engine's first-fit planner
    /// reconstructs the buckets as large shards. Off by default because
    /// it reorders delivery (and therefore slot order): the problem
    /// drivers enable it exactly when `SweepStrategy::ShardedParallel`
    /// is selected, keeping sequential solves bit-identical to the
    /// historical delivery order.
    pub shard_bucket: bool,
    /// Collect mode only: persist per-source scan state across rounds
    /// and rescan only dirty sources (see the module docs). Findings are
    /// identical to a full rescan; `false` forces the full scan (the
    /// bench/ablation axis).
    pub incremental: bool,
    /// Memory budget for the radius certificates (see
    /// [`DEFAULT_INCREMENTAL_BUDGET_NODES`]).
    pub incremental_budget_nodes: usize,
    /// Optional geometric restriction: only in-scope edges are checked
    /// for (and reported as) violations. Witness paths still use the
    /// whole graph, so emitted rows stay valid MET(G) inequalities. Must
    /// be set before the first separation round and not changed after —
    /// the incremental cache assumes a fixed scope.
    pub scope: Option<Arc<EdgeScope>>,
    cache: Option<ScanCache>,
    scratch: DijkstraScratch,
}

/// One source's persisted scan state.
#[derive(Debug, Default, Clone)]
struct SourceState {
    /// Violated cycle rows found by this source's last rescan, in
    /// discovery order.
    found: Vec<(f64, Constraint)>,
    /// The radius certificate: every node settled within the source's
    /// radius at the last rescan (includes the source itself), with its
    /// exact distance. The distances make the staleness test
    /// *quantitative*: a moved edge `(u, v)` can affect this source only
    /// if `dist(src, u) + min(w_old, w_new) ≤ radius` for one of its
    /// endpoints — i.e. a path through the moved edge could enter the
    /// radius. (A boolean "endpoint in ball" test would degenerate on
    /// complete graphs, where every ball is all of `V`.)
    ball: Vec<(u32, f64)>,
    /// The radius the ball was computed for (max incident clamped
    /// weight at the last rescan; unchanged while no incident edge
    /// moves, which the staleness test checks first).
    radius: f64,
    /// `ball` is a valid certificate (it fit the per-source budget).
    /// Uncertified sources rescan every round.
    certified: bool,
}

/// The oracle's committed incremental state: per-source rows +
/// certificates, the iterate snapshot they were computed against, and
/// the movement-log cursor taken at that snapshot.
#[derive(Debug)]
struct ScanCache {
    x_prev: Vec<f64>,
    sources: Vec<SourceState>,
    cursor: Option<u64>,
}

/// Per-source outcome of one Collect scan.
enum SourceScan {
    /// Certified clean — the cache's rows for this source still hold.
    Cached,
    /// Rescanned (or never scanned): fresh rows + certificate.
    Fresh(SourceState),
}

/// Findings of one Collect-mode separation scan, in deterministic source
/// order. Produced by [`MetricOracle::scan_cycles`] — possibly on the
/// worker pool, against the back buffer of an overlapped solve — and
/// consumed at the sweep barrier by [`OverlappableOracle::deliver`],
/// which also commits the carried per-source state into the oracle's
/// cache ([`MetricOracle::commit_scan`]).
pub struct MetricScan {
    sources: Vec<SourceScan>,
    found: usize,
    rescanned: usize,
    /// Becomes the cache's `x_prev` at commit (`None` when incremental
    /// mode is off — committing then clears the cache).
    x_snapshot: Option<Vec<f64>>,
    cursor: Option<u64>,
}

impl MetricScan {
    /// Number of violated cycle rows found (cached + rescanned).
    pub fn len(&self) -> usize {
        self.found
    }

    pub fn is_empty(&self) -> bool {
        self.found == 0
    }

    /// Sources actually rescanned (the rest reused their certificates).
    pub fn rescanned(&self) -> usize {
        self.rescanned
    }
}

/// Rescan one source: radius-bounded Dijkstra + witness extraction +
/// (optionally) the radius certificate. Pure in `(g, w, src, tol)` —
/// runs on the worker pool.
fn rescan_source(
    g: &Graph,
    w: &[f64],
    src: usize,
    tol: f64,
    ball_cap: Option<usize>,
    scope: Option<&EdgeScope>,
    scratch: &mut DijkstraScratch,
) -> SourceState {
    let in_scope = |eid: u32| scope.map_or(true, |s| s.edge(eid as usize));
    // Radius over *in-scope* incident edges only: out-of-scope edges are
    // never checked for violations, so they must not inflate the bound.
    let mut radius = 0.0f64;
    for &(_, eid) in g.neighbors(src) {
        if in_scope(eid) {
            radius = radius.max(w[eid as usize]);
        }
    }
    let mut st = SourceState { radius, ..SourceState::default() };
    if radius <= tol {
        // No incident edge can witness a violation above tol
        // (viol = w_e − dist ≤ radius ≤ tol): skip the run outright.
        // The outcome depends only on the incident weights, and every
        // incident edge touches `src` — a one-node ball certifies it.
        if let Some(cap) = ball_cap {
            if cap >= 1 {
                st.ball.push((src as u32, 0.0));
                st.certified = true;
            }
        }
        return st;
    }
    dijkstra_auto(g, w, src, radius, scratch);
    for &(nb, eid) in g.neighbors(src) {
        if (nb as usize) < src || !in_scope(eid) {
            // Each undirected edge is scanned from its smaller endpoint;
            // out-of-scope edges are not candidates.
            continue;
        }
        let viol = w[eid as usize] - scratch.dist[nb as usize];
        if viol > tol {
            let path = scratch.path_edges(nb as usize);
            // Degenerate case: the "path" is the edge itself.
            if path.len() == 1 && path[0] == eid {
                continue;
            }
            st.found.push((viol, Constraint::cycle(eid, &path)));
        }
    }
    if let Some(cap) = ball_cap {
        let ball: Vec<(u32, f64)> = scratch
            .touched()
            .iter()
            .filter_map(|&v| {
                let d = scratch.dist[v as usize];
                (d <= radius).then_some((v, d))
            })
            .collect();
        if ball.len() <= cap {
            st.ball = ball;
            st.certified = true;
        }
    }
    st
}

impl MetricOracle {
    pub fn new(graph: Arc<Graph>, mode: OracleMode) -> MetricOracle {
        let n = graph.num_nodes();
        MetricOracle {
            graph,
            mode,
            threads: crate::util::pool::default_threads(),
            report_tol: 1e-12,
            nonneg: true,
            upper_bound: None,
            shard_bucket: false,
            incremental: true,
            incremental_budget_nodes: DEFAULT_INCREMENTAL_BUDGET_NODES,
            scope: None,
            cache: None,
            scratch: DijkstraScratch::new(n),
        }
    }

    /// Deliver the box rows (`L_a`) through the sink's fused pass:
    /// projected every round, so their duals persist while needed and
    /// the rows are re-added if forgotten. Only a `count`ing pass merges
    /// its witnesses into the round certificate — the second per-round
    /// pass still projects but must not double-count (see module docs).
    fn deliver_box(&self, sink: &mut dyn ProjectionSink, out: &mut OracleOutcome, count: bool) {
        let m = self.graph.num_edges();
        if self.nonneg {
            let b = sink.project_box(BoxKind::NonNeg, 0, m, 0.0, self.report_tol);
            if count {
                out.found += b.found;
                out.max_violation = out.max_violation.max(b.max_violation);
            }
        }
        if let Some(ub) = self.upper_bound {
            let b = sink.project_box(BoxKind::Upper, 0, m, ub, self.report_tol);
            if count {
                out.found += b.found;
                out.max_violation = out.max_violation.max(b.max_violation);
            }
        }
    }

    fn separate_on_find(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        // Box rows first: Dijkstra needs non-negative weights, so pull the
        // iterate inside MET(G)'s box faces before the cycle scan.
        self.deliver_box(sink, &mut out, true);
        let g = self.graph.clone();
        let n = g.num_nodes();
        // Clamped weight mirror of x, maintained *incrementally*: a
        // projection only touches its constraint's support, so refreshing
        // those entries is O(|support|) instead of O(m) per source.
        // (Transient negative entries mid-round would break Dijkstra, and
        // any cycle violated under the clamp is violated under x.)
        let mut w: Vec<f64> = sink.x().iter().map(|&v| v.max(0.0)).collect();
        // Reused buffers: the shortest path and the constraint row.
        let mut path: Vec<u32> = Vec::new();
        let mut cons = Constraint::new(vec![], vec![], 0.0);
        let scope = self.scope.clone();
        let in_scope = |eid: u32| scope.as_deref().map_or(true, |s| s.edge(eid as usize));
        for src in 0..n {
            // Radius bound: x_e ≤ w_e ≤ radius for every in-scope
            // incident edge, so no reportable violation can live past it
            // — and a source whose radius is within the reporting
            // tolerance has nothing to report at all.
            let mut radius = 0.0f64;
            for &(_, eid) in g.neighbors(src) {
                if in_scope(eid) {
                    radius = radius.max(w[eid as usize]);
                }
            }
            if radius <= self.report_tol {
                continue;
            }
            // Shortest paths under the *current* x (which earlier
            // projections this round may already have improved).
            dijkstra_auto(&g, &w, src, radius, &mut self.scratch);
            for &(nb, eid) in g.neighbors(src) {
                // Each undirected edge is scanned from its smaller
                // endpoint; out-of-scope edges are not candidates.
                if (nb as usize) < src || !in_scope(eid) {
                    continue;
                }
                let viol = sink.x()[eid as usize] - self.scratch.dist[nb as usize];
                if viol > self.report_tol {
                    self.scratch.path_edges_into(nb as usize, &mut path);
                    // Degenerate case: the "path" is the edge itself.
                    if path.len() == 1 && path[0] == eid {
                        continue;
                    }
                    out.max_violation = out.max_violation.max(viol);
                    out.found += 1;
                    // Build the cycle row into the reused buffer.
                    cons.indices.clear();
                    cons.coeffs.clear();
                    cons.indices.push(eid);
                    cons.coeffs.push(1.0);
                    for &p in &path {
                        cons.indices.push(p);
                        cons.coeffs.push(-1.0);
                    }
                    cons.rhs = 0.0;
                    sink.project_and_remember(&cons);
                    // Refresh the clamped mirror on the touched support.
                    for &i in &cons.indices {
                        w[i as usize] = sink.x()[i as usize].max(0.0);
                    }
                }
            }
        }
        out
    }

    /// Read-only Collect scan: radius-bounded Dijkstra from every dirty
    /// source against a clamped snapshot of `x`, cached rows for every
    /// certified-clean source, returning the violated cycle rows in
    /// deterministic source order (per-source lists concatenated in
    /// source order — independent of chunking and of the pool's worker
    /// count, and independent of *which* dirty derivation skipped which
    /// source, since a clean rescan reproduces its cached rows bit for
    /// bit). Safe to run concurrently with projection sweeps mutating a
    /// *different* buffer of the iterate; that is exactly what
    /// `Solver::solve_overlapped` does with it.
    pub fn scan_cycles(&self, x: &[f64]) -> MetricScan {
        self.scan_with(x, None, None)
    }

    /// The scan core. `moved_hint` is a superset of the coordinates that
    /// changed since the cache snapshot (from the engine's movement
    /// log); `None` falls back to the exact element-wise diff. `cursor`
    /// is carried into the new cache for the *next* round's hint.
    fn scan_with(
        &self,
        x: &[f64],
        moved_hint: Option<&[u32]>,
        cursor: Option<u64>,
    ) -> MetricScan {
        let mut scan_span = crate::obs::span(crate::obs::SpanKind::OracleScan);
        let g = &*self.graph;
        let n = g.num_nodes();
        let m = g.num_edges();
        debug_assert_eq!(x.len(), m);
        // Clamp for Dijkstra; any cycle violated under the clamp is
        // violated under x itself.
        let w: Vec<f64> = x.iter().map(|&v| v.max(0.0)).collect();
        let tol = self.report_tol;
        let incremental = self.incremental;
        // A usable cache must match this graph's shape.
        let cache = if incremental {
            self.cache.as_ref().filter(|c| c.x_prev.len() == m && c.sources.len() == n)
        } else {
            None
        };
        // Per-node "reach" of the movement since the cache snapshot:
        // `reach[t]` = the smallest min(old, new) clamped weight over
        // the *changed* edges incident to `t` (∞ when none changed).
        // The movement hint is a superset of the changed set, so it is
        // intersected with the exact element-wise comparison — hint and
        // diff paths therefore compute the identical array (the hint
        // only bounds how many coordinates are examined).
        let reach: Option<Vec<f64>> = cache.map(|c| {
            let mut reach = vec![f64::INFINITY; n];
            let mut mark = |reach: &mut [f64], e: usize| {
                let wmin = x[e].max(0.0).min(c.x_prev[e].max(0.0));
                let (a, b) = g.edges()[e];
                if wmin < reach[a as usize] {
                    reach[a as usize] = wmin;
                }
                if wmin < reach[b as usize] {
                    reach[b as usize] = wmin;
                }
            };
            match moved_hint {
                Some(coords) => {
                    for &e in coords {
                        if (e as usize) < m && x[e as usize] != c.x_prev[e as usize] {
                            mark(&mut reach, e as usize);
                        }
                    }
                }
                None => {
                    for (e, (&xe, &pe)) in x.iter().zip(&c.x_prev).enumerate() {
                        if xe != pe {
                            mark(&mut reach, e);
                        }
                    }
                }
            }
            reach
        });
        let per_source_cap =
            if incremental && n > 0 { self.incremental_budget_nodes / n } else { 0 };
        let reach_ref = reach.as_ref();
        let scope = self.scope.as_deref();
        let per_chunk: Vec<Vec<SourceScan>> = parallel_map_chunks(n, self.threads, |range| {
            // Chunk-level span: lands in the executing pool worker's
            // thread buffer, so the trace shows per-worker scan rows.
            let mut chunk_span = crate::obs::span(crate::obs::SpanKind::OracleScan);
            let chunk_len = range.len();
            let mut scratch = DijkstraScratch::new(n);
            let mut out: Vec<SourceScan> = Vec::with_capacity(chunk_len);
            for src in range {
                if let (Some(c), Some(reach)) = (cache, reach_ref) {
                    // The staleness test (see the module docs): rescan
                    // iff an incident edge changed (the radius and the
                    // compared weights depend on them), or a changed
                    // edge could lie on a path entering this source's
                    // radius — its endpoint's settled distance plus the
                    // smaller of its old/new weight reaches the radius.
                    // `≤` (not `<`) also catches exact-tie paths.
                    let st = &c.sources[src];
                    if st.certified
                        && reach[src].is_infinite()
                        && !st
                            .ball
                            .iter()
                            .any(|&(t, d)| d + reach[t as usize] <= st.radius)
                    {
                        out.push(SourceScan::Cached);
                        continue;
                    }
                }
                out.push(SourceScan::Fresh(rescan_source(
                    g,
                    &w,
                    src,
                    tol,
                    incremental.then_some(per_source_cap),
                    scope,
                    &mut scratch,
                )));
            }
            if let Some(sp) = chunk_span.as_mut() {
                let fresh =
                    out.iter().filter(|s| matches!(s, SourceScan::Fresh(_))).count();
                sp.counts(chunk_len as u64, fresh as u64);
            }
            out
        });
        let sources: Vec<SourceScan> = per_chunk.into_iter().flatten().collect();
        let mut found = 0;
        let mut rescanned = 0;
        for (src, s) in sources.iter().enumerate() {
            match s {
                SourceScan::Cached => {
                    found += cache.expect("cached source without a cache").sources[src]
                        .found
                        .len()
                }
                SourceScan::Fresh(st) => {
                    found += st.found.len();
                    rescanned += 1;
                }
            }
        }
        if let Some(sp) = scan_span.as_mut() {
            sp.counts(found as u64, rescanned as u64);
        }
        MetricScan {
            sources,
            found,
            rescanned,
            x_snapshot: incremental.then(|| x.to_vec()),
            cursor,
        }
    }

    /// Movement hint for the next scan: the engine's dirty log since the
    /// cache's cursor, when the sink tracks movement and the window is
    /// still covered.
    fn movement_hint(&self, sink: &dyn ProjectionSink) -> Option<Vec<u32>> {
        let cursor = self.cache.as_ref()?.cursor?;
        let mut buf = Vec::new();
        sink.moved_since(cursor, &mut buf).then_some(buf)
    }

    /// Commit a scan's per-source state into the incremental cache. The
    /// deliver path does this automatically; benches and tests that
    /// drive [`MetricOracle::scan_cycles`] directly call it by hand. A
    /// scan taken with incremental mode off clears the cache.
    pub fn commit_scan(&mut self, scan: MetricScan) {
        let Some(x_prev) = scan.x_snapshot else {
            self.cache = None;
            return;
        };
        let n = self.graph.num_nodes();
        let mut cache = match self.cache.take() {
            Some(c) if c.sources.len() == n => c,
            _ => ScanCache {
                x_prev: Vec::new(),
                sources: (0..n).map(|_| SourceState::default()).collect(),
                cursor: None,
            },
        };
        cache.x_prev = x_prev;
        cache.cursor = scan.cursor;
        for (src, s) in scan.sources.into_iter().enumerate() {
            if let SourceScan::Fresh(st) = s {
                cache.sources[src] = st;
            }
        }
        self.cache = Some(cache);
    }

    /// Count a scan's rows into the certificate and hand them to the
    /// sink — in historical source order, or pre-bucketed by
    /// support-disjoint shard when `shard_bucket` is set.
    fn deliver_found(
        &self,
        all: Vec<&(f64, Constraint)>,
        sink: &mut dyn ProjectionSink,
        out: &mut OracleOutcome,
    ) {
        for e in &all {
            out.max_violation = out.max_violation.max(e.0);
            out.found += 1;
        }
        if !self.shard_bucket {
            // Historical delivery order (deterministic: per-source lists
            // concatenated in source order).
            for e in all {
                sink.remember(&e.1);
            }
        } else {
            // Deliver pre-bucketed by support-disjoint shard: consecutive
            // slots then form long disjoint runs, so the engine's
            // first-fit planner (which scans in slot order) reconstructs
            // these exact buckets as shards — bigger shards, cheaper
            // planning. The bucketing is the same epoch trick as the
            // planner; delivery order within a bucket follows discovery
            // order, so the set of delivered constraints is unchanged.
            let mut owner = vec![0u32; self.graph.num_edges()];
            let mut epoch = 0u32;
            let mut all = all;
            let mut leftover: Vec<&(f64, Constraint)> = Vec::new();
            const MAX_BUCKET_PASSES: u32 = 32;
            while !all.is_empty() {
                epoch += 1;
                if epoch > MAX_BUCKET_PASSES {
                    // Adversarial conflict chains: deliver the rest as-is.
                    for e in &all {
                        sink.remember(&e.1);
                    }
                    break;
                }
                for e in all.drain(..) {
                    let c = &e.1;
                    if c.indices.iter().any(|&i| owner[i as usize] == epoch) {
                        leftover.push(e);
                    } else {
                        for &i in &c.indices {
                            owner[i as usize] = epoch;
                        }
                        sink.remember(c);
                    }
                }
                std::mem::swap(&mut all, &mut leftover);
            }
        }
    }

    /// Shared tail of a Collect round: deliver the scan's rows (cached +
    /// fresh, in source order), commit the carried per-source state, run
    /// the second (non-counting) box pass.
    fn deliver_tail(
        &mut self,
        scan: MetricScan,
        sink: &mut dyn ProjectionSink,
        out: &mut OracleOutcome,
    ) {
        {
            let cache = self.cache.as_ref();
            let mut rows: Vec<&(f64, Constraint)> = Vec::with_capacity(scan.found);
            for (src, s) in scan.sources.iter().enumerate() {
                match s {
                    SourceScan::Cached => rows.extend(
                        cache.expect("cached source without a cache").sources[src].found.iter(),
                    ),
                    SourceScan::Fresh(st) => rows.extend(st.found.iter()),
                }
            }
            self.deliver_found(rows, sink, out);
        }
        self.commit_scan(scan);
        self.deliver_box(sink, out, false);
    }

    fn separate_collect(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        // Box rows first: Dijkstra needs the iterate inside the box
        // faces before the cycle scan.
        self.deliver_box(sink, &mut out, true);
        let scan = {
            // The cursor is read *after* the box pass so its window
            // starts exactly at the snapshot the scan sees.
            let cursor = sink.movement_cursor();
            let hint = self.movement_hint(&*sink);
            self.scan_with(sink.x(), hint.as_deref(), cursor)
        };
        self.deliver_tail(scan, sink, &mut out);
        out
    }
}

impl<F: BregmanFunction> OverlappableOracle<F> for MetricOracle {
    type Scan = MetricScan;

    fn scan(&self, x: &[f64]) -> MetricScan {
        // The overlapped scan runs detached from any sink, so the dirty
        // set always comes from the exact snapshot diff (no cursor).
        self.scan_with(x, None, None)
    }

    /// Same shape as `separate_collect` with the scan factored out: box
    /// rows (measured against the *current* iterate), the scanned cycle
    /// rows (violations refer to the scanned snapshot), box rows again
    /// (projection only — the round was already counted).
    fn deliver(&mut self, scan: MetricScan, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        self.deliver_box(sink, &mut out, true);
        self.deliver_tail(scan, sink, &mut out);
        out
    }
}

impl<F: BregmanFunction> Oracle<F> for MetricOracle {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        match self.mode {
            OracleMode::ProjectOnFind => self.separate_on_find(sink),
            OracleMode::Collect => self.separate_collect(sink),
        }
    }

    fn name(&self) -> &str {
        "metric-violations"
    }
}

/// Check full metric feasibility of `x` on `G` up to `tol`: every edge
/// weight within `tol` of being ≤ its shortest-path distance, and
/// `x ≥ −tol`. (Test/diagnostic helper — runs a full APSP.)
pub fn max_metric_violation(g: &Graph, x: &[f64]) -> f64 {
    let mut worst = x.iter().cloned().fold(0.0f64, |acc, xi| acc.max(-xi));
    let mut scratch = DijkstraScratch::new(g.num_nodes());
    for src in 0..g.num_nodes() {
        dijkstra(g, x, src, &mut scratch);
        for &(nb, eid) in g.neighbors(src) {
            if (nb as usize) < src {
                continue;
            }
            worst = worst.max(x[eid as usize] - scratch.dist[nb as usize]);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bregman::DiagonalQuadratic;
    use crate::core::solver::{Solver, SolverConfig};
    use crate::util::Rng;

    fn solve_nearness_with(mode: OracleMode, n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let inst = crate::graph::generators::type1_complete(n, &mut rng);
        let g = Arc::new(inst.graph.clone());
        let f = DiagonalQuadratic::unweighted(inst.weights.clone());
        let oracle = MetricOracle::new(g, mode);
        let cfg = SolverConfig {
            max_iters: 300,
            inner_sweeps: 1,
            violation_tol: 1e-8,
            dual_tol: 1e-8,
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        let res = solver.solve(oracle);
        assert!(res.converged, "did not converge");
        (inst.weights, res.x)
    }

    #[test]
    fn output_is_metric_project_on_find() {
        let (_, x) = solve_nearness_with(OracleMode::ProjectOnFind, 12, 1);
        let g = Graph::complete(12);
        assert!(max_metric_violation(&g, &x) < 1e-6);
    }

    #[test]
    fn output_is_metric_collect() {
        let (_, x) = solve_nearness_with(OracleMode::Collect, 12, 2);
        let g = Graph::complete(12);
        assert!(max_metric_violation(&g, &x) < 1e-6);
    }

    #[test]
    fn modes_agree_on_optimum() {
        // Both modes solve the same strictly convex program, so the
        // optimal x must match regardless of constraint discovery order.
        let (_, xa) = solve_nearness_with(OracleMode::ProjectOnFind, 10, 3);
        let (_, xb) = solve_nearness_with(OracleMode::Collect, 10, 3);
        for (a, b) in xa.iter().zip(&xb) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn shard_bucketed_collect_reaches_same_optimum() {
        // Bucketing only permutes delivery order; the strictly convex
        // program still has one optimum, and the sharded engine must
        // agree with the plain sequential Collect solve.
        let mut rng = Rng::new(3);
        let inst = crate::graph::generators::type1_complete(10, &mut rng);
        let g = Arc::new(inst.graph.clone());
        let f = DiagonalQuadratic::unweighted(inst.weights.clone());
        let mut oracle = MetricOracle::new(g, OracleMode::Collect);
        oracle.shard_bucket = true;
        let cfg = SolverConfig {
            max_iters: 300,
            inner_sweeps: 1,
            violation_tol: 1e-8,
            dual_tol: 1e-8,
            sweep: crate::core::engine::SweepStrategy::ShardedParallel { threads: 2 },
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        let res = solver.solve(oracle);
        assert!(res.converged, "bucketed collect did not converge");
        let (_, xb) = solve_nearness_with(OracleMode::Collect, 10, 3);
        for (a, b) in res.x.iter().zip(&xb) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn already_metric_input_is_fixed_point() {
        // Build a metric input (shortest-path closure of a random graph)
        // and verify the solver returns it unchanged in one iteration.
        let mut rng = Rng::new(4);
        let inst = crate::graph::generators::type1_complete(9, &mut rng);
        let g = Arc::new(inst.graph.clone());
        let apsp = crate::graph::apsp::apsp_dense(&inst.graph, &inst.weights);
        let mut metric = inst.weights.clone();
        for (e, &(a, b)) in inst.graph.edges().iter().enumerate() {
            metric[e] = apsp.get(a as usize, b as usize);
        }
        let f = DiagonalQuadratic::unweighted(metric.clone());
        let oracle = MetricOracle::new(g, OracleMode::ProjectOnFind);
        let mut solver = Solver::new(
            f,
            SolverConfig { violation_tol: 1e-9, dual_tol: 1e-9, ..Default::default() },
        );
        let res = solver.solve(oracle);
        assert!(res.converged);
        assert_eq!(res.iterations, 1);
        for (a, b) in res.x.iter().zip(&metric) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn nonneg_enforced() {
        // Negative input weights must be lifted to ≥ 0.
        let g = Arc::new(Graph::complete(4));
        let d = vec![-1.0, 0.5, 0.5, 0.5, 0.5, 0.5];
        let f = DiagonalQuadratic::unweighted(d);
        let oracle = MetricOracle::new(g.clone(), OracleMode::ProjectOnFind);
        let mut solver = Solver::new(
            f,
            SolverConfig { violation_tol: 1e-9, dual_tol: 1e-9, ..Default::default() },
        );
        let res = solver.solve(oracle);
        assert!(res.converged);
        assert!(res.x.iter().all(|&v| v >= -1e-9), "{:?}", res.x);
    }

    #[test]
    fn upper_bound_box_respected() {
        let g = Arc::new(Graph::complete(4));
        let d = vec![2.0; 6];
        let f = DiagonalQuadratic::unweighted(d);
        let mut oracle = MetricOracle::new(g.clone(), OracleMode::ProjectOnFind);
        oracle.upper_bound = Some(1.0);
        let mut solver = Solver::new(
            f,
            SolverConfig { violation_tol: 1e-9, dual_tol: 1e-9, ..Default::default() },
        );
        let res = solver.solve(oracle);
        assert!(res.converged);
        assert!(res.x.iter().all(|&v| v <= 1.0 + 1e-9), "{:?}", res.x);
    }

    #[test]
    fn oracle_certifies_feasible_point() {
        let g = Arc::new(Graph::complete(5));
        // All-ones is a metric on K_5.
        let f = DiagonalQuadratic::unweighted(vec![1.0; 10]);
        let oracle = MetricOracle::new(g, OracleMode::Collect);
        let mut solver = Solver::new(f, SolverConfig::default());
        let res = solver.solve(oracle);
        assert!(res.converged);
        assert_eq!(res.iterations, 1);
    }

    #[test]
    fn incremental_scan_equals_full_scan_rows() {
        // Warm the cache, perturb a few coordinates, and pin that the
        // incremental scan's delivered rows (and certificate) match a
        // from-scratch full scan of the same iterate exactly.
        let mut rng = Rng::new(17);
        let inst = crate::graph::generators::type1_complete(16, &mut rng);
        let g = Arc::new(inst.graph.clone());
        let m = g.num_edges();
        let mut warm = MetricOracle::new(g.clone(), OracleMode::Collect);
        let mut cold = MetricOracle::new(g.clone(), OracleMode::Collect);
        cold.incremental = false;
        let mut x = inst.weights.clone();
        for round in 0..12 {
            let inc = warm.scan_cycles(&x);
            let full = cold.scan_cycles(&x);
            assert_eq!(inc.len(), full.len(), "round {round}: found count diverged");
            let collect = |scan: &MetricScan, oracle: &MetricOracle| -> Vec<(u64, Constraint)> {
                let mut rows = Vec::new();
                for (src, s) in scan.sources.iter().enumerate() {
                    let list = match s {
                        SourceScan::Cached => {
                            &oracle.cache.as_ref().unwrap().sources[src].found
                        }
                        SourceScan::Fresh(st) => &st.found,
                    };
                    for (v, c) in list {
                        rows.push((v.to_bits(), c.clone()));
                    }
                }
                rows
            };
            assert_eq!(
                collect(&inc, &warm),
                collect(&full, &cold),
                "round {round}: rows diverged"
            );
            warm.commit_scan(inc);
            cold.commit_scan(full);
            // Randomized sweep-like perturbation: a few coordinates move.
            for _ in 0..3 {
                let e = rng.below(m);
                x[e] = (x[e] + rng.uniform(-0.3, 0.3)).max(-0.2);
            }
        }
    }

    #[test]
    fn unperturbed_rescan_skips_and_movement_stays_local() {
        // Unit-weight path graph: source v's radius is 1, so its ball is
        // {v−1, v, v+1} with distances {1, 0, 1} — movement on a far
        // edge must not rescan it.
        let n = 12usize;
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        let g = Arc::new(Graph::from_edges(n, &edges));
        let m = g.num_edges();
        let mut oracle = MetricOracle::new(g, OracleMode::Collect);
        let x = vec![1.0; m];
        let first = oracle.scan_cycles(&x);
        assert_eq!(first.rescanned(), n, "cold cache must scan everything");
        oracle.commit_scan(first);
        let second = oracle.scan_cycles(&x);
        assert_eq!(second.rescanned(), 0, "clean iterate must skip every source");
        oracle.commit_scan(second);
        // Increase the last edge (nodes 10–11): only its incident
        // sources (10, 11) rescan. Source 9 stays clean even though
        // node 10 is in its ball — the quantitative test knows a path
        // through the moved edge (dist 1 + weight 1) overshoots its
        // radius 1.
        let mut moved = x.clone();
        moved[m - 1] += 0.25;
        let third = oracle.scan_cycles(&moved);
        assert_eq!(
            third.rescanned(),
            2,
            "an incident-only change must rescan exactly the edge's endpoints"
        );
        oracle.commit_scan(third);
        // Shrink a middle edge (5, 6) to 0.1: its endpoints rescan
        // (incident), while source 4 stays clean — the cheapest path
        // through the shrunk edge still needs dist(4, 5) + 0.1 = 1.1,
        // which overshoots its radius 1.
        let mut shrunk = moved.clone();
        shrunk[5] = 0.1; // edge (5, 6)
        let fourth = oracle.scan_cycles(&shrunk);
        assert_eq!(fourth.rescanned(), 2, "a local shrink must rescan only its endpoints");
    }

    #[test]
    fn scoped_oracle_reports_only_in_scope_violations() {
        // Triangle with one violated edge: (0,2) at 3.0 vs the two-hop
        // path 0-1-2 of length 2.0.
        let g = Arc::new(Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]));
        let x = vec![1.0, 1.0, 3.0];
        let full = MetricOracle::new(g.clone(), OracleMode::Collect);
        assert_eq!(full.scan_cycles(&x).len(), 1);
        // Masking the violated edge out of scope hides it...
        let mut masked = MetricOracle::new(g.clone(), OracleMode::Collect);
        masked.scope = Some(Arc::new(EdgeScope::from_edge_mask(vec![true, true, false])));
        assert_eq!(masked.scan_cycles(&x).len(), 0);
        // ...while an all-edges scope matches the unscoped oracle.
        let mut all = MetricOracle::new(g, OracleMode::Collect);
        all.scope = Some(Arc::new(EdgeScope::all(3)));
        assert_eq!(all.scan_cycles(&x).len(), 1);
    }

    #[test]
    fn scoped_solve_repairs_in_scope_only() {
        // ProjectOnFind path: the scoped solve must fix the in-scope
        // violation while leaving the out-of-scope edge untouched.
        let g = Arc::new(Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3), (1, 3)]));
        // Edge (0,2) violated (3 > 1+1); edge (1,3) violated (3 > 1+1).
        let d = vec![1.0, 1.0, 3.0, 1.0, 3.0];
        let f = DiagonalQuadratic::unweighted(d.clone());
        let mut oracle = MetricOracle::new(g.clone(), OracleMode::ProjectOnFind);
        // Scope admits everything except edge 4 = (1,3).
        oracle.scope =
            Some(Arc::new(EdgeScope::from_edge_mask(vec![true, true, true, true, false])));
        let mut solver = Solver::new(
            f,
            SolverConfig { violation_tol: 1e-9, dual_tol: 1e-9, ..Default::default() },
        );
        let res = solver.solve(oracle);
        assert!(res.converged);
        // In-scope triangle 0-1-2 is repaired...
        assert!(res.x[2] <= res.x[0] + res.x[1] + 1e-6, "{:?}", res.x);
        assert!(res.x[2] < 3.0 - 1e-3, "in-scope violation untouched: {:?}", res.x);
        // ...the out-of-scope edge keeps its input value (nonneg box
        // aside, nothing projects it).
        assert!((res.x[4] - 3.0).abs() < 1e-9, "out-of-scope edge moved: {:?}", res.x);
    }

    #[test]
    fn budget_overflow_degrades_to_full_rescans() {
        let mut rng = Rng::new(19);
        let inst = crate::graph::generators::type1_complete(10, &mut rng);
        let g = Arc::new(inst.graph.clone());
        let mut oracle = MetricOracle::new(g, OracleMode::Collect);
        oracle.incremental_budget_nodes = 0; // nothing fits: no certificates
        let x = inst.weights.clone();
        let first = oracle.scan_cycles(&x);
        oracle.commit_scan(first);
        let second = oracle.scan_cycles(&x);
        assert_eq!(
            second.rescanned(),
            10,
            "uncertified sources must rescan even on a clean iterate"
        );
    }
}
