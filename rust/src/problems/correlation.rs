//! Weighted correlation clustering (§4.2): LP relaxation via the Veldt
//! et al. (2019) transform, solved over MET(G) with PROJECT AND FORGET.
//!
//! The LP (4.1) `min Σ_e w⁺ x_e + w⁻ (1−x_e)` over the metric polytope
//! with `x ∈ [0,1]` is replaced by the strictly convex program (4.2)
//!
//! `min  w̃ᵀ|x−d| + (1/γ)|x−d|ᵀ W |x−d|   s.t.  x ∈ MET(G)`
//!
//! with `w̃(e) = |w⁺(e) − w⁻(e)|`, `W = diag(w̃)`, `d_e = 1` iff
//! `w⁻ > w⁺`. Inside the `[0,1]` box, `|x_e − d_e|` is linear
//! (`x_e` when `d_e = 0`, `1 − x_e` when `d_e = 1`), so the objective is a
//! diagonal quadratic with shifted anchor:
//!
//! `f(x) = Σ_e (w̃_e/γ)·(x_e − d̃_e)² + const`, `d̃_e = d_e − γ·s_e/2`,
//! `s_e = +1` if `d_e = 0` else `−1`.
//!
//! The box rows are the paper's never-forgotten additional constraints
//! `L_a`, delivered by the oracle every iteration. Proposition 3 justifies
//! relaxing MET(K_n) to MET(G) for sparse instances.

use super::metric_oracle::{MetricOracle, OracleMode};
use crate::core::bregman::DiagonalQuadratic;
use crate::core::engine::SweepStrategy;
use crate::core::problem::{
    ErasedOverlappable, Lowered, Problem, SolveOptions, VectorOracle, VectorPart,
};
use crate::core::session::Session;
use crate::core::solver::SolverResult;
use crate::graph::generators::SignedGraph;
use crate::graph::Graph;
use crate::util::Rng;
use std::sync::Arc;

/// A correlation clustering instance: per-edge similarity/dissimilarity
/// weights on a (not necessarily complete) graph.
#[derive(Debug, Clone)]
pub struct CcInstance {
    pub graph: Graph,
    pub wplus: Vec<f64>,
    pub wminus: Vec<f64>,
}

impl CcInstance {
    /// From a ±1 signed graph: `w⁺ = 1` on positive edges, `w⁻ = 1` on
    /// negative ones.
    pub fn from_signed(sg: &SignedGraph) -> CcInstance {
        let wplus = sg.signs.iter().map(|&s| if s > 0 { 1.0 } else { 0.0 }).collect();
        let wminus = sg.signs.iter().map(|&s| if s < 0 { 1.0 } else { 0.0 }).collect();
        CcInstance { graph: sg.graph.clone(), wplus, wminus }
    }

    /// Wang et al. (2013)-style densification used by the paper's dense
    /// experiments: lift an unweighted graph to a *complete* signed
    /// instance — adjacent pairs are similar (`w⁺=1`), non-adjacent pairs
    /// dissimilar (`w⁻=1`). (Cluster-editing form; see DESIGN.md.)
    pub fn densify(g: &Graph) -> CcInstance {
        let n = g.num_nodes();
        let complete = Graph::complete(n);
        let m = complete.num_edges();
        let mut wplus = vec![0.0; m];
        let mut wminus = vec![0.0; m];
        for e in 0..m {
            let (a, b) = complete.endpoints(e);
            if g.edge_between(a as usize, b as usize).is_some() {
                wplus[e] = 1.0;
            } else {
                wminus[e] = 1.0;
            }
        }
        CcInstance { graph: complete, wplus, wminus }
    }

    /// LP objective `Σ_e w⁺ x_e + w⁻ (1 − x_e)` at a fractional point.
    pub fn lp_objective(&self, x: &[f64]) -> f64 {
        self.wplus
            .iter()
            .zip(&self.wminus)
            .zip(x)
            .map(|((&wp, &wm), &xe)| wp * xe + wm * (1.0 - xe))
            .sum()
    }

    /// Clustering objective (disagreements) for integer labels.
    pub fn clustering_objective(&self, labels: &[u32]) -> f64 {
        self.graph
            .edges()
            .iter()
            .enumerate()
            .map(|(e, &(a, b))| {
                let cut = labels[a as usize] != labels[b as usize];
                if cut {
                    self.wplus[e]
                } else {
                    self.wminus[e]
                }
            })
            .sum()
    }
}

/// The Veldt transform products.
#[derive(Debug, Clone)]
pub struct VeldtTransform {
    /// Strictly convex surrogate objective.
    pub f: DiagonalQuadratic,
    /// Targets d (0/1 per edge).
    pub d: Vec<f64>,
    /// w̃ = |w⁺ − w⁻|.
    pub wt: Vec<f64>,
    pub gamma: f64,
}

/// Build the quadratic surrogate (4.2) for an instance.
/// Zero-w̃ edges get a tiny weight so `f` stays strictly convex.
pub fn veldt_transform(inst: &CcInstance, gamma: f64) -> VeldtTransform {
    const EPS_W: f64 = 1e-6;
    let m = inst.graph.num_edges();
    let mut d = vec![0.0; m];
    let mut wt = vec![0.0; m];
    let mut anchor = vec![0.0; m];
    let mut q = vec![0.0; m];
    for e in 0..m {
        wt[e] = (inst.wplus[e] - inst.wminus[e]).abs();
        d[e] = if inst.wminus[e] > inst.wplus[e] { 1.0 } else { 0.0 };
        let s = if d[e] == 0.0 { 1.0 } else { -1.0 };
        let w = wt[e].max(EPS_W);
        // f_e(x) = w̃·s·(x−d) + (w̃/γ)(x−d)² = (w/γ)(x − d̃)² + const
        anchor[e] = d[e] - gamma * s / 2.0;
        q[e] = 2.0 * w / gamma;
    }
    VeldtTransform { f: DiagonalQuadratic::new(anchor, q), d, wt, gamma }
}

/// Approximation-ratio certificate from §8.1: with
/// `R = (f̂ᵀ W f̂)/(2γ · w̃ᵀ f̂)`, `f̂ = |x − d|`, the LP solution is a
/// `(1+γ)/(1+R)` approximation of the optimal LP value.
pub fn approx_ratio(t: &VeldtTransform, x: &[f64]) -> f64 {
    let mut quad = 0.0;
    let mut lin = 0.0;
    for e in 0..x.len() {
        let fe = (x[e] - t.d[e]).abs();
        quad += t.wt[e] * fe * fe;
        lin += t.wt[e] * fe;
    }
    if lin <= 0.0 {
        return 1.0;
    }
    let r = quad / (2.0 * t.gamma * lin);
    (1.0 + t.gamma) / (1.0 + r)
}

/// Correlation clustering as a [`Problem`]: the Veldt surrogate (4.2)
/// over MET(G), rounded with Ailon–Charikar–Newman pivoting.
///
/// ```ignore
/// let res: CcResult = Correlation::dense(&inst).solve(&SolveOptions::new());
/// ```
pub struct Correlation<'a> {
    inst: &'a CcInstance,
    /// Veldt transform sharpness γ.
    gamma: f64,
    /// Projection sweeps per round (dense 2 / sparse 75; becomes the
    /// problem's `inner_sweeps` default, overridable via the options).
    inner_sweeps: usize,
    mode: OracleMode,
    /// Worker threads for the Collect-mode Dijkstra scan.
    threads: usize,
    /// Pivot-rounding seed.
    seed: u64,
    /// Dirty-source incremental separation (Collect mode; identical
    /// findings, rescans only moved sources).
    incremental: bool,
}

impl<'a> Correlation<'a> {
    /// Algorithm 6 settings (dense / complete graphs).
    pub fn dense(inst: &'a CcInstance) -> Correlation<'a> {
        Correlation {
            inst,
            gamma: 1.0,
            inner_sweeps: 2,
            mode: OracleMode::ProjectOnFind,
            threads: crate::util::pool::default_threads(),
            seed: 0,
            incremental: true,
        }
    }

    /// Algorithm 7 settings (large sparse graphs).
    pub fn sparse(inst: &'a CcInstance) -> Correlation<'a> {
        Correlation {
            inst,
            gamma: 1.0,
            inner_sweeps: 75,
            mode: OracleMode::Collect,
            threads: crate::util::pool::default_threads(),
            seed: 0,
            incremental: true,
        }
    }

    /// Toggle the oracle's dirty-source incremental scan (default on;
    /// `false` forces a full rescan every round — the ablation axis).
    pub fn incremental(mut self, on: bool) -> Self {
        self.incremental = on;
        self
    }

    pub fn gamma(mut self, gamma: f64) -> Self {
        self.gamma = gamma;
        self
    }

    pub fn inner_sweeps(mut self, sweeps: usize) -> Self {
        self.inner_sweeps = sweeps;
        self
    }

    pub fn mode(mut self, mode: OracleMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// One-shot convenience: solve this instance alone.
    pub fn solve(self, opts: &SolveOptions) -> CcResult {
        Session::solve_one(opts.clone(), self)
    }
}

impl<'a> Problem<'a> for Correlation<'a> {
    type Output = CcResult;

    fn lower(self, opts: &SolveOptions) -> Lowered<'a, CcResult> {
        let t = veldt_transform(self.inst, self.gamma);
        let mut oracle = MetricOracle::new(Arc::new(self.inst.graph.clone()), self.mode);
        oracle.upper_bound = Some(1.0);
        oracle.threads = self.threads;
        oracle.report_tol = (opts.violation_tol * 1e-3).max(1e-12);
        oracle.incremental = self.incremental;
        // Shard-bucketed delivery helps exactly when the sharded engine
        // consumes it; sequential solves keep the historical slot order.
        oracle.shard_bucket = matches!(opts.sweep, SweepStrategy::ShardedParallel { .. });
        let oracle = if self.mode == OracleMode::Collect {
            VectorOracle::Overlappable(ErasedOverlappable::new(oracle))
        } else {
            VectorOracle::Plain(Box::new(oracle))
        };
        let config = opts.solver_config(self.inner_sweeps);
        let inst = self.inst;
        let seed = self.seed;
        let f = t.f.clone();
        Lowered::Vector(VectorPart {
            name: "correlation-clustering",
            f,
            oracle,
            config,
            interpret: Box::new(move |_f: &DiagonalQuadratic, result: SolverResult| {
                let ratio = approx_ratio(&t, &result.x);
                let lp_objective = inst.lp_objective(&result.x);
                let labels = round_pivot(inst, &result.x, seed);
                let rounded_objective = inst.clustering_objective(&labels);
                CcResult { result, lp_objective, approx_ratio: ratio, labels, rounded_objective }
            }),
        })
    }
}

/// Solve configuration for correlation clustering.
#[deprecated(note = "use `Correlation` with `core::problem::SolveOptions` / `core::Session`")]
#[derive(Debug, Clone)]
pub struct CcConfig {
    pub gamma: f64,
    /// Paper: dense runs use 2 inner sweeps (Algorithm 6), sparse 75
    /// (Algorithm 7).
    pub inner_sweeps: usize,
    pub mode: OracleMode,
    pub violation_tol: f64,
    pub max_iters: usize,
    pub threads: usize,
    pub record_trace: bool,
    /// Projection-sweep executor (sequential vs sharded parallel).
    pub sweep: SweepStrategy,
    /// Overlap the oracle's Dijkstra scan with the projection sweeps
    /// (`Solver::solve_overlapped`; Collect mode only — ignored for
    /// ProjectOnFind). The scan then certifies the previous round's
    /// iterate, so convergence detection is one round more conservative.
    pub overlap: bool,
}

#[allow(deprecated)]
impl CcConfig {
    /// The [`SolveOptions`] this legacy config maps onto.
    pub fn to_options(&self) -> SolveOptions {
        SolveOptions {
            max_iters: self.max_iters,
            violation_tol: self.violation_tol,
            inner_sweeps: Some(self.inner_sweeps),
            record_trace: self.record_trace,
            sweep: self.sweep,
            overlap: self.overlap,
            ..SolveOptions::default()
        }
    }

    /// Algorithm 6 settings (dense / complete graphs).
    pub fn dense() -> CcConfig {
        CcConfig {
            gamma: 1.0,
            inner_sweeps: 2,
            mode: OracleMode::ProjectOnFind,
            violation_tol: 1e-2,
            max_iters: 200,
            threads: crate::util::pool::default_threads(),
            record_trace: true,
            sweep: SweepStrategy::Sequential,
            overlap: false,
        }
    }

    /// Algorithm 7 settings (large sparse graphs).
    pub fn sparse() -> CcConfig {
        CcConfig {
            gamma: 1.0,
            inner_sweeps: 75,
            mode: OracleMode::Collect,
            violation_tol: 1e-2,
            max_iters: 300,
            threads: crate::util::pool::default_threads(),
            record_trace: true,
            sweep: SweepStrategy::Sequential,
            overlap: false,
        }
    }
}

/// Result of the LP solve plus rounding.
#[derive(Debug, Clone)]
pub struct CcResult {
    pub result: SolverResult,
    /// LP objective at the fractional solution (a lower bound after full
    /// convergence).
    pub lp_objective: f64,
    /// §8.1 approximation-ratio certificate.
    pub approx_ratio: f64,
    /// Rounded clustering and its objective.
    pub labels: Vec<u32>,
    pub rounded_objective: f64,
}

/// Solve the LP relaxation and round.
///
/// Thin wrapper over the [`Session`] API (bit-identical to it; pinned
/// in `tests/determinism.rs`).
#[deprecated(note = "use `Correlation::dense(inst)`/`Correlation::sparse(inst)` + `solve`")]
#[allow(deprecated)]
pub fn solve_cc(inst: &CcInstance, cfg: &CcConfig, seed: u64) -> CcResult {
    Correlation::dense(inst)
        .gamma(cfg.gamma)
        .inner_sweeps(cfg.inner_sweeps)
        .mode(cfg.mode)
        .threads(cfg.threads)
        .seed(seed)
        .solve(&cfg.to_options())
}

/// Ailon–Charikar–Newman pivot rounding of a fractional metric `x`
/// (treating `x_e < 1/2` as "same cluster"). Works on any graph: only
/// *adjacent* unclustered vertices can join a pivot's cluster, which is
/// the natural sparse generalisation.
pub fn round_pivot(inst: &CcInstance, x: &[f64], seed: u64) -> Vec<u32> {
    let n = inst.graph.num_nodes();
    let mut rng = Rng::new(seed);
    let order = rng.permutation(n);
    let mut labels = vec![u32::MAX; n];
    let mut next = 0u32;
    for &pivot in &order {
        if labels[pivot] != u32::MAX {
            continue;
        }
        labels[pivot] = next;
        for &(nb, eid) in inst.graph.neighbors(pivot) {
            if labels[nb as usize] == u32::MAX && x[eid as usize] < 0.5 {
                labels[nb as usize] = next;
            }
        }
        next += 1;
    }
    labels
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, planted_signed, sign_edges};
    use crate::util::Rng;

    fn planted_instance(n: usize, k: usize, flip: f64, seed: u64) -> (CcInstance, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let g = Graph::complete(n);
        let (sg, labels) = planted_signed(g, k, flip, &mut rng);
        (CcInstance::from_signed(&sg), labels)
    }

    #[test]
    fn veldt_anchor_math() {
        let (inst, _) = planted_instance(5, 2, 0.0, 1);
        let t = veldt_transform(&inst, 1.0);
        for e in 0..inst.graph.num_edges() {
            if t.d[e] == 0.0 {
                assert!((t.f.d[e] + 0.5).abs() < 1e-12); // d̃ = −γ/2
            } else {
                assert!((t.f.d[e] - 1.5).abs() < 1e-12); // d̃ = 1 + γ/2
            }
            assert!(t.f.w[e] > 0.0);
        }
    }

    #[test]
    fn perfect_planting_recovered() {
        // Noise-free planted clusters: the LP solution should be integral
        // (x = 0 within, 1 across) and rounding exact.
        let (inst, truth) = planted_instance(10, 2, 0.0, 2);
        let res = solve_cc(&inst, &CcConfig { violation_tol: 1e-6, ..CcConfig::dense() }, 7);
        assert!(res.result.converged);
        // The rounded clustering must equal the planted one (up to label
        // permutation): same-cluster iff same truth label.
        for i in 0..10 {
            for j in (i + 1)..10 {
                let same_truth = truth[i] == truth[j];
                let same_ours = res.labels[i] == res.labels[j];
                assert_eq!(same_truth, same_ours, "pair ({i},{j})");
            }
        }
        // Zero disagreements.
        assert_eq!(res.rounded_objective, 0.0);
    }

    #[test]
    fn x_within_box_and_metric() {
        let (inst, _) = planted_instance(9, 3, 0.1, 3);
        let res = solve_cc(&inst, &CcConfig { violation_tol: 1e-5, ..CcConfig::dense() }, 1);
        assert!(res.result.converged);
        // Box rows are projected once per round, so residuals are of the
        // order of the stopping tolerance, not machine precision.
        for &xe in &res.result.x {
            assert!((-1e-4..=1.0 + 1e-4).contains(&xe), "x out of box: {xe}");
        }
        let viol =
            crate::problems::metric_oracle::max_metric_violation(&inst.graph, &res.result.x);
        assert!(viol < 1e-3, "metric violation {viol}");
    }

    #[test]
    fn approx_ratio_bounded() {
        let (inst, _) = planted_instance(8, 2, 0.2, 4);
        let res = solve_cc(&inst, &CcConfig::dense(), 5);
        // With γ=1 the certificate is at most 2 and at least 1.
        assert!(res.approx_ratio >= 1.0 - 1e-9 && res.approx_ratio <= 2.0 + 1e-9);
    }

    #[test]
    fn ratio_certificate_lower_bounds_rounding() {
        // The surrogate solution x̂ satisfies
        // lp(x̂) ≤ ratio · lp_opt ≤ ratio · rounded_objective (§8.1), so
        // lp(x̂)/ratio is a valid lower bound for any integral clustering.
        let (inst, _) = planted_instance(10, 3, 0.15, 6);
        let res = solve_cc(&inst, &CcConfig { violation_tol: 1e-6, ..CcConfig::dense() }, 8);
        assert!(res.result.converged);
        let lower = res.lp_objective / res.approx_ratio;
        assert!(
            lower <= res.rounded_objective + 1e-6,
            "certified bound {lower} must lower-bound rounding {}",
            res.rounded_objective
        );
    }

    #[test]
    fn sparse_mode_runs_on_noncomplete_graph() {
        let mut rng = Rng::new(7);
        let g = erdos_renyi(30, 0.2, &mut rng);
        let sg = sign_edges(g, 0.7, &mut rng);
        let inst = CcInstance::from_signed(&sg);
        let mut cfg = CcConfig::sparse();
        cfg.max_iters = 100;
        let res = solve_cc(&inst, &cfg, 3);
        assert!(res.result.converged, "sparse CC did not converge");
        for &xe in &res.result.x {
            assert!((-1e-6..=1.0 + 1e-6).contains(&xe));
        }
    }

    #[test]
    fn densify_matches_adjacency() {
        let mut rng = Rng::new(8);
        let g = erdos_renyi(12, 0.3, &mut rng);
        let inst = CcInstance::densify(&g);
        assert_eq!(inst.graph.num_edges(), 66);
        let pos: f64 = inst.wplus.iter().sum();
        assert_eq!(pos as usize, g.num_edges());
        // Objectives: all-singletons pays Σw⁺, all-one-cluster pays Σw⁻.
        let singletons: Vec<u32> = (0..12).collect();
        assert_eq!(inst.clustering_objective(&singletons), pos);
        let one = vec![0u32; 12];
        let neg: f64 = inst.wminus.iter().sum();
        assert_eq!(inst.clustering_objective(&one), neg);
    }

    #[test]
    fn trace_shows_forget_dynamics() {
        // Figure 2's shape: constraints found by the oracle shrink over
        // iterations once the active set stabilises.
        let (inst, _) = planted_instance(10, 2, 0.2, 9);
        let res = solve_cc(&inst, &CcConfig { violation_tol: 1e-6, ..CcConfig::dense() }, 2);
        assert!(res.result.trace.len() >= 2);
        let first = res.result.trace.first().unwrap();
        let last = res.result.trace.last().unwrap();
        assert!(last.max_violation <= first.max_violation);
    }
}
