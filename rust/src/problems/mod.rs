//! The paper's four metric constrained problem instantiations.
//!
//! - [`metric_oracle`] — the METRIC VIOLATIONS separation oracle
//!   (Algorithm 2) in both the project-on-find (Algorithm 8) and
//!   collect-then-project (Algorithms 6/7) modes.
//! - [`nearness`] — metric nearness (§4.1, Table 1 / Figures 1 & 4).
//! - [`correlation`] — weighted correlation clustering via the Veldt
//!   et al. transform (§4.2, Tables 2 & 3 / Figures 2 & 3).
//! - [`random_oracle`] — Property-2 uniform triangle sampling (§6.3),
//!   the stochastic counterpart used by the oracle ablation.
//! - [`itml`] — information-theoretic metric learning (§4.3, Table 4).
//! - [`svm`] — L2-SVM training with the truly stochastic variant
//!   (§4.4, Table 5).

pub mod correlation;
pub mod itml;
pub mod metric_oracle;
pub mod nearness;
pub mod random_oracle;
pub mod svm;
