//! A Property-2 (random) separation oracle for MET(G): uniform triangle
//! sampling.
//!
//! §6.3 of the paper: "uniformly randomly sampling constraints is an
//! oracle that satisfies Property 2" — every triangle inequality has
//! sampling probability ≥ τ = batch / (3·#triangles) > 0, so Theorem 1
//! (part 1, probability-1 convergence) applies without ever running
//! Dijkstra. Useful when per-iteration cost must be flat (streaming /
//! anytime settings) and as the ablation partner for the deterministic
//! METRIC VIOLATIONS oracle.
//!
//! On sparse graphs, triangles are sampled by picking an edge `(u, v)`
//! and a common neighbour of `u` and `v`; on complete graphs any node
//! triple works. Box rows are delivered exactly as the deterministic
//! oracle does.

use crate::core::bregman::BregmanFunction;
use crate::core::constraint::Constraint;
use crate::core::oracle::{Oracle, OracleOutcome, ProjectionSink, RandomOracle};
use crate::graph::Graph;
use crate::util::Rng;
use std::sync::Arc;

/// Uniform random-triangle oracle over MET(G).
pub struct RandomTriangleOracle {
    pub graph: Arc<Graph>,
    /// Triangles sampled per round.
    pub batch: usize,
    pub rng: Rng,
    pub nonneg: bool,
    pub upper_bound: Option<f64>,
    pub report_tol: f64,
}

impl RandomTriangleOracle {
    pub fn new(graph: Arc<Graph>, batch: usize, seed: u64) -> Self {
        RandomTriangleOracle {
            graph,
            batch,
            rng: Rng::new(seed),
            nonneg: true,
            upper_bound: None,
            report_tol: 1e-12,
        }
    }

    /// Sample one triangle `(e_ij, e_ik, e_jk)` of `G`, if any exists at
    /// the attempted seeds (sparse graphs may need several tries).
    fn sample_triangle(&mut self) -> Option<(u32, u32, u32)> {
        let g = &self.graph;
        for _ in 0..32 {
            // Pick a random edge (u, v) ...
            let e = self.rng.below(g.num_edges());
            let (u, v) = g.endpoints(e);
            // ... then a random neighbour of the lower-degree endpoint
            // that also closes the triangle.
            let (a, b) = if g.degree(u as usize) <= g.degree(v as usize) {
                (u, v)
            } else {
                (v, u)
            };
            let nbrs = g.neighbors(a as usize);
            if nbrs.is_empty() {
                continue;
            }
            let &(w, e_aw) = &nbrs[self.rng.below(nbrs.len())];
            if w == b {
                continue;
            }
            if let Some(e_bw) = g.edge_between(b as usize, w as usize) {
                return Some((e as u32, e_aw, e_bw));
            }
        }
        None
    }
}

impl<F: BregmanFunction> Oracle<F> for RandomTriangleOracle {
    fn separate(&mut self, sink: &mut dyn ProjectionSink) -> OracleOutcome {
        let mut out = OracleOutcome::default();
        // Box rows, same as the deterministic oracle.
        let m = self.graph.num_edges();
        if self.nonneg {
            let mut c = Constraint::nonneg(0);
            for e in 0..m {
                let v = -sink.x()[e];
                if v > self.report_tol {
                    out.max_violation = out.max_violation.max(v);
                    out.found += 1;
                }
                c.indices[0] = e as u32;
                sink.project_and_remember(&c);
            }
        }
        if let Some(ub) = self.upper_bound {
            let mut c = Constraint::upper(0, ub);
            for e in 0..m {
                let v = sink.x()[e] - ub;
                if v > self.report_tol {
                    out.max_violation = out.max_violation.max(v);
                    out.found += 1;
                }
                c.indices[0] = e as u32;
                sink.project_and_remember(&c);
            }
        }
        // Random triangles: all three orientations of each sample are
        // delivered (projection handles satisfied rows as no-ops).
        for _ in 0..self.batch {
            let Some((e1, e2, e3)) = self.sample_triangle() else { continue };
            for (head, p1, p2) in [(e1, e2, e3), (e2, e1, e3), (e3, e1, e2)] {
                let c = Constraint::cycle(head, &[p1, p2]);
                let v = c.violation(sink.x());
                if v > self.report_tol {
                    out.max_violation = out.max_violation.max(v);
                    out.found += 1;
                }
                sink.project_and_remember(&c);
            }
        }
        out
    }

    fn name(&self) -> &str {
        "random-triangles"
    }
}

impl<F: BregmanFunction> RandomOracle<F> for RandomTriangleOracle {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bregman::DiagonalQuadratic;
    use crate::core::solver::{Solver, SolverConfig};
    use crate::graph::generators::type1_complete;
    use crate::problems::metric_oracle::max_metric_violation;

    #[test]
    fn sampler_returns_valid_triangles() {
        let mut rng = Rng::new(1);
        let g = Arc::new(crate::graph::generators::erdos_renyi(40, 0.3, &mut rng));
        let mut oracle = RandomTriangleOracle::new(g.clone(), 1, 2);
        let mut found = 0;
        for _ in 0..200 {
            if let Some((e1, e2, e3)) = oracle.sample_triangle() {
                found += 1;
                // The three edges must pairwise share exactly the three
                // triangle nodes.
                let (a1, b1) = g.endpoints(e1 as usize);
                let (a2, b2) = g.endpoints(e2 as usize);
                let (a3, b3) = g.endpoints(e3 as usize);
                let mut nodes = vec![a1, b1, a2, b2, a3, b3];
                nodes.sort_unstable();
                nodes.dedup();
                assert_eq!(nodes.len(), 3, "edges {e1},{e2},{e3} not a triangle");
            }
        }
        assert!(found > 100, "sampler starved: {found}/200");
    }

    #[test]
    fn random_oracle_reaches_metric_on_small_instance() {
        // Theorem 1 with Property 2: fixed iteration budget, then check
        // near-feasibility (a random oracle cannot certify, so we verify
        // with the deterministic max_metric_violation afterwards).
        let mut rng = Rng::new(3);
        let inst = type1_complete(12, &mut rng);
        let g = Arc::new(inst.graph.clone());
        let f = DiagonalQuadratic::unweighted(inst.weights.clone());
        let oracle = RandomTriangleOracle::new(g, 600, 5);
        let cfg = SolverConfig {
            max_iters: 400,
            inner_sweeps: 1,
            violation_tol: -1.0, // never self-certify
            dual_tol: 0.0,
            record_trace: false,
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        let _ = solver.solve(oracle);
        let viol = max_metric_violation(&inst.graph, &solver.x);
        assert!(viol < 5e-2, "random-oracle residual violation {viol}");
    }

    #[test]
    fn random_oracle_approaches_deterministic_optimum() {
        let mut rng = Rng::new(7);
        let inst = type1_complete(10, &mut rng);
        // Deterministic reference.
        let det = crate::problems::nearness::Nearness::new(&inst).solve(
            &crate::core::problem::SolveOptions::new().violation_tol(1e-9).dual_tol(1e-9),
        );
        // Random-oracle run.
        let g = Arc::new(inst.graph.clone());
        let f = DiagonalQuadratic::unweighted(inst.weights.clone());
        let oracle = RandomTriangleOracle::new(g, 800, 11);
        let cfg = SolverConfig {
            max_iters: 600,
            inner_sweeps: 1,
            violation_tol: -1.0,
            dual_tol: 0.0,
            record_trace: false,
            ..Default::default()
        };
        let mut solver = Solver::new(f, cfg);
        let _ = solver.solve(oracle);
        let maxdiff = solver
            .x
            .iter()
            .zip(&det.result.x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(maxdiff < 5e-2, "random vs deterministic optimum gap {maxdiff}");
    }
}
