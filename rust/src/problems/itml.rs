//! Information-theoretic metric learning (§4.3, Table 4) with PROJECT AND
//! FORGET.
//!
//! ITML (Davis et al. 2007) learns a Mahalanobis matrix `M` minimising the
//! LogDet divergence to the identity subject to
//! `d_M(x_i, x_j) ≤ u` for similar pairs and `≥ l` for dissimilar pairs
//! (slack-relaxed with trade-off γ). Bregman projections onto single pair
//! constraints are closed-form rank-one updates (Algorithm 9).
//!
//! The paper's PFITML applies the P&F recipe to the *full* constraint set
//! (all O(n²) pairs) instead of ITML's once-sampled 20c² subset: a random
//! oracle (Property 2) samples fresh pairs every iteration, remembered
//! pairs with nonzero duals are re-projected in sweeps, and pairs whose
//! dual returns to zero are forgotten.

use crate::core::problem::{Lowered, Problem, RoundProblem, RoundReport, RoundSnapshot, SolveOptions};
use crate::core::session::Session;
use crate::ml::dataset::Dataset;
use crate::ml::mahalanobis::Mat;
use crate::util::wire::{Reader, WireError, Writer};
use crate::util::Rng;
use std::collections::HashMap;
use std::sync::Arc;

/// Pair constraints: indices into the dataset plus the similar/dissimilar
/// tag (δ = +1 similar, −1 dissimilar).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Pair {
    pub i: u32,
    pub j: u32,
    pub similar: bool,
}

/// Per-pair adaptive state (Algorithm 9's ξ and λ).
#[derive(Debug, Clone, Copy)]
pub(crate) struct PairState {
    pub(crate) lambda: f64,
    pub(crate) xi: f64,
}

/// Shared hyper-parameters (§8.3 uses γ=1, u=1, l=10).
#[derive(Debug, Clone)]
pub struct ItmlParams {
    pub gamma: f64,
    pub u: f64,
    pub l: f64,
}

impl Default for ItmlParams {
    fn default() -> Self {
        ItmlParams { gamma: 1.0, u: 1.0, l: 10.0 }
    }
}

/// One Bregman (LogDet) projection with dual correction onto a pair
/// constraint. Mutates `m` and the pair's (λ, ξ); returns |α| (the dual
/// movement; 0 means the projection was a no-op).
pub(crate) fn project_pair(
    m: &mut Mat,
    data: &Dataset,
    pair: Pair,
    st: &mut PairState,
    params: &ItmlParams,
    mv: &mut Vec<f64>,
    diff: &mut Vec<f64>,
) -> f64 {
    let (xi_row, xj_row) = (data.row(pair.i as usize), data.row(pair.j as usize));
    diff.clear();
    diff.extend(xi_row.iter().zip(xj_row).map(|(&a, &b)| a - b));
    let p = m.quad_form(diff);
    if p <= 1e-300 {
        return 0.0;
    }
    let delta = if pair.similar { 1.0 } else { -1.0 };
    let alpha = st
        .lambda
        .min(delta / 2.0 * (1.0 / p - params.gamma / st.xi));
    if alpha == 0.0 {
        return 0.0;
    }
    let beta = delta * alpha / (1.0 - delta * alpha * p);
    st.xi = params.gamma * st.xi / (params.gamma + delta * alpha * st.xi);
    st.lambda -= alpha;
    // M += β (Mv)(Mv)ᵀ
    mv.resize(data.d, 0.0);
    m.matvec(diff, mv);
    m.rank_one_update(mv, beta);
    alpha.abs()
}

/// Configuration for the P&F ITML solver.
#[derive(Debug, Clone)]
pub struct PfItmlConfig {
    /// Fresh pairs sampled per iteration (half from S, half from D).
    pub batch: usize,
    /// Projection sweeps over the remembered list per iteration.
    pub sweeps: usize,
    /// Total projection budget (the paper equalises this across methods).
    pub max_projections: usize,
    pub params: ItmlParams,
    pub seed: u64,
}

impl Default for PfItmlConfig {
    fn default() -> Self {
        PfItmlConfig {
            batch: 200,
            sweeps: 1,
            max_projections: 100_000,
            params: ItmlParams::default(),
            seed: 0,
        }
    }
}

/// Result: learned matrix plus accounting.
#[derive(Debug, Clone)]
pub struct ItmlResult {
    pub m: Mat,
    pub projections: usize,
    /// Remembered (active) pairs at the end.
    pub active_pairs: usize,
}

/// Labels -> similar/dissimilar pair universe: a pair is similar iff the
/// labels agree. Pairs are never materialised; they are sampled on demand.
fn sample_pair(data: &Dataset, similar: bool, rng: &mut Rng) -> Option<Pair> {
    for _ in 0..64 {
        let i = rng.below(data.n);
        let j = rng.below(data.n);
        if i == j {
            continue;
        }
        if (data.y[i] == data.y[j]) == similar {
            let (i, j) = if i < j { (i, j) } else { (j, i) };
            return Some(Pair { i: i as u32, j: j as u32, similar });
        }
    }
    None
}

/// Insertion-ordered remembered-pair set (the active set of PF-ITML).
///
/// The `HashMap` it replaces iterated sweeps in the map's per-process
/// random order, so two identical runs applied the (non-commuting)
/// rank-one updates in different orders and produced different matrices.
/// Discovery order is deterministic given the seed — exactly like the
/// engine's slot-ordered `ActiveSet` — which makes runs reproducible and
/// checkpoint/resume exact.
#[derive(Debug, Clone, Default)]
pub(crate) struct PairList {
    pairs: Vec<(Pair, PairState)>,
    index: HashMap<Pair, usize>,
}

impl PairList {
    fn len(&self) -> usize {
        self.pairs.len()
    }

    /// Slot of `pair`, inserting `fresh` at the tail if unknown.
    fn slot_or_insert(&mut self, pair: Pair, fresh: PairState) -> usize {
        match self.index.entry(pair) {
            std::collections::hash_map::Entry::Occupied(e) => *e.get(),
            std::collections::hash_map::Entry::Vacant(v) => {
                let slot = self.pairs.len();
                v.insert(slot);
                self.pairs.push((pair, fresh));
                slot
            }
        }
    }

    fn get_mut(&mut self, slot: usize) -> (Pair, &mut PairState) {
        let (pair, st) = &mut self.pairs[slot];
        (*pair, st)
    }

    /// FORGET: drop pairs whose dual returned to zero (slot order is
    /// preserved for survivors, like the engine's stable compaction).
    fn forget_inactive(&mut self) {
        self.pairs.retain(|(_, st)| st.lambda != 0.0);
        self.index.clear();
        for (slot, (pair, _)) in self.pairs.iter().enumerate() {
            self.index.insert(*pair, slot);
        }
    }

    /// Rebuild from a deserialized slot-ordered pair list; the hash index
    /// is derived state and is reconstructed here.
    pub(crate) fn from_pairs(pairs: Vec<(Pair, PairState)>) -> PairList {
        let index =
            pairs.iter().enumerate().map(|(slot, (pair, _))| (*pair, slot)).collect();
        PairList { pairs, index }
    }
}

/// PF-ITML as a [`Problem`]: a *round-driven* block (the Mahalanobis
/// iterate lives in the LogDet geometry, not the vector engine), stepped
/// by the [`Session`] in lockstep with any vector blocks. ITML over many
/// folds is the ROADMAP's canonical batched-instance example: add one
/// `PfItml` per fold to a single session.
pub struct PfItml<'a> {
    data: &'a Dataset,
    cfg: PfItmlConfig,
}

impl<'a> PfItml<'a> {
    pub fn new(data: &'a Dataset, cfg: PfItmlConfig) -> PfItml<'a> {
        PfItml { data, cfg }
    }

    /// One-shot convenience: solve this instance alone.
    pub fn solve(self, opts: &SolveOptions) -> ItmlResult {
        Session::solve_one(opts.clone(), self)
    }
}

impl<'a> Problem<'a> for PfItml<'a> {
    type Output = ItmlResult;

    fn lower(self, _opts: &SolveOptions) -> Lowered<'a, ItmlResult> {
        Lowered::Rounds(Box::new(PfItmlRun::new(self.data, self.cfg)))
    }
}

/// Checkpointable state of one PF-ITML run.
#[derive(Clone)]
struct ItmlSnapshot {
    m: Mat,
    rng: Rng,
    remembered: PairList,
    projections: usize,
}

/// The running PF-ITML state machine: one `round()` = one oracle batch +
/// sweeps + FORGET (the body of the historical solve loop).
pub(crate) struct PfItmlRun<'a> {
    data: &'a Dataset,
    cfg: PfItmlConfig,
    m: Mat,
    rng: Rng,
    remembered: PairList,
    projections: usize,
    mv: Vec<f64>,
    diff: Vec<f64>,
}

impl<'a> PfItmlRun<'a> {
    fn new(data: &'a Dataset, cfg: PfItmlConfig) -> PfItmlRun<'a> {
        PfItmlRun {
            data,
            rng: Rng::new(cfg.seed),
            cfg,
            m: Mat::identity(data.d),
            remembered: PairList::default(),
            projections: 0,
            mv: Vec::new(),
            diff: Vec::new(),
        }
    }

    fn fresh_state(pair: Pair, params: &ItmlParams) -> PairState {
        PairState { lambda: 0.0, xi: if pair.similar { params.u } else { params.l } }
    }

    fn one_round(&mut self) -> RoundReport {
        let proj_before = self.projections;
        let mut found = 0usize;
        // Phase 1: random oracle — sample a fresh batch (Property 2) and
        // project on find.
        for b in 0..self.cfg.batch {
            if self.projections >= self.cfg.max_projections {
                break;
            }
            let similar = b % 2 == 0;
            let Some(pair) = sample_pair(self.data, similar, &mut self.rng) else { continue };
            found += 1;
            let slot =
                self.remembered.slot_or_insert(pair, Self::fresh_state(pair, &self.cfg.params));
            let (pair, st) = self.remembered.get_mut(slot);
            let moved = project_pair(
                &mut self.m,
                self.data,
                pair,
                st,
                &self.cfg.params,
                &mut self.mv,
                &mut self.diff,
            );
            if moved != 0.0 {
                self.projections += 1;
            }
        }
        // Phase 2: sweeps over the remembered list, in slot order.
        for _ in 0..self.cfg.sweeps {
            if self.projections >= self.cfg.max_projections {
                break;
            }
            for slot in 0..self.remembered.len() {
                if self.projections >= self.cfg.max_projections {
                    break;
                }
                let (pair, st) = self.remembered.get_mut(slot);
                let moved = project_pair(
                    &mut self.m,
                    self.data,
                    pair,
                    st,
                    &self.cfg.params,
                    &mut self.mv,
                    &mut self.diff,
                );
                if moved != 0.0 {
                    self.projections += 1;
                }
            }
        }
        // Phase 3: FORGET pairs whose dual returned to zero.
        self.remembered.forget_inactive();
        RoundReport {
            found,
            projections: self.projections - proj_before,
            active: self.remembered.len(),
        }
    }
}

impl RoundProblem for PfItmlRun<'_> {
    type Output = ItmlResult;

    fn name(&self) -> &'static str {
        "pf-itml"
    }

    fn round(&mut self) -> RoundReport {
        self.one_round()
    }

    fn done(&self) -> bool {
        self.projections >= self.cfg.max_projections
    }

    fn finish(self: Box<Self>) -> ItmlResult {
        ItmlResult {
            m: self.m,
            projections: self.projections,
            active_pairs: self.remembered.len(),
        }
    }

    fn snapshot(&self) -> Option<RoundSnapshot> {
        Some(Arc::new(ItmlSnapshot {
            m: self.m.clone(),
            rng: self.rng.clone(),
            remembered: self.remembered.clone(),
            projections: self.projections,
        }))
    }

    fn restore(&mut self, snapshot: &RoundSnapshot) {
        let snap = snapshot
            .downcast_ref::<ItmlSnapshot>()
            .expect("foreign snapshot handed to a PF-ITML block");
        self.m = snap.m.clone();
        self.rng = snap.rng.clone();
        self.remembered = snap.remembered.clone();
        self.projections = snap.projections;
    }
}

/// Serialize a PF-ITML [`RoundSnapshot`] into `w` for durable
/// checkpoints (`serve::persist`): the Mahalanobis matrix as IEEE bits,
/// the full RNG state (xoshiro words + Box–Muller spare), the projection
/// count, and the remembered pairs in slot order with their (λ, ξ).
/// Returns `false` if the snapshot belongs to some other round-driven
/// problem — the caller reports that checkpoint as unsupported.
///
/// Byte-stable: encoding a decoded snapshot reproduces the bytes
/// exactly (the pair-list hash index is derived state and not written).
pub(crate) fn encode_round_snapshot(snap: &RoundSnapshot, w: &mut Writer) -> bool {
    let Some(s) = snap.downcast_ref::<ItmlSnapshot>() else {
        return false;
    };
    w.put_u64(s.m.d as u64);
    w.put_u64(s.m.a.len() as u64);
    for &v in &s.m.a {
        w.put_f64(v);
    }
    let (words, spare) = s.rng.state();
    for word in words {
        w.put_u64(word);
    }
    match spare {
        Some(z) => {
            w.put_u8(1);
            w.put_f64(z);
        }
        None => w.put_u8(0),
    }
    w.put_u64(s.projections as u64);
    w.put_u64(s.remembered.pairs.len() as u64);
    for (pair, st) in &s.remembered.pairs {
        w.put_u32(pair.i);
        w.put_u32(pair.j);
        w.put_u8(pair.similar as u8);
        w.put_f64(st.lambda);
        w.put_f64(st.xi);
    }
    true
}

/// Decode the [`encode_round_snapshot`] layout back into a restorable
/// [`RoundSnapshot`]. Every length and tag is validated, so a truncated
/// or bit-flipped buffer yields a typed error, never a panic.
pub(crate) fn decode_round_snapshot(r: &mut Reader<'_>) -> Result<RoundSnapshot, WireError> {
    let d = r.get_u64("itml.d")? as usize;
    let na = r.get_count(8, "itml.mat")?;
    let mut a = Vec::with_capacity(na);
    for _ in 0..na {
        a.push(r.get_f64("itml.mat")?);
    }
    if d.checked_mul(d) != Some(na) {
        return Err(WireError { what: "itml.mat", at: r.pos() });
    }
    let mut words = [0u64; 4];
    for word in &mut words {
        *word = r.get_u64("itml.rng")?;
    }
    let spare = match r.get_u8("itml.rng.spare")? {
        0 => None,
        1 => Some(r.get_f64("itml.rng.spare")?),
        _ => return Err(WireError { what: "itml.rng.spare", at: r.pos() }),
    };
    let projections = r.get_u64("itml.projections")? as usize;
    let np = r.get_count(4 + 4 + 1 + 8 + 8, "itml.pairs")?;
    let mut pairs = Vec::with_capacity(np);
    for _ in 0..np {
        let i = r.get_u32("itml.pair.i")?;
        let j = r.get_u32("itml.pair.j")?;
        let similar = match r.get_u8("itml.pair.similar")? {
            0 => false,
            1 => true,
            _ => return Err(WireError { what: "itml.pair.similar", at: r.pos() }),
        };
        let lambda = r.get_f64("itml.pair.lambda")?;
        let xi = r.get_f64("itml.pair.xi")?;
        pairs.push((Pair { i, j, similar }, PairState { lambda, xi }));
    }
    Ok(Arc::new(ItmlSnapshot {
        m: Mat { d, a },
        rng: Rng::from_state(words, spare),
        remembered: PairList::from_pairs(pairs),
        projections,
    }))
}

/// PROJECT AND FORGET for ITML over the full implicit pair set.
///
/// Thin wrapper over the [`Session`] API (bit-identical to it; pinned
/// in `tests/determinism.rs`).
#[deprecated(note = "use `PfItml::new(data, cfg).solve(&opts)` or `core::Session`")]
pub fn solve_pf_itml(data: &Dataset, cfg: &PfItmlConfig) -> ItmlResult {
    PfItml::new(data, cfg.clone()).solve(&SolveOptions::default())
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::ml::dataset::gaussian_mixture;
    use crate::ml::knn::knn_accuracy;
    use crate::ml::mahalanobis::mahalanobis_sq;

    #[test]
    fn projection_pulls_similar_pair_towards_u() {
        // A similar pair with distance ≫ u must be pulled down (the γ=1
        // slack relaxation converges between u and the initial distance).
        let data = Dataset {
            n: 2,
            d: 2,
            x: vec![0.0, 0.0, 3.0, 0.0], // dist² = 9 under I
            y: vec![0, 0],
        };
        let params = ItmlParams::default(); // γ=1, u=1, l=10
        let mut m = Mat::identity(2);
        let mut st = PairState { lambda: 0.0, xi: params.u };
        let (mut mv, mut diff) = (Vec::new(), Vec::new());
        let pair = Pair { i: 0, j: 1, similar: true };
        for _ in 0..200 {
            project_pair(&mut m, &data, pair, &mut st, &params, &mut mv, &mut diff);
        }
        let d2 = mahalanobis_sq(&m, &[0.0, 0.0], &[3.0, 0.0], &mut diff);
        assert!(d2 < 4.0, "distance {d2} not pulled towards u=1");
        assert!(d2 > 0.5, "distance {d2} overshot");
        // Dual must have accumulated (λ = −Σα > 0 means corrections made).
        assert!(st.lambda > 0.0);
    }

    #[test]
    fn projection_pushes_dissimilar_pair_towards_l() {
        let data = Dataset {
            n: 2,
            d: 2,
            x: vec![0.0, 0.0, 1.0, 0.0], // dist² = 1 < l = 10
            y: vec![0, 1],
        };
        let params = ItmlParams::default();
        let mut m = Mat::identity(2);
        let mut st = PairState { lambda: 0.0, xi: params.l };
        let (mut mv, mut diff) = (Vec::new(), Vec::new());
        let pair = Pair { i: 0, j: 1, similar: false };
        for _ in 0..200 {
            project_pair(&mut m, &data, pair, &mut st, &params, &mut mv, &mut diff);
        }
        let d2 = mahalanobis_sq(&m, &[0.0, 0.0], &[1.0, 0.0], &mut diff);
        // γ=1 slack equilibrium for p₀=1, l=10 sits near 1.8 — well above
        // the starting distance but far from the hard-constraint l.
        assert!(d2 > 1.5, "distance {d2} not pushed towards l=10");
        assert!(st.lambda > 0.0);
    }

    #[test]
    fn satisfied_pair_is_noop_and_forgettable() {
        let data = Dataset {
            n: 2,
            d: 2,
            x: vec![0.0, 0.0, 0.5, 0.0], // dist² = 0.25 ≤ u = 1 ok
            y: vec![0, 0],
        };
        let params = ItmlParams::default();
        let mut m = Mat::identity(2);
        let mut st = PairState { lambda: 0.0, xi: params.u };
        let (mut mv, mut diff) = (Vec::new(), Vec::new());
        let moved = project_pair(
            &mut m,
            &data,
            Pair { i: 0, j: 1, similar: true },
            &mut st,
            &params,
            &mut mv,
            &mut diff,
        );
        assert_eq!(moved, 0.0);
        assert_eq!(st.lambda, 0.0, "pair stays forgettable");
    }

    #[test]
    fn learned_metric_stays_psd_and_symmetric() {
        let mut rng = Rng::new(4);
        let data = gaussian_mixture(120, 5, 3, 2.0, &mut rng);
        let cfg = PfItmlConfig { max_projections: 3000, batch: 60, seed: 4, ..Default::default() };
        let res = solve_pf_itml(&data, &cfg);
        assert!(res.m.asymmetry() < 1e-9);
        assert!(res.m.min_rayleigh_sample(300, &mut rng) > 0.0, "not PSD");
        assert!(res.projections > 0);
    }

    #[test]
    fn metric_learning_improves_knn() {
        // Stretch one irrelevant dimension hugely; ITML should learn to
        // discount it and beat the Euclidean baseline.
        let mut rng = Rng::new(5);
        let mut data = gaussian_mixture(300, 4, 3, 3.0, &mut rng);
        for i in 0..data.n {
            data.x[i * 4 + 3] = rng.normal() * 25.0; // noise dim
        }
        let (tr, te) = data.split(0.8, &mut rng);
        let base = knn_accuracy(&Mat::identity(4), &tr, &te, 4);
        let cfg = PfItmlConfig { max_projections: 20_000, batch: 100, seed: 5, ..Default::default() };
        let res = solve_pf_itml(&tr, &cfg);
        let learned = knn_accuracy(&res.m, &tr, &te, 4);
        assert!(
            learned >= base - 0.02,
            "learned metric {learned} much worse than euclidean {base}"
        );
    }

    #[test]
    fn forget_keeps_pair_count_bounded() {
        let mut rng = Rng::new(6);
        let data = gaussian_mixture(150, 4, 2, 2.0, &mut rng);
        let cfg = PfItmlConfig { max_projections: 5000, batch: 100, seed: 6, ..Default::default() };
        let res = solve_pf_itml(&data, &cfg);
        // Remembered pairs must be far fewer than all sampled pairs.
        assert!(res.active_pairs < 5000, "active {}", res.active_pairs);
    }

    #[test]
    fn snapshot_codec_roundtrips_byte_stably_and_restores_exactly() {
        let mut rng = Rng::new(21);
        let data = gaussian_mixture(80, 4, 2, 2.0, &mut rng);
        let cfg = PfItmlConfig { max_projections: 4000, batch: 50, seed: 21, ..Default::default() };
        let mut run = PfItmlRun::new(&data, cfg.clone());
        for _ in 0..5 {
            run.one_round();
        }
        let snap = run.snapshot().expect("PF-ITML supports checkpointing");

        // Encode → decode → re-encode reproduces the bytes exactly.
        let mut w = Writer::new();
        assert!(encode_round_snapshot(&snap, &mut w));
        let bytes = w.into_bytes();
        let decoded = decode_round_snapshot(&mut Reader::new(&bytes)).expect("decode");
        let mut w2 = Writer::new();
        assert!(encode_round_snapshot(&decoded, &mut w2));
        assert_eq!(bytes, w2.into_bytes(), "re-serialization is not byte-stable");

        // Restoring the decoded snapshot continues bit-identically.
        let mut resumed = PfItmlRun::new(&data, cfg);
        resumed.restore(&decoded);
        for _ in 0..5 {
            run.one_round();
            resumed.one_round();
        }
        let (a, b) = (Box::new(run).finish(), Box::new(resumed).finish());
        assert_eq!(a.projections, b.projections);
        assert_eq!(a.active_pairs, b.active_pairs);
        let bits = |m: &Mat| m.a.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.m), bits(&b.m), "resumed matrix diverged");

        // A foreign snapshot is refused, not mis-decoded.
        let foreign: RoundSnapshot = Arc::new(42usize);
        assert!(!encode_round_snapshot(&foreign, &mut Writer::new()));

        // Truncation is a typed error.
        assert!(decode_round_snapshot(&mut Reader::new(&bytes[..bytes.len() - 3])).is_err());
    }
}
