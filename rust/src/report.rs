//! Report emission helpers shared by `main.rs` and the benches: every
//! experiment prints the paper-style table/series and persists CSV under
//! the report directory.

use crate::util::table::{Series, Table};

/// Where reports land (`$PAF_REPORT_DIR`, default `reports/`).
pub fn report_dir() -> String {
    std::env::var("PAF_REPORT_DIR").unwrap_or_else(|_| "reports".to_string())
}

/// Emit a table under the standard directory.
pub fn emit_table(t: &Table, basename: &str) {
    t.emit(&report_dir(), basename);
}

/// Emit a series under the standard directory.
pub fn emit_series(s: &Series, basename: &str) {
    s.emit(&report_dir(), basename);
}

/// Format a seconds value like the paper's tables (3 significant-ish).
pub fn fmt_time(s: f64) -> String {
    if s < 10.0 {
        format!("{s:.2}")
    } else if s < 100.0 {
        format!("{s:.1}")
    } else {
        format!("{s:.0}")
    }
}

/// Format a byte count as GiB with 2 decimals (Table 2's unit).
pub fn fmt_gib(bytes: u64) -> String {
    format!("{:.2}", bytes as f64 / (1u64 << 30) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_time(1.234), "1.23");
        assert_eq!(fmt_time(45.67), "45.7");
        assert_eq!(fmt_time(1649.0), "1649");
        assert_eq!(fmt_gib(1u64 << 30), "1.00");
    }
}
