//! Report emission helpers shared by `main.rs` and the benches: every
//! experiment prints the paper-style table/series, persists CSV under
//! the report directory, and can persist a machine-readable
//! [`SolverResult`] JSON (schema-versioned; includes the per-phase
//! oracle/sweep/forget timing breakdown).

use crate::core::solver::SolverResult;
use crate::graph::ingest::IngestStats;
use crate::util::table::{Series, Table};

/// Where reports land (`$PAF_REPORT_DIR`, default `reports/`).
pub fn report_dir() -> String {
    std::env::var("PAF_REPORT_DIR").unwrap_or_else(|_| "reports".to_string())
}

/// Emit a table under the standard directory.
pub fn emit_table(t: &Table, basename: &str) {
    t.emit(&report_dir(), basename);
}

/// Emit a series under the standard directory.
pub fn emit_series(s: &Series, basename: &str) {
    s.emit(&report_dir(), basename);
}

/// Version of the solver JSON schemas (the [`SolverResult`] shape below
/// and the serve-stats shape in [`crate::serve::serve_stats_json`]).
/// Bump on any field-shape change so downstream consumers can dispatch.
/// v2: added the `"kind": "serve"` document (per-job serving stats +
/// event stream); solver-result documents are unchanged in shape.
/// v3: trace entries and serve job objects gained the sweep-scheduling
/// counters `rows_projected` / `rows_skipped` (additive).
/// v4: serve documents gained fault-tolerance fields — per job `shed`,
/// `failed`, `retries`, `recovered`, `error`; top-level `recovered`,
/// `shed`, `retried`, `failed`, `crashed`; and the `recovered` / `shed`
/// / `retried` / `quarantined` event kinds (additive).
/// v5: solver-result documents may carry an additive `ingest` object
/// (disk-streamed inputs only: format, dup policy, line/byte/record
/// counts, peak working-set and CSR byte accounting, parse/build times).
/// v6: solver-result documents may carry an additive `telemetry` array
/// (sampled convergence frames, present when `telemetry_every` > 0);
/// serve-document events gained a monotonic `seq` plus the scheduler
/// `round` they were emitted in (additive).
/// v7: added the `"kind": "serve-fleet"` document (per-shard service
/// records, per-job migration counts and `x_fnv1a` solution digests,
/// fleet event stream with `at_us` timestamps); serve documents gained
/// a top-level `paused` flag (additive).
pub const SOLVER_JSON_SCHEMA_VERSION: u32 = 7;

/// Serialise a [`SolverResult`] (with its per-phase timing breakdown
/// and, when recorded, the full per-iteration trace) as JSON. `label`
/// identifies the run; it must not contain `"` or `\` (the emitter does
/// no escaping — labels are code-controlled).
pub fn solver_result_json(label: &str, r: &SolverResult) -> String {
    solver_result_json_with_ingest(label, r, None)
}

/// [`solver_result_json`] with the optional schema-v5 `ingest` object
/// for disk-streamed inputs ([`crate::graph::ingest`] byte accounting).
pub fn solver_result_json_with_ingest(
    label: &str,
    r: &SolverResult,
    ingest: Option<&IngestStats>,
) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"schema_version\": {SOLVER_JSON_SCHEMA_VERSION},\n"));
    out.push_str(&format!("  \"label\": \"{label}\",\n"));
    out.push_str(&format!("  \"converged\": {},\n", r.converged));
    out.push_str(&format!("  \"iterations\": {},\n", r.iterations));
    out.push_str(&format!("  \"seconds\": {:.9},\n", r.seconds));
    out.push_str(&format!("  \"total_projections\": {},\n", r.total_projections));
    out.push_str(&format!("  \"active_constraints\": {},\n", r.active_constraints));
    out.push_str(&format!(
        "  \"phases\": {{\"oracle_s\": {:.9}, \"sweep_s\": {:.9}, \"forget_s\": {:.9}}},\n",
        r.phases.oracle_s, r.phases.sweep_s, r.phases.forget_s
    ));
    if let Some(s) = ingest {
        out.push_str(&format!(
            "  \"ingest\": {{\"format\": \"{}\", \"dup_policy\": \"{}\", \"lines\": {}, \
             \"bytes_read\": {}, \"parsed_edges\": {}, \"self_loops\": {}, \
             \"duplicates\": {}, \"nodes\": {}, \"edges\": {}, \"peak_bytes\": {}, \
             \"csr_bytes\": {}, \"parse_s\": {:.9}, \"build_s\": {:.9}}},\n",
            s.format,
            s.dup_policy,
            s.lines,
            s.bytes_read,
            s.parsed_edges,
            s.self_loops,
            s.duplicates,
            s.nodes,
            s.edges,
            s.peak_bytes,
            s.csr_bytes,
            s.parse_s,
            s.build_s
        ));
    }
    out.push_str("  \"trace\": [\n");
    for (k, it) in r.trace.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"iteration\": {}, \"found\": {}, \"merged\": {}, \"remembered\": {}, \
             \"max_violation\": {:e}, \"projections\": {}, \"seconds\": {:.9}, \
             \"oracle_s\": {:.9}, \"sweep_s\": {:.9}, \"forget_s\": {:.9}, \
             \"rows_projected\": {}, \"rows_skipped\": {}}}{}\n",
            it.iteration,
            it.found,
            it.merged,
            it.remembered,
            it.max_violation,
            it.projections,
            it.seconds,
            it.oracle_s,
            it.sweep_s,
            it.forget_s,
            it.rows_projected,
            it.rows_skipped,
            if k + 1 == r.trace.len() { "" } else { "," }
        ));
    }
    if r.telemetry.is_empty() {
        out.push_str("  ]\n}\n");
    } else {
        out.push_str("  ],\n");
        out.push_str(&format!(
            "  \"telemetry\": {}\n}}\n",
            crate::obs::telemetry_json_array(&r.telemetry)
        ));
    }
    out
}

/// Persist a solver result's sampled telemetry frames as
/// `<basename>.csv` under the report directory (plotting-friendly
/// companion to the schema-v6 `telemetry` array). No-op returning
/// `None` when no frames were sampled.
pub fn emit_telemetry_csv(
    r: &SolverResult,
    basename: &str,
) -> std::io::Result<Option<std::path::PathBuf>> {
    if r.telemetry.is_empty() {
        return Ok(None);
    }
    let dir = report_dir();
    std::fs::create_dir_all(&dir)?;
    let path = std::path::Path::new(&dir).join(format!("{basename}.csv"));
    std::fs::write(&path, crate::obs::telemetry_csv(&r.telemetry))?;
    println!("  wrote {}", path.display());
    Ok(Some(path))
}

/// Persist a JSON document as `<basename>.json` under the report
/// directory; returns the written path. Shared by the solver-result and
/// serve-stats emitters.
pub fn emit_json(basename: &str, text: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = report_dir();
    std::fs::create_dir_all(&dir)?;
    let path = std::path::Path::new(&dir).join(format!("{basename}.json"));
    std::fs::write(&path, text)?;
    println!("  wrote {}", path.display());
    Ok(path)
}

/// Persist a solver result as `<basename>.json` under the report
/// directory; returns the written path.
pub fn emit_solver_json(
    r: &SolverResult,
    basename: &str,
) -> std::io::Result<std::path::PathBuf> {
    emit_json(basename, &solver_result_json(basename, r))
}

/// Format a seconds value like the paper's tables (3 significant-ish).
pub fn fmt_time(s: f64) -> String {
    if s < 10.0 {
        format!("{s:.2}")
    } else if s < 100.0 {
        format!("{s:.1}")
    } else {
        format!("{s:.0}")
    }
}

/// Format a byte count as GiB with 2 decimals (Table 2's unit).
pub fn fmt_gib(bytes: u64) -> String {
    format!("{:.2}", bytes as f64 / (1u64 << 30) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::solver::{IterStats, PhaseTimes};

    #[test]
    fn formats() {
        assert_eq!(fmt_time(1.234), "1.23");
        assert_eq!(fmt_time(45.67), "45.7");
        assert_eq!(fmt_time(1649.0), "1649");
        assert_eq!(fmt_gib(1u64 << 30), "1.00");
    }

    #[test]
    fn solver_json_is_parseable_and_versioned() {
        let r = SolverResult {
            x: vec![0.0; 3],
            iterations: 2,
            converged: true,
            total_projections: 5,
            active_constraints: 1,
            trace: vec![
                IterStats {
                    iteration: 0,
                    found: 3,
                    merged: 3,
                    remembered: 1,
                    max_violation: 0.5,
                    projections: 4,
                    seconds: 0.01,
                    oracle_s: 0.004,
                    sweep_s: 0.005,
                    forget_s: 0.001,
                    rows_projected: 6,
                    rows_skipped: 2,
                },
                IterStats { iteration: 1, ..Default::default() },
            ],
            seconds: 0.02,
            phases: PhaseTimes { oracle_s: 0.004, sweep_s: 0.005, forget_s: 0.001 },
            telemetry: Vec::new(),
        };
        let text = solver_result_json("unit", &r);
        let json = crate::runtime::json::Json::parse(&text).expect("invalid JSON");
        assert_eq!(
            json.get("schema_version").and_then(|v| v.as_usize()),
            Some(SOLVER_JSON_SCHEMA_VERSION as usize)
        );
        assert_eq!(json.get("label").and_then(|v| v.as_str()), Some("unit"));
        assert_eq!(json.get("iterations").and_then(|v| v.as_usize()), Some(2));
        let phases = json.get("phases").expect("phases object");
        match phases.get("sweep_s") {
            Some(crate::runtime::json::Json::Num(v)) => assert!((v - 0.005).abs() < 1e-12),
            other => panic!("missing sweep_s: {other:?}"),
        }
        let trace = json.get("trace").and_then(|t| t.as_arr()).expect("trace array");
        assert_eq!(trace.len(), 2);
        assert_eq!(trace[0].get("found").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(trace[0].get("rows_projected").and_then(|v| v.as_usize()), Some(6));
        assert_eq!(trace[0].get("rows_skipped").and_then(|v| v.as_usize()), Some(2));
        match trace[0].get("max_violation") {
            Some(crate::runtime::json::Json::Num(v)) => assert!((v - 0.5).abs() < 1e-12),
            other => panic!("missing max_violation: {other:?}"),
        }
        // No ingest object unless one is supplied, and no telemetry
        // array unless frames were sampled.
        assert!(json.get("ingest").is_none());
        assert!(json.get("telemetry").is_none());
        let stats = IngestStats {
            format: "snap",
            dup_policy: "keep-first",
            lines: 10,
            bytes_read: 200,
            parsed_edges: 8,
            self_loops: 1,
            duplicates: 1,
            nodes: 5,
            edges: 7,
            peak_bytes: 4096,
            csr_bytes: 1024,
            parse_s: 0.001,
            build_s: 0.002,
        };
        let text = solver_result_json_with_ingest("unit-ingest", &r, Some(&stats));
        let json = crate::runtime::json::Json::parse(&text).expect("invalid ingest JSON");
        let ing = json.get("ingest").expect("ingest object");
        assert_eq!(ing.get("format").and_then(|v| v.as_str()), Some("snap"));
        assert_eq!(ing.get("dup_policy").and_then(|v| v.as_str()), Some("keep-first"));
        assert_eq!(ing.get("peak_bytes").and_then(|v| v.as_usize()), Some(4096));
        assert_eq!(ing.get("nodes").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(ing.get("edges").and_then(|v| v.as_usize()), Some(7));
    }

    #[test]
    fn solver_json_carries_sampled_telemetry() {
        use crate::obs::TelemetryFrame;
        let r = SolverResult {
            x: vec![0.0; 2],
            iterations: 4,
            converged: true,
            total_projections: 9,
            active_constraints: 2,
            trace: vec![IterStats::default()],
            seconds: 0.01,
            phases: PhaseTimes::default(),
            telemetry: vec![
                TelemetryFrame {
                    round: 0,
                    max_violation: 0.75,
                    active_rows: 12,
                    dual_l1: 2.5,
                    moved_fraction: 0.5,
                    rows_projected: 24,
                    rows_skipped: 3,
                    forget_evictions: 4,
                },
                TelemetryFrame { round: 2, max_violation: 0.01, ..Default::default() },
            ],
        };
        let text = solver_result_json("telemetry-unit", &r);
        let json = crate::runtime::json::Json::parse(&text).expect("invalid JSON");
        assert_eq!(
            json.get("schema_version").and_then(|v| v.as_usize()),
            Some(SOLVER_JSON_SCHEMA_VERSION as usize)
        );
        let tel = json.get("telemetry").and_then(|t| t.as_arr()).expect("telemetry array");
        assert_eq!(tel.len(), 2);
        assert_eq!(tel[0].get("active_rows").and_then(|v| v.as_usize()), Some(12));
        assert_eq!(tel[0].get("forget_evictions").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(tel[1].get("round").and_then(|v| v.as_usize()), Some(2));
        match tel[0].get("dual_l1") {
            Some(crate::runtime::json::Json::Num(v)) => assert!((v - 2.5).abs() < 1e-12),
            other => panic!("missing dual_l1: {other:?}"),
        }
    }
}
