//! Aligned-text + CSV table emitters for reproducing the paper's tables.
//!
//! Every bench target builds a `Table`, prints it (the "same rows the paper
//! reports") and writes a CSV under `reports/` for EXPERIMENTS.md.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: row from displayable items.
    pub fn rowd<D: std::fmt::Display>(&mut self, cells: &[D]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    /// Render as aligned text.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                let _ = write!(s, "{:<w$}", cells[i], w = widths[i] + 2);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().map(|w| w + 2).sum();
        let _ = writeln!(out, "{}", "-".repeat(total.saturating_sub(2)));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// CSV encoding (quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Print to stdout and persist CSV under the given directory.
    pub fn emit(&self, dir: &str, basename: &str) {
        println!("{}", self.render());
        let dirp = Path::new(dir);
        if std::fs::create_dir_all(dirp).is_ok() {
            let path = dirp.join(format!("{basename}.csv"));
            if let Err(e) = std::fs::write(&path, self.to_csv()) {
                eprintln!("warning: failed writing {}: {e}", path.display());
            } else {
                println!("[saved {}]", path.display());
            }
        }
    }
}

/// A named (x, series...) line plot, emitted as CSV for the paper's figures.
#[derive(Debug, Clone)]
pub struct Series {
    pub title: String,
    pub x_name: String,
    pub series_names: Vec<String>,
    pub points: Vec<(f64, Vec<f64>)>,
}

impl Series {
    pub fn new(title: &str, x_name: &str, series_names: &[&str]) -> Series {
        Series {
            title: title.to_string(),
            x_name: x_name.to_string(),
            series_names: series_names.iter().map(|s| s.to_string()).collect(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, ys: &[f64]) {
        assert_eq!(ys.len(), self.series_names.len());
        self.points.push((x, ys.to_vec()));
    }

    /// Render as a table (the "series the paper reports").
    pub fn to_table(&self) -> Table {
        let mut headers = vec![self.x_name.as_str()];
        headers.extend(self.series_names.iter().map(|s| s.as_str()));
        let mut t = Table::new(&self.title, &headers);
        for (x, ys) in &self.points {
            let mut row = vec![format!("{x}")];
            row.extend(ys.iter().map(|y| format!("{y:.6}")));
            t.row(&row);
        }
        t
    }

    pub fn emit(&self, dir: &str, basename: &str) {
        self.to_table().emit(dir, basename);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_csv() {
        let mut t = Table::new("demo", &["algo", "n", "time"]);
        t.rowd(&["ours", "100", "1.5"]);
        t.rowd(&["baseline", "100", "3.0"]);
        let text = t.render();
        assert!(text.contains("demo"));
        assert!(text.contains("baseline"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("algo,n,time"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("q", &["a"]);
        t.rowd(&["x,y"]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("bad", &["a", "b"]);
        t.rowd(&["only-one"]);
    }

    #[test]
    fn series_to_table() {
        let mut s = Series::new("fig", "iter", &["violation"]);
        s.push(1.0, &[0.5]);
        s.push(2.0, &[0.25]);
        let t = s.to_table();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.headers, vec!["iter", "violation"]);
    }
}
