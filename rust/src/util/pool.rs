//! Scoped data-parallel helpers over `std::thread` (offline stand-in for
//! `rayon`).
//!
//! The paper parallelises the separation oracle (per-source Dijkstra runs)
//! across cores; `parallel_map_chunks` is that primitive. On a single-core
//! box the helpers degrade to the serial path with zero thread overhead.

/// Number of worker threads to use by default (respects `PAF_THREADS`).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PAF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every index in `0..n`, writing results into a `Vec`.
/// `f` must be `Sync` (read-only captured state).
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out = vec![T::default(); n];
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, slot) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = t * chunk;
                for (i, s) in slot.iter_mut().enumerate() {
                    *s = f(base + i);
                }
            });
        }
    });
    out
}

/// Run `f` over contiguous index ranges, one per worker, each producing a
/// partial result; returns the partials in order. Useful when each worker
/// wants to batch its own output (e.g. lists of violated constraints).
pub fn parallel_map_chunks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return vec![f(0..n)];
    }
    let chunk = n.div_ceil(threads);
    let mut ranges = Vec::new();
    let mut start = 0;
    while start < n {
        let end = (start + chunk).min(n);
        ranges.push(start..end);
        start = end;
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| {
                let f = &f;
                scope.spawn(move || f(r))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_matches_serial() {
        let serial: Vec<usize> = (0..1000).map(|i| i * i).collect();
        for threads in [1, 2, 4, 7] {
            assert_eq!(parallel_map(1000, threads, |i| i * i), serial);
        }
    }

    #[test]
    fn map_chunks_cover_everything() {
        for threads in [1, 3, 8] {
            let partials = parallel_map_chunks(100, threads, |r| r.collect::<Vec<_>>());
            let flat: Vec<usize> = partials.into_iter().flatten().collect();
            assert_eq!(flat, (0..100).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_input() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        let parts = parallel_map_chunks(0, 4, |r| r.len());
        assert_eq!(parts.iter().sum::<usize>(), 0);
    }

    #[test]
    fn threads_capped_by_n() {
        // More threads than items must not panic or duplicate work.
        let out = parallel_map(3, 64, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
    }
}
