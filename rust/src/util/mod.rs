//! Self-contained utility substrate.
//!
//! This crate builds fully offline, so the usual ecosystem crates (`rand`,
//! `clap`, `serde`, `rayon`, `criterion`) are replaced by small, focused
//! implementations: a counter-based PRNG with normal/uniform samplers, a
//! CLI argument parser, a `key = value` config format, a persistent
//! work-stealing worker pool, wall-clock instrumentation, table/CSV
//! emitters, and a micro-bench harness used by `benches/`.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod pool;
pub mod rng;
pub mod table;
pub mod timer;
pub mod wire;

pub use rng::Rng;
pub use timer::Stopwatch;
